"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import retrieval as rt
from repro.kernels import ops, ref

SHAPES = [
    # B, S, Hkv, Hq, D, g
    (2, 256, 2, 4, 64, 32),
    (1, 512, 1, 8, 128, 32),
    (2, 128, 4, 4, 32, 16),
    (1, 1024, 2, 2, 128, 64),
    (3, 192, 3, 6, 16, 8),
]


def _inputs(B, S, Hkv, Hq, D, seed=0, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    K = (jax.random.normal(k1, (B, S, Hkv, D)) * jnp.exp(jax.random.normal(k4, (D,)))).astype(dtype)
    V = jax.random.normal(k2, (B, S, Hkv, D), dtype)
    q = jax.random.normal(k3, (B, Hq, D), dtype)
    return q, K, V


@pytest.mark.parametrize("B,S,Hkv,Hq,D,g", SHAPES)
def test_pack_quantize_kernel(B, S, Hkv, Hq, D, g):
    q, K, V = _inputs(B, S, Hkv, Hq, D)
    got = ops.pack_quantize(K, g)
    want = ref.pack_quantize(K, g)
    np.testing.assert_array_equal(np.asarray(got.codes), np.asarray(want.codes))
    np.testing.assert_allclose(
        np.asarray(got.scale, np.float32), np.asarray(want.scale, np.float32), rtol=1e-2
    )
    np.testing.assert_allclose(
        np.asarray(got.zero, np.float32), np.asarray(want.zero, np.float32), rtol=1e-2, atol=1e-2
    )


@pytest.mark.parametrize("B,S,Hkv,Hq,D,g", SHAPES)
def test_fier_score_kernel(B, S, Hkv, Hq, D, g):
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=1)
    qk = ref.pack_quantize(K, g)
    got = np.asarray(ops.fier_score(q, qk))
    want = np.asarray(ref.fier_score(q, qk))
    # bf16 operands accumulate in different orders kernel-vs-ref: compare
    # at score scale (what matters for top-k ranking)
    atol = 2e-2 * np.abs(want).max()
    np.testing.assert_allclose(got, want, atol=atol)


@pytest.mark.parametrize("B,S,Hkv,Hq,D,g", SHAPES)
def test_sparse_attention_kernel(B, S, Hkv, Hq, D, g):
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=2)
    qk = ref.pack_quantize(K, g)
    s = ref.fier_score(q, qk)
    kv_s = rt.reduce_over_query_group(s, Hkv)
    length = jnp.full((B,), S - 7, jnp.int32)
    idx = rt.select_topk(kv_s, min(64, S), length)
    Ks, Vs = rt.gather_kv(K, V, idx)
    got = ops.sparse_attention(q, Ks, Vs, idx, length)
    want = ref.sparse_attention(q, Ks, Vs, idx, length)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernels_dtype_sweep(dtype):
    q, K, V = _inputs(2, 256, 2, 4, 64, seed=3, dtype=dtype)
    qk = ops.pack_quantize(K, 32)
    out_k = ops.fier_attention_decode(q, K, V, qk, budget=64,
                                      length=jnp.array([256, 200], jnp.int32))
    out_r = rt.fier_attention_decode(q, K, V, qk, budget=64,
                                     length=jnp.array([256, 200], jnp.int32))
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
        rtol=5e-2, atol=5e-2,
    )


def test_end_to_end_kernel_path_in_policy():
    """PolicyConfig(use_kernels=True) routes scoring through Pallas."""
    from repro.core.policy import PolicyConfig, build_metadata, decode_attention

    q, K, V = _inputs(2, 256, 2, 4, 64, seed=4)
    length = jnp.array([256, 256], jnp.int32)
    for kernels in (False, True):
        cfg = PolicyConfig(kind="fier", budget=64, group=32, skip_layers=0,
                           use_kernels=kernels)
        meta = build_metadata(K, cfg)
        out = decode_attention(q, K, V, meta, cfg, length, layer=1)
        assert jnp.isfinite(out).all()


# ------------------------------------------------- fused select-and-attend

@pytest.mark.parametrize("B,S,Hkv,Hq,D,g", SHAPES)
def test_topk_select_kernel_matches_oracle(B, S, Hkv, Hq, D, g):
    """Threshold select must return exactly lax.top_k's index *set* —
    including NEG_INF padding ties and sink/recent +inf overrides."""
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=5)
    s = rt.reduce_over_query_group(ref.fier_score(q, ref.pack_quantize(K, g)), Hkv)
    length = jnp.full((B,), max(S // 2, 16), jnp.int32)
    for budget, sink, recent in [(min(64, S), 0, 0), (min(32, S), 4, 8), (S, 0, 0)]:
        got = np.asarray(ops.topk_select(s, budget, length, sink=sink, recent=recent))
        want = np.asarray(ref.topk_select(s, budget, length, sink=sink, recent=recent))
        np.testing.assert_array_equal(np.sort(got, -1), np.sort(want, -1))


@pytest.mark.parametrize("B,S,Hkv,Hq,D,g", SHAPES)
def test_fused_sparse_attention_matches_ref(B, S, Hkv, Hq, D, g):
    """Fused kernel (in-kernel row gather) vs the materialised-gather jnp
    oracle, on identical indices, across GQA shapes."""
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=6)
    qk = ref.pack_quantize(K, g)
    kv_s = rt.reduce_over_query_group(ref.fier_score(q, qk), Hkv)
    length = jnp.full((B,), S - 5, jnp.int32)
    idx = rt.select_topk(kv_s, min(64, S), length)
    got = np.asarray(ops.fused_sparse_attention(q, K, V, idx, length), np.float32)
    want = np.asarray(ref.fused_sparse_attention(q, K, V, idx, length), np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_fused_budget_exceeds_length():
    """budget > valid length: selection padding must be masked identically
    in fused and unfused paths (the degenerate-to-dense edge)."""
    B, S, Hkv, Hq, D = 2, 128, 2, 4, 32
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=7)
    qk = ref.pack_quantize(K, 16)
    length = jnp.array([40, 96], jnp.int32)
    got = np.asarray(
        ops.fused_fier_attention_decode(q, K, V, qk, budget=64, length=length),
        np.float32,
    )
    want = np.asarray(
        rt.fier_attention_decode(q, K, V, qk, budget=64, length=length),
        np.float32,
    )
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)
    full = np.asarray(rt.full_attention_decode(q, K, V, length), np.float32)
    np.testing.assert_allclose(got[0], full[0], rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("B,S,Hkv,Hq,D,g", SHAPES)
def test_fused_pipeline_end_to_end(B, S, Hkv, Hq, D, g):
    """Score kernel → threshold select → fused attend vs the jnp oracle."""
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=8)
    qk = ref.pack_quantize(K, g)
    length = jnp.full((B,), S - 3, jnp.int32)
    budget = min(64, S)
    got = np.asarray(
        ops.fused_fier_attention_decode(q, K, V, qk, budget, length), np.float32
    )
    want = np.asarray(
        rt.fier_attention_decode(q, K, V, qk, budget, length), np.float32
    )
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


def test_fused_policy_dispatch_matches_unfused():
    """PolicyConfig(fused=True) through decode_attention: same tokens of
    attention output as the unfused oracle path."""
    from repro.core.policy import PolicyConfig, build_metadata, decode_attention

    q, K, V = _inputs(2, 256, 2, 4, 64, seed=9)
    length = jnp.array([256, 200], jnp.int32)
    outs = {}
    for fused in (False, True):
        cfg = PolicyConfig(kind="fier", budget=64, group=32, skip_layers=0,
                           fused=fused)
        meta = build_metadata(K, cfg)
        outs[fused] = np.asarray(
            decode_attention(q, K, V, meta, cfg, length, layer=1), np.float32
        )
    np.testing.assert_allclose(outs[True], outs[False], rtol=5e-2, atol=5e-2)
