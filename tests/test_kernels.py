"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracle.

All dispatch goes through the registry API (``CacheView`` + ``DecodePlan``
+ ``ops.retrieve`` / ``ops.attend_selected`` / the ``fier_decode_*``
pipelines); the deprecated boolean-flag entrypoints are covered separately
in tests/test_backends.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import retrieval as rt
from repro.core.policy import CacheView, DecodePlan, PolicyConfig, build_metadata, decode_attention
from repro.kernels import ops, ref

SHAPES = [
    # B, S, Hkv, Hq, D, g
    (2, 256, 2, 4, 64, 32),
    (1, 512, 1, 8, 128, 32),
    (2, 128, 4, 4, 32, 16),
    (1, 1024, 2, 2, 128, 64),
    (3, 192, 3, 6, 16, 8),
]


def _inputs(B, S, Hkv, Hq, D, seed=0, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    K = (jax.random.normal(k1, (B, S, Hkv, D)) * jnp.exp(jax.random.normal(k4, (D,)))).astype(dtype)
    V = jax.random.normal(k2, (B, S, Hkv, D), dtype)
    q = jax.random.normal(k3, (B, Hq, D), dtype)
    return q, K, V


def _retrieve_view(qk, length=None):
    """Metadata-only slab view for retrieval kernels (no K/V operand)."""
    return CacheView.slab(None, None, qk, length)


@pytest.mark.parametrize("B,S,Hkv,Hq,D,g", SHAPES)
def test_pack_quantize_kernel(B, S, Hkv, Hq, D, g):
    q, K, V = _inputs(B, S, Hkv, Hq, D)
    got = ops.pack_quantize(K, g)
    want = ref.pack_quantize(K, g)
    np.testing.assert_array_equal(np.asarray(got.codes), np.asarray(want.codes))
    np.testing.assert_allclose(
        np.asarray(got.scale, np.float32), np.asarray(want.scale, np.float32), rtol=1e-2
    )
    np.testing.assert_allclose(
        np.asarray(got.zero, np.float32), np.asarray(want.zero, np.float32), rtol=1e-2, atol=1e-2
    )


@pytest.mark.parametrize("B,S,Hkv,Hq,D,g", SHAPES)
def test_fier_score_kernel(B, S, Hkv, Hq, D, g):
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=1)
    qk = ref.pack_quantize(K, g)
    got = np.asarray(ops.fier_score(q, qk))
    want = np.asarray(ref.fier_score(q, qk))
    # bf16 operands accumulate in different orders kernel-vs-ref: compare
    # at score scale (what matters for top-k ranking)
    atol = 2e-2 * np.abs(want).max()
    np.testing.assert_allclose(got, want, atol=atol)


@pytest.mark.parametrize("B,S,Hkv,Hq,D,g", SHAPES)
def test_sparse_attention_kernel(B, S, Hkv, Hq, D, g):
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=2)
    qk = ref.pack_quantize(K, g)
    s = ref.fier_score(q, qk)
    kv_s = rt.reduce_over_query_group(s, Hkv)
    length = jnp.full((B,), S - 7, jnp.int32)
    idx = rt.select_topk(kv_s, min(64, S), length)
    Ks, Vs = rt.gather_kv(K, V, idx)
    got = ops.sparse_attention(q, Ks, Vs, idx, length)
    want = ref.sparse_attention(q, Ks, Vs, idx, length)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernels_dtype_sweep(dtype):
    """Kernel-path unfused decode (kernel score + select + kernel attend)
    vs the jnp reference pipeline, f32 and bf16 slabs."""
    q, K, V = _inputs(2, 256, 2, 4, 64, seed=3, dtype=dtype)
    qk = ops.pack_quantize(K, 32)
    length = jnp.array([256, 200], jnp.int32)
    kv = rt.reduce_over_query_group(ops.fier_score(q, qk), K.shape[2])
    idx = rt.select_topk(kv, 64, length)
    Ks, Vs = rt.gather_kv(K, V, idx)
    out_k = ops.sparse_attention(q, Ks, Vs, idx, length)
    out_r = rt.fier_decode_reference(q, K, V, qk, budget=64, length=length)
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
        rtol=5e-2, atol=5e-2,
    )


def test_end_to_end_kernel_path_in_policy():
    """PolicyConfig(use_kernels=True) routes the reference pipeline's
    scoring through Pallas."""
    q, K, V = _inputs(2, 256, 2, 4, 64, seed=4)
    length = jnp.array([256, 256], jnp.int32)
    for kernels in (False, True):
        cfg = PolicyConfig(kind="fier", budget=64, group=32, skip_layers=0,
                           use_kernels=kernels)
        meta = build_metadata(K, cfg)
        view = CacheView.slab(K, V, meta, length)
        out = decode_attention(q, view, DecodePlan.build(cfg), layer=1)
        assert jnp.isfinite(out).all()


# ------------------------------------------------- fused select-and-attend

@pytest.mark.parametrize("B,S,Hkv,Hq,D,g", SHAPES)
def test_topk_select_kernel_matches_oracle(B, S, Hkv, Hq, D, g):
    """Threshold select must return exactly lax.top_k's index *set* —
    including NEG_INF padding ties and sink/recent +inf overrides."""
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=5)
    s = rt.reduce_over_query_group(ref.fier_score(q, ref.pack_quantize(K, g)), Hkv)
    length = jnp.full((B,), max(S // 2, 16), jnp.int32)
    for budget, sink, recent in [(min(64, S), 0, 0), (min(32, S), 4, 8), (S, 0, 0)]:
        got = np.asarray(ops.topk_select(s, budget, length, sink=sink, recent=recent))
        want = np.asarray(ref.topk_select(s, budget, length, sink=sink, recent=recent))
        np.testing.assert_array_equal(np.sort(got, -1), np.sort(want, -1))


@pytest.mark.parametrize("B,S,Hkv,Hq,D,g", SHAPES)
def test_attend_selected_matches_ref(B, S, Hkv, Hq, D, g):
    """Fused kernel (in-kernel row gather) vs the materialised-gather jnp
    oracle, on identical indices, across GQA shapes."""
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=6)
    qk = ref.pack_quantize(K, g)
    kv_s = rt.reduce_over_query_group(ref.fier_score(q, qk), Hkv)
    length = jnp.full((B,), S - 5, jnp.int32)
    idx = rt.select_topk(kv_s, min(64, S), length)
    view = CacheView.slab(K, V, qk, length)
    got = np.asarray(ops.attend_selected(q, view, idx), np.float32)
    want = np.asarray(ref.fused_sparse_attention(q, K, V, idx, length), np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_fused_budget_exceeds_length():
    """budget > valid length: selection padding must be masked identically
    in fused and unfused paths (the degenerate-to-dense edge)."""
    B, S, Hkv, Hq, D = 2, 128, 2, 4, 32
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=7)
    qk = ref.pack_quantize(K, 16)
    length = jnp.array([40, 96], jnp.int32)
    view = CacheView.slab(K, V, qk, length)
    got = np.asarray(ops.fier_decode_one_pass(q, view, 64), np.float32)
    want = np.asarray(
        rt.fier_decode_reference(q, K, V, qk, budget=64, length=length),
        np.float32,
    )
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)
    full = np.asarray(rt.full_attention_decode(q, K, V, length), np.float32)
    np.testing.assert_allclose(got[0], full[0], rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("B,S,Hkv,Hq,D,g", SHAPES)
def test_fused_pipeline_end_to_end(B, S, Hkv, Hq, D, g):
    """One-pass retrieval → fused attend vs the jnp oracle pipeline."""
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=8)
    qk = ref.pack_quantize(K, g)
    length = jnp.full((B,), S - 3, jnp.int32)
    budget = min(64, S)
    view = CacheView.slab(K, V, qk, length)
    got = np.asarray(ops.fier_decode_one_pass(q, view, budget), np.float32)
    want = np.asarray(
        rt.fier_decode_reference(q, K, V, qk, budget, length), np.float32
    )
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


def test_fused_policy_dispatch_matches_unfused():
    """pipeline='one_pass' through decode_attention: same tokens of
    attention output as the reference (oracle) pipeline."""
    q, K, V = _inputs(2, 256, 2, 4, 64, seed=9)
    length = jnp.array([256, 200], jnp.int32)
    outs = {}
    for pipeline in ("reference", "one_pass"):
        cfg = PolicyConfig(kind="fier", budget=64, group=32, skip_layers=0,
                           pipeline=pipeline)
        meta = build_metadata(K, cfg)
        view = CacheView.slab(K, V, meta, length)
        outs[pipeline] = np.asarray(
            decode_attention(q, view, DecodePlan.build(cfg), layer=1), np.float32
        )
    np.testing.assert_allclose(
        outs["one_pass"], outs["reference"], rtol=5e-2, atol=5e-2
    )


# ------------------------------------------------------ one-pass retrieval

def _kernel_score_oracle(q, qk, Hkv, budget, length, *, group_reduce="max",
                         sink=0, recent=0):
    """select_topk over the *kernel's own* scores (ops.fier_score is
    bit-identical to the in-kernel scorer — shared score_block), the
    exact-index-set contract of the one-pass kernel."""
    kv = rt.reduce_over_query_group(ops.fier_score(q, qk), Hkv, group_reduce)
    return rt.select_topk(kv, budget, length, sink=sink, recent=recent)


@pytest.mark.parametrize("B,S,Hkv,Hq,D,g", SHAPES)
@pytest.mark.parametrize("group_reduce", ["max", "sum"])
def test_retrieve_exact_index_set(B, S, Hkv, Hq, D, g, group_reduce):
    """One-pass retrieval must return exactly the lax.top_k index set over
    the masked, group-reduced kernel scores — budget==S, sink/recent
    overrides and NEG_INF length-padding ties included."""
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=10)
    qk = ref.pack_quantize(K, g)
    length = jnp.full((B,), max(S // 2, 16), jnp.int32)
    for budget, sink, recent in [(min(64, S), 0, 0), (min(32, S), 4, 8), (S, 0, 0)]:
        got = np.asarray(ops.retrieve(
            q, _retrieve_view(qk, length), budget, group_reduce=group_reduce,
            sink=sink, recent=recent,
        ))
        want = np.asarray(_kernel_score_oracle(
            q, qk, Hkv, budget, length, group_reduce=group_reduce,
            sink=sink, recent=recent,
        ))
        np.testing.assert_array_equal(np.sort(got, -1), np.sort(want, -1))


@pytest.mark.parametrize("B,S,Hkv,Hq,D,g", SHAPES)
def test_retrieve_matches_jnp_oracle(B, S, Hkv, Hq, D, g):
    """And the ref.py oracle (fully materialised jnp pipeline) agrees on
    random inputs: approx_scores is built to round identically."""
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=11)
    qk = ref.pack_quantize(K, g)
    length = jnp.full((B,), S - 5, jnp.int32)
    budget = min(48, S)
    view = _retrieve_view(qk, length)
    got = np.asarray(ops.retrieve(q, view, budget))
    want = np.asarray(ref.retrieve(q, view, budget))
    np.testing.assert_array_equal(np.sort(got, -1), np.sort(want, -1))


def test_retrieve_adversarial_ties():
    """Duplicate-score ties straddling τ: K built from a handful of
    repeated prototype tokens → exactly tied scores, with the budget
    cutting through a tie class.  The index set (first ties in ascending
    position, lax.top_k's convention) must still match exactly."""
    B, Hkv, Hq, D, g = 2, 2, 4, 32, 8
    protos = jax.random.normal(jax.random.PRNGKey(12), (4, Hkv, D))
    S = 128
    K = jnp.tile(protos, (S // 4, 1, 1))[None].repeat(B, 0)  # [B,S,Hkv,D]
    q, _, _ = _inputs(B, S, Hkv, Hq, D, seed=13)
    qk = ref.pack_quantize(K, g)
    length = jnp.full((B,), S, jnp.int32)
    view = _retrieve_view(qk, length)
    for budget in (3, 7, 32, 50, S):  # cut inside every tie class size
        got = np.asarray(ops.retrieve(q, view, budget))
        want = np.asarray(_kernel_score_oracle(q, qk, Hkv, budget, length))
        want2 = np.asarray(ref.retrieve(q, view, budget))
        np.testing.assert_array_equal(np.sort(got, -1), np.sort(want, -1))
        np.testing.assert_array_equal(np.sort(got, -1), np.sort(want2, -1))


def test_retrieve_all_tied_scores():
    """q = 0 → every score is the per-group constant 0·z = 0: the whole
    row ties and the kernel must pick the first `budget` positions."""
    B, S, Hkv, Hq, D, g = 1, 96, 1, 2, 16, 8
    _, K, _ = _inputs(B, S, Hkv, Hq, D, seed=14)
    q = jnp.zeros((B, Hq, D))
    qk = ref.pack_quantize(K, g)
    got = np.asarray(ops.retrieve(
        q, _retrieve_view(qk, jnp.full((B,), S, jnp.int32)), 24
    ))
    np.testing.assert_array_equal(np.sort(got, -1)[0, 0], np.arange(24))


def test_retrieve_budget_exceeds_length():
    """budget > valid length: NEG_INF padding participates in selection
    (tie class at the floor) exactly as in the oracle."""
    B, S, Hkv, Hq, D, g = 2, 128, 2, 4, 32, 16
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=15)
    qk = ref.pack_quantize(K, g)
    length = jnp.array([40, 96], jnp.int32)
    got = np.asarray(ops.retrieve(q, _retrieve_view(qk, length), 64))
    want = np.asarray(_kernel_score_oracle(q, qk, Hkv, 64, length))
    np.testing.assert_array_equal(np.sort(got, -1), np.sort(want, -1))


def test_retrieve_sink_recent_overlap():
    """sink ∪ recent covering (and overlapping within) a short valid
    prefix: a +inf tie class larger than the distinct-score region."""
    B, S, Hkv, Hq, D, g = 1, 128, 2, 4, 32, 8
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=16)
    qk = ref.pack_quantize(K, g)
    length = jnp.array([20], jnp.int32)
    view = _retrieve_view(qk, length)
    for budget, sink, recent in [(16, 8, 16), (20, 8, 16), (64, 12, 12)]:
        got = np.asarray(ops.retrieve(q, view, budget, sink=sink, recent=recent))
        want = np.asarray(_kernel_score_oracle(
            q, qk, Hkv, budget, length, sink=sink, recent=recent
        ))
        np.testing.assert_array_equal(np.sort(got, -1), np.sort(want, -1))


def test_retrieve_stats_and_no_length():
    """return_stats: τ is the budget-th largest masked score and m the
    strictly-greater count; length=None selects over the whole row."""
    B, S, Hkv, Hq, D, g = 2, 256, 2, 4, 64, 32
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=17)
    qk = ref.pack_quantize(K, g)
    budget = 32
    idx, tau, m = ops.retrieve(
        q, _retrieve_view(qk, None), budget, return_stats=True
    )
    kv = np.asarray(rt.reduce_over_query_group(ops.fier_score(q, qk), Hkv))
    srt = np.sort(kv, axis=-1)[:, :, ::-1]
    np.testing.assert_array_equal(np.asarray(tau), srt[:, :, budget - 1])
    np.testing.assert_array_equal(
        np.asarray(m), (kv > np.asarray(tau)[:, :, None]).sum(-1)
    )
    want = np.asarray(rt.select_topk(jnp.asarray(kv), budget))
    np.testing.assert_array_equal(
        np.sort(np.asarray(idx), -1), np.sort(want, -1)
    )


@pytest.mark.parametrize("B,S,Hkv,Hq,D,g", SHAPES)
def test_onepass_attention_bit_identical(B, S, Hkv, Hq, D, g):
    """Acceptance: the one-pass decode returns *bit-identical* attention
    outputs to the two-pass fused pipeline (same scores → same index set
    in the same compaction order → same attend kernel)."""
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=18)
    qk = ref.pack_quantize(K, g)
    length = jnp.full((B,), S - 3, jnp.int32)
    budget = min(64, S)
    view = CacheView.slab(K, V, qk, length)
    one = np.asarray(ops.fier_decode_one_pass(q, view, budget))
    two = np.asarray(ops.fier_decode_two_pass(q, view, budget))
    np.testing.assert_array_equal(one, two)


def test_onepass_pipeline_matches_jnp_oracle():
    """End-to-end one-pass decode vs the jnp oracle pipeline (tolerance:
    attend numerics differ kernel-vs-ref)."""
    B, S, Hkv, Hq, D, g = 2, 256, 2, 4, 64, 32
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=19)
    qk = ref.pack_quantize(K, g)
    length = jnp.full((B,), S - 3, jnp.int32)
    view = CacheView.slab(K, V, qk, length)
    got = np.asarray(ops.fier_decode_one_pass(q, view, 64), np.float32)
    want = np.asarray(rt.fier_decode_reference(
        q, K, V, qk, 64, length
    ), np.float32)
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


def test_onepass_policy_dispatch():
    """pipeline='one_pass' — the serving default — dispatches through
    decode_attention and matches the two_pass plan bitwise."""
    q, K, V = _inputs(2, 256, 2, 4, 64, seed=20)
    length = jnp.array([256, 200], jnp.int32)
    outs = {}
    for pipeline in ("two_pass", "one_pass"):
        cfg = PolicyConfig(kind="fier", budget=64, group=32, skip_layers=0,
                           pipeline=pipeline)
        meta = build_metadata(K, cfg)
        view = CacheView.slab(K, V, meta, length)
        outs[pipeline] = np.asarray(
            decode_attention(q, view, DecodePlan.build(cfg), layer=1), np.float32
        )
    np.testing.assert_array_equal(outs["one_pass"], outs["two_pass"])
