"""Data substrate: streams, passkey structure, tokenizer."""
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.data.passkey import MARK_OPEN, N_DIGITS, QUERY, make_passkey_batch
from repro.data.pipeline import lm_tokens
from repro.data.tokenizer import BOS, VOCAB_SIZE, decode, encode


def test_lm_tokens_in_vocab_and_learnable():
    toks = np.asarray(lm_tokens(0, 0, 4, 128, 512))
    assert toks.shape == (4, 129)
    assert toks.min() >= 0 and toks.max() < 512
    # bigram structure: successors are drawn from ≤8 options per token
    succ = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            succ.setdefault(int(a), set()).add(int(b))
    branching = np.mean([len(v) for v in succ.values()])
    assert branching <= 8.01


def test_passkey_structure():
    cfg = reduced_config("olmo-1b")
    batch, answers = make_passkey_batch(cfg, 4, 128, seed=0, step=0, depth=0.4)
    toks = np.asarray(batch["tokens"])
    for b in range(4):
        pos = int(np.where(toks[b] == MARK_OPEN)[0][0])
        np.testing.assert_array_equal(
            toks[b, pos + 1 : pos + 1 + N_DIGITS], np.asarray(answers)[b]
        )
        assert QUERY in toks[b]
        np.testing.assert_array_equal(toks[b, -N_DIGITS:], np.asarray(answers)[b])
    # the loss mask covers exactly the answer-predicting positions
    assert float(batch["loss_mask"].sum(axis=1)[0]) == N_DIGITS


def test_tokenizer_roundtrip():
    text = "FIER retrieves 1-bit keys — ünïcode too."
    ids = encode(text)
    assert ids[0] == BOS and max(ids) < VOCAB_SIZE
    assert decode(ids) == text
