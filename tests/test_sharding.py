"""Sharding plan: every param of every arch gets a divisible PartitionSpec
on the production meshes (AbstractMesh — no devices needed)."""
import jax
import jax.numpy as jnp
import pytest

from repro.compat import abstract_mesh
from repro.configs import ARCHS, get_config, padded_vocab
from repro.launch.sharding import param_pspec, _path_str

MESH_1POD = abstract_mesh((16, 16), ("data", "model"))
MESH_2POD = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _axis_size(mesh, name):
    return dict(zip(mesh.axis_names, mesh.axis_sizes))[name]


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("mesh", [MESH_1POD, MESH_2POD], ids=["1pod", "2pod"])
def test_param_specs_divisible(arch, mesh):
    from repro.models import build_model

    cfg = get_config(arch)
    bundle = build_model(cfg, max_positions=64)
    shapes = jax.eval_shape(bundle.init, jax.random.key(0))
    fsdp = ("data",)
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    assert flat, arch
    for path, leaf in flat:
        spec = param_pspec(_path_str(path), len(leaf.shape), fsdp)
        for dim, names in enumerate(spec):
            if names is None:
                continue
            names = names if isinstance(names, tuple) else (names,)
            total = 1
            for n in names:
                total *= _axis_size(mesh, n)
            assert leaf.shape[dim] % total == 0, (
                f"{arch}: {_path_str(path)} dim {dim} ({leaf.shape[dim]}) "
                f"not divisible by {names} ({total})"
            )


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_padded_vocab_divisible(arch):
    cfg = get_config(arch)
    assert padded_vocab(cfg) % 256 == 0
    assert padded_vocab(cfg) >= cfg.vocab


def test_kv_cache_seq_dims_divisible():
    """decode KV sequence sharding: 32k and 500k caches divide the shard
    counts and keep whole quantization groups per shard."""
    for S, shards in ((32_768, 16), (524_288, 256), (524_288, 512)):
        S_loc = S // shards
        assert S % shards == 0
        assert S_loc % 32 == 0, "FIER group must not straddle shards"
        assert S_loc % 8 == 0, "packing byte must not straddle shards"
