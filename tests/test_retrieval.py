"""FIER retrieval: score identity, top-k semantics, end-to-end equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize as qz
from repro.core import retrieval as rt


def _setup(seed=0, B=2, S=256, Hkv=2, Hq=4, D=64, g=32):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    K = jax.random.normal(k1, (B, S, Hkv, D)) * jnp.exp(jax.random.normal(k4, (D,)))
    V = jax.random.normal(k2, (B, S, Hkv, D))
    q = jax.random.normal(k3, (B, Hq, D))
    return q, K, V, qz.quantize(K, g)


def test_approx_equals_dequantized_exact():
    """s̃ computed from packed codes == q·K̃ᵀ with f32 dequantization (the
    score path never rounds K̃ to bf16; only (s, z) storage is bf16)."""
    q, K, V, qk = _setup()
    s1 = rt.approx_scores(q, qk)
    bits = qz.unpack_bits(qk.codes).astype(jnp.float32) * 2.0 - 1.0
    s32 = jnp.repeat(qk.scale.astype(jnp.float32), qk.group, axis=1)
    z32 = jnp.repeat(qk.zero.astype(jnp.float32), qk.group, axis=1)
    s2 = np.asarray(rt.exact_scores(q, bits * s32 + z32))
    s1 = np.asarray(s1)
    # the score path uses bf16 operands with f32 accumulation (MXU
    # contract): compare at score scale
    np.testing.assert_allclose(s1, s2, atol=5e-3 * np.abs(s2).max())


def test_approx_scores_blockwise_independent_of_block():
    import repro.core.retrieval as R

    q, K, V, qk = _setup(S=512)
    old = R.APPROX_SCORE_BLOCK
    try:
        R.APPROX_SCORE_BLOCK = 64
        s_small = rt.approx_scores(q, qk)
        R.APPROX_SCORE_BLOCK = 512
        s_big = rt.approx_scores(q, qk)
    finally:
        R.APPROX_SCORE_BLOCK = old
    np.testing.assert_allclose(np.asarray(s_small), np.asarray(s_big), atol=1e-5)


def test_budget_equals_length_recovers_full():
    """With budget ≥ valid length, FIER must equal full attention exactly
    (selection is a no-op; paper Alg. 1 degenerates to dense)."""
    q, K, V, qk = _setup(S=128)
    length = jnp.array([100, 64], jnp.int32)
    full = rt.full_attention_decode(q, K, V, length)
    fier = rt.fier_decode_reference(q, K, V, qk, budget=128, length=length)
    np.testing.assert_allclose(np.asarray(full), np.asarray(fier), atol=1e-3, rtol=1e-3)


def test_select_topk_masks_invalid():
    q, K, V, qk = _setup()
    scores = rt.exact_scores(q, K)
    kv = rt.reduce_over_query_group(scores, K.shape[2])
    length = jnp.array([64, 32], jnp.int32)
    idx = rt.select_topk(kv, budget=16, length=length)
    assert (np.asarray(idx)[0] < 64).all()
    assert (np.asarray(idx)[1] < 32).all()


def test_sink_and_recent_forced():
    q, K, V, qk = _setup()
    scores = jnp.zeros((2, 2, 256))  # flat scores: selection is arbitrary
    length = jnp.array([200, 200], jnp.int32)
    idx = np.asarray(rt.select_topk(scores, 16, length, sink=4, recent=4))
    for b in range(2):
        for h in range(2):
            s = set(idx[b, h].tolist())
            assert {0, 1, 2, 3} <= s, "sink tokens must be selected"
            assert {196, 197, 198, 199} <= s, "recent tokens must be selected"


def test_gqa_reduction_modes():
    q, K, V, qk = _setup(Hq=8, Hkv=2)
    s = rt.approx_scores(q, qk)
    for mode in ("max", "sum"):
        r = rt.reduce_over_query_group(s, 2, mode)
        assert r.shape == (2, 2, 256)
    with pytest.raises(ValueError):
        rt.reduce_over_query_group(s, 2, "min")


def test_fier_recall_beats_quest_at_matched_load_ratio():
    """The paper's central comparison (Fig. 6 / Tab. 3): token-level 1-bit
    retrieval recalls more true top-k tokens than page-level min/max at the
    same cache-load ratio (FIER g=32 ↔ Quest p=16, both 1/8)."""
    from repro.core import quest

    B, S, Hkv, Hq, D = 1, 2048, 2, 4, 128
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    chan = jnp.exp(jax.random.normal(k3, (D,)))
    K = jax.random.normal(k1, (B, S, Hkv, D)) * chan
    q = jax.random.normal(k2, (B, Hq, D)) * chan
    exact = np.asarray(rt.exact_scores(q, K))
    top = np.argsort(-exact, axis=-1)[..., :64]

    fier = np.asarray(rt.approx_scores(q, qz.quantize(K, 32)))
    fier_top = np.argsort(-fier, axis=-1)[..., :64]

    meta = quest.build_page_meta(K, 16)
    ps = np.asarray(quest.page_scores(q, meta))
    quest_sel = []
    for h in range(Hq):
        pages = np.argsort(-ps[0, h])[:4]
        sel = set()
        for p in pages:
            sel |= set(range(p * 16, (p + 1) * 16))
        quest_sel.append(sel)

    def recall(sel_sets):
        return np.mean([
            len(set(top[0, h]) & sel_sets[h]) / 64 for h in range(Hq)
        ])

    r_fier = recall([set(fier_top[0, h]) for h in range(Hq)])
    r_quest = recall(quest_sel)
    assert r_fier > r_quest, (r_fier, r_quest)
