"""Backend registry: the (policy × layout × pipeline) compatibility
matrix against the kernels/ref.py oracle, plan validation, and the
deprecation shims for the pre-registry boolean-flag API.

This file is the home of the compat-shim tests — it is the only place
outside the shims themselves allowed to spell the deprecated
``fused=`` / ``one_pass=`` / ``paged=`` kwargs.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import policy as pol
from repro.core import quantize as qz
from repro.core import retrieval as rt
from repro.core.policy import (
    CacheView,
    DecodePlan,
    PolicyConfig,
    UnsupportedPlanError,
    build_metadata,
    decode_attention,
    get_backend,
    registered_backends,
)
from repro.kernels import ops, ref

# (B, S, Hkv, Hq, D, g, bs): the GQA grid of test_kernels with a cache
# block size dividing S (bs % 8 == 0, bs % g == 0) for the paged combos
GRID = [
    (2, 256, 2, 4, 64, 32, 32),
    (1, 512, 1, 8, 128, 32, 64),
    (2, 128, 4, 4, 32, 16, 16),
    (1, 1024, 2, 2, 128, 64, 128),
    (3, 192, 3, 6, 16, 8, 24),
]

COMBOS = [
    (name, layout, pipeline)
    for name in registered_backends()
    for layout, pipeline in sorted(get_backend(name).supports)
]


def _inputs(B, S, Hkv, Hq, D, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    K = jax.random.normal(k1, (B, S, Hkv, D), jnp.bfloat16)
    V = jax.random.normal(k2, (B, S, Hkv, D), jnp.bfloat16)
    q = jax.random.normal(k3, (B, Hq, D))
    return q, K, V


def _slab_to_pool(arr, table, N):
    B, S = arr.shape[:2]
    nb = table.shape[1]
    pb = S // nb
    pool = jnp.zeros((N, pb, *arr.shape[2:]), arr.dtype)
    blocks = arr.reshape(B, nb, pb, *arr.shape[2:])
    return pool.at[table.reshape(-1)].set(blocks.reshape(B * nb, pb, *arr.shape[2:]))


def _make_view(layout, K, V, meta, length, bs, seed=0):
    """A CacheView over the given logical contents in either layout (the
    paged pool scatters the slab's blocks at a permuted physical order)."""
    if layout == "slab":
        return CacheView.slab(K, V, meta, length)
    B, S = K.shape[:2]
    nb = S // bs
    N = B * nb + 1
    rng = np.random.default_rng(seed)
    table = jnp.asarray(1 + rng.permutation(B * nb).reshape(B, nb), jnp.int32)
    pk, pv = _slab_to_pool(K, table, N), _slab_to_pool(V, table, N)
    pmeta = meta
    if meta is not None:
        pmeta = qz.QuantizedKeys(
            _slab_to_pool(meta.codes, table, N),
            _slab_to_pool(meta.scale, table, N),
            _slab_to_pool(meta.zero, table, N),
            meta.group,
        )
    return CacheView.paged(pk, pv, pmeta, table, length)


def _combo_out(name, layout, pipeline, B, S, Hkv, Hq, D, g, bs, seed=0):
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=seed)
    cfg = PolicyConfig(
        kind=name, budget=min(64, S), group=g, page=8, skip_layers=0,
        pipeline=pipeline, layout=layout, block_size=bs,
    )
    meta = build_metadata(K, cfg)
    view = _make_view(layout, K, V, meta, jnp.full((B,), S - 3, jnp.int32), bs)
    plan = DecodePlan.build(cfg)
    out = decode_attention(q, view, plan, layer=1)
    # the oracle always evaluates the reference pipeline over the logical
    # slab contents (ref.decode_attention materialises paged views)
    oracle = ref.decode_attention(q, view, plan)
    return q, view, plan, np.asarray(out), np.asarray(oracle)


@pytest.mark.parametrize("name,layout,pipeline", COMBOS)
@pytest.mark.parametrize("B,S,Hkv,Hq,D,g,bs", GRID)
def test_matrix_combo_matches_oracle(name, layout, pipeline, B, S, Hkv, Hq, D, g, bs):
    """Every registered (policy, layout, pipeline) combination agrees with
    the kernels/ref.py oracle across the GQA grid: bit-identical for the
    reference pipelines (same jnp ops on the same logical contents —
    gathering a paged pool is exact), and for the kernel pipelines an
    exact index *set* (asserted below via ops.retrieve vs ref.retrieve)
    with attend-kernel tolerance on the output."""
    _, _, _, out, oracle = _combo_out(name, layout, pipeline, B, S, Hkv, Hq, D, g, bs)
    if pipeline == "reference":
        np.testing.assert_array_equal(out, oracle)
    else:
        np.testing.assert_allclose(
            out.astype(np.float32), oracle.astype(np.float32),
            rtol=5e-2, atol=5e-2,
        )


@pytest.mark.parametrize("B,S,Hkv,Hq,D,g,bs", GRID)
def test_matrix_fier_pipelines_bit_identical(B, S, Hkv, Hq, D, g, bs):
    """Within the fier backend the kernel pipelines are *bit-identical*
    across every registered combo that shares the logical cache contents:
    slab one_pass == slab two_pass == paged one_pass (same scores → same
    index set in the same compaction order → same attend kernel), and the
    paged reference gather reproduces the slab reference bitwise."""
    outs = {}
    for layout, pipeline in sorted(get_backend("fier").supports):
        *_, out, _ = _combo_out("fier", layout, pipeline, B, S, Hkv, Hq, D, g, bs)
        outs[(layout, pipeline)] = out
    np.testing.assert_array_equal(
        outs[("slab", "one_pass")], outs[("slab", "two_pass")]
    )
    np.testing.assert_array_equal(
        outs[("slab", "one_pass")], outs[("paged", "one_pass")]
    )
    np.testing.assert_array_equal(
        outs[("slab", "reference")], outs[("paged", "reference")]
    )


@pytest.mark.parametrize("layout", ["slab", "paged"])
def test_matrix_retrieval_exact_index_set(layout):
    """The retrieval stage of the kernel pipelines returns exactly the
    oracle's index set in both layouts (the bit-level half of the matrix
    contract that the attend-tolerance comparison above cannot see)."""
    B, S, Hkv, Hq, D, g, bs = GRID[0]
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=3)
    meta = qz.quantize(K.astype(jnp.float32), g)
    view = _make_view(layout, K, V, meta, jnp.full((B,), S - 5, jnp.int32), bs)
    for budget, sink, recent in [(64, 0, 0), (32, 4, 8)]:
        got = np.asarray(ops.retrieve(q, view, budget, sink=sink, recent=recent))
        want = np.asarray(ref.retrieve(q, view, budget, sink=sink, recent=recent))
        np.testing.assert_array_equal(np.sort(got, -1), np.sort(want, -1))


def test_registry_contents():
    assert registered_backends() == ("full", "fier", "quest", "slm")
    assert pol.POLICIES == registered_backends()
    assert get_backend("fier").supports == frozenset({
        ("slab", "reference"), ("slab", "two_pass"), ("slab", "one_pass"),
        ("paged", "reference"), ("paged", "one_pass"),
    })


def test_third_party_backend_registration():
    """A backend registered from outside the repo plugs into the same
    dispatch: DecodePlan resolves it and decode_attention routes to it."""
    calls = []

    def dummy_decode(q, view, plan):
        calls.append(plan.pipeline)
        K, V, _ = view.logical()
        return rt.full_attention_decode(q, K, V, view.length)

    backend = pol.AttentionBackend(
        name="thirdparty",
        supports=frozenset({("slab", "reference")}),
        build_metadata=lambda K, cfg: None,
        update_metadata=lambda meta, K, pos, cfg: meta,
        decode=dummy_decode,
        needs_metadata=False,  # metadata-less: decode must still be called
    )
    pol.register_backend(backend)
    try:
        with pytest.raises(ValueError, match="already registered"):
            pol.register_backend(backend)
        import repro.core as core

        assert "thirdparty" in pol.POLICIES
        assert "thirdparty" in core.POLICIES  # lazy re-export, not frozen
        cfg = PolicyConfig(kind="thirdparty", budget=16, skip_layers=0)
        plan = DecodePlan.build(cfg)
        q, K, V = _inputs(1, 64, 2, 4, 16, seed=5)
        out = decode_attention(
            q, CacheView.slab(K, V, None, jnp.array([64], jnp.int32)), plan
        )
        # needs_metadata=False routed a meta-less view to the backend's
        # own decode, not the dense fallback
        assert calls == ["reference"]
        assert np.isfinite(np.asarray(out)).all()
        with pytest.raises(UnsupportedPlanError):
            DecodePlan.build(cfg, layout="paged")
    finally:
        pol._REGISTRY.pop("thirdparty", None)
        pol.POLICIES = pol.registered_backends()


# ------------------------------------------------------------ plan validation

def test_unsupported_plan_lists_matrix():
    """quest on a paged cache (or any kernel pipeline) must raise a clear
    UnsupportedPlanError listing the supported matrix — the old dispatch
    silently fell through to the unfused slab path."""
    cfg = PolicyConfig(kind="quest", budget=16, page=8)
    with pytest.raises(UnsupportedPlanError, match=r"slab×reference"):
        DecodePlan.build(cfg, layout="paged")
    with pytest.raises(UnsupportedPlanError, match="quest"):
        DecodePlan.build(cfg, pipeline="one_pass")
    with pytest.raises(UnsupportedPlanError):
        DecodePlan.build(
            PolicyConfig(kind="fier", budget=16), layout="paged",
            pipeline="two_pass",
        )


def test_quest_fused_flags_raise_not_fall_through():
    """The legacy flag spelling of quest+fused/paged now raises instead of
    silently running the slab reference path."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        cfg = PolicyConfig(kind="quest", budget=16, page=8, fused=True)
    assert cfg.pipeline == "one_pass"
    with pytest.raises(UnsupportedPlanError, match="supported"):
        DecodePlan.build(cfg)
    from repro.models import build_model
    from repro.configs import reduced_config

    with pytest.raises(UnsupportedPlanError):
        build_model(reduced_config("olmo-1b"), cfg)


def test_block_size_validation_hoisted_to_plan_build():
    """PolicyConfig no longer import-validates block_size in
    __post_init__; DecodePlan.build owns it (and the error is as clear)."""
    cfg = PolicyConfig(kind="fier", group=32, layout="paged", block_size=12)
    with pytest.raises(ValueError, match="divisible by 8"):
        DecodePlan.build(cfg)
    with pytest.raises(ValueError, match="divisible by group"):
        DecodePlan.build(
            PolicyConfig(kind="fier", group=32, layout="paged", block_size=16)
        )
    DecodePlan.build(PolicyConfig(kind="fier", group=32, layout="paged",
                                  block_size=64))  # divisible: fine


def test_budget_validated_against_capacity():
    """Over-budget configs fail at plan/capacity validation time with a
    clear message, not deep inside the kernel at the first decode step.
    sink/recent are score overrides clamped by decode-time masking, so
    any value stays valid at any capacity (the pre-registry behaviour)."""
    cfg = PolicyConfig(kind="fier", budget=128, group=8, skip_layers=1)
    with pytest.raises(ValueError, match="budget 128 exceeds"):
        DecodePlan.build(cfg, capacity=64)
    DecodePlan.build(cfg, capacity=128)  # fits: fine
    DecodePlan.build(  # oversized guard-rails are masked, not rejected
        PolicyConfig(kind="fier", budget=32, group=8, sink=4, recent=128),
        capacity=64,
    )


def test_engine_and_init_cache_validate_capacity():
    from repro.configs import reduced_config
    from repro.models import build_model
    from repro.serving import Engine

    cfg = reduced_config("olmo-1b")
    bundle = build_model(
        cfg, PolicyConfig(kind="fier", budget=128, group=8, skip_layers=1)
    )
    with pytest.raises(ValueError, match="budget 128 exceeds"):
        Engine(bundle, n_slots=2, capacity=64)
    with pytest.raises(ValueError, match="budget 128 exceeds"):
        bundle.init_cache(2, 64, 0)


def test_engine_build_serving_defaults_at_small_capacity():
    """Engine.build with no explicit policy must serve at any capacity:
    the budget clamps and the default sink/recent guard-rails (4/64)
    pass validation unchanged (masking clamps them at decode time)."""
    from repro.configs import reduced_config
    from repro.serving import Engine

    eng = Engine.build(reduced_config("olmo-1b"), n_slots=2, capacity=32)
    p = eng.bundle.policy
    assert p.budget <= 32 and (p.sink, p.recent) == (4, 64)
    # and an explicit policy with oversized guard-rails also constructs
    from repro.serving import serving_policy

    Engine.build(reduced_config("olmo-1b"), n_slots=2, capacity=32,
                 policy=serving_policy(budget=32))


def test_serving_policy_legacy_kwargs_forward(fresh_warnings):
    """serving_policy's old fused=/one_pass= booleans translate onto
    pipeline with a deprecation warning (not a TypeError)."""
    from repro.serving import serving_policy

    p, _ = _assert_warns_exactly_once(lambda: serving_policy(one_pass=False))
    assert p.pipeline == "two_pass"
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        assert serving_policy(fused=False).pipeline == "reference"
        assert serving_policy(fused=True).pipeline == "one_pass"
        assert serving_policy(fused=True, one_pass=True).pipeline == "one_pass"
    assert serving_policy().pipeline == "one_pass"  # flag-free: no warning


def test_engine_build_paged_kwarg_forwards(fresh_warnings):
    """The PR 3 spelling Engine.build(..., paged=True) forwards onto
    layout='paged' with a deprecation warning instead of a TypeError in
    build_model."""
    from repro.configs import reduced_config
    from repro.serving import Engine

    cfg = reduced_config("olmo-1b")
    eng, _ = _assert_warns_exactly_once(
        lambda: Engine.build(
            cfg, n_slots=2, capacity=64, paged=True, block_size=32,
        )
    )
    assert eng.paged and eng.bundle.policy.layout == "paged"
    # and the pre-registry spelling of the two_pass+paged combo — a
    # two_pass policy paged via the deprecated kwarg — keeps serving on
    # the one-pass kernels (old paged dispatch ignored the flag)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.serving import serving_policy

        eng2 = Engine.build(
            cfg, n_slots=2, capacity=64,
            policy=serving_policy(budget=16, group=8, skip_layers=1,
                                  one_pass=False),
            paged=True, block_size=8,
        )
    assert eng2.paged and eng2.bundle.policy.pipeline == "one_pass"


def test_plan_view_layout_mismatch_rejected():
    """A plan validated for one layout cannot silently decode a view of
    the other: decode_attention cross-checks plan.layout vs view.layout."""
    B, S, Hkv, Hq, D, g, bs = GRID[2]
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=21)
    cfg = PolicyConfig(kind="fier", budget=32, group=g, skip_layers=0,
                       block_size=bs)
    meta = build_metadata(K, cfg)
    view = CacheView.slab(K, V, meta, jnp.full((B,), S, jnp.int32))
    plan = DecodePlan.build(cfg, layout="paged", pipeline="one_pass")
    with pytest.raises(UnsupportedPlanError, match="does not match view"):
        decode_attention(q, view, plan, layer=1)


def test_invalid_pipeline_and_layout_strings_rejected():
    with pytest.raises(ValueError, match="unknown pipeline"):
        PolicyConfig(kind="fier", pipeline="fused")
    with pytest.raises(ValueError, match="unknown layout"):
        PolicyConfig(kind="fier", layout="pooled")


# ------------------------------------------------------------- compat shims

@pytest.fixture()
def fresh_warnings(monkeypatch):
    """Reset the warn-once registry so each shim's first call in this test
    re-warns regardless of what earlier tests touched."""
    monkeypatch.setattr(pol, "_warned", set())


def _assert_warns_exactly_once(fn):
    """Call twice; exactly one DeprecationWarning total."""
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        first = fn()
        second = fn()
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 1, [str(x.message) for x in dep]
    return first, second


def test_legacy_policyconfig_flags_forward(fresh_warnings):
    (c, c2) = _assert_warns_exactly_once(
        lambda: PolicyConfig(kind="fier", fused=True, one_pass=False)
    )
    assert c.pipeline == "two_pass" and c.layout == "slab"
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        assert PolicyConfig(kind="fier", fused=True).pipeline == "one_pass"
        assert PolicyConfig(kind="fier", fused=False).pipeline == "reference"
        assert PolicyConfig(kind="fier", paged=False).layout == "slab"
        # the pre-registry paged dispatch ignored one_pass (the paged fast
        # path was always the one-pass kernels): this combo keeps serving
        pp = PolicyConfig(kind="fier", fused=True, one_pass=False, paged=True,
                          block_size=32)
        assert (pp.layout, pp.pipeline) == ("paged", "one_pass")
        DecodePlan.build(pp)  # resolves, no UnsupportedPlanError
    # dataclasses.replace must not resurrect the (unstored) flags or
    # override explicit layout/pipeline changes
    import dataclasses as dc

    r = dc.replace(c, budget=99)
    assert (r.pipeline, r.layout) == ("two_pass", "slab")
    r2 = dc.replace(c, pipeline="one_pass", layout="paged")
    assert (r2.pipeline, r2.layout) == ("one_pass", "paged")
    # flag-free construction doesn't warn
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        PolicyConfig(kind="fier", pipeline="one_pass")
    assert not [x for x in w if issubclass(x.category, DeprecationWarning)]


def test_deprecated_retrieval_entrypoint_forwards(fresh_warnings):
    q, K, V = _inputs(2, 128, 2, 4, 32, seed=7)
    qk = qz.quantize(K.astype(jnp.float32), 16)
    length = jnp.array([100, 128], jnp.int32)
    view = CacheView.slab(K, V, qk, length)
    got, again = _assert_warns_exactly_once(
        lambda: rt.fier_attention_decode(q, K, V, qk, 32, length, fused=True)
    )
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ops.fier_decode_one_pass(q, view, 32))
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(again))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        two = rt.fier_attention_decode(
            q, K, V, qk, 32, length, fused=True, one_pass=False
        )
        unf = rt.fier_attention_decode(q, K, V, qk, 32, length)
    np.testing.assert_array_equal(
        np.asarray(two), np.asarray(ops.fier_decode_two_pass(q, view, 32))
    )
    np.testing.assert_array_equal(
        np.asarray(unf),
        np.asarray(rt.fier_decode_reference(q, K, V, qk, 32, length)),
    )


def test_deprecated_ops_entrypoints_forward(fresh_warnings):
    q, K, V = _inputs(2, 128, 2, 4, 32, seed=8)
    qk = qz.quantize(K.astype(jnp.float32), 16)
    length = jnp.array([100, 128], jnp.int32)
    view = CacheView.slab(K, V, qk, length)
    idx_new = np.asarray(ops.retrieve(q, view, 32))

    got, _ = _assert_warns_exactly_once(
        lambda: ops.fused_retrieve(q, qk, 32, length)
    )
    np.testing.assert_array_equal(np.asarray(got), idx_new)

    got, _ = _assert_warns_exactly_once(
        lambda: ops.fused_fier_attention_decode(q, K, V, qk, 32, length)
    )
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ops.fier_decode_one_pass(q, view, 32))
    )
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        two = ops.fused_fier_attention_decode(
            q, K, V, qk, 32, length, one_pass=False
        )
        att = ops.fused_sparse_attention(q, K, V, jnp.asarray(idx_new), length)
        unf = ops.fier_attention_decode(q, K, V, qk, 32, length)
    np.testing.assert_array_equal(
        np.asarray(two), np.asarray(ops.fier_decode_two_pass(q, view, 32))
    )
    np.testing.assert_array_equal(
        np.asarray(att),
        np.asarray(ops.attend_selected(q, view, jnp.asarray(idx_new))),
    )
    assert np.isfinite(np.asarray(unf, np.float32)).all()


def test_deprecated_paged_ops_entrypoints_forward(fresh_warnings):
    B, S, Hkv, Hq, D, g, bs = GRID[2]
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=9)
    meta = qz.quantize(K.astype(jnp.float32), g)
    length = jnp.full((B,), S - 5, jnp.int32)
    view = _make_view("paged", K, V, meta, length, bs)
    idx_new = np.asarray(ops.retrieve(q, view, 32))

    got, _ = _assert_warns_exactly_once(
        lambda: ops.paged_fused_retrieve(q, view.meta, view.block_table, 32, length)
    )
    np.testing.assert_array_equal(np.asarray(got), idx_new)

    got, _ = _assert_warns_exactly_once(
        lambda: ops.paged_fused_fier_attention_decode(
            q, view.k, view.v, view.meta, view.block_table, 32, length
        )
    )
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ops.fier_decode_one_pass(q, view, 32))
    )
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        att = ops.paged_fused_sparse_attention(
            q, view.k, view.v, view.block_table, jnp.asarray(idx_new), length
        )
    np.testing.assert_array_equal(
        np.asarray(att),
        np.asarray(ops.attend_selected(q, view, jnp.asarray(idx_new))),
    )


def test_deprecated_policy_entrypoints_forward(fresh_warnings):
    B, S, Hkv, Hq, D, g, bs = GRID[2]
    q, K, V = _inputs(B, S, Hkv, Hq, D, seed=10)
    cfg = PolicyConfig(kind="fier", budget=32, group=g, skip_layers=0)
    meta = build_metadata(K, cfg)
    length = jnp.full((B,), S - 5, jnp.int32)
    view = CacheView.slab(K, V, meta, length)
    plan = DecodePlan.build(cfg)
    want = np.asarray(decode_attention(q, view, plan, layer=1))

    got, _ = _assert_warns_exactly_once(
        lambda: decode_attention(q, K, V, meta, cfg, length, 1)
    )
    np.testing.assert_array_equal(np.asarray(got), want)

    pview = _make_view("paged", K, V, meta, length, bs)
    pcfg = PolicyConfig(
        kind="fier", budget=32, group=g, skip_layers=0,
        pipeline="one_pass", block_size=bs,
    )
    want_paged = np.asarray(decode_attention(
        q, pview, DecodePlan.build(pcfg, layout="paged"), layer=1
    ))
    got, _ = _assert_warns_exactly_once(
        lambda: pol.decode_attention_paged(
            q, pview.k, pview.v, pview.meta, pview.block_table, pcfg, length,
            layer=1,
        )
    )
    np.testing.assert_array_equal(np.asarray(got), want_paged)
