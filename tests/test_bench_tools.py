"""Bench persistence schema + the CI regression-check tool."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)

from benchmarks.persist import (  # noqa: E402
    SCHEMA_VERSION, load_bench_json, metric, write_bench_json,
)

TOOL = os.path.join(REPO, "tools", "check_bench_regression.py")


def test_persist_roundtrip(tmp_path):
    doc = write_bench_json(
        str(tmp_path), "demo", {"S": 256},
        [metric("lat", 12.5, unit="us", better="lower", gate=True),
         metric("note", 1.0)],
    )
    path = tmp_path / "BENCH_demo.json"
    assert path.exists()
    back = load_bench_json(str(path))
    assert back == doc
    assert back["schema"] == SCHEMA_VERSION
    assert back["config"] == {"S": 256}
    assert [m["name"] for m in back["metrics"]] == ["lat", "note"]


def test_persist_rejects_bad_metrics(tmp_path):
    with pytest.raises(ValueError):
        metric("x", 1.0, better="sideways")
    with pytest.raises(ValueError):
        metric("x", 1.0, gate=True)  # gated metrics need a direction
    with pytest.raises(ValueError):
        write_bench_json(
            str(tmp_path), "dup", {},
            [metric("a", 1.0), metric("a", 2.0)],
        )


def _write(dirpath, metrics):
    os.makedirs(dirpath, exist_ok=True)
    doc = {
        "schema": SCHEMA_VERSION, "bench": "demo", "git_sha": "test",
        "created_unix": 0, "jax_version": "x", "config": {},
        "metrics": metrics,
    }
    with open(os.path.join(dirpath, "BENCH_demo.json"), "w") as f:
        json.dump(doc, f)


def _check(base, new, *extra):
    return subprocess.run(
        [sys.executable, TOOL, "--baseline-dir", str(base),
         "--new-dir", str(new), *extra],
        capture_output=True, text=True,
    )


BASE = [
    metric("lat", 100.0, better="lower", gate=True),
    metric("tput", 50.0, better="higher", gate=True),
    metric("zero", 0.0, better="lower", gate=True),
    metric("wall", 3.0),  # info: never gated
]


def test_regression_check_within_tolerance(tmp_path):
    _write(tmp_path / "base", BASE)
    _write(tmp_path / "new", [
        metric("lat", 115.0, better="lower", gate=True),    # +15% < +20%
        metric("tput", 46.0, better="higher", gate=True),   # -8% > -10%
        metric("zero", 0.0, better="lower", gate=True),
        metric("wall", 300.0),  # info regressions never fail the check
    ])
    r = _check(tmp_path / "base", tmp_path / "new")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "within tolerance" in r.stdout


@pytest.mark.parametrize("bad", [
    metric("lat", 125.0, better="lower", gate=True),   # +25% latency
    metric("tput", 40.0, better="higher", gate=True),  # -20% throughput
    metric("zero", 4096.0, better="lower", gate=True),  # zero base is exact
])
def test_regression_check_fails_on_degraded(tmp_path, bad):
    """The negative test the CI lane relies on: a synthetically degraded
    BENCH json must turn the check red."""
    _write(tmp_path / "base", BASE)
    degraded = [m if m["name"] != bad["name"] else bad for m in BASE]
    _write(tmp_path / "new", degraded)
    r = _check(tmp_path / "base", tmp_path / "new")
    assert r.returncode == 1, r.stdout + r.stderr
    assert "PERF REGRESSION" in r.stderr
    assert bad["name"] in r.stderr


def test_regression_check_fails_on_gone_gated_metric(tmp_path):
    _write(tmp_path / "base", BASE)
    _write(tmp_path / "new", [m for m in BASE if m["name"] != "lat"])
    r = _check(tmp_path / "base", tmp_path / "new")
    assert r.returncode == 1
    assert "disappeared" in r.stderr + r.stdout


def test_regression_check_missing_baseline(tmp_path):
    _write(tmp_path / "new", BASE)
    os.makedirs(tmp_path / "base", exist_ok=True)
    r = _check(tmp_path / "base", tmp_path / "new")
    assert r.returncode == 1
    assert "missing baseline" in r.stderr + r.stdout


def test_update_baseline_blesses(tmp_path):
    _write(tmp_path / "base", BASE)
    _write(tmp_path / "new", [
        metric("lat", 200.0, better="lower", gate=True),
        metric("tput", 50.0, better="higher", gate=True),
        metric("zero", 0.0, better="lower", gate=True),
        metric("wall", 3.0),
    ])
    assert _check(tmp_path / "base", tmp_path / "new").returncode == 1
    r = _check(tmp_path / "base", tmp_path / "new", "--update-baseline")
    assert r.returncode == 0 and "blessed" in r.stdout
    # after blessing, the same numbers pass
    assert _check(tmp_path / "base", tmp_path / "new").returncode == 0


@pytest.mark.slow
def test_serve_trace_smoke_end_to_end(tmp_path):
    """Full trace replay (chunked vs monolithic on the bursty trace):
    the bench's own gate must hold and the persisted doc must be loadable.
    Slow: two complete scheduler replays (~minutes on CPU)."""
    from benchmarks.bench_serve_trace import smoke

    doc = smoke(str(tmp_path))  # asserts ttft_p99 + throughput internally
    path = tmp_path / "BENCH_serve_trace.json"
    assert path.exists()
    assert load_bench_json(str(path)) == doc
    names = {m["name"] for m in doc["metrics"]}
    assert {"chunked_over_mono_ttft_p99", "chunked_vt_ttft_p99",
            "mono_vt_ttft_p99"} <= names
