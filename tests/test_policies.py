"""Policy registry: incremental metadata updates == rebuild-from-scratch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import policy as pol
from repro.core import quantize as qz
from repro.kvcache import cache as kvcache


def _slab(seed, B=2, S=64, H=2, D=16):
    return jax.random.normal(jax.random.PRNGKey(seed), (B, S, H, D), jnp.float32)


@pytest.mark.parametrize("kind,kw", [("fier", {"group": 8}), ("quest", {"page": 8})])
def test_incremental_update_matches_rebuild(kind, kw):
    """Append tokens one at a time; the incrementally-maintained metadata
    must equal metadata rebuilt from the full slab at every step."""
    cfg = pol.PolicyConfig(kind=kind, budget=16, **kw)
    B, S, H, D = 2, 64, 2, 16
    K = _slab(0, B, S, H, D)
    slab = jnp.zeros((B, S, H, D))
    prefix = 24
    slab = slab.at[:, :prefix].set(K[:, :prefix])
    meta = pol.build_metadata(slab, cfg)
    lengths = jnp.array([prefix, prefix], jnp.int32)
    for t in range(prefix, 40):
        slab = slab.at[:, t].set(K[:, t])
        meta = kvcache.append_token_metadata(meta, slab, lengths, cfg)
        lengths = lengths + 1
        rebuilt = pol.build_metadata(slab, cfg)
        for a, b in zip(jax.tree.leaves(meta), jax.tree.leaves(rebuilt)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_commit_mask_keeps_old_blocks():
    cfg = pol.PolicyConfig(kind="fier", budget=16, group=8)
    K = _slab(1)
    meta = pol.build_metadata(K, cfg)
    K2 = K.at[:, 10].set(99.0)
    lengths = jnp.array([10, 10], jnp.int32)
    updated = kvcache.append_token_metadata(
        meta, K2, lengths, cfg, commit_mask=jnp.array([True, False])
    )
    # row 0 refreshed (sees the 99), row 1 untouched
    assert not np.array_equal(np.asarray(updated.scale[0]), np.asarray(meta.scale[0]))
    np.testing.assert_array_equal(np.asarray(updated.scale[1]), np.asarray(meta.scale[1]))


def test_policy_dispatch_and_skip_layers():
    cfg_full = pol.PolicyConfig(kind="full")
    cfg_fier = pol.PolicyConfig(kind="fier", budget=8, group=8, skip_layers=2)
    K = _slab(2)
    V = _slab(3)
    q = jax.random.normal(jax.random.PRNGKey(4), (2, 4, 16))
    length = jnp.array([64, 64], jnp.int32)
    meta = pol.build_metadata(K, cfg_fier)
    plan_full = pol.DecodePlan.build(cfg_full)
    plan_fier = pol.DecodePlan.build(cfg_fier)
    full = pol.decode_attention(
        q, pol.CacheView.slab(K, V, None, length), plan_full
    )
    fier_view = pol.CacheView.slab(K, V, meta, length)
    skip = pol.decode_attention(q, fier_view, plan_fier, layer=0)
    np.testing.assert_allclose(np.asarray(full), np.asarray(skip), atol=1e-5)
    sparse = pol.decode_attention(q, fier_view, plan_fier, layer=2)
    assert not np.allclose(np.asarray(full), np.asarray(sparse), atol=1e-5)


def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        pol.PolicyConfig(kind="nope")
