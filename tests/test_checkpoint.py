"""Checkpoint manager: roundtrip, async, GC, elastic mesh resharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager

from conftest import run_in_subprocess


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)), "b": jnp.zeros((16,))},
        "opt": {"mu": jnp.ones((8, 16)), "step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save(3, st)
    assert mgr.latest_step() == 3
    back = mgr.restore(3, st)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    st = _state()
    for step in (1, 2, 3, 4):
        mgr.save_async(step, st)
    mgr.wait()
    assert mgr.all_steps() == [3, 4]


def test_atomic_publish_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state())
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_tree_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state())
    with pytest.raises(ValueError):
        mgr.restore(1, {"different": jnp.zeros(3)})


def test_elastic_reshard_between_meshes():
    """Save under mesh (4,) sharding, restore onto mesh (2,) — the elastic
    path after losing half the slice."""
    run_in_subprocess("""
import tempfile
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager

d = tempfile.mkdtemp()
mgr = CheckpointManager(d)
x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
mesh4 = jax.make_mesh((4,), ("data",))
x4 = jax.device_put(x, NamedSharding(mesh4, P("data")))
mgr.save(5, {"x": x4})

mesh2 = jax.make_mesh((2,), ("data",))
sh2 = {"x": NamedSharding(mesh2, P("data"))}
back = mgr.restore(5, {"x": x}, sharding=sh2)
np.testing.assert_array_equal(np.asarray(back["x"]), np.asarray(x))
assert back["x"].sharding.mesh.shape["data"] == 2
print("elastic reshard OK")
""")
