"""Paged KV cache subsystem: allocator invariants, page-table-aware kernel
exactness vs the slab path, prefix sharing / copy-on-write / preemption
through the serving stack."""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core import quantize as qz
from repro.core.policy import CacheView, PolicyConfig
from repro.kernels import ops, ref
from repro.kvcache import cache as kvcache
from repro.kvcache import paged
from repro.models import build_model
from repro.serving import ContinuousScheduler, Engine, Request

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))
from flopcount import count_fn_flops, count_fn_score_bytes  # noqa: E402

# (B, S, Hkv, Hq, D, g, bs): the GQA matrix of test_kernels, with a cache
# block size dividing S (bs % 8 == 0, bs % g == 0)
PAGED_SHAPES = [
    (2, 256, 2, 4, 64, 32, 32),
    (1, 512, 1, 8, 128, 32, 64),
    (2, 128, 4, 4, 32, 16, 16),
    (1, 1024, 2, 2, 128, 64, 128),
    (3, 192, 3, 6, 16, 8, 24),
]


# ------------------------------------------------------------- allocator

def test_block_allocator_invariants():
    a = paged.BlockAllocator(6, 16)
    assert a.usable == 5 and a.n_free == 5 and a.n_in_use == 0
    got = [a.alloc() for _ in range(5)]
    assert sorted(got) == [1, 2, 3, 4, 5]  # null block 0 never handed out
    assert a.alloc() is None and a.n_in_use == 5
    for b in got:
        a.free(b)
    assert a.n_in_use == 0 and a.n_free == 5
    with pytest.raises(AssertionError):
        a.free(got[0])  # double free


def test_block_allocator_refcounts_and_prefix_cache():
    a = paged.BlockAllocator(4, 8)
    b = a.alloc()
    a.register(b, 42)
    assert a.lookup(42) == b and a.ref[b] == 2  # shared
    a.free(b)
    assert a.ref[b] == 1 and a.n_in_use == 1
    a.free(b)
    # parked free-cached: still hittable, still counted free
    assert a.ref[b] == 0 and a.n_free == 3
    assert a.lookup(42) == b and a.ref[b] == 1
    a.free(b)
    # eviction: exhausting the plain free list reclaims the cached block
    got = [a.alloc() for _ in range(3)]
    assert None not in got and a.lookup(42) is None


def test_block_allocator_peek_and_blocks_needed():
    a = paged.BlockAllocator(8, 8)
    keys = paged.block_hash_chain(list(range(20)), 8)  # 3 blocks
    assert a.blocks_needed(20, keys) == 3
    bids = [a.alloc() for _ in range(3)]
    for bid, key in zip(bids, keys):
        a.register(bid, key)
    assert a.peek(keys) == (3, 0)
    assert a.blocks_needed(20, keys) == 0
    # an extended prompt shares the 2 full blocks, misses the tail
    keys2 = paged.block_hash_chain(list(range(16)) + [99] * 4, 8)
    assert a.peek(keys2) == (2, 0) and a.blocks_needed(20, keys2) == 1
    for bid in bids:
        a.free(bid)
    # all three parked free-cached: hits now charge revivals
    assert a.peek(keys) == (3, 3) and a.blocks_needed(20, keys) == 3


def test_block_hash_chain_prefix_property():
    k1 = paged.block_hash_chain([1, 2, 3, 4, 5, 6], 4)
    k2 = paged.block_hash_chain([1, 2, 3, 4, 9, 9], 4)
    k3 = paged.block_hash_chain([7, 2, 3, 4, 5, 6], 4)
    assert k1[0] == k2[0] and k1[1] != k2[1]   # shared full block, split tail
    assert k1[0] != k3[0] and k1[1] != k3[1]   # chained: early split propagates


# ------------------------------------------------------------- validation

def test_init_layer_cache_validates_divisibility():
    fier = PolicyConfig(kind="fier", group=32)
    with pytest.raises(ValueError, match="divisible by 8"):
        kvcache.init_layer_cache(1, 1, 60, 2, 8, fier)
    with pytest.raises(ValueError, match="divisible by group"):
        kvcache.init_layer_cache(1, 1, 72, 2, 8, fier)
    quest = PolicyConfig(kind="quest", page=16)
    with pytest.raises(ValueError, match="quest page"):
        kvcache.init_layer_cache(1, 1, 72, 2, 8, quest)
    kvcache.init_layer_cache(1, 1, 64, 2, 8, fier)  # divisible: fine


def test_init_paged_pool_validates_block_size():
    fier = PolicyConfig(kind="fier", group=32)
    with pytest.raises(ValueError, match="divisible by 8"):
        paged.init_paged_pool(1, 4, 12, 2, 8, fier)
    with pytest.raises(ValueError, match="divisible by group"):
        paged.init_paged_pool(1, 4, 16, 2, 8, fier)
    with pytest.raises(ValueError, match="null block"):
        paged.init_paged_pool(1, 1, 32, 2, 8, fier)
    pool = paged.init_paged_pool(2, 4, 32, 2, 8, fier)
    assert pool["meta"].codes.shape == (2, 4, 4, 2, 8)


# ----------------------------------------------- kernels: paged vs slab

def _slab_to_pool(arr, perm, N):
    """Chunk a slab leaf [B, S, ...] into pool blocks at a permuted layout."""
    B, S = arr.shape[:2]
    nb = perm.shape[1]
    pb = S // nb
    pool = jnp.zeros((N, pb, *arr.shape[2:]), arr.dtype)
    blocks = arr.reshape(B, nb, pb, *arr.shape[2:])
    return pool.at[perm.reshape(-1)].set(blocks.reshape(B * nb, pb, *arr.shape[2:]))


def _paged_inputs(B, S, Hkv, Hq, D, g, bs, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    K = jax.random.normal(k1, (B, S, Hkv, D), jnp.bfloat16)
    V = jax.random.normal(k2, (B, S, Hkv, D), jnp.bfloat16)
    q = jax.random.normal(k3, (B, Hq, D))
    qk = qz.quantize(K.astype(jnp.float32), g)
    nb = S // bs
    N = B * nb + 1
    rng = np.random.default_rng(seed)
    table = jnp.asarray(1 + rng.permutation(B * nb).reshape(B, nb), jnp.int32)
    k_pool, v_pool = _slab_to_pool(K, table, N), _slab_to_pool(V, table, N)
    meta = qz.QuantizedKeys(
        _slab_to_pool(qk.codes, table, N),
        _slab_to_pool(qk.scale, table, N),
        _slab_to_pool(qk.zero, table, N),
        g,
    )
    return q, K, V, qk, k_pool, v_pool, meta, table


@pytest.mark.parametrize("B,S,Hkv,Hq,D,g,bs", PAGED_SHAPES)
def test_paged_retrieve_exact_vs_slab(B, S, Hkv, Hq, D, g, bs):
    """Page-table-aware one-pass retrieval must return the *identical*
    index array as the slab kernel on the same logical cache contents
    (scores are bit-identical, both compact ascending-by-position)."""
    q, K, V, qk, k_pool, v_pool, meta, table = _paged_inputs(B, S, Hkv, Hq, D, g, bs)
    length = jnp.full((B,), S - 7, jnp.int32)
    for budget, sink, recent in [(min(64, S), 0, 0), (min(32, S), 4, 8)]:
        slab = ops.retrieve(
            q, CacheView.slab(None, None, qk, length), budget,
            sink=sink, recent=recent,
        )
        pview = CacheView.paged(None, None, meta, table, length)
        got = ops.retrieve(q, pview, budget, sink=sink, recent=recent)
        np.testing.assert_array_equal(np.asarray(slab), np.asarray(got))
        want = ref.retrieve(q, pview, budget, sink=sink, recent=recent)
        np.testing.assert_array_equal(
            np.sort(np.asarray(got), -1), np.sort(np.asarray(want), -1)
        )


@pytest.mark.parametrize("B,S,Hkv,Hq,D,g,bs", PAGED_SHAPES)
def test_paged_decode_bit_identical_vs_slab(B, S, Hkv, Hq, D, g, bs):
    """Paged one-pass decode (retrieval + select-and-attend, block table
    walked in-kernel) is bit-identical to the slab fused pipeline."""
    q, K, V, qk, k_pool, v_pool, meta, table = _paged_inputs(
        B, S, Hkv, Hq, D, g, bs, seed=1
    )
    length = jnp.full((B,), S - 5, jnp.int32)
    budget = min(64, S)
    slab = ops.fier_decode_one_pass(
        q, CacheView.slab(K, V, qk, length), budget
    )
    pview = CacheView.paged(k_pool, v_pool, meta, table, length)
    got = ops.fier_decode_one_pass(q, pview, budget)
    np.testing.assert_array_equal(np.asarray(slab), np.asarray(got))
    want = ref.paged_fused_fier_attention_decode(
        q, k_pool, v_pool, meta, table, budget, length
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=3e-2, atol=3e-2,
    )


def test_paged_append_matches_slab_append():
    """Appending one token through the block table leaves the same logical
    cache (K/V rows and refreshed side-car) as the slab append."""
    B, S, H, D, g, bs = 2, 64, 2, 8, 8, 16
    q, K, V, qk, k_pool, v_pool, meta, table = _paged_inputs(B, S, H, 4, D, g, bs)
    cfg = PolicyConfig(kind="fier", group=g)
    length = jnp.array([17, 40], jnp.int32)
    kn = jax.random.normal(jax.random.PRNGKey(9), (B, 1, H, D), jnp.bfloat16)
    vn = jax.random.normal(jax.random.PRNGKey(10), (B, 1, H, D), jnp.bfloat16)

    K2, V2 = kvcache.append_kv(K, V, kn, vn, length)
    m2 = kvcache.append_token_metadata(qk, K2, length, cfg)

    kp2, vp2 = paged.paged_append_kv(k_pool, v_pool, kn, vn, table, length)
    mp2 = paged.paged_append_token_metadata(meta, kp2, table, length, cfg)

    np.testing.assert_array_equal(
        np.asarray(K2, np.float32),
        np.asarray(paged.gather_block_rows(kp2, table), np.float32),
    )
    np.testing.assert_array_equal(
        np.asarray(V2, np.float32),
        np.asarray(paged.gather_block_rows(vp2, table), np.float32),
    )
    np.testing.assert_array_equal(
        np.asarray(m2.codes), np.asarray(paged.gather_block_rows(mp2.codes, table))
    )
    np.testing.assert_array_equal(
        np.asarray(m2.scale, np.float32),
        np.asarray(paged.gather_block_rows(mp2.scale, table), np.float32),
    )


def test_paged_onepass_zero_score_bytes():
    """The paged one-pass decode keeps the per-token score tensors out of
    HBM, exactly like the slab one-pass kernel (the CI smoke gate)."""
    B, S, Hkv, Hq, D, g, bs = 1, 256, 2, 4, 32, 8, 32
    q, K, V, qk, k_pool, v_pool, meta, table = _paged_inputs(B, S, Hkv, Hq, D, g, bs)
    length = jnp.full((B,), S, jnp.int32)
    sb = count_fn_score_bytes(
        lambda q, kp, vp: ops.fier_decode_one_pass(
            q, CacheView.paged(kp, vp, meta, table, length), 32
        ),
        S, q, k_pool, v_pool,
    )
    assert sb == 0.0, sb


# --------------------------------------------------- serving integration

@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("olmo-1b")

    def mk(paged_mode, pool_blocks=0):
        pol = PolicyConfig(
            kind="fier", budget=16, group=8, skip_layers=1,
            pipeline="one_pass", layout="paged" if paged_mode else "slab",
            block_size=8, pool_blocks=pool_blocks,
        )
        return build_model(cfg, pol)

    slab = mk(False)
    params = slab.init(jax.random.PRNGKey(0))
    return cfg, mk, slab, params


def _reqs(n=4, max_new=5):
    return [
        Request(rid=i, tokens=list(range(3 + i, 11 + i)), max_new=max_new)
        for i in range(n)
    ]


def test_paged_scheduler_matches_slab(setup):
    """Same workload through a paged and a slab engine: identical outputs
    (the paged decode is bit-identical on the same logical contents)."""
    cfg, mk, slab, params = setup
    out_slab = ContinuousScheduler(
        Engine(slab, n_slots=3, capacity=64), params, pad_prompt_to=16
    ).run(_reqs())
    eng = Engine(mk(True), n_slots=3, capacity=64)
    out_paged = ContinuousScheduler(eng, params, pad_prompt_to=16).run(_reqs())
    assert out_slab == out_paged
    # every block came back: nothing resident after the run
    assert eng.allocator.n_in_use == 0


def test_paged_engine_decode_logits_match_slab(setup):
    """Direct engine-level check: insert + decode produce bit-identical
    logits slab-vs-paged on fresh caches."""
    cfg, mk, slab, params = setup
    toks = jnp.asarray(np.arange(1, 12, dtype=np.int32)[None])
    outs = []
    for bundle in (slab, mk(True)):
        eng = Engine(bundle, n_slots=2, capacity=64)
        cache = eng.new_cache()
        logits, cache = eng.insert(params, cache, toks, 11, slot=1)
        seq = [np.asarray(logits)]
        tok = jnp.asarray([0, int(jnp.argmax(logits[0]))], jnp.int32)
        active = jnp.asarray([False, True])
        for _ in range(3):
            if eng.paged:
                ok, cache = eng.advance_slot(cache, 1)
                assert ok
            tok_next, lg, cache = eng.decode(params, tok, cache, active=active)
            seq.append(np.asarray(lg[1]))
            tok = jnp.asarray([0, int(tok_next[1])], jnp.int32)
        outs.append(seq)
    for a, b in zip(*outs):
        np.testing.assert_array_equal(a, b)


def test_prefix_hit_skips_prefill_flops_identical_logits(setup):
    """A full-prompt prefix hit replays the cached first-token logits and
    runs zero prefill FLOPs (the cold prefill costs > 0 by flopcount)."""
    from functools import partial

    cfg, mk, slab, params = setup
    bundle = mk(True)
    eng = Engine(bundle, n_slots=2, capacity=64)
    cache = eng.new_cache()
    toks = jnp.asarray(np.arange(5, 16, dtype=np.int32)[None])

    prefill_flops = count_fn_flops(
        partial(bundle.prefill, capacity=64), params,
        {"tokens": toks, "lengths": jnp.array([11], jnp.int32)},
    )
    assert prefill_flops > 0

    cold, cache = eng.insert(params, cache, toks, 11, slot=0)
    assert eng.prefill_count == 1 and eng.prefix_hits == 0
    cache = eng.release_slot(cache, 0)  # blocks park free-cached
    hit, cache = eng.insert(params, cache, toks, 11, slot=1)
    # no prefill ran: the flopcount-measured cost was skipped entirely
    assert eng.prefill_count == 1 and eng.prefix_hits == 1
    np.testing.assert_array_equal(np.asarray(cold), np.asarray(hit))


def test_prefix_shared_blocks_and_cow_divergence(setup):
    """Two concurrent identical prompts: the second admission shares every
    block (one prefill total), the first divergent decode write triggers
    copy-on-write, and both requests' outputs equal cold single runs."""
    cfg, mk, slab, params = setup
    eng = Engine(mk(True), n_slots=2, capacity=64)
    sched = ContinuousScheduler(eng, params, pad_prompt_to=16)
    twin = lambda: [
        Request(rid=0, tokens=[5, 6, 7, 8, 9], max_new=6),
        Request(rid=1, tokens=[5, 6, 7, 8, 9], max_new=6),
    ]
    out = sched.run(twin())
    st = eng.pool_stats()
    assert st["prefills"] == 1 and st["prefix_hits"] == 1, st
    assert st["cow_copies"] >= 1, st  # shared partial tail diverged
    assert out[0] == out[1]
    solo = ContinuousScheduler(
        Engine(mk(True), n_slots=1, capacity=64), params, pad_prompt_to=16
    ).run([Request(rid=0, tokens=[5, 6, 7, 8, 9], max_new=6)])
    assert out[0] == solo[0]


def test_preemption_roundtrip_under_2x_oversubscription(setup):
    """A workload whose summed worst-case contexts exceed the pool by
    >= 2x completes via preemption with outputs identical to an
    unconstrained pool (greedy decode: recompute-on-readmit is exact)."""
    cfg, mk, slab, params = setup
    # capacity 64 / bs 8 → 8 blocks worst case per request; 3 requests =
    # 24 blocks vs 9 usable (pool_blocks=10) → 2.7× oversubscribed
    eng = Engine(mk(True, pool_blocks=10), n_slots=3, capacity=64)
    sched = ContinuousScheduler(eng, params, pad_prompt_to=16)
    out = sched.run(_reqs(3, max_new=25))
    assert sched.preemptions > 0
    assert all(len(v) == 25 for v in out.values())
    big = ContinuousScheduler(
        Engine(mk(True), n_slots=3, capacity=64), params, pad_prompt_to=16
    ).run(_reqs(3, max_new=25))
    assert out == big


def test_scheduler_rejects_overlong_prompt(setup):
    """A prompt longer than engine capacity is rejected with a warning
    instead of writing out of range (slab: dynamic_update_slice clamp
    corruption; paged: table overrun)."""
    cfg, mk, slab, params = setup
    for bundle in (slab, mk(True)):
        eng = Engine(bundle, n_slots=2, capacity=64)
        sched = ContinuousScheduler(eng, params, pad_prompt_to=16)
        reqs = [
            Request(rid=0, tokens=list(range(1, 100)), max_new=4),  # 99 > 64
            Request(rid=1, tokens=[3, 4, 5], max_new=3),
        ]
        with pytest.warns(UserWarning, match="exceeds engine capacity"):
            out = sched.run(reqs)
        assert reqs[0].rejected and out[0] == []
        assert len(out[1]) == 3  # the short request is unaffected


def test_full_capacity_prompt_retires_without_out_of_range_write(setup):
    """A prompt of exactly ``capacity`` tokens admits, emits its prefill
    token, and retires immediately — the first decode step would have
    nowhere to write the token's KV (slab: clamp onto the last prompt
    row; paged: null-block drop)."""
    cfg, mk, slab, params = setup
    for bundle in (slab, mk(True)):
        eng = Engine(bundle, n_slots=2, capacity=64)
        sched = ContinuousScheduler(eng, params, pad_prompt_to=16)
        out = sched.run([Request(rid=0, tokens=list(range(1, 65)), max_new=8)])
        assert len(out[0]) == 1  # prefill token only, then retired
        if eng.paged:
            assert eng.allocator.n_in_use == 0


def test_empty_prompt_does_not_crash_paged_insert(setup):
    """Zero-length prompts take the prefill path with no blocks and no
    hash chain (regression: keys[-1] raised IndexError)."""
    cfg, mk, slab, params = setup
    eng = Engine(mk(True), n_slots=1, capacity=64)
    cache = eng.new_cache()
    toks = jnp.zeros((1, 16), jnp.int32)
    logits, cache = eng.insert(params, cache, toks, 0, slot=0)
    assert logits.shape[0] == 1
    assert eng._seq[0].blocks == [] and eng.allocator.n_in_use == 0


def test_admit_samples_prefill_token_from_rng_stream(setup, monkeypatch):
    """Regression (satellite): _admit used to argmax the prefill logits
    even at temperature > 0 — now the first token goes through
    sample_token with a key split off the scheduler rng stream."""
    from repro.serving import SamplingConfig
    import repro.serving.engine as engine_mod

    cfg, mk, slab, params = setup
    seen = []
    orig = engine_mod.sample_token

    def spy(rng, logits, scfg):
        seen.append((np.asarray(rng).copy(), logits.shape[0]))
        return orig(rng, logits, scfg)

    monkeypatch.setattr(engine_mod, "sample_token", spy)
    eng = Engine(slab, n_slots=2, capacity=64,
                 sampling=SamplingConfig(temperature=1.0, top_k=4))
    sched = ContinuousScheduler(eng, params, pad_prompt_to=16)
    sched.run([Request(rid=i, tokens=[3 + i, 4 + i], max_new=3) for i in range(2)])
    # one B=1 call per admission (the prefill token), distinct keys across
    # every sampled draw
    admit_calls = [k for k, b in seen if b == 1]
    assert len(admit_calls) == 2
    keys = {tuple(k.tolist()) for k, _ in seen}
    assert len(keys) == len(seen), "sampling rng key reused"
