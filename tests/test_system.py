"""End-to-end system behaviour: train → quality with FIER ≈ full-KV,
and the paper's core contrast (retrieval ≫ eviction) on a trained model."""
import dataclasses
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))

from repro.configs import reduced_config
from repro.configs.base import ShapeConfig
from repro.core.policy import PolicyConfig
from repro.data.pipeline import make_train_batch
from repro.launch.steps import TrainHParams, init_train_state, make_train_step
from repro.models import build_model


@pytest.fixture(scope="module")
def trained():
    cfg = dataclasses.replace(
        reduced_config("olmo-1b"), n_layers=3, d_model=96, n_heads=4,
        n_kv_heads=4, d_head=24, d_ff=192, vocab=256,
    )
    bundle = build_model(cfg)
    hp = TrainHParams(peak_lr=2e-3, warmup=10, total_steps=150)
    state = init_train_state(bundle, jax.random.PRNGKey(0), hp)
    step = jax.jit(make_train_step(bundle, hp))
    shape = ShapeConfig("sys", 128, 8, "train")
    losses = []
    for s in range(150):
        batch = make_train_batch(cfg, shape, s, seed=11)
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    return cfg, state["params"], losses


def test_training_learns(trained):
    cfg, params, losses = trained
    assert losses[-1] < 0.7 * losses[0], (losses[0], losses[-1])


def _greedy(bundle, params, prompt, n=16):
    B, S = prompt.shape
    pre = {"tokens": prompt, "lengths": jnp.full((B,), S, jnp.int32)}
    logits, cache = jax.jit(
        lambda p, b: bundle.prefill(p, b, capacity=S + n + 8)
    )(params, pre)
    dec = jax.jit(bundle.decode_step)
    toks = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(n):
        toks.append(np.asarray(tok))
        logits, cache = dec(params, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    return np.stack(toks, 1)


def test_fier_matches_full_on_trained_model(trained):
    """FIER degrades gracefully with budget (exact at budget=capacity) and
    dominates page-level and eviction selection at a tight budget.

    (Greedy-token agreement on this tiny bigram model is a harsh metric —
    its attention is diffuse, so ORDERING is the meaningful invariant;
    measured: fier .47 > slm .34 > quest .19 at budget 24/112.)"""
    cfg, params, _ = trained
    from repro.data.pipeline import lm_tokens

    prompt = lm_tokens(11, 999, 4, 96, cfg.vocab)[:, :96]
    full = _greedy(build_model(cfg, PolicyConfig(kind="full")), params, prompt)

    def agree(pol):
        return (full == _greedy(build_model(cfg, pol), params, prompt)).mean()

    exact = agree(PolicyConfig(kind="fier", budget=112, group=8, skip_layers=1))
    assert exact == 1.0, "budget ≥ length must reproduce full-KV exactly"

    a_fier = agree(PolicyConfig(kind="fier", budget=24, group=8, skip_layers=1))
    a_quest = agree(PolicyConfig(kind="quest", budget=24, page=8, skip_layers=1))
    a_slm = agree(PolicyConfig(kind="slm", budget=24, skip_layers=1))
    assert a_fier > a_quest, (a_fier, a_quest)
    assert a_fier > a_slm, (a_fier, a_slm)
    assert a_fier >= 0.4, a_fier


def test_quest_and_fier_beat_slm_on_trained_model(trained):
    cfg, params, _ = trained
    from repro.data.pipeline import lm_tokens

    toks = lm_tokens(11, 500, 4, 160, cfg.vocab)

    # teacher-forced NLL of the next 24 gold tokens under each policy
    def nll(kind):
        pol = None if kind == "full" else PolicyConfig(
            kind=kind, budget=24, group=8, page=8, skip_layers=1
        )
        bundle = build_model(cfg, pol)
        pre = {"tokens": toks[:, :128], "lengths": jnp.full((4,), 128, jnp.int32)}
        logits, cache = jax.jit(
            lambda p, b: bundle.prefill(p, b, capacity=160)
        )(params, pre)
        dec = jax.jit(bundle.decode_step)
        tot = 0.0
        for t in range(24):
            gold = toks[:, 128 + t]
            lp = jax.nn.log_softmax(logits, -1)
            tot += float(-jnp.take_along_axis(lp, gold[:, None], 1).mean())
            logits, cache = dec(params, gold, cache)
        return tot / 24

    n_full, n_fier, n_slm = nll("full"), nll("fier"), nll("slm")
    # FIER's quality gap to full-KV stays well below eviction's
    assert n_fier - n_full < 0.5 * max(n_slm - n_full, 1e-9) + 0.05, (
        n_full, n_fier, n_slm,
    )
