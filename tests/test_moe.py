"""MoE: dense-scatter reference vs shard_map EP vs dense-masked decode path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import moe as moe_mod

from conftest import run_in_subprocess


def _setup(T=64, seed=0):
    cfg = reduced_config("granite-moe-1b-a400m")  # 4 experts, top-2, d=64
    rng = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(rng)
    p = moe_mod.init_moe(k1, cfg)
    x = jax.random.normal(k2, (T, cfg.d_model), jnp.float32)
    return cfg, p, x


def test_masked_matches_scatter_no_drops():
    """With capacity_factor high enough that nothing drops, the dense-masked
    decode path must equal the scatter reference exactly."""
    import dataclasses

    cfg, p, x = _setup(T=32)
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    y1, aux1 = moe_mod.moe_apply(x, p, cfg)
    y2, aux2 = moe_mod.moe_apply_masked(x, p, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-5)


def test_aux_loss_balanced_router_is_one():
    """A perfectly uniform router gives aux ≈ 1 (Switch normalisation)."""
    import dataclasses

    cfg, p, x = _setup(T=512)
    p = dict(p, router=jnp.zeros_like(p["router"]))
    _, aux = moe_mod.moe_apply_masked(x, p, cfg)
    assert 0.9 < float(aux) < 1.1


def test_ep_matches_scatter_multidevice():
    """shard_map EP on a 2×2 mesh == single-device scatter reference."""
    run_in_subprocess(
        """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import reduced_config
from repro.models import moe as moe_mod

cfg = dataclasses.replace(reduced_config("granite-moe-1b-a400m"), capacity_factor=8.0)
k1, k2 = jax.random.split(jax.random.PRNGKey(0))
p = moe_mod.init_moe(k1, cfg)
x = jax.random.normal(k2, (64, cfg.d_model), jnp.float32)
y_ref, aux_ref = moe_mod.moe_apply(x, p, cfg)

mesh = jax.make_mesh((2, 2), ("data", "model"))
y_ep, aux_ep = jax.jit(lambda x, p: moe_mod.moe_apply_ep(
    x, p, cfg, mesh=mesh, token_axes=("data",), model_axis="model"))(x, p)
np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ep), atol=2e-4, rtol=2e-4)
# aux is a per-shard estimator under EP (E[f·P] over shards != global f·P):
# outputs must match exactly, aux only approximately
np.testing.assert_allclose(float(aux_ref), float(aux_ep), rtol=0.05)
print("EP == scatter OK")
""",
        n_devices=4,
    )


def test_ep_with_fsdp_gather_multidevice():
    """EP with FSDP-stored expert weights (gather inside the body)."""
    run_in_subprocess(
        """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import reduced_config
from repro.models import moe as moe_mod

cfg = dataclasses.replace(reduced_config("granite-moe-1b-a400m"), capacity_factor=8.0)
k1, k2 = jax.random.split(jax.random.PRNGKey(1))
p = moe_mod.init_moe(k1, cfg)
x = jax.random.normal(k2, (64, cfg.d_model), jnp.float32)
y_ref, _ = moe_mod.moe_apply(x, p, cfg)

mesh = jax.make_mesh((2, 2), ("data", "model"))
sh = {
    "router": NamedSharding(mesh, P()),
    "w1": NamedSharding(mesh, P("model", "data", None)),
    "w3": NamedSharding(mesh, P("model", "data", None)),
    "w2": NamedSharding(mesh, P("model", None, "data")),
}
p_sharded = {k: jax.device_put(v, sh[k]) for k, v in p.items()}
x_sh = jax.device_put(x, NamedSharding(mesh, P("data", None)))
y_ep, _ = jax.jit(lambda x, p: moe_mod.moe_apply_ep(
    x, p, cfg, mesh=mesh, token_axes=("data",), model_axis="model",
    fsdp_axes=("data",)))(x_sh, p_sharded)
np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ep), atol=2e-4, rtol=2e-4)
print("EP+FSDP == scatter OK")
""",
        n_devices=4,
    )


def test_ep_gradients_flow():
    """EP path is differentiable (psum/all_gather transpose correctly)."""
    run_in_subprocess(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import reduced_config
from repro.models import moe as moe_mod

cfg = reduced_config("granite-moe-1b-a400m")
k1, k2 = jax.random.split(jax.random.PRNGKey(0))
p = moe_mod.init_moe(k1, cfg)
x = jax.random.normal(k2, (64, cfg.d_model), jnp.float32)
mesh = jax.make_mesh((2, 2), ("data", "model"))

def loss(p, x):
    y, aux = moe_mod.moe_apply_ep(x, p, cfg, mesh=mesh, token_axes=("data",),
                                  model_axis="model")
    return jnp.sum(y * y) + 0.01 * aux

g = jax.jit(jax.grad(loss))(p, x)
for k, v in g.items():
    assert bool(jnp.isfinite(v).all()), k
assert float(jnp.abs(g["w1"]).sum()) > 0
print("EP grads OK")
""",
        n_devices=4,
    )
