"""Property-based tests (hypothesis) for the radix-trie prefix cache and
the trie-backed block allocator.

Same convention as test_property.py: the module skips when hypothesis is
absent (declared in pyproject.toml, installed in CI).  The deterministic
trie/offload coverage lives in test_prefix_tree.py.
"""
from collections import Counter

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402
from hypothesis.stateful import (  # noqa: E402
    RuleBasedStateMachine,
    initialize,
    invariant,
    precondition,
    rule,
)

from repro.kvcache.paged import BlockAllocator, block_hash_chain  # noqa: E402
from repro.kvcache.prefix_tree import PrefixTree  # noqa: E402

BS = 8  # FIER side-car bit-packing requires block_size % 8 == 0
# small alphabet + bounded length → real prefix collisions between prompts
PROMPTS = st.lists(
    st.lists(st.integers(0, 2), min_size=1, max_size=6 * BS),
    min_size=1, max_size=8,
)


def _flat_insert(tree, flat, toks, next_bid):
    """Insert ``toks``'s chain into both the trie and a reference flat
    map (the pre-trie chained-hash matcher)."""
    keys = block_hash_chain(toks, BS)
    for j, key in enumerate(keys):
        if key in flat:
            continue
        assert tree.insert(key, next_bid[0],
                           parent_key=keys[j - 1] if j else None)
        flat[key] = next_bid[0]
        next_bid[0] += 1
    return keys


@settings(max_examples=60, deadline=None)
@given(PROMPTS)
def test_trie_walk_equals_flat_map(prompts):
    """∀ prompt sets: match_longest equals the flat chained-hash walk
    (first-miss semantics), point lookups agree, and the trie audits
    clean — the trie is a drop-in for the old matcher."""
    tree, flat, next_bid = PrefixTree(), {}, [1]
    for toks in prompts:
        _flat_insert(tree, flat, toks, next_bid)
    for toks in prompts:
        keys = block_hash_chain(toks, BS)
        expect = []
        for k in keys:
            if k not in flat:
                break
            expect.append(flat[k])
        assert tree.match_longest(keys) == expect == [
            tree.get(k) for k in keys[: len(expect)]
        ]
    assert len(tree) == len(flat)
    assert tree.audit() == []


@settings(max_examples=40, deadline=None)
@given(PROMPTS, st.integers(0, 2**31 - 1))
def test_eviction_drains_whole_trie_and_leaves_never_strand(prompts, seed):
    """Park everything, then evict to exhaustion: every pop removes
    exactly one node, a *leaf whenever any parked leaf exists* (so no
    cached descendant is stranded while an evictable leaf remained), and
    the trie ends empty with a clean audit after every step."""
    import random

    tree, flat, next_bid = PrefixTree(), {}, [1]
    for toks in prompts:
        _flat_insert(tree, flat, toks, next_bid)
    rng = random.Random(seed)
    bids = list(range(1, next_bid[0]))
    rng.shuffle(bids)
    for bid in bids:
        tree.park(bid)
    n = len(tree)
    for i in range(n):
        had_leaf = any(
            node.is_leaf() for node in tree._parked.values()
        )
        before_interior = tree.interior_evictions
        assert tree.pop_eviction() is not None
        if had_leaf:
            assert tree.interior_evictions == before_interior
        assert len(tree) == n - i - 1
        assert tree.audit() == []
    assert tree.pop_eviction() is None


@settings(max_examples=40, deadline=None)
@given(PROMPTS)
def test_full_prompt_hits_equal_chained_hash_matcher(prompts):
    """Register every prompt through the allocator, release all refs,
    then look each full chain up again: every prompt is a full hit onto
    the exact blocks it registered — trie-backed lookup reproduces the
    old flat matcher on full-prompt hits."""
    a = BlockAllocator(256, BS)
    registered = {}
    for toks in prompts:
        keys = block_hash_chain(toks, BS)
        held = []
        for j, key in enumerate(keys):
            bid = a.lookup(key)
            if bid is None:
                bid = a.alloc()
                a.register(bid, key, parent_key=keys[j - 1] if j else None)
                registered[key] = bid
            held.append(bid)
        for bid in held:
            a.free(bid)
    for toks in prompts:
        keys = block_hash_chain(toks, BS)
        assert a.peek(keys)[0] == len(keys)
        got = [a.lookup(k) for k in keys]
        assert got == [registered[k] for k in keys]
        for bid in got:
            a.free(bid)
    a.audit()


class AllocatorMachine(RuleBasedStateMachine):
    """Random alloc/free/register/lookup/TTL walks: after every rule the
    allocator audits clean against the exact refs this model holds, and
    block conservation (in_use + free + parked == usable) holds."""

    def __init__(self):
        super().__init__()
        self.t = 0.0
        self.a = BlockAllocator(12, BS, park_ttl=6.0)
        self.a.set_clock(lambda: self.t)
        self.a.record_evictions = True
        self.held: list[int] = []
        self.next_key = 0

    @initialize()
    def setup(self):
        pass

    @rule()
    def tick(self):
        self.t += 1.0

    @rule()
    def alloc(self):
        bid = self.a.alloc()
        if bid is not None:
            assert self.a.ref[bid] == 1
            self.held.append(bid)

    @precondition(lambda self: self.held)
    @rule(data=st.data())
    def free(self, data):
        i = data.draw(st.integers(0, len(self.held) - 1), label="free idx")
        self.a.free(self.held.pop(i))

    @precondition(lambda self: self.held)
    @rule(data=st.data())
    def register(self, data):
        i = data.draw(st.integers(0, len(self.held) - 1), label="reg idx")
        self.next_key += 1
        self.a.register(self.held[i], self.next_key)

    @precondition(lambda self: self.held)
    @rule(data=st.data())
    def lookup_held(self, data):
        """Ref-count safety: looking up a held block's key returns that
        block and bumps its ref."""
        i = data.draw(st.integers(0, len(self.held) - 1), label="lookup idx")
        key = self.a.key_of(self.held[i])
        if key is not None:
            before = self.a.ref[self.held[i]]
            assert self.a.lookup(key) == self.held[i]
            assert self.a.ref[self.held[i]] == before + 1
            self.held.append(self.held[i])

    @rule()
    def ttl_sweep(self):
        self.a.expire_parked()
        self.a.take_evicted()

    @invariant()
    def audits_clean_and_conserved(self):
        self.a.audit(dict(Counter(self.held)))
        assert (
            self.a.n_in_use + len(self.a._free) + self.a.n_parked
            == self.a.usable
        )
        # an in-use block is never evictable
        for bid in self.held:
            assert bid not in self.a.tree._parked


TestAllocatorMachine = AllocatorMachine.TestCase
TestAllocatorMachine.settings = settings(
    max_examples=40, stateful_step_count=60, deadline=None
)


@settings(max_examples=30, deadline=None)
@given(PROMPTS, st.integers(1, 10))
def test_ttl_eviction_is_deterministic(prompts, ttl):
    """Two allocators driven through the identical script on the same
    virtual clock expire the identical blocks in the identical order."""
    logs = []
    for _ in range(2):
        t = [0.0]
        a = BlockAllocator(128, BS, park_ttl=float(ttl))
        a.set_clock(lambda: t[0])
        a.record_evictions = True
        log = []
        for toks in prompts:
            keys = block_hash_chain(toks, BS)
            held = []
            for j, key in enumerate(keys):
                bid = a.lookup(key) or a.alloc()
                a.register(bid, key, parent_key=keys[j - 1] if j else None)
                held.append(bid)
            for bid in held:
                a.free(bid)
            t[0] += 3.0
            a.expire_parked()
            log.extend((e.bid, e.key, e.reason) for e in a.take_evicted())
        logs.append(log)
    assert logs[0] == logs[1]
