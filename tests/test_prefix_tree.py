"""Two-tier KV reuse (DESIGN.md §KV reuse tiers): the radix-trie prefix
cache, the host-DRAM offload tier, and their engine-level round trip.

The trie's randomized/property suite lives in test_prefix_tree_prop.py
(hypothesis, optional dependency); this module is the deterministic
coverage — trie lifecycle/eviction semantics, host-tier accounting, and
the acceptance-critical bit-identity checks: a block that is offloaded
and recalled must read back byte-for-byte, and an offload-enabled engine
must reproduce the plain paged engine's outputs while recomputing
strictly fewer prompt tokens under pool pressure.
"""
import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.policy import PolicyConfig
from repro.kvcache.offload import (
    HostOffloadTier,
    double_buffered_puts,
    payload_nbytes,
)
from repro.kvcache.paged import BlockAllocator, block_hash_chain
from repro.kvcache.prefix_tree import PrefixTree
from repro.models import build_model
from repro.serving import ContinuousScheduler, Engine, Request


# ======================================================================
# PrefixTree semantics
# ======================================================================

def _chain(tree, toks, bs=4, first_bid=1):
    """Insert the full key chain of ``toks``; returns its keys."""
    keys = block_hash_chain(toks, bs)
    bid = first_bid
    for j, key in enumerate(keys):
        if key in tree:
            continue
        tree.insert(key, bid, parent_key=keys[j - 1] if j else None)
        bid += 1
    return keys


def test_trie_longest_prefix_walk():
    tree = PrefixTree()
    ka = _chain(tree, list(range(12)), first_bid=1)        # 3 blocks
    kb = _chain(tree, list(range(8)) + [99, 99, 99, 99], first_bid=10)
    # shared first 2 blocks: chain B reused keys ka[0:2]
    assert kb[:2] == ka[:2] and kb[2] != ka[2]
    assert tree.match_longest(ka) == [1, 2, 3]
    assert tree.match_longest(kb) == [1, 2, 10]
    # a divergent third chain matches only the shared prefix
    kc = block_hash_chain(list(range(8)) + [7, 7, 7, 7], 4)
    assert tree.match_longest(kc) == [1, 2]
    assert tree.audit() == []


def test_trie_leaf_first_lru_eviction():
    tree = PrefixTree()
    t = [0.0]
    tree.set_clock(lambda: t[0])
    keys = _chain(tree, list(range(12)))                   # bids 1, 2, 3
    # park in root-first order — LRU order would pick bid 1, but evicting
    # an interior node strands its cached descendants: leaves win
    for bid in (1, 2, 3):
        tree.park(bid)
        t[0] += 1.0
    assert tree.pop_eviction()[0] == 3                     # the only leaf
    assert tree.pop_eviction()[0] == 2                     # new leaf
    bid, key, parent_key = tree.pop_eviction()
    assert (bid, key, parent_key) == (1, keys[0], None)
    assert tree.pop_eviction() is None
    assert tree.leaf_evictions == 3 and tree.interior_evictions == 0
    assert len(tree) == 0 and tree.audit() == []


def test_trie_interior_fallback_and_reparent():
    tree = PrefixTree()
    keys = _chain(tree, list(range(12)))                   # 1 → 2 → 3
    tree.park(2)                                           # park only bid 2
    # bid 2 is interior (child bid 3 in use): fallback evicts it anyway
    bid, key, parent_key = tree.pop_eviction()
    assert (bid, key, parent_key) == (2, keys[1], keys[0])
    assert tree.interior_evictions == 1
    # the orphaned child re-hung on its grandparent
    assert tree.reparented == 1
    node3 = tree.node_of(3)
    assert node3.parent_key == keys[0]
    # the walk now stops at the removed key
    assert tree.match_longest(keys) == [1]
    assert tree.audit() == []


def test_trie_ttl_expiry_deepest_first():
    tree = PrefixTree()
    t = [0.0]
    tree.set_clock(lambda: t[0])
    _chain(tree, list(range(12)))
    for bid in (1, 2, 3):
        tree.park(bid)
    t[0] = 10.0
    assert tree.expired(20.0) == []
    # deepest-first: chains unwind leaf-to-root
    assert tree.expired(5.0) == [3, 2, 1]
    ages = sorted(tree.parked_ages())
    assert ages == [10.0, 10.0, 10.0]


def test_trie_park_revive_and_first_writer_wins():
    tree = PrefixTree()
    assert tree.insert(42, 1) is True
    assert tree.insert(42, 2) is False                     # key taken
    with pytest.raises(ValueError):
        tree.insert(43, 1)                                 # bid taken
    tree.park(1)
    assert tree.n_parked == 1
    tree.revive(1)
    assert tree.n_parked == 0 and tree.get(42) == 1
    assert tree.audit() == []


# ======================================================================
# Trie-backed allocator: equivalence with the old chained-hash matcher
# ======================================================================

def test_allocator_full_prompt_hit_equivalence():
    """A full chain registered through the allocator behaves exactly like
    the flat chained-hash map on full-prompt hits: peek reports every
    block hit, lookup revives the same bids, blocks_needed charges only
    the revivals."""
    a = BlockAllocator(10, 8)
    toks = list(range(28))                                 # 4 blocks (1 partial)
    keys = block_hash_chain(toks, 8)
    bids = [a.alloc() for _ in keys]
    for j, (bid, key) in enumerate(zip(bids, keys)):
        a.register(bid, key, parent_key=keys[j - 1] if j else None)
    for bid in bids:
        a.free(bid)                                        # all park
    assert a.n_parked == len(keys)
    assert a.peek(keys) == (len(keys), len(keys))
    assert a.blocks_needed(len(toks), keys) == len(keys)   # revivals charged
    assert [a.lookup(k) for k in keys] == bids             # same blocks back
    assert a.n_in_use == len(keys)
    for bid in bids:
        a.free(bid)
    a.audit()


def test_allocator_ttl_sweep_and_age_percentiles():
    t = [0.0]
    a = BlockAllocator(10, 8, park_ttl=5.0)
    a.set_clock(lambda: t[0])
    a.record_evictions = True
    keys = block_hash_chain(list(range(24)), 8)
    bids = [a.alloc() for _ in keys]
    for j, (bid, key) in enumerate(zip(bids, keys)):
        a.register(bid, key, parent_key=keys[j - 1] if j else None)
    for bid in bids:
        a.free(bid)
    t[0] = 3.0
    st = a.stats()
    assert st["pool_parked_age_p50"] == 3.0 == st["pool_parked_age_max"]
    assert a.expire_parked() == 0                          # too young
    t[0] = 6.0
    assert a.expire_parked() == 3
    evs = a.take_evicted()
    assert [e.reason for e in evs] == ["ttl"] * 3
    # deepest-first: parent linkage preserved in the log
    assert [e.key for e in evs] == [keys[2], keys[1], keys[0]]
    assert [e.parent_key for e in evs] == [keys[1], keys[0], None]
    assert a.stats()["pool_ttl_evictions"] == 3
    assert a.take_evicted() == []                          # drained
    a.audit()


def test_allocator_cross_tier_audit_rejects_double_ownership():
    from repro.kvcache.paged import AllocatorAuditError

    a = BlockAllocator(6, 8)
    bid = a.alloc()
    a.register(bid, 1234)
    a.audit(host_keys={999})                               # disjoint: fine
    with pytest.raises(AllocatorAuditError, match="both tiers"):
        a.audit(host_keys={1234})
    a.free(bid)


# ======================================================================
# Host offload tier
# ======================================================================

def _payload(seed, shape=(2, 4, 3)):
    rng = np.random.default_rng(seed)
    return {
        "front": {"k": rng.standard_normal(shape, np.float32)},
        "rest": {"v": rng.standard_normal(shape, np.float32)},
    }


def test_offload_tier_save_pop_lru():
    tier = HostOffloadTier(capacity_blocks=2)
    p = {k: _payload(k) for k in (1, 2, 3)}
    assert tier.save(1, None, p[1]) is True
    assert tier.save(1, None, p[1]) is False               # resident: refused
    assert tier.save(2, 1, p[2]) is True
    assert tier.nbytes == payload_nbytes(p[1]) + payload_nbytes(p[2])
    tier.save(3, 2, p[3])                                  # over capacity
    assert tier.lru_evictions == 1 and 1 not in tier       # key 1 was LRU
    assert tier.match_extension([2, 3, 7], 0) == [2, 3]
    hb = tier.pop(2)
    assert hb.parent_key == 1
    np.testing.assert_array_equal(hb.payload["rest"]["v"], p[2]["rest"]["v"])
    assert tier.pop(2) is None                             # ownership moved
    assert tier.drop_lru(5) == 1                           # only key 3 left
    assert len(tier) == 0 and tier.nbytes == 0
    assert tier.audit() == []


def test_offload_tier_disabled_at_zero_capacity():
    tier = HostOffloadTier(0)
    assert tier.save(1, None, _payload(1)) is False
    assert len(tier) == 0


def test_double_buffered_puts_preserves_order_and_values():
    entries = [(i, _payload(i)) for i in range(5)]
    out = list(double_buffered_puts(iter(entries)))
    assert [bid for bid, _ in out] == [0, 1, 2, 3, 4]
    for (bid, dev), (_, host) in zip(out, entries):
        for a, b in zip(jax.tree.leaves(dev), jax.tree.leaves(host)):
            np.testing.assert_array_equal(np.asarray(a), b)
    assert list(double_buffered_puts(iter([]))) == []


# ======================================================================
# Engine-level round trip: offload → recall must be bit-identical, and
# the offload engine must beat the plain paged engine on recomputation
# ======================================================================

def _paged_policy(pool_blocks, **kw):
    return PolicyConfig(
        kind="fier", budget=16, group=8, skip_layers=1, sink=2, recent=4,
        pipeline="reference", layout="paged", block_size=8,
        pool_blocks=pool_blocks, **kw,
    )


@pytest.fixture(scope="module")
def offload_setup():
    cfg = reduced_config("olmo-1b")
    bundle = build_model(cfg, _paged_policy(pool_blocks=14))
    params = bundle.init(jax.random.PRNGKey(0))
    return cfg, bundle, params


def test_offload_roundtrip_bit_identical(offload_setup):
    """Insert a prompt, park its blocks, age them onto the host tier via
    TTL, recall them through begin_chunked — every recalled pool row must
    equal its pre-eviction snapshot byte-for-byte."""
    import jax.numpy as jnp

    _, bundle, params = offload_setup
    clock = [0.0]
    eng = Engine(bundle, n_slots=2, capacity=64,
                 offload_blocks=8, prefix_ttl=5.0)
    eng.set_pool_clock(lambda: clock[0])
    cache = eng.new_cache()
    toks = np.arange(1, 21, dtype=np.int32)                # 20 toks, 3 blocks
    keys = block_hash_chain([int(t) for t in toks], eng.block_size)
    _, cache = eng.insert(params, cache, jnp.asarray(toks[None]),
                          len(toks), slot=0)
    bids = list(eng._seq[0].blocks)
    snap = {
        k: jax.device_get(eng._read_block(cache, jnp.int32(b)))
        for k, b in zip(keys, bids)
    }
    cache = eng.release_slot(cache, 0)                     # all park
    clock[0] = 10.0                                        # past the TTL
    swept, cache = eng.sweep_parked(cache)
    assert swept == len(keys)
    assert eng.offload is not None and set(keys) <= eng.offload.keys()
    # the host copy equals the pre-eviction device snapshot
    for k in keys:
        for a, b in zip(jax.tree.leaves(eng.offload._store[k].payload),
                        jax.tree.leaves(snap[k])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # recall: the chunked resume extends through the host tier
    resume, cache = eng.begin_chunked(cache, 0, toks)
    n_full = (len(toks) - 1) // eng.block_size             # final chunk computes
    assert resume == n_full * eng.block_size
    assert eng.blocks_recalled == n_full
    assert eng.take_recall_units() == pytest.approx(eng.recall_cost * n_full)
    for j, bid in enumerate(eng._seq[0].blocks):
        got = jax.device_get(eng._read_block(cache, jnp.int32(bid)))
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(snap[keys[j]])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # recalled keys moved back to the device tier — exactly one owner
    assert not (eng.offload.keys() & set(keys[:n_full]))
    eng.audit()
    cache = eng.abort_chunked(cache, 0)
    eng.audit()
    assert eng.allocator.n_in_use == 0


def test_offload_engine_matches_baseline_with_fewer_recomputed_tokens(
    offload_setup,
):
    """Acceptance: on a shared-prefix trace under pool pressure the
    two-tier engine produces bit-identical outputs to the plain paged
    engine while recomputing strictly fewer prompt tokens."""
    cfg, _, params = offload_setup
    bundle = build_model(cfg, _paged_policy(pool_blocks=10))

    def trace():
        shared = list(range(7, 23))                        # 16-token prefix
        reqs = [
            Request(rid=i, tokens=shared + [40 + i] * 4, max_new=6)
            for i in range(2)                              # warm the prefix
        ]
        for i in range(2, 6):                              # distinct fillers
            base = 60 + 10 * i                             # age the prefix out
            reqs.append(
                Request(rid=i, tokens=list(range(base, base + 20)), max_new=6)
            )
        reqs += [
            Request(rid=i, tokens=shared + [50 + i] * 4, max_new=6)
            for i in (6, 7)                                # prefix returns
        ]
        return reqs

    # both engines run the same TTL so parked blocks age out identically;
    # only the offload engine can demote them to host instead of losing them
    outs, recomputed = {}, {}
    for name, kw in (
        ("base", dict(prefix_ttl=8.0)),
        ("offload", dict(prefix_ttl=8.0, offload_blocks=12)),
    ):
        eng = Engine(bundle, n_slots=2, capacity=64, **kw)
        sched = ContinuousScheduler(eng, params, chunk_tokens=8)
        outs[name] = dict(sched.run(trace()))
        recomputed[name] = eng.tokens_recomputed
        if name == "offload":
            recalled = eng.blocks_recalled
        eng.audit()
        assert eng.allocator.n_in_use == 0
    assert outs["offload"] == outs["base"]                 # equal fidelity
    assert 0 < recomputed["offload"] < recomputed["base"]
    assert recalled > 0                                    # via real recalls
