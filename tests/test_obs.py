"""Observability subsystem: metrics registry, span tracing, retrieval
introspection — plus the zero-overhead guarantees of the disabled path."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.policy import PolicyConfig
from repro.models import build_model
from repro.obs import (
    MetricsRegistry,
    Observability,
    Snapshot,
    Tracer,
    derive_serving_metrics,
    load_trace_events,
    parse_prometheus_text,
    validate_chrome_trace,
)
from repro.obs.tracing import PID_REQUEST, _percentile
from repro.serving import (
    ContinuousScheduler,
    Engine,
    FaultSpec,
    Request,
    ServingFaultInjector,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OBS_TOOL = os.path.join(REPO, "tools", "obs_report.py")
REG_TOOL = os.path.join(REPO, "tools", "check_bench_regression.py")


# ------------------------------------------------------------ registry units

def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("req_total", "requests")
    c.inc()
    c.inc(2, status="finished")
    assert c.value() == 1.0
    assert c.value(status="finished") == 2.0
    g = reg.gauge("depth")
    g.set(4)
    g.add(-1)
    assert g.value() == 3.0
    h = reg.histogram("lat", buckets=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0):
        h.observe(v)
    assert h.count() == 3 and h.sum() == 55.5
    assert h.mean() == pytest.approx(18.5)
    # create-or-return: same instrument object, kind mismatch raises
    assert reg.counter("req_total") is c
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("req_total")


def test_counter_rejects_negative_and_gate_needs_direction():
    reg = MetricsRegistry()
    with pytest.raises(ValueError, match="negative"):
        reg.counter("c").inc(-1)
    with pytest.raises(ValueError, match="direction"):
        reg.gauge("g", gate=True)


def test_disabled_registry_is_noop():
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("x")
    c.inc(5)
    assert c.value() == 0.0
    reg.gauge("y").set(3)
    reg.histogram("z").observe(1)
    assert reg.snapshot().series == []
    # one shared null instrument — no per-call allocation
    assert reg.counter("a") is reg.gauge("b")


def test_snapshot_diff_counters_subtract_gauges_keep_level():
    reg = MetricsRegistry()
    c, g, h = reg.counter("c"), reg.gauge("g"), reg.histogram("h", buckets=(1.0,))
    c.inc(3)
    g.set(10)
    h.observe(0.5)
    older = reg.snapshot()
    c.inc(4)
    g.set(2)
    h.observe(7.0)
    d = reg.snapshot().diff(older)
    assert d.value("c") == 4.0
    assert d.value("g") == 2.0
    hs = d.get("h")
    assert hs.count == 1 and hs.value == 7.0 and hs.bucket_counts == (0, 1)


def test_snapshot_json_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c", "help", unit="tok").inc(2, mode="x")
    reg.gauge("g", better="lower", gate=True).set(1.5)
    reg.histogram("h", buckets=(1.0, 2.0)).observe(1.7)
    doc = reg.write_snapshot_json(str(tmp_path / "snap.json"))
    with open(tmp_path / "snap.json") as f:
        assert json.load(f) == doc
    back = Snapshot.from_json(doc)
    assert back.to_json() == doc
    assert back.value("c", mode="x") == 2.0
    assert back.get("g").gate is True


def test_prometheus_roundtrip():
    reg = MetricsRegistry()
    reg.counter("c").inc(3, mode="a")
    reg.gauge("g").set(0.25)
    h = reg.histogram("h", buckets=(1.0, 2.0))
    h.observe(0.5)
    h.observe(1.5)
    h.observe(9.0)
    text = reg.snapshot().to_prometheus_text()
    flat = parse_prometheus_text(text)
    assert flat['c{mode="a"}'] == 3.0
    assert flat["g"] == 0.25
    assert flat['h_bucket{le="1.0"}'] == 1.0
    assert flat['h_bucket{le="2.0"}'] == 2.0
    assert flat['h_bucket{le="+Inf"}'] == 3.0
    assert flat["h_sum"] == 11.0 and flat["h_count"] == 3.0


# ------------------------------------------------------------- tracing units

def _synthetic_tracer():
    tr = Tracer()
    tr.instant("submitted", ts=0.0, pid=PID_REQUEST, tid=0, cat="lifecycle")
    tr.instant("submitted", ts=5.0, pid=PID_REQUEST, tid=1, cat="lifecycle")
    tr.complete("prefill", 0.0, 8.0, pid=PID_REQUEST, tid=0, slot=0)
    for t in (10.0, 12.0, 14.0):
        tr.instant("token", ts=t, pid=PID_REQUEST, tid=0, cat="decode")
    tr.instant("token", ts=20.0, pid=PID_REQUEST, tid=1, cat="decode")
    tr.counter("occupancy", {"running": 2.0}, ts=14.0)
    return tr


def test_chrome_export_validates_and_roundtrips(tmp_path):
    tr = _synthetic_tracer()
    doc = tr.write_chrome_trace(str(tmp_path / "t.trace.json"))
    with open(tmp_path / "t.trace.json") as f:
        assert json.load(f) == doc
    assert validate_chrome_trace(doc) == []
    back = load_trace_events(doc)
    assert [(e.name, e.ph, e.ts, e.pid, e.tid, e.dur) for e in back] == [
        (e.name, e.ph, e.ts, e.pid, e.tid, e.dur) for e in tr.events]
    # jsonl: one parseable row per event
    lines = tr.to_jsonl().strip().split("\n")
    assert len(lines) == len(tr.events)
    assert json.loads(lines[0])["name"] == "submitted"


def test_validate_catches_malformed():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({"traceEvents": [{"name": "x"}]}) != []
    bad_dur = {"traceEvents": [
        {"name": "x", "ph": "X", "ts": 0, "pid": 0, "tid": 0}]}
    assert any("dur" in e for e in validate_chrome_trace(bad_dur))
    bad_counter = {"traceEvents": [
        {"name": "x", "ph": "C", "ts": 0, "pid": 0, "tid": 0,
         "args": {"v": "nan?"}}]}
    assert any("numeric" in e for e in validate_chrome_trace(bad_counter))


def test_derive_serving_metrics_synthetic():
    d = derive_serving_metrics(_synthetic_tracer())
    assert d["n_requests"] == 2 and d["total_tokens"] == 4
    # TTFTs are [10, 15] → p50 linearly interpolated
    assert d["ttft_p50"] == pytest.approx(12.5)
    assert d["itl_p50"] == 2.0
    assert d["makespan"] == 20.0
    assert d["tokens_per_kunit"] == pytest.approx(200.0)


def test_percentile_matches_numpy_bitwise():
    rng = np.random.default_rng(0)
    for n in (1, 2, 5, 17, 100):
        xs = sorted(rng.normal(size=n).tolist())
        for p in (0, 25, 50, 90, 99, 100):
            assert _percentile(xs, p / 100.0) == float(np.percentile(xs, p)), (n, p)


# ---------------------------------------------------- serving integration

@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("olmo-1b")

    def mk(pool_blocks=0):
        pol = PolicyConfig(
            kind="fier", budget=16, group=8, skip_layers=1,
            pipeline="one_pass",
            layout="paged" if pool_blocks else "slab",
            block_size=8, pool_blocks=pool_blocks,
        )
        return build_model(cfg, pol)

    slab = mk()
    params = slab.init(jax.random.PRNGKey(0))
    return cfg, mk, slab, params


def _reqs(n=3, max_new=5):
    return [Request(rid=i, tokens=list(range(3 + i, 11 + i)), max_new=max_new)
            for i in range(n)]


def test_disabled_obs_identical_outputs_and_no_extra_compiles(setup):
    """The overhead guard: an obs-enabled engine produces bit-identical
    outputs AND identical jit cache populations (zero extra recompiles)
    vs an engine with observability off."""
    cfg, mk, slab, params = setup
    runs = {}
    for label, obs in (("off", None), ("on", Observability())):
        eng = Engine(mk(pool_blocks=24), n_slots=2, capacity=64, obs=obs)
        sched = ContinuousScheduler(eng, params, pad_prompt_to=16)
        out = sched.run(_reqs())
        runs[label] = (dict(out), eng.jit_cache_sizes())
    out_off, jits_off = runs["off"]
    out_on, jits_on = runs["on"]
    assert out_off == out_on
    assert jits_off == jits_on, (jits_off, jits_on)
    # and the disabled path really recorded nothing
    assert isinstance(jits_off, dict) and sum(jits_off.values()) > 0


def test_trace_determinism_two_seeded_runs(setup):
    """Two identical seeded runs must produce identical virtual-clock
    traces (wall_ts excluded via canonical()) and identical snapshots."""
    cfg, mk, slab, params = setup

    def one_run():
        eng = Engine(mk(pool_blocks=24), n_slots=2, capacity=64,
                     obs=Observability())
        sched = ContinuousScheduler(eng, params, pad_prompt_to=16)
        sched.run(_reqs())
        return (eng.obs.tracer.canonical(),
                eng.obs.metrics.snapshot().as_dict(),
                derive_serving_metrics(eng.obs.tracer))

    trace_a, snap_a, d_a = one_run()
    trace_b, snap_b, d_b = one_run()
    assert trace_a == trace_b
    assert snap_a == snap_b
    assert d_a == d_b
    assert d_a["total_tokens"] > 0 and d_a["ttft_p99"] > 0


def test_outcomes_carry_slot_and_preempt_events(setup):
    """Preemptions under oversubscription leave structured health events
    (slot, rid, reason) and every retirement records its slot."""
    cfg, mk, slab, params = setup
    eng = Engine(mk(pool_blocks=10), n_slots=3, capacity=64,
                 obs=Observability())
    sched = ContinuousScheduler(eng, params, pad_prompt_to=16)
    out = sched.run(_reqs(3, max_new=25))
    assert sched.preemptions > 0
    preempts = [e for e in sched.health.events if e["kind"] == "preempt"]
    assert preempts, sched.health.events
    for e in preempts:
        assert isinstance(e["slot"], int) and isinstance(e["rid"], int)
        assert e["reason"]
    for oc in out.outcomes.values():
        assert oc.status == "finished" and oc.slot is not None
    # the same preemptions landed on the trace and in the registry
    tr_preempts = [e for e in eng.obs.tracer.events if e.name == "preempt"]
    assert len(tr_preempts) == sched.preemptions
    assert eng.obs.metrics.counter("preemptions_total").value() == float(
        sched.preemptions)
    assert sched.health.summary()["events"] == len(sched.health.events)


def test_quarantine_and_fault_events(setup):
    """An injected poison-logits fault quarantines its slot: the outcome,
    the health event log, and the trace all agree."""
    cfg, mk, slab, params = setup
    inj = ServingFaultInjector([FaultSpec("poison_logits", step=2, rid=0)])
    eng = Engine(mk(pool_blocks=24), n_slots=2, capacity=64,
                 obs=Observability())
    sched = ContinuousScheduler(eng, params, pad_prompt_to=16, injector=inj)
    out = sched.run(_reqs(2, max_new=20))
    assert inj.all_fired
    oc = out.outcomes[0]
    assert oc.status == "quarantined" and oc.slot is not None
    q_events = [e for e in sched.health.events if e["kind"] == "quarantine"]
    assert len(q_events) == 1 and q_events[0]["rid"] == 0
    names = [e.name for e in eng.obs.tracer.events]
    assert "fault" in names and "quarantine" in names
    assert eng.obs.metrics.counter("faults_injected_total").value(
        kind="poison_logits") == 1.0


def test_pool_stats_shim_matches_allocator_stats(setup):
    """Engine.pool_stats() is a naming shim over BlockAllocator.stats():
    every legacy key must alias a canonical series exactly."""
    cfg, mk, slab, params = setup
    eng = Engine(mk(pool_blocks=24), n_slots=2, capacity=64,
                 obs=Observability())
    sched = ContinuousScheduler(eng, params, pad_prompt_to=16)
    sched.run(_reqs())
    legacy, canon = eng.pool_stats(), eng.allocator.stats()
    assert legacy["blocks_in_use"] == canon["pool_blocks_in_use"]
    assert legacy["blocks_allocated"] == canon["pool_blocks_usable"]
    assert legacy["peak_in_use"] == canon["pool_peak_in_use"]
    assert legacy["prefix_block_hits"] == canon["pool_prefix_block_hits"]
    assert legacy["cow_copies"] == canon["pool_cow_copies"]
    assert legacy["utilization"] == canon["pool_utilization"]
    es = eng.engine_stats()
    assert legacy["prefills"] == es["engine_prefills"]
    assert legacy["budget_downshifts"] == es["engine_budget_downshifts"]
    # the sampled gauges carry the canonical names
    snap = eng.obs.metrics.snapshot()
    assert snap.value("pool_blocks_usable") == canon["pool_blocks_usable"]
    assert snap.value("engine_prefills") == es["engine_prefills"]


def test_introspector_records_bounded_quality_series(setup):
    """Opt-in retrieval introspection: probes land in the registry with
    ratio values in [0, 1] and budget utilization consistent with
    min(length, budget) / budget."""
    cfg, mk, slab, params = setup
    obs = Observability(introspect=True)
    eng = Engine(mk(pool_blocks=24), n_slots=2, capacity=64, obs=obs)
    sched = ContinuousScheduler(eng, params, pad_prompt_to=16)
    sched.run(_reqs(2, max_new=8))
    recs = obs.introspector.records
    assert recs, "no probes taken"
    for r in recs:
        assert 0.0 <= r.oracle_overlap <= 1.0
        assert 0.0 <= r.recaptured_mass <= 1.0
        assert r.budget_utilization == pytest.approx(
            min(r.length, r.budget) / r.budget)
        assert np.isfinite(r.tau)
    snap = obs.metrics.snapshot()
    fier = {s.name for s in snap.series if s.name.startswith("fier_")}
    assert {"fier_oracle_overlap", "fier_recaptured_mass",
            "fier_budget_utilization", "fier_tau",
            "fier_probes_total"} <= fier
    assert snap.value("fier_probes_total") == float(len(recs))
    # probes also land on the trace as counter rows
    assert any(e.name.startswith("introspect/")
               for e in obs.tracer.events)


def test_introspection_skips_probe_layer_outside_rest_stack(setup):
    """A probe layer beyond the rest (retrieval-policy) stack must yield
    no records instead of indexing out of range — the reduced config has
    a single rest layer, so layer 99 exercises the guard."""
    cfg, mk, slab, params = setup
    obs = Observability(introspect=True, probe_layer=99)
    eng = Engine(slab, n_slots=1, capacity=64, obs=obs)
    sched = ContinuousScheduler(eng, params, pad_prompt_to=16)
    sched.run(_reqs(1))
    assert obs.introspector.records == []
    assert obs.metrics.snapshot().value("fier_probes_total") == 0.0


# -------------------------------------------------------------- tool lanes

def _trace_file(tmp_path, name="t.trace.json"):
    path = str(tmp_path / name)
    _synthetic_tracer().write_chrome_trace(path)
    return path


def test_obs_report_validate_and_report(tmp_path):
    good = _trace_file(tmp_path)
    reg = MetricsRegistry()
    reg.gauge("vt_ttft_p99", better="lower", gate=True).set(100.0)
    snap = str(tmp_path / "METRICS_demo.json")
    reg.write_snapshot_json(snap)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, OBS_TOOL, "--validate", good, snap],
                       capture_output=True, text=True, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    r = subprocess.run([sys.executable, OBS_TOOL, good, snap],
                       capture_output=True, text=True, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "span-derived serving metrics" in r.stdout
    assert "vt_ttft_p99" in r.stdout and "[gated]" in r.stdout


def test_obs_report_validate_fails_on_malformed(tmp_path):
    path = _trace_file(tmp_path)
    with open(path) as f:
        doc = json.load(f)
    for row in doc["traceEvents"]:
        row.pop("ph", None)
    bad = str(tmp_path / "bad.trace.json")
    with open(bad, "w") as f:
        json.dump(doc, f)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, OBS_TOOL, "--validate", bad],
                       capture_output=True, text=True, env=env)
    assert r.returncode == 1
    assert "INVALID" in r.stderr


def _snapshot_doc(dirpath, value):
    reg = MetricsRegistry()
    reg.gauge("vt_ttft_p99", unit="unit", better="lower", gate=True).set(value)
    reg.counter("info_counter").inc(3)
    os.makedirs(dirpath, exist_ok=True)
    reg.write_snapshot_json(os.path.join(dirpath, "METRICS_demo.json"))


def test_regression_tool_gates_snapshot_format(tmp_path):
    """check_bench_regression reads METRICS_*.json registry snapshots:
    gated series within tolerance pass, a +30% latency regression fails."""
    _snapshot_doc(tmp_path / "base", 100.0)
    _snapshot_doc(tmp_path / "ok", 115.0)     # +15% < +20%
    _snapshot_doc(tmp_path / "bad", 130.0)    # +30% > +20%
    run = lambda new: subprocess.run(
        [sys.executable, REG_TOOL, "--baseline-dir", str(tmp_path / "base"),
         "--new-dir", str(new)], capture_output=True, text=True)
    r = run(tmp_path / "ok")
    assert r.returncode == 0, r.stdout + r.stderr
    r = run(tmp_path / "bad")
    assert r.returncode == 1
    assert "vt_ttft_p99" in r.stderr
