"""Config registry: exact assigned values, reduced-config families, shapes."""
import pytest

from repro.configs import ARCHS, SHAPES, get_config, reduced_config, shape_cells

EXPECTED = {
    # arch: (layers, d_model, heads, kv, d_ff, vocab)
    "whisper-small": (12, 768, 12, 12, 3072, 51865),
    "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
    "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
    "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
    "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
    "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
    "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
    "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
    "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
    "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
}


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_assigned_values_exact(arch):
    c = get_config(arch)
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == \
        EXPECTED[arch]


def test_all_archs_registered():
    assert set(ARCHS) == set(EXPECTED)


def test_moe_and_ssm_extras():
    g = get_config("granite-moe-1b-a400m")
    assert (g.n_experts, g.topk_experts) == (32, 8)
    q = get_config("qwen3-moe-235b-a22b")
    assert (q.n_experts, q.topk_experts) == (128, 8)
    m = get_config("mamba2-370m")
    assert m.ssm_state == 128 and m.attention_free
    z = get_config("zamba2-7b")
    assert z.ssm_state == 64 and z.attn_every > 0


def test_param_counts_in_range():
    """Sanity: computed param counts land near the advertised sizes."""
    approx = {
        "olmo-1b": (0.9e9, 1.6e9),
        "command-r-plus-104b": (90e9, 120e9),
        "starcoder2-3b": (2.5e9, 3.8e9),
        "minicpm-2b": (2.0e9, 3.3e9),
        "mamba2-370m": (0.3e9, 0.5e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "zamba2-7b": (6e9, 9e9),
        "llava-next-mistral-7b": (6.5e9, 8e9),
    }
    for arch, (lo, hi) in approx.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)
    # MoE active params ≪ total
    q = get_config("qwen3-moe-235b-a22b")
    assert q.active_param_count() < 0.2 * q.param_count()


def test_shapes_and_cells():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert SHAPES["long_500k"].seq_len == 524_288
    assert shape_cells("whisper-small") == ["train_4k", "prefill_32k", "decode_32k"]
    assert len(shape_cells("olmo-1b")) == 4
    total = sum(len(shape_cells(a)) for a in ARCHS)
    assert total == 39  # 40 assigned minus whisper long_500k (DESIGN.md §5)


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_reduced_configs_buildable(arch):
    c = reduced_config(arch)
    assert c.family == get_config(arch).family
    assert c.d_model <= 128 and c.vocab <= 512
