import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# the `slow` marker itself is declared once, in pyproject.toml
# [tool.pytest.ini_options].

def pytest_collection_modifyitems(config, items):
    # Tests that call the subprocess helper spawn forced multi-device CPU
    # topologies (fresh jax init + compile each, ~minutes in total): mark
    # them `slow` so CI's fast lane (`-m "not slow"`) skips them.  Detect
    # by source so the set can't drift as tests are added.
    import inspect

    for item in items:
        try:
            src = inspect.getsource(item.function)
        except (OSError, TypeError):
            continue
        if "run_in_subprocess" in src:
            item.add_marker(pytest.mark.slow)


def run_in_subprocess(code: str, n_devices: int = 4, timeout: int = 600):
    """Run a python snippet with a forced CPU device count (multi-device
    tests need the flag set before jax init, so: subprocess).  NOTE: the
    512-device flag is only ever set inside launch/dryrun.py, per spec —
    tests use small counts here."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout, env=env,
    )
    if r.returncode != 0:
        pytest.fail(f"subprocess failed:\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}")
    return r.stdout


@pytest.fixture(autouse=True)
def _allocator_leak_audit():
    """After every test: run the allocator invariant audit on every live
    paged engine, and — when the engine is drained (no resident
    sequences) — assert zero leaked blocks.  A double free, a lost ref,
    or a release path that skips a block fails the *offending* test
    instead of silently corrupting a later one."""
    yield
    # import lazily: most test modules never touch the serving engine
    import sys

    eng_mod = sys.modules.get("repro.serving.engine")
    if eng_mod is None:
        return
    for eng in list(eng_mod._LIVE_ENGINES):
        if not getattr(eng, "paged", False):
            continue
        eng.audit()
        if not eng._seq:  # drained: every block must be back in the pool
            assert eng.allocator.n_in_use == 0, (
                f"paged engine leaked {eng.allocator.n_in_use} blocks "
                f"after drain"
            )
