"""Property-based tests (hypothesis) on the system's core invariants.

The whole module skips when hypothesis isn't installed (it is declared in
pyproject.toml and present in CI, but optional in minimal dev containers).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import quantize as qz
from repro.core import retrieval as rt

SHAPE = st.tuples(
    st.integers(1, 3),                      # B
    st.sampled_from([32, 64, 128]),         # S
    st.integers(1, 3),                      # Hkv
    st.sampled_from([8, 16, 32]),           # D
    st.sampled_from([8, 16, 32]),           # g
).filter(lambda t: t[1] % t[4] == 0)


def _keys(seed, B, S, H, D):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return jax.random.normal(k1, (B, S, H, D)) * jnp.exp(
        jax.random.normal(k2, (D,)) * 0.5
    )


@settings(max_examples=20, deadline=None)
@given(SHAPE, st.integers(0, 2**31 - 1))
def test_quantize_roundtrip_and_bounds(shape, seed):
    """∀ K: pack∘unpack = id, and K̃ stays within each group's [min, max]."""
    B, S, H, D, g = shape
    K = _keys(seed, B, S, H, D)
    qk = qz.quantize(K, g)
    np.testing.assert_array_equal(
        np.asarray(qz.pack_bits(qz.unpack_bits(qk.codes))), np.asarray(qk.codes)
    )
    Kd = np.asarray(qz.dequantize(qk), np.float32).reshape(B, S // g, g, H, D)
    Kg = np.asarray(K).reshape(B, S // g, g, H, D)
    lo, hi = Kg.min(2, keepdims=True), Kg.max(2, keepdims=True)
    span = hi - lo + 1e-3
    assert (Kd >= lo - 0.02 * span - 1e-3).all()
    assert (Kd <= hi + 0.02 * span + 1e-3).all()


@settings(max_examples=20, deadline=None)
@given(SHAPE, st.integers(0, 2**31 - 1), st.integers(1, 8))
def test_topk_indices_always_valid(shape, seed, budget_pow):
    """select_topk never returns an out-of-length index when enough valid
    tokens exist, for any scores."""
    B, S, H, D, g = shape
    scores = jax.random.normal(jax.random.PRNGKey(seed), (B, H, S))
    budget = min(2 * budget_pow, S // 2)
    length = jnp.full((B,), S // 2, jnp.int32)
    idx = np.asarray(rt.select_topk(scores, budget, length))
    assert (idx < S // 2).all()
    # indices unique per (b, h)
    for b in range(B):
        for h in range(H):
            assert len(set(idx[b, h].tolist())) == budget


@settings(max_examples=15, deadline=None)
@given(SHAPE, st.integers(0, 2**31 - 1))
def test_margin_preservation(shape, seed):
    """The paper's hinge-objective insight (§3.2): tokens whose true score
    exceeds all others by more than the worst-case quantization error must
    stay in the 1-bit top-k."""
    B, S, H, D, g = shape
    K = _keys(seed, B, S, H, D)
    q = jax.random.normal(jax.random.PRNGKey(seed ^ 1), (B, H, D))
    qk = qz.quantize(K, g)
    exact = np.asarray(rt.exact_scores(q, K))          # [B, H, S]
    approx = np.asarray(rt.approx_scores(q, qk))
    # worst-case per-token error bound: |q|·s_group (scale = half range)
    s_full = np.asarray(
        jnp.repeat(qk.scale.astype(jnp.float32), g, axis=1)
    )  # [B, S, H, D]
    qn = np.abs(np.asarray(q))                          # [B, H, D]
    err_bound = np.einsum("bhd,bshd->bhs", qn, s_full) + 1e-4
    for b in range(B):
        for h in range(H):
            e, a, eb = exact[b, h], approx[b, h], err_bound[b, h]
            top = int(np.argmax(e))
            margin = e[top] - np.delete(e, top).max(initial=-np.inf)
            if margin > eb[top] + eb.max():
                top_a = set(np.argsort(-a)[:2].tolist())
                assert top in top_a


GQA_SHAPE = st.tuples(
    st.integers(1, 3),                      # B
    st.sampled_from([32, 64, 128]),         # S
    st.integers(1, 3),                      # Hkv
    st.integers(1, 4),                      # rep (Hq = Hkv · rep)
    st.sampled_from([8, 16, 32]),           # D
    st.sampled_from([8, 16, 32]),           # g
).filter(lambda t: t[1] % t[5] == 0)


@settings(max_examples=20, deadline=None)
@given(GQA_SHAPE, st.integers(0, 2**31 - 1), st.integers(1, 64),
       st.sampled_from(["max", "sum"]))
def test_onepass_retrieval_exact_index_set_property(shape, seed, budget, mode):
    """∀ GQA shapes, seeds, budgets, reductions: the one-pass retrieval
    kernel returns exactly the lax.top_k index set over the masked,
    group-reduced kernel scores (scores it never materialises)."""
    from repro.kernels import ops

    B, S, Hkv, rep, D, g = shape
    Hq = Hkv * rep
    budget = min(budget, S)
    K = _keys(seed, B, S, Hkv, D)
    q = jax.random.normal(jax.random.PRNGKey(seed ^ 3), (B, Hq, D))
    qk = qz.quantize(K, g)
    length = jnp.full((B,), max(S // 2, g), jnp.int32)
    from repro.core.policy import CacheView

    got = np.asarray(ops.retrieve(
        q, CacheView.slab(None, None, qk, length), budget, group_reduce=mode
    ))
    kv = rt.reduce_over_query_group(ops.fier_score(q, qk), Hkv, mode)
    want = np.asarray(rt.select_topk(kv, budget, length))
    np.testing.assert_array_equal(np.sort(got, -1), np.sort(want, -1))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_flash_attention_matches_oracle_property(seed):
    from repro.models.layers import attention_ref, flash_attention

    r = np.random.default_rng(seed)
    B, Sq, Sk = int(r.integers(1, 3)), int(r.integers(4, 24)), int(r.integers(8, 40))
    Hkv = int(r.integers(1, 3))
    rep = int(r.integers(1, 3))
    D = int(r.choice([8, 16]))
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hkv * rep, D))
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D))
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D))
    off = Sk - Sq
    o1 = flash_attention(q, k, v, causal=True, block_k=8, q_offset=off)
    o2 = attention_ref(q, k, v, causal=True, q_offset=off)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5, rtol=3e-5)
