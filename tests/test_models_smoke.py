"""Per-arch smoke tests (reduced configs): one train step, prefill+decode,
shape/NaN assertions, and the golden prefill↔decode consistency check."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config
from repro.core.policy import PolicyConfig
from repro.models import build_model

B, S = 2, 32
POL = PolicyConfig(kind="fier", budget=16, group=8, skip_layers=1)


def _batches(cfg, rng):
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    train = {"tokens": toks, "targets": toks, "loss_mask": jnp.ones((B, S))}
    pre = {"tokens": toks, "lengths": jnp.full((B,), S, jnp.int32)}
    if cfg.family == "vlm":
        nv = cfg.n_vision_tokens
        vis = jax.random.normal(rng, (B, nv, cfg.d_model), jnp.bfloat16)
        train = {
            "tokens": toks[:, : S - nv], "targets": toks, "loss_mask":
            jnp.ones((B, S)), "vision_embeds": vis,
        }
        pre = {"tokens": toks[:, : S - nv], "vision_embeds": vis,
               "lengths": jnp.full((B,), S, jnp.int32)}
    if cfg.family == "encdec":
        frames = jax.random.normal(rng, (B, cfg.enc_ctx, cfg.d_model), jnp.bfloat16)
        train["frames"] = frames
        pre["frames"] = frames
    return train, pre


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_train_prefill_decode(arch):
    cfg = reduced_config(arch)
    bundle = build_model(cfg, POL, max_positions=64)
    params = bundle.init(jax.random.PRNGKey(0))
    train, pre = _batches(cfg, jax.random.PRNGKey(1))

    loss, metrics = jax.jit(bundle.train_loss)(params, train)
    assert jnp.isfinite(loss), arch
    assert float(metrics["tokens"]) > 0

    logits, cache = jax.jit(lambda p, b: bundle.prefill(p, b, capacity=64))(params, pre)
    from repro.configs.base import padded_vocab

    assert logits.shape == (B, padded_vocab(cfg))
    assert jnp.isfinite(logits).all(), arch
    # padded vocab columns must be masked out
    assert float(logits[:, cfg.vocab :].max(initial=-jnp.inf)) < -1e20

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits2, cache2 = jax.jit(bundle.decode_step)(params, tok, cache)
    assert jnp.isfinite(logits2).all(), arch
    assert int(cache2["length"][0]) == int(cache["length"][0]) + 1


@pytest.mark.parametrize("arch", ["olmo-1b", "mamba2-370m", "zamba2-7b", "whisper-small"])
def test_decode_consistent_with_longer_prefill(arch):
    """Golden consistency: prefill(t0..tn) then decode(t_{n+1}) must give the
    same logits as prefill(t0..t_{n+1}) directly (full policy — exactness)."""
    cfg = reduced_config(arch)
    bundle = build_model(cfg, PolicyConfig(kind="full"), max_positions=64)
    params = bundle.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.enc_ctx, cfg.d_model), jnp.bfloat16
        )

    n = S - 1
    pre_n = {"tokens": toks[:, :n], "lengths": jnp.full((B,), n, jnp.int32), **extras}
    _, cache = jax.jit(lambda p, b: bundle.prefill(p, b, capacity=64))(params, pre_n)
    logits_dec, _ = jax.jit(bundle.decode_step)(params, toks[:, n], cache)

    pre_full = {"tokens": toks, "lengths": jnp.full((B,), S, jnp.int32), **extras}
    logits_pre, _ = jax.jit(lambda p, b: bundle.prefill(p, b, capacity=64))(params, pre_full)

    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32), np.asarray(logits_pre, np.float32),
        atol=0.15, rtol=0.05,  # bf16 compute; rankings must agree
    )
    agree = (np.argmax(np.asarray(logits_dec), -1)
             == np.argmax(np.asarray(logits_pre), -1)).mean()
    assert agree == 1.0, f"{arch}: greedy tokens diverge between paths"


def test_variable_length_prefill_masking():
    """Shorter sequences in a batch must not see the padding garbage."""
    cfg = reduced_config("olmo-1b")
    bundle = build_model(cfg, PolicyConfig(kind="full"))
    params = bundle.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0, cfg.vocab)
    n = 20
    # batch row 1 has length n; row 0 full
    pre = {"tokens": toks, "lengths": jnp.array([S, n], jnp.int32)}
    logits_mixed, _ = jax.jit(lambda p, b: bundle.prefill(p, b, capacity=64))(params, pre)
    # same short sequence alone, exactly length n
    pre_short = {"tokens": toks[1:, :n], "lengths": jnp.array([n], jnp.int32)}
    logits_short, _ = jax.jit(lambda p, b: bundle.prefill(p, b, capacity=64))(params, pre_short)
    np.testing.assert_allclose(
        np.asarray(logits_mixed[1], np.float32),
        np.asarray(logits_short[0], np.float32), atol=0.15, rtol=0.05,
    )
