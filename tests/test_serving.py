"""Serving engine + continuous-batching scheduler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.policy import PolicyConfig
from repro.models import build_model
from repro.serving import ContinuousScheduler, Engine, Request, SamplingConfig
from repro.serving.engine import sample_token


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("olmo-1b")
    pol = PolicyConfig(kind="fier", budget=16, group=8, skip_layers=1)
    bundle = build_model(cfg, pol)
    params = bundle.init(jax.random.PRNGKey(0))
    return cfg, bundle, params


def test_continuous_matches_static(setup):
    cfg, bundle, params = setup
    eng = Engine(bundle, n_slots=3, capacity=64)
    sched = ContinuousScheduler(eng, params, pad_prompt_to=16)
    reqs = [Request(rid=i, tokens=list(range(3 + i, 11 + i)), max_new=5)
            for i in range(5)]
    out = sched.run(reqs)
    assert all(len(v) == 5 for v in out.values())
    assert sched.mean_occupancy > 1.5  # slots actually shared

    eng1 = Engine(bundle, n_slots=1, capacity=64)
    for r in reqs[:2]:
        p = jnp.asarray(np.asarray(r.tokens, np.int32)[None])
        toks = eng1.generate(params, p, jnp.array([len(r.tokens)], jnp.int32), 5)
        assert np.asarray(toks[0]).tolist() == out[r.rid], r.rid


def test_eos_terminates_early(setup):
    cfg, bundle, params = setup
    eng = Engine(bundle, n_slots=2, capacity=64)
    sched = ContinuousScheduler(eng, params, pad_prompt_to=16)
    # find what the model emits first, then use it as the EOS token
    probe = ContinuousScheduler(Engine(bundle, n_slots=1, capacity=64), params,
                                pad_prompt_to=16)
    first = probe.run([Request(rid=0, tokens=[1, 2, 3], max_new=2)])[0][0]
    reqs = [Request(rid=0, tokens=[1, 2, 3], max_new=50, eos=first)]
    out = sched.run(reqs)
    assert len(out[0]) == 1  # stopped at eos immediately


def test_sampling_modes():
    logits = jnp.array([[0.0, 5.0, 1.0, -2.0]])
    greedy = sample_token(jax.random.PRNGKey(0), logits, SamplingConfig())
    assert int(greedy[0]) == 1
    topk = sample_token(
        jax.random.PRNGKey(0), logits, SamplingConfig(temperature=1.0, top_k=2)
    )
    assert int(topk[0]) in (1, 2)


def test_scheduler_threads_fresh_rng_each_step(setup, monkeypatch):
    """Regression: Engine.decode used to fall back to PRNGKey(0) on every
    call and the scheduler never passed an rng, so temperature > 0 serving
    resampled from the identical key each step.  Two consecutive sampled
    steps must now use distinct keys."""
    import repro.serving.engine as engine_mod

    cfg, bundle, params = setup
    seen = []
    orig = engine_mod.sample_token

    def spy(rng, logits, scfg):
        seen.append(np.asarray(rng).copy())
        return orig(rng, logits, scfg)

    monkeypatch.setattr(engine_mod, "sample_token", spy)
    eng = Engine(bundle, n_slots=2, capacity=64,
                 sampling=SamplingConfig(temperature=1.0, top_k=2))
    sched = ContinuousScheduler(eng, params, pad_prompt_to=16)
    sched.run([Request(rid=0, tokens=[1, 2, 3], max_new=6)])
    assert len(seen) >= 2
    keys = {tuple(k.tolist()) for k in seen}
    assert len(keys) == len(seen), "sampling rng key reused across steps"


def test_engine_decode_fallback_rng_advances(setup):
    """Engine.decode without an explicit rng must split a fresh key per
    call (not PRNGKey(0) forever): consecutive sampled steps differ."""
    cfg, bundle, params = setup
    eng = Engine(bundle, n_slots=1, capacity=64,
                 sampling=SamplingConfig(temperature=1.0, top_k=0))
    k0 = eng._rng
    batch = {"tokens": jnp.zeros((1, 16), jnp.int32),
             "lengths": jnp.array([4], jnp.int32)}
    _, cache = eng.prefill_batch(params, batch)
    tok = jnp.zeros((1,), jnp.int32)
    draws = []
    for _ in range(8):
        tok, _, cache = eng.decode(params, tok, cache)
        draws.append(int(tok[0]))
    assert not np.array_equal(np.asarray(eng._rng), np.asarray(k0))
    # 8 draws at temperature 1.0 over a 512-vocab softmax: all-identical
    # only if the rng key repeats (the exact bug) or the distribution is
    # near-deterministic — the trained-free random init it isn't
    assert len(set(draws)) > 1, draws


def test_scheduler_queue_fifo_order(setup):
    """The deque-backed admission queue must preserve FIFO order: with one
    slot, requests finish in submission order."""
    cfg, bundle, params = setup
    eng = Engine(bundle, n_slots=1, capacity=64)
    sched = ContinuousScheduler(eng, params, pad_prompt_to=16)
    reqs = [Request(rid=i, tokens=[3 + i, 4 + i], max_new=2) for i in range(4)]
    admits = []
    orig_admit = sched._admit

    def tracking_admit(queue, cache, cur):
        before = [r.rid for r in queue]
        res = orig_admit(queue, cache, cur)
        admits.append((before, [r.rid for r in queue]))
        return res

    sched._admit = tracking_admit
    out = sched.run(reqs)
    assert set(out) == {0, 1, 2, 3}
    # every admission must take from the *head*: the remaining queue is a
    # suffix of the pre-admit queue (tail-popping LIFO would leave a
    # prefix instead and fail here)
    for before, after in admits:
        assert after == before[len(before) - len(after):], (before, after)


def test_slot_isolation(setup):
    """A request's output must not depend on what occupies other slots."""
    cfg, bundle, params = setup
    out = {}
    for other in ([11, 12, 13, 14], [99, 98, 97]):
        eng = Engine(bundle, n_slots=2, capacity=64)
        sched = ContinuousScheduler(eng, params, pad_prompt_to=16)
        reqs = [
            Request(rid=0, tokens=[5, 6, 7, 8], max_new=4),
            Request(rid=1, tokens=other, max_new=4),
        ]
        out[tuple(other)] = sched.run(reqs)[0]
    vals = list(out.values())
    assert vals[0] == vals[1], "slot contents leaked across requests"


# ---------------------------------------------------------- chunked prefill

@pytest.fixture(scope="module")
def chunk_setup():
    cfg = reduced_config("olmo-1b")

    def mk(layout, pool_blocks=0):
        pol = PolicyConfig(
            kind="fier", budget=16, group=8, skip_layers=1,
            pipeline="one_pass", layout=layout,
            block_size=8, pool_blocks=pool_blocks,
        )
        return build_model(cfg, pol)

    slab = mk("slab")
    params = slab.init(jax.random.PRNGKey(0))
    return cfg, mk, slab, params


def test_chunked_prefill_matches_monolithic(chunk_setup):
    """Chunked admission must be a pure scheduling change: token-for-token
    identical outputs to monolithic prefill, on both cache layouts."""
    cfg, mk, slab, params = chunk_setup

    def reqs():
        return [
            Request(rid=i, tokens=list(range(3 + i, 20 + 3 * i)), max_new=6)
            for i in range(4)
        ]

    for bundle in (slab, mk("paged", pool_blocks=40)):
        mono = ContinuousScheduler(
            Engine(bundle, n_slots=2, capacity=64), params
        ).run(reqs())
        chunked = ContinuousScheduler(
            Engine(bundle, n_slots=2, capacity=64), params, chunk_tokens=5
        ).run(reqs())
        assert chunked == mono, bundle.policy.layout


def test_decode_runs_between_chunks(chunk_setup):
    """The token quantum interleaves: while a long prompt is admitted
    chunk by chunk, the resident request keeps decoding in between."""
    cfg, mk, slab, params = chunk_setup
    eng = Engine(slab, n_slots=2, capacity=64)
    sched = ContinuousScheduler(eng, params, chunk_tokens=4)
    events = []
    orig_chunk, orig_decode = eng.prefill_chunk, eng.decode

    def chunk_spy(*a, **k):
        events.append("chunk")
        return orig_chunk(*a, **k)

    def decode_spy(*a, **k):
        events.append("decode")
        return orig_decode(*a, **k)

    eng.prefill_chunk, eng.decode = chunk_spy, decode_spy
    sched.start()
    short = Request(rid=0, tokens=[2, 3, 4], max_new=30)
    sched.submit(short)
    sched.step()  # short admitted (single chunk) and decoding
    sched.submit(Request(rid=1, tokens=list(range(2, 22)), max_new=2))
    while sched.busy:
        sched.step()
    assert len(short.out) == 30
    ci = [i for i, e in enumerate(events) if e == "chunk"]
    assert len(ci) >= 3  # short's single chunk + the long prompt's 5
    assert any(
        "decode" in events[a + 1:b] for a, b in zip(ci[1:], ci[2:])
    ), events


def test_chunked_preemption_resumes_from_boundary(chunk_setup):
    """A half-prefilled request that hits a dry pool aborts itself,
    re-queues at the head, resumes from its completed-chunk boundary (not
    token 0), and still produces the un-contended reference output."""
    cfg, mk, slab, params = chunk_setup

    def reqs():
        return [
            Request(rid=0, tokens=list(range(2, 42)), max_new=8),
            Request(rid=1, tokens=list(range(5, 53)), max_new=4),
        ]

    ref = ContinuousScheduler(
        Engine(mk("paged", pool_blocks=32), n_slots=2, capacity=64), params
    ).run(reqs())

    eng = Engine(mk("paged", pool_blocks=9), n_slots=2, capacity=64)
    sched = ContinuousScheduler(eng, params, chunk_tokens=16)
    calls, aborts = [], []
    orig_chunk, orig_abort = eng.prefill_chunk, eng.abort_chunked

    def chunk_spy(p, c, slot, toks, start, n):
        calls.append((sched._prefilling.req.rid, int(start)))
        return orig_chunk(p, c, slot, toks, start, n)

    def abort_spy(cache, slot):
        aborts.append((sched._prefilling.req.rid, len(calls)))
        return orig_abort(cache, slot)

    eng.prefill_chunk, eng.abort_chunked = chunk_spy, abort_spy
    out = sched.run(reqs())
    assert out == ref
    assert sched.prefill_aborts >= 1
    resumed = False
    for rid, idx in aborts:
        nxt = next((s for r, s in calls[idx:] if r == rid), None)
        resumed |= nxt is not None and nxt > 0
    assert resumed, (calls, aborts)


def test_paged_admission_skips_blocked_head(chunk_setup):
    """Head-of-line fix: a big request that can't get blocks yet must not
    block a later small request when a slot and blocks are free."""
    cfg, mk, slab, params = chunk_setup
    eng = Engine(mk("paged", pool_blocks=9), n_slots=2, capacity=64)
    sched = ContinuousScheduler(eng, params)  # monolithic admission
    sched.start()
    hold = Request(rid=0, tokens=list(range(2, 26)), max_new=20)
    sched.submit(hold)
    sched.step()
    assert hold in sched.running.values()  # 3 of 8 usable blocks held
    big = Request(rid=1, tokens=list(range(3, 50)), max_new=4)    # 6 blocks
    small = Request(rid=2, tokens=list(range(4, 12)), max_new=4)  # 1 block
    sched.submit(big)
    sched.submit(small)
    sched.step()
    assert small in sched.running.values() or small.done
    assert not big.out and not big.done  # still queued, not blocking
    while sched.busy:
        sched.step()
    assert len(big.out) == 4 and len(small.out) == 4 and len(hold.out) == 20
