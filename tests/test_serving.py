"""Serving engine + continuous-batching scheduler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.policy import PolicyConfig
from repro.models import build_model
from repro.serving import ContinuousScheduler, Engine, Request, SamplingConfig
from repro.serving.engine import sample_token


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("olmo-1b")
    pol = PolicyConfig(kind="fier", budget=16, group=8, skip_layers=1)
    bundle = build_model(cfg, pol)
    params = bundle.init(jax.random.PRNGKey(0))
    return cfg, bundle, params


def test_continuous_matches_static(setup):
    cfg, bundle, params = setup
    eng = Engine(bundle, n_slots=3, capacity=64)
    sched = ContinuousScheduler(eng, params, pad_prompt_to=16)
    reqs = [Request(rid=i, tokens=list(range(3 + i, 11 + i)), max_new=5)
            for i in range(5)]
    out = sched.run(reqs)
    assert all(len(v) == 5 for v in out.values())
    assert sched.mean_occupancy > 1.5  # slots actually shared

    eng1 = Engine(bundle, n_slots=1, capacity=64)
    for r in reqs[:2]:
        p = jnp.asarray(np.asarray(r.tokens, np.int32)[None])
        toks = eng1.generate(params, p, jnp.array([len(r.tokens)], jnp.int32), 5)
        assert np.asarray(toks[0]).tolist() == out[r.rid], r.rid


def test_eos_terminates_early(setup):
    cfg, bundle, params = setup
    eng = Engine(bundle, n_slots=2, capacity=64)
    sched = ContinuousScheduler(eng, params, pad_prompt_to=16)
    # find what the model emits first, then use it as the EOS token
    probe = ContinuousScheduler(Engine(bundle, n_slots=1, capacity=64), params,
                                pad_prompt_to=16)
    first = probe.run([Request(rid=0, tokens=[1, 2, 3], max_new=2)])[0][0]
    reqs = [Request(rid=0, tokens=[1, 2, 3], max_new=50, eos=first)]
    out = sched.run(reqs)
    assert len(out[0]) == 1  # stopped at eos immediately


def test_sampling_modes():
    logits = jnp.array([[0.0, 5.0, 1.0, -2.0]])
    greedy = sample_token(jax.random.PRNGKey(0), logits, SamplingConfig())
    assert int(greedy[0]) == 1
    topk = sample_token(
        jax.random.PRNGKey(0), logits, SamplingConfig(temperature=1.0, top_k=2)
    )
    assert int(topk[0]) in (1, 2)


def test_scheduler_threads_fresh_rng_each_step(setup, monkeypatch):
    """Regression: Engine.decode used to fall back to PRNGKey(0) on every
    call and the scheduler never passed an rng, so temperature > 0 serving
    resampled from the identical key each step.  Two consecutive sampled
    steps must now use distinct keys."""
    import repro.serving.engine as engine_mod

    cfg, bundle, params = setup
    seen = []
    orig = engine_mod.sample_token

    def spy(rng, logits, scfg):
        seen.append(np.asarray(rng).copy())
        return orig(rng, logits, scfg)

    monkeypatch.setattr(engine_mod, "sample_token", spy)
    eng = Engine(bundle, n_slots=2, capacity=64,
                 sampling=SamplingConfig(temperature=1.0, top_k=2))
    sched = ContinuousScheduler(eng, params, pad_prompt_to=16)
    sched.run([Request(rid=0, tokens=[1, 2, 3], max_new=6)])
    assert len(seen) >= 2
    keys = {tuple(k.tolist()) for k in seen}
    assert len(keys) == len(seen), "sampling rng key reused across steps"


def test_engine_decode_fallback_rng_advances(setup):
    """Engine.decode without an explicit rng must split a fresh key per
    call (not PRNGKey(0) forever): consecutive sampled steps differ."""
    cfg, bundle, params = setup
    eng = Engine(bundle, n_slots=1, capacity=64,
                 sampling=SamplingConfig(temperature=1.0, top_k=0))
    k0 = eng._rng
    batch = {"tokens": jnp.zeros((1, 16), jnp.int32),
             "lengths": jnp.array([4], jnp.int32)}
    _, cache = eng.prefill_batch(params, batch)
    tok = jnp.zeros((1,), jnp.int32)
    draws = []
    for _ in range(8):
        tok, _, cache = eng.decode(params, tok, cache)
        draws.append(int(tok[0]))
    assert not np.array_equal(np.asarray(eng._rng), np.asarray(k0))
    # 8 draws at temperature 1.0 over a 512-vocab softmax: all-identical
    # only if the rng key repeats (the exact bug) or the distribution is
    # near-deterministic — the trained-free random init it isn't
    assert len(set(draws)) > 1, draws


def test_scheduler_queue_fifo_order(setup):
    """The deque-backed admission queue must preserve FIFO order: with one
    slot, requests finish in submission order."""
    cfg, bundle, params = setup
    eng = Engine(bundle, n_slots=1, capacity=64)
    sched = ContinuousScheduler(eng, params, pad_prompt_to=16)
    reqs = [Request(rid=i, tokens=[3 + i, 4 + i], max_new=2) for i in range(4)]
    admits = []
    orig_admit = sched._admit

    def tracking_admit(queue, cache, cur):
        before = [r.rid for r in queue]
        res = orig_admit(queue, cache, cur)
        admits.append((before, [r.rid for r in queue]))
        return res

    sched._admit = tracking_admit
    out = sched.run(reqs)
    assert set(out) == {0, 1, 2, 3}
    # every admission must take from the *head*: the remaining queue is a
    # suffix of the pre-admit queue (tail-popping LIFO would leave a
    # prefix instead and fail here)
    for before, after in admits:
        assert after == before[len(before) - len(after):], (before, after)


def test_slot_isolation(setup):
    """A request's output must not depend on what occupies other slots."""
    cfg, bundle, params = setup
    out = {}
    for other in ([11, 12, 13, 14], [99, 98, 97]):
        eng = Engine(bundle, n_slots=2, capacity=64)
        sched = ContinuousScheduler(eng, params, pad_prompt_to=16)
        reqs = [
            Request(rid=0, tokens=[5, 6, 7, 8], max_new=4),
            Request(rid=1, tokens=other, max_new=4),
        ]
        out[tuple(other)] = sched.run(reqs)[0]
    vals = list(out.values())
    assert vals[0] == vals[1], "slot contents leaked across requests"
