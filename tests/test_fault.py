"""Fault tolerance: injected failures + resume must be bit-exact."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import reduced_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import make_train_batch
from repro.launch.steps import TrainHParams, init_train_state, make_train_step
from repro.models import build_model
from repro.runtime import FaultInjector, StragglerMonitor, run_with_recovery


def _train_setup(steps=12):
    cfg = reduced_config("olmo-1b")
    bundle = build_model(cfg)
    hp = TrainHParams(peak_lr=1e-3, warmup=2, total_steps=steps)
    state = init_train_state(bundle, jax.random.PRNGKey(0), hp)
    step_jit = jax.jit(make_train_step(bundle, hp))
    shape = ShapeConfig("t", 32, 4, "train")

    def one_step(st, step):
        batch = make_train_batch(cfg, shape, step, seed=0)
        st, _ = step_jit(st, batch)
        return st

    return state, one_step


def test_resume_is_bit_exact(tmp_path):
    """Run A: uninterrupted.  Run B: crash at steps 5 and 9, recover from
    checkpoints.  Final params must be bit-identical."""
    state, one_step = _train_setup()

    ref = state
    for s in range(12):
        ref = one_step(ref, s)

    injector = FaultInjector([5, 9])

    def faulty_step(st, step):
        injector.maybe_fail(step)
        return one_step(st, step)

    ckpt = CheckpointManager(str(tmp_path), keep_n=3)
    out, stats = run_with_recovery(
        faulty_step, state, 12, ckpt, ckpt_every=4, state_like=state
    )
    assert stats["restarts"] == 2
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_too_many_restarts_raises(tmp_path):
    state, one_step = _train_setup()

    def always_fail(st, step):
        raise RuntimeError("permafault")

    ckpt = CheckpointManager(str(tmp_path))
    with pytest.raises(RuntimeError, match="too many restarts"):
        run_with_recovery(always_fail, state, 5, ckpt, max_restarts=2,
                          state_like=state)


def test_straggler_monitor_flags_outliers():
    import time

    mon = StragglerMonitor(alpha=0.5, threshold=2.0)
    for i in range(5):
        mon.start()
        time.sleep(0.01)
        mon.stop(i)
    mon.start()
    time.sleep(0.12)  # 12× slower step
    mon.stop(5)
    assert len(mon.events) == 1 and mon.events[0][0] == 5


def test_data_pipeline_deterministic():
    cfg = reduced_config("olmo-1b")
    shape = ShapeConfig("t", 32, 4, "train")
    a = make_train_batch(cfg, shape, step=7, seed=3)
    b = make_train_batch(cfg, shape, step=7, seed=3)
    c = make_train_batch(cfg, shape, step=8, seed=3)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_process_slices_disjoint():
    cfg = reduced_config("olmo-1b")
    shape = ShapeConfig("t", 32, 8, "train")
    p0 = make_train_batch(cfg, shape, 0, seed=0, process_index=0, process_count=2)
    p1 = make_train_batch(cfg, shape, 0, seed=0, process_index=1, process_count=2)
    assert p0["tokens"].shape[0] == 4  # global 8 / 2 processes
    assert not np.array_equal(np.asarray(p0["tokens"]), np.asarray(p1["tokens"]))
