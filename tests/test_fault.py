"""Fault tolerance: injected failures + resume must be bit-exact."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import reduced_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import make_train_batch
from repro.launch.steps import TrainHParams, init_train_state, make_train_step
from repro.models import build_model
from repro.runtime import FaultInjector, StragglerMonitor, run_with_recovery


def _train_setup(steps=12):
    cfg = reduced_config("olmo-1b")
    bundle = build_model(cfg)
    hp = TrainHParams(peak_lr=1e-3, warmup=2, total_steps=steps)
    state = init_train_state(bundle, jax.random.PRNGKey(0), hp)
    step_jit = jax.jit(make_train_step(bundle, hp))
    shape = ShapeConfig("t", 32, 4, "train")

    def one_step(st, step):
        batch = make_train_batch(cfg, shape, step, seed=0)
        st, _ = step_jit(st, batch)
        return st

    return state, one_step


def test_resume_is_bit_exact(tmp_path):
    """Run A: uninterrupted.  Run B: crash at steps 5 and 9, recover from
    checkpoints.  Final params must be bit-identical."""
    state, one_step = _train_setup()

    ref = state
    for s in range(12):
        ref = one_step(ref, s)

    injector = FaultInjector([5, 9])

    def faulty_step(st, step):
        injector.maybe_fail(step)
        return one_step(st, step)

    ckpt = CheckpointManager(str(tmp_path), keep_n=3)
    out, stats = run_with_recovery(
        faulty_step, state, 12, ckpt, ckpt_every=4, state_like=state
    )
    assert stats["restarts"] == 2
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_too_many_restarts_raises(tmp_path):
    state, one_step = _train_setup()

    def always_fail(st, step):
        raise RuntimeError("permafault")

    ckpt = CheckpointManager(str(tmp_path))
    with pytest.raises(RuntimeError, match="too many restarts"):
        run_with_recovery(always_fail, state, 5, ckpt, max_restarts=2,
                          state_like=state)


def test_straggler_monitor_flags_outliers():
    import time

    mon = StragglerMonitor(alpha=0.5, threshold=2.0)
    for i in range(5):
        mon.start()
        time.sleep(0.01)
        mon.stop(i)
    mon.start()
    time.sleep(0.12)  # 12× slower step
    mon.stop(5)
    assert len(mon.events) == 1 and mon.events[0][0] == 5


def test_data_pipeline_deterministic():
    cfg = reduced_config("olmo-1b")
    shape = ShapeConfig("t", 32, 4, "train")
    a = make_train_batch(cfg, shape, step=7, seed=3)
    b = make_train_batch(cfg, shape, step=7, seed=3)
    c = make_train_batch(cfg, shape, step=8, seed=3)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_process_slices_disjoint():
    cfg = reduced_config("olmo-1b")
    shape = ShapeConfig("t", 32, 8, "train")
    p0 = make_train_batch(cfg, shape, 0, seed=0, process_index=0, process_count=2)
    p1 = make_train_batch(cfg, shape, 0, seed=0, process_index=1, process_count=2)
    assert p0["tokens"].shape[0] == 4  # global 8 / 2 processes
    assert not np.array_equal(np.asarray(p0["tokens"]), np.asarray(p1["tokens"]))


# ======================================================================
# Serving-layer fault tolerance (DESIGN.md §Serving fault tolerance):
# the deterministic chaos harness against the continuous scheduler.
# ======================================================================

import warnings  # noqa: E402

from repro.core.policy import PolicyConfig  # noqa: E402
from repro.kvcache.paged import AllocatorAuditError, BlockAllocator  # noqa: E402
from repro.serving import (  # noqa: E402
    ContinuousScheduler,
    Engine,
    FaultSpec,
    Request,
    ServingFaultInjector,
)


def _serving_policy(layout, pool_blocks=0):
    return PolicyConfig(
        kind="fier", budget=16, group=8, skip_layers=1, sink=2, recent=4,
        pipeline="reference", layout=layout, block_size=8,
        pool_blocks=pool_blocks,
    )


@pytest.fixture(scope="module")
def serve_setup():
    """One slab + one paged engine, shared across the chaos tests (the
    jitted decode fns dominate test wall-clock; ``sched.run`` re-starts a
    fresh session — cache, allocator, budget — on every call)."""
    cfg = reduced_config("olmo-1b")
    slab_bundle = build_model(cfg, _serving_policy("slab"))
    paged_bundle = build_model(cfg, _serving_policy("paged", pool_blocks=40))
    params = slab_bundle.init(jax.random.PRNGKey(0))
    engines = {
        "slab": Engine(slab_bundle, n_slots=3, capacity=64),
        "paged": Engine(paged_bundle, n_slots=3, capacity=64),
        # two-tier engine: host offload attached + aggressive TTL so the
        # chaos trace actually demotes blocks (and the offload_drop fault
        # has something to lose); driven chunked so re-admissions recall
        "offload": Engine(
            paged_bundle, n_slots=3, capacity=64,
            offload_blocks=16, prefix_ttl=25.0,
        ),
    }
    return cfg, params, engines


def _sched_kwargs(layout):
    # the offload row runs chunked: host-tier recall only happens on the
    # begin_chunked resume path (monolithic prefill recomputes anyway)
    return {"chunk_tokens": 4} if layout == "offload" else {}


def _chaos_reqs():
    return [
        Request(rid=i, tokens=list(range(2 + i, 12 + i)), max_new=12)
        for i in range(3)
    ]


_CHAOS_REF = {}  # layout → fault-free reference outputs (per-module cache)


def _reference(engines, params, layout):
    if layout not in _CHAOS_REF:
        sched = ContinuousScheduler(
            engines[layout], params, audit_every=4, **_sched_kwargs(layout)
        )
        _CHAOS_REF[layout] = dict(sched.run(_chaos_reqs()))
    return _CHAOS_REF[layout]


@pytest.mark.parametrize("layout", ["slab", "paged", "offload"])
@pytest.mark.parametrize(
    "kind",
    ["alloc_fail", "poison_logits", "corrupt_metadata", "cancel",
     "offload_drop"],
)
def test_serving_chaos_matrix(serve_setup, layout, kind):
    """Every injector fault class, on both cache layouts: the scheduler
    completes the trace, the allocator audits clean at drain, every
    request leaves with a structured outcome, and requests NOT targeted
    by the fault produce bit-identical outputs to the fault-free run."""
    _, params, engines = serve_setup
    eng = engines[layout]
    ref = _reference(engines, params, layout)

    target = 1
    inj = ServingFaultInjector([FaultSpec(kind, step=3, rid=target, count=2)])
    sched = ContinuousScheduler(
        eng, params, injector=inj, audit_every=4, **_sched_kwargs(layout)
    )
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        res = sched.run(_chaos_reqs())

    assert inj.all_fired, f"{kind} never fired: {inj.fired_log}"
    # every request has a terminal structured outcome
    assert sorted(res.outcomes) == [0, 1, 2]
    # unaffected requests are bit-identical to the fault-free run
    for rid in (0, 2):
        assert res[rid] == ref[rid], f"rid {rid} diverged under {kind}"
    expect = {
        "poison_logits": "quarantined",
        "cancel": "cancelled",
    }.get(kind)
    if expect is not None:
        assert res.outcomes[target].status == expect
        # the victim's tokens stop at the fault, the rest ran to max_new
        assert len(res[target]) < len(ref[target])
    else:
        # alloc_fail / corrupt_metadata / offload_drop degrade, they
        # don't kill
        assert res.outcomes[target].status == "finished"
    if eng.paged:
        # cross-tier audit: zero leaked / double-owned blocks across the
        # device pool AND the host tier after every chaos scenario
        eng.audit()
        assert eng.allocator.n_in_use == 0


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_serving_chaos_seeded(serve_setup, seed):
    """Seeded random fault schedules (the CI chaos lane's three seeds):
    whatever the draw, the scheduler drains, every request retires with a
    structured outcome, and the allocator audits clean."""
    _, params, engines = serve_setup
    for layout in ("slab", "paged", "offload"):
        eng = engines[layout]
        inj = ServingFaultInjector.random(
            seed, rids=[0, 1, 2], n_faults=3, step_lo=1, step_hi=8
        )
        sched = ContinuousScheduler(
            eng, params, injector=inj, audit_every=3, **_sched_kwargs(layout)
        )
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            res = sched.run(_chaos_reqs())
        assert sorted(res.outcomes) == [0, 1, 2]
        assert all(o.status in (
            "finished", "cancelled", "quarantined", "rejected",
        ) for o in res.outcomes.values())
        if eng.paged:
            eng.audit()
            assert eng.allocator.n_in_use == 0


def test_seeded_injector_is_deterministic():
    a = ServingFaultInjector.random(7, rids=[1, 2, 3])
    b = ServingFaultInjector.random(7, rids=[1, 2, 3])
    assert [(s.kind, s.step, s.rid, s.count) for s in a.specs] == [
        (s.kind, s.step, s.rid, s.count) for s in b.specs
    ]
    c = ServingFaultInjector.random(8, rids=[1, 2, 3])
    assert [(s.kind, s.step) for s in a.specs] != [
        (s.kind, s.step) for s in c.specs
    ]


def test_budget_degradation_keeps_oversubscribed_running(serve_setup):
    """The graceful-degradation ladder: an oversubscription that
    preemption-only thrashes on completes with ZERO preemptions when the
    scheduler may downshift the retrieval budget and shed middle blocks —
    and the degraded budget is restored for the next session."""
    cfg, params, _ = serve_setup
    bundle = build_model(cfg, _serving_policy("paged", pool_blocks=12))

    def reqs():
        return [
            Request(rid=0, tokens=list(range(2, 32)), max_new=20),
            Request(rid=1, tokens=list(range(40, 56)), max_new=20),
        ]

    eng = Engine(bundle, n_slots=2, capacity=64, degrade_floor=4)
    sched = ContinuousScheduler(eng, params)
    res = sched.run(reqs())
    assert all(o.status == "finished" for o in res.outcomes.values())
    assert eng.downshifts >= 1 and eng.blocks_shed >= 1
    assert sched.preemptions == 0
    eng.audit()
    assert eng.allocator.n_in_use == 0

    # preemption-only baseline: floor == budget disables the ladder
    eng2 = Engine(bundle, n_slots=2, capacity=64, degrade_floor=16)
    sched2 = ContinuousScheduler(eng2, params)
    res2 = sched2.run(reqs())
    assert all(o.status == "finished" for o in res2.outcomes.values())
    assert eng2.downshifts == 0 and sched2.preemptions >= 1

    # a fresh session starts back at the full budget
    sched.start()
    assert eng.current_budget == eng.base_budget and eng.restores >= 1


def test_livelock_lone_request_retires_rejected(serve_setup):
    """Regression (satellite): a lone request whose decode outgrows an
    undersized pool (pool_blocks × block_size < capacity) used to
    self-preempt / re-admit forever (monolithic: a stall RuntimeError;
    chunked: an infinite abort loop).  It must now retire with a
    structured `rejected` outcome — on both admission paths — and leak
    nothing."""
    cfg, params, _ = serve_setup
    with pytest.warns(UserWarning, match="cannot hold one"):
        eng = Engine(
            build_model(cfg, _serving_policy("paged", pool_blocks=5)),
            n_slots=2, capacity=64,
        )
    for chunk in (None, 8):
        sched = ContinuousScheduler(eng, params, chunk_tokens=chunk)
        with pytest.warns(UserWarning):
            res = sched.run(
                [Request(rid=0, tokens=list(range(2, 18)), max_new=40)]
            )
        oc = res.outcomes[0]
        assert oc.status == "rejected" and oc.reason
        assert res[0], "partial output before retirement is preserved"
        eng.audit()
        assert eng.allocator.n_in_use == 0


def test_self_preempt_streak_detection(serve_setup):
    """The livelock detector fires only on repeats WITHOUT progress."""
    _, params, engines = serve_setup
    sched = ContinuousScheduler(engines["paged"], params, self_preempt_limit=3)
    r = Request(rid=0, tokens=[1])
    assert not sched._note_self_preempt(r, 5)   # streak 1
    assert not sched._note_self_preempt(r, 5)   # streak 2 (no progress)
    assert not sched._note_self_preempt(r, 9)   # progress → streak resets
    assert not sched._note_self_preempt(r, 9)
    assert sched._note_self_preempt(r, 9)       # third repeat at 9 → fire


def test_deadline_expiry_mid_chunked_prefill(serve_setup):
    """A deadline passing while the request is still chunk-prefilling
    aborts the admission (blocks released, slot freed) and records a
    `deadline_exceeded` outcome."""
    _, params, engines = serve_setup
    eng = engines["paged"]
    sched = ContinuousScheduler(eng, params, chunk_tokens=8)
    # 40-token prompt at 8 tokens/step: the virtual clock passes 20
    # strictly before the prefill's 5th chunk completes
    res = sched.run(
        [Request(rid=0, tokens=list(range(2, 42)), max_new=8, deadline=20.0)]
    )
    oc = res.outcomes[0]
    assert oc.status == "deadline_exceeded"
    assert "prefill" in oc.reason
    assert res[0] == []                      # never produced a token
    assert sched._prefilling is None and len(sched.free) == eng.n_slots
    eng.audit()
    assert eng.allocator.n_in_use == 0


def test_deadline_expiry_queued_and_decoding(serve_setup):
    """Deadlines also fire while queued and mid-decode."""
    _, params, engines = serve_setup
    for layout in ("slab", "paged"):
        eng = engines[layout]
        sched = ContinuousScheduler(eng, params)
        res = sched.run([
            Request(rid=0, tokens=[2, 3, 4], max_new=6),
            Request(rid=1, tokens=[5, 6, 7], max_new=50, deadline=15.0),
            Request(rid=2, tokens=[8, 9, 10], max_new=4, deadline=1e9),
        ])
        assert res.outcomes[0].status == "finished"
        assert res.outcomes[1].status == "deadline_exceeded"
        assert 0 < len(res[1]) < 50          # partial output preserved
        assert res.outcomes[2].status == "finished"


def test_cancel_during_preemption(serve_setup):
    """Cancelling a request that is sitting in the queue *because it was
    preempted* releases nothing twice: the preemption already freed its
    blocks, the cancel retires it from the queue, everything else runs to
    completion and the pool drains clean."""
    cfg, params, _ = serve_setup
    eng = Engine(
        build_model(cfg, _serving_policy("paged", pool_blocks=10)),
        n_slots=3, capacity=64,
    )
    sched = ContinuousScheduler(eng, params)
    sched.start()
    reqs = [
        Request(rid=i, tokens=list(range(2 + i, 10 + i)), max_new=25)
        for i in range(3)
    ]
    for r in reqs:
        sched.submit(r)
    while sched.busy and not sched._queue:
        sched.step()                          # run until someone is preempted
    assert sched._queue, "oversubscription should have preempted a request"
    victim = sched._queue[0]
    assert sched.cancel(victim.rid, reason="cancelled while preempted")
    assert victim.outcome.status == "cancelled"
    while sched.busy:
        sched.step()
    for r in reqs:
        if r.rid != victim.rid:
            assert r.outcome.status == "finished"
    eng.audit()
    assert eng.allocator.n_in_use == 0


def test_cancel_all_phases(serve_setup):
    """cancel() reaches a request wherever it lives: queued, mid-decode,
    and unknown rids are refused."""
    _, params, engines = serve_setup
    eng = engines["paged"]
    sched = ContinuousScheduler(eng, params)
    sched.start()
    a = Request(rid=0, tokens=[2, 3, 4], max_new=20)
    b = Request(rid=1, tokens=[5, 6, 7], max_new=20)
    sched.submit(a)
    sched.submit(b)
    assert sched.cancel(1)                   # still queued
    assert b.outcome.status == "cancelled" and not b.out
    sched.step()                             # admits + decodes a
    assert sched.slot_of(0) is not None
    assert sched.cancel(0)                   # mid-decode
    assert a.outcome.status == "cancelled" and a.out
    assert not sched.cancel(0)               # already retired
    assert not sched.cancel(99)              # unknown
    assert not sched.busy
    eng.audit()
    assert eng.allocator.n_in_use == 0


def test_structured_rejection_no_warning_parse(serve_setup):
    """Satellite: rejection is a structured outcome (status + reason),
    with the human warning preserved."""
    _, params, engines = serve_setup
    sched = ContinuousScheduler(engines["paged"], params)
    with pytest.warns(UserWarning, match="exceeds engine capacity"):
        res = sched.run(
            [Request(rid=0, tokens=list(range(1, 70)), max_new=4)]
        )
    oc = res.outcomes[0]
    assert oc.status == "rejected" and "capacity" in oc.reason
    assert sched.health.counts["rejected"] == 1


def test_allocator_audit_catches_violations():
    """BlockAllocator.audit: ref-count drift, free-list corruption, and
    ownership mismatches all raise; a healthy allocator passes."""
    a = BlockAllocator(8, 8)
    b1, b2 = a.alloc(), a.alloc()
    a.audit()
    a.audit({b1: 1, b2: 1})
    with pytest.raises(AllocatorAuditError, match="drift"):
        a.audit({b1: 1})                     # a ref the owners don't hold
    with pytest.raises(AllocatorAuditError, match="drift"):
        a.audit({b1: 1, b2: 2})              # owners hold more than allocator
    # free-list corruption: a referenced block pushed onto the free list
    a._free.append(b1)
    with pytest.raises(AllocatorAuditError, match="referenced"):
        a.audit()
    a._free.pop()
    # counter drift
    a._in_use += 1
    with pytest.raises(AllocatorAuditError, match="_in_use"):
        a.audit()
    a._in_use -= 1
    # double free still dies immediately at the free() site
    a.free(b2)
    with pytest.raises(AssertionError):
        a.free(b2)


def test_fail_next_injects_then_drains():
    a = BlockAllocator(4, 8)
    a.fail_next(2)
    assert a.alloc() is None and a.alloc() is None
    assert a.alloc() is not None             # burst drained
    assert a.injected_alloc_failures == 2
    a.audit()
