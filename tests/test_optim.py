"""Optimizer substrate: AdamW, schedules, 1-bit gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    compress_decompress,
    cosine_schedule,
    ef_state_init,
    wsd_schedule,
)

from conftest import run_in_subprocess


def test_adamw_minimises_quadratic():
    params = {"x": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    loss = lambda p: jnp.sum(p["x"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(g, opt, params, lr=0.05, weight_decay=0.0)
    assert float(loss(params)) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_schedules():
    assert float(cosine_schedule(0, peak_lr=1.0, warmup=10, total=100)) == 0.0
    assert float(cosine_schedule(10, peak_lr=1.0, warmup=10, total=100)) == pytest.approx(1.0)
    assert float(cosine_schedule(100, peak_lr=1.0, warmup=10, total=100)) == pytest.approx(0.1)
    # WSD: flat plateau then sharp tail
    assert float(wsd_schedule(50, peak_lr=1.0, warmup=10, total=100)) == 1.0
    assert float(wsd_schedule(89, peak_lr=1.0, warmup=10, total=100)) == 1.0
    assert float(wsd_schedule(100, peak_lr=1.0, warmup=10, total=100)) == pytest.approx(0.01)


def test_error_feedback_accumulates_residual():
    g = {"w": jnp.array([1.0, -0.1, 0.05, -2.0])}
    ef = ef_state_init(g)
    comp, ef = compress_decompress(g, ef)
    scale = float(jnp.mean(jnp.abs(g["w"])))
    np.testing.assert_allclose(
        np.asarray(comp["w"]), scale * np.sign(np.asarray(g["w"])), rtol=1e-6
    )
    # residual carries the quantization error to the next step
    np.testing.assert_allclose(
        np.asarray(ef["w"]), np.asarray(g["w"]) - np.asarray(comp["w"]), rtol=1e-6
    )


def test_compressed_training_still_converges():
    """signSGD-with-EF through AdamW still minimises a least-squares."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    A = jax.random.normal(k1, (32, 8))
    b = jax.random.normal(k2, (32,))
    params = {"w": jnp.zeros((8,))}
    opt = adamw_init(params)
    ef = ef_state_init(params)
    loss = lambda p: jnp.mean((A @ p["w"] - b) ** 2)
    w_star, *_ = jnp.linalg.lstsq(A, b)
    l_opt = float(jnp.mean((A @ w_star - b) ** 2))
    l0 = float(loss(params))
    for _ in range(300):
        g = jax.grad(loss)(params)
        g, ef = compress_decompress(g, ef)
        params, opt = adamw_update(g, opt, params, lr=0.02, weight_decay=0.0)
    # close most of the gap to the least-squares optimum despite 1-bit grads
    assert float(loss(params)) - l_opt < 0.3 * (l0 - l_opt)


def test_compressed_psum_multidevice():
    """shard_map compressed all-reduce: mean of per-shard sign·scale."""
    run_in_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.optim import compressed_psum

mesh = jax.make_mesh((4,), ("data",))
x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))

def body(xl):
    return compressed_psum(xl[0], "data")

f = shard_map(body, mesh=mesh, in_specs=P("data"), out_specs=P(),
                  check_vma=False)
got = jax.jit(f)(x)
want = np.mean([np.sign(np.asarray(x[i])) * np.abs(np.asarray(x[i])).mean()
                for i in range(4)], axis=0)
np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)
print("compressed psum OK")
""")
