"""Distributed FIER: sequence-sharded decode vs single-device oracle."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import run_in_subprocess

_COMMON = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import shard_map
from repro.core import quantize as qz, retrieval as rt, distributed as dist

B, S, Hkv, Hq, D, g = 2, 256, 2, 4, 32, 8
ks = jax.random.split(jax.random.PRNGKey(0), 4)
K = jax.random.normal(ks[0], (B, S, Hkv, D)) * jnp.exp(jax.random.normal(ks[3], (D,)))
V = jax.random.normal(ks[1], (B, S, Hkv, D))
q = jax.random.normal(ks[2], (B, Hq, D))
length = jnp.array([256, 200], jnp.int32)
qk = qz.quantize(K, g)
mesh = jax.make_mesh((4,), ("model",))
n_shards = 4
S_loc = S // n_shards

def sharded(mode, budget):
    def body(q_l, K_l, V_l, c_l, s_l, z_l, len_l):
        meta_l = qz.QuantizedKeys(c_l, s_l, z_l, g)
        start = jax.lax.axis_index("model") * S_loc
        return dist.fier_decode_sharded(
            q_l, K_l, V_l, meta_l, budget, len_l, axis=("model",),
            shard_start=start, n_shards=n_shards, mode=mode)
    kv = P(None, "model")
    f = shard_map(body, mesh=mesh,
        in_specs=(P(), kv, kv, kv, kv, kv, P()), out_specs=P(), check_vma=False)
    return jax.jit(f)(q, K, V, qk.codes, qk.scale, qk.zero, length)

def full_sharded():
    def body(q_l, K_l, V_l, len_l):
        start = jax.lax.axis_index("model") * S_loc
        return dist.full_decode_sharded(q_l, K_l, V_l, len_l, axis=("model",),
                                        shard_start=start)
    kv = P(None, "model")
    f = shard_map(body, mesh=mesh, in_specs=(P(), kv, kv, P()),
                      out_specs=P(), check_vma=False)
    return jax.jit(f)(q, K, V, length)
"""


def test_full_decode_sharded_equals_dense():
    run_in_subprocess(_COMMON + """
ref = rt.full_attention_decode(q, K, V, length)
got = full_sharded()
np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(ref, np.float32),
                           atol=2e-3, rtol=2e-3)
print("full sharded == dense OK")
""")


def test_exact_mode_matches_single_device_fier():
    run_in_subprocess(_COMMON + """
budget = 64
ref = rt.fier_decode_reference(q, K, V, qk, budget=budget, length=length)
got = sharded("exact", budget)
np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(ref, np.float32),
                           atol=2e-3, rtol=2e-3)
print("exact mode == single-device FIER OK")
""")


def test_local_mode_close_to_global_fier():
    """mode='local' splits the budget evenly — an approximation; its output
    must stay close to full attention when the budget is generous."""
    run_in_subprocess(_COMMON + """
budget = 128
full = rt.full_attention_decode(q, K, V, length)
got = sharded("local", budget)
err = float(jnp.abs(got.astype(jnp.float32) - full.astype(jnp.float32)).mean())
scale = float(jnp.abs(full).mean())
assert err < 0.25 * scale, (err, scale)
print("local mode close to full OK", err, scale)
""")


def test_budget_full_exact_mode_equals_dense():
    """budget = S in exact mode ⇒ every token selected ⇒ dense attention."""
    run_in_subprocess(_COMMON + """
got = sharded("exact", S)
full = rt.full_attention_decode(q, K, V, length)
np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(full, np.float32),
                           atol=2e-3, rtol=2e-3)
print("exact-full-budget == dense OK")
""")


def test_model_decode_with_seq_sharded_cache():
    """End-to-end: transformer decode_step with the cache sequence-sharded
    over a 2×2 mesh equals the unsharded decode."""
    run_in_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import reduced_config
from repro.core.policy import PolicyConfig
from repro.models import build_model, DistConfig
from repro.launch import sharding as shard

cfg = reduced_config("olmo-1b")
pol = PolicyConfig(kind="fier", budget=16, group=8, skip_layers=1)
mesh = jax.make_mesh((2, 2), ("data", "model"))

bundle_plain = build_model(cfg, pol)
# exact mode: global-top-k threshold via all-gather — must match the
# single-device policy path exactly (mode='local' is a documented
# approximation and is exercised by test_local_mode_close_to_global_fier)
dcfg = DistConfig(mesh=mesh, seq_axes=("model",), batch_axes=("data",),
                  mode="exact")
bundle_dist = build_model(cfg, pol, dcfg)

params = bundle_plain.init(jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
pre = {"tokens": toks, "lengths": jnp.full((2,), 32, jnp.int32)}
logits, cache = jax.jit(lambda p, b: bundle_plain.prefill(p, b, capacity=64))(params, pre)
tok = jnp.argmax(logits, -1).astype(jnp.int32)

l_plain, c_plain = jax.jit(bundle_plain.decode_step)(params, tok, cache)

baxes = shard.cache_batch_axes(bundle_dist.init_cache)
cache_sh = shard.cache_shardings(jax.eval_shape(lambda: cache), mesh, ("data",),
                                 ("model",), baxes)
cache_s = jax.tree.map(jax.device_put, cache, cache_sh)
l_dist, c_dist = jax.jit(bundle_dist.decode_step)(params, tok, cache_s)

# local mode with generous budget (16 of 64) — rankings should agree
agree = (np.argmax(np.asarray(l_plain), -1) == np.argmax(np.asarray(l_dist), -1)).mean()
assert agree == 1.0, agree
# cache contents must be IDENTICAL (append is exact regardless of mode)
for a, b in zip(jax.tree.leaves(c_plain), jax.tree.leaves(c_dist)):
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-2)
print("seq-sharded model decode OK")
""")
