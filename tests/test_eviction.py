"""Eviction baselines: invariants the quality benchmarks rely on."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import eviction as ev


def _qkv(seed=0, B=2, S=64, Hkv=2, Hq=4, D=16):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (
        jax.random.normal(ks[0], (B, Hq, D)),
        jax.random.normal(ks[1], (B, S, Hkv, D)),
        jax.random.normal(ks[2], (B, S, Hkv, D)),
    )


def test_streaming_mask_shape_and_budget():
    length = jnp.array([60, 30], jnp.int32)
    m = np.asarray(ev.streaming_llm_mask(64, length, budget=16, sink=4))
    assert m.sum(-1).tolist() == [16, 16]
    # recent window = budget - sink = 12 tokens before each length
    assert m[0, :4].all() and m[0, 48:60].all() and not m[0, 20]
    assert m[1, :4].all() and m[1, 18:30].all()


def test_h2o_evicts_lowest_cumulative():
    q, K, V = _qkv()
    length = jnp.array([64, 64], jnp.int32)
    st = ev.init_state(2, 2, 64, length)
    out, probs = ev.masked_attention_decode(q, K, V, st.alive)
    st2 = ev.h2o_step(st, probs, length, budget=32, recent=8)
    alive = np.asarray(st2.alive)
    assert (alive.sum(-1) == 63).all()  # one eviction per (b, h)
    # victim must be outside the recent window
    victims = np.asarray(st.alive & ~st2.alive)
    vidx = victims.nonzero()[2]
    assert (vidx < 56).all()


def test_tova_keeps_budget_stable():
    q, K, V = _qkv(1)
    length = jnp.array([64, 64], jnp.int32)
    st = ev.init_state(2, 2, 64, length)
    for _ in range(3):
        _, probs = ev.masked_attention_decode(q, K, V, st.alive)
        st = ev.tova_step(st, probs, length, budget=60)
    assert (np.asarray(st.alive).sum(-1) >= 60).all()


def test_snapkv_selects_window_plus_topk():
    B, S, Hkv, Hq, D, W = 1, 64, 2, 4, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    qw = jax.random.normal(ks[0], (B, Hq, W, D))
    K = jax.random.normal(ks[1], (B, S, Hkv, D))
    length = jnp.array([48], jnp.int32)
    st = ev.snapkv_state(qw, K, length, budget=16, window=W)
    alive = np.asarray(st.alive)
    assert (alive[:, :, 48:] == False).all()  # noqa: E712 — nothing beyond length
    assert alive[:, :, 40:48].all()           # observation window kept
    assert (alive.sum(-1) <= 17).all()


def test_append_alive():
    length = jnp.array([10, 20], jnp.int32)
    st = ev.init_state(2, 2, 64, length)
    st2 = ev.append_alive(st, length)
    a = np.asarray(st2.alive)
    assert a[0, :, 10].all() and a[1, :, 20].all()
