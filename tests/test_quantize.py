"""Unit tests: 1-bit group RTN quantization + bit packing (core of FIER)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize as qz


def _keys(seed, B=2, S=128, H=2, D=32, outlier=True):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    K = jax.random.normal(k1, (B, S, H, D), jnp.float32)
    if outlier:
        K = K * jnp.exp(jax.random.normal(k2, (D,)))
    return K


@pytest.mark.parametrize("group", [8, 16, 32, 64])
def test_pack_unpack_roundtrip(group):
    K = _keys(0, S=128)
    qk = qz.quantize(K, group)
    bits = qz.unpack_bits(qk.codes)
    assert bits.shape == K.shape
    np.testing.assert_array_equal(
        np.asarray(qz.pack_bits(bits)), np.asarray(qk.codes)
    )


def test_dequant_within_group_range():
    """K̃ ∈ {z−s, z+s} = {≈min, ≈max} of each (group, channel)."""
    K = _keys(1)
    qk = qz.quantize(K, 32)
    Kd = np.asarray(qz.dequantize(qk), np.float32)
    Kg = np.asarray(K).reshape(2, 128 // 32, 32, 2, 32)
    kmin = Kg.min(axis=2, keepdims=True)
    kmax = Kg.max(axis=2, keepdims=True)
    Kdg = Kd.reshape(2, 128 // 32, 32, 2, 32)
    tol = 0.02 * (np.abs(kmax) + np.abs(kmin) + 1)
    assert (Kdg >= kmin - tol).all() and (Kdg <= kmax + tol).all()


def test_sign_semantics():
    """code bit = (K >= z); dequant picks the closer of the two levels."""
    K = _keys(2)
    qk = qz.quantize(K, 16)
    Kd = qz.dequantize(qk).astype(jnp.float32)
    z = jnp.repeat(qk.zero.astype(jnp.float32), 16, axis=1)
    above = np.asarray(K >= z)
    deq_above = np.asarray(Kd >= z - 1e-3)
    assert (above == deq_above).mean() > 0.999


@pytest.mark.parametrize("group,expected", [(32, 1 / 8), (128, 0.078125), (256, 0.0703125)])
def test_load_ratio_formula(group, expected):
    """Paper Eq. 8 — and the packed bytes match the formula exactly."""
    assert abs(qz.load_ratio(group) - expected) < 1e-9
    S, H, D = 1024, 2, 64
    measured = qz.packed_nbytes(S, H, D, group)
    full = S * H * D * 2  # bf16 keys
    assert measured / full == pytest.approx(qz.load_ratio(group), rel=1e-9)


def test_seq_len_must_divide():
    K = _keys(3, S=100)
    with pytest.raises(ValueError):
        qz.quantize(K, 32)
