"""The jaxpr FLOP counter must (a) match XLA on unrolled graphs and
(b) correctly multiply scan bodies — the property XLA lacks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))
from flopcount import (  # noqa: E402
    count_fn_flops, count_fn_gather_bytes, count_fn_score_bytes,
    xla_cost_flops,
)

_xla_flops = xla_cost_flops


def test_matmul_exact():
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    f = lambda x, w: x @ w
    assert count_fn_flops(f, x, w) == 2 * 64 * 128 * 256
    assert count_fn_flops(f, x, w) == _xla_flops(f, x, w)


def test_batched_dot_and_elementwise():
    x = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)

    def f(x, w):
        return jnp.tanh(jnp.einsum("bij,bjk->bik", x, w))

    mine = count_fn_flops(f, x, w)
    expected = 2 * 4 * 32 * 64 * 16 + 4 * 32 * 16
    assert mine == expected


def test_scan_multiplies_xla_does_not():
    """The motivating case: scan-over-layers."""
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)

    def scanned(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    def unrolled(x, ws):
        for i in range(8):
            x = x @ ws[i]
        return x

    mine_scan = count_fn_flops(scanned, x, ws)
    mine_unroll = count_fn_flops(unrolled, x, ws)
    assert mine_scan == mine_unroll == 8 * 2 * 128**3
    # XLA counts the scan body once — the bug this module works around
    assert _xla_flops(scanned, x, ws) == pytest.approx(2 * 128**3, rel=0.01)


def test_grad_includes_backward():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def loss(x, w):
        return jnp.sum((x @ w) ** 2)

    fwd = count_fn_flops(lambda x, w: jnp.sum((x @ w) ** 2), x, w)
    both = count_fn_flops(jax.grad(loss, argnums=1), x, w)
    assert both > 2 * fwd * 0.8  # bwd ≈ 2× fwd matmuls


def test_remat_recompute_counted():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def block(x, w):
        return jnp.tanh(x @ w) @ w

    plain = count_fn_flops(jax.grad(lambda x, w: block(x, w).sum(), argnums=1), x, w)
    rematted = count_fn_flops(
        jax.grad(lambda x, w: jax.checkpoint(block)(x, w).sum(), argnums=1), x, w
    )
    assert rematted >= plain  # recompute adds flops


def test_score_bytes_counts_trailing_seq_tensors():
    """Every materialised float tensor with trailing dim S counts once;
    scan bodies multiply; non-S-trailing tensors don't count."""
    S = 320

    def f(q, K):
        s = jnp.einsum("hd,sd->hs", q, K)      # [4, S] f32 → 4·4·S bytes
        m = s * 2.0                             # another 4·4·S
        return m.max(axis=0)                    # [S] — ndim 1, not counted

    q = jax.ShapeDtypeStruct((4, 16), jnp.float32)
    K = jax.ShapeDtypeStruct((S, 16), jnp.float32)
    assert count_fn_score_bytes(f, S, q, K) == 2 * 4 * 4 * S
    # a different seq_len matches nothing
    assert count_fn_score_bytes(f, S + 1, q, K) == 0

    def scanned(q, Ks):
        return jax.lax.scan(lambda c, K: (c, f(q, K)), None, Ks)[1]

    Ks = jax.ShapeDtypeStruct((3, S, 16), jnp.float32)
    assert count_fn_score_bytes(scanned, S, q, Ks) == 3 * 2 * 4 * 4 * S


def test_score_bytes_pallas_leaf():
    """pallas_call outputs count (HBM); its body is never recursed into —
    in-kernel VMEM blocks must not be mistaken for materialised tensors."""
    from repro.core import quantize as qz
    from repro.kernels import ops as kops

    B, S, Hkv, Hq, D, g = 1, 256, 2, 4, 32, 8
    K = jax.random.normal(jax.random.PRNGKey(0), (B, S, Hkv, D))
    q = jax.random.normal(jax.random.PRNGKey(1), (B, Hq, D))
    qk = qz.quantize(K, g)
    # two-pass score kernel materialises [B·Hkv, rep, S] f32 (+ reshape)
    two = count_fn_score_bytes(lambda q: kops.fier_score(q, qk), S, q)
    assert two >= 4 * Hq * S, two
    # one-pass retrieval: scores stay in VREGs — exactly zero
    length = jnp.full((B,), S, jnp.int32)
    from repro.core.policy import CacheView

    one = count_fn_score_bytes(
        lambda q: kops.retrieve(
            q, CacheView.slab(None, None, qk, length), 32
        ),
        S, q,
    )
    assert one == 0.0, one
    # and zero gather bytes end-to-end through the one-pass decode
    V = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, D), jnp.bfloat16)
    Kb = K.astype(jnp.bfloat16)
    gb = count_fn_gather_bytes(
        lambda q: kops.fier_decode_one_pass(
            q, CacheView.slab(Kb, V, qk, length), 32
        ),
        q,
    )
    assert gb == 0.0, gb


def test_transformer_layer_vs_xla_unrolled():
    """Whole tiny model, unrolled: counter within 10% of XLA."""
    from repro.configs import reduced_config
    from repro.models import build_model
    from repro.models import tuning

    cfg = reduced_config("olmo-1b")
    bundle = build_model(cfg, remat=False)
    params = jax.eval_shape(bundle.init, jax.random.key(0))
    batch = {
        "tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32),
        "targets": jax.ShapeDtypeStruct((2, 32), jnp.int32),
        "loss_mask": jax.ShapeDtypeStruct((2, 32), jnp.float32),
    }
    fn = lambda p, b: bundle.train_loss(p, b)[0]
    with tuning.tuned(scan_layers=False):
        mine = count_fn_flops(fn, params, batch)
        theirs = _xla_flops(fn, params, batch)
    assert mine == pytest.approx(theirs, rel=0.15), (mine, theirs)
