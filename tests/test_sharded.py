"""Sharded multi-device serving (DESIGN.md §Sharded serving).

Fast lane: ShardSpec / DecodePlan capability validation, the per-shard
ShardedBlockAllocator behind the global-id surface, and a hypothesis
property tying exact-mode sharded selection to the single-device top-k
oracle.  Slow lane (forced-multi-device subprocesses): engine-level
TP×DP decode bit-identity vs the single-device oracle, per-shard
score-byte gating of the one-pass pipeline, and a seeded chaos pass on
the DP-sharded layout.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
# unlike test_property.py this module holds more than property tests, so
# a missing hypothesis skips only the selection-equivalence property
# (declared in the `test` extra; CI installs it) instead of the module
try:
    from hypothesis import assume, given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # pragma: no cover - CI always has it
    st = None

from conftest import run_in_subprocess
from repro.configs import reduced_config
from repro.core import policy as core_policy
from repro.core import retrieval as rt
from repro.core.policy import (
    AttentionBackend,
    DecodePlan,
    PolicyConfig,
    UnsupportedPlanError,
    register_backend,
)
from repro.kvcache.paged import AllocatorAuditError
from repro.kvcache.sharded import ShardSpec, ShardedBlockAllocator
from repro.serving import Engine

_BENCH_DIR = os.path.join(os.path.dirname(__file__), "..", "benchmarks")


def _mesh11():
    # single-device mesh: enough for spec/plan validation in the fast lane
    return jax.make_mesh((1, 1), ("data", "model"))


def _pol(kind="fier", layout="paged", pipeline="reference", block_size=8):
    return PolicyConfig(
        kind=kind, budget=16, group=8, skip_layers=1, sink=2, recent=4,
        pipeline=pipeline, layout=layout, block_size=block_size,
    )


# ------------------------------------------------------------- ShardSpec

def test_shard_spec_validation():
    m = _mesh11()
    spec = ShardSpec(mesh=m, tp_axes=("model",), dp_axes=("data",))
    assert spec.n_tp == 1 and spec.n_dp == 1 and spec.mode == "exact"
    with pytest.raises(ValueError, match="mode"):
        ShardSpec(mesh=m, tp_axes=("model",), mode="approx")
    with pytest.raises(ValueError, match="not in mesh"):
        ShardSpec(mesh=m, tp_axes=("expert",))
    with pytest.raises(ValueError, match="both tp and dp"):
        ShardSpec(mesh=m, tp_axes=("model",), dp_axes=("model",))
    with pytest.raises(ValueError, match="at least one"):
        ShardSpec(mesh=m)


# ----------------------------------------------------- plan capabilities

def test_plan_accepts_sharding_capable_backends():
    spec = ShardSpec(mesh=_mesh11(), tp_axes=("model",), dp_axes=("data",))
    for kind in ("fier", "full"):
        plan = DecodePlan.build(_pol(kind=kind), shard=spec)
        assert plan.shard is spec
        # re-resolution keeps the spec on the plan
        assert plan.with_pipeline(plan.pipeline).shard is spec
    # shard-free build is unchanged
    assert DecodePlan.build(_pol()).shard is None


def test_plan_sharding_requires_paged_layout():
    spec = ShardSpec(mesh=_mesh11(), tp_axes=("model",))
    with pytest.raises(UnsupportedPlanError, match="requires layout='paged'"):
        DecodePlan.build(_pol(layout="slab"), shard=spec)


def test_plan_error_names_axes_and_backend_modes():
    """Satellite: a backend without the requested sharding mode fails
    plan validation with the offending mesh axes AND the backend's
    ``supports_sharding`` entry in the message."""
    backend = AttentionBackend(
        name="_testonly_unsharded",
        supports=frozenset({("paged", "reference")}),
        build_metadata=lambda K, cfg: None,
        update_metadata=lambda meta, K, pos, cfg: meta,
        decode=lambda q, view, plan: q,
        needs_metadata=False,
    )
    register_backend(backend)
    try:
        spec = ShardSpec(
            mesh=_mesh11(), tp_axes=("model",), dp_axes=("data",)
        )
        with pytest.raises(UnsupportedPlanError) as exc:
            DecodePlan.build(_pol(kind="_testonly_unsharded"), shard=spec)
        msg = str(exc.value)
        assert "('model', 'data')" in msg        # the offending mesh axes
        assert "mode='exact'" in msg             # the requested mode
        assert "sharding modes: -" in msg        # the backend's capability
    finally:
        del core_policy._REGISTRY["_testonly_unsharded"]
        core_policy.POLICIES = tuple(core_policy._REGISTRY)


def test_backend_registration_rejects_bad_sharding_modes():
    backend = AttentionBackend(
        name="_testonly_badmode",
        supports=frozenset({("slab", "reference")}),
        build_metadata=lambda K, cfg: None,
        update_metadata=lambda meta, K, pos, cfg: meta,
        decode=lambda q, view, plan: q,
        supports_sharding=frozenset({"approximate"}),
    )
    with pytest.raises(ValueError, match="invalid sharding modes"):
        register_backend(backend)


def test_engine_build_mesh_validation():
    cfg = reduced_config("olmo-1b")
    with pytest.raises(ValueError, match="layout='paged'"):
        Engine.build(cfg, n_slots=2, capacity=64, policy=_pol(layout="slab"),
                     mesh=_mesh11())
    with pytest.raises(ValueError, match="must be named"):
        Engine.build(cfg, n_slots=2, capacity=64,
                     policy=_pol(), layout="paged",
                     mesh=jax.make_mesh((1,), ("expert",)))


# -------------------------------------------------- ShardedBlockAllocator

def test_sharded_allocator_routing_and_admission():
    a = ShardedBlockAllocator(8, 16, n_shards=2)
    assert a.n_local == 4 and a.usable == 3 and a.n_free == 3
    # local row 0 is each shard's null block: gids 0 and 4 never allocated
    got0 = [a.alloc(shard=0) for _ in range(3)]
    assert sorted(got0) == [1, 2, 3]
    assert a.alloc(shard=0) is None
    # admission accounting is the per-device MINIMUM: shard 1 still has 3
    # free blocks but an admitted request may land on the exhausted shard
    assert a.n_free == 0 and a.n_in_use == 3
    got1 = [a.alloc(shard=1) for _ in range(3)]
    assert sorted(got1) == [5, 6, 7]
    for gid in got0 + got1:
        assert a.ref[gid] == 1
        assert a.home(gid) == (0 if gid < 4 else 1)
        a.free(gid)
    assert a.n_in_use == 0 and a.n_free == 3
    assert sorted(a._free) == [1, 2, 3, 5, 6, 7]
    a.audit()
    with pytest.raises(ValueError, match="not divisible"):
        ShardedBlockAllocator(9, 16, n_shards=2)


def test_sharded_allocator_prefix_cache_is_shard_local():
    a = ShardedBlockAllocator(8, 16, n_shards=2)
    b = a.alloc(shard=1)
    a.register(b, 42)
    assert a.ref[b] == 1
    assert a.lookup(42, shard=1) == b and a.ref[b] == 2
    assert a.lookup(42, shard=0) is None     # shard-local: no cross revive
    assert a.key_of(b) == 42 and a.key_resident(42)
    a.free(b)
    a.free(b)
    # parked free-cached on shard 1: still hittable there, counted free
    assert a.ref[b] == 0 and a.n_free == 3 and a.n_parked == 1
    assert a.lookup(42, shard=1) == b
    a.free(b)
    assert a.drop_key(42) == b
    assert not a.key_resident(42)
    a.audit()


def test_sharded_allocator_peek_is_conservative_without_home_shard():
    a = ShardedBlockAllocator(8, 16, n_shards=2)
    b = a.alloc(shard=0)
    a.register(b, 7)
    a.free(b)                                # ref 0: parked free-cached
    # admission sizing before the slot (hence home shard) is known: no-hit
    assert a.peek([7]) == (0, 0)
    assert a.peek_prefix([7]) == []
    assert a.blocks_needed(33) == 3
    # with the home shard: the inner allocator's real answer
    assert a.peek([7], shard=0) == (1, 1)
    assert a.peek([7], shard=1) == (0, 0)
    # parked hit: the revival still comes out of the free pool (3-1+1)
    assert a.blocks_needed(33, keys=[7], shard=0) == 3
    assert a.lookup(7, shard=0) == b         # revive: now a live hit
    assert a.blocks_needed(33, keys=[7], shard=0) == 2
    a.free(b)
    a.audit()


def test_sharded_allocator_audit_splits_owners_and_detects_drift():
    a = ShardedBlockAllocator(8, 16, n_shards=2)
    b0, b1 = a.alloc(shard=0), a.alloc(shard=1)
    a.audit({b0: 1, b1: 1})
    with pytest.raises(AllocatorAuditError, match="ref-count drift"):
        a.audit({b0: 1, b1: 2})
    a.free(b0)
    a.free(b1)
    a.audit({})


def test_sharded_allocator_ttl_eviction_globalizes_ids():
    t = [0.0]
    a = ShardedBlockAllocator(8, 16, n_shards=2, park_ttl=5.0)
    a.set_clock(lambda: t[0])
    a.record_evictions = True
    b = a.alloc(shard=1)
    a.register(b, 99)
    a.free(b)                                # ref 0: parked, TTL running
    t[0] = 6.0
    assert a.expire_parked() == 1
    evs = a.take_evicted()
    assert [(e.bid, e.key, e.reason) for e in evs] == [(b, 99, "ttl")]
    assert b >= a.n_local                    # global id, not the local one
    assert a.take_evicted() == []
    a.audit()


def test_sharded_allocator_fail_next_and_stats():
    a = ShardedBlockAllocator(8, 16, n_shards=2)
    a.fail_next(1)
    assert a.alloc(shard=1) is None
    assert a.injected_alloc_failures == 1
    b = a.alloc(shard=1)
    assert b is not None
    st_all = a.stats()
    assert st_all["pool_shards"] == 2
    assert st_all["pool_blocks_total"] == 8
    assert st_all["pool_blocks_usable"] == 6
    assert st_all["pool_blocks_in_use"] == 1
    assert st_all["pool_injected_alloc_failures"] == 1
    per = a.shard_stats()
    assert len(per) == 2
    assert per[0]["pool_blocks_in_use"] == 0
    assert per[1]["pool_blocks_in_use"] == 1
    a.free(b)
    a.audit()


# ------------------------------------------- exact-mode selection property

if st is not None:
    @st.composite
    def _selection_cases(draw):
        n_shards = draw(st.sampled_from([1, 2, 4]))
        hq, hkv = draw(st.sampled_from([(4, 4), (4, 2), (8, 2)]))
        s_loc = draw(st.integers(2, 10))
        S = n_shards * s_loc
        budget = draw(st.integers(1, S))
        length = draw(st.integers(1, S))
        ties = draw(st.booleans())
        if ties:
            flat = draw(
                st.lists(st.integers(0, 4), min_size=hq * S, max_size=hq * S)
            )
        else:
            flat = draw(st.permutations(list(range(hq * S))))
        scores = np.asarray(flat, np.float32).reshape(1, hq, S)
        return n_shards, hq, hkv, s_loc, budget, length, scores, ties


def _sharded_exact_select(kv, length, budget, n_shards, s_loc):
    """Mirror of ``dist.fier_decode_sharded``'s exact mode (the shard_map
    body in core/distributed.py), flattened to host numpy: per-shard
    top-``k_cand`` nomination, all-gather of candidate scores, global
    budget-th threshold, keep candidates >= threshold."""
    Hkv = kv.shape[1]
    local_budget = max(budget // n_shards, 1)
    k_cand = min(max(local_budget * 2, 1) if n_shards > 1 else budget, s_loc)
    cand_s, cand_i = [], []
    for j in range(n_shards):
        s = kv[0, :, j * s_loc:(j + 1) * s_loc].copy()
        local_len = min(max(length - j * s_loc, 0), s_loc)
        s[:, local_len:] = rt.NEG_INF
        # lax.top_k semantics: descending, ties broken by lower index
        order = np.lexsort((np.arange(s_loc)[None, :].repeat(Hkv, 0), -s))
        idx = order[:, :k_cand]
        cand_s.append(np.take_along_axis(s, idx, axis=1))
        cand_i.append(idx + j * s_loc)
    all_s = np.concatenate(cand_s, axis=1)
    all_i = np.concatenate(cand_i, axis=1)
    kth = -np.sort(-all_s, axis=1)[:, min(budget, all_s.shape[1]) - 1]
    keep = (all_s >= kth[:, None]) & (all_s > rt.NEG_INF / 2)
    return [set(all_i[h][keep[h]].tolist()) for h in range(Hkv)], kth


def _selection_property(case):
    """Exact-mode sharded selection returns the same index set as the
    single-device ``select_topk`` oracle — exactly under distinct scores
    (given the nomination condition), and up to τ-ties otherwise."""
    n_shards, hq, hkv, s_loc, budget, length, scores, ties = case
    S = n_shards * s_loc
    kv = np.asarray(rt.reduce_over_query_group(jnp.asarray(scores), hkv))

    # single-device oracle (the real library function)
    idx = np.asarray(
        rt.select_topk(jnp.asarray(kv), min(budget, S),
                       jnp.asarray([length], jnp.int32))
    )
    oracle = [
        {int(i) for i in idx[0, h] if i < length} for h in range(hkv)
    ]

    got, kth = _sharded_exact_select(kv, length, budget, n_shards, s_loc)

    # nomination condition: every shard must be able to surface all of
    # its tokens scoring >= the global budget-th score (2× fair-share
    # candidate cap) — hypothesis discards draws that violate it
    local_budget = max(budget // n_shards, 1)
    k_cand = min(max(local_budget * 2, 1) if n_shards > 1 else budget, s_loc)
    for h in range(hkv):
        valid = kv[0, h, :length]
        eff = min(budget, length)
        tau = -np.sort(-valid)[eff - 1]
        for j in range(n_shards):
            lo, hi = j * s_loc, min((j + 1) * s_loc, length)
            assume(int((kv[0, h, lo:hi] >= tau).sum()) <= k_cand)

    for h in range(hkv):
        if not ties:
            assert got[h] == oracle[h], (h, kth[h])
        else:
            diff = got[h] ^ oracle[h]
            assert all(kv[0, h, i] == kth[h] for i in diff), (h, diff)


if st is not None:
    test_exact_mode_selection_matches_single_device_topk = settings(
        max_examples=40, deadline=None
    )(given(_selection_cases())(_selection_property))
else:  # keep the skip visible in reports when hypothesis is absent
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_exact_mode_selection_matches_single_device_topk():
        pass


# =====================================================================
# multi-device subprocess lane (auto-marked slow by conftest: the
# literal ``run_in_subprocess`` below is the marker trigger)
# =====================================================================

_DRIVER = """
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.configs import reduced_config
from repro.serving import Engine
from repro.serving.engine import serving_policy

cfg = reduced_config("olmo-1b")

def run(mesh, pipeline="reference", kind="fier", slot=0, chunked=False):
    pol = serving_policy(budget=64, skip_layers=1, recent=32, pipeline=pipeline)
    if kind != "fier":
        pol = dataclasses.replace(pol, kind=kind)
    eng = Engine.build(cfg, n_slots=4, capacity=256, policy=pol,
                       layout="paged", block_size=32, pool_blocks=40, mesh=mesh)
    params = eng.bundle.init(jax.random.PRNGKey(0))
    cache = eng.new_cache()
    toks = (np.arange(50) * 7 % 97).astype(np.int32)
    if chunked:
        resume, cache = eng.begin_chunked(cache, slot, toks)
        pos = resume
        while pos < 50:
            n = min(24, 50 - pos)
            ok, pre, cache = eng.prefill_chunk(params, cache, slot, toks, pos, n)
            assert ok
            pos += n
    else:
        pre, cache = eng.insert(params, cache, jnp.asarray(toks[None, :]), 50, slot)
    tok = int(jnp.argmax(pre[0]))
    outs = [tok]
    tvec = jnp.zeros((4,), jnp.int32)
    active = jnp.zeros((4,), bool).at[slot].set(True)
    for _ in range(6):
        ok, cache = eng.advance_slot(cache, slot)
        assert ok
        nxt, lg, cache = eng.decode(params, tvec.at[slot].set(tok), cache,
                                    active=active)
        tok = int(nxt[slot])
        outs.append(tok)
    cache = eng.release_slot(cache, slot)
    eng.audit()
    assert eng.allocator.n_in_use == 0
    return outs, np.asarray(pre), np.asarray(lg[slot])

def check(name, base, got):
    assert got[0] == base[0], (name, got[0], base[0])
    assert np.array_equal(got[1], base[1]), name + ": prefill logits drifted"
    assert np.array_equal(got[2], base[2]), name + ": decode logits drifted"
    print(name, "bit-identical")
"""


def test_sharded_decode_bit_identical_to_oracle():
    """TP=2, DP=2 and TP×DP engines produce bit-identical prefill
    logits, decode logits, and token streams vs the single-device
    oracle (fier backend, reference pipeline), with a clean audit."""
    run_in_subprocess(_DRIVER + """
base = run(None)
check("tp2", base, run(jax.make_mesh((2,), ("model",))))
check("dp2", base, run(jax.make_mesh((2,), ("data",))))
check("tp2xdp2", base, run(jax.make_mesh((2, 2), ("data", "model"))))
""")


def test_sharded_pipelines_and_backends_bit_identical():
    """The one-pass FIER kernel pipeline and the full-KV backend run
    sharded through the same plan surface; a slot homed on DP shard 1
    is bit-identical to the slot-0 single-device run."""
    run_in_subprocess(_DRIVER + """
mesh = jax.make_mesh((2, 2), ("data", "model"))
for kind, pipeline in [("fier", "one_pass"), ("full", "reference")]:
    base = run(None, pipeline=pipeline, kind=kind)
    got = run(mesh, pipeline=pipeline, kind=kind, slot=3)
    check(f"{kind}/{pipeline} slot3", base, got)
""")


def test_sharded_chunked_prefill_bit_identical():
    """Chunked admission on the sharded pool: the per-chunk pool
    gather/scatter round-trip must stay bit-identical to the unsharded
    chunked run (the gathered K/V are re-replicated before attention)."""
    run_in_subprocess(_DRIVER + """
base = run(None, chunked=True)
check("chunked tp2xdp2",
      base, run(jax.make_mesh((2, 2), ("data", "model")), chunked=True,
                slot=2))
""")


def test_sharded_tp_divisibility_error():
    run_in_subprocess(_DRIVER + """
mesh3 = jax.make_mesh((3,), ("model",))
try:
    Engine.build(cfg, n_slots=2, capacity=64,
                 policy=serving_policy(budget=16, skip_layers=1),
                 layout="paged", mesh=mesh3)
except ValueError as e:
    assert "divisible" in str(e) and "model" in str(e), e
else:
    raise AssertionError("n_kv_heads=4 with TP=3 must be rejected")
print("divisibility error OK")
""")


def test_sharded_one_pass_zero_score_bytes_per_shard():
    """The sharded one-pass decode keeps per-token score tensors out of
    HBM on every shard: the jaxpr byte counter (which recurses into the
    shard_map body per device) reports exactly zero, while the reference
    pipeline on the same sharded layout is nonzero (the counter is not
    vacuous under shard_map)."""
    run_in_subprocess("""
import sys
sys.path.insert(0, %r)
from flopcount import count_fn_score_bytes
import numpy as np, jax, jax.numpy as jnp
from repro.core import quantize as qz
from repro.core.policy import DecodePlan, PolicyConfig
from repro.kvcache.sharded import ShardSpec, sharded_paged_decode_step

B, S, Hkv, Hq, D, g, bs = 2, 256, 2, 4, 32, 8, 32
nb = S // bs
n_dp = 2
n_local = nb + 1
N = n_dp * n_local
ks = jax.random.split(jax.random.PRNGKey(0), 5)
K = jax.random.normal(ks[0], (B, S, Hkv, D), jnp.bfloat16)
V = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.bfloat16)
q = jax.random.normal(ks[2], (B, Hq, D), jnp.bfloat16)
k_new = jax.random.normal(ks[3], (B, 1, Hkv, D), jnp.bfloat16)
v_new = jax.random.normal(ks[4], (B, 1, Hkv, D), jnp.bfloat16)
qk = qz.quantize(K.astype(jnp.float32), g)

# batch row b's blocks live on its home DP shard: gids b*n_local+1 ..
table = jnp.asarray(
    [[b * n_local + 1 + i for i in range(nb)] for b in range(B)], jnp.int32
)

def to_pool(arr):
    pb = arr.shape[1] // nb     # side-car leaves carry S//g rows, not S
    pool = jnp.zeros((N, pb, *arr.shape[2:]), arr.dtype)
    blocks = arr.reshape(B, nb, pb, *arr.shape[2:])
    return pool.at[table.reshape(-1)].set(
        blocks.reshape(B * nb, pb, *arr.shape[2:])
    )

k_pool, v_pool = to_pool(K), to_pool(V)
meta = qz.QuantizedKeys(to_pool(qk.codes), to_pool(qk.scale),
                        to_pool(qk.zero), g)
length = jnp.full((B,), S - 1, jnp.int32)
mesh = jax.make_mesh((2, 2), ("data", "model"))
spec = ShardSpec(mesh=mesh, tp_axes=("model",), dp_axes=("data",))

def count(pipeline):
    pol = PolicyConfig(
        kind="fier", budget=32, group=8, skip_layers=0, sink=2, recent=4,
        pipeline=pipeline, layout="paged", block_size=bs, pool_blocks=N,
    )
    plan = DecodePlan.build(pol, shard=spec)
    return count_fn_score_bytes(
        lambda q, kp, vp: sharded_paged_decode_step(
            q, k_new, v_new, kp, vp, meta, table, length, pol, plan, spec
        )[0],
        S, q, k_pool, v_pool,
    )

ref = count("reference")
assert ref > 0, "counter is blind inside shard_map: reference counted 0"
one = count("one_pass")
assert one == 0.0, f"sharded one-pass leaked score bytes: {one}"
print("score bytes: reference", ref, "one_pass", one)
""" % (_BENCH_DIR,))


def test_sharded_chaos_audits_clean():
    """Seeded random fault schedules against a DP=2 sharded engine: the
    scheduler drains, every request retires with a structured outcome,
    and the per-shard allocators audit clean with zero leaked blocks."""
    run_in_subprocess("""
import warnings
import jax
from repro.configs import reduced_config
from repro.core.policy import PolicyConfig
from repro.serving import (
    ContinuousScheduler, Engine, Request, ServingFaultInjector,
)

cfg = reduced_config("olmo-1b")
pol = PolicyConfig(
    kind="fier", budget=16, group=8, skip_layers=1, sink=2, recent=4,
    pipeline="reference", layout="paged", block_size=8, pool_blocks=40,
)
eng = Engine.build(cfg, n_slots=4, capacity=64, policy=pol,
                   mesh=jax.make_mesh((2,), ("data",)))
params = eng.bundle.init(jax.random.PRNGKey(0))
reqs = [Request(rid=i, tokens=list(range(2 + i, 12 + i)), max_new=12)
        for i in range(4)]
for seed in (0, 1):
    inj = ServingFaultInjector.random(
        seed, rids=[0, 1, 2, 3], n_faults=3, step_lo=1, step_hi=8
    )
    sched = ContinuousScheduler(eng, params, injector=inj, audit_every=3)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        res = sched.run(reqs)
    assert sorted(res.outcomes) == [0, 1, 2, 3]
    assert all(o.status in ("finished", "cancelled", "quarantined",
                            "rejected") for o in res.outcomes.values()), (
        seed, {r: o.status for r, o in res.outcomes.items()})
    eng.audit()
    assert eng.allocator.n_in_use == 0, seed
    print("chaos seed", seed, "audits clean")
""")
