#!/usr/bin/env python3
"""Compare fresh BENCH_*.json / METRICS_*.json results against the
committed baselines and fail (exit 1) on a perf regression.

Two input formats, one gate:

  * ``BENCH_*.json`` — the benchmarks/persist.py document (flat metric
    list with ``better``/``gate``).
  * ``METRICS_*.json`` — a metrics-registry snapshot
    (``repro.obs.metrics.Snapshot.to_json``: ``kind: metrics_snapshot``).
    Each series becomes a metric named ``name{label="v",...}``; series
    metadata carries the same ``better``/``gate`` contract, so gated
    registry series (the serve-trace summary gauges) are regression-
    checked exactly like bench metrics.

Only metrics with ``gate: true`` participate; everything else is printed
for the record.  Tolerances:

  * ``better: lower``  — fail if new > baseline * 1.20 (+20% latency);
    a zero baseline is an exact gate (new must stay ~0, e.g. the
    "one-pass path materialises zero score bytes" property).
  * ``better: higher`` — fail if new < baseline * 0.90 (−10% throughput).

Typical flows:

  # CI / local check (baselines live at the repo root):
  python tools/check_bench_regression.py --new-dir bench_out

  # intentional perf change: regenerate, inspect, then bless
  PYTHONPATH=src python -m benchmarks.bench_serve_trace --smoke --out bench_out
  PYTHONPATH=src python -m benchmarks.bench_latency --smoke --out bench_out
  python tools/check_bench_regression.py --new-dir bench_out --update-baseline

``--update-baseline`` copies each new BENCH_*.json over its baseline
(creating it if absent) so the blessed numbers are committed with the PR
that changed them.  Stdlib-only on purpose: CI runs it without jax.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import sys

SCHEMA_VERSION = 1
OBS_SCHEMA_VERSION = 1  # repro.obs.metrics.OBS_SCHEMA_VERSION (stdlib tool:
                        # the constant is mirrored, not imported)
LOWER_TOL = 0.20   # +20% allowed on lower-is-better (latency) metrics
HIGHER_TOL = 0.10  # -10% allowed on higher-is-better (throughput) metrics
ZERO_EPS = 1e-9    # zero baselines gate exactly


def _snapshot_to_bench(doc: dict, path: str) -> dict:
    """Flatten a metrics-registry snapshot into the bench-doc shape so
    one compare path serves both formats.  Labeled series keep their
    labels in the metric name (``name{k="v"}``) — unique per series."""
    metrics = []
    for s in doc["series"]:
        labels = s.get("labels", {})
        lab = ("" if not labels else
               "{" + ",".join(f'{k}="{v}"'
                              for k, v in sorted(labels.items())) + "}")
        metrics.append({
            "name": s["name"] + lab,
            "value": float(s["value"]),
            "unit": s.get("unit", ""),
            "better": s.get("better", "info"),
            "gate": bool(s.get("gate", False)),
        })
    return {
        "schema": SCHEMA_VERSION,
        "bench": os.path.basename(path),
        "git_sha": "metrics-snapshot",
        "metrics": metrics,
    }


def load(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("kind") == "metrics_snapshot":
        if doc.get("obs_schema") != OBS_SCHEMA_VERSION:
            raise SystemExit(
                f"{path}: obs_schema {doc.get('obs_schema')} != "
                f"{OBS_SCHEMA_VERSION}")
        return _snapshot_to_bench(doc, path)
    if doc.get("schema") != SCHEMA_VERSION:
        raise SystemExit(f"{path}: schema {doc.get('schema')} != {SCHEMA_VERSION}")
    return doc


def check_metric(base: dict, new: dict) -> tuple[str, bool, str]:
    """Returns (status, regressed?, delta%) for one gated metric pair."""
    b, n = base["value"], new["value"]
    if base["better"] == "lower":
        limit = b * (1.0 + LOWER_TOL) if b > ZERO_EPS else ZERO_EPS
        bad = n > limit
    else:  # higher
        limit = b * (1.0 - HIGHER_TOL)
        bad = n < limit
    delta = "n/a" if abs(b) <= ZERO_EPS else f"{(n - b) / b * 100.0:+.1f}%"
    return ("REGRESSED" if bad else "ok"), bad, delta


def compare(base_doc: dict, new_doc: dict, bench: str) -> list[str]:
    """Prints the table for one bench; returns regression descriptions."""
    base_m = {m["name"]: m for m in base_doc["metrics"]}
    regressions: list[str] = []
    print(f"\n== {bench} (baseline {base_doc['git_sha'][:10]} -> "
          f"new {new_doc['git_sha'][:10]})")
    print(f"{'metric':40s} {'base':>12s} {'new':>12s} {'delta':>8s}  status")
    for m in new_doc["metrics"]:
        name = m["name"]
        if name not in base_m:
            print(f"{name:40s} {'--':>12s} {m['value']:12.3f} {'new':>8s}  "
                  + ("GATED-NEW" if m["gate"] else "info"))
            continue
        b = base_m[name]
        if not m["gate"]:
            d = ("n/a" if abs(b["value"]) <= ZERO_EPS
                 else f"{(m['value'] - b['value']) / b['value'] * 100.0:+.1f}%")
            print(f"{name:40s} {b['value']:12.3f} {m['value']:12.3f} "
                  f"{d:>8s}  info")
            continue
        status, bad, delta = check_metric(b, m)
        print(f"{name:40s} {b['value']:12.3f} {m['value']:12.3f} "
              f"{delta:>8s}  {status}")
        if bad:
            regressions.append(f"{bench}:{name} {b['value']:g} -> {m['value']:g}")
    gone = [n for n, bm in base_m.items()
            if bm["gate"] and n not in {m["name"] for m in new_doc["metrics"]}]
    for name in gone:
        print(f"{name:40s} {base_m[name]['value']:12.3f} {'--':>12s} "
              f"{'gone':>8s}  REGRESSED")
        regressions.append(f"{bench}:{name} gated metric disappeared")
    return regressions


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("--baseline-dir", default=".",
                    help="directory holding the committed BENCH_*.json "
                         "baselines (default: repo root)")
    ap.add_argument("--new-dir", required=True,
                    help="directory holding the freshly generated BENCH_*.json")
    ap.add_argument("--update-baseline", action="store_true",
                    help="bless: copy each new BENCH_*.json over its baseline "
                         "instead of checking")
    args = ap.parse_args()

    new_paths = sorted(
        glob.glob(os.path.join(args.new_dir, "BENCH_*.json"))
        + glob.glob(os.path.join(args.new_dir, "METRICS_*.json"))
    )
    if not new_paths:
        print(f"no BENCH_*.json / METRICS_*.json under {args.new_dir}",
              file=sys.stderr)
        return 1

    if args.update_baseline:
        for p in new_paths:
            dst = os.path.join(args.baseline_dir, os.path.basename(p))
            shutil.copyfile(p, dst)
            print(f"blessed {dst}")
        return 0

    regressions: list[str] = []
    for p in new_paths:
        name = os.path.basename(p)
        base_path = os.path.join(args.baseline_dir, name)
        if not os.path.exists(base_path):
            print(f"\n== {name}: no baseline at {base_path} — "
                  f"run with --update-baseline to create it", file=sys.stderr)
            regressions.append(f"{name}: missing baseline")
            continue
        base_doc, new_doc = load(base_path), load(p)
        b_dev = (base_doc.get("config") or {}).get("devices")
        n_dev = (new_doc.get("config") or {}).get("devices")
        if b_dev != n_dev and None not in (b_dev, n_dev):
            # a sharded run is a different workload, not a regression of
            # the single-device one: shard counts are distinct baselines
            # (metrics snapshots carry no config and never hit this)
            print(f"\n== {name}: baseline ran with devices={b_dev}, new "
                  f"with devices={n_dev} — distinct baselines, gating "
                  f"skipped (bless a matching baseline with "
                  f"--update-baseline)")
            continue
        regressions += compare(base_doc, new_doc, name)

    print()
    if regressions:
        print("PERF REGRESSION:", file=sys.stderr)
        for r in regressions:
            print(f"  {r}", file=sys.stderr)
        print("(intentional? bless with --update-baseline and commit)",
              file=sys.stderr)
        return 1
    print("bench regression check: all gated metrics within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
