#!/usr/bin/env python3
"""Render serving observability artifacts as human-readable summaries.

Input files are auto-detected by shape:

  * Chrome trace-event JSON (``*.trace.json``, written by
    ``Tracer.write_chrome_trace``) — prints the span-derived serving
    metrics (TTFT / ITL / throughput on the virtual token clock), the
    event census by name, and the notable lifecycle events (preemptions,
    quarantines, faults, budget downshifts).
  * Metrics-registry snapshots (``METRICS_*.json``, written by
    ``MetricsRegistry.write_snapshot_json``) — prints every series with
    kind / value / unit, gated series flagged.

``--validate`` runs the stdlib-only structural checker
(``repro.obs.tracing.validate_chrome_trace``) over every trace file and
exits non-zero on the first malformed document — the CI bench lane's
Perfetto-JSON gate.  The whole tool is stdlib-only (run with
``PYTHONPATH=src``): ``repro.obs.tracing`` / ``repro.obs.metrics``
import no third-party packages.

Typical use::

    PYTHONPATH=src python tools/obs_report.py bench_out/serve_trace_chunked.trace.json \\
        bench_out/METRICS_serve_trace.json
    PYTHONPATH=src python tools/obs_report.py --validate bench_out/*.trace.json
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import Counter

from repro.obs.metrics import Snapshot
from repro.obs.tracing import (
    derive_serving_metrics,
    load_trace_events,
    validate_chrome_trace,
)

# instants worth listing one-by-one (the "what went wrong" events)
NOTABLE = ("preempt", "prefill_abort", "quarantine", "fault",
           "budget_downshift", "budget_restore", "blocks_shed")


def _fmt(v: float) -> str:
    return f"{v:.3f}".rstrip("0").rstrip(".") if isinstance(v, float) else str(v)


def report_trace(path: str, doc: dict) -> None:
    events = load_trace_events(doc)
    derived = derive_serving_metrics(events)
    print(f"\n== {path} ({len(events)} events)")
    print("-- span-derived serving metrics (virtual token clock)")
    for k in ("n_requests", "n_finished_first_token", "total_tokens",
              "makespan", "ttft_p50", "ttft_p99", "itl_p50", "itl_p99",
              "tokens_per_kunit"):
        print(f"   {k:24s} {_fmt(derived[k]):>12s}")
    census = Counter(e.name.split("[")[0] for e in events)
    print("-- event census")
    for name, n in sorted(census.items()):
        print(f"   {name:24s} {n:>6d}")
    notable = [e for e in events if e.name in NOTABLE]
    if notable:
        print("-- notable events")
        for e in notable:
            args = " ".join(f"{k}={v}" for k, v in e.args)
            print(f"   t={_fmt(e.ts):>10s} {e.name:16s} {args}")


def _shard_rollup(snap: Snapshot) -> list[tuple[str, str, list[float]]]:
    """Group ``shard``-labeled series by (name, remaining labels): the
    per-shard ``pool_*`` gauges and introspection histograms a mesh-
    sharded engine emits.  Returns (display name, unit, shard values)."""
    groups: dict[tuple, list[float]] = {}
    units: dict[tuple, str] = {}
    for s in snap.series:
        labels = dict(s.labels)
        if labels.pop("shard", None) is None:
            continue
        rest = "" if not labels else (
            "{" + ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
            + "}")
        key = (s.name + rest,)
        groups.setdefault(key, []).append(s.value)
        units[key] = s.unit
    return [(k[0], units[k], vs) for k, vs in sorted(groups.items())]


def report_snapshot(path: str, doc: dict) -> None:
    snap = Snapshot.from_json(doc)
    print(f"\n== {path} ({len(snap.series)} series)")
    print(f"   {'series':44s} {'kind':10s} {'value':>14s} unit")
    for s in snap.series:
        flag = "  [gated]" if s.gate else ""
        print(f"   {s.full_name:44s} {s.kind:10s} {_fmt(s.value):>14s} "
              f"{s.unit}{flag}")
    rollup = _shard_rollup(snap)
    if rollup:
        # cluster-wide view of the per-shard series: sum is the global
        # level (e.g. total blocks in use), max flags the hottest shard
        print("-- across-shard rollup")
        print(f"   {'series':44s} {'shards':>6s} {'sum':>12s} "
              f"{'max':>12s} {'mean':>12s}")
        for name, unit, vs in rollup:
            print(f"   {name:44s} {len(vs):>6d} {_fmt(sum(vs)):>12s} "
                  f"{_fmt(max(vs)):>12s} "
                  f"{_fmt(sum(vs) / len(vs)):>12s}  {unit}")


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("paths", nargs="+",
                    help="trace (*.trace.json) and/or metrics snapshot "
                         "(METRICS_*.json) files")
    ap.add_argument("--validate", action="store_true",
                    help="structurally validate trace files (Perfetto/"
                         "Chrome trace-event schema) instead of reporting")
    args = ap.parse_args()

    status = 0
    for path in args.paths:
        with open(path) as f:
            doc = json.load(f)
        is_trace = isinstance(doc, dict) and "traceEvents" in doc
        is_snapshot = isinstance(doc, dict) and doc.get("kind") == "metrics_snapshot"
        if args.validate:
            if is_trace:
                errs = validate_chrome_trace(doc)
                if errs:
                    status = 1
                    print(f"{path}: INVALID ({len(errs)} problems)",
                          file=sys.stderr)
                    for e in errs[:20]:
                        print(f"  {e}", file=sys.stderr)
                else:
                    print(f"{path}: ok "
                          f"({len(doc['traceEvents'])} trace events)")
            elif is_snapshot:
                try:
                    Snapshot.from_json(doc)
                    print(f"{path}: ok ({len(doc['series'])} series)")
                except (KeyError, ValueError) as e:
                    status = 1
                    print(f"{path}: INVALID ({e})", file=sys.stderr)
            else:
                status = 1
                print(f"{path}: unrecognized document", file=sys.stderr)
            continue
        if is_trace:
            report_trace(path, doc)
        elif is_snapshot:
            report_snapshot(path, doc)
        else:
            status = 1
            print(f"{path}: unrecognized document (neither a Chrome trace "
                  f"nor a metrics snapshot)", file=sys.stderr)
    return status


if __name__ == "__main__":
    sys.exit(main())
