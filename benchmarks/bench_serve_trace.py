"""Traffic-trace serving benchmark: replay a seeded arrival trace through
a live ContinuousScheduler and measure what users feel — TTFT, inter-token
latency, throughput at saturation, preemptions, pool occupancy.

The replay is driven through the scheduler's stepwise API
(``start``/``submit``/``step``) on two clocks at once:

  * **virtual time** — the scheduler's own token clock (``sched.vtime``:
    a prefill token costs 1 unit, a batched decode step costs 1 per
    active slot).  Virtual metrics depend only on the schedule (arrival
    trace, block accounting, chunk quantum), not on the host, so they are
    reproducible across machines and **gated** in CI.
  * **wall clock** — recorded alongside and reported as info metrics
    (interpret-mode kernels and shared CI runners make it unsuitable for
    gating).

TTFT / ITL / throughput are **derived from the request-span trace**
(``repro.obs.tracing.derive_serving_metrics`` over the scheduler's
lifecycle events) — the benchmark no longer keeps its own clock or token
stamps, so the persisted numbers, the metrics-registry snapshot
(``METRICS_serve_trace.json``) and the per-mode Perfetto traces
(``serve_trace_<mode>.trace.json``, viewable via ``tools/obs_report.py``)
can never disagree.

``--smoke`` replays a bursty trace (long prompts bursting into a pool
already held by decoding requests) twice — chunked admission
(``chunk_tokens=256``) vs monolithic — and asserts the chunked schedule's
p99 TTFT is strictly lower at equal (±10%) token throughput: under block
pressure, chunked admission overlaps prefill compute with the wait for
blocks to drain, while a monolithic admission pays its whole prefill
*after* the pool finally fits the prompt.  A third *faulted* pass replays
the same trace on a degradation-enabled engine under a fixed
``ServingFaultInjector`` schedule (cancel, poison, alloc-fail burst) plus
an already-expired deadline, and gates zero leaked blocks at drain.
With ``--devices N`` (the multi-device CI lane) a fourth pass replays
the chunked trace on a mesh-sharded engine (DESIGN.md §Sharded serving)
and gates bit-identical outputs plus zero leaked blocks; shard count and
per-shard occupancy are recorded in the bench doc, and
tools/check_bench_regression.py treats differing shard counts as
distinct baselines.  Results go to ``BENCH_serve_trace.json`` (see
benchmarks/persist.py; baseline checked by
tools/check_bench_regression.py).

``--prefix-mix`` replays a prefix-heavy trace (two thirds of the
requests share one of two 128-token family prefixes, with a
distinct-prompt filler phase that ages the parked prefixes past the
TTL) on two engines sharing the same weights: a baseline whose expired
prefix blocks are destroyed, and a two-tier engine that demotes them to
host DRAM and recalls them on reuse (DESIGN.md §KV reuse tiers).  It
gates identical outputs, a positive prefix hit-rate / recall count, and
strictly fewer recomputed prompt tokens on the offload engine; results
go to ``BENCH_serve_prefix.json``.
"""
from __future__ import annotations

import argparse
import os
import time
from collections import deque

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core.policy import PolicyConfig
from repro.models import build_model
from repro.obs.tracing import PID_REQUEST, derive_serving_metrics
from repro.serving import (
    ContinuousScheduler,
    Engine,
    FaultSpec,
    Observability,
    Request,
    ServingFaultInjector,
)

from .persist import metric, write_bench_json

DECODE_TOKEN_COST = 1.0  # virtual units per active slot per decode step


# ------------------------------------------------------------------- traces

def bursty_trace(seed: int, vocab: int) -> list[tuple[float, dict]]:
    """The smoke workload: 4 medium decoders warm the pool, then 3 long
    prompts burst in while most blocks are still held, then a Poisson
    tail of short requests (sharing a family prefix with the burst)."""
    rng = np.random.default_rng(seed)
    toks = lambda n: rng.integers(1, vocab, size=n).tolist()
    family = toks(256)  # shared prefix of the long-prompt family
    trace: list[tuple[float, dict]] = []
    rid = 0
    for _ in range(4):
        trace.append((0.0, dict(rid=rid, tokens=toks(128), max_new=24)))
        rid += 1
    for _ in range(3):
        trace.append(
            (150.0, dict(rid=rid, tokens=family + toks(320), max_new=8))
        )
        rid += 1
    t = 160.0
    for _ in range(6):
        t += float(rng.exponential(40.0))
        trace.append((t, dict(rid=rid, tokens=family[:64] + toks(32), max_new=12)))
        rid += 1
    return trace


def poisson_trace(
    seed: int, vocab: int, *, n_requests: int, mean_gap: float,
    prompt_lo: int = 64, prompt_hi: int = 512, max_new: int = 16,
    n_families: int = 4, prefix_len: int = 64,
) -> list[tuple[float, dict]]:
    """Open-loop Poisson arrivals over shared-prefix prompt families."""
    rng = np.random.default_rng(seed)
    toks = lambda n: rng.integers(1, vocab, size=n).tolist()
    families = [toks(prefix_len) for _ in range(n_families)]
    trace, t = [], 0.0
    for rid in range(n_requests):
        t += float(rng.exponential(mean_gap))
        fam = families[int(rng.integers(0, n_families))]
        n = int(rng.integers(prompt_lo, prompt_hi + 1))
        suffix = toks(max(1, n - prefix_len))
        trace.append((t, dict(rid=rid, tokens=fam + suffix, max_new=max_new)))
    return trace


def prefix_mix_trace(seed: int, vocab: int) -> list[tuple[float, dict]]:
    """The --prefix-mix workload: 12 of 18 requests (67%) share one of
    two 128-token family prefixes.  A warm phase parks both families in
    the prefix cache, a distinct-prompt filler phase ages them past the
    park TTL (a baseline engine destroys the expired blocks; a two-tier
    engine demotes them to host DRAM), then a reuse phase re-sends the
    families — recall vs recompute is exactly the difference measured."""
    rng = np.random.default_rng(seed)
    toks = lambda n: rng.integers(1, vocab, size=n).tolist()
    families = [toks(128) for _ in range(2)]
    trace, rid = [], 0
    for i in range(4):          # warm: park both families
        fam = families[i % 2]
        trace.append((0.0, dict(rid=rid, tokens=fam + toks(32), max_new=8)))
        rid += 1
    t = 900.0
    for _ in range(6):          # fillers: age the parked prefixes out
        trace.append((t, dict(rid=rid, tokens=toks(256), max_new=8)))
        rid += 1
        t += 120.0
    t = 3200.0
    for i in range(8):          # reuse: recall (offload) vs recompute (base)
        fam = families[i % 2]
        trace.append((t, dict(rid=rid, tokens=fam + toks(48), max_new=8)))
        rid += 1
        t += 60.0
    return trace


# ------------------------------------------------------------------- replay

def build_serving(pipeline: str, *, capacity: int, n_slots: int,
                  pool_blocks: int, block_size: int = 32,
                  prefix_ttl: float | None = None, offload_blocks: int = 0,
                  mesh=None, metrics=None):
    cfg = reduced_config("olmo-1b")
    pol = PolicyConfig(
        kind="fier", budget=64, group=32, skip_layers=1, sink=4, recent=32,
        pipeline=pipeline, layout="paged", block_size=block_size,
        pool_blocks=pool_blocks,
    )
    if mesh is not None:
        # sharded pool (DESIGN.md §Sharded serving): Engine.build owns the
        # ShardSpec/DistConfig threading; params init is deterministic from
        # (cfg, key) so the sharded engine's weights match the unsharded one
        eng = Engine.build(
            cfg, n_slots=n_slots, capacity=capacity, policy=pol,
            obs=Observability(metrics=metrics), prefix_ttl=prefix_ttl,
            offload_blocks=offload_blocks, mesh=mesh,
        )
        params = eng.bundle.init(jax.random.PRNGKey(0))
        return cfg, params, eng
    bundle = build_model(cfg, pol)
    params = bundle.init(jax.random.PRNGKey(0))
    eng = Engine(bundle, n_slots=n_slots, capacity=capacity,
                 obs=Observability(metrics=metrics), prefix_ttl=prefix_ttl,
                 offload_blocks=offload_blocks)
    return cfg, params, eng


def device_mesh(devices: int):
    """The bench's mesh shapes: 1 → single-device (no mesh), 2 → DP=2,
    4 → DP=2 × TP=2 (axis names are the Engine.build contract)."""
    if devices == 1:
        return None
    if devices == 2:
        return jax.make_mesh((2,), ("data",))
    if devices == 4:
        return jax.make_mesh((2, 2), ("data", "model"))
    raise SystemExit(f"--devices must be 1, 2 or 4, got {devices}")


def replay(eng, sched, trace, outputs: dict | None = None):
    """Drive one trace through the scheduler; returns the stats dict.

    The scheduler's virtual token clock IS the replay clock: arrivals pin
    ``Request.arrival`` via ``submit(req, arrival=t)``, idle gaps advance
    it through ``idle_until``, and every latency number comes out of the
    request-span trace (``derive_serving_metrics``) — no shadow clock, no
    engine monkey-patching."""
    obs = eng.obs
    sched.start()
    pending = deque((t, Request(**spec)) for t, spec in trace)
    reqs = [r for _, r in pending]
    wall0 = time.monotonic()
    while pending or sched.busy:
        while pending and pending[0][0] <= sched.vtime:
            t, r = pending.popleft()
            sched.submit(r, arrival=t)
        if not sched.busy:
            sched.idle_until(pending[0][0])
            continue
        if not sched.step():
            if pending:
                # idle until the next arrival can be admitted
                sched.idle_until(pending[0][0])
                continue
            raise RuntimeError("trace replay stalled")
    wall_s = time.monotonic() - wall0

    d = derive_serving_metrics(obs.tracer)
    # wall-clock TTFT rides on the events' informational wall_ts
    first_wall: dict[int, float] = {}
    for e in obs.tracer.events:
        if e.pid == PID_REQUEST and e.name == "token" and e.tid not in first_wall:
            first_wall[e.tid] = e.wall_ts - wall0
    wall_ttft = list(first_wall.values())
    pool = eng.pool_stats()
    # the spans are the single source of truth — but the requests are
    # still the ground truth for *what was generated*: every token a
    # request kept must have exactly one span stamp
    assert d["total_tokens"] == sum(len(r.out) for r in reqs), (
        d["total_tokens"], sum(len(r.out) for r in reqs))
    if outputs is not None:
        outputs.update({r.rid: list(r.out) for r in reqs})
    return dict(
        vt_ttft_p50=d["ttft_p50"], vt_ttft_p99=d["ttft_p99"],
        vt_itl_p50=d["itl_p50"], vt_itl_p99=d["itl_p99"],
        vt_tokens_per_kunit=d["tokens_per_kunit"],
        wall_seconds=wall_s,
        wall_ttft_p99_s=float(np.percentile(wall_ttft, 99)) if wall_ttft else 0.0,
        total_tokens=d["total_tokens"], decode_steps=sched.steps,
        preemptions=sched.preemptions, prefill_aborts=sched.prefill_aborts,
        prefill_chunks=sched.prefill_chunks,
        mean_occupancy=sched.mean_occupancy,
        peak_blocks=pool["peak_in_use"],
        prefix_block_hits=pool["prefix_block_hits"],
        # fault-tolerance counters (all zero on a fault-free replay)
        rejected=sched.health.counts["rejected"],
        cancelled=sched.health.counts["cancelled"],
        deadline_exceeded=sched.health.counts["deadline_exceeded"],
        quarantined=sched.health.counts["quarantined"],
        insert_retries=sched.insert_retries,
        budget_downshifts=pool.get("budget_downshifts", 0),
        blocks_shed=pool.get("blocks_shed", 0),
        leaked_blocks=eng.allocator.n_in_use if eng.paged else 0,
    )


# --------------------------------------------------------------------- modes

SMOKE_ENGINE = dict(capacity=1024, n_slots=4, pool_blocks=34, block_size=32)

# the chaos pass's fixed fault schedule: a mid-flight cancel of a burst
# prompt, a poisoned decode step for a warm decoder (quarantine), and a
# transient allocation-failure burst (degradation ladder / insert retry)
FAULT_SCHEDULE = (
    FaultSpec("poison_logits", step=4, rid=2),
    FaultSpec("cancel", step=6, rid=4),
    FaultSpec("alloc_fail", step=8, count=3),
)


def faulted_replay(cfg, params, bundle, *, seed: int, chunk_tokens: int,
                   metrics=None, out_dir: str | None = None):
    """The chaos pass: the same bursty trace, plus one request whose
    deadline is already unmeetable, on a degradation-enabled engine under
    :data:`FAULT_SCHEDULE` — with retrieval introspection on, so the
    snapshot carries budget-utilization / oracle-overlap series from a
    degraded engine.  ``metrics`` shares the fault-free passes' registry;
    ``out_dir`` writes ``serve_trace_faulted.trace.json``.  Returns
    (stats, injector, engine)."""
    obs = Observability(introspect=True, probe_every=2, metrics=metrics)
    eng = Engine(
        bundle, n_slots=SMOKE_ENGINE["n_slots"],
        capacity=SMOKE_ENGINE["capacity"], degrade_floor=16, obs=obs,
    )
    trace = bursty_trace(seed, cfg.vocab)
    rid = 1 + max(spec["rid"] for _, spec in trace)
    trace.append(
        (200.0, dict(rid=rid, tokens=list(range(1, 48)), max_new=8,
                     deadline=10.0))
    )
    inj = ServingFaultInjector(list(FAULT_SCHEDULE))
    sched = ContinuousScheduler(
        eng, params, chunk_tokens=chunk_tokens, injector=inj, audit_every=8
    )
    stats = replay(eng, sched, trace)
    eng.audit()  # invariant check on top of the gated leak metric
    if out_dir is not None:
        obs.tracer.write_chrome_trace(
            os.path.join(out_dir, "serve_trace_faulted.trace.json"))
    return stats, inj, eng


def smoke(out_dir: str, *, seed: int = 0, chunk_tokens: int = 256,
          pipeline: str = "reference", devices: int = 1) -> dict:
    """CI gate: chunked vs monolithic on the bursty trace; writes
    BENCH_serve_trace.json, the per-mode Perfetto traces and the shared
    metrics-registry snapshot, and asserts the tentpole's latency claim.
    ``devices > 1`` adds a sharded pass: the trace replayed on a
    mesh-sharded engine must produce bit-identical outputs to a
    single-device oracle with zero leaked blocks (the multi-device CI
    lane's gate)."""
    cfg, params, eng = build_serving(pipeline, **SMOKE_ENGINE)
    trace = bursty_trace(seed, cfg.vocab)
    results = {}
    for mode, ct in (("chunked", chunk_tokens), ("mono", None)):
        sched = ContinuousScheduler(eng, params, chunk_tokens=ct)
        results[mode] = replay(eng, sched, trace)
        # the next replay's start() resets the tracer — export now
        eng.obs.tracer.write_chrome_trace(
            os.path.join(out_dir, f"serve_trace_{mode}.trace.json"))
        print(f"-- {mode}: " + " ".join(
            f"{k}={v:.1f}" for k, v in sorted(results[mode].items())
        ))
    fr, inj, feng = faulted_replay(
        cfg, params, eng.bundle, seed=seed, chunk_tokens=chunk_tokens,
        metrics=eng.obs.metrics, out_dir=out_dir,
    )
    print("-- faulted: " + " ".join(
        f"{k}={v:.1f}" for k, v in sorted(fr.items())
    ))
    sharded_res = shard_stats = None
    n_dp = n_tp = 1
    if devices > 1:
        mesh = device_mesh(devices)
        # per-shard usable block count matches the single-device pool's
        # (pool-1 usable blocks): each DP shard serves its slot share at
        # the single-device engine's per-slot pressure
        n_dp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)
        n_tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
        shard_engine = dict(
            SMOKE_ENGINE,
            pool_blocks=(SMOKE_ENGINE["pool_blocks"] - 1) * n_dp + n_dp,
        )
        # the sharded engine shares the run's metrics registry, so its
        # per-shard pool_*{shard=i} gauges land in the snapshot (and in
        # obs_report's across-shard rollup)
        _, sparams, seng = build_serving(
            pipeline, **shard_engine, mesh=mesh, metrics=eng.obs.metrics)
        souts: dict = {}
        sched = ContinuousScheduler(seng, sparams, chunk_tokens=chunk_tokens)
        sharded_res = replay(seng, sched, trace, outputs=souts)
        seng.audit()
        shard_stats = seng.allocator.shard_stats()
        print(f"-- sharded (devices={devices} dp={n_dp} tp={n_tp}): " + " ".join(
            f"{k}={v:.1f}" for k, v in sorted(sharded_res.items())
        ))
        # identity oracle: a single-device engine with the sharded run's
        # aggregate usable blocks.  Preemption legitimately changes
        # tokens (a preempted request resumes via re-prefill, whose
        # next-token logits attend over the FULL prefix, while
        # uninterrupted decode attends over the FIER-budgeted
        # selection), so the gate compares two preemption-free
        # schedules — asserted below so a future trace change that
        # reintroduces preemption fails loudly instead of flaking
        ref_engine = dict(
            SMOKE_ENGINE,
            pool_blocks=(SMOKE_ENGINE["pool_blocks"] - 1) * n_dp + 1,
        )
        _, rparams, reng = build_serving(pipeline, **ref_engine)
        ref_outs: dict = {}
        ref_res = replay(
            reng, ContinuousScheduler(reng, rparams, chunk_tokens=chunk_tokens),
            trace, outputs=ref_outs,
        )
        assert ref_res["preemptions"] == 0, (
            "oracle replay preempted — grow the oracle pool", ref_res)
        assert sharded_res["preemptions"] == 0, (
            "sharded replay preempted — grow the per-shard pool", sharded_res)
        # the sharded serving claim, gated: sharding changes WHERE blocks
        # and heads live, never what is generated
        assert souts == ref_outs, "sharded replay changed outputs"
        assert sharded_res["leaked_blocks"] == 0, sharded_res
    ch, mo = results["chunked"], results["mono"]
    ratio = ch["vt_ttft_p99"] / max(mo["vt_ttft_p99"], 1e-9)
    tput_ratio = ch["vt_tokens_per_kunit"] / max(mo["vt_tokens_per_kunit"], 1e-9)

    # every persisted number goes THROUGH the registry: the bench row is
    # read back from the gauge it just set, so BENCH_serve_trace.json and
    # METRICS_serve_trace.json are bit-identical by construction
    metrics = []

    def summary(name, value, *, unit="", better="info", gate=False):
        g = eng.obs.metrics.gauge(
            name, "serve_trace summary metric", unit=unit,
            better=better, gate=gate)
        g.set(float(value))
        metrics.append(metric(name, g.value(), unit=unit, better=better,
                              gate=gate))

    for mode, r in results.items():
        summary(f"{mode}_vt_ttft_p50", r["vt_ttft_p50"], unit="unit",
                better="lower", gate=True)
        summary(f"{mode}_vt_ttft_p99", r["vt_ttft_p99"], unit="unit",
                better="lower", gate=True)
        summary(f"{mode}_vt_itl_p50", r["vt_itl_p50"], unit="unit",
                better="lower", gate=True)
        summary(f"{mode}_vt_itl_p99", r["vt_itl_p99"], unit="unit",
                better="lower", gate=True)
        summary(f"{mode}_vt_tokens_per_kunit", r["vt_tokens_per_kunit"],
                unit="tok/kunit", better="higher", gate=True)
        summary(f"{mode}_wall_seconds", r["wall_seconds"], unit="s")
        summary(f"{mode}_preemptions", r["preemptions"])
        summary(f"{mode}_mean_occupancy", r["mean_occupancy"])
        summary(f"{mode}_peak_blocks", r["peak_blocks"])
        summary(f"{mode}_prefix_block_hits", r["prefix_block_hits"])
    summary("chunked_over_mono_ttft_p99", ratio, better="lower", gate=True)
    summary("chunked_over_mono_tput", tput_ratio, better="higher", gate=True)
    summary("chunked_prefill_chunks", ch["prefill_chunks"])
    summary("chunked_prefill_aborts", ch["prefill_aborts"])
    # chaos pass: leak gate + lifecycle / degradation counters
    summary("faulted_leaked_blocks", fr["leaked_blocks"], unit="blocks",
            better="lower", gate=True)
    summary("faulted_rejected", fr["rejected"])
    summary("faulted_cancelled", fr["cancelled"])
    summary("faulted_deadline_exceeded", fr["deadline_exceeded"])
    summary("faulted_quarantined", fr["quarantined"])
    summary("faulted_budget_downshifts", fr["budget_downshifts"])
    summary("faulted_blocks_shed", fr["blocks_shed"])
    summary("faulted_insert_retries", fr["insert_retries"])
    summary("faulted_total_tokens", fr["total_tokens"])
    if sharded_res is not None:
        # shard count + per-shard occupancy ride in the bench doc (info:
        # the hard gates are the output-identity/leak asserts above, and
        # differing shard counts are distinct baselines to the checker)
        summary("sharded_devices", devices)
        summary("sharded_n_dp", n_dp)
        summary("sharded_n_tp", n_tp)
        summary("sharded_leaked_blocks", sharded_res["leaked_blocks"],
                unit="blocks", better="lower", gate=True)
        summary("sharded_vt_ttft_p99", sharded_res["vt_ttft_p99"],
                unit="unit")
        summary("sharded_mean_occupancy", sharded_res["mean_occupancy"])
        for i, st in enumerate(shard_stats):
            summary(f"sharded_shard{i}_peak_blocks", st["pool_peak_in_use"])
            summary(f"sharded_shard{i}_prefix_block_hits",
                    st["pool_prefix_block_hits"])

    snap_doc = eng.obs.metrics.write_snapshot_json(
        os.path.join(out_dir, "METRICS_serve_trace.json"))
    by_name = {(s["name"], tuple(sorted(s["labels"].items()))): s["value"]
               for s in snap_doc["series"]}
    for m in metrics:
        assert by_name[(m["name"], ())] == m["value"], m

    doc = write_bench_json(
        out_dir, "serve_trace",
        dict(seed=seed, trace="bursty", chunk_tokens=chunk_tokens,
             pipeline=pipeline, decode_token_cost=DECODE_TOKEN_COST,
             devices=devices, shard_dp=n_dp, shard_tp=n_tp,
             **SMOKE_ENGINE),
        metrics,
    )
    # the tentpole claim, asserted: strictly lower p99 TTFT at equal
    # (within 10%) virtual token throughput
    assert ch["vt_ttft_p99"] < mo["vt_ttft_p99"], (ch, mo)
    assert tput_ratio >= 0.9, (ch, mo)
    # the fault-tolerance claim: every scheduled fault fired, each left
    # its structured outcome, and the pool drained without leaking
    assert inj.all_fired, inj.fired_log
    assert fr["leaked_blocks"] == 0, fr
    assert fr["cancelled"] >= 1 and fr["quarantined"] >= 1, fr
    assert fr["deadline_exceeded"] >= 1, fr
    assert feng.allocator.n_in_use == 0
    print(f"smoke ok: ttft_p99 {ch['vt_ttft_p99']:.0f} (chunked) vs "
          f"{mo['vt_ttft_p99']:.0f} (mono), tput ratio {tput_ratio:.2f}; "
          f"faulted pass survived {len(inj.fired_log)} faults, "
          f"0 leaked blocks")
    return doc


# --prefix-mix engine shape: a pool small enough that the filler phase
# pressures the warm families out, a host tier large enough to hold every
# demoted block (host DRAM is the cheap tier), and a park TTL well under
# the filler phase's virtual-time span so expiry — not just pressure —
# moves the prefixes between tiers
PREFIX_ENGINE = dict(capacity=512, n_slots=4, pool_blocks=30, block_size=32)
PREFIX_TTL = 600.0
PREFIX_OFFLOAD_BLOCKS = 80
PREFIX_CHUNK_TOKENS = 64


def prefix_mix(out_dir: str, *, seed: int = 0,
               pipeline: str = "reference") -> dict:
    """CI gate for the two-tier KV reuse subsystem: the prefix-mix trace
    on a baseline (TTL only — expired prefix blocks destroyed) vs a
    host-offload engine (expired blocks demoted, recalled on reuse),
    sharing the same weights.  Asserts bit-identical outputs, a positive
    prefix hit-rate and recall count, and strictly fewer recomputed
    prompt tokens on the offload engine; writes BENCH_serve_prefix.json
    + METRICS_serve_prefix.json + per-variant Perfetto traces."""
    cfg, params, base = build_serving(
        pipeline, **PREFIX_ENGINE, prefix_ttl=PREFIX_TTL)
    off = Engine(
        base.bundle, n_slots=PREFIX_ENGINE["n_slots"],
        capacity=PREFIX_ENGINE["capacity"], obs=Observability(),
        prefix_ttl=PREFIX_TTL, offload_blocks=PREFIX_OFFLOAD_BLOCKS,
    )
    trace = prefix_mix_trace(seed, cfg.vocab)
    n_requests = len(trace)
    engines = {"base": base, "offload": off}
    results, outs = {}, {}
    for name, eng in engines.items():
        sched = ContinuousScheduler(
            eng, params, chunk_tokens=PREFIX_CHUNK_TOKENS)
        outs[name] = {}
        results[name] = replay(eng, sched, trace, outputs=outs[name])
        eng.obs.tracer.write_chrome_trace(
            os.path.join(out_dir, f"serve_prefix_{name}.trace.json"))
        eng.audit()  # device-pool AND host-tier invariants at drain
        print(f"-- {name}: recomputed={eng.tokens_recomputed} "
              f"hits={eng.prefix_partial_hits} "
              f"recalled={eng.blocks_recalled} "
              f"ttft_p99={results[name]['vt_ttft_p99']:.0f}")

    metrics = []
    reg = off.obs.metrics

    def summary(name, value, *, unit="", better="info", gate=False):
        g = reg.gauge(name, "serve_prefix summary metric", unit=unit,
                      better=better, gate=gate)
        g.set(float(value))
        metrics.append(metric(name, g.value(), unit=unit, better=better,
                              gate=gate))

    for name, eng in engines.items():
        r = results[name]
        summary(f"{name}_tokens_recomputed", eng.tokens_recomputed,
                unit="tok", better="lower", gate=True)
        summary(f"{name}_prefix_hit_rate",
                eng.prefix_partial_hits / n_requests,
                better="higher", gate=(name == "offload"))
        summary(f"{name}_vt_ttft_p99", r["vt_ttft_p99"], unit="unit",
                better="lower", gate=True)
        summary(f"{name}_vt_tokens_per_kunit", r["vt_tokens_per_kunit"],
                unit="tok/kunit", better="higher", gate=True)
        summary(f"{name}_total_tokens", r["total_tokens"])
        summary(f"{name}_preemptions", r["preemptions"])
        summary(f"{name}_leaked_blocks", r["leaked_blocks"], unit="blocks",
                better="lower", gate=True)
    summary("offload_blocks_recalled", off.blocks_recalled, unit="blocks",
            better="higher", gate=True)
    summary("offload_tokens_recalled", off.tokens_recalled, unit="tok")
    summary("offload_host_resident", len(off.offload), unit="blocks")
    summary("offload_over_base_recomputed",
            off.tokens_recomputed / max(base.tokens_recomputed, 1),
            better="lower", gate=True)

    snap_doc = reg.write_snapshot_json(
        os.path.join(out_dir, "METRICS_serve_prefix.json"))
    by_name = {(s["name"], tuple(sorted(s["labels"].items()))): s["value"]
               for s in snap_doc["series"]}
    for m in metrics:
        assert by_name[(m["name"], ())] == m["value"], m

    doc = write_bench_json(
        out_dir, "serve_prefix",
        dict(seed=seed, trace="prefix_mix", pipeline=pipeline,
             chunk_tokens=PREFIX_CHUNK_TOKENS, prefix_ttl=PREFIX_TTL,
             offload_blocks=PREFIX_OFFLOAD_BLOCKS,
             decode_token_cost=DECODE_TOKEN_COST, **PREFIX_ENGINE),
        metrics,
    )
    # the subsystem's claim, asserted: the host tier changes WHAT is
    # recomputed, never what is generated
    assert outs["offload"] == outs["base"], "offload changed outputs"
    assert off.prefix_partial_hits > 0, "no prefix hits on the mix trace"
    assert off.blocks_recalled > 0, "host tier never recalled a block"
    assert off.tokens_recomputed < base.tokens_recomputed, (
        off.tokens_recomputed, base.tokens_recomputed)
    assert results["base"]["leaked_blocks"] == 0
    assert results["offload"]["leaked_blocks"] == 0
    print(f"prefix-mix ok: recomputed {off.tokens_recomputed} (offload) vs "
          f"{base.tokens_recomputed} (base), "
          f"{off.blocks_recalled} blocks recalled, "
          f"hit-rate {off.prefix_partial_hits / n_requests:.2f}, "
          f"identical outputs")
    return doc


def full(*, seed: int = 0, chunk_tokens: int = 256,
         pipeline: str = "reference", n_requests: int = 48):
    """Exploratory sweep (not persisted): Poisson arrivals at a few
    rates, chunked vs monolithic side by side."""
    cfg, params, eng = build_serving(pipeline, **SMOKE_ENGINE)
    for mean_gap in (120.0, 60.0, 30.0):
        trace = poisson_trace(
            seed, cfg.vocab, n_requests=n_requests, mean_gap=mean_gap
        )
        for mode, ct in (("chunked", chunk_tokens), ("mono", None)):
            sched = ContinuousScheduler(eng, params, chunk_tokens=ct)
            r = replay(eng, sched, trace)
            print(
                f"gap={mean_gap:5.0f} {mode:7s} "
                f"ttft_p99={r['vt_ttft_p99']:8.1f} "
                f"itl_p99={r['vt_itl_p99']:7.1f} "
                f"tput={r['vt_tokens_per_kunit']:7.1f} "
                f"preempt={r['preemptions']:3d} occ={r['mean_occupancy']:.2f}"
            )


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: bursty trace, chunked vs monolithic, "
                         "writes BENCH_serve_trace.json")
    ap.add_argument("--prefix-mix", action="store_true",
                    help="CI gate: prefix-heavy trace, baseline vs "
                         "host-offload engine, writes "
                         "BENCH_serve_prefix.json")
    ap.add_argument("--out", default=".",
                    help="directory (or file) for BENCH_serve_trace.json")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunk-tokens", type=int, default=256)
    ap.add_argument("--pipeline", default="reference",
                    choices=("reference", "one_pass"))
    ap.add_argument("--n-requests", type=int, default=48)
    ap.add_argument("--devices", type=int, default=1,
                    help="mesh size for the sharded smoke pass (1 = "
                         "single-device, 2 = DP, 4 = DP×TP; needs "
                         "XLA_FLAGS=--xla_force_host_platform_device_count "
                         "or real devices)")
    args = ap.parse_args()
    if args.devices > 1 and jax.device_count() < args.devices:
        raise SystemExit(
            f"--devices {args.devices} needs >= {args.devices} jax devices, "
            f"found {jax.device_count()} (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={args.devices})")
    if args.smoke or args.prefix_mix:
        os.makedirs(args.out, exist_ok=True)
        if args.smoke:
            smoke(args.out, seed=args.seed, chunk_tokens=args.chunk_tokens,
                  pipeline=args.pipeline, devices=args.devices)
        if args.prefix_mix:
            prefix_mix(args.out, seed=args.seed, pipeline=args.pipeline)
    else:
        full(seed=args.seed, chunk_tokens=args.chunk_tokens,
             pipeline=args.pipeline, n_requests=args.n_requests)


if __name__ == "__main__":
    main()
