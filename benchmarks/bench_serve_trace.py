"""Traffic-trace serving benchmark: replay a seeded arrival trace through
a live ContinuousScheduler and measure what users feel — TTFT, inter-token
latency, throughput at saturation, preemptions, pool occupancy.

The replay is driven through the scheduler's stepwise API
(``start``/``submit``/``step``) on two clocks at once:

  * **virtual time** — a deterministic token-cost model: a prefill token
    costs 1 unit, a batched decode step costs ``decode_token_cost`` per
    active slot.  Virtual metrics depend only on the schedule (arrival
    trace, block accounting, chunk quantum), not on the host, so they are
    reproducible across machines and **gated** in CI.
  * **wall clock** — recorded alongside and reported as info metrics
    (interpret-mode kernels and shared CI runners make it unsuitable for
    gating).

``--smoke`` replays a bursty trace (long prompts bursting into a pool
already held by decoding requests) twice — chunked admission
(``chunk_tokens=256``) vs monolithic — and asserts the chunked schedule's
p99 TTFT is strictly lower at equal (±10%) token throughput: under block
pressure, chunked admission overlaps prefill compute with the wait for
blocks to drain, while a monolithic admission pays its whole prefill
*after* the pool finally fits the prompt.  A third *faulted* pass replays
the same trace on a degradation-enabled engine under a fixed
``ServingFaultInjector`` schedule (cancel, poison, alloc-fail burst) plus
an already-expired deadline, and gates zero leaked blocks at drain.
Results go to ``BENCH_serve_trace.json`` (see benchmarks/persist.py;
baseline checked by tools/check_bench_regression.py).
"""
from __future__ import annotations

import argparse
import time
from collections import defaultdict, deque

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core.policy import PolicyConfig
from repro.models import build_model
from repro.serving import (
    ContinuousScheduler,
    Engine,
    FaultSpec,
    Request,
    ServingFaultInjector,
)

from .persist import metric, write_bench_json

DECODE_TOKEN_COST = 1.0  # virtual units per active slot per decode step


# ------------------------------------------------------------------- traces

def bursty_trace(seed: int, vocab: int) -> list[tuple[float, dict]]:
    """The smoke workload: 4 medium decoders warm the pool, then 3 long
    prompts burst in while most blocks are still held, then a Poisson
    tail of short requests (sharing a family prefix with the burst)."""
    rng = np.random.default_rng(seed)
    toks = lambda n: rng.integers(1, vocab, size=n).tolist()
    family = toks(256)  # shared prefix of the long-prompt family
    trace: list[tuple[float, dict]] = []
    rid = 0
    for _ in range(4):
        trace.append((0.0, dict(rid=rid, tokens=toks(128), max_new=24)))
        rid += 1
    for _ in range(3):
        trace.append(
            (150.0, dict(rid=rid, tokens=family + toks(320), max_new=8))
        )
        rid += 1
    t = 160.0
    for _ in range(6):
        t += float(rng.exponential(40.0))
        trace.append((t, dict(rid=rid, tokens=family[:64] + toks(32), max_new=12)))
        rid += 1
    return trace


def poisson_trace(
    seed: int, vocab: int, *, n_requests: int, mean_gap: float,
    prompt_lo: int = 64, prompt_hi: int = 512, max_new: int = 16,
    n_families: int = 4, prefix_len: int = 64,
) -> list[tuple[float, dict]]:
    """Open-loop Poisson arrivals over shared-prefix prompt families."""
    rng = np.random.default_rng(seed)
    toks = lambda n: rng.integers(1, vocab, size=n).tolist()
    families = [toks(prefix_len) for _ in range(n_families)]
    trace, t = [], 0.0
    for rid in range(n_requests):
        t += float(rng.exponential(mean_gap))
        fam = families[int(rng.integers(0, n_families))]
        n = int(rng.integers(prompt_lo, prompt_hi + 1))
        suffix = toks(max(1, n - prefix_len))
        trace.append((t, dict(rid=rid, tokens=fam + suffix, max_new=max_new)))
    return trace


# ------------------------------------------------------------------- replay

def build_serving(pipeline: str, *, capacity: int, n_slots: int,
                  pool_blocks: int, block_size: int = 32):
    cfg = reduced_config("olmo-1b")
    pol = PolicyConfig(
        kind="fier", budget=64, group=32, skip_layers=1, sink=4, recent=32,
        pipeline=pipeline, layout="paged", block_size=block_size,
        pool_blocks=pool_blocks,
    )
    bundle = build_model(cfg, pol)
    params = bundle.init(jax.random.PRNGKey(0))
    eng = Engine(bundle, n_slots=n_slots, capacity=capacity)
    return cfg, params, eng


def replay(eng, sched, trace, *, decode_token_cost: float = DECODE_TOKEN_COST):
    """Drive one trace through the scheduler; returns the stats dict."""
    counter = {"prefill": 0}
    orig_chunk, orig_insert = eng.prefill_chunk, eng.insert

    def chunk_spy(params, cache, slot, tokens, start, n):
        ok, logits, cache = orig_chunk(params, cache, slot, tokens, start, n)
        if ok:
            counter["prefill"] += n
        return ok, logits, cache

    def insert_spy(params, cache, tokens, length, slot, extras=None):
        counter["prefill"] += length
        return orig_insert(params, cache, tokens, length, slot, extras)

    eng.prefill_chunk, eng.insert = chunk_spy, insert_spy
    try:
        sched.start()
        pending = deque((t, Request(**spec)) for t, spec in trace)
        reqs = [r for _, r in pending]
        arrive: dict[int, float] = {}
        stamps: dict[int, list[tuple[float, float]]] = defaultdict(list)
        seen: dict[int, int] = defaultdict(int)
        clock, wall0 = 0.0, time.perf_counter()
        while pending or sched.busy:
            while pending and pending[0][0] <= clock:
                t, r = pending.popleft()
                sched.submit(r)
                arrive[r.rid] = t
            if not sched.busy:
                clock = max(clock, pending[0][0])
                continue
            p0, occ0 = counter["prefill"], len(sched.occupancy)
            progressed = sched.step()
            cost = float(counter["prefill"] - p0)
            if len(sched.occupancy) > occ0:
                cost += sched.occupancy[-1] * decode_token_cost
            if not progressed and cost == 0.0:
                if pending:
                    # idle until the next arrival can be admitted
                    clock = max(clock, pending[0][0])
                    continue
                raise RuntimeError("trace replay stalled")
            clock += cost
            wall = time.perf_counter() - wall0
            for r in reqs:
                if len(r.out) > seen[r.rid]:
                    stamps[r.rid].extend(
                        (clock, wall) for _ in range(len(r.out) - seen[r.rid])
                    )
                    seen[r.rid] = len(r.out)
        wall_s = time.perf_counter() - wall0
    finally:
        eng.prefill_chunk, eng.insert = orig_chunk, orig_insert

    ttft = [stamps[r.rid][0][0] - arrive[r.rid] for r in reqs if stamps[r.rid]]
    wall_ttft = [
        stamps[r.rid][0][1] for r in reqs if stamps[r.rid]
    ]  # vs wall 0 (arrivals are virtual-time events)
    itl = [
        b[0] - a[0]
        for r in reqs
        for a, b in zip(stamps[r.rid], stamps[r.rid][1:])
    ]
    total_tokens = sum(len(r.out) for r in reqs)
    makespan = max(clock - min(arrive.values()), 1e-9)
    pool = eng.pool_stats()
    pct = lambda xs, p: float(np.percentile(xs, p)) if xs else 0.0
    return dict(
        vt_ttft_p50=pct(ttft, 50), vt_ttft_p99=pct(ttft, 99),
        vt_itl_p50=pct(itl, 50), vt_itl_p99=pct(itl, 99),
        vt_tokens_per_kunit=1e3 * total_tokens / makespan,
        wall_seconds=wall_s, wall_ttft_p99_s=pct(wall_ttft, 99),
        total_tokens=total_tokens, decode_steps=sched.steps,
        preemptions=sched.preemptions, prefill_aborts=sched.prefill_aborts,
        prefill_chunks=sched.prefill_chunks,
        mean_occupancy=sched.mean_occupancy,
        peak_blocks=pool["peak_in_use"],
        prefix_block_hits=pool["prefix_block_hits"],
        # fault-tolerance counters (all zero on a fault-free replay)
        rejected=sched.health.counts["rejected"],
        cancelled=sched.health.counts["cancelled"],
        deadline_exceeded=sched.health.counts["deadline_exceeded"],
        quarantined=sched.health.counts["quarantined"],
        insert_retries=sched.insert_retries,
        budget_downshifts=pool.get("budget_downshifts", 0),
        blocks_shed=pool.get("blocks_shed", 0),
        leaked_blocks=eng.allocator.n_in_use if eng.paged else 0,
    )


# --------------------------------------------------------------------- modes

SMOKE_ENGINE = dict(capacity=1024, n_slots=4, pool_blocks=34, block_size=32)

# the chaos pass's fixed fault schedule: a mid-flight cancel of a burst
# prompt, a poisoned decode step for a warm decoder (quarantine), and a
# transient allocation-failure burst (degradation ladder / insert retry)
FAULT_SCHEDULE = (
    FaultSpec("poison_logits", step=4, rid=2),
    FaultSpec("cancel", step=6, rid=4),
    FaultSpec("alloc_fail", step=8, count=3),
)


def faulted_replay(cfg, params, bundle, *, seed: int, chunk_tokens: int):
    """The chaos pass: the same bursty trace, plus one request whose
    deadline is already unmeetable, on a degradation-enabled engine under
    :data:`FAULT_SCHEDULE`.  Returns (stats, injector, engine)."""
    eng = Engine(
        bundle, n_slots=SMOKE_ENGINE["n_slots"],
        capacity=SMOKE_ENGINE["capacity"], degrade_floor=16,
    )
    trace = bursty_trace(seed, cfg.vocab)
    rid = 1 + max(spec["rid"] for _, spec in trace)
    trace.append(
        (200.0, dict(rid=rid, tokens=list(range(1, 48)), max_new=8,
                     deadline=10.0))
    )
    inj = ServingFaultInjector(list(FAULT_SCHEDULE))
    sched = ContinuousScheduler(
        eng, params, chunk_tokens=chunk_tokens, injector=inj, audit_every=8
    )
    stats = replay(eng, sched, trace)
    eng.audit()  # invariant check on top of the gated leak metric
    return stats, inj, eng


def smoke(out_dir: str, *, seed: int = 0, chunk_tokens: int = 256,
          pipeline: str = "reference") -> dict:
    """CI gate: chunked vs monolithic on the bursty trace; writes
    BENCH_serve_trace.json and asserts the tentpole's latency claim."""
    cfg, params, eng = build_serving(pipeline, **SMOKE_ENGINE)
    trace = bursty_trace(seed, cfg.vocab)
    results = {}
    for mode, ct in (("chunked", chunk_tokens), ("mono", None)):
        sched = ContinuousScheduler(eng, params, chunk_tokens=ct)
        results[mode] = replay(eng, sched, trace)
        print(f"-- {mode}: " + " ".join(
            f"{k}={v:.1f}" for k, v in sorted(results[mode].items())
        ))
    fr, inj, feng = faulted_replay(
        cfg, params, eng.bundle, seed=seed, chunk_tokens=chunk_tokens
    )
    print("-- faulted: " + " ".join(
        f"{k}={v:.1f}" for k, v in sorted(fr.items())
    ))
    ch, mo = results["chunked"], results["mono"]
    ratio = ch["vt_ttft_p99"] / max(mo["vt_ttft_p99"], 1e-9)
    tput_ratio = ch["vt_tokens_per_kunit"] / max(mo["vt_tokens_per_kunit"], 1e-9)
    metrics = []
    for mode, r in results.items():
        metrics += [
            metric(f"{mode}_vt_ttft_p50", r["vt_ttft_p50"], unit="unit",
                   better="lower", gate=True),
            metric(f"{mode}_vt_ttft_p99", r["vt_ttft_p99"], unit="unit",
                   better="lower", gate=True),
            metric(f"{mode}_vt_itl_p50", r["vt_itl_p50"], unit="unit",
                   better="lower", gate=True),
            metric(f"{mode}_vt_itl_p99", r["vt_itl_p99"], unit="unit",
                   better="lower", gate=True),
            metric(f"{mode}_vt_tokens_per_kunit", r["vt_tokens_per_kunit"],
                   unit="tok/kunit", better="higher", gate=True),
            metric(f"{mode}_wall_seconds", r["wall_seconds"], unit="s"),
            metric(f"{mode}_preemptions", r["preemptions"]),
            metric(f"{mode}_mean_occupancy", r["mean_occupancy"]),
            metric(f"{mode}_peak_blocks", r["peak_blocks"]),
            metric(f"{mode}_prefix_block_hits", r["prefix_block_hits"]),
        ]
    metrics += [
        metric("chunked_over_mono_ttft_p99", ratio, better="lower", gate=True),
        metric("chunked_over_mono_tput", tput_ratio, better="higher", gate=True),
        metric("chunked_prefill_chunks", ch["prefill_chunks"]),
        metric("chunked_prefill_aborts", ch["prefill_aborts"]),
        # chaos pass: leak gate + lifecycle / degradation counters
        metric("faulted_leaked_blocks", fr["leaked_blocks"], unit="blocks",
               better="lower", gate=True),
        metric("faulted_rejected", fr["rejected"]),
        metric("faulted_cancelled", fr["cancelled"]),
        metric("faulted_deadline_exceeded", fr["deadline_exceeded"]),
        metric("faulted_quarantined", fr["quarantined"]),
        metric("faulted_budget_downshifts", fr["budget_downshifts"]),
        metric("faulted_blocks_shed", fr["blocks_shed"]),
        metric("faulted_insert_retries", fr["insert_retries"]),
        metric("faulted_total_tokens", fr["total_tokens"]),
    ]
    doc = write_bench_json(
        out_dir, "serve_trace",
        dict(seed=seed, trace="bursty", chunk_tokens=chunk_tokens,
             pipeline=pipeline, decode_token_cost=DECODE_TOKEN_COST,
             **SMOKE_ENGINE),
        metrics,
    )
    # the tentpole claim, asserted: strictly lower p99 TTFT at equal
    # (within 10%) virtual token throughput
    assert ch["vt_ttft_p99"] < mo["vt_ttft_p99"], (ch, mo)
    assert tput_ratio >= 0.9, (ch, mo)
    # the fault-tolerance claim: every scheduled fault fired, each left
    # its structured outcome, and the pool drained without leaking
    assert inj.all_fired, inj.fired_log
    assert fr["leaked_blocks"] == 0, fr
    assert fr["cancelled"] >= 1 and fr["quarantined"] >= 1, fr
    assert fr["deadline_exceeded"] >= 1, fr
    assert feng.allocator.n_in_use == 0
    print(f"smoke ok: ttft_p99 {ch['vt_ttft_p99']:.0f} (chunked) vs "
          f"{mo['vt_ttft_p99']:.0f} (mono), tput ratio {tput_ratio:.2f}; "
          f"faulted pass survived {len(inj.fired_log)} faults, "
          f"0 leaked blocks")
    return doc


def full(*, seed: int = 0, chunk_tokens: int = 256,
         pipeline: str = "reference", n_requests: int = 48):
    """Exploratory sweep (not persisted): Poisson arrivals at a few
    rates, chunked vs monolithic side by side."""
    cfg, params, eng = build_serving(pipeline, **SMOKE_ENGINE)
    for mean_gap in (120.0, 60.0, 30.0):
        trace = poisson_trace(
            seed, cfg.vocab, n_requests=n_requests, mean_gap=mean_gap
        )
        for mode, ct in (("chunked", chunk_tokens), ("mono", None)):
            sched = ContinuousScheduler(eng, params, chunk_tokens=ct)
            r = replay(eng, sched, trace)
            print(
                f"gap={mean_gap:5.0f} {mode:7s} "
                f"ttft_p99={r['vt_ttft_p99']:8.1f} "
                f"itl_p99={r['vt_itl_p99']:7.1f} "
                f"tput={r['vt_tokens_per_kunit']:7.1f} "
                f"preempt={r['preemptions']:3d} occ={r['mean_occupancy']:.2f}"
            )


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: bursty trace, chunked vs monolithic, "
                         "writes BENCH_serve_trace.json")
    ap.add_argument("--out", default=".",
                    help="directory (or file) for BENCH_serve_trace.json")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunk-tokens", type=int, default=256)
    ap.add_argument("--pipeline", default="reference",
                    choices=("reference", "one_pass"))
    ap.add_argument("--n-requests", type=int, default=48)
    args = ap.parse_args()
    if args.smoke:
        import os

        os.makedirs(args.out, exist_ok=True)
        smoke(args.out, seed=args.seed, chunk_tokens=args.chunk_tokens,
              pipeline=args.pipeline)
    else:
        full(seed=args.seed, chunk_tokens=args.chunk_tokens,
             pipeline=args.pipeline, n_requests=args.n_requests)


if __name__ == "__main__":
    main()
