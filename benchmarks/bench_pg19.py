"""Paper Fig. 5 proxy: LM perplexity vs context length under cache budgets.

PG19's pretrained 7B models aren't available offline, so the *claim shape*
is reproduced on a model trained in-container on the deterministic bigram
corpus: generate continuations scoring next-token NLL with the cache
policy active, for contexts of increasing length; FIER at ~12% budget
should track full-KV closely while Quest (same load ratio) and SLM drift.

Measured as teacher-forced decode: prefill L tokens, then decode the next
32 gold tokens one-by-one through the policy path, accumulating NLL.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import lm_tokens

from .common import emit, policy_bundle, train_tiny_lm

EVAL_TOKENS = 32


def nll_for(bundle, params, cfg, toks: jax.Array, prefix: int) -> float:
    B = toks.shape[0]
    pre = {"tokens": toks[:, :prefix],
           "lengths": jnp.full((B,), prefix, jnp.int32)}
    cap = prefix + EVAL_TOKENS
    cap += (-cap) % 8
    logits, cache = jax.jit(lambda p, b: bundle.prefill(p, b, capacity=cap))(params, pre)
    nll, n = 0.0, 0
    decode = jax.jit(bundle.decode_step)
    for t in range(EVAL_TOKENS):
        gold = toks[:, prefix + t]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll += float(-jnp.take_along_axis(logp, gold[:, None], 1).mean())
        n += 1
        logits, cache = decode(params, gold, cache)
    return nll / n


def run():
    cfg, params = train_tiny_lm("lm")
    params = jax.tree.map(jnp.asarray, params)
    B = 4
    budget = 32  # ~12% of the longest context (matches the paper's 11%)
    toks = lm_tokens(123, 9, B, 384, cfg.vocab)
    for prefix in (64, 128, 256):
        for kind in ("full", "fier", "quest", "slm"):
            bundle = policy_bundle(cfg, kind, budget)
            ppl = float(np.exp(nll_for(bundle, params, cfg, toks, prefix)))
            emit(f"pg19_ppl_{kind}_ctx{prefix}", 0.0,
                 f"ppl={ppl:.3f} budget={budget}")


def main():
    run()


if __name__ == "__main__":
    main()
