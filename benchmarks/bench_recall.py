"""Paper Figs. 3 & 6: top-k recall of 1-bit scores vs page-level selection.

Measures, on (a) synthetic outlier-channel keys and (b) keys produced by a
*trained* tiny LM mid-prefill, the overlap between the policy's selected
tokens and the full-precision attention top-k — the paper's core
mechanism claim: token-level 1-bit ≫ page-level min/max at equal load
ratio, and ≈ full-precision selection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize as qz
from repro.core import quest, retrieval as rt

from .common import emit, timeit, train_tiny_lm


def _recall(selected_idx: np.ndarray, exact_scores: np.ndarray, k: int) -> float:
    """selected_idx [B,H,k'], exact [B,H,S]."""
    top = np.argsort(-exact_scores, axis=-1)[..., :k]
    out = []
    for b in range(top.shape[0]):
        for h in range(top.shape[1]):
            out.append(len(set(top[b, h]) & set(selected_idx[b, h])) / k)
    return float(np.mean(out))


def synthetic_keys(seed=0, B=2, S=2048, Hkv=2, Hq=4, D=64):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    chan = jnp.exp(jax.random.normal(ks[2], (D,)))
    K = jax.random.normal(ks[0], (B, S, Hkv, D)) * chan
    q = jax.random.normal(ks[1], (B, Hq, D)) * chan
    return q, K


def model_keys(S=256):
    """Keys/query from a trained model's first policy layer at prefill."""
    cfg, params = train_tiny_lm("lm")
    from repro.data.pipeline import make_prefill_batch
    from repro.models import attention as attn
    from repro.models.layers import apply_norm

    batch = make_prefill_batch(cfg, 2, S)
    emb = jnp.take(jnp.asarray(params["embed"]), batch["tokens"], axis=0)
    lp = jax.tree.map(lambda a: jnp.asarray(a)[2], params["layers"])  # layer 2
    xn = apply_norm(emb.astype(jnp.bfloat16), lp["norm1"], cfg.norm)
    q_all, K, _ = attn.qkv_proj(lp["attn"], xn, cfg, positions=None)
    return q_all[:, -1].astype(jnp.float32), K.astype(jnp.float32)


def run(budget_k: int = 64) -> list[str]:
    rows = []
    for src, (q, K) in (("synthetic", synthetic_keys()), ("trained", model_keys())):
        S = K.shape[1]
        Hkv, Hq = K.shape[2], q.shape[1]
        exact = np.asarray(rt.exact_scores(q, K))
        kk = min(budget_k, S // 4)

        for g in (32, 128):
            if S % g:
                continue
            t0 = timeit(lambda: rt.approx_scores(q, qz.quantize(K, g)))
            s = np.asarray(rt.approx_scores(q, qz.quantize(K, g)))
            sel = np.argsort(-s, axis=-1)[..., :kk]
            r = _recall(sel, exact, kk)
            emit(f"recall_fier_g{g}_{src}", t0, f"recall@{kk}={r:.3f}")
            rows.append(r)

        for p in (16, 32):
            if S % p:
                continue
            meta = quest.build_page_meta(K, p)
            ps = np.asarray(quest.page_scores(q, meta))
            sel = []
            for b in range(ps.shape[0]):
                row = []
                for h in range(ps.shape[1]):
                    pages = np.argsort(-ps[b, h])[: max(kk // p, 1)]
                    ids = np.concatenate([np.arange(x * p, (x + 1) * p) for x in pages])
                    row.append(ids[:kk] if len(ids) >= kk else
                               np.pad(ids, (0, kk - len(ids))))
                sel.append(row)
            r = _recall(np.asarray(sel), exact, kk)
            t0 = timeit(lambda: quest.page_scores(q, meta))
            emit(f"recall_quest_p{p}_{src}", t0, f"recall@{kk}={r:.3f}")
            rows.append(r)

        # random-page floor
        rng = np.random.default_rng(0)
        sel = np.stack([
            np.stack([rng.choice(S, kk, replace=False) for _ in range(Hq)])
            for _ in range(K.shape[0])
        ])
        emit(f"recall_random_{src}", 0.0,
             f"recall@{kk}={_recall(sel, exact, kk):.3f}")
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
