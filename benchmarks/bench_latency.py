"""Paper Fig. 8: decode latency vs context length, Full-KV vs FIER
(unfused, fused two-pass, and one-pass fused retrieval).

Four measurements:
  1. CPU wall-clock of the jitted decode step at growing cache lengths —
    the *trend* (FIER flattens, full grows linearly) is hardware-agnostic;
    the fused paths additionally run in Pallas interpret mode on CPU, so
    their wall-clock is a correctness smoke, not a perf number;
  2. materialised gather bytes per decode step, counted from the jaxpr
     (scan-aware, all layers): the unfused path writes+reads budget-sized
     K'/V' copies every layer every step; the fused paths must show the
     cache-slab gathers *gone* — measured, not asserted;
  3. materialised score-tensor bytes per decode step
     (``count_score_bytes``): the unfused/two-pass paths round-trip the
     f32 [B, Hq, S] (and [B, Hkv, S]) approximate-score tensors through
     HBM between scoring and selection (≥ 2·4·Hq·S bytes/layer/step);
     the one-pass retrieval kernel must measure **zero** — the property
     the ``--smoke`` CI gate asserts;
  4. the analytic v5e bytes model (decode is HBM-bound): step time ≈
     bytes_touched / 819 GB/s using the exact cache/metadata byte counts —
     the paper's 1.2–1.5× claim mapped onto TPU, and the fused-vs-unfused
     delta (no 2·budget·D bf16 copies per kv head per layer per step).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import packed_nbytes

from .common import (
    bench_model_cfg, emit, emit_paged_score_traffic, emit_score_traffic,
    policy_bundle, timeit, train_tiny_lm,
)
from .flopcount import count_fn_gather_bytes
from .persist import metric, write_bench_json

HBM_BW = 819e9


def analytic_v5e_speedup(S: int, cfg, budget: int, g: int = 32) -> float:
    """bytes(full)/bytes(fier) per layer at context S (B=1)."""
    Hkv, D = cfg.n_kv_heads, cfg.d_head
    full = 2 * S * Hkv * D * 2
    fier = packed_nbytes(S, Hkv, D, g) + 2 * budget * Hkv * D * 2
    return full / fier


def gather_copy_bytes(cfg, budget: int, B: int, n_sparse: int) -> int:
    """Analytic bytes of the materialised K'/V' gather per decode step:
    2 slabs · budget rows · Hkv · D · bf16, per sparse layer."""
    return 2 * budget * cfg.n_kv_heads * cfg.d_head * 2 * B * n_sparse


def _fier_slab_pipelines():
    """The registered (slab) fier pipelines, straight off the backend's
    capability matrix — new pipelines benchmark without editing this file."""
    from repro.core.policy import get_backend

    return sorted(p for lo, p in get_backend("fier").supports if lo == "slab")


def run():
    cfg, params = train_tiny_lm("lm")
    params = jax.tree.map(jnp.asarray, params)
    B = 4
    budget = 64
    variants = [("full", dict(kind="full"))] + [
        (f"fier_{p}", dict(kind="fier", pipeline=p))
        for p in _fier_slab_pipelines()
    ]
    for S in (512, 1024, 2048):
        tok = jnp.zeros((B,), jnp.int32)
        gbytes = {}
        for name, kw in variants:
            bundle = policy_bundle(cfg, budget=budget, skip=1, **kw)
            cache = bundle.init_cache(B, S, S - 2)
            step = jax.jit(bundle.decode_step)
            us = timeit(step, params, tok, cache, reps=5)
            if name != "full":  # gather accounting only compares fier paths
                gbytes[name] = count_fn_gather_bytes(
                    bundle.decode_step, params, tok, cache
                )
            emit(f"decode_latency_{name}_ctx{S}", us, f"B={B}")
        # the fused paths must eliminate the budget-sized K'/V' copies:
        # unfused − fused == the analytic gather bytes (embedding-lookup
        # gathers etc. are common to both and cancel)
        copies = gather_copy_bytes(cfg, budget, B, cfg.n_layers - 1)
        eliminated = gbytes["fier_reference"] - gbytes["fier_one_pass"]
        emit(
            f"decode_gather_bytes_ctx{S}", 0.0,
            " ".join(f"{n}={v:.0f}" for n, v in sorted(gbytes.items()))
            + f" eliminated={eliminated:.0f} analytic_kv_copies={copies}",
        )
        # the one-pass retrieval kernel must additionally eliminate the
        # f32 score-tensor round trip between scoring and selection
        emit_score_traffic(cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
                           budget=budget, B=B, S=S)
        emit(
            f"decode_latency_v5e_model_ctx{S}", 0.0,
            f"analytic_fullKV_over_FIER={analytic_v5e_speedup(S, cfg, budget):.2f}x",
        )
    # the paper's setting: 32k context, 4k budget, 7B-class GQA dims
    from repro.configs import get_config

    big = get_config("llava-next-mistral-7b")  # mistral-7b backbone
    for S in (8192, 16384, 32768):
        emit(
            f"decode_latency_v5e_model_7b_ctx{S}", 0.0,
            f"analytic_fullKV_over_FIER={analytic_v5e_speedup(S, big, 4096):.2f}x",
        )


def smoke(out_dir: str = "."):
    """Fast CI gate (`--smoke`): assert the one-pass retrieval path
    materialises zero score-tensor bytes (and the two-pass path pays the
    full ≥ 2·4·Hq·S round trip) at a tiny config — the perf property is
    *gated*, not just benchmarked.  No model training involved.

    The gate iterates the backend registry's capability matrix instead
    of hard-coding variant names: every layout the fier backend registers
    a ``one_pass`` pipeline for is asserted zero-score-byte, so a new
    layout cannot land without passing (or explicitly skipping) the gate."""
    from repro.core.policy import get_backend

    cfg = bench_model_cfg()
    parts = []
    metrics = []
    one_pass_layouts = sorted(
        lo for lo, p in get_backend("fier").supports if p == "one_pass"
    )
    assert one_pass_layouts, "fier registers no one_pass pipeline?"
    for layout in one_pass_layouts:
        if layout == "slab":
            sb = emit_score_traffic(cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
                                    budget=32, B=1, S=256, check=True)
            parts.append(
                " ".join(f"slab_{p}={sb[p]:.0f}" for p in sorted(sb))
            )
            for p, v in sorted(sb.items()):
                # the fused one-pass path is the gated zero; the unfused
                # paths' round-trip bytes are recorded for the trajectory
                metrics.append(metric(
                    f"slab_{p}_score_bytes", v, unit="B",
                    better="lower", gate=(p == "one_pass"),
                ))
        elif layout == "paged":
            psb = emit_paged_score_traffic(
                cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
                budget=32, B=1, S=256, block_size=32, check=True,
            )
            parts.append(f"paged_onepass={psb:.0f}")
            metrics.append(metric(
                "paged_one_pass_score_bytes", psb, unit="B",
                better="lower", gate=True,
            ))
        else:
            raise AssertionError(
                f"fier registers one_pass for unknown layout {layout!r}: "
                f"extend the smoke gate"
            )
    emit("bench_smoke_ok", 0.0, " ".join(parts))
    write_bench_json(
        out_dir, "latency",
        dict(budget=32, B=1, S=256, block_size=32,
             one_pass_layouts=one_pass_layouts),
        metrics,
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: assert zero score-tensor bytes on the "
                         "one-pass paths; writes BENCH_latency.json")
    ap.add_argument("--out", default=".",
                    help="directory (or file) for BENCH_latency.json")
    args = ap.parse_args()
    if args.smoke:
        import os

        os.makedirs(args.out, exist_ok=True)
        smoke(args.out)
    else:
        run()


if __name__ == "__main__":
    main()
