"""Paper Fig. 8: decode latency vs context length, Full-KV vs FIER.

Two measurements:
  1. CPU wall-clock of the jitted decode step at growing cache lengths —
    the *trend* (FIER flattens, full grows linearly) is hardware-agnostic;
  2. the analytic v5e bytes model (decode is HBM-bound): step time ≈
     bytes_touched / 819 GB/s using the exact cache/metadata byte counts —
     this is the paper's 1.2–1.5× claim mapped onto TPU, and matches the
     roofline table's memory term.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import packed_nbytes

from .common import bench_model_cfg, emit, policy_bundle, timeit, train_tiny_lm

HBM_BW = 819e9


def analytic_v5e_speedup(S: int, cfg, budget: int, g: int = 32) -> float:
    """bytes(full)/bytes(fier) per layer at context S (B=1)."""
    Hkv, D = cfg.n_kv_heads, cfg.d_head
    full = 2 * S * Hkv * D * 2
    fier = packed_nbytes(S, Hkv, D, g) + 2 * budget * Hkv * D * 2
    return full / fier


def run():
    cfg, params = train_tiny_lm("lm")
    params = jax.tree.map(jnp.asarray, params)
    B = 4
    budget = 64
    for S in (512, 1024, 2048):
        tok = jnp.zeros((B,), jnp.int32)
        for kind in ("full", "fier"):
            bundle = policy_bundle(cfg, kind, budget, skip=1)
            cache = bundle.init_cache(B, S, S - 2)
            step = jax.jit(bundle.decode_step)
            us = timeit(step, params, tok, cache, reps=5)
            emit(f"decode_latency_{kind}_ctx{S}", us, f"B={B}")
        emit(
            f"decode_latency_v5e_model_ctx{S}", 0.0,
            f"analytic_fullKV_over_FIER={analytic_v5e_speedup(S, cfg, budget):.2f}x",
        )
    # the paper's setting: 32k context, 4k budget, 7B-class GQA dims
    from repro.configs import get_config

    big = get_config("llava-next-mistral-7b")  # mistral-7b backbone
    for S in (8192, 16384, 32768):
        emit(
            f"decode_latency_v5e_model_7b_ctx{S}", 0.0,
            f"analytic_fullKV_over_FIER={analytic_v5e_speedup(S, big, 4096):.2f}x",
        )


def main():
    run()


if __name__ == "__main__":
    main()
