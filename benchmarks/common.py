"""Shared benchmark substrate: tiny trained models (cached across benches),
policy bundles, timing, CSV emission."""
from __future__ import annotations

import dataclasses
import os
import pickle
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.policy import PolicyConfig
from repro.data.passkey import make_passkey_batch
from repro.data.pipeline import make_train_batch
from repro.launch.steps import TrainHParams, init_train_state, make_train_step
from repro.models import build_model

CACHE_DIR = os.environ.get("REPRO_BENCH_CACHE", "/tmp/repro_bench_cache")


def bench_model_cfg(seq: int = 256) -> ModelConfig:
    """Benchmark LM: big enough to learn the tasks, small enough for CPU."""
    return dataclasses.replace(
        reduced_config("olmo-1b"),
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, d_head=32,
        d_ff=256, vocab=512,
    )


def train_tiny_lm(kind: str = "lm", steps: int = 300, seq: int = 256,
                  batch: int = 16, seed: int = 0):
    """Train (or load cached) the benchmark model.  kind: lm | passkey.

    ``REPRO_BENCH_TRAIN_STEPS`` overrides ``steps`` (constrained CI boxes:
    latency/byte benchmarks don't need a converged model, quality
    benchmarks do — leave it unset for those)."""
    steps = int(os.environ.get("REPRO_BENCH_TRAIN_STEPS", steps))
    os.makedirs(CACHE_DIR, exist_ok=True)
    cfg = bench_model_cfg(seq)
    tag = f"{kind}_s{steps}_q{seq}_b{batch}_{seed}"
    path = os.path.join(CACHE_DIR, f"params_{tag}.pkl")
    bundle = build_model(cfg)
    if os.path.exists(path):
        with open(path, "rb") as f:
            return cfg, pickle.load(f)
    hp = TrainHParams(peak_lr=1e-3, warmup=20, total_steps=steps)
    state = init_train_state(bundle, jax.random.PRNGKey(seed), hp)
    step_jit = jax.jit(make_train_step(bundle, hp))
    shape = ShapeConfig("bench", seq, batch, "train")
    for s in range(steps):
        if kind == "passkey":
            # pure passkey curriculum (a 4-layer model needs the focus)
            batch_data, _ = make_passkey_batch(cfg, batch, seq, seed=seed, step=s)
        else:
            batch_data = make_train_batch(cfg, shape, s, seed=seed)
        state, metrics = step_jit(state, batch_data)
        if s % 100 == 0:
            print(f"  [{tag}] step {s}: loss={float(metrics['loss']):.3f}")
    params = jax.tree.map(np.asarray, state["params"])
    with open(path, "wb") as f:
        pickle.dump(params, f)
    return cfg, params


def policy_bundle(cfg, kind: str, budget: int, group: int = 8, page: int = 8,
                  skip: int = 1, pipeline: str = "reference"):
    pol = None if kind == "full" else PolicyConfig(
        kind=kind, budget=budget, group=group, page=page, skip_layers=skip,
        pipeline=pipeline,
    )
    return build_model(cfg, pol)


def score_traffic_bytes(Hq: int, Hkv: int, D: int, *, budget: int, B: int,
                        S: int, group: int = 8, seed: int = 0) -> dict:
    """Materialised score-tensor bytes per *retrieval+attend op* (one
    layer, isolated from the model so skip-layer full attention and
    embedding lookups don't blur the accounting) for every registered
    fier slab pipeline (registry-iterated, not hard-coded).  The
    one-pass pipeline must be exactly zero."""
    from repro.core import quantize as qz
    from repro.core.policy import CacheView, DecodePlan, PolicyConfig, decode_attention, get_backend

    from .flopcount import count_fn_score_bytes

    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    Kc = jax.random.normal(ks[0], (B, S, Hkv, D), jnp.bfloat16)
    Vc = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.bfloat16)
    q = jax.random.normal(ks[2], (B, Hq, D))
    qk = qz.quantize(Kc.astype(jnp.float32), group)
    length = jnp.full((B,), S, jnp.int32)
    pol = PolicyConfig(kind="fier", budget=budget, group=group, skip_layers=0)
    out = {}
    for layout, pipeline in sorted(get_backend("fier").supports):
        if layout != "slab":
            continue  # the paged matrix is gated by paged_score_traffic_bytes
        plan = DecodePlan.build(pol, pipeline=pipeline)
        out[pipeline] = count_fn_score_bytes(
            lambda q, K, V, plan=plan: decode_attention(
                q, CacheView.slab(K, V, qk, length), plan, layer=1
            ),
            S, q, Kc, Vc,
        )
    return out


def emit_score_traffic(Hq: int, Hkv: int, D: int, *, budget: int, B: int,
                       S: int, group: int = 8, check: bool = False) -> dict:
    """Emit (and with ``check=True`` *assert*) the score-byte contract:
    one-pass == 0, two-pass pays ≥ the 2·4·Hq·S f32 write+read floor.
    The single shared gate for bench_latency / bench_load_ratio / CI."""
    sb = score_traffic_bytes(Hq, Hkv, D, budget=budget, B=B, S=S, group=group)
    floor = 2 * 4 * Hq * S * B  # write+read of the f32 [B, Hq, S] scores
    emit(
        f"retrieval_score_bytes_ctx{S}", 0.0,
        " ".join(f"{p}={sb[p]:.0f}" for p in sorted(sb))
        + f" floor_2x4HqS={floor}",
    )
    if check:
        assert sb["one_pass"] == 0.0, sb
        assert sb["two_pass"] >= floor, (sb, floor)
        assert sb["reference"] > 0.0, sb
    return sb


def paged_score_traffic_bytes(Hq: int, Hkv: int, D: int, *, budget: int,
                              B: int, S: int, block_size: int,
                              group: int = 8, seed: int = 0) -> float:
    """Materialised score-tensor bytes of the *paged* one-pass decode op
    (paged retrieval + paged select-and-attend over a block pool).  Must
    be exactly zero — the page-table walk happens in-kernel, so paging
    the cache must not reintroduce any score (or logical-slab) HBM
    round trip."""
    from repro.core import quantize as qz
    from repro.core.policy import CacheView, DecodePlan, PolicyConfig, decode_attention

    from .flopcount import count_fn_score_bytes

    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    Kc = jax.random.normal(ks[0], (B, S, Hkv, D), jnp.bfloat16)
    Vc = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.bfloat16)
    q = jax.random.normal(ks[2], (B, Hq, D))
    qk = qz.quantize(Kc.astype(jnp.float32), group)
    nb = S // block_size
    N = B * nb + 1

    def to_pool(arr):
        pb = arr.shape[1] // nb
        pool = jnp.zeros((N, pb, *arr.shape[2:]), arr.dtype)
        blocks = arr.reshape(B, nb, pb, *arr.shape[2:])
        return pool.at[1:].set(blocks.reshape(B * nb, pb, *arr.shape[2:]))

    table = jnp.arange(1, B * nb + 1, dtype=jnp.int32).reshape(B, nb)
    k_pool, v_pool = to_pool(Kc), to_pool(Vc)
    meta = qz.QuantizedKeys(
        to_pool(qk.codes), to_pool(qk.scale), to_pool(qk.zero), group
    )
    length = jnp.full((B,), S, jnp.int32)
    pol = PolicyConfig(kind="fier", budget=budget, group=group, skip_layers=0,
                       block_size=block_size)
    plan = DecodePlan.build(pol, layout="paged", pipeline="one_pass")
    return count_fn_score_bytes(
        lambda q, kp, vp: decode_attention(
            q, CacheView.paged(kp, vp, meta, table, length), plan, layer=1
        ),
        S, q, k_pool, v_pool,
    )


def emit_paged_score_traffic(Hq: int, Hkv: int, D: int, *, budget: int,
                             B: int, S: int, block_size: int, group: int = 8,
                             check: bool = False) -> float:
    """Emit (and with ``check=True`` assert) the paged one-pass score-byte
    contract: exactly zero materialised score bytes."""
    sb = paged_score_traffic_bytes(
        Hq, Hkv, D, budget=budget, B=B, S=S, block_size=block_size, group=group
    )
    emit(
        f"retrieval_score_bytes_paged_ctx{S}", 0.0,
        f"paged_onepass={sb:.0f} block_size={block_size}",
    )
    if check:
        assert sb == 0.0, sb
    return sb


def timeit(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in µs (after jit warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")
