"""Shared benchmark substrate: tiny trained models (cached across benches),
policy bundles, timing, CSV emission."""
from __future__ import annotations

import dataclasses
import os
import pickle
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.policy import PolicyConfig
from repro.data.passkey import make_passkey_batch
from repro.data.pipeline import make_train_batch
from repro.launch.steps import TrainHParams, init_train_state, make_train_step
from repro.models import build_model

CACHE_DIR = os.environ.get("REPRO_BENCH_CACHE", "/tmp/repro_bench_cache")


def bench_model_cfg(seq: int = 256) -> ModelConfig:
    """Benchmark LM: big enough to learn the tasks, small enough for CPU."""
    return dataclasses.replace(
        reduced_config("olmo-1b"),
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, d_head=32,
        d_ff=256, vocab=512,
    )


def train_tiny_lm(kind: str = "lm", steps: int = 300, seq: int = 256,
                  batch: int = 16, seed: int = 0):
    """Train (or load cached) the benchmark model.  kind: lm | passkey.

    ``REPRO_BENCH_TRAIN_STEPS`` overrides ``steps`` (constrained CI boxes:
    latency/byte benchmarks don't need a converged model, quality
    benchmarks do — leave it unset for those)."""
    steps = int(os.environ.get("REPRO_BENCH_TRAIN_STEPS", steps))
    os.makedirs(CACHE_DIR, exist_ok=True)
    cfg = bench_model_cfg(seq)
    tag = f"{kind}_s{steps}_q{seq}_b{batch}_{seed}"
    path = os.path.join(CACHE_DIR, f"params_{tag}.pkl")
    bundle = build_model(cfg)
    if os.path.exists(path):
        with open(path, "rb") as f:
            return cfg, pickle.load(f)
    hp = TrainHParams(peak_lr=1e-3, warmup=20, total_steps=steps)
    state = init_train_state(bundle, jax.random.PRNGKey(seed), hp)
    step_jit = jax.jit(make_train_step(bundle, hp))
    shape = ShapeConfig("bench", seq, batch, "train")
    for s in range(steps):
        if kind == "passkey":
            # pure passkey curriculum (a 4-layer model needs the focus)
            batch_data, _ = make_passkey_batch(cfg, batch, seq, seed=seed, step=s)
        else:
            batch_data = make_train_batch(cfg, shape, s, seed=seed)
        state, metrics = step_jit(state, batch_data)
        if s % 100 == 0:
            print(f"  [{tag}] step {s}: loss={float(metrics['loss']):.3f}")
    params = jax.tree.map(np.asarray, state["params"])
    with open(path, "wb") as f:
        pickle.dump(params, f)
    return cfg, params


def policy_bundle(cfg, kind: str, budget: int, group: int = 8, page: int = 8,
                  skip: int = 1, fused: bool = False, one_pass: bool = True):
    pol = None if kind == "full" else PolicyConfig(
        kind=kind, budget=budget, group=group, page=page, skip_layers=skip,
        fused=fused, one_pass=one_pass,
    )
    return build_model(cfg, pol)


def score_traffic_bytes(Hq: int, Hkv: int, D: int, *, budget: int, B: int,
                        S: int, group: int = 8, seed: int = 0) -> dict:
    """Materialised score-tensor bytes per *retrieval+attend op* (one
    layer, isolated from the model so skip-layer full attention and
    embedding lookups don't blur the accounting) for the three fier
    pipelines.  The one-pass path must be exactly zero."""
    from repro.core import quantize as qz
    from repro.core import retrieval as rt
    from repro.kernels import ops as kops

    from .flopcount import count_fn_score_bytes

    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    Kc = jax.random.normal(ks[0], (B, S, Hkv, D), jnp.bfloat16)
    Vc = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.bfloat16)
    q = jax.random.normal(ks[2], (B, Hq, D))
    qk = qz.quantize(Kc.astype(jnp.float32), group)
    length = jnp.full((B,), S, jnp.int32)
    return {
        "unfused": count_fn_score_bytes(
            lambda q, K, V: rt.fier_attention_decode(q, K, V, qk, budget, length),
            S, q, Kc, Vc,
        ),
        "two_pass": count_fn_score_bytes(
            lambda q, K, V: kops.fused_fier_attention_decode(
                q, K, V, qk, budget, length, one_pass=False
            ),
            S, q, Kc, Vc,
        ),
        "one_pass": count_fn_score_bytes(
            lambda q, K, V: kops.fused_fier_attention_decode(
                q, K, V, qk, budget, length, one_pass=True
            ),
            S, q, Kc, Vc,
        ),
    }


def emit_score_traffic(Hq: int, Hkv: int, D: int, *, budget: int, B: int,
                       S: int, group: int = 8, check: bool = False) -> dict:
    """Emit (and with ``check=True`` *assert*) the score-byte contract:
    one-pass == 0, two-pass pays ≥ the 2·4·Hq·S f32 write+read floor.
    The single shared gate for bench_latency / bench_load_ratio / CI."""
    sb = score_traffic_bytes(Hq, Hkv, D, budget=budget, B=B, S=S, group=group)
    floor = 2 * 4 * Hq * S * B  # write+read of the f32 [B, Hq, S] scores
    emit(
        f"retrieval_score_bytes_ctx{S}", 0.0,
        f"unfused={sb['unfused']:.0f} two_pass={sb['two_pass']:.0f} "
        f"one_pass={sb['one_pass']:.0f} floor_2x4HqS={floor}",
    )
    if check:
        assert sb["one_pass"] == 0.0, sb
        assert sb["two_pass"] >= floor, (sb, floor)
        assert sb["unfused"] > 0.0, sb
    return sb


def paged_score_traffic_bytes(Hq: int, Hkv: int, D: int, *, budget: int,
                              B: int, S: int, block_size: int,
                              group: int = 8, seed: int = 0) -> float:
    """Materialised score-tensor bytes of the *paged* one-pass decode op
    (paged retrieval + paged select-and-attend over a block pool).  Must
    be exactly zero — the page-table walk happens in-kernel, so paging
    the cache must not reintroduce any score (or logical-slab) HBM
    round trip."""
    from repro.core import quantize as qz
    from repro.kernels import ops as kops

    from .flopcount import count_fn_score_bytes

    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    Kc = jax.random.normal(ks[0], (B, S, Hkv, D), jnp.bfloat16)
    Vc = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.bfloat16)
    q = jax.random.normal(ks[2], (B, Hq, D))
    qk = qz.quantize(Kc.astype(jnp.float32), group)
    nb = S // block_size
    N = B * nb + 1

    def to_pool(arr):
        pb = arr.shape[1] // nb
        pool = jnp.zeros((N, pb, *arr.shape[2:]), arr.dtype)
        blocks = arr.reshape(B, nb, pb, *arr.shape[2:])
        return pool.at[1:].set(blocks.reshape(B * nb, pb, *arr.shape[2:]))

    table = jnp.arange(1, B * nb + 1, dtype=jnp.int32).reshape(B, nb)
    k_pool, v_pool = to_pool(Kc), to_pool(Vc)
    meta = qz.QuantizedKeys(
        to_pool(qk.codes), to_pool(qk.scale), to_pool(qk.zero), group
    )
    length = jnp.full((B,), S, jnp.int32)
    return count_fn_score_bytes(
        lambda q, kp, vp: kops.paged_fused_fier_attention_decode(
            q, kp, vp, meta, table, budget, length
        ),
        S, q, k_pool, v_pool,
    )


def emit_paged_score_traffic(Hq: int, Hkv: int, D: int, *, budget: int,
                             B: int, S: int, block_size: int, group: int = 8,
                             check: bool = False) -> float:
    """Emit (and with ``check=True`` assert) the paged one-pass score-byte
    contract: exactly zero materialised score bytes."""
    sb = paged_score_traffic_bytes(
        Hq, Hkv, D, budget=budget, B=B, S=S, block_size=block_size, group=group
    )
    emit(
        f"retrieval_score_bytes_paged_ctx{S}", 0.0,
        f"paged_one_pass={sb:.0f} block_size={block_size}",
    )
    if check:
        assert sb == 0.0, sb
    return sb


def timeit(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in µs (after jit warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")
