"""Paper Fig. 7 / Tab. 1 proxy: long-context QA quality across budgets.

LongBench needs pretrained instruction models; the in-container proxy is
multi-needle retrieval QA: several (key → digit-sequence) facts are
scattered through filler, the query names one key, and exact-match
accuracy plays the role of F1.  The paper's ordering should reproduce:
FIER ≥ Quest > SLM at every budget, approaching Full-KV by ~12% budget.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit, policy_bundle, train_tiny_lm

SEQ = 256
N_FACTS = 3
N_DIGITS = 3
KEY0 = 20  # fact-key token ids: KEY0..KEY0+N_FACTS


def make_multi_needle(cfg, B, S, *, seed, step):
    from repro.data.pipeline import lm_tokens

    rng = np.random.default_rng(seed * 7919 + step)
    filler = np.asarray(lm_tokens(seed ^ 0xFAC7, step, B, S, cfg.vocab - 32))
    toks = filler[:, :S] + 32
    answers = rng.integers(0, 10, (B, N_FACTS, N_DIGITS))
    tail = N_DIGITS + 2
    qkey = rng.integers(0, N_FACTS, (B,))
    for b in range(B):
        pos = np.sort(rng.choice(
            np.arange(4, S - tail - (N_DIGITS + 2) * N_FACTS - 2),
            N_FACTS, replace=False,
        ))
        for f in range(N_FACTS):
            p = pos[f] + f * (N_DIGITS + 2)
            toks[b, p] = KEY0 + f
            toks[b, p + 1 : p + 1 + N_DIGITS] = answers[b, f]
        toks[b, S - tail] = 12              # QUERY marker
        toks[b, S - tail + 1] = KEY0 + qkey[b]
        toks[b, S - N_DIGITS:] = answers[b, qkey[b]]
    gold = answers[np.arange(B), qkey]
    mask = np.zeros((B, S), np.float32)
    mask[:, S - N_DIGITS - 1 : S - 1] = 1.0
    batch = {
        "tokens": jnp.asarray(toks, jnp.int32),
        "targets": jnp.asarray(np.concatenate([toks[:, 1:], toks[:, :1]], 1), jnp.int32),
        "loss_mask": jnp.asarray(mask),
    }
    return batch, jnp.asarray(gold, jnp.int32)


def train_needle_model(steps=400):
    import os
    import pickle

    from .common import CACHE_DIR, bench_model_cfg
    from repro.launch.steps import TrainHParams, init_train_state, make_train_step
    from repro.models import build_model

    cfg = bench_model_cfg()
    path = os.path.join(CACHE_DIR, f"params_needle_{steps}.pkl")
    os.makedirs(CACHE_DIR, exist_ok=True)
    bundle = build_model(cfg)
    if os.path.exists(path):
        with open(path, "rb") as f:
            return cfg, pickle.load(f)
    hp = TrainHParams(peak_lr=1e-3, warmup=30, total_steps=steps)
    state = init_train_state(bundle, jax.random.PRNGKey(0), hp)
    step_jit = jax.jit(make_train_step(bundle, hp))
    for s in range(steps):
        batch, _ = make_multi_needle(cfg, 16, SEQ, seed=0, step=s)
        state, metrics = step_jit(state, batch)
        if s % 100 == 0:
            print(f"  [needle] step {s}: loss={float(metrics['loss']):.3f}")
    params = jax.tree.map(np.asarray, state["params"])
    with open(path, "wb") as f:
        pickle.dump(params, f)
    return cfg, params


def accuracy(bundle, params, cfg, n_batches=4) -> float:
    hits = total = 0
    prefill = jax.jit(lambda p, b: bundle.prefill(p, b, capacity=SEQ + 8))
    decode = jax.jit(bundle.decode_step)
    for i in range(n_batches):
        batch, gold = make_multi_needle(cfg, 8, SEQ, seed=321, step=i)
        prompt = batch["tokens"][:, : SEQ - N_DIGITS]
        B = prompt.shape[0]
        pre = {"tokens": prompt, "lengths": jnp.full((B,), prompt.shape[1], jnp.int32)}
        logits, cache = prefill(params, pre)
        digs = []
        for _ in range(N_DIGITS):
            tok = jnp.argmax(logits[:, :10], axis=-1).astype(jnp.int32)
            digs.append(tok)
            logits, cache = decode(params, tok, cache)
        got = np.stack([np.asarray(d) for d in digs], 1)
        hits += int((got == np.asarray(gold)).all(1).sum())
        total += B
    return hits / total


def run():
    cfg, params = train_needle_model()
    params = jax.tree.map(jnp.asarray, params)
    for budget in (16, 32, 64):
        for kind in ("full", "fier", "quest", "slm"):
            bundle = policy_bundle(cfg, kind, budget)
            acc = accuracy(bundle, params, cfg)
            emit(f"longbench_proxy_{kind}_b{budget}", 0.0,
                 f"acc={acc:.2f} ctx={SEQ} facts={N_FACTS}")


def main():
    run()


if __name__ == "__main__":
    main()
