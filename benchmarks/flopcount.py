"""Scan-aware jaxpr FLOP counter.

XLA's ``compiled.cost_analysis()`` counts every loop body exactly once
(verified in tests/test_flopcount.py) — useless for scan-over-layers
models.  This counter walks the jaxpr instead, multiplying scan bodies by
their trip count and shard_map bodies by their manual-axis device count,
so the result is the true *global* executed FLOPs (remat recomputation
included, since the post-autodiff jaxpr contains the recomputed ops).

Conventions (matching XLA's cost model):
    dot_general:   2·B·M·N·K
    conv:          2·out_elems·K_spatial·C_in/groups
    elementwise:   1 flop per output element (transcendentals too)
    reductions:    1 flop per input element
Everything else (layout, slicing, gathers) counts 0 flops.
"""
from __future__ import annotations

import math

import jax
import numpy as np

ELEMENTWISE_1 = {
    "add", "sub", "mul", "div", "max", "min", "pow", "rem", "and", "or",
    "xor", "not", "neg", "sign", "floor", "ceil", "round", "abs", "exp",
    "log", "log1p", "expm1", "tanh", "logistic", "sqrt", "rsqrt", "cbrt",
    "sin", "cos", "tan", "erf", "erf_inv", "erfc", "atan2", "square",
    "integer_pow", "select_n", "clamp", "nextafter",
}
REDUCTIONS = {
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "reduce_and",
    "reduce_or", "argmax", "argmin", "reduce_precision", "cumsum",
    "cumlogsumexp", "cummax", "cummin", "cumprod",
}
CALL_PRIMS = {
    "pjit", "closed_call", "core_call", "custom_jvp_call", "custom_vjp_call",
    "custom_vjp_call_jaxpr", "remat", "checkpoint", "remat2", "custom_lin",
}


def _avals_size(avals) -> int:
    return sum(int(np.prod(a.shape)) for a in avals if hasattr(a, "shape"))


def _dot_flops(eqn) -> float:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = (v.aval for v in eqn.invars[:2])
    K = math.prod(lhs.shape[i] for i in lc)
    Bd = math.prod(lhs.shape[i] for i in lb)
    M = math.prod(
        d for i, d in enumerate(lhs.shape) if i not in lc and i not in lb
    )
    N = math.prod(
        d for i, d in enumerate(rhs.shape) if i not in rc and i not in rb
    )
    return 2.0 * Bd * M * N * K


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    groups = eqn.params.get("feature_group_count", 1)
    k_elems = math.prod(rhs.shape[2:]) if len(rhs.shape) > 2 else 1
    cin = rhs.shape[1]
    return 2.0 * math.prod(out.shape) * k_elems * cin / max(groups, 1)


def _subjaxprs(eqn):
    for k in ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr", "body_jaxpr"):
        if k in eqn.params:
            yield eqn.params[k]
    for k in ("branches",):
        if k in eqn.params:
            yield from eqn.params[k]


def _as_jaxpr(j):
    return j.jaxpr if hasattr(j, "jaxpr") else j


def count_jaxpr(jaxpr, scale: float = 1.0) -> float:
    flops = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            flops += scale * _dot_flops(eqn)
        elif name == "conv_general_dilated":
            flops += scale * _conv_flops(eqn)
        elif name == "scan":
            inner = _as_jaxpr(eqn.params["jaxpr"])
            flops += count_jaxpr(inner, scale * eqn.params["length"])
        elif name == "while":
            # we never emit unbounded whiles; count once and flag
            for j in _subjaxprs(eqn):
                flops += count_jaxpr(_as_jaxpr(j), scale)
        elif name == "cond":
            branches = [count_jaxpr(_as_jaxpr(b), scale) for b in eqn.params["branches"]]
            flops += max(branches) if branches else 0.0
        elif name == "shard_map":
            inner = _as_jaxpr(eqn.params["jaxpr"])
            flops += count_jaxpr(inner, scale * _shard_map_device_count(eqn))
        elif name in ELEMENTWISE_1:
            flops += scale * _avals_size([v.aval for v in eqn.outvars])
        elif name in REDUCTIONS or name.startswith("reduce_"):
            flops += scale * _avals_size([v.aval for v in eqn.invars[:1]])
        elif name == "custom_vjp_call" or name in CALL_PRIMS or name.endswith("_call"):
            for j in _subjaxprs(eqn):
                flops += count_jaxpr(_as_jaxpr(j), scale)
        else:
            # layout/data-movement ops: 0 flops; but recurse into any
            # embedded jaxprs (e.g. checkpoint variants)
            for j in _subjaxprs(eqn):
                flops += count_jaxpr(_as_jaxpr(j), scale)
    return flops


def count_fn_flops(fn, *args) -> float:
    """Global FLOPs of ``fn(*args)`` (args may be ShapeDtypeStructs)."""
    closed = jax.make_jaxpr(fn)(*args)
    return count_jaxpr(closed.jaxpr)


# ------------------------------------------------------- XLA cost analysis

def xla_cost_flops(fn, *args) -> float:
    """XLA's own flop count for comparison.  ``Compiled.cost_analysis()``
    returned ``list[dict]`` (one per computation) through jax 0.4.x and a
    bare ``dict`` afterwards — normalise both."""
    ca = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca["flops"])


# ------------------------------------------------------------ gather bytes

GATHER_PRIMS = {"gather", "take", "take_along_axis"}


def _shard_map_device_count(eqn) -> int:
    mesh = eqn.params.get("mesh")
    manual = eqn.params.get("manual_axes", getattr(mesh, "axis_names", ()))
    n = 1
    for a in manual:
        try:
            n *= dict(zip(mesh.axis_names, mesh.axis_sizes))[a]
        except Exception:
            n *= mesh.shape[a]
    return n


def count_gather_bytes(jaxpr, scale: float = 1.0) -> float:
    """Bytes *materialised* by gather ops (output buffers), scan trip
    counts and shard_map device counts applied — the copies a fused
    select-and-attend path eliminates.  Used by bench_latency to show the
    K'/V' gather is gone rather than assert it."""
    total = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in GATHER_PRIMS:
            for v in eqn.outvars:
                a = v.aval
                if hasattr(a, "shape"):
                    total += scale * np.prod(a.shape) * a.dtype.itemsize
        elif name == "scan":
            inner = _as_jaxpr(eqn.params["jaxpr"])
            total += count_gather_bytes(inner, scale * eqn.params["length"])
        elif name == "shard_map":
            inner = _as_jaxpr(eqn.params["jaxpr"])
            total += count_gather_bytes(
                inner, scale * _shard_map_device_count(eqn)
            )
        else:
            for j in _subjaxprs(eqn):
                total += count_gather_bytes(_as_jaxpr(j), scale)
    return total


def count_fn_gather_bytes(fn, *args) -> float:
    closed = jax.make_jaxpr(fn)(*args)
    return count_gather_bytes(closed.jaxpr)


# ------------------------------------------------------------- score bytes

FLOAT_DTYPES = ("float32", "bfloat16", "float16")
_LANE = 128  # kernels' lane-padded scalar outputs ([..., LANE] f32 carries)


def count_score_bytes(jaxpr, seq_len: int, scale: float = 1.0) -> float:
    """Bytes of *materialised* sequence-length score tensors: outputs of
    non-call primitives whose trailing dim equals ``seq_len`` (float
    dtypes, ndim ≥ 2) — the [B, Hq, S] / [B, Hkv, S] approximate-score
    tensors (and their masked/reduced variants) that the unfused decode
    path round-trips through HBM between scoring and selection.  Scan
    trip counts and shard_map device counts are applied, like
    ``count_gather_bytes``.

    ``pallas_call`` is a *leaf*: its HBM outputs are counted (the
    two-pass ``fier_score`` kernel emits a [B·Hkv, rep, S] f32 tensor)
    but its body is not recursed into — in-kernel values live in
    VMEM/VREGs, which is exactly the distinction the one-pass retrieval
    kernel exploits (it must measure **zero**).

    Caveat: the trailing-dim match is positional — pick a ``seq_len``
    that doesn't collide with other model dims (vocab, d_ff) when
    counting a whole decode step.  ``seq_len == 128`` is rejected
    outright: the kernels emit lane-padded f32 scalar carries
    (``[..., LANE=128]`` τ/m/softmax-state outputs) that would be
    miscounted as score tensors.
    """
    assert seq_len != _LANE, (
        "seq_len == 128 collides with the kernels' lane-padded scalar "
        "outputs; measure at a different cache length"
    )

    def shaped_bytes(outvars) -> float:
        total = 0.0
        for v in outvars:
            a = v.aval
            if (
                hasattr(a, "shape")
                and len(a.shape) >= 2
                and a.shape[-1] == seq_len
                and str(a.dtype) in FLOAT_DTYPES
            ):
                total += np.prod(a.shape) * a.dtype.itemsize
        return total

    total = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "scan":
            inner = _as_jaxpr(eqn.params["jaxpr"])
            total += count_score_bytes(inner, seq_len, scale * eqn.params["length"])
        elif name == "shard_map":
            inner = _as_jaxpr(eqn.params["jaxpr"])
            total += count_score_bytes(
                inner, seq_len, scale * _shard_map_device_count(eqn)
            )
        elif name == "pallas_call":
            total += scale * shaped_bytes(eqn.outvars)
        else:
            subs = list(_subjaxprs(eqn))
            if subs:  # call-like: count inside only (outvars alias inner)
                for j in subs:
                    total += count_score_bytes(_as_jaxpr(j), seq_len, scale)
            else:
                total += scale * shaped_bytes(eqn.outvars)
    return total


def count_fn_score_bytes(fn, seq_len: int, *args) -> float:
    """Materialised score-tensor bytes of ``fn(*args)`` at cache length
    ``seq_len`` (args may be ShapeDtypeStructs)."""
    closed = jax.make_jaxpr(fn)(*args)
    return count_score_bytes(closed.jaxpr, seq_len)
