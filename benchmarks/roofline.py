"""Roofline analysis driver (§Roofline of EXPERIMENTS.md).

Per (arch × shape) cell on the single-pod 16×16 mesh, derives the three
roofline terms for TPU v5e:

    compute term    = FLOPs_per_chip   / 197e12        (bf16 peak)
    memory term     = HBM_bytes_per_chip / 819e9
    collective term = wire_bytes_per_chip / 50e9        (per-link ICI)

Sources (methodology in EXPERIMENTS.md §Roofline — XLA's cost_analysis
counts loop bodies once, so three measurements combine):
  * FLOPs: scan-aware jaxpr counter (benchmarks/flopcount.py), exact.
  * HBM bytes + collective wire bytes: two depth-extrapolation compiles
    (depth 1 and 2, layer scan unrolled, microbatches=1) →
    total = c1 + (L−1)·(c2 − c1); fusion-aware because they come from the
    partitioned, optimised HLO.
  * memory fit: the full-depth scanned compile (results/dryrun_1pod.jsonl).

Usage:
    PYTHONPATH=src python -m benchmarks.roofline --out results/roofline.jsonl
    PYTHONPATH=src python -m benchmarks.roofline --arch olmo-1b --shape decode_32k
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link (ICI)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(args: list[str], timeout: int = 3600) -> dict:
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--json"] + args
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=REPO)
    if r.returncode != 0:
        raise RuntimeError(f"dryrun {' '.join(args)} failed:\n{r.stderr[-2000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def _depths_for(arch: str, kind: str) -> dict:
    from repro.configs import get_config

    cfg = get_config(arch)
    if cfg.family == "hybrid":
        # depth counts superblocks; real model ≈ 13.5 superblocks (81 layers
        # / attn_every=6, the 3-layer tail ≈ half a superblock — documented)
        return {"unit_layers": cfg.n_layers / cfg.attn_every}
    if cfg.family == "encdec" and kind != "decode":
        return {"unit_layers": cfg.n_layers, "enc_layers": cfg.n_enc_layers}
    return {"unit_layers": cfg.n_layers}


def ideal_bytes_per_chip(arch: str, shape_name: str, policy: str,
                         budget: int, devices: int = 256) -> float:
    """Analytic lower bound on HBM bytes per chip for one step — what a
    perfect implementation must still move.

    decode: params/devices + per-layer FIER metadata scan (Eq. 8 load
    ratio) + top-k K'/V' gather + front-layer full K/V + cache append.
    prefill: params + one read/write of activations + KV-cache write.
    train: 3 param passes (fwd read, bwd read, grad write) + opt state RW
    + remat activation traffic (2 reads/write per layer boundary).
    """
    from repro.configs import SHAPES, get_config
    from repro.core.quantize import packed_nbytes

    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    B, S = sh.global_batch, sh.seq_len
    pbytes = cfg.param_count() * (2 if cfg.param_dtype == "bfloat16" else 4)
    if sh.kind == "decode":
        per_chip = pbytes / devices
        if cfg.family == "ssm":
            # recurrent state read+write
            st = cfg.n_layers * B * cfg.n_ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
            return per_chip + 2 * st / devices
        Hkv, D = cfg.n_kv_heads, cfg.d_head
        if cfg.family == "hybrid":
            n_attn = cfg.n_layers // cfg.attn_every
            st = cfg.n_layers * B * cfg.n_ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
            per_chip += 2 * st / devices
        else:
            n_attn = cfg.n_layers
        skip = 0 if policy == "full" else 2
        rest = max(n_attn - skip, 0)
        if policy == "fier":
            scan = packed_nbytes(S, Hkv, D, 32)          # Eq. 8 bytes
            gather = 2 * budget * Hkv * D * 2            # K' + V' bf16
            per_layer = scan + gather
        else:                                            # full baseline
            per_layer = 2 * S * Hkv * D * 2
        full_layer = 2 * S * Hkv * D * 2
        total = B * (rest * per_layer + skip * full_layer)
        return per_chip + total / devices
    # train / prefill: parameter passes + boundary activations + cache write
    act = cfg.n_layers * B * S * cfg.d_model * 2
    passes = 3 if sh.kind == "train" else 1
    opt = 2 * pbytes * 2 if sh.kind == "train" else 0  # fp32 moments RW ≈ 4×bf16
    kvw = (
        0 if cfg.family == "ssm" or sh.kind == "train"
        else 2 * cfg.n_layers * B * S * cfg.n_kv_heads * cfg.d_head * 2
    )
    return (passes * pbytes + opt) / devices + (3 * act + kvw) / devices


def analyse_cell(arch: str, shape: str, *, policy: str = "fier",
                 budget: int = 4096, full_record: dict | None = None,
                 dist_mode: str = "local") -> dict:
    base = ["--arch", arch, "--shape", shape, "--policy", policy,
            "--budget", str(budget), "--dist-mode", dist_mode]
    flops_rec = _run_dryrun(base + ["--flops-only"])
    kind = flops_rec["kind"]
    dd = _depths_for(arch, kind)
    L = dd["unit_layers"]

    c1 = _run_dryrun(base + ["--cost-depth", "1"])
    c2 = _run_dryrun(base + ["--cost-depth", "2"])
    recs = {"c1": c1, "c2": c2}
    if "enc_layers" in dd:
        c21 = _run_dryrun(base + ["--cost-depth", "2", "--cost-depth-enc", "1"])
        recs["c21"] = c21

    def extrap(key, sub=None):
        def get(r):
            return r[key] if sub is None else r[key][sub]

        if "enc_layers" in dd:
            per_dec = get(recs["c2"]) - get(recs["c21"])
            per_enc = get(recs["c21"]) - get(recs["c1"])
            return (get(recs["c1"]) + (L - 1) * per_dec
                    + (dd["enc_layers"] - 1) * per_enc)
        per_layer = get(recs["c2"]) - get(recs["c1"])
        return get(recs["c1"]) + (L - 1) * per_layer

    bytes_pc = max(extrap("bytes_accessed"), 0.0)
    coll_pc = max(extrap("collectives", "total"), 0.0)
    # microbatch scaling: the cost compiles run microbatches=1 over the full
    # global batch, which already equals one optimizer step's work — no scale
    flops_pc = flops_rec["jaxpr_flops_per_device"]

    t_comp = flops_pc / PEAK_FLOPS
    t_mem = bytes_pc / HBM_BW
    t_coll = coll_pc / LINK_BW
    dominant = max(
        (("compute", t_comp), ("memory", t_mem), ("collective", t_coll)),
        key=lambda kv: kv[1],
    )[0]
    model_pc = flops_rec["model_flops_per_device"]
    out = {
        "arch": arch, "shape": shape, "kind": kind, "policy": policy,
        "budget": budget, "dist_mode": dist_mode,
        "flops_per_chip": flops_pc,
        "hbm_bytes_per_chip": bytes_pc,
        "collective_bytes_per_chip": coll_pc,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_step_s": max(t_comp, t_mem, t_coll),
        "model_flops_per_chip": model_pc,
        "useful_flops_ratio": model_pc / flops_pc if flops_pc else 0.0,
        "roofline_fraction": (
            model_pc / PEAK_FLOPS / max(t_comp, t_mem, t_coll)
            if max(t_comp, t_mem, t_coll) > 0 else 0.0
        ),
        "collective_detail": {
            k: extrap("collectives", k)
            for k in ("all-reduce", "all-gather", "reduce-scatter",
                      "all-to-all", "collective-permute")
        },
    }
    if full_record:
        out["memory_fit"] = {
            "args_gb": full_record["argument_size_in_bytes"] / 1e9,
            "temp_gb": full_record["temp_size_in_bytes"] / 1e9,
            "fits_16gb": (full_record["argument_size_in_bytes"]
                          + full_record["temp_size_in_bytes"]) < 16e9,
        }
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--policy", default="fier")
    ap.add_argument("--budget", type=int, default=4096)
    ap.add_argument("--dist-mode", default="local")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--full-records", default="results/dryrun_1pod.jsonl")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    full = {}
    if os.path.exists(args.full_records):
        for line in open(args.full_records):
            r = json.loads(line)
            if not r.get("multi_pod"):
                full[(r["arch"], r["shape"])] = r

    cells = []
    if args.all:
        from repro.configs import ARCHS, shape_cells

        for arch in ARCHS:
            for shape in shape_cells(arch):
                cells.append((arch, shape))
    else:
        cells = [(args.arch, args.shape)]

    sink = open(args.out, "a") if args.out else None
    failures = []
    for arch, shape in cells:
        try:
            rec = analyse_cell(arch, shape, policy=args.policy,
                               budget=args.budget, dist_mode=args.dist_mode,
                               full_record=full.get((arch, shape)))
            print(f"{arch:26s} {shape:12s} [{rec['kind']:7s}] "
                  f"comp={rec['t_compute_s']*1e3:8.3f}ms "
                  f"mem={rec['t_memory_s']*1e3:8.3f}ms "
                  f"coll={rec['t_collective_s']*1e3:8.3f}ms "
                  f"dom={rec['dominant']:10s} "
                  f"roofline={rec['roofline_fraction']*100:5.1f}%")
            if sink:
                sink.write(json.dumps(rec) + "\n")
                sink.flush()
        except Exception as e:
            print(f"FAIL {arch} × {shape}: {e}")
            failures.append((arch, shape))
    if sink:
        sink.close()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
