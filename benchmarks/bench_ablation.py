"""Paper Tab. 3 ablation: token granularity vs quantized attention.

Grid over the selection mechanisms at matched/varied load ratios:
  Quest p∈{8,16,32} (box bounds), Quest-p16-w/quant (page scores from the
  mean 1-bit token score — the paper's hybrid row), FIER g∈{8,32,64}.
Metric: top-k recall against full-precision attention on trained-model
keys + passkey accuracy for the main pairing.  Load ratios printed beside
each row (Eqs. 4/8).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize as qz, quest, retrieval as rt

from .common import emit, train_tiny_lm
from .bench_recall import _recall, model_keys


def run():
    q, K = model_keys(S=256)
    S, Hq = K.shape[1], q.shape[1]
    exact = np.asarray(rt.exact_scores(q, K))
    kk = 32

    for g in (8, 32, 64):
        s = np.asarray(rt.approx_scores(q, qz.quantize(K, g)))
        sel = np.argsort(-s, axis=-1)[..., :kk]
        emit(f"ablation_fier_g{g}", 0.0,
             f"recall@{kk}={_recall(sel, exact, kk):.3f} "
             f"load_ratio={qz.load_ratio(g):.4f}")

    for p in (8, 16, 32):
        meta = quest.build_page_meta(K, p)
        ps = np.asarray(quest.page_scores(q, meta))
        sel = []
        for b in range(ps.shape[0]):
            row = []
            for h in range(Hq):
                pages = np.argsort(-ps[b, h])[: max(kk // p, 1)]
                ids = np.concatenate([np.arange(x * p, (x + 1) * p) for x in pages])
                row.append(ids[:kk] if len(ids) >= kk
                           else np.pad(ids, (0, kk - len(ids))))
            sel.append(row)
        emit(f"ablation_quest_p{p}", 0.0,
             f"recall@{kk}={_recall(np.asarray(sel), exact, kk):.3f} "
             f"load_ratio={2 / p:.4f}")

    # Quest-p16-w/quant: page scores from mean 1-bit token scores
    qk = qz.quantize(K, 32)
    ps = np.asarray(quest.quant_page_scores(q, qk, 16))
    sel = []
    for b in range(ps.shape[0]):
        row = []
        for h in range(Hq):
            pages = np.argsort(-ps[b, h])[: max(kk // 16, 1)]
            ids = np.concatenate([np.arange(x * 16, (x + 1) * 16) for x in pages])
            row.append(ids[:kk])
        sel.append(row)
    emit("ablation_quest_p16_wquant", 0.0,
         f"recall@{kk}={_recall(np.asarray(sel), exact, kk):.3f} load_ratio=0.1250")


def main():
    run()


if __name__ == "__main__":
    main()
