"""Benchmark persistence: one JSON schema shared by every bench script.

Each bench writes ``BENCH_<name>.json`` — git SHA, timestamp, config, and
a flat metric list — so the perf trajectory is recorded per commit and
``tools/check_bench_regression.py`` can diff a PR's numbers against the
committed baseline at the repo root.

Metric contract:
  * ``better``: "lower" | "higher" | "info".  Info metrics are recorded
    but never gated (wall-clock on shared CI runners is info; the
    deterministic virtual-time / byte-count metrics are gated).
  * ``gate``: only gated metrics participate in the regression check
    (±20% latency / −10% throughput tolerances, see the tool).

The schema is deliberately flat (no nested suites): a bench that measures
two configurations prefixes the metric names (``chunked_…`` / ``mono_…``).
"""
from __future__ import annotations

import json
import os
import subprocess
import time

SCHEMA_VERSION = 1


def metric(
    name: str,
    value: float,
    *,
    unit: str = "",
    better: str = "info",
    gate: bool = False,
) -> dict:
    """One metric row.  ``better`` ∈ {lower, higher, info}; only
    ``gate=True`` rows are regression-checked."""
    if better not in ("lower", "higher", "info"):
        raise ValueError(f"better must be lower|higher|info, got {better!r}")
    if gate and better == "info":
        raise ValueError(f"metric {name!r}: gated metrics need a direction")
    return {
        "name": name,
        "value": float(value),
        "unit": unit,
        "better": better,
        "gate": bool(gate),
    }


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, check=True,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip()
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def write_bench_json(path: str, bench: str, config: dict, metrics: list[dict]) -> dict:
    """Write the bench document to ``path`` (a file, or a directory that
    gets ``BENCH_<bench>.json`` appended).  Returns the document."""
    names = [m["name"] for m in metrics]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate metric names: {sorted(names)}")
    try:
        import jax

        jax_version = jax.__version__
    except Exception:
        jax_version = "unknown"
    doc = {
        "schema": SCHEMA_VERSION,
        "bench": bench,
        "git_sha": _git_sha(),
        "created_unix": int(time.time()),
        "jax_version": jax_version,
        "config": config,
        "metrics": metrics,
    }
    if os.path.isdir(path):
        path = os.path.join(path, f"BENCH_{bench}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}")
    return doc


def load_bench_json(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != SCHEMA_VERSION:
        raise ValueError(
            f"{path}: schema {doc.get('schema')} != {SCHEMA_VERSION}"
        )
    return doc
