"""Paper Eqs. 4 & 8: selection-phase cache load ratios, measured exactly.

FIER: (1 + 32/g)/16 of the bf16 key bytes.  Quest: 2/L.  The benchmark
measures the actual bytes of the metadata structures this repo builds and
asserts they equal the formulas (this is also where the paper's
"g=32 ↔ p=16 both 1/8" pairing is verified).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize as qz, quest

from .common import emit


def run():
    B, S, H, D = 1, 4096, 4, 64
    K = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D))
    full_bytes = S * H * D * 2  # bf16 keys per batch row

    for g in (32, 64, 128, 256):
        qk = qz.quantize(K, g)
        measured = (
            qk.codes.nbytes + qk.scale.nbytes + qk.zero.nbytes
        ) / B
        formula = qz.load_ratio(g)
        assert abs(measured / full_bytes - formula) < 1e-9, (g, measured)
        emit(f"load_ratio_fier_g{g}", 0.0,
             f"measured={measured / full_bytes:.6f} formula={formula:.6f}")

    for p in (8, 16, 32):
        meta = quest.build_page_meta(K, p)
        measured = (meta.kmax.nbytes + meta.kmin.nbytes) / B
        formula = 2.0 / p
        assert abs(measured / full_bytes - formula) < 1e-9, (p, measured)
        emit(f"load_ratio_quest_p{p}", 0.0,
             f"measured={measured / full_bytes:.6f} formula={formula:.6f}")

    # the paper's fairness pairing
    assert abs(qz.load_ratio(32) - 2.0 / 16) < 1e-12
    emit("load_ratio_pairing_g32_p16", 0.0, "both=0.125")


def main():
    run()


if __name__ == "__main__":
    main()
