"""Paper Eqs. 4 & 8: selection-phase cache load ratios, measured exactly —
plus the attend-phase bytes the fused select-and-attend path removes.

FIER: (1 + 32/g)/16 of the bf16 key bytes.  Quest: 2/L.  The benchmark
measures the actual bytes of the metadata structures this repo builds and
asserts they equal the formulas (this is also where the paper's
"g=32 ↔ p=16 both 1/8" pairing is verified).

Attend phase: the unfused pipeline *materialises* K'/V' (2·budget·Hkv·D
bf16 written to HBM, then read back by attention → 4·budget·Hkv·D·2 bytes
of extra traffic on top of the budget rows read from the slabs); the
fused kernel reads the selected rows straight from the slabs.  Measured
here from the jaxpr (gather output bytes), not asserted.

Selection phase, fused: the *one-pass* retrieval kernel also removes the
f32 score-tensor round trip between scoring and selection — the two-pass
pipeline writes [B·Hkv·rep, S] f32 out of the score kernel and reads it
back through the reduce + threshold-select stages (≥ 2·4·Hq·S bytes),
the one-pass kernel keeps every block's scores in VREGs.  Measured from
the jaxpr (``count_score_bytes``) and asserted exactly zero.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize as qz, quest

from .common import bench_model_cfg, emit, emit_paged_score_traffic, emit_score_traffic
from .flopcount import count_fn_gather_bytes


def run():
    B, S, H, D = 1, 4096, 4, 64
    K = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D))
    full_bytes = S * H * D * 2  # bf16 keys per batch row

    for g in (32, 64, 128, 256):
        qk = qz.quantize(K, g)
        measured = (
            qk.codes.nbytes + qk.scale.nbytes + qk.zero.nbytes
        ) / B
        formula = qz.load_ratio(g)
        assert abs(measured / full_bytes - formula) < 1e-9, (g, measured)
        emit(f"load_ratio_fier_g{g}", 0.0,
             f"measured={measured / full_bytes:.6f} formula={formula:.6f}")

    for p in (8, 16, 32):
        meta = quest.build_page_meta(K, p)
        measured = (meta.kmax.nbytes + meta.kmin.nbytes) / B
        formula = 2.0 / p
        assert abs(measured / full_bytes - formula) < 1e-9, (p, measured)
        emit(f"load_ratio_quest_p{p}", 0.0,
             f"measured={measured / full_bytes:.6f} formula={formula:.6f}")

    # the paper's fairness pairing
    assert abs(qz.load_ratio(32) - 2.0 / 16) < 1e-12
    emit("load_ratio_pairing_g32_p16", 0.0, "both=0.125")

    # ------------------------------------------- attend-phase gather bytes
    from repro.core.policy import CacheView, DecodePlan, PolicyConfig, decode_attention

    Bq, Sq, Hkv, Hq, Dq, g = 1, 2048, 4, 8, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    Kc = jax.random.normal(ks[0], (Bq, Sq, Hkv, Dq), jnp.bfloat16)
    Vc = jax.random.normal(ks[1], (Bq, Sq, Hkv, Dq), jnp.bfloat16)
    q = jax.random.normal(ks[2], (Bq, Hq, Dq))
    qk = qz.quantize(Kc.astype(jnp.float32), g)
    length = jnp.full((Bq,), Sq, jnp.int32)
    budget = 256

    pol = PolicyConfig(kind="fier", budget=budget, group=g, skip_layers=0)

    def decode_with(pipeline):
        plan = DecodePlan.build(pol, pipeline=pipeline)
        return lambda q, K, V: decode_attention(
            q, CacheView.slab(K, V, qk, length), plan, layer=1
        )

    unfused = count_fn_gather_bytes(decode_with("reference"), q, Kc, Vc)
    fused = count_fn_gather_bytes(decode_with("one_pass"), q, Kc, Vc)
    copies = 2 * budget * Hkv * Dq * 2 * Bq  # K'+V' bf16, materialised once
    assert unfused >= copies, (unfused, copies)
    emit(
        "attend_gather_bytes_fused_vs_unfused", 0.0,
        f"reference={unfused:.0f} onepass={fused:.0f} kv_copies={copies} "
        f"eliminated={unfused - fused:.0f}",
    )

    # --------------------------------------------- select-phase score bytes
    # shared gate (same helper the CI bench-smoke asserts through): the
    # one-pass kernel materialises zero score bytes, the two-pass pipeline
    # pays at least the f32 [B, Hq, S] write+read floor
    emit_score_traffic(Hq, Hkv, Dq, budget=budget, B=Bq, S=Sq, group=g,
                       check=True)
    emit_paged_score_traffic(Hq, Hkv, Dq, budget=budget, B=Bq, S=Sq,
                             block_size=64, group=g, check=True)


def pool_utilization():
    """Paged-pool utilization under a real continuous-batching workload:
    blocks resident / blocks allocated, peak, prefix-sharing and CoW
    counters, and the slab-vs-pool HBM provisioning ratio.  The pool is
    sized below the summed worst-case contexts, so the run also exercises
    preemption — utilization is what the slab layout can never report
    above `resident/worst-case`."""
    import jax

    from repro.core.policy import PolicyConfig
    from repro.models import build_model
    from repro.serving import ContinuousScheduler, Engine, Request

    cfg = bench_model_cfg()
    capacity, bs, n_slots, pool_blocks = 64, 8, 4, 11
    pol = PolicyConfig(
        kind="fier", budget=16, group=8, skip_layers=1,
        pipeline="one_pass", layout="paged", block_size=bs,
        pool_blocks=pool_blocks,
    )
    bundle = build_model(cfg, pol)
    params = bundle.init(jax.random.PRNGKey(0))
    eng = Engine(bundle, n_slots=n_slots, capacity=capacity)
    sched = ContinuousScheduler(eng, params, pad_prompt_to=16)
    reqs = [
        Request(rid=i, tokens=[3 + i, 4 + i, 5 + i, 6 + i], max_new=20)
        for i in range(6)
    ]
    # snapshot utilization every step via the occupancy hook
    peak_util = 0.0

    orig_decode = eng.decode

    def spy(*a, **kw):
        nonlocal peak_util
        peak_util = max(peak_util, eng.allocator.utilization())
        return orig_decode(*a, **kw)

    eng.decode = spy
    sched.run(reqs)
    st = eng.pool_stats()
    worst_case_blocks = n_slots * (capacity // bs)
    emit(
        "paged_pool_utilization", 0.0,
        f"peak_resident={st['peak_in_use']}/{st['blocks_allocated']} "
        f"peak_util={peak_util:.2f} preemptions={sched.preemptions} "
        f"prefix_block_hits={st['prefix_block_hits']} cow={st['cow_copies']} "
        f"slab_equivalent_blocks={worst_case_blocks} "
        f"hbm_ratio_vs_slab={st['blocks_allocated'] / worst_case_blocks:.3f}",
    )


def main():
    run()
    pool_utilization()


if __name__ == "__main__":
    main()
