"""Paper Eqs. 4 & 8: selection-phase cache load ratios, measured exactly —
plus the attend-phase bytes the fused select-and-attend path removes.

FIER: (1 + 32/g)/16 of the bf16 key bytes.  Quest: 2/L.  The benchmark
measures the actual bytes of the metadata structures this repo builds and
asserts they equal the formulas (this is also where the paper's
"g=32 ↔ p=16 both 1/8" pairing is verified).

Attend phase: the unfused pipeline *materialises* K'/V' (2·budget·Hkv·D
bf16 written to HBM, then read back by attention → 4·budget·Hkv·D·2 bytes
of extra traffic on top of the budget rows read from the slabs); the
fused kernel reads the selected rows straight from the slabs.  Measured
here from the jaxpr (gather output bytes), not asserted.

Selection phase, fused: the *one-pass* retrieval kernel also removes the
f32 score-tensor round trip between scoring and selection — the two-pass
pipeline writes [B·Hkv·rep, S] f32 out of the score kernel and reads it
back through the reduce + threshold-select stages (≥ 2·4·Hq·S bytes),
the one-pass kernel keeps every block's scores in VREGs.  Measured from
the jaxpr (``count_score_bytes``) and asserted exactly zero.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize as qz, quest
from repro.core import retrieval as rt

from .common import emit, emit_score_traffic
from .flopcount import count_fn_gather_bytes


def run():
    B, S, H, D = 1, 4096, 4, 64
    K = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D))
    full_bytes = S * H * D * 2  # bf16 keys per batch row

    for g in (32, 64, 128, 256):
        qk = qz.quantize(K, g)
        measured = (
            qk.codes.nbytes + qk.scale.nbytes + qk.zero.nbytes
        ) / B
        formula = qz.load_ratio(g)
        assert abs(measured / full_bytes - formula) < 1e-9, (g, measured)
        emit(f"load_ratio_fier_g{g}", 0.0,
             f"measured={measured / full_bytes:.6f} formula={formula:.6f}")

    for p in (8, 16, 32):
        meta = quest.build_page_meta(K, p)
        measured = (meta.kmax.nbytes + meta.kmin.nbytes) / B
        formula = 2.0 / p
        assert abs(measured / full_bytes - formula) < 1e-9, (p, measured)
        emit(f"load_ratio_quest_p{p}", 0.0,
             f"measured={measured / full_bytes:.6f} formula={formula:.6f}")

    # the paper's fairness pairing
    assert abs(qz.load_ratio(32) - 2.0 / 16) < 1e-12
    emit("load_ratio_pairing_g32_p16", 0.0, "both=0.125")

    # ------------------------------------------- attend-phase gather bytes
    from repro.kernels import ops as kops

    Bq, Sq, Hkv, Hq, Dq, g = 1, 2048, 4, 8, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    Kc = jax.random.normal(ks[0], (Bq, Sq, Hkv, Dq), jnp.bfloat16)
    Vc = jax.random.normal(ks[1], (Bq, Sq, Hkv, Dq), jnp.bfloat16)
    q = jax.random.normal(ks[2], (Bq, Hq, Dq))
    qk = qz.quantize(Kc.astype(jnp.float32), g)
    length = jnp.full((Bq,), Sq, jnp.int32)
    budget = 256

    unfused = count_fn_gather_bytes(
        lambda q, K, V: rt.fier_attention_decode(q, K, V, qk, budget, length),
        q, Kc, Vc,
    )
    fused = count_fn_gather_bytes(
        lambda q, K, V: kops.fused_fier_attention_decode(
            q, K, V, qk, budget, length
        ),
        q, Kc, Vc,
    )
    copies = 2 * budget * Hkv * Dq * 2 * Bq  # K'+V' bf16, materialised once
    assert unfused >= copies, (unfused, copies)
    emit(
        "attend_gather_bytes_fused_vs_unfused", 0.0,
        f"unfused={unfused:.0f} fused={fused:.0f} kv_copies={copies} "
        f"eliminated={unfused - fused:.0f}",
    )

    # --------------------------------------------- select-phase score bytes
    # shared gate (same helper the CI bench-smoke asserts through): the
    # one-pass kernel materialises zero score bytes, the two-pass pipeline
    # pays at least the f32 [B, Hq, S] write+read floor
    emit_score_traffic(Hq, Hkv, Dq, budget=budget, B=Bq, S=Sq, group=g,
                       check=True)


def main():
    run()


if __name__ == "__main__":
    main()
