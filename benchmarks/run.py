"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Mapping (DESIGN.md §7):
    bench_load_ratio      → Eqs. 4/8 (exact byte accounting)
    bench_recall          → Figs. 3 & 6 (1-bit top-k recall vs Quest)
    bench_ablation        → Tab. 3 (granularity × quantized scoring)
    bench_latency         → Fig. 8 (decode latency trend + v5e byte model)
    bench_pg19            → Fig. 5 (ppl vs context under budgets; proxy)
    bench_passkey         → Tab. 2 (passkey accuracy vs budget)
    bench_longbench_proxy → Fig. 7 / Tab. 1 (multi-needle QA; proxy)

Roofline (§Roofline/§Perf) is separate: ``python -m benchmarks.roofline``
(needs the 512-device dry-run environment).
"""
from __future__ import annotations

import sys
import time
import traceback

MODULES = [
    "bench_load_ratio",
    "bench_recall",
    "bench_ablation",
    "bench_latency",
    "bench_pg19",
    "bench_passkey",
    "bench_longbench_proxy",
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = []
    for name in MODULES:
        t0 = time.time()
        print(f"# --- {name} ---")
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            mod.run()
            print(f"# {name} done in {time.time()-t0:.1f}s")
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
