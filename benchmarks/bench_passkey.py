"""Paper Tab. 2: passkey retrieval accuracy under cache budgets.

A tiny model is trained in-container on the passkey task (hidden 5-digit
key + filler + query), then evaluated with each policy at budgets that are
small fractions of the context.  The paper's structural claim reproduces:
eviction (SLM) collapses — the passkey tokens are outside sink+recent —
while retrieval (FIER/Quest) recovers them, FIER at finer granularity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.passkey import N_DIGITS, make_passkey_batch

from .common import emit, policy_bundle, train_tiny_lm

SEQ = 256


def accuracy(bundle, params, cfg, n_batches: int = 4, depth=None) -> float:
    hits, total = 0, 0
    prefill = jax.jit(lambda p, b: bundle.prefill(p, b, capacity=SEQ + 8))
    decode = jax.jit(bundle.decode_step)
    for i in range(n_batches):
        batch, answers = make_passkey_batch(cfg, 8, SEQ, seed=999, step=i,
                                            depth=depth)
        prompt = batch["tokens"][:, : SEQ - N_DIGITS]
        B = prompt.shape[0]
        pre = {"tokens": prompt, "lengths": jnp.full((B,), prompt.shape[1], jnp.int32)}
        logits, cache = prefill(params, pre)
        digs = []
        for _ in range(N_DIGITS):
            tok = jnp.argmax(logits[:, :10], axis=-1).astype(jnp.int32)  # digit head
            digs.append(tok)
            logits, cache = decode(params, tok, cache)
        got = np.stack([np.asarray(d) for d in digs], 1)
        hits += int((got == np.asarray(answers)).all(axis=1).sum())
        total += B
    return hits / total


def run():
    cfg, params = train_tiny_lm("passkey", steps=600)
    params = jax.tree.map(jnp.asarray, params)
    for budget in (16, 32, 64):
        for kind in ("full", "fier", "quest", "slm"):
            bundle = policy_bundle(cfg, kind, budget)
            acc = accuracy(bundle, params, cfg)
            emit(f"passkey_{kind}_b{budget}", 0.0, f"acc={acc:.2f} ctx={SEQ}")


def main():
    run()


if __name__ == "__main__":
    main()
