"""build(cfg) → ModelBundle dispatch over architecture families."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.core.policy import PolicyConfig

from . import encdec, hybrid, mamba2, transformer
from .attention import DistConfig
from .transformer import ModelBundle


def build_model(
    cfg: ModelConfig,
    pol: PolicyConfig | None = None,
    dcfg: DistConfig | None = None,
    *,
    remat: bool = True,
    max_positions: int | None = None,
) -> ModelBundle:
    if (
        pol is not None
        and pol.layout == "paged"
        and cfg.family not in ("dense", "moe", "vlm")
    ):
        raise ValueError(
            f"paged KV cache is only supported for transformer families, "
            f"not {cfg.family!r}"
        )
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.build(cfg, pol, dcfg, remat=remat)
    if cfg.family == "ssm":
        return mamba2.build(cfg, dcfg, remat=remat)
    if cfg.family == "hybrid":
        return hybrid.build(cfg, pol, dcfg, remat=remat)
    if cfg.family == "encdec":
        return encdec.build(cfg, pol, dcfg, remat=remat, max_positions=max_positions)
    raise ValueError(f"unknown family {cfg.family!r}")
