"""GQA attention block: train/prefill (flash) and decode (policy-dispatched).

The decode path is where FIER lives: the per-layer cache slice carries the
packed 1-bit side-car, and attention is dispatched through
``repro.core.policy`` — or, when the cache is sequence-sharded across mesh
axes, through the distributed LSE-merge path (``repro.core.distributed``)
inside a ``shard_map``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.core import distributed as dist
from repro.core import policy as core_policy
from repro.core.policy import CacheView, DecodePlan, PolicyConfig
from repro.kvcache import cache as kvcache
from repro.kvcache import paged as kvcache_paged
from repro.kvcache import sharded as kvcache_sharded

from .layers import apply_rope, flash_attention, init_linear, wuse


@dataclasses.dataclass(frozen=True)
class DistConfig:
    """How the model runs across the mesh.

    seq_axes: mesh axes the KV-cache *sequence* dim is sharded over at
    decode; empty tuple → single-shard policy path.  mode: 'local' |
    'exact' (see core.distributed).  ep_axis: mesh axis for MoE expert
    parallelism in train/prefill (shard_map path); fsdp_axes: axes expert
    weights are FSDP-stored over (gathered inside the EP body).
    """

    mesh: Any = None
    seq_axes: tuple[str, ...] = ()
    mode: str = "local"
    batch_axes: tuple[str, ...] = ()
    ep_axis: str | None = None
    fsdp_axes: tuple[str, ...] = ()
    # mesh sharding spec for the *paged* pool (kvcache.sharded.ShardSpec):
    # TP over KV heads × DP over slots.  Threaded into DecodePlan.build so
    # the plan carries it into decode_attention; None = single device
    shard: Any = None


def seq_shard_constraint(h: jax.Array, dcfg: "DistConfig | None") -> jax.Array:
    """Megatron-style sequence-parallel activation sharding: the residual
    stream between layers is sharded [batch→batch_axes, seq→'model'].

    This is what the layer-scan remat *saves*, so it bounds activation-
    checkpoint memory at L·B·S·d/(data·model) instead of /(data) — the
    difference between 155 GB and ~10 GB per device on qwen3-moe train_4k
    (EXPERIMENTS.md §Perf iteration 2).  XLA inserts the all-gather before
    attention and the reduce-scatter after, exactly as in Megatron-SP.
    """
    if dcfg is None or dcfg.mesh is None or "model" not in dcfg.mesh.axis_names:
        return h
    if "model" in dcfg.batch_axes:  # fsdp_pure: batch spans 'model' already
        return h
    if h.ndim < 2 or h.shape[1] % dcfg.mesh.shape["model"]:
        return h
    bd = tuple(dcfg.batch_axes) if dcfg.batch_axes else None
    from jax.sharding import NamedSharding

    return jax.lax.with_sharding_constraint(
        h, NamedSharding(dcfg.mesh, P(bd, "model"))
    )


def init_attention(rng: jax.Array, cfg: ModelConfig, d_in: int | None = None) -> dict:
    d = d_in if d_in is not None else cfg.d_model
    kq, kk, kv, ko = jax.random.split(rng, 4)
    p = {
        "wq": init_linear(kq, d, cfg.n_heads * cfg.d_head),
        "wk": init_linear(kk, d, cfg.n_kv_heads * cfg.d_head),
        "wv": init_linear(kv, d, cfg.n_kv_heads * cfg.d_head),
        "wo": init_linear(ko, cfg.n_heads * cfg.d_head, cfg.d_model),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * cfg.d_head,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * cfg.d_head,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * cfg.d_head,), jnp.float32)
    return p


def _proj(x: jax.Array, w: jax.Array, b: jax.Array | None) -> jax.Array:
    y = x @ wuse(w, -1).astype(x.dtype)
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def qkv_proj(
    p: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array | None
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x: [B, S, d] → q [B,S,Hq,D], k/v [B,S,Hkv,D] (RoPE applied)."""
    B, S, _ = x.shape
    q = _proj(x, p["wq"], p.get("bq")).reshape(B, S, cfg.n_heads, cfg.d_head)
    k = _proj(x, p["wk"], p.get("bk")).reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    v = _proj(x, p["wv"], p.get("bv")).reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    if cfg.use_rope:
        if positions is None:
            positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_train(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    causal: bool = True,
    kv_x: jax.Array | None = None,
    positions: jax.Array | None = None,
    block_k: int = 512,
) -> jax.Array:
    """Full (flash) attention for train/prefill; ``kv_x`` → cross-attention."""
    B, S, _ = x.shape
    if kv_x is None:
        q, k, v = qkv_proj(p, x, cfg, positions)
    else:
        Sk = kv_x.shape[1]
        q = _proj(x, p["wq"], p.get("bq")).reshape(B, S, cfg.n_heads, cfg.d_head)
        k = _proj(kv_x, p["wk"], p.get("bk")).reshape(B, Sk, cfg.n_kv_heads, cfg.d_head)
        v = _proj(kv_x, p["wv"], p.get("bv")).reshape(B, Sk, cfg.n_kv_heads, cfg.d_head)
    o = flash_attention(q, k, v, causal=causal, block_k=block_k)
    return o.reshape(B, S, cfg.n_heads * cfg.d_head) @ wuse(p["wo"], 0).astype(x.dtype)


# ------------------------------------------------------------------- decode

def decode_self_attention(
    p: dict,
    x: jax.Array,
    layer_cache: dict,
    length: jax.Array,
    cfg: ModelConfig,
    plan: DecodePlan | PolicyConfig,
    dcfg: DistConfig | None = None,
    *,
    update_meta: bool = True,
    block_table: jax.Array | None = None,
) -> tuple[jax.Array, dict]:
    """One-token decode self-attention with cache append + plan-dispatched
    attention.

    x: [B, 1, d]; layer_cache: {k, v[, meta]} (single layer, no L axis);
    length: [B] current lengths (the new token is written at ``length``).
    ``plan`` is the resolved ``DecodePlan`` (a bare ``PolicyConfig`` is
    wrapped via ``DecodePlan.build`` as a convenience).  Returns
    (out [B, 1, d], updated layer_cache).

    ``block_table`` [B, n_btab] switches the layer to the *paged* cache:
    layer_cache holds block-pool slabs [N, bs, Hkv, D] (+ paged side-car)
    shared by all requests, the append and the metadata refresh write
    through the table, and attention dispatches through a paged
    ``CacheView`` to the page-table-aware kernels.

    When the cache is sequence-sharded (dcfg.seq_axes), the append, the
    metadata refresh AND the attention all run inside one shard_map — a
    traced-index dynamic_update_slice along a GSPMD-sharded dim would
    otherwise all-gather the whole slab (observed: 2.13 GB/chip/layer on
    the first dry-run; EXPERIMENTS.md §Perf iteration 1).  This path is
    its own reference implementation (``core.distributed`` LSE merge):
    the single-shard kernel pipelines never run under GSPMD.
    """
    if isinstance(plan, PolicyConfig):
        plan = DecodePlan.build(plan)
    pol = plan.policy
    B = x.shape[0]
    q, k_new, v_new = qkv_proj(p, x, cfg, positions=length[:, None])
    qh = q.reshape(B, cfg.n_heads, cfg.d_head)
    meta = layer_cache.get("meta")

    if block_table is not None:
        if dcfg is not None and dcfg.seq_axes:
            raise ValueError(
                "paged KV cache + sequence-sharded decode is not supported; "
                "shard the paged pool over the mesh instead "
                "(Engine.build(mesh=...) → kvcache.sharded)"
            )
        spec = getattr(plan, "shard", None)
        if spec is not None:
            out, k_pool, v_pool, meta = kvcache_sharded.sharded_paged_decode_step(
                qh, k_new, v_new, layer_cache["k"], layer_cache["v"], meta,
                block_table, length, pol, plan, spec, update_meta=update_meta,
            )
        else:
            k_pool, v_pool = kvcache_paged.paged_append_kv(
                layer_cache["k"], layer_cache["v"], k_new, v_new,
                block_table, length,
            )
            if meta is not None and update_meta:
                meta = kvcache_paged.paged_append_token_metadata(
                    meta, k_pool, block_table, length, pol
                )
            view = CacheView.paged(k_pool, v_pool, meta, block_table, length + 1)
            out = core_policy.decode_attention(
                qh, view, plan, layer=pol.skip_layers
            )
        new_cache = dict(layer_cache, k=k_pool, v=v_pool)
        if meta is not None:
            new_cache["meta"] = meta
        y = out.reshape(B, 1, cfg.n_heads * cfg.d_head) @ wuse(p["wo"], 0).astype(x.dtype)
        return y, new_cache

    if dcfg is not None and dcfg.seq_axes:
        out, k_slab, v_slab, meta = _sharded_decode_step(
            qh, k_new, v_new, layer_cache["k"], layer_cache["v"], meta,
            length, cfg, pol, dcfg,
        )
    else:
        k_slab, v_slab = kvcache.append_kv(
            layer_cache["k"], layer_cache["v"], k_new, v_new, length
        )
        if meta is not None and update_meta:
            meta = kvcache.append_token_metadata(meta, k_slab, length, pol)
        view = CacheView.slab(k_slab, v_slab, meta, length + 1)
        out = core_policy.decode_attention(
            qh, view, plan, layer=pol.skip_layers
        )
    new_cache = dict(layer_cache, k=k_slab, v=v_slab)
    if meta is not None:
        new_cache["meta"] = meta
    y = out.reshape(B, 1, cfg.n_heads * cfg.d_head) @ wuse(p["wo"], 0).astype(x.dtype)
    return y, new_cache


def _sharded_decode_step(
    q: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    K: jax.Array,
    V: jax.Array,
    meta: Any,
    length: jax.Array,
    cfg: ModelConfig,
    pol: PolicyConfig,
    dcfg: DistConfig,
):
    """Sequence-sharded decode: shard-local append + metadata refresh +
    distributed FIER (or full) attention with LSE merge.  The only
    collective is the O(Hq·D) psum of partial attention outputs (plus the
    small candidate all-gather in mode='exact')."""
    mesh = dcfg.mesh
    axes = dcfg.seq_axes
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    bspec = tuple(dcfg.batch_axes) if dcfg.batch_axes else None

    kv_spec = P(bspec, axes)
    q_spec = P(bspec)
    S = K.shape[1]
    S_loc = S // n_shards
    g = pol.group if pol.kind == "fier" else 0

    def body(q_l, kn_l, vn_l, K_l, V_l, meta_l, len_l):
        idx = jnp.int32(0)
        mul = 1
        for a in reversed(axes):
            idx = idx + jax.lax.axis_index(a) * mul
            mul *= mesh.shape[a]
        shard_start = idx * S_loc

        # ---- shard-local append: only the owning shard commits the write.
        # The select happens on the 1-row update value, never on the slab
        # (a slab-wide where() copies the whole cache per layer per token,
        # and XLA:CPU additionally promotes it to f32 — §Perf iteration 6).
        rel = len_l - shard_start                       # [B]
        owns = (rel >= 0) & (rel < S_loc)
        wpos = jnp.clip(rel, 0, S_loc - 1)
        read_row = jax.vmap(
            lambda c, i: jax.lax.dynamic_slice_in_dim(c, i, 1, axis=0)
        )
        upd = jax.vmap(
            lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, axis=0)
        )
        ow = owns[:, None, None, None]
        kw = jnp.where(ow, kn_l.astype(K_l.dtype), read_row(K_l, wpos))
        vw = jnp.where(ow, vn_l.astype(V_l.dtype), read_row(V_l, wpos))
        K2 = upd(K_l, kw, wpos)
        V2 = upd(V_l, vw, wpos)

        # ---- shard-local metadata refresh (group containing the write)
        meta2 = meta_l
        if meta_l is not None and pol.kind == "fier":
            meta2 = kvcache.append_token_metadata(
                meta_l, K2, wpos, pol, commit_mask=owns
            )

        new_len = len_l + 1
        if pol.kind == "fier" and meta2 is not None:
            out = dist.fier_decode_sharded(
                q_l, K2, V2, meta2, pol.budget, new_len,
                axis=axes, shard_start=shard_start, n_shards=n_shards,
                group_reduce=pol.group_reduce, mode=dcfg.mode,
            )
        else:
            out = dist.full_decode_sharded(
                q_l, K2, V2, new_len, axis=axes, shard_start=shard_start
            )
        return out, K2, V2, meta2

    meta_spec = jax.tree.map(lambda _: kv_spec, meta)
    f = shard_map(
        body,
        mesh=mesh,
        in_specs=(q_spec, q_spec, q_spec, kv_spec, kv_spec, meta_spec, q_spec),
        out_specs=(q_spec, kv_spec, kv_spec, meta_spec),
        check_vma=False,
    )
    return f(q, k_new, v_new, K, V, meta, length)
