"""Zamba2-style hybrid: Mamba2 backbone + weight-shared attention blocks.

Every ``attn_every`` Mamba2 layers, ONE shared (single weight copy)
attention block is applied.  Per the Zamba2 design the shared block reads
``concat(hidden, original_embedding)`` (width 2·d_model); we route that
concat through the attention path (q/k/v projections from 2d) while the
block's MLP consumes the post-attention hidden (width d) — recorded as a
simplification in DESIGN.md §2.

Each *application point* has its own KV cache (weights shared, activations
not), so the model has n_apps = n_layers // attn_every attention caches —
the only KV caches in the model, and exactly where FIER applies
(DESIGN.md §5).  ``pol.skip_layers`` is ignored here (the first shared
block already sits ``attn_every`` layers deep).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, padded_vocab
from repro.core.policy import DecodePlan, PolicyConfig, build_metadata
from repro.kvcache import cache as kvcache

from . import attention as attn
from .layers import apply_norm, init_embedding, init_mlp, init_norm, mlp_apply, rms_norm, wuse
from .mamba2 import (
    init_mamba_block,
    init_mamba_state,
    mamba_block_decode,
    mamba_block_train,
)
from .transformer import ModelBundle, _chunked_ce, _masked_logits
from .tuning import maybe_scan


def _n_apps(cfg: ModelConfig) -> tuple[int, int]:
    n_apps = cfg.n_layers // cfg.attn_every
    tail = cfg.n_layers - n_apps * cfg.attn_every
    return n_apps, tail


def init_shared_block(rng: jax.Array, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(rng)
    return {
        "norm1": init_norm(cfg.norm, 2 * cfg.d_model),
        "attn": attn.init_attention(k1, cfg, d_in=2 * cfg.d_model),
        "norm2": init_norm(cfg.norm, cfg.d_model),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act),
    }


def shared_block_train(h, x0, sp, cfg):
    xin = jnp.concatenate([h, x0], axis=-1)
    a = attn.attention_train(sp["attn"], apply_norm(xin, sp["norm1"], cfg.norm), cfg)
    h = h + a
    return h + mlp_apply(apply_norm(h, sp["norm2"], cfg.norm), sp["mlp"], cfg.act)


def build(
    cfg: ModelConfig,
    pol: PolicyConfig | None = None,
    dcfg: attn.DistConfig | None = None,
    *,
    remat: bool = True,
    loss_chunk: int = 1024,
) -> ModelBundle:
    pol = pol or PolicyConfig(kind="full")
    plan = DecodePlan.build(pol)
    Vp = padded_vocab(cfg)
    cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    n_apps, tail = _n_apps(cfg)
    E = cfg.attn_every

    def init(rng):
        ke, km, kt, ks = jax.random.split(rng, 4)
        main = jax.vmap(lambda r: init_mamba_block(r, cfg))(
            jax.random.split(km, n_apps * E)
        )
        main = jax.tree.map(lambda a: a.reshape(n_apps, E, *a.shape[1:]), main)
        params = {
            "embed": init_embedding(ke, Vp, cfg.d_model),
            "mamba": main,
            "shared": init_shared_block(ks, cfg),
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        }
        if tail:
            params["mamba_tail"] = jax.vmap(lambda r: init_mamba_block(r, cfg))(
                jax.random.split(kt, tail)
            )
        return params

    # ---------------------------------------------------------------- train
    def _fwd_train(params, h):
        x0 = h

        def super_fn(hc, lp6):
            def m_fn(hm, lp):
                return mamba_block_train(hm, lp, cfg), None

            hc, _ = jax.lax.scan(m_fn, hc, lp6)
            hc = shared_block_train(hc, x0, params["shared"], cfg)
            return attn.seq_shard_constraint(hc, dcfg), None

        body = super_fn
        if remat:
            body = jax.checkpoint(
                super_fn, policy=jax.checkpoint_policies.nothing_saveable
            )
        h, _ = maybe_scan(body, h, params["mamba"])
        if tail:
            def m_fn(hm, lp):
                return mamba_block_train(hm, lp, cfg), None

            h, _ = maybe_scan(m_fn, h, params["mamba_tail"])
        return rms_norm(h, params["final_norm"])

    def train_loss(params, batch):
        h = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cdt)
        h = attn.seq_shard_constraint(h, dcfg)  # §Perf iteration 11
        h = _fwd_train(params, h)
        loss, n = _chunked_ce(
            h, params["embed"].T, batch["targets"], batch["loss_mask"], cfg.vocab,
            Vp, loss_chunk,
        )
        return loss, {"loss": loss, "moe_aux": jnp.float32(0.0), "tokens": n}

    # -------------------------------------------------------------- prefill
    def prefill(params, batch, capacity: int | None = None):
        lengths = batch["lengths"]
        h = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cdt)
        h = attn.seq_shard_constraint(h, dcfg)  # §Perf iteration 11
        B, S, _ = h.shape
        cap = capacity if capacity is not None else S
        x0 = h
        valid = kvcache.valid_mask(S, lengths)

        def mamba_prefill_layer(hc, lp):
            return _mamba_prefill_step(hc, lp, cfg, lengths, valid)

        def super_fn(hc, lp6):
            hc, mstates = jax.lax.scan(mamba_prefill_layer, hc, lp6)
            # shared attention with cache capture
            sp = params["shared"]
            xin = jnp.concatenate([hc, x0], axis=-1)
            xn = apply_norm(xin, sp["norm1"], cfg.norm)
            q, k, v = attn.qkv_proj(sp["attn"], xn, cfg, positions=None)
            o = attn.flash_attention(q, k, v, causal=True, bias_mask=valid)
            o = o.reshape(B, S, cfg.n_heads * cfg.d_head) @ sp["attn"]["wo"].astype(hc.dtype)
            hc = hc + o
            hc = hc + mlp_apply(apply_norm(hc, sp["norm2"], cfg.norm), sp["mlp"], cfg.act)
            hc = attn.seq_shard_constraint(hc, dcfg)
            pad = ((0, 0), (0, cap - S), (0, 0), (0, 0))
            return hc, (
                mstates,
                jnp.pad(k.astype(jnp.bfloat16), pad),
                jnp.pad(v.astype(jnp.bfloat16), pad),
            )

        h, (mstates, K, V) = maybe_scan(super_fn, h, params["mamba"])
        tail_states = None
        if tail:
            h, tail_states = maybe_scan(mamba_prefill_layer, h, params["mamba_tail"])
        h = rms_norm(h, params["final_norm"])
        attn_cache = {"k": K, "v": V}
        if pol.kind in ("fier", "quest"):
            attn_cache["meta"] = jax.vmap(lambda Kl: build_metadata(Kl, pol))(K)
        cache = {
            "mamba": mstates,
            "attn": attn_cache,
            "length": lengths,
        }
        if tail:
            cache["mamba_tail"] = tail_states
        last = jnp.take_along_axis(h, (lengths - 1)[:, None, None], axis=1)[:, 0]
        return _masked_logits(last, params["embed"].T, cfg.vocab, Vp), cache

    # --------------------------------------------------------------- decode
    def decode_step(params, token, cache):
        length = cache["length"]
        x = jnp.take(params["embed"], token, axis=0)[:, None, :].astype(cdt)
        x0 = x
        sp = params["shared"]

        def super_fn(hc, xs):
            lp6, mstate, ac = xs

            def m_fn(hm, inner):
                lp, st = inner
                return mamba_block_decode(hm, lp, st, cfg)

            hc, mstate = jax.lax.scan(m_fn, hc, (lp6, mstate))
            xin = jnp.concatenate([hc, x0], axis=-1)
            o, ac = attn.decode_self_attention(
                sp["attn"], apply_norm(xin, sp["norm1"], cfg.norm), ac, length,
                cfg, plan, dcfg,
            )
            hc = hc + o
            hc = hc + mlp_apply(apply_norm(hc, sp["norm2"], cfg.norm), sp["mlp"], cfg.act)
            return hc, (mstate, ac)

        h, (mstates, attn_cache) = maybe_scan(
            super_fn, x, (params["mamba"], cache["mamba"], cache["attn"])
        )
        new_cache = dict(cache, mamba=mstates, attn=attn_cache, length=length + 1)
        if tail:
            def m_fn(hm, inner):
                lp, st = inner
                return mamba_block_decode(hm, lp, st, cfg)

            h, tail_states = maybe_scan(
                m_fn, h, (params["mamba_tail"], cache["mamba_tail"])
            )
            new_cache["mamba_tail"] = tail_states
        h = rms_norm(h, params["final_norm"])[:, 0]
        return _masked_logits(h, params["embed"].T, cfg.vocab, Vp), new_cache

    def init_cache(B, capacity, length):
        st = init_mamba_state(B, cfg)
        mstates = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_apps, E) + a.shape), st
        )
        cache = {
            "mamba": mstates,
            "attn": kvcache.init_layer_cache(
                n_apps, B, capacity, cfg.n_kv_heads, cfg.d_head,
                pol if pol.kind != "full" else None,
            ),
            "length": jnp.full((B,), length, jnp.int32),
        }
        if tail:
            cache["mamba_tail"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (tail,) + a.shape), st
            )
        return cache

    return ModelBundle(
        cfg=cfg, init=init, train_loss=train_loss, prefill=prefill,
        decode_step=decode_step, init_cache=init_cache,
        param_count=cfg.param_count, policy=pol, plan=plan,
    )


def _mamba_prefill_step(hc, lp, cfg, lengths, valid):
    """One Mamba2 layer forward over the full sequence + final-state capture
    (shared between hybrid prefill scans)."""
    from .mamba2 import _causal_conv, _split_proj, ssd_chunked

    B, S, _ = hc.shape
    di, N, H, Pd = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    xn = rms_norm(hc, lp["pre_norm"])
    z, xBC, dt_raw = _split_proj(xn @ wuse(lp["in_proj"], -1).astype(xn.dtype), cfg)
    xBC_c = _causal_conv(xBC, lp["conv_w"].astype(xn.dtype), lp["conv_b"])
    xs = xBC_c[..., :di].reshape(B, S, H, Pd).astype(jnp.float32)
    Bm = xBC_c[..., di : di + N].astype(jnp.float32)
    Cm = xBC_c[..., di + N :].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])
    dt = dt * valid[:, :, None]
    A = -jnp.exp(lp["A_log"])
    y, h_last = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + lp["D"][None, None, :, None] * xs
    y = y.reshape(B, S, di).astype(hc.dtype)
    y = rms_norm(y * jax.nn.silu(z), lp["norm_w"])
    hc = hc + y @ wuse(lp["out_proj"], 0).astype(hc.dtype)
    K = cfg.conv_kernel
    tail = jax.vmap(
        lambda xb, ln: jax.lax.dynamic_slice_in_dim(
            xb, jnp.maximum(ln - (K - 1), 0), K - 1, axis=0
        )
    )(xBC, lengths)
    return hc, {"conv": tail.astype(jnp.bfloat16), "ssm": h_last}
