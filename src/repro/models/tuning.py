"""Trace-time tuning knobs (perf iterations + cost-mode compiles).

``scan_layers=False`` replaces the layer lax.scan with a Python loop —
used by the roofline depth-extrapolation compiles, where XLA's
cost_analysis must see every layer (it counts loop bodies exactly once;
verified in tests/test_flopcount.py).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any

import jax


@dataclasses.dataclass
class Tuning:
    scan_layers: bool = True
    flash_block_k: int = 512
    flash_block_q: int = 512


_ACTIVE = Tuning()


def get() -> Tuning:
    return _ACTIVE


@contextlib.contextmanager
def tuned(**kw):
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = dataclasses.replace(prev, **kw)
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = prev


def maybe_scan(body, init, xs, length: int | None = None):
    """lax.scan or an unrolled Python loop, per the active Tuning.

    xs: pytree with leading axis L (or None with ``length``).
    Returns (carry, stacked_ys) like lax.scan.
    """
    if _ACTIVE.scan_layers:
        return jax.lax.scan(body, init, xs, length=length)
    import jax.numpy as jnp

    L = length if length is not None else jax.tree.leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(L):
        sl = jax.tree.map(lambda a: a[i], xs) if xs is not None else None
        carry, y = body(carry, sl)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys
