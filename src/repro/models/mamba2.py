"""Mamba2 (SSD — state-space duality) blocks, attention-free LM.

Implements the chunked SSD algorithm (intra-chunk quadratic attention-like
term + inter-chunk state recurrence via ``lax.scan``) for train/prefill and
the O(1)-state recurrent step for decode.  FIER is inapplicable here (no KV
cache — DESIGN.md §5); decode state is already constant-size.

Block: in_proj → causal depthwise conv (x,B,C) → SSD → gated RMSNorm →
out_proj, with D skip and dt softplus discretisation.  ngroups = 1.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, padded_vocab
from repro.kvcache.cache import valid_mask as kvcache_valid

from .attention import seq_shard_constraint
from .layers import init_embedding, init_linear, rms_norm, wuse
from .tuning import maybe_scan
from .transformer import ModelBundle, _chunked_ce, _masked_logits


def conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state


def init_mamba_block(rng: jax.Array, cfg: ModelConfig) -> dict:
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    return {
        "in_proj": init_linear(k1, d, 2 * di + 2 * N + H),
        "conv_w": jax.random.normal(k2, (cfg.conv_kernel, conv_dim(cfg)), jnp.float32)
        * (cfg.conv_kernel**-0.5),
        "conv_b": jnp.zeros((conv_dim(cfg),), jnp.float32),
        "A_log": jnp.log(
            jax.random.uniform(k3, (H,), jnp.float32, minval=1.0, maxval=16.0)
        ),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(0.01)) * jnp.ones((H,), jnp.float32),
        "norm_w": jnp.ones((di,), jnp.float32),
        "out_proj": init_linear(k4, di, d),
        "pre_norm": jnp.ones((d,), jnp.float32),
    }


def _split_proj(z_all: jax.Array, cfg: ModelConfig):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    z = z_all[..., :di]
    xBC = z_all[..., di : 2 * di + 2 * N]
    dt = z_all[..., 2 * di + 2 * N :]
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over [B, S, Ch], kernel [K, Ch]."""
    K = w.shape[0]
    xp = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + xBC.shape[1], :] * w[i][None, None, :] for i in range(K)
    )
    return jax.nn.silu(out + b[None, None, :])


def ssd_chunked(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    chunk: int,
    h0: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x [B,S,H,P], dt [B,S,H] (post-softplus), A [H] (negative),
    Bm/Cm [B,S,N] (ngroups=1) → (y [B,S,H,P], h_last [B,H,P,N]).
    """
    B_, S, H, Pd = x.shape
    N = Bm.shape[-1]
    c = min(chunk, S)
    while S % c:
        c //= 2
    nc = S // c
    xc = x.reshape(B_, nc, c, H, Pd)
    dtc = dt.reshape(B_, nc, c, H)
    Bc = Bm.reshape(B_, nc, c, N)
    Cc = Cm.reshape(B_, nc, c, N)

    dA = dtc * A[None, None, None, :]                     # [B,nc,c,H] (≤0)
    cum = jnp.cumsum(dA, axis=2)                          # inclusive
    # intra-chunk: y[t] += Σ_{s≤t} exp(cum_t − cum_s)·dt_s·(C_t·B_s)·x_s
    G = jnp.einsum("bztn,bzsn->bzts", Cc, Bc)             # [B,nc,c,c]
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,c,c,H]
    tri = jnp.tril(jnp.ones((c, c), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    M = G[..., None] * L * dtc[:, :, None, :, :]          # dt at source s
    y_intra = jnp.einsum("bztsh,bzshp->bzthp", M, xc)
    # chunk-final states and inter-chunk recurrence
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)       # [B,nc,c,H]
    S_z = jnp.einsum("bzsh,bzsn,bzshp->bzhpn", decay_to_end * dtc, Bc, xc)
    chunk_decay = jnp.exp(dA.sum(axis=2))                 # [B,nc,H]

    def scan_fn(h, inp):
        S_i, dec_i = inp                                  # [B,H,P,N], [B,H]
        h_new = h * dec_i[..., None, None] + S_i
        return h_new, h                                   # emit state *before* chunk

    init = h0 if h0 is not None else jnp.zeros((B_, H, Pd, N), jnp.float32)
    h_last, h_prev = jax.lax.scan(
        scan_fn, init, (jnp.moveaxis(S_z, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)                   # [B,nc,H,P,N]
    y_inter = jnp.einsum("bztn,bzhpn->bzthp", Cc, h_prev) * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(B_, S, H, Pd)
    return y, h_last


def mamba_block_train(
    h: jax.Array, p: dict, cfg: ModelConfig
) -> jax.Array:
    """Pre-norm residual Mamba2 block over a full sequence."""
    B, S, d = h.shape
    H, Pd, N, di = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.d_inner
    xn = rms_norm(h, p["pre_norm"])
    z, xBC, dt_raw = _split_proj(xn @ wuse(p["in_proj"], -1).astype(xn.dtype), cfg)
    xBC = _causal_conv(xBC, p["conv_w"].astype(xn.dtype), p["conv_b"])
    xs = xBC[..., :di].reshape(B, S, H, Pd).astype(jnp.float32)
    Bm = xBC[..., di : di + N].astype(jnp.float32)
    Cm = xBC[..., di + N :].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + p["D"][None, None, :, None] * xs
    y = y.reshape(B, S, di).astype(h.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"])
    return h + y @ wuse(p["out_proj"], 0).astype(h.dtype)


def mamba_block_decode(
    h: jax.Array, p: dict, state: dict, cfg: ModelConfig
) -> tuple[jax.Array, dict]:
    """One-token recurrent step.  state: {conv [B,K-1,Ch], ssm [B,H,P,N]}."""
    B = h.shape[0]
    H, Pd, N, di = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.d_inner
    xn = rms_norm(h, p["pre_norm"])
    z, xBC, dt_raw = _split_proj(xn @ p["in_proj"].astype(xn.dtype), cfg)
    xBC = xBC[:, 0]                                        # [B,Ch]
    # conv ring buffer
    window = jnp.concatenate([state["conv"], xBC[:, None]], axis=1)  # [B,K,Ch]
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), p["conv_w"])
    xBC = jax.nn.silu(conv_out + p["conv_b"]).astype(h.dtype)
    new_conv = window[:, 1:]
    xs = xBC[:, :di].reshape(B, H, Pd).astype(jnp.float32)
    Bm = xBC[:, di : di + N].astype(jnp.float32)
    Cm = xBC[:, di + N :].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"])
    dec = jnp.exp(dt * A)                                  # [B,H]
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt, Bm, xs)
    h_new = state["ssm"] * dec[..., None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm, h_new) + p["D"][None, :, None] * xs
    y = y.reshape(B, 1, di).astype(h.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"])
    out = h + y @ wuse(p["out_proj"], 0).astype(h.dtype)
    return out, {"conv": new_conv, "ssm": h_new}


def init_mamba_state(B: int, cfg: ModelConfig) -> dict:
    return {
        "conv": jnp.zeros((B, cfg.conv_kernel - 1, conv_dim(cfg)), jnp.bfloat16),
        "ssm": jnp.zeros(
            (B, cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        ),
    }


# ----------------------------------------------------------------- LM build

def build(cfg: ModelConfig, dcfg=None, *, remat: bool = True, loss_chunk: int = 1024) -> ModelBundle:
    Vp = padded_vocab(cfg)
    cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32

    def init(rng):
        ke, kl = jax.random.split(rng)
        layers = jax.vmap(lambda r: init_mamba_block(r, cfg))(
            jax.random.split(kl, cfg.n_layers)
        )
        return {
            "embed": init_embedding(ke, Vp, cfg.d_model),
            "layers": layers,
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        }

    def _fwd(params, h):
        # keep the seq-parallel constraint even though SSD is
        # sequence-mixing: measured WITHOUT it the train collective term
        # jumps 1.54 s → 12.4 s (GSPMD replicates the stream instead) —
        # §Perf iteration 10, hypothesis refuted and reverted
        body = lambda hc, lp: (
            seq_shard_constraint(mamba_block_train(hc, lp, cfg), dcfg), None)
        if remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        h, _ = maybe_scan(body, h, params["layers"])
        return rms_norm(h, params["final_norm"])

    def train_loss(params, batch):
        h = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cdt)
        h = seq_shard_constraint(h, dcfg)  # §Perf iteration 11
        h = _fwd(params, h)
        loss, n = _chunked_ce(
            h, params["embed"].T, batch["targets"], batch["loss_mask"], cfg.vocab,
            Vp, loss_chunk,
        )
        return loss, {"loss": loss, "moe_aux": jnp.float32(0.0), "tokens": n}

    def prefill(params, batch, capacity: int | None = None,
                uniform_full: bool = False):
        """Sequential-state prefill: run the chunked scan, keep final states
        (``capacity`` unused — SSM state is O(1)).  ``uniform_full`` (static):
        every row uses its full length — enables the static conv-tail slice."""
        lengths = batch["lengths"]
        h = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cdt)
        # pin the stream's sharding right after the vocab-sharded embedding
        # gather — otherwise GSPMD propagates a batch-replicated layout
        # through every layer (measured: 1.15 GB f32 activation all-reduce
        # per layer on prefill_32k; §Perf iteration 11)
        h = seq_shard_constraint(h, dcfg)
        B, S, _ = h.shape
        valid = kvcache_valid(S, lengths)  # [B,S]

        def layer_fn(hc, lp):
            # recompute per-layer final state via block train pass
            xn = rms_norm(hc, lp["pre_norm"])
            z, xBC, dt_raw = _split_proj(xn @ lp["in_proj"].astype(xn.dtype), cfg)
            xBC_c = _causal_conv(xBC, lp["conv_w"].astype(xn.dtype), lp["conv_b"])
            di, N, H, Pd = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
            xs = xBC_c[..., :di].reshape(B, S, H, Pd).astype(jnp.float32)
            Bm = xBC_c[..., di : di + N].astype(jnp.float32)
            Cm = xBC_c[..., di + N :].astype(jnp.float32)
            dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])
            # padded positions must not advance the state: dt→0 there makes
            # decay=1 and update=0, so h_last is exactly the state at `length`
            dt = dt * valid[:, :, None]
            A = -jnp.exp(lp["A_log"])
            y, h_last = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk)
            y = y + lp["D"][None, None, :, None] * xs
            y = y.reshape(B, S, di).astype(hc.dtype)
            y = rms_norm(y * jax.nn.silu(z), lp["norm_w"])
            hc = seq_shard_constraint(
                hc + y @ wuse(lp["out_proj"], 0).astype(hc.dtype), dcfg
            )
            # conv state = raw (pre-conv) inputs at each sequence's last K-1
            # valid positions.  Uniform-length batches (the serving/dry-run
            # common case) take the static slice: the per-sequence traced
            # gather forces GSPMD to replicate the whole activation across
            # the batch axis (§Perf iteration 11).
            K = cfg.conv_kernel
            if uniform_full:
                tail = xBC[:, S - (K - 1):]
            else:
                tail = jax.vmap(
                    lambda xb, ln: jax.lax.dynamic_slice_in_dim(
                        xb, jnp.maximum(ln - (K - 1), 0), K - 1, axis=0
                    )
                )(xBC, lengths)
            return hc, {"conv": tail.astype(jnp.bfloat16), "ssm": h_last}

        h, states = maybe_scan(layer_fn, h, params["layers"])
        h = rms_norm(h, params["final_norm"])
        last = jnp.take_along_axis(h, (lengths - 1)[:, None, None], axis=1)[:, 0]
        logits = _masked_logits(last, params["embed"].T, cfg.vocab, Vp)
        return logits, {"layers": states, "length": lengths}

    def decode_step(params, token, cache):
        x = jnp.take(params["embed"], token, axis=0)[:, None, :].astype(cdt)

        def body(hc, xs):
            lp, st = xs
            out, st2 = mamba_block_decode(hc, lp, st, cfg)
            return out, st2

        h, new_states = maybe_scan(body, x, (params["layers"], cache["layers"]))
        h = rms_norm(h, params["final_norm"])[:, 0]
        logits = _masked_logits(h, params["embed"].T, cfg.vocab, Vp)
        return logits, {"layers": new_states, "length": cache["length"] + 1}

    def init_cache(B, capacity, length):
        states = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape),
            init_mamba_state(B, cfg),
        )
        return {"layers": states, "length": jnp.full((B,), length, jnp.int32)}

    return ModelBundle(
        cfg=cfg, init=init, train_loss=train_loss, prefill=prefill,
        decode_step=decode_step, init_cache=init_cache,
        param_count=cfg.param_count,
    )
