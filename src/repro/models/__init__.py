from .attention import DistConfig
from .model_zoo import ModelBundle, build_model

__all__ = ["DistConfig", "ModelBundle", "build_model"]
