"""Top-k routed MoE FFN (Granite 32e/top-8, Qwen3-MoE 128e/top-8).

Capacity-based scatter dispatch (Megablocks-style, GShard capacity):
tokens are scattered into per-expert buckets ``[E, C, d]``, experts run as
one batched matmul, outputs gather back weighted by the renormalised top-k
router probs.  Overflow tokens drop (capacity_factor bounds memory — the
dump row trick keeps everything shape-static and jit/GSPMD friendly).

Sharding: expert-major tensors (``w1/w2/w3`` and the ``[E·C, d]`` buckets)
shard over the 'model' axis (EP); the roofline hillclimb may swap this for
an explicit shard_map EP path (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.configs.base import ModelConfig

from .layers import init_linear


def init_moe(rng: jax.Array, cfg: ModelConfig) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    kr, k1, k2, k3 = jax.random.split(rng, 4)
    s_in, s_out = d**-0.5, ff**-0.5
    return {
        "router": init_linear(kr, d, E),
        "w1": jax.random.normal(k1, (E, d, ff), jnp.float32) * s_in,
        "w3": jax.random.normal(k3, (E, d, ff), jnp.float32) * s_in,
        "w2": jax.random.normal(k2, (E, ff, d), jnp.float32) * s_out,
    }


def moe_apply(
    x: jax.Array, p: dict, cfg: ModelConfig
) -> tuple[jax.Array, jax.Array]:
    """x: [T, d] (caller flattens batch×seq) → (y [T, d], aux_loss scalar)."""
    T, d = x.shape
    E, k = cfg.n_experts, cfg.topk_experts
    C = max(int(T * k / E * cfg.capacity_factor + 0.999), 1)

    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # [T,E]
    gvals, eidx = jax.lax.top_k(logits, k)  # [T,k]
    gates = jax.nn.softmax(gvals, axis=-1)  # renormalise among top-k

    # position of each (token, k) slot within its expert's bucket
    e_flat = eidx.reshape(-1)  # [T*k]
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
    pos = jnp.take_along_axis(
        jnp.cumsum(onehot, axis=0) - 1, e_flat[:, None], axis=1
    )[:, 0]
    keep = pos < C
    slot = jnp.where(keep, e_flat * C + pos, E * C)  # E*C = dump row (dropped)

    xrep = jnp.repeat(x, k, axis=0)  # [T*k, d]
    buf = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(xrep)
    hb = buf[: E * C].reshape(E, C, d)
    h1 = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", hb, p["w1"].astype(x.dtype))
    ) * jnp.einsum("ecd,edf->ecf", hb, p["w3"].astype(x.dtype))
    ob = jnp.einsum("ecf,efd->ecd", h1, p["w2"].astype(x.dtype)).reshape(E * C, d)
    ob = jnp.concatenate([ob, jnp.zeros((1, d), ob.dtype)], axis=0)
    y_slots = ob[slot] * keep[:, None].astype(ob.dtype)  # dropped → 0
    y = (y_slots.reshape(T, k, d) * gates[..., None].astype(ob.dtype)).sum(axis=1)

    # load-balancing aux (Switch-style): E · Σ_e f_e · P_e
    probs = jax.nn.softmax(logits, axis=-1)
    f = jnp.mean(onehot.reshape(T, k, E).sum(axis=1).astype(jnp.float32), axis=0)
    P = probs.mean(axis=0)
    aux = E * jnp.sum(f / k * P)
    return y.astype(x.dtype), aux


# --------------------------------------------------------------------- EP

def moe_apply_ep(
    x: jax.Array,
    p: dict,
    cfg: ModelConfig,
    *,
    mesh,
    token_axes: tuple[str, ...],
    model_axis: str = "model",
    fsdp_axes: tuple[str, ...] = (),
) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE via shard_map (train/prefill path at pod scale).

    Communication-free dispatch: expert weights shard E over ``model_axis``
    and are *replicated over the data axes* (mod FSDP storage), so every
    (data, model) device runs its own data shard's tokens through its own
    expert shard — no all-to-all.  Combine = one psum over ``model_axis``
    (merges with the TP all-reduce pattern).  FSDP-stored expert weights
    all-gather over ``fsdp_axes`` inside the body (ZeRO-3 semantics).

    Memory per device is bounded by construction:
    T_loc·k·capacity_factor·d dispatch buffer — the GSPMD scatter
    pathology of ``moe_apply`` at 1M tokens cannot occur (DESIGN.md §4).
    """
    from jax.sharding import PartitionSpec as P

    E, k = cfg.n_experts, cfg.topk_experts
    n_model = mesh.shape[model_axis]
    E_loc = E // n_model
    tok = tuple(token_axes) if token_axes else None
    f_ax = tuple(fsdp_axes) if fsdp_axes else ()
    all_axes = tuple(a for a in mesh.axis_names)

    def body(xl, router, w1, w3, w2):
        if f_ax:
            w1 = jax.lax.all_gather(w1, f_ax, axis=1, tiled=True)
            w3 = jax.lax.all_gather(w3, f_ax, axis=1, tiled=True)
            w2 = jax.lax.all_gather(w2, f_ax, axis=2, tiled=True)
        T_loc, d = xl.shape
        C = max(int(T_loc * k / E * cfg.capacity_factor + 0.999), 1)
        logits = xl.astype(jnp.float32) @ router
        gvals, eidx = jax.lax.top_k(logits, k)
        gates = jax.nn.softmax(gvals, axis=-1)
        midx = jax.lax.axis_index(model_axis)
        e_flat = eidx.reshape(-1)
        e_rel = e_flat - midx * E_loc
        local = (e_rel >= 0) & (e_rel < E_loc)
        e_loc = jnp.where(local, e_rel, E_loc)
        onehot = jax.nn.one_hot(e_loc, E_loc + 1, dtype=jnp.int32)
        pos = jnp.take_along_axis(
            jnp.cumsum(onehot, axis=0) - 1, e_loc[:, None], axis=1
        )[:, 0]
        keep = local & (pos < C)
        slot = jnp.where(keep, e_loc * C + pos, E_loc * C)
        # dispatch as scatter-of-INDICES + gather (never materialises the
        # [T·k, d] repeat — 4.3 GB/layer on qwen3; §Perf iteration 4):
        # empty slots point at a zero row of the padded tokens
        slot_tok = (
            jnp.full((E_loc * C + 1,), T_loc, jnp.int32)
            .at[slot]
            .set(jnp.arange(e_flat.shape[0], dtype=jnp.int32) // k)
        )
        xp = jnp.concatenate([xl, jnp.zeros((1, d), xl.dtype)], axis=0)
        hb = xp[slot_tok[: E_loc * C]].reshape(E_loc, C, d)
        h1 = jax.nn.silu(
            jnp.einsum("ecd,edf->ecf", hb, w1.astype(xl.dtype))
        ) * jnp.einsum("ecd,edf->ecf", hb, w3.astype(xl.dtype))
        ob = jnp.einsum("ecf,efd->ecd", h1, w2.astype(xl.dtype)).reshape(-1, d)
        ob = jnp.concatenate([ob, jnp.zeros((1, d), ob.dtype)], axis=0)
        # combine unrolled over k: k gathers of [T_loc, d] instead of one
        # [T_loc·k, d] materialisation
        slot_t = slot.reshape(T_loc, k)
        gk = gates.astype(ob.dtype)
        y_part = sum(ob[slot_t[:, j]] * gk[:, j, None] for j in range(k))
        y = jax.lax.psum(y_part, model_axis)
        # aux loss: local estimate, averaged over every mesh shard
        probs = jax.nn.softmax(logits, axis=-1)
        ffrac = jnp.mean(
            jax.nn.one_hot(e_flat, E).reshape(T_loc, k, E).sum(1), axis=0
        )
        aux_local = E * jnp.sum(ffrac / k * probs.mean(0))
        aux = jax.lax.pmean(aux_local, all_axes)
        return y, aux

    f = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(tok, None),
            P(None, None),
            P(model_axis, f_ax if f_ax else None, None),
            P(model_axis, f_ax if f_ax else None, None),
            P(model_axis, None, f_ax if f_ax else None),
        ),
        out_specs=(P(tok, None), P()),
        check_vma=False,
    )
    return f(x, p["router"], p["w1"], p["w3"], p["w2"])


def moe_apply_masked(
    x: jax.Array, p: dict, cfg: ModelConfig
) -> tuple[jax.Array, jax.Array]:
    """Dense-masked MoE for DECODE (token count ≈ batch size): computes all
    experts for all tokens as plain einsums — at decode scale this costs
    E/k× waste on a negligible FLOP total, in exchange for perfectly
    GSPMD-shardable ops (E over 'model', no scatter).  Not for training."""
    T, d = x.shape
    E, k = cfg.n_experts, cfg.topk_experts
    logits = x.astype(jnp.float32) @ p["router"]
    gvals, eidx = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(gvals, axis=-1)
    g_full = jnp.sum(jax.nn.one_hot(eidx, E) * gates[..., None], axis=1)  # [T,E]
    h1 = jax.nn.silu(
        jnp.einsum("td,edf->tef", x, p["w1"].astype(x.dtype))
    ) * jnp.einsum("td,edf->tef", x, p["w3"].astype(x.dtype))
    y = jnp.einsum(
        "tef,efd,te->td", h1, p["w2"].astype(x.dtype), g_full.astype(x.dtype)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    f = jnp.mean(jax.nn.one_hot(eidx.reshape(-1), E).reshape(T, k, E).sum(1), axis=0)
    aux = E * jnp.sum(f / k * probs.mean(0))
    return y.astype(x.dtype), aux
