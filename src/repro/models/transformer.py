"""Decoder-only LM (dense / MoE / VLM-backbone) with FIER-integrated decode.

Design points:
  * stacked layer params + ``lax.scan`` — HLO depth-independent;
  * train/prefill use blocked flash attention (no S×S materialisation);
  * decode splits the stack into front (full attention, the paper's
    skip-layers) and rest (policy: fier/quest/full) — two scans, so the
    compiled decode HLO contains each attention flavour once;
  * cross-entropy is sequence-chunked (never materialises [B,S,V] logits);
  * vocab padded to a sharding-friendly multiple; padded columns masked.

Batch formats (produced by repro.data / launch.input_specs):
  train:   {tokens [B,St], targets [B,S], loss_mask [B,S], vision_embeds?}
  prefill: {tokens [B,St], lengths [B], vision_embeds?}
  decode:  token [B] + cache pytree
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, padded_vocab
from repro.core.policy import DecodePlan, PolicyConfig
from repro.kvcache import cache as kvcache
from repro.kvcache import paged as kvcache_paged

from . import attention as attn
from . import moe as moe_mod
from .tuning import maybe_scan
from .layers import apply_norm, init_embedding, init_mlp, init_norm, mlp_apply

MOE_AUX_COEF = 0.01


@dataclasses.dataclass
class ModelBundle:
    cfg: ModelConfig
    init: Callable
    train_loss: Callable          # (params, batch) -> (loss, metrics)
    prefill: Callable             # (params, batch) -> (logits [B,Vp], cache)
    decode_step: Callable         # (params, token [B], cache) -> (logits, cache)
    init_cache: Callable          # (B, capacity, length) -> cache
    param_count: Callable
    policy: "PolicyConfig | None" = None  # the cache policy the bundle was
                                          # built with (engine introspects
                                          # layout/block_size from here)
    plan: "DecodePlan | None" = None      # the resolved DecodePlan the
                                          # decode path dispatches through
    prefill_chunk: "Callable | None" = None  # (params, batch, cache, *,
                                             # final) -> (logits|None, cache)
                                             # chunked-prefill step; None on
                                             # families without it


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


def build(
    cfg: ModelConfig,
    pol: PolicyConfig | None = None,
    dcfg: attn.DistConfig | None = None,
    *,
    remat: bool = True,
    loss_chunk: int = 1024,
) -> ModelBundle:
    pol = pol or PolicyConfig(kind="full")
    # resolve + validate the decode plan once (capability matrix, paged
    # block-size rules); capacity-dependent checks re-run in init_cache.
    # A mesh sharding spec (dcfg.shard) rides on the plan — the front-scan
    # full layers share the same sharded pool, so plan_full carries it too
    shard = dcfg.shard if dcfg is not None and pol.layout == "paged" else None
    plan = DecodePlan.build(pol, shard=shard)
    pol_full = PolicyConfig(
        kind="full", skip_layers=0,
        layout=pol.layout, block_size=pol.block_size,
        pool_blocks=pol.pool_blocks,
    )
    plan_full = DecodePlan.build(pol_full, shard=shard)
    Vp = padded_vocab(cfg)
    cdt = _dtype(cfg.compute_dtype)
    pdt = _dtype(cfg.param_dtype)
    skip = min(pol.skip_layers if pol.kind != "full" else 0, cfg.n_layers)
    is_moe = cfg.family == "moe"

    # ----------------------------------------------------------------- init
    def init_layer(rng):
        k1, k2, k3 = jax.random.split(rng, 3)
        p = {
            "norm1": init_norm(cfg.norm, cfg.d_model),
            "attn": attn.init_attention(k1, cfg),
            "norm2": init_norm(cfg.norm, cfg.d_model),
        }
        if is_moe:
            p["moe"] = moe_mod.init_moe(k2, cfg)
        else:
            p["mlp"] = init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.act)
        return p

    def init(rng):
        ke, kl, kh = jax.random.split(rng, 3)
        layers = jax.vmap(init_layer)(jax.random.split(kl, cfg.n_layers))
        params = {
            "embed": init_embedding(ke, Vp, cfg.d_model),
            "layers": layers,
            "final_norm": init_norm(cfg.norm, cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = init_embedding(kh, Vp, cfg.d_model).T
        return jax.tree.map(lambda a: a.astype(pdt), params)

    # ------------------------------------------------------------- helpers
    def _embed_inputs(params, batch):
        toks = batch["tokens"]
        h = jnp.take(params["embed"], toks, axis=0).astype(cdt)  # [B,St,d]
        if "vision_embeds" in batch and batch["vision_embeds"] is not None:
            h = jnp.concatenate([batch["vision_embeds"].astype(cdt), h], axis=1)
        # pin the layout after the vocab-sharded gather (§Perf iteration 11)
        return attn.seq_shard_constraint(h, dcfg)

    def _head(params):
        if cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    def _ffn(lp, x2, B, S, mode="train"):
        if not is_moe:
            return mlp_apply(x2, lp["mlp"], cfg.act), jnp.float32(0.0)
        x2d = x2.reshape(B * S, cfg.d_model)
        if mode == "decode":
            # T ≈ batch: dense-masked einsum path (GSPMD-friendly, no scatter)
            y, aux = moe_mod.moe_apply_masked(x2d, lp["moe"], cfg)
        elif dcfg is not None and dcfg.ep_axis is not None:
            # pod scale: shard_map expert parallelism
            tok = tuple(dcfg.batch_axes)
            y, aux = moe_mod.moe_apply_ep(
                x2d, lp["moe"], cfg, mesh=dcfg.mesh, token_axes=tok,
                model_axis=dcfg.ep_axis, fsdp_axes=tuple(dcfg.fsdp_axes),
            )
        else:
            y, aux = moe_mod.moe_apply(x2d, lp["moe"], cfg)
        return y.reshape(B, S, cfg.d_model), aux

    # --------------------------------------------------------------- train
    def _layer_train(h, lp):
        B, S, _ = h.shape
        a = attn.attention_train(lp["attn"], apply_norm(h, lp["norm1"], cfg.norm), cfg)
        h = h + a
        y, aux = _ffn(lp, apply_norm(h, lp["norm2"], cfg.norm), B, S)
        # sequence-parallel residual stream: bounds the remat-saved
        # activation at L·B·S·d/(data·model) per device
        return attn.seq_shard_constraint(h + y, dcfg), aux

    layer_train = (
        jax.checkpoint(_layer_train, policy=jax.checkpoint_policies.nothing_saveable)
        if remat
        else _layer_train
    )

    def train_loss(params, batch):
        h = _embed_inputs(params, batch)
        h, auxs = maybe_scan(layer_train, h, params["layers"])
        h = apply_norm(h, params["final_norm"], cfg.norm)
        loss, n_tok = _chunked_ce(
            h, _head(params), batch["targets"], batch["loss_mask"], cfg.vocab, Vp,
            loss_chunk,
        )
        aux = auxs.mean() if is_moe else jnp.float32(0.0)
        total = loss + MOE_AUX_COEF * aux
        return total, {"loss": loss, "moe_aux": aux, "tokens": n_tok}

    # ------------------------------------------------------------- prefill
    def prefill(params, batch, capacity: int | None = None):
        """Returns (last-token logits [B, Vp], filled cache).  ``capacity``
        is static (jit with functools.partial)."""
        lengths = batch["lengths"]
        h = _embed_inputs(params, batch)
        B, S, _ = h.shape
        cap = capacity if capacity is not None else S
        valid = kvcache.valid_mask(S, lengths)

        def layer_fn(hc, lp):
            xn = apply_norm(hc, lp["norm1"], cfg.norm)
            q, k, v = attn.qkv_proj(lp["attn"], xn, cfg, positions=None)
            o = attn.flash_attention(q, k, v, causal=True, bias_mask=valid)
            o = o.reshape(B, S, cfg.n_heads * cfg.d_head) @ lp["attn"]["wo"].astype(hc.dtype)
            hc = hc + o
            y, _ = _ffn(lp, apply_norm(hc, lp["norm2"], cfg.norm), B, S)
            pad = ((0, 0), (0, cap - S), (0, 0), (0, 0))
            return attn.seq_shard_constraint(hc + y, dcfg), (
                jnp.pad(k.astype(jnp.bfloat16), pad),
                jnp.pad(v.astype(jnp.bfloat16), pad),
            )

        h, (K, V) = maybe_scan(layer_fn, h, params["layers"])  # K: [L,B,cap,H,D]
        h = apply_norm(h, params["final_norm"], cfg.norm)
        cache = _assemble_cache(K, V, lengths)
        last = jnp.take_along_axis(h, (lengths - 1)[:, None, None], axis=1)[:, 0]
        logits = _masked_logits(last, _head(params), cfg.vocab, Vp)
        return logits, cache

    def _assemble_cache(K, V, lengths):
        front = {"k": K[:skip], "v": V[:skip]}
        rest = {"k": K[skip:], "v": V[skip:]}
        if pol.kind in ("fier", "quest"):
            from repro.core.policy import build_metadata

            rest["meta"] = jax.vmap(lambda Kl: build_metadata(Kl, pol))(rest["k"])
        return {"front": front, "rest": rest, "length": lengths}

    def init_cache(B, capacity, length):
        # capacity-dependent plan validation happens here, where capacity
        # is first known (budget/sink/recent bounds, block divisibility)
        plan.validate_capacity(capacity)
        if pol.layout == "paged":
            # one block pool shared by every request: a physical block id
            # indexes the same row of every layer's pool slab, and the
            # per-request [B, capacity/bs] block table (all-zeros = the
            # reserved null block) is the only per-slot state
            bs = pol.block_size
            if capacity % bs:
                raise ValueError(
                    f"capacity {capacity} not divisible by block_size {bs}"
                )
            n_btab = capacity // bs
            n_blocks = pol.pool_blocks or (B * n_btab + 1)
            return {
                "front": kvcache_paged.init_paged_pool(
                    skip, n_blocks, bs, cfg.n_kv_heads, cfg.d_head, None
                ),
                "rest": kvcache_paged.init_paged_pool(
                    cfg.n_layers - skip, n_blocks, bs, cfg.n_kv_heads,
                    cfg.d_head, pol if pol.kind != "full" else None,
                ),
                "length": jnp.full((B,), length, jnp.int32),
                "block_table": jnp.zeros((B, n_btab), jnp.int32),
            }
        c = {
            "front": kvcache.init_layer_cache(
                skip, B, capacity, cfg.n_kv_heads, cfg.d_head, None
            ),
            "rest": kvcache.init_layer_cache(
                cfg.n_layers - skip, B, capacity, cfg.n_kv_heads, cfg.d_head,
                pol if pol.kind != "full" else None,
            ),
            "length": jnp.full((B,), length, jnp.int32),
        }
        return c

    # ------------------------------------------------------ chunked prefill
    def prefill_chunk(params, batch, cache, *, final: bool):
        """One prompt chunk for a single slot of the *batched* cache.

        batch = {tokens [1,n], start, slot, total, table_row? [n_btab]}:
        the chunk covers logical positions [start, start+n) of a prompt of
        ``total`` tokens.  The chunk's K/V are appended through the cache
        layout's addressing (slab row write / block-table scatter), then
        each layer attends over the logical prefix with ``q_offset=start``
        — flash attention's masked keys contribute exact zeros, so row i
        sees precisely keys 0..i and the hidden states are bit-identical
        to a monolithic prefill of the same prompt (bf16 compute: the
        cache round-trip is lossless).

        Only the final chunk produces logits: it zeroes the slab/tail-
        block rows beyond ``total`` (matching monolithic prefill's zero
        padding, so selection-group statistics straddling the prompt end
        agree), rebuilds the selection metadata over the full logical key
        row, publishes ``length[slot] = total`` and (paged) the device
        block-table row.  Non-final chunks return (None, cache) and leave
        length untouched, so interleaved decode steps keep routing this
        slot's scratch writes into masked rows.
        """
        toks = batch["tokens"]                  # [1, n]
        start = batch["start"]                  # scalar int32
        slot = batch["slot"]                    # scalar int32
        total = batch["total"]                  # scalar int32
        table_row = batch.get("table_row")      # [n_btab] int32 (paged)
        paged = pol.layout == "paged"
        h = jnp.take(params["embed"], toks, axis=0).astype(cdt)  # [1,n,d]
        n = h.shape[1]
        positions = (start + jnp.arange(n, dtype=jnp.int32))[None]
        if paged:
            bs = pol.block_size
            phys = table_row[positions[0] // bs]                 # [n]
            offs = positions[0] % bs

        def chunk_body(hc, xs):
            lp, lc = xs
            xn = apply_norm(hc, lp["norm1"], cfg.norm)
            q, k, v = attn.qkv_proj(lp["attn"], xn, cfg, positions=positions)
            kc, vc = k.astype(lc["k"].dtype), v.astype(lc["v"].dtype)
            if paged:
                lck = lc["k"].at[phys, offs].set(kc[0])
                lcv = lc["v"].at[phys, offs].set(vc[0])
                Kl = kvcache_paged.gather_block_rows(lck, table_row[None])
                Vl = kvcache_paged.gather_block_rows(lcv, table_row[None])
                if shard is not None:
                    # gathered from a mesh-sharded pool: replicate before
                    # attention so the wo contraction reduces in the same
                    # order as the single-device prefill (bit-identity)
                    rep = jax.sharding.NamedSharding(
                        shard.mesh, jax.sharding.PartitionSpec()
                    )
                    Kl = jax.lax.with_sharding_constraint(Kl, rep)
                    Vl = jax.lax.with_sharding_constraint(Vl, rep)
            else:
                lck = jax.lax.dynamic_update_slice(lc["k"], kc, (slot, start, 0, 0))
                lcv = jax.lax.dynamic_update_slice(lc["v"], vc, (slot, start, 0, 0))
                Kl = jax.lax.dynamic_index_in_dim(lck, slot, axis=0, keepdims=True)
                Vl = jax.lax.dynamic_index_in_dim(lcv, slot, axis=0, keepdims=True)
            cap = Kl.shape[1]
            valid = (jnp.arange(cap, dtype=jnp.int32) < start + n)[None]
            o = attn.flash_attention(
                q, Kl, Vl, causal=True, q_offset=start, bias_mask=valid
            )
            o = o.reshape(1, n, cfg.n_heads * cfg.d_head) @ lp["attn"]["wo"].astype(hc.dtype)
            hc = hc + o
            y, _ = _ffn(lp, apply_norm(hc, lp["norm2"], cfg.norm), 1, n)
            new_lc = dict(lc, k=lck, v=lcv)
            if final:
                row_valid = (jnp.arange(cap, dtype=jnp.int32) < total)
                rmask = row_valid[None, :, None, None]
                Kz = jnp.where(rmask, Kl, 0).astype(Kl.dtype)
                Vz = jnp.where(rmask, Vl, 0).astype(Vl.dtype)
                if paged:
                    nb = table_row.shape[0]

                    def put_blocks(pool, val):
                        pb = pool.shape[1]
                        return pool.at[table_row].set(
                            val[0].reshape(nb, pb, *val.shape[2:]).astype(pool.dtype)
                        )

                    new_lc["k"] = put_blocks(new_lc["k"], Kz)
                    new_lc["v"] = put_blocks(new_lc["v"], Vz)
                else:
                    new_lc["k"] = jax.lax.dynamic_update_index_in_dim(
                        new_lc["k"], Kz[0], slot, 0
                    )
                    new_lc["v"] = jax.lax.dynamic_update_index_in_dim(
                        new_lc["v"], Vz[0], slot, 0
                    )
                if "meta" in lc:
                    from repro.core.policy import build_metadata

                    mv = build_metadata(Kz, pol)
                    if paged:
                        new_lc["meta"] = jax.tree.map(
                            put_blocks, new_lc["meta"], mv
                        )
                    else:
                        new_lc["meta"] = jax.tree.map(
                            lambda pool, val: jax.lax.dynamic_update_index_in_dim(
                                pool, val[0].astype(pool.dtype), slot, 0
                            ),
                            new_lc["meta"], mv,
                        )
            return hc + y, new_lc

        front_p = jax.tree.map(lambda a: a[:skip], params["layers"])
        rest_p = jax.tree.map(lambda a: a[skip:], params["layers"])
        h, front_cache = maybe_scan(
            chunk_body, h, (front_p, cache["front"])
        ) if skip else (h, cache["front"])
        h, rest_cache = maybe_scan(chunk_body, h, (rest_p, cache["rest"]))
        new_cache = dict(cache, front=front_cache, rest=rest_cache)
        if not final:
            return None, new_cache
        new_cache["length"] = cache["length"].at[slot].set(total)
        if "block_table" in cache:
            new_cache["block_table"] = cache["block_table"].at[slot].set(table_row)
        h = apply_norm(h, params["final_norm"], cfg.norm)[:, n - 1]
        return _masked_logits(h, _head(params), cfg.vocab, Vp), new_cache

    # -------------------------------------------------------------- decode
    def decode_step(params, token, cache):
        length = cache["length"]
        # paged layout: the per-request block table rides in the cache
        # pytree (host-updated between steps by the engine's allocator)
        # and is closed over by both layer scans — it has no layer axis
        block_table = (
            cache.get("block_table") if plan.layout == "paged" else None
        )
        x = jnp.take(params["embed"], token, axis=0)[:, None, :].astype(cdt)
        B = x.shape[0]

        def mk_body(layer_plan, use_dist):
            def body(h, xs):
                lp, lc = xs
                o, lc = attn.decode_self_attention(
                    lp["attn"], apply_norm(h, lp["norm1"], cfg.norm), lc, length,
                    cfg, layer_plan, dcfg if use_dist else None,
                    block_table=block_table,
                )
                h = h + o
                y, _ = _ffn(lp, apply_norm(h, lp["norm2"], cfg.norm), B, 1, "decode")
                return h + y, lc

            return body

        front_params = jax.tree.map(lambda a: a[:skip], params["layers"])
        rest_params = jax.tree.map(lambda a: a[skip:], params["layers"])
        h, front_cache = maybe_scan(
            mk_body(plan_full, use_dist=False), x, (front_params, cache["front"])
        ) if skip else (x, cache["front"])
        h, rest_cache = maybe_scan(
            mk_body(plan, use_dist=True), h, (rest_params, cache["rest"])
        )
        h = apply_norm(h, params["final_norm"], cfg.norm)[:, 0]
        logits = _masked_logits(h, _head(params), cfg.vocab, Vp)
        new_cache = {
            "front": front_cache,
            "rest": rest_cache,
            "length": length + 1,
        }
        if block_table is not None:
            new_cache["block_table"] = block_table
        return logits, new_cache

    return ModelBundle(
        cfg=cfg,
        init=init,
        train_loss=train_loss,
        prefill=prefill,
        decode_step=decode_step,
        init_cache=init_cache,
        param_count=cfg.param_count,
        policy=pol,
        plan=plan,
        prefill_chunk=prefill_chunk,
    )


# ---------------------------------------------------------------- CE / head

def _vocab_col_mask(vocab: int, Vp: int) -> jax.Array:
    return jnp.where(jnp.arange(Vp) < vocab, 0.0, -1e30).astype(jnp.float32)


def _masked_logits(h: jax.Array, W: jax.Array, vocab: int, Vp: int) -> jax.Array:
    logits = h.astype(jnp.float32) @ W.astype(jnp.float32)
    return logits + _vocab_col_mask(vocab, Vp)


def _chunked_ce(
    h: jax.Array,
    W: jax.Array,
    targets: jax.Array,
    mask: jax.Array,
    vocab: int,
    Vp: int,
    chunk: int,
) -> tuple[jax.Array, jax.Array]:
    """Sequence-chunked CE: logits live one [B, chunk, Vp] slice at a time."""
    B, S, d = h.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    nc = S // chunk
    hc = jnp.moveaxis(h.reshape(B, nc, chunk, d), 1, 0)
    tc = jnp.moveaxis(targets.reshape(B, nc, chunk), 1, 0)
    mc = jnp.moveaxis(mask.reshape(B, nc, chunk).astype(jnp.float32), 1, 0)
    col_mask = _vocab_col_mask(vocab, Vp)
    Wf = W.astype(jnp.float32)

    # remat per chunk: the backward recomputes this chunk's logits instead
    # of keeping [B, chunk, Vp] per chunk alive across the whole scan
    @jax.checkpoint
    def body(carry, xs):
        hs, ts, ms = xs
        logits = hs.astype(jnp.float32) @ Wf + col_mask
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ts[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * ms
        return (carry[0] + nll.sum(), carry[1] + ms.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (hc, tc, mc)
    )
    return tot / jnp.maximum(cnt, 1.0), cnt
