"""Shared model-zoo layers: norms, RoPE, MLPs, flash attention, embeddings.

Everything is a pure function over explicit param pytrees (plain dicts of
arrays) — no framework dependency.  Layer params are *stacked* along a
leading L axis by the builders so depth is traversed with ``lax.scan``
(keeps HLO size O(1) in depth; mandatory for the 94-layer dry-runs on one
CPU core).

Compute dtype is bf16 (TPU-native), params fp32 by default, reductions and
softmax in f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

def wuse(w: jax.Array, tp_dim: int = -1) -> jax.Array:
    """ZeRO-3 gather-before-use: constrain a weight to its TP-only sharding
    at the use site.

    FSDP stores matmul weights sharded on the *contraction* dim; left
    alone, GSPMD keeps that dim sharded through the matmul and all-reduces
    partial ACTIVATIONS (measured 1.15 GB f32 per layer on mamba2-370m
    prefill vs the 18 MB weight gather it should do — §Perf iteration 10).
    Constraining the weight to P(model-on-tp_dim) here forces the cheap
    weight all-gather instead.  No-op without an active mesh (unit tests,
    single device) or for shard_map-managed weights (MoE EP).
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except AttributeError:  # older jax
        return w
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return w
    if "model" not in mesh.axis_names or w.ndim < 2:
        return w
    from jax.sharding import PartitionSpec as P

    spec = [None] * w.ndim
    d = tp_dim if tp_dim >= 0 else w.ndim + tp_dim
    spec[d] = "model"
    try:
        return jax.lax.with_sharding_constraint(w, P(*spec))
    except Exception:
        return w


# --------------------------------------------------------------------- norms

def rms_norm(x: jax.Array, w: jax.Array | None, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    if w is not None:
        y = y * w.astype(jnp.float32)
    return y.astype(x.dtype)


def layer_norm(
    x: jax.Array, w: jax.Array | None, b: jax.Array | None, eps: float = 1e-5
) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if w is not None:
        y = y * w.astype(jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(x.dtype)


def apply_norm(x: jax.Array, p: dict, kind: str) -> jax.Array:
    """kind: rms | layernorm | nonparametric (OLMo: LN with no learnables)."""
    if kind == "rms":
        return rms_norm(x, p["w"])
    if kind == "layernorm":
        return layer_norm(x, p.get("w"), p.get("b"))
    if kind == "nonparametric":
        return layer_norm(x, None, None)
    raise ValueError(f"unknown norm {kind!r}")


def init_norm(kind: str, d: int) -> dict:
    if kind == "rms":
        return {"w": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}
    if kind == "nonparametric":
        return {}
    raise ValueError(kind)


# ---------------------------------------------------------------------- RoPE

def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (np.arange(0, d_head, 2, dtype=np.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S] (int32)."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)  # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : D // 2], x[..., D // 2 :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------- MLPs

def mlp_apply(x: jax.Array, p: dict, act: str) -> jax.Array:
    """SwiGLU ('silu': w1/w3 gate) or GeLU ('gelu': single up-proj)."""
    if act == "silu":
        h = jax.nn.silu(x @ wuse(p["w1"], -1).astype(x.dtype)) * (
            x @ wuse(p["w3"], -1).astype(x.dtype))
    elif act == "gelu":
        h = jax.nn.gelu(x @ wuse(p["w1"], -1).astype(x.dtype))
    else:
        raise ValueError(act)
    return h @ wuse(p["w2"], 0).astype(x.dtype)


def init_mlp(rng: jax.Array, d: int, ff: int, act: str) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    s_in, s_out = d**-0.5, ff**-0.5
    p = {
        "w1": jax.random.normal(k1, (d, ff), jnp.float32) * s_in,
        "w2": jax.random.normal(k2, (ff, d), jnp.float32) * s_out,
    }
    if act == "silu":
        p["w3"] = jax.random.normal(k3, (d, ff), jnp.float32) * s_in
    return p


# ----------------------------------------------------------- flash attention

def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_k: int = 512,
    q_offset: int | jax.Array = 0,
    bias_mask: jax.Array | None = None,
) -> jax.Array:
    """Flash attention with a custom VJP (memory O(S·block) in fwd AND bwd).

    q: [B, Sq, Hq, D]; k, v: [B, Sk, Hkv, D] (GQA: Hq = rep·Hkv).
    Forward scans key blocks with an online softmax; backward recomputes
    scores blockwise from saved (q, k, v, out, lse) — autodiff through the
    forward scan would instead save per-block probability tensors
    (observed: 10s of GB/device on the 4k-train cells; EXPERIMENTS.md
    §Perf iteration 3).  ``q_offset`` is the global position of q[0];
    ``bias_mask`` [B, Sk] marks valid key slots (padding).
    """
    return _flash_custom(q, k, v, causal, block_k, q_offset, bias_mask)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _flash_custom(q, k, v, causal, block_k, q_offset, bias_mask):
    out, _ = _flash_fwd_impl(q, k, v, causal, block_k, q_offset, bias_mask)
    return out


def _flash_fwd_rule(q, k, v, causal, block_k, q_offset, bias_mask):
    out, lse = _flash_fwd_impl(q, k, v, causal, block_k, q_offset, bias_mask)
    return out, (q, k, v, out, lse, q_offset, bias_mask)


def _flash_bwd_rule(causal, block_k, res, dout):
    q, k, v, out, lse, q_offset, bias_mask = res
    dq, dk, dv = _flash_bwd_impl(
        q, k, v, out, lse, dout, causal, block_k, q_offset, bias_mask
    )
    return dq, dk, dv, None, None


_BLOCK_Q = 512


def _qblocks(x, block_q):
    """[B, Sq, ...] → [nq, B, block_q, ...] (zero-padded)."""
    B, Sq = x.shape[:2]
    nq = -(-Sq // block_q)
    if nq * block_q != Sq:
        pad = [(0, 0), (0, nq * block_q - Sq)] + [(0, 0)] * (x.ndim - 2)
        x = jnp.pad(x, pad)
    return jnp.moveaxis(x.reshape(B, nq, block_q, *x.shape[2:]), 1, 0)


def _flash_fwd_impl(q, k, v, causal, block_k, q_offset, bias_mask):
    """Tile over q blocks (scan) × k blocks (inner scan): peak score tile
    is [B, block_q, Hq, block_k] — both dims bounded."""
    B, Sq, Hq, D = q.shape
    if Sq <= _BLOCK_Q:
        return _flash_fwd_one(q, k, v, causal, block_k, q_offset, bias_mask)
    qb = _qblocks(q, _BLOCK_Q)
    nq = qb.shape[0]

    def body(_, xs):
        qi, i = xs
        out_i, lse_i = _flash_fwd_one(
            qi, k, v, causal, block_k,
            jnp.asarray(q_offset, jnp.int32) + i * _BLOCK_Q, bias_mask,
        )
        return None, (out_i, lse_i)

    _, (outb, lseb) = jax.lax.scan(body, None, (qb, jnp.arange(nq, dtype=jnp.int32)))
    out = jnp.moveaxis(outb, 0, 1).reshape(B, nq * _BLOCK_Q, Hq, D)[:, :Sq]
    Hkv, rep = lseb.shape[3], lseb.shape[4]
    lse = jnp.moveaxis(lseb, 0, 1).reshape(B, nq * _BLOCK_Q, Hkv, rep)[:, :Sq]
    return out, lse


def _flash_bwd_impl(q, k, v, out, lse, dout, causal, block_k, q_offset, bias_mask):
    B, Sq, Hq, D = q.shape
    if Sq <= _BLOCK_Q:
        return _flash_bwd_one(q, k, v, out, lse, dout, causal, block_k, q_offset, bias_mask)
    qb, ob, dob, lb = (_qblocks(x, _BLOCK_Q) for x in (q, out, dout, lse))
    nq = qb.shape[0]
    Sk, Hkv = k.shape[1], k.shape[2]

    def body(carry, xs):
        dk_acc, dv_acc = carry
        qi, oi, doi, li, i = xs
        dq_i, dk_i, dv_i = _flash_bwd_one(
            qi, k, v, oi, li, doi, causal, block_k,
            jnp.asarray(q_offset, jnp.int32) + i * _BLOCK_Q, bias_mask,
        )
        return (dk_acc + dk_i.astype(jnp.float32),
                dv_acc + dv_i.astype(jnp.float32)), dq_i

    zero = jnp.zeros((B, Sk, Hkv, D), jnp.float32)
    (dk, dv), dqb = jax.lax.scan(
        body, (zero, zero), (qb, ob, dob, lb, jnp.arange(nq, dtype=jnp.int32))
    )
    dq = jnp.moveaxis(dqb, 0, 1).reshape(B, nq * _BLOCK_Q, Hq, D)[:, :Sq]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def _flash_fwd_one(q, k, v, causal, block_k, q_offset, bias_mask):
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    nb = -(-Sk // block_k)
    Skp = nb * block_k
    if Skp != Sk:  # pad keys to a whole number of blocks
        pad = [(0, 0), (0, Skp - Sk), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    scale = 1.0 / np.sqrt(D)
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, rep, D)
    q_pos = jnp.asarray(q_offset, jnp.int32) + jnp.arange(Sq, dtype=jnp.int32)

    kb = k.reshape(B, nb, block_k, Hkv, D)
    vb = v.reshape(B, nb, block_k, Hkv, D)

    def body(carry, xs):
        m, num, den = carry
        kblk, vblk, bidx = xs
        s = jnp.einsum(
            "bqhrd,bkhd->bqhrk", qf, kblk.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        k_pos = bidx * block_k + jnp.arange(block_k, dtype=jnp.int32)
        mask = k_pos[None, :] < Sk  # [1, blk] padding
        if bias_mask is not None:
            blk_valid = jax.lax.dynamic_slice_in_dim(
                jnp.pad(bias_mask, ((0, 0), (0, Skp - Sk))), bidx * block_k,
                block_k, axis=1,
            )
            mask = mask & blk_valid
        if causal:
            cm = q_pos[:, None] >= k_pos[None, :]  # [Sq, blk]
            s = jnp.where(cm[None, :, None, None, :], s, -jnp.inf)
        s = jnp.where(mask[:, None, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard fully-masked rows (m_new = -inf): no contribution
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - safe_m[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - safe_m), 0.0)
        num = num * alpha[..., None] + jnp.einsum(
            "bqhrk,bkhd->bqhrd", p, vblk.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        den = den * alpha + p.sum(axis=-1)
        return (m_new, num, den), None

    init = (
        jnp.full((B, Sq, Hkv, rep), -jnp.inf, jnp.float32),
        jnp.zeros((B, Sq, Hkv, rep, D), jnp.float32),
        jnp.zeros((B, Sq, Hkv, rep), jnp.float32),
    )
    (m, num, den), _ = jax.lax.scan(
        body, init, (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0),
                     jnp.arange(nb, dtype=jnp.int32))
    )
    out = num / jnp.maximum(den, 1e-30)[..., None]
    lse = m + jnp.log(jnp.maximum(den, 1e-30))  # [B,Sq,Hkv,rep]
    return out.reshape(B, Sq, Hq, D).astype(q.dtype), lse


def _flash_bwd_one(q, k, v, out, lse, dout, causal, block_k, q_offset, bias_mask):
    """Blockwise flash backward: recompute p from (q,k,lse), accumulate
    dq/dk/dv over key blocks.  All f32 accumulation; O(S·block) memory."""
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    nb = -(-Sk // block_k)
    Skp = nb * block_k
    if Skp != Sk:
        pad = [(0, 0), (0, Skp - Sk), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    scale = 1.0 / np.sqrt(D)
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, rep, D)
    dof = dout.astype(jnp.float32).reshape(B, Sq, Hkv, rep, D)
    of = out.astype(jnp.float32).reshape(B, Sq, Hkv, rep, D)
    # D_i = Σ_d dout·out  (softmax backward diagonal term)
    Dterm = jnp.sum(dof * of, axis=-1)  # [B,Sq,Hkv,rep]
    q_pos = jnp.asarray(q_offset, jnp.int32) + jnp.arange(Sq, dtype=jnp.int32)
    kb = jnp.moveaxis(k.reshape(B, nb, block_k, Hkv, D), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nb, block_k, Hkv, D), 1, 0)
    if bias_mask is not None:
        bm = jnp.pad(bias_mask, ((0, 0), (0, Skp - Sk)))

    def body(dq_acc, xs):
        kblk, vblk, bidx = xs
        kf = kblk.astype(jnp.float32)
        s = jnp.einsum("bqhrd,bkhd->bqhrk", qf, kf) * scale
        k_pos = bidx * block_k + jnp.arange(block_k, dtype=jnp.int32)
        mask = k_pos[None, :] < Sk
        if bias_mask is not None:
            blk_valid = jax.lax.dynamic_slice_in_dim(bm, bidx * block_k, block_k, 1)
            mask = mask & blk_valid
        neg = jnp.float32(-1e30)
        if causal:
            s = jnp.where((q_pos[:, None] >= k_pos[None, :])[None, :, None, None, :], s, neg)
        s = jnp.where(mask[:, None, None, None, :], s, neg)
        p = jnp.exp(s - lse[..., None])            # [B,Sq,Hkv,rep,blk]
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        dv_blk = jnp.einsum("bqhrk,bqhrd->bkhd", p, dof)
        dp = jnp.einsum("bqhrd,bkhd->bqhrk", dof, vblk.astype(jnp.float32))
        ds = p * (dp - Dterm[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum("bqhrk,bkhd->bqhrd", ds, kf)
        dk_blk = jnp.einsum("bqhrk,bqhrd->bkhd", ds, qf)
        return dq_acc, (dk_blk, dv_blk)

    dq0 = jnp.zeros((B, Sq, Hkv, rep, D), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(
        body, dq0, (kb, vb, jnp.arange(nb, dtype=jnp.int32))
    )
    dk = jnp.moveaxis(dk_b, 0, 1).reshape(B, Skp, Hkv, D)[:, :Sk]
    dv = jnp.moveaxis(dv_b, 0, 1).reshape(B, Skp, Hkv, D)[:, :Sk]
    return (
        dq.reshape(B, Sq, Hq, D).astype(q.dtype),
        dk.astype(k.dtype),
        dv.astype(v.dtype),
    )


_flash_custom.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def attention_ref(q, k, v, *, causal=True, q_offset=0, bias_mask=None):
    """Dense oracle for flash_attention (test-only; materialises S×S)."""
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    scale = 1.0 / np.sqrt(D)
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, rep, D) * scale
    s = jnp.einsum("bqhrd,bkhd->bqhrk", qf, k.astype(jnp.float32))
    q_pos = jnp.asarray(q_offset, jnp.int32) + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    if causal:
        s = jnp.where(
            (q_pos[:, None] >= k_pos[None, :])[None, :, None, None, :], s, -jnp.inf
        )
    if bias_mask is not None:
        s = jnp.where(bias_mask[:, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bqhrk,bkhd->bqhrd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)


# ----------------------------------------------------------------- embedding

def init_embedding(rng: jax.Array, vocab: int, d: int) -> jax.Array:
    return jax.random.normal(rng, (vocab, d), jnp.float32) * (d**-0.5)


def init_linear(rng: jax.Array, d_in: int, d_out: int) -> jax.Array:
    return jax.random.normal(rng, (d_in, d_out), jnp.float32) * (d_in**-0.5)
