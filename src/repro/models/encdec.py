"""Whisper-style encoder-decoder.

The audio conv frontend is a STUB per the assignment: inputs are
precomputed frame embeddings [B, enc_ctx, d_model] (``input_specs``
supplies them).  Encoder: bidirectional self-attention, sinusoidal
positions.  Decoder: causal self-attention (cached, FIER-eligible) +
cross-attention to the encoder output (cache computed once at prefill;
kept full — 1500 frames, below any useful retrieval budget) + GeLU MLP.
Decoder positions are learned; the table is sized to the serving capacity
(the family bound is 448 — dry-run shapes exceed it by assignment, noted
in DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, padded_vocab
from repro.core.policy import DecodePlan, PolicyConfig, build_metadata
from repro.kvcache import cache as kvcache

from . import attention as attn
from .layers import apply_norm, init_embedding, init_mlp, init_norm, mlp_apply
from .transformer import ModelBundle, _chunked_ce, _masked_logits
from .tuning import maybe_scan


def sinusoids(length: int, channels: int) -> np.ndarray:
    log_timescale = np.log(10000.0) / (channels // 2 - 1)
    inv = np.exp(-log_timescale * np.arange(channels // 2))
    ang = np.arange(length)[:, None] * inv[None, :]
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=1).astype(np.float32)


def init_enc_layer(rng, cfg):
    k1, k2 = jax.random.split(rng)
    return {
        "norm1": init_norm(cfg.norm, cfg.d_model),
        "attn": attn.init_attention(k1, cfg),
        "norm2": init_norm(cfg.norm, cfg.d_model),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act),
    }


def init_dec_layer(rng, cfg):
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "norm1": init_norm(cfg.norm, cfg.d_model),
        "self_attn": attn.init_attention(k1, cfg),
        "norm_x": init_norm(cfg.norm, cfg.d_model),
        "cross_attn": attn.init_attention(k2, cfg),
        "norm2": init_norm(cfg.norm, cfg.d_model),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.act),
    }


def _cross_attention_decode(p, x, k_cross, v_cross, cfg):
    """q from x [B,1,d] against fixed cross K/V [B,Senc,H,D] (full)."""
    B = x.shape[0]
    q = x @ p["wq"].astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    q = q.reshape(B, cfg.n_heads, cfg.d_head)
    from repro.core.retrieval import full_attention_decode

    o = full_attention_decode(q, k_cross, v_cross, length=None)
    return o.reshape(B, 1, cfg.n_heads * cfg.d_head) @ p["wo"].astype(x.dtype)


def build(
    cfg: ModelConfig,
    pol: PolicyConfig | None = None,
    dcfg: attn.DistConfig | None = None,
    *,
    remat: bool = True,
    loss_chunk: int = 512,
    max_positions: int | None = None,
) -> ModelBundle:
    pol = pol or PolicyConfig(kind="full")
    plan = DecodePlan.build(pol)
    plan_full = DecodePlan.build(PolicyConfig(kind="full", skip_layers=0))
    Vp = padded_vocab(cfg)
    cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    skip = min(pol.skip_layers if pol.kind != "full" else 0, cfg.n_layers)
    max_pos = max_positions or cfg.max_target_positions

    def init(rng):
        ke, kenc, kdec, kp = jax.random.split(rng, 4)
        enc = jax.vmap(lambda r: init_enc_layer(r, cfg))(
            jax.random.split(kenc, cfg.n_enc_layers)
        )
        dec = jax.vmap(lambda r: init_dec_layer(r, cfg))(
            jax.random.split(kdec, cfg.n_layers)
        )
        return {
            "embed": init_embedding(ke, Vp, cfg.d_model),
            "pos_dec": jax.random.normal(kp, (max_pos, cfg.d_model), jnp.float32)
            * 0.01,
            "enc_layers": enc,
            "enc_norm": init_norm(cfg.norm, cfg.d_model),
            "dec_layers": dec,
            "dec_norm": init_norm(cfg.norm, cfg.d_model),
        }

    # --------------------------------------------------------------- encode
    def encode(params, frames):
        h = frames.astype(cdt) + jnp.asarray(
            sinusoids(frames.shape[1], cfg.d_model), cdt
        )

        def layer_fn(hc, lp):
            a = attn.attention_train(
                lp["attn"], apply_norm(hc, lp["norm1"], cfg.norm), cfg, causal=False
            )
            hc = hc + a
            m = mlp_apply(apply_norm(hc, lp["norm2"], cfg.norm), lp["mlp"], cfg.act)
            return attn.seq_shard_constraint(hc + m, dcfg), None

        body = jax.checkpoint(layer_fn) if remat else layer_fn
        h, _ = maybe_scan(body, h, params["enc_layers"])
        return apply_norm(h, params["enc_norm"], cfg.norm)

    def _dec_embed(params, tokens, offset=0):
        B, S = tokens.shape
        pos = jnp.arange(S, dtype=jnp.int32) + offset
        h = jnp.take(params["embed"], tokens, axis=0)
        return (h + jnp.take(params["pos_dec"], pos, axis=0)[None]).astype(cdt)

    # ---------------------------------------------------------------- train
    def train_loss(params, batch):
        enc = encode(params, batch["frames"])
        h = _dec_embed(params, batch["tokens"])

        def layer_fn(hc, lp):
            a = attn.attention_train(
                lp["self_attn"], apply_norm(hc, lp["norm1"], cfg.norm), cfg
            )
            hc = hc + a
            x = attn.attention_train(
                lp["cross_attn"], apply_norm(hc, lp["norm_x"], cfg.norm), cfg,
                causal=False, kv_x=enc,
            )
            hc = hc + x
            m = mlp_apply(apply_norm(hc, lp["norm2"], cfg.norm), lp["mlp"], cfg.act)
            return attn.seq_shard_constraint(hc + m, dcfg), None

        body = jax.checkpoint(layer_fn) if remat else layer_fn
        h, _ = maybe_scan(body, h, params["dec_layers"])
        h = apply_norm(h, params["dec_norm"], cfg.norm)
        loss, n = _chunked_ce(
            h, params["embed"].T, batch["targets"], batch["loss_mask"], cfg.vocab,
            Vp, loss_chunk,
        )
        return loss, {"loss": loss, "moe_aux": jnp.float32(0.0), "tokens": n}

    # -------------------------------------------------------------- prefill
    def prefill(params, batch, capacity: int | None = None):
        lengths = batch["lengths"]
        enc = encode(params, batch["frames"])
        h = _dec_embed(params, batch["tokens"])
        B, S, _ = h.shape
        cap = capacity if capacity is not None else S
        valid = kvcache.valid_mask(S, lengths)
        Senc = enc.shape[1]

        def layer_fn(hc, lp):
            xn = apply_norm(hc, lp["norm1"], cfg.norm)
            q, k, v = attn.qkv_proj(lp["self_attn"], xn, cfg, positions=None)
            o = attn.flash_attention(q, k, v, causal=True, bias_mask=valid)
            o = o.reshape(B, S, -1) @ lp["self_attn"]["wo"].astype(hc.dtype)
            hc = hc + o
            # cross attention + cross-KV capture
            xq = apply_norm(hc, lp["norm_x"], cfg.norm)
            kc = (enc @ lp["cross_attn"]["wk"].astype(cdt)).reshape(
                B, Senc, cfg.n_kv_heads, cfg.d_head
            )
            vc = (enc @ lp["cross_attn"]["wv"].astype(cdt)).reshape(
                B, Senc, cfg.n_kv_heads, cfg.d_head
            )
            qc = (xq @ lp["cross_attn"]["wq"].astype(cdt)).reshape(
                B, S, cfg.n_heads, cfg.d_head
            )
            xo = attn.flash_attention(qc, kc, vc, causal=False)
            hc = hc + xo.reshape(B, S, -1) @ lp["cross_attn"]["wo"].astype(hc.dtype)
            m = mlp_apply(apply_norm(hc, lp["norm2"], cfg.norm), lp["mlp"], cfg.act)
            pad = ((0, 0), (0, cap - S), (0, 0), (0, 0))
            return hc + m, (
                jnp.pad(k.astype(jnp.bfloat16), pad),
                jnp.pad(v.astype(jnp.bfloat16), pad),
                kc.astype(jnp.bfloat16),
                vc.astype(jnp.bfloat16),
            )

        h, (K, V, Kc, Vc) = maybe_scan(layer_fn, h, params["dec_layers"])
        h = apply_norm(h, params["dec_norm"], cfg.norm)
        front = {"k": K[:skip], "v": V[:skip]}
        rest = {"k": K[skip:], "v": V[skip:]}
        if pol.kind in ("fier", "quest"):
            rest["meta"] = jax.vmap(lambda Kl: build_metadata(Kl, pol))(rest["k"])
        cache = {
            "front": front, "rest": rest,
            "cross_k": Kc, "cross_v": Vc,
            "length": lengths,
        }
        last = jnp.take_along_axis(h, (lengths - 1)[:, None, None], axis=1)[:, 0]
        return _masked_logits(last, params["embed"].T, cfg.vocab, Vp), cache

    # --------------------------------------------------------------- decode
    def decode_step(params, token, cache):
        length = cache["length"]
        B = token.shape[0]
        x = jnp.take(params["embed"], token, axis=0)[:, None, :]
        pos = jnp.clip(length, 0, max_pos - 1)
        x = (x + jnp.take(params["pos_dec"], pos, axis=0)[:, None, :]).astype(cdt)

        def mk_body(layer_plan, use_dist):
            def body(h, xs):
                lp, lc, kc, vc = xs
                o, lc = attn.decode_self_attention(
                    lp["self_attn"], apply_norm(h, lp["norm1"], cfg.norm), lc,
                    length, cfg, layer_plan, dcfg if use_dist else None,
                )
                h = h + o
                h = h + _cross_attention_decode(
                    lp["cross_attn"], apply_norm(h, lp["norm_x"], cfg.norm), kc, vc, cfg
                )
                m = mlp_apply(apply_norm(h, lp["norm2"], cfg.norm), lp["mlp"], cfg.act)
                return h + m, lc

            return body

        front_p = jax.tree.map(lambda a: a[:skip], params["dec_layers"])
        rest_p = jax.tree.map(lambda a: a[skip:], params["dec_layers"])
        h = x
        front_cache = cache["front"]
        if skip:
            h, front_cache = maybe_scan(
                mk_body(plan_full, False), x,
                (front_p, cache["front"], cache["cross_k"][:skip], cache["cross_v"][:skip]),
            )
        h, rest_cache = maybe_scan(
            mk_body(plan, True), h,
            (rest_p, cache["rest"], cache["cross_k"][skip:], cache["cross_v"][skip:]),
        )
        h = apply_norm(h, params["dec_norm"], cfg.norm)[:, 0]
        logits = _masked_logits(h, params["embed"].T, cfg.vocab, Vp)
        new_cache = dict(cache, front=front_cache, rest=rest_cache, length=length + 1)
        return logits, new_cache

    def init_cache(B, capacity, length):
        return {
            "front": kvcache.init_layer_cache(
                skip, B, capacity, cfg.n_kv_heads, cfg.d_head, None
            ),
            "rest": kvcache.init_layer_cache(
                cfg.n_layers - skip, B, capacity, cfg.n_kv_heads, cfg.d_head,
                pol if pol.kind != "full" else None,
            ),
            "cross_k": jnp.zeros(
                (cfg.n_layers, B, cfg.enc_ctx, cfg.n_kv_heads, cfg.d_head), jnp.bfloat16
            ),
            "cross_v": jnp.zeros(
                (cfg.n_layers, B, cfg.enc_ctx, cfg.n_kv_heads, cfg.d_head), jnp.bfloat16
            ),
            "length": jnp.full((B,), length, jnp.int32),
        }

    return ModelBundle(
        cfg=cfg, init=init, train_loss=train_loss, prefill=prefill,
        decode_step=decode_step, init_cache=init_cache,
        param_count=cfg.param_count, policy=pol, plan=plan,
    )
