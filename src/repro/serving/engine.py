"""Serving engine: jitted prefill/decode around a ModelBundle, with
slot-based continuous batching support.

The decode step is the FIER fast path: policy-dispatched attention over
the cache slabs (optionally sequence-sharded across the mesh).  The
*default* serving policy (``serving_policy`` / ``Engine.build``) is the
one-pass fused pipeline: a single Pallas retrieval kernel (1-bit score
scan + GQA group-reduce + masking + exact radix threshold top-k — the
per-token score tensors never touch HBM) chained into in-kernel row
gather + attention (no materialised K'/V' copies) — see DESIGN.md
§One-pass retrieval and §Fused decode.

Slot insertion runs a B=1 prefill and scatters the resulting cache into
the batched cache; the batch axis of every cache leaf is discovered
automatically by diffing ``init_cache`` shapes at two batch sizes (no
per-model bookkeeping).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.policy import PolicyConfig
from repro.models.model_zoo import ModelBundle


def serving_policy(
    budget: int = 1024,
    group: int = 32,
    *,
    skip_layers: int = 2,
    sink: int = 4,
    recent: int = 64,
    fused: bool = True,
    one_pass: bool = True,
) -> PolicyConfig:
    """The serving-default FIER policy: one-pass fused retrieval (score
    scan + group-reduce + mask + exact threshold top-k in a single
    kernel — per-token scores never touch HBM) chained into the fused
    select-and-attend kernel, with the standard sink/recent guard-rails
    for generation quality.  ``one_pass=False`` keeps the two-pass kernel
    retrieval (score tensor materialised between kernels);
    ``fused=False`` falls back to the unfused top-k + gather pipeline
    (the validation oracle)."""
    return PolicyConfig(
        kind="fier", budget=budget, group=group, skip_layers=skip_layers,
        sink=sink, recent=recent, fused=fused, one_pass=one_pass,
    )


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    temperature: float = 0.0   # 0 → greedy
    top_k: int = 0             # 0 → no truncation


def sample_token(rng, logits: jax.Array, cfg: SamplingConfig) -> jax.Array:
    if cfg.temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    l = logits / cfg.temperature
    if cfg.top_k > 0:
        kth = jax.lax.top_k(l, cfg.top_k)[0][..., -1:]
        l = jnp.where(l < kth, -1e30, l)
    return jax.random.categorical(rng, l, axis=-1).astype(jnp.int32)


def _cache_batch_axes(bundle: ModelBundle, capacity: int) -> Any:
    """Pytree of batch-axis indices, discovered by shape-diffing."""
    c2 = jax.eval_shape(lambda: bundle.init_cache(2, capacity, 0))
    c3 = jax.eval_shape(lambda: bundle.init_cache(3, capacity, 0))

    def axis(a, b):
        diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
        if len(diffs) != 1:
            raise ValueError(f"ambiguous batch axis: {a.shape} vs {b.shape}")
        return diffs[0]

    return jax.tree.map(axis, c2, c3)


class Engine:
    """Batched generation engine with continuous-batching slot management."""

    def __init__(
        self,
        bundle: ModelBundle,
        *,
        n_slots: int,
        capacity: int,
        sampling: SamplingConfig = SamplingConfig(),
        donate_cache: bool = True,
        seed: int = 0,
    ):
        self.bundle = bundle
        self.n_slots = n_slots
        self.capacity = capacity
        self.sampling = sampling
        # fallback sampling rng: split per decode call so stochastic
        # sampling never reuses a key (callers may still pass rng=...)
        self._rng = jax.random.PRNGKey(seed)
        self._batch_axes = _cache_batch_axes(bundle, capacity)
        self._prefill = jax.jit(partial(bundle.prefill, capacity=capacity))
        donate = (2,) if donate_cache else ()
        self._decode = jax.jit(bundle.decode_step, donate_argnums=donate)

        def _decode_active_impl(params, tokens, cache, active):
            old_len = cache["length"]
            logits, new_cache = bundle.decode_step(params, tokens, cache)
            new_cache = dict(
                new_cache, length=jnp.where(active, new_cache["length"], old_len)
            )
            return logits, new_cache

        self._decode_active = jax.jit(_decode_active_impl, donate_argnums=donate)
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))

    @classmethod
    def build(
        cls,
        cfg,
        *,
        n_slots: int,
        capacity: int,
        policy: PolicyConfig | None = None,
        sampling: SamplingConfig = SamplingConfig(),
        **build_kwargs,
    ) -> "Engine":
        """Build bundle + engine with the serving defaults: when ``policy``
        is None the fused FIER fast path (``serving_policy()``) is used,
        with the budget clamped to ``capacity`` (a budget larger than the
        cache would otherwise fail the kernel's budget ≤ S check at the
        first decode step)."""
        from repro.models import build_model

        if policy is not None:
            pol = policy
        else:
            base = serving_policy()
            pol = dataclasses.replace(base, budget=min(base.budget, capacity))
        bundle = build_model(cfg, pol, **build_kwargs)
        return cls(bundle, n_slots=n_slots, capacity=capacity, sampling=sampling)

    # ------------------------------------------------------------ lifecycle
    def new_cache(self, length: int = 0):
        return self.bundle.init_cache(self.n_slots, self.capacity, length)

    def prefill_batch(self, params, batch):
        """Whole-batch prefill (offline / static batching path)."""
        return self._prefill(params, batch)

    def _insert_impl(self, batched_cache, single_cache, slot):
        def put(dest, src, ax):
            return jax.lax.dynamic_update_index_in_dim(dest, src[0], slot, ax)

        return jax.tree.map(put, batched_cache, single_cache, self._batch_axes)

    def insert(self, params, batched_cache, tokens_1xS, length: int, slot: int, extras=None):
        """Prefill one request and place it into ``slot``.  Returns
        (first sampled token logits, updated batched cache)."""
        batch = {"tokens": tokens_1xS, "lengths": jnp.array([length], jnp.int32)}
        if extras:
            batch.update(extras)
        logits, single = self._prefill(params, batch)
        return logits, self._insert(batched_cache, single, jnp.int32(slot))

    def decode(self, params, tokens, cache, active=None, rng=None):
        """One decode step for all slots; inactive slots don't advance.

        tokens [n_slots] int32 → (next_tokens [n_slots], logits, cache).
        When ``rng`` is omitted, a fresh key is split off the engine's
        internal rng — every call samples with a distinct key (the old
        behaviour re-used ``PRNGKey(0)`` each step, so temperature > 0
        serving resampled the same draw forever).
        """
        if active is not None:
            # inactive slots' lengths are frozen inside the jitted step
            # (their cache writes are scratch, overwritten on insert)
            logits, new_cache = self._decode_active(params, tokens, cache, active)
        else:
            logits, new_cache = self._decode(params, tokens, cache)
        if rng is None:
            self._rng, rng = jax.random.split(self._rng)
        nxt = sample_token(rng, logits, self.sampling)
        return nxt, logits, new_cache

    # --------------------------------------------------------- conveniences
    def generate(
        self, params, prompts: jax.Array, lengths: jax.Array, max_new: int,
        extras=None, rng=None,
    ):
        """Static-batch generate: prefill the whole batch then decode
        ``max_new`` tokens.  prompts [B, S]; returns tokens [B, max_new].
        Without an explicit ``rng``, each call draws a fresh key off the
        engine rng (same contract as ``decode``)."""
        if rng is None:
            self._rng, rng = jax.random.split(self._rng)
        batch = {"tokens": prompts, "lengths": lengths}
        if extras:
            batch.update(extras)
        logits, cache = self._prefill(params, batch)
        tok = sample_token(rng, logits, self.sampling)
        outs = [tok]
        for i in range(max_new - 1):
            rng, sub = jax.random.split(rng)
            tok, _, cache = self.decode(params, tok, cache, rng=sub)
            outs.append(tok)
        return jnp.stack(outs, axis=1)
