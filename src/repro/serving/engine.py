"""Serving engine: jitted prefill/decode around a ModelBundle, with
slot-based continuous batching support.

The decode step is the FIER fast path: policy-dispatched attention over
the cache slabs (optionally sequence-sharded across the mesh).  The
*default* serving policy (``serving_policy`` / ``Engine.build``) is the
one-pass fused pipeline: a single Pallas retrieval kernel (1-bit score
scan + GQA group-reduce + masking + exact radix threshold top-k — the
per-token score tensors never touch HBM) chained into in-kernel row
gather + attention (no materialised K'/V' copies) — see DESIGN.md
§One-pass retrieval and §Fused decode.

Slot insertion runs a B=1 prefill and scatters the resulting cache into
the batched cache; the batch axis of every cache leaf is discovered
automatically by diffing ``init_cache`` shapes at two batch sizes (no
per-model bookkeeping).

Paged mode (``Engine.build(..., layout='paged')``; DESIGN.md §Paged KV
cache): the cache is a shared block pool + per-request block tables, the
engine owns the host-side ``BlockAllocator`` (prefix sharing via chained
block hashes, full-prompt hits skip prefill entirely, copy-on-write on
shared tails), and insertion scatters the prefilled slab block-wise into
the pool — HBM is bounded by tokens resident, not slots × capacity.
"""
from __future__ import annotations

import dataclasses
import time
import weakref
from collections import Counter, OrderedDict
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import DecodePlan, PolicyConfig
from repro.kvcache.offload import HostOffloadTier, double_buffered_puts, to_host
from repro.kvcache.paged import (
    NULL_BLOCK,
    AllocatorAuditError,
    BlockAllocator,
    SeqBlocks,
    block_hash_chain,
)
from repro.models.model_zoo import ModelBundle
from repro.obs import Observability

MAX_CACHED_PROMPT_LOGITS = 1024  # LRU bound on the full-prompt logits cache

# Every live engine, for the test-suite allocator-audit fixture: conftest
# sweeps this after each test and asserts a drained engine leaked nothing.
_LIVE_ENGINES: "weakref.WeakSet[Engine]" = weakref.WeakSet()


class PoolExhausted(RuntimeError):
    """The block pool ran dry mid-operation (insert raced a concurrent
    consumer, or a fault-injected allocation failure).  The operation has
    been rolled back — the caller can re-queue and retry."""


def serving_policy(
    budget: int = 1024,
    group: int = 32,
    *,
    skip_layers: int = 2,
    sink: int = 4,
    recent: int = 64,
    pipeline: str = "one_pass",
    layout: str = "slab",
    fused: bool | None = None,
    one_pass: bool | None = None,
) -> PolicyConfig:
    """The serving-default FIER policy: the ``one_pass`` pipeline (score
    scan + group-reduce + mask + exact threshold top-k in a single
    kernel — per-token scores never touch HBM) chained into the fused
    select-and-attend kernel, with the standard sink/recent guard-rails
    for generation quality.  ``pipeline='two_pass'`` keeps the two-pass
    kernel retrieval (score tensor materialised between kernels);
    ``pipeline='reference'`` is the unfused top-k + gather oracle.
    ``layout='paged'`` serves from the block-pool cache.

    The pre-registry ``fused`` / ``one_pass`` booleans are accepted as
    deprecated aliases and translated onto ``pipeline``."""
    if fused is not None or one_pass is not None:
        from repro.core.policy import _warn_deprecated

        _warn_deprecated(
            "serving_policy's `fused` / `one_pass` booleans",
            "pipeline='reference'|'two_pass'|'one_pass'",
        )
        if fused is False:
            pipeline = "reference"
        elif one_pass is False:
            pipeline = "two_pass"
        else:
            pipeline = "one_pass"
    return PolicyConfig(
        kind="fier", budget=budget, group=group, skip_layers=skip_layers,
        sink=sink, recent=recent, pipeline=pipeline, layout=layout,
    )


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    temperature: float = 0.0   # 0 → greedy
    top_k: int = 0             # 0 → no truncation


def sample_token(rng, logits: jax.Array, cfg: SamplingConfig) -> jax.Array:
    if cfg.temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    l = logits / cfg.temperature
    if cfg.top_k > 0:
        kth = jax.lax.top_k(l, cfg.top_k)[0][..., -1:]
        l = jnp.where(l < kth, -1e30, l)
    return jax.random.categorical(rng, l, axis=-1).astype(jnp.int32)


def _cache_batch_axes(bundle: ModelBundle, capacity: int) -> Any:
    """Pytree of batch-axis indices, discovered by shape-diffing."""
    c2 = jax.eval_shape(lambda: bundle.init_cache(2, capacity, 0))
    c3 = jax.eval_shape(lambda: bundle.init_cache(3, capacity, 0))

    def axis(a, b):
        diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
        if len(diffs) != 1:
            raise ValueError(f"ambiguous batch axis: {a.shape} vs {b.shape}")
        return diffs[0]

    return jax.tree.map(axis, c2, c3)


class Engine:
    """Batched generation engine with continuous-batching slot management."""

    def __init__(
        self,
        bundle: ModelBundle,
        *,
        n_slots: int,
        capacity: int,
        sampling: SamplingConfig = SamplingConfig(),
        donate_cache: bool = True,
        seed: int = 0,
        degrade_floor: int = 64,
        restore_free_frac: float = 0.5,
        obs: Observability | None = None,
        offload_blocks: int = 0,
        prefix_ttl: float | None = None,
        recall_cost: float = 1.0,
        shard: Any = None,
        dcfg: Any = None,
    ):
        self.bundle = bundle
        # observability bundle (DESIGN.md §Observability): shared metrics
        # registry + tracer.  The default is the disabled bundle — no-op
        # instruments, null tracer — so an un-instrumented engine runs
        # the identical host path and jitted functions as before.
        self.obs = obs if obs is not None else Observability.disabled()
        self.n_slots = n_slots
        self.capacity = capacity
        self.sampling = sampling
        # fallback sampling rng: split per decode call so stochastic
        # sampling never reuses a key (callers may still pass rng=...)
        self._rng = jax.random.PRNGKey(seed)
        pol = bundle.policy
        self.paged = bool(pol is not None and pol.layout == "paged")
        # mesh sharding (DESIGN.md §Sharded serving): `shard` is the
        # kvcache.sharded.ShardSpec the bundle's plan carries; `dcfg` is
        # kept so budget-ladder bundle rebuilds preserve the sharding
        self.shard = shard
        self._dcfg = dcfg
        self._n_dp = shard.n_dp if shard is not None else 1
        if shard is not None and not self.paged:
            raise ValueError("mesh-sharded serving requires layout='paged'")
        if self._n_dp > 1 and n_slots % self._n_dp:
            raise ValueError(
                f"n_slots {n_slots} not divisible by {self._n_dp} DP shards"
            )
        self._slots_per_shard = n_slots // max(1, self._n_dp)
        if bundle.plan is not None:
            # fail fast at engine construction instead of deep inside the
            # first decode kernel (budget/sink/recent vs capacity)
            bundle.plan.validate_capacity(capacity)
        self._prefill = jax.jit(partial(bundle.prefill, capacity=capacity))
        self._donate = (2,) if donate_cache else ()
        self._decode, self._decode_active = self._make_decode_fns(bundle)

        # graceful-degradation budget ladder (DESIGN.md §Serving fault
        # tolerance): under pool pressure the scheduler halves the
        # retrieval budget down to ``degrade_floor`` (rebuilding the
        # decode fns from a plan-validated policy), restoring the full
        # budget once the free pool recovers past ``restore_free_frac``
        self.base_budget = pol.budget if pol is not None else 0
        self.current_budget = self.base_budget
        self.degrade_floor = max(1, degrade_floor)
        self.restore_free_frac = restore_free_frac
        self.downshifts = 0
        self.restores = 0
        self.blocks_shed = 0
        # prefill/prefix accounting lives on both layouts (engine_stats()
        # reports it for slab engines too; prefix_hits stays 0 there — the
        # prefix cache is a paged-pool feature)
        self.prefill_count = 0
        self.prefix_hits = 0
        self._budget_fns = {self.base_budget: (self._decode, self._decode_active)}

        # chunked prefill (ContinuousScheduler's token quantum): one jitted
        # step per (final?) flavour — jax retraces per chunk length
        self._chunk_jits: dict[bool, Any] = {}
        self._chunk_keys: dict[int, list[int]] = {}
        self._set_length = jax.jit(self._set_length_impl, donate_argnums=(0,))

        if self.paged:
            # paged mode: slot insertion scatters prefix blocks into the
            # shared pool through the allocator instead of writing one
            # batch row, so the batch-axis discovery is neither possible
            # (pool leaves have no batch axis) nor needed
            self.block_size = pol.block_size
            if capacity % self.block_size:
                raise ValueError(
                    f"capacity {capacity} not divisible by "
                    f"block_size {self.block_size}"
                )
            self.n_btab = capacity // self.block_size
            # sharded pools reserve one null block per DP shard
            self.pool_blocks = pol.pool_blocks or (
                n_slots * self.n_btab + max(1, self._n_dp)
            )
            if self._n_dp > 1 and self.pool_blocks % self._n_dp:
                raise ValueError(
                    f"pool_blocks {self.pool_blocks} not divisible by "
                    f"{self._n_dp} DP shards"
                )
            if self.pool_blocks // max(1, self._n_dp) - 1 < self.n_btab:
                # undersized pool: a request can outgrow the pool before
                # reaching capacity.  Previously a hard error ("a lone
                # request could deadlock the scheduler") — the scheduler
                # now retires such requests with a structured `rejected`
                # outcome (livelock detection + admission-time pool-bound
                # check), so the configuration is merely degraded
                import warnings

                warnings.warn(
                    f"pool_blocks={self.pool_blocks} cannot hold one "
                    f"worst-case context ({self.n_btab} blocks + null): "
                    f"requests outgrowing the pool will be retired as "
                    f"rejected instead of running to capacity"
                )
            # two-tier KV reuse (DESIGN.md §KV reuse tiers): the trie-
            # backed allocator is tier 1 (free-but-cached device blocks,
            # TTL-aged on the scheduler's virtual clock); an optional
            # host-DRAM tier receives LRU/TTL-evicted blocks and recalls
            # them bit-identically at admission time
            self.prefix_ttl = prefix_ttl
            self.recall_cost = float(recall_cost)
            self.allocator = self._make_allocator()
            self.offload: HostOffloadTier | None = (
                HostOffloadTier(offload_blocks) if offload_blocks > 0 else None
            )
            self.allocator.record_evictions = self.offload is not None
            self._pool_clock = None
            self.prefix_partial_hits = 0
            self.blocks_recalled = 0
            self.tokens_recalled = 0
            self.tokens_recomputed = 0
            self._recall_units = 0.0
            self._seq: dict[int, SeqBlocks] = {}
            self._prompt_logits: OrderedDict[int, np.ndarray] = OrderedDict()
            self._paged_scatter = jax.jit(
                self._paged_scatter_impl, donate_argnums=(0,)
            )
            self._read_block = jax.jit(self._read_block_impl)
            self._write_block = jax.jit(
                self._write_block_impl, donate_argnums=(0,)
            )
            self._set_slot_state = jax.jit(
                self._set_slot_state_impl, donate_argnums=(0,)
            )
            self._set_table_entry = jax.jit(
                self._set_table_entry_impl, donate_argnums=(0,)
            )
            self._copy_block = jax.jit(self._copy_block_impl, donate_argnums=(0,))
            self._zero_block = jax.jit(self._zero_block_impl, donate_argnums=(0,))
        else:
            self.offload = None
            self._batch_axes = _cache_batch_axes(bundle, capacity)
            self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))
        self._corrupt_meta = jax.jit(self._corrupt_meta_impl, donate_argnums=(0,))
        _LIVE_ENGINES.add(self)

    def _make_decode_fns(self, bundle: ModelBundle):
        """Jitted (decode, decode_active) pair for one bundle — rebuilt
        per budget rung by the degradation ladder (the cache pytree is
        budget-independent, so swapping fns never invalidates a cache)."""
        dec = jax.jit(bundle.decode_step, donate_argnums=self._donate)

        def _decode_active_impl(params, tokens, cache, active):
            old_len = cache["length"]
            logits, new_cache = bundle.decode_step(params, tokens, cache)
            new_cache = dict(
                new_cache, length=jnp.where(active, new_cache["length"], old_len)
            )
            return logits, new_cache

        return dec, jax.jit(_decode_active_impl, donate_argnums=self._donate)

    # ------------------------------------------------------- shard routing
    def _make_allocator(self):
        """The host-side allocator for the current layout: one pool, or
        one pool per DP shard behind the global-id wrapper."""
        if self._n_dp > 1:
            from repro.kvcache.sharded import ShardedBlockAllocator

            return ShardedBlockAllocator(
                self.pool_blocks, self.block_size, self._n_dp,
                park_ttl=self.prefix_ttl,
            )
        return BlockAllocator(
            self.pool_blocks, self.block_size, park_ttl=self.prefix_ttl
        )

    def slot_shard(self, slot: int) -> int:
        """Home DP shard of ``slot`` (0 on unsharded engines).  Slots
        split into contiguous per-shard ranges matching the DP partition
        of the cache's slot axis, so a slot's blocks always come from —
        and its decode reads always stay on — one device group."""
        return slot // self._slots_per_shard if self._n_dp > 1 else 0

    def _alloc_block(self, slot: int) -> int | None:
        if self._n_dp > 1:
            return self.allocator.alloc(self.slot_shard(slot))
        return self.allocator.alloc()

    def _lookup_block(self, key: int, slot: int) -> int | None:
        if self._n_dp > 1:
            return self.allocator.lookup(key, self.slot_shard(slot))
        return self.allocator.lookup(key)

    def _peek_blocks(self, keys, slot: int) -> tuple[int, int]:
        if self._n_dp > 1:
            return self.allocator.peek(keys, self.slot_shard(slot))
        return self.allocator.peek(keys)

    @classmethod
    def build(
        cls,
        cfg,
        *,
        n_slots: int,
        capacity: int,
        policy: PolicyConfig | None = None,
        sampling: SamplingConfig = SamplingConfig(),
        layout: str | None = None,
        block_size: int = 32,
        pool_blocks: int = 0,
        degrade_floor: int = 64,
        restore_free_frac: float = 0.5,
        obs: Observability | None = None,
        offload_blocks: int = 0,
        prefix_ttl: float | None = None,
        recall_cost: float = 1.0,
        mesh=None,
        shard_mode: str = "exact",
        **build_kwargs,
    ) -> "Engine":
        """Build bundle + engine with the serving defaults: when ``policy``
        is None the one-pass FIER fast path (``serving_policy()``) is
        used, with the budget clamped to ``capacity`` (a budget larger
        than the cache would otherwise fail plan validation).

        ``mesh=`` (DESIGN.md §Sharded serving) shards the paged pool over
        the mesh: axes named ``'model'`` run KV-head tensor parallelism,
        axes named ``'data'`` run slot/batch data parallelism.  The spec
        rides on the ``DecodePlan`` (validated against each backend's
        ``supports_sharding``) and the engine's allocator becomes
        per-shard (``kvcache.sharded.ShardedBlockAllocator``).

        ``layout='paged'`` switches the cache to the block-pool layout
        (``pool_blocks`` physical blocks of ``block_size`` tokens, prefix
        sharing + copy-on-write; see DESIGN.md §Paged KV cache), so HBM
        is bounded by *tokens resident* instead of n_slots × worst-case
        capacity.  ``pool_blocks=0`` keeps the worst-case pool size (no
        memory saving, useful for A/B testing the layouts)."""
        from repro.models import build_model

        if "paged" in build_kwargs:
            # pre-registry kwarg: forward onto layout= with a deprecation
            # warning instead of dying in build_model's signature
            from repro.core.policy import _warn_deprecated

            _warn_deprecated(
                "Engine.build's `paged` boolean", "layout='paged'"
            )
            if build_kwargs.pop("paged") and layout is None:
                layout = "paged"
                # legacy semantics: the pre-registry paged dispatch
                # ignored the one_pass flag, so a two_pass policy paged
                # through this deprecated kwarg keeps serving via the
                # one-pass kernels instead of tripping the
                # (paged, two_pass) capability-matrix hole.  The new
                # layout= parameter does NOT remap — an explicit
                # two_pass+paged plan raises UnsupportedPlanError.
                if policy is not None and policy.pipeline == "two_pass":
                    policy = dataclasses.replace(policy, pipeline="one_pass")
        if policy is not None:
            pol = policy
        else:
            base = serving_policy()
            pol = dataclasses.replace(base, budget=min(base.budget, capacity))
        if layout is not None and layout != pol.layout:
            pol = dataclasses.replace(
                pol, layout=layout, block_size=block_size,
                pool_blocks=pool_blocks,
            )
        spec = None
        if mesh is not None:
            from repro.kvcache.sharded import ShardSpec
            from repro.models.attention import DistConfig

            if pol.layout != "paged":
                raise ValueError(
                    "Engine.build(mesh=...) shards the paged pool; pass "
                    "layout='paged'"
                )
            names = tuple(mesh.axis_names)
            unknown = [a for a in names if a not in ("model", "data")]
            if unknown:
                raise ValueError(
                    f"mesh axes must be named 'model' (TP over KV heads) "
                    f"or 'data' (DP over slots); got {unknown}"
                )
            spec = ShardSpec(
                mesh=mesh,
                tp_axes=tuple(a for a in names if a == "model"),
                dp_axes=tuple(a for a in names if a == "data"),
                mode=shard_mode,
            )
            if cfg.n_kv_heads % spec.n_tp:
                raise ValueError(
                    f"n_kv_heads {cfg.n_kv_heads} not divisible by TP "
                    f"degree {spec.n_tp} (mesh axes "
                    f"{spec.tp_axes!r})"
                )
            # mesh=None on the DistConfig: the paged shard path carries
            # its mesh on the spec; DistConfig.mesh would additionally
            # arm the slab sequence-sharding machinery (activation
            # constraints over the 'model' axis), whose partitioned
            # prefill reductions are not bit-identical to the oracle
            build_kwargs["dcfg"] = DistConfig(shard=spec)
        bundle = build_model(cfg, pol, **build_kwargs)
        return cls(
            bundle, n_slots=n_slots, capacity=capacity, sampling=sampling,
            degrade_floor=degrade_floor, restore_free_frac=restore_free_frac,
            obs=obs, offload_blocks=offload_blocks, prefix_ttl=prefix_ttl,
            recall_cost=recall_cost, shard=spec,
            dcfg=build_kwargs.get("dcfg"),
        )

    # ------------------------------------------------------------ lifecycle
    def new_cache(self, length: int = 0):
        if self.current_budget != self.base_budget:
            # a degraded budget never outlives its serving session
            self.restore_budget()
        if self.paged:
            # the pool restarts empty: reset the allocator and drop the
            # prompt caches (their contents describe the old pool / the
            # params used with it)
            self.allocator = self._make_allocator()
            if self.offload is not None:
                # the host tier restarts empty too: sessions must not see
                # KV produced under another session's params/budget
                self.offload = HostOffloadTier(self.offload.capacity_blocks)
            self.allocator.record_evictions = self.offload is not None
            if self._pool_clock is not None:
                self.set_pool_clock(self._pool_clock)
            self._recall_units = 0.0
            self._seq = {}
            self._prompt_logits = OrderedDict()
        cache = self.bundle.init_cache(self.n_slots, self.capacity, length)
        if self.shard is not None:
            from repro.kvcache.sharded import shard_cache

            cache = shard_cache(cache, self.shard)
        return cache

    def prefill_batch(self, params, batch):
        """Whole-batch prefill (offline / static batching path)."""
        if self.paged:
            raise NotImplementedError(
                "paged engines insert requests one by one (Engine.insert / "
                "ContinuousScheduler); whole-batch prefill returns a slab "
                "cache the paged decode step cannot consume"
            )
        return self._prefill(params, batch)

    def _insert_impl(self, batched_cache, single_cache, slot):
        def put(dest, src, ax):
            return jax.lax.dynamic_update_index_in_dim(dest, src[0], slot, ax)

        return jax.tree.map(put, batched_cache, single_cache, self._batch_axes)

    def insert(self, params, batched_cache, tokens_1xS, length: int, slot: int, extras=None):
        """Prefill one request and place it into ``slot``.  Returns
        (first sampled token logits, updated batched cache).

        Paged mode: allocates/shares blocks through the allocator; a
        full-prompt prefix hit skips the prefill computation entirely
        (the first-token logits are replayed from the prompt cache)."""
        if self.paged:
            return self._insert_paged(
                params, batched_cache, tokens_1xS, length, slot, extras
            )
        batch = {"tokens": tokens_1xS, "lengths": jnp.array([length], jnp.int32)}
        if extras:
            batch.update(extras)
        logits, single = self._prefill(params, batch)
        self.prefill_count += 1
        return logits, self._insert(batched_cache, single, jnp.int32(slot))

    # ------------------------------------------------------- paged lifecycle
    def _paged_scatter_impl(self, cache, single, row, wmask, slot, length):
        """Scatter a prefilled single-request slab cache into the pool.

        ``row`` [n_btab] int32: this request's physical block ids (null-
        padded); ``wmask`` [n_btab] bool: which of them to actually write
        (False = prefix-shared block, its identical contents are already
        resident — the write is redirected to the null block).
        """
        ids = jnp.where(wmask, row, NULL_BLOCK)

        def put(pool, slab):
            # pool [L, N, pb, ...]; slab [L, 1, n_btab·pb, ...]
            L, _, pb = pool.shape[:3]
            if L == 0:
                # zero-layer stack (e.g. the "front" pool under
                # kind="full", where every layer is a rest layer) — the
                # -1 reshape below would divide by zero
                return pool
            blocks = slab.reshape(L, -1, pb, *pool.shape[3:])
            return pool.at[:, ids].set(blocks.astype(pool.dtype))

        pools = {"front": cache["front"], "rest": cache["rest"]}
        slabs = {"front": single["front"], "rest": single["rest"]}
        out = jax.tree.map(put, pools, slabs)
        return dict(
            cache,
            front=out["front"],
            rest=out["rest"],
            block_table=cache["block_table"].at[slot].set(row),
            length=cache["length"].at[slot].set(length),
        )

    def _set_length_impl(self, cache, slot, val):
        return dict(cache, length=cache["length"].at[slot].set(val))

    def _set_slot_state_impl(self, cache, slot, row, length):
        return dict(
            cache,
            block_table=cache["block_table"].at[slot].set(row),
            length=cache["length"].at[slot].set(length),
        )

    def _set_table_entry_impl(self, cache, slot, j, bid):
        return dict(
            cache, block_table=cache["block_table"].at[slot, j].set(bid)
        )

    def _copy_block_impl(self, cache, src, dst):
        """Copy-on-write: duplicate pool block ``src`` into ``dst`` across
        every layer of every pool leaf (K/V and the code side-car)."""

        def cp(pool):
            return pool.at[:, dst].set(pool[:, src])

        return dict(
            cache,
            front=jax.tree.map(cp, cache["front"]),
            rest=jax.tree.map(cp, cache["rest"]),
        )

    def _zero_block_impl(self, cache, bid):
        """Scrub a recycled block before a decode-time append lands in it.
        The token-append metadata update *merges* with the group stats
        already in the block, so a recycled block's stale stats would leak
        into the new tokens' quantization scales — outputs would depend on
        pool recycling history.  Zeroing restores the never-used-block
        contents, making decode bit-identical regardless of pool pressure."""

        def z(pool):
            return pool.at[:, bid].set(jnp.zeros_like(pool[:, bid]))

        return dict(
            cache,
            front=jax.tree.map(z, cache["front"]),
            rest=jax.tree.map(z, cache["rest"]),
        )

    def _read_block_impl(self, cache, bid):
        """Slice one block's rows out of every pool leaf (K/V and the FIER
        side-car) — the D2H half of an offload save."""

        def rd(pool):
            return pool[:, bid]

        return {
            "front": jax.tree.map(rd, cache["front"]),
            "rest": jax.tree.map(rd, cache["rest"]),
        }

    def _write_block_impl(self, cache, payload, bid):
        """Commit a recalled block payload into pool row ``bid`` — the H2D
        half of a recall.  Payload layout is exactly ``_read_block``'s
        output, so an offload round trip is bit-identical."""

        def wr(pool, blk):
            return pool.at[:, bid].set(blk.astype(pool.dtype))

        out = jax.tree.map(
            wr,
            {"front": cache["front"], "rest": cache["rest"]},
            {"front": payload["front"], "rest": payload["rest"]},
        )
        return dict(cache, front=out["front"], rest=out["rest"])

    # ----------------------------------------------------- host offload tier
    def _drain_evictions(self, cache):
        """Snapshot just-evicted prefix blocks into the host tier.  Must
        run after the allocator operation that evicted and *before* any
        device write to the reclaimed rows — at this point the pool rows
        still hold the evicted contents."""
        if self.offload is None:
            return cache
        for ev in self.allocator.take_evicted():
            if self.allocator.key_resident(ev.key):
                # sharded pools can register the same content key on
                # several DP shards; a key still resident on *any* shard
                # must not move to the host tier (cross-tier
                # single-ownership — audit checks host ∩ device = ∅).
                # Conservative: the other shard's copy serves future hits
                continue
            payload = to_host(self._read_block(cache, jnp.int32(ev.bid)))
            self.offload.save(ev.key, ev.parent_key, payload, reason=ev.reason)
            if self.obs.enabled:
                self.obs.metrics.counter(
                    "offload_saves_total",
                    "blocks demoted to the host tier").inc()
        return cache

    def sweep_parked(self, cache):
        """TTL sweep of tier-1 parked blocks — the scheduler calls this
        once per step on its virtual clock.  Expired blocks demote to the
        host tier (when attached) before their rows become reusable.
        Returns (n_expired, cache)."""
        if not self.paged or self.allocator.park_ttl is None:
            return 0, cache
        n = self.allocator.expire_parked()
        if n:
            cache = self._drain_evictions(cache)
        return n, cache

    def _recall_extension(self, cache, keys, blocks, L, slot):
        """Extend a device prefix match through the host tier: allocate a
        fresh device block per resident host key (capped so the final
        chunk still computes ≥ 1 token), stream the payloads back with
        double-buffered ``device_put``s, and re-register each block under
        its original parent linkage — bit-identical to never having been
        evicted.  Partial recall is fine: an alloc failure mid-walk keeps
        what was recalled and recomputes the rest.  Mutates ``blocks`` in
        place; returns the updated cache."""
        if self.offload is None:
            return cache
        max_blocks = (L - 1) // self.block_size
        ext = self.offload.match_extension(keys, len(blocks))
        ext = ext[: max_blocks - len(blocks)]
        if not ext:
            return cache
        fresh: list[int] = []
        for _ in ext:
            bid = self._alloc_block(slot)
            if bid is None:
                break
            fresh.append(bid)
        # evictions caused by the recall allocations themselves demote
        # before we overwrite the reclaimed rows with recalled payloads
        cache = self._drain_evictions(cache)
        if not fresh:
            return cache
        hbs = [self.offload.pop(k) for k in ext[: len(fresh)]]
        t0 = time.monotonic()
        n_done = 0
        for i, (bid, payload) in enumerate(
            double_buffered_puts((b, hb.payload) for b, hb in zip(fresh, hbs))
        ):
            cache = self._write_block(cache, payload, jnp.int32(bid))
            self.allocator.register(
                bid, hbs[i].key, parent_key=hbs[i].parent_key
            )
            blocks.append(bid)
            n_done += 1
        wall = time.monotonic() - t0
        self.offload.recall_wall_s += wall
        self.blocks_recalled += n_done
        self.tokens_recalled += n_done * self.block_size
        self._recall_units += self.recall_cost * n_done
        if self.obs.enabled:
            self.obs.tracer.instant(
                "blocks_recalled", cat="offload", blocks=n_done)
            self.obs.metrics.histogram(
                "offload_recall_seconds",
                "wall time of host-tier block recalls").observe(wall)
        return cache

    def set_pool_clock(self, clock) -> None:
        """Point the allocator trie and host tier at an external monotone
        clock (the scheduler's virtual token clock).  Remembered across
        ``new_cache`` resets, which rebuild both tiers."""
        self._pool_clock = clock
        self.allocator.set_clock(clock)
        if self.offload is not None:
            self.offload.set_clock(clock)

    def take_recall_units(self) -> float:
        """Drain the virtual-clock cost of recalls since the last call.
        The scheduler charges it to vtime: recalling a block costs
        ``recall_cost`` units against the ``block_size`` prefill-token
        units it saved."""
        u, self._recall_units = self._recall_units, 0.0
        return u

    def try_prefix_replay(self, cache, tokens, slot: int):
        """Full-prompt prefix hit: every block resident AND the first-token
        logits cached under the full-prompt key — place the slot with zero
        prefill FLOPs (references taken on every block, logits replayed
        from the prompt cache).  Returns (logits | None, cache); None
        means no full hit and nothing was changed."""
        if not self.paged:
            return None, cache
        toks = [int(t) for t in tokens]
        keys = block_hash_chain(toks, self.block_size)
        nb = len(keys)
        # empty prompt: no blocks, no hash chain — nothing to replay
        if not keys or keys[-1] not in self._prompt_logits:
            return None, cache
        n_hit, _ = self._peek_blocks(keys, slot)
        if n_hit < nb:
            return None, cache
        blocks = [self._lookup_block(key, slot) for key in keys]
        self.prefix_hits += 1
        self._prompt_logits.move_to_end(keys[-1])
        row = np.zeros((self.n_btab,), np.int32)
        row[:nb] = blocks
        cache = self._set_slot_state(
            cache, jnp.int32(slot), jnp.asarray(row), jnp.int32(len(toks))
        )
        self._seq[slot] = SeqBlocks(blocks=blocks, length=len(toks))
        return jnp.asarray(self._prompt_logits[keys[-1]]), cache

    def _insert_paged(self, params, cache, tokens_1xS, length, slot, extras):
        toks = [int(t) for t in np.asarray(tokens_1xS)[0, :length]]
        keys = block_hash_chain(toks, self.block_size)
        nb = len(keys)
        if nb > self.n_btab:
            raise ValueError(
                f"prompt of {length} tokens exceeds capacity {self.capacity}"
            )
        if slot in self._seq:
            raise ValueError(f"slot {slot} still holds blocks; release first")
        logits, cache = self.try_prefix_replay(cache, toks, slot)
        if logits is not None:
            return logits, cache
        # longest shared prefix: take a reference on every hit block
        blocks: list[int] = []
        for key in keys:
            bid = self._lookup_block(key, slot)
            if bid is None:
                break
            blocks.append(bid)
        n_hit = len(blocks)
        full_key = keys[-1] if keys else None
        row = np.zeros((self.n_btab,), np.int32)

        for _ in range(n_hit, nb):
            bid = self._alloc_block(slot)
            if bid is None:
                for b in blocks:
                    self.allocator.free(b)
                raise PoolExhausted(
                    "block pool exhausted during insert — admit on "
                    "Engine.blocks_needed() <= Engine.free_blocks first"
                )
            blocks.append(bid)
        # demote evicted prefix blocks before the scatter overwrites them
        cache = self._drain_evictions(cache)
        batch = {"tokens": tokens_1xS, "lengths": jnp.array([length], jnp.int32)}
        if extras:
            batch.update(extras)
        logits, single = self._prefill(params, batch)
        self.prefill_count += 1
        # monolithic prefill recomputes the whole prompt (the scatter only
        # skips *writes* for hit blocks) — chunked admission is the path
        # that converts prefix/host hits into skipped FLOPs
        self.tokens_recomputed += length
        row[:nb] = blocks
        wmask = np.zeros((self.n_btab,), bool)
        wmask[n_hit:nb] = True
        cache = self._paged_scatter(
            cache, {"front": single["front"], "rest": single["rest"]},
            jnp.asarray(row), jnp.asarray(wmask), jnp.int32(slot),
            jnp.int32(length),
        )
        for i in range(n_hit, nb):
            self.allocator.register(
                blocks[i], keys[i], parent_key=keys[i - 1] if i else None
            )
        if full_key is not None:
            self._prompt_logits[full_key] = np.asarray(logits)
            while len(self._prompt_logits) > MAX_CACHED_PROMPT_LOGITS:
                self._prompt_logits.popitem(last=False)
        self._seq[slot] = SeqBlocks(blocks=blocks, length=length)
        return logits, cache

    @property
    def free_blocks(self) -> int:
        return self.allocator.n_free

    def blocks_needed(self, tokens) -> int:
        """Fresh pool blocks an admission of ``tokens`` would consume
        (prefix-cache hits subtracted, free-cached revivals charged)."""
        keys = block_hash_chain(tokens, self.block_size)
        return self.allocator.blocks_needed(len(tokens), keys)

    # ------------------------------------------------------- chunked prefill
    def _chunk_fn(self, final: bool):
        fn = self._chunk_jits.get(final)
        if fn is None:
            if self.bundle.prefill_chunk is None:
                raise NotImplementedError(
                    f"model family {self.bundle.cfg.family!r} has no chunked "
                    f"prefill; use monolithic Engine.insert"
                )
            fn = jax.jit(
                partial(self.bundle.prefill_chunk, final=final),
                donate_argnums=(2,),
            )
            self._chunk_jits[final] = fn
        return fn

    def blocks_needed_chunk(self, tokens, chunk_tokens: int) -> int:
        """Fresh pool blocks needed to *begin* a chunked admission of
        ``tokens`` and run its first chunk — the chunked analogue of
        ``blocks_needed`` (resume-prefix hits discounted, free-cached
        revivals charged).  The quantum scheduler admits on this and grows
        the allocation chunk by chunk."""
        L = len(tokens)
        keys = block_hash_chain(tokens, self.block_size)
        flags = self.allocator.peek_prefix(keys)
        # begin_chunked never resumes past L-1 (the final chunk must run
        # at least one token to produce logits): drop tail hits
        while flags and len(flags) * self.block_size >= L:
            flags.pop()
        # host-tier extension: each recalled block needs a fresh device
        # block (counted inside nb - len(flags) below, since the resume
        # point moves past them)
        n_host = 0
        if self.offload is not None:
            ext = self.offload.match_extension(keys, len(flags))
            cap = (L - 1) // self.block_size - len(flags)
            n_host = min(len(ext), max(0, cap))
        end = min((len(flags) + n_host) * self.block_size + chunk_tokens, L)
        nb = -(-end // self.block_size)
        return (nb - len(flags)) + sum(flags)

    def begin_chunked(self, cache, slot: int, tokens):
        """Open a chunked insertion of the full prompt ``tokens`` into
        ``slot``.  Returns (resume, cache): the position the first
        ``prefill_chunk`` call must start from.

        Paged: takes references on prefix-cache hit blocks (capped at the
        last whole block *before* the prompt end, so the final chunk
        always computes logits) and seeds the slot's host block list —
        the device table row stays zeroed until the final chunk, so
        interleaved decode steps route this slot's scratch writes to the
        null block.  Slab: parks the slot's length at ``capacity`` so the
        scratch writes clamp onto the last row (masked, and rewritten by
        the final chunk when the prompt fills the slab)."""
        if not self.paged:
            cache = self._set_length(
                cache, jnp.int32(slot), jnp.int32(self.capacity)
            )
            return 0, cache
        if slot in self._seq:
            raise ValueError(f"slot {slot} still holds blocks; release first")
        toks = [int(t) for t in tokens]
        keys = block_hash_chain(toks, self.block_size)
        if len(keys) > self.n_btab:
            raise ValueError(
                f"prompt of {len(toks)} tokens exceeds capacity {self.capacity}"
            )
        L = len(toks)
        blocks: list[int] = []
        for key in keys:
            bid = self._lookup_block(key, slot)
            if bid is None:
                break
            blocks.append(bid)
        while blocks and len(blocks) * self.block_size >= L:
            self.allocator.free(blocks.pop())
        # where the device trie runs out, the host tier may extend the
        # match: recalled blocks push the resume point further right
        cache = self._recall_extension(cache, keys, blocks, L, slot)
        resume = len(blocks) * self.block_size
        if resume:
            self.prefix_partial_hits += 1
        self._seq[slot] = SeqBlocks(blocks=blocks, length=resume)
        self._chunk_keys[slot] = keys
        return resume, cache

    def prefill_chunk(self, params, cache, slot: int, tokens, start: int, n: int):
        """Run one chunk — prompt positions [start, start+n) — of an open
        chunked insertion (``begin_chunked`` first).  Returns
        (ok, logits | None, cache): ok=False means the paged pool could
        not grow the allocation (nothing changed — abort or retry later);
        logits are produced only by the final chunk (start+n == len).

        Paged bookkeeping per chunk: fresh blocks are allocated all-or-
        nothing, and every block fully covered by completed chunks is
        hash-registered immediately — an aborted half-prefilled request
        parks its progress in the prefix cache and re-admits from the
        completed-chunk boundary instead of token 0."""
        toks = np.asarray(tokens, np.int32).reshape(-1)
        L = int(toks.shape[0])
        end = start + n
        if not (0 < n and end <= L <= self.capacity):
            raise ValueError(f"bad chunk [{start}, {end}) of {L} tokens")
        final = end == L
        batch = {
            "tokens": jnp.asarray(toks[None, start:end]),
            "start": jnp.int32(start),
            "slot": jnp.int32(slot),
            "total": jnp.int32(L),
        }
        if self.paged:
            seq = self._seq[slot]
            if start != seq.length:
                raise ValueError(
                    f"chunk starts at {start}, slot resident to {seq.length}"
                )
            nb_needed = -(-end // self.block_size)
            fresh: list[int] = []
            while len(seq.blocks) + len(fresh) < nb_needed:
                bid = self._alloc_block(slot)
                if bid is None:
                    for b in fresh:
                        self.allocator.free(b)
                    return False, None, cache
                fresh.append(bid)
            seq.blocks.extend(fresh)
            if fresh:
                # demote evicted prefix blocks before this chunk's appends
                # overwrite the reclaimed rows
                cache = self._drain_evictions(cache)
            row = np.zeros((self.n_btab,), np.int32)
            row[: len(seq.blocks)] = seq.blocks
            batch["table_row"] = jnp.asarray(row)
        logits, cache = self._chunk_fn(final)(params, batch, cache)
        if self.paged:
            seq.length = end
            self.tokens_recomputed += n
            keys = self._chunk_keys[slot]
            for j in range(end // self.block_size):
                self.allocator.register(
                    seq.blocks[j], keys[j],
                    parent_key=keys[j - 1] if j else None,
                )
            if final:
                if L % self.block_size:
                    self.allocator.register(
                        seq.blocks[-1], keys[-1],
                        parent_key=keys[-2] if len(keys) > 1 else None,
                    )
                self.prefill_count += 1
                self._prompt_logits[keys[-1]] = np.asarray(logits)
                while len(self._prompt_logits) > MAX_CACHED_PROMPT_LOGITS:
                    self._prompt_logits.popitem(last=False)
                del self._chunk_keys[slot]
        return True, logits, cache

    def abort_chunked(self, cache, slot: int):
        """Abandon an open chunked insertion (pool dry / preemption): drop
        the slot's block references — registered completed-chunk blocks
        park free-cached, so a re-admission resumes from the boundary."""
        self._chunk_keys.pop(slot, None)
        if self.paged:
            cache = self.release_slot(cache, slot)
        return cache

    def advance_slot(self, cache, slot: int):
        """Guarantee the next decode write of ``slot`` lands in a private,
        allocated block: allocate a fresh tail block on a block boundary,
        or copy-on-write a shared tail.  Returns (ok, cache); ok=False
        means the pool is dry — the caller preempts someone and retries.
        Must be called once per running slot before every decode step.
        """
        seq = self._seq[slot]
        pos = seq.length
        if pos >= self.capacity:
            # at capacity: the write routes to the null block; the
            # scheduler retires the request at this boundary
            return True, cache
        j, off = divmod(pos, self.block_size)
        if off == 0:
            bid = self._alloc_block(slot)
            if bid is None:
                return False, cache
            # recycled blocks carry stale K/V and group stats; the append-
            # time metadata update merges with what's resident, so scrub
            # (demoting any evicted prefix block first — zeroing destroys it)
            cache = self._drain_evictions(cache)
            cache = self._zero_block(cache, jnp.int32(bid))
            seq.blocks.append(bid)
            cache = self._set_table_entry(
                cache, jnp.int32(slot), jnp.int32(j), jnp.int32(bid)
            )
        else:
            b = seq.blocks[j]
            if self.allocator.ref[b] > 1:
                bid = self._alloc_block(slot)
                if bid is None:
                    return False, cache
                cache = self._drain_evictions(cache)
                cache = self._copy_block(cache, jnp.int32(b), jnp.int32(bid))
                self.allocator.free(b)
                self.allocator.cow_copies += 1
                seq.blocks[j] = bid
                cache = self._set_table_entry(
                    cache, jnp.int32(slot), jnp.int32(j), jnp.int32(bid)
                )
        seq.length = pos + 1
        return True, cache

    def release_slot(self, cache, slot: int):
        """Free a retired/preempted slot: drop the block references (hash-
        registered blocks park in the prefix cache) and zero the table
        row, so the slot's scratch decode writes hit the null block."""
        seq = self._seq.pop(slot, None)
        if seq is not None:
            for b in seq.blocks:
                if b != NULL_BLOCK:  # shed middle blocks leave null holes
                    self.allocator.free(b)
            cache = self._set_slot_state(
                cache, jnp.int32(slot),
                jnp.zeros((self.n_btab,), jnp.int32), jnp.int32(0),
            )
        return cache

    # legacy pool_stats key → canonical BlockAllocator.stats() name
    _POOL_STAT_ALIASES = {
        "blocks_in_use": "pool_blocks_in_use",
        "blocks_allocated": "pool_blocks_usable",
        "utilization": "pool_utilization",
        "peak_in_use": "pool_peak_in_use",
        "prefix_block_hits": "pool_prefix_block_hits",
        "cow_copies": "pool_cow_copies",
    }

    def engine_stats(self) -> dict:
        """Engine-level serving counters under their canonical (registry)
        names — the companion of ``BlockAllocator.stats()``."""
        out = dict(
            engine_prefills=self.prefill_count,
            engine_prefix_hits=self.prefix_hits,
            engine_budget_downshifts=self.downshifts,
            engine_budget_restores=self.restores,
            engine_blocks_shed=self.blocks_shed,
            engine_current_budget=self.current_budget,
        )
        if self.paged:
            out.update(
                engine_prefix_partial_hits=self.prefix_partial_hits,
                engine_blocks_recalled=self.blocks_recalled,
                engine_tokens_recalled=self.tokens_recalled,
                engine_tokens_recomputed=self.tokens_recomputed,
            )
        return out

    def pool_stats(self) -> dict:
        """Thin snapshot shim over the canonical accounting: legacy keys
        alias onto ``BlockAllocator.stats()`` / ``engine_stats()`` names
        (kept for existing callers; new code should read the canonical
        ``pool_*`` / ``engine_*`` names or the metrics registry)."""
        canon = self.allocator.stats()
        out = {k: canon[v] for k, v in self._POOL_STAT_ALIASES.items()}
        out.update(
            prefix_hits=self.prefix_hits,
            prefills=self.prefill_count,
            budget_downshifts=self.downshifts,
            budget_restores=self.restores,
            blocks_shed=self.blocks_shed,
            # parked-block aging (trie clock units) — passed through under
            # the canonical names; the legacy aliases predate the trie
            pool_parked_age_p50=canon["pool_parked_age_p50"],
            pool_parked_age_p90=canon["pool_parked_age_p90"],
            pool_parked_age_max=canon["pool_parked_age_max"],
            pool_ttl_evictions=canon["pool_ttl_evictions"],
        )
        return out

    def sample_pool_gauges(self) -> None:
        """Push the canonical pool + engine counters into the metrics
        registry as gauges (sampled by the scheduler once per step; no-op
        when observability is disabled)."""
        if not self.obs.metrics.enabled:
            return
        m = self.obs.metrics
        if self.paged:
            m.set_gauges(self.allocator.stats())
            if self._n_dp > 1:
                # per-shard series ride alongside the unlabeled aggregate
                # (existing consumers keep reading the label-free series)
                for i, st in enumerate(self.allocator.shard_stats()):
                    m.set_gauges(st, shard=str(i))
            if self.offload is not None:
                m.set_gauges(self.offload.stats())
        m.set_gauges(self.engine_stats())

    # --------------------------------------------- graceful budget degradation
    @property
    def degradable(self) -> bool:
        """Whether this engine's policy has a retrieval budget the ladder
        can downshift (fier/quest; 'full' reads everything by definition)."""
        pol = self.bundle.policy
        return pol is not None and pol.kind in ("fier", "quest")

    def _swap_budget(self, budget: int) -> None:
        """Point the decode fns at a bundle rebuilt with ``budget``.

        The rebuilt policy goes through ``DecodePlan.build`` (capability
        matrix + capacity bounds), so an invalid rung fails loudly here
        rather than inside a kernel.  Rungs are cached — thrashing between
        two budgets re-jits nothing.  The cache pytree does not depend on
        the budget, so the live cache carries across the swap.
        """
        fns = self._budget_fns.get(budget)
        if fns is None:
            from repro.models import build_model

            pol2 = dataclasses.replace(self.bundle.policy, budget=budget)
            DecodePlan.build(
                pol2, capacity=self.capacity,
                shard=self.shard if pol2.layout == "paged" else None,
            )
            # dcfg rides along so a degraded bundle keeps the mesh
            # sharding (dropping it would silently fall back to the
            # single-device paged path on a sharded cache)
            bundle2 = build_model(self.bundle.cfg, pol2, self._dcfg)
            fns = self._budget_fns[budget] = self._make_decode_fns(bundle2)
        self._decode, self._decode_active = fns
        self.current_budget = budget

    def downshift_budget(self) -> bool:
        """One rung down the ladder (halve, floored at ``degrade_floor``).
        False when already at the floor / not degradable."""
        if not self.degradable:
            return False
        new = max(self.degrade_floor, self.current_budget // 2)
        if new >= self.current_budget:
            return False
        prev = self.current_budget
        self._swap_budget(new)
        self.downshifts += 1
        if self.obs.enabled:
            self.obs.tracer.instant(
                "budget_downshift", cat="degradation",
                from_budget=prev, to_budget=new)
            self.obs.metrics.counter(
                "budget_downshifts_total",
                "degradation-ladder budget halvings").inc()
        return True

    def restore_budget(self) -> bool:
        """Back to the full configured budget (pressure cleared)."""
        if self.current_budget == self.base_budget:
            return False
        prev = self.current_budget
        self._swap_budget(self.base_budget)
        self.restores += 1
        if self.obs.enabled:
            self.obs.tracer.instant(
                "budget_restore", cat="degradation",
                from_budget=prev, to_budget=self.base_budget)
            self.obs.metrics.counter(
                "budget_restores_total",
                "degradation-ladder full-budget restores").inc()
        return True

    def maybe_restore_budget(self) -> bool:
        """Restore the full budget iff degraded and the free pool has
        recovered past ``restore_free_frac`` of the usable blocks."""
        if self.current_budget == self.base_budget or not self.paged:
            return False
        if self.allocator.n_free < self.restore_free_frac * self.allocator.usable:
            return False
        return self.restore_budget()

    def shed_middle_blocks(self, cache, slot: int):
        """Free the *middle* blocks of a running slot — the memory half of
        a budget downshift (the budget itself is read-side only; shrinking
        it frees nothing).  Keeps the sink blocks at the front and the
        recent-window + writable-tail blocks at the back — exactly the
        rows the degraded policy's guard-rails still read exactly — and
        replaces each shed entry with the null block (reads as zeros,
        masked-by-score like any unselected row).  Shared blocks are
        skipped (dropping one ref of a ref>1 block frees no memory, it
        only loses this slot's access); hash-registered blocks *are*
        shed — they park free-cached with contents intact, evictable for
        fresh allocations and still valid for prefix revival.
        Returns (blocks freed, cache)."""
        seq = self._seq.get(slot)
        pol = self.bundle.policy
        if seq is None or pol is None:
            return 0, cache
        bs = self.block_size
        keep_front = max(1, -(-pol.sink // bs))
        keep_tail = max(2, -(-(pol.recent + 1) // bs))
        freed = 0
        for j in range(keep_front, len(seq.blocks) - keep_tail):
            b = seq.blocks[j]
            if b == NULL_BLOCK or self.allocator.ref[b] > 1:
                continue
            seq.blocks[j] = NULL_BLOCK
            cache = self._set_table_entry(
                cache, jnp.int32(slot), jnp.int32(j), jnp.int32(NULL_BLOCK)
            )
            self.allocator.free(b)
            freed += 1
        self.blocks_shed += freed
        if freed and self.obs.enabled:
            self.obs.tracer.instant(
                "blocks_shed", cat="degradation", slot=slot, freed=freed)
            self.obs.metrics.counter(
                "blocks_shed_total",
                "middle blocks freed by budget degradation").inc(freed)
        return freed, cache

    # ----------------------------------------------------- faults & auditing
    def _corrupt_meta_impl(self, cache, idx):
        """Scramble the FIER side-car at axis-1 index ``idx`` of the rest
        pool — a physical block id (paged) or a slot's batch row (slab).
        Codes bit-flip and (scale, zero) are pushed away from their true
        values; everything stays finite (this fault class is *silent*
        retrieval-quality corruption, not the NaN watchdog's)."""
        rest = cache["rest"]
        if not isinstance(rest, dict) or "meta" not in rest:
            return cache
        from repro.core.quantize import QuantizedKeys

        m = rest["meta"]
        meta = QuantizedKeys(
            m.codes.at[:, idx].set(m.codes[:, idx] ^ jnp.uint8(0xA5)),
            m.scale.at[:, idx].set(-m.scale[:, idx] - 1.0),
            m.zero.at[:, idx].set(-m.zero[:, idx] + 1.0),
            m.group,
        )
        return dict(cache, rest=dict(rest, meta=meta))

    def corrupt_slot_metadata(self, cache, slot: int):
        """Chaos hook: corrupt the FIER metadata backing ``slot``.

        Paged mode targets a *privately held, unregistered* block
        (ref == 1, no prefix-cache hash) so the corruption cannot bleed
        into prefix-sharing requests or future prefix hits; when the slot
        holds no such block yet (fully shared prompt, no decode append),
        nothing happens and the caller retries later.  Slab mode scrambles
        the slot's own batch row.  Returns (corrupted?, cache)."""
        if not self.paged:
            if 0 <= slot < self.n_slots:
                return True, self._corrupt_meta(cache, jnp.int32(slot))
            return False, cache
        seq = self._seq.get(slot)
        if seq is None:
            return False, cache
        for b in reversed(seq.blocks):
            if (
                b != NULL_BLOCK
                and self.allocator.ref[b] == 1
                and self.allocator.key_of(b) is None
            ):
                return True, self._corrupt_meta(cache, jnp.int32(b))
        return False, cache

    def jit_cache_sizes(self) -> dict[str, int]:
        """Compile-cache entry counts of every jitted engine function —
        the overhead-guard tests' compile-count spy: enabling metrics or
        tracing must add ZERO entries to any of these (observability is
        host-side only and never enters a traced computation)."""
        fns: dict[str, Any] = {"prefill": self._prefill}
        for b, (dec, dec_act) in self._budget_fns.items():
            fns[f"decode[{b}]"] = dec
            fns[f"decode_active[{b}]"] = dec_act
        for final, fn in self._chunk_jits.items():
            fns[f"prefill_chunk[final={final}]"] = fn
        fns["set_length"] = self._set_length
        if self.paged:
            fns.update(
                paged_scatter=self._paged_scatter,
                set_slot_state=self._set_slot_state,
                set_table_entry=self._set_table_entry,
                copy_block=self._copy_block,
                zero_block=self._zero_block,
                read_block=self._read_block,
                write_block=self._write_block,
            )
        return {name: int(fn._cache_size()) for name, fn in fns.items()}

    def audit(self) -> None:
        """Cross-check the allocator against the engine's live sequences:
        every block reference the engine holds must be counted exactly by
        the allocator (ref-count conservation), on top of the allocator's
        internal invariants.  Raises ``AllocatorAuditError``; no-op for
        slab engines (nothing to leak)."""
        if not self.paged:
            return
        owners: Counter[int] = Counter()
        for seq in self._seq.values():
            for b in seq.blocks:
                if b != NULL_BLOCK:
                    owners[b] += 1
        host_keys = None
        if self.offload is not None:
            errs = self.offload.audit()
            if errs:
                raise AllocatorAuditError(
                    "host tier audit failed: " + "; ".join(errs)
                )
            host_keys = self.offload.keys()
        self.allocator.audit(dict(owners), host_keys=host_keys)

    def decode(self, params, tokens, cache, active=None, rng=None):
        """One decode step for all slots; inactive slots don't advance.

        tokens [n_slots] int32 → (next_tokens [n_slots], logits, cache).
        When ``rng`` is omitted, a fresh key is split off the engine's
        internal rng — every call samples with a distinct key (the old
        behaviour re-used ``PRNGKey(0)`` each step, so temperature > 0
        serving resampled the same draw forever).
        """
        if active is not None:
            # inactive slots' lengths are frozen inside the jitted step
            # (their cache writes are scratch, overwritten on insert)
            logits, new_cache = self._decode_active(params, tokens, cache, active)
        else:
            logits, new_cache = self._decode(params, tokens, cache)
        if rng is None:
            self._rng, rng = jax.random.split(self._rng)
        nxt = sample_token(rng, logits, self.sampling)
        return nxt, logits, new_cache

    # --------------------------------------------------------- conveniences
    def generate(
        self, params, prompts: jax.Array, lengths: jax.Array, max_new: int,
        extras=None, rng=None,
    ):
        """Static-batch generate: prefill the whole batch then decode
        ``max_new`` tokens.  prompts [B, S]; returns tokens [B, max_new].
        Without an explicit ``rng``, each call draws a fresh key off the
        engine rng (same contract as ``decode``)."""
        if self.paged:
            raise NotImplementedError(
                "paged engines generate through the ContinuousScheduler "
                "(per-request insert + block accounting), not the "
                "static-batch generate path"
            )
        if rng is None:
            self._rng, rng = jax.random.split(self._rng)
        batch = {"tokens": prompts, "lengths": lengths}
        if extras:
            batch.update(extras)
        logits, cache = self._prefill(params, batch)
        tok = sample_token(rng, logits, self.sampling)
        outs = [tok]
        for i in range(max_new - 1):
            rng, sub = jax.random.split(rng)
            tok, _, cache = self.decode(params, tok, cache, rng=sub)
            outs.append(tok)
        return jnp.stack(outs, axis=1)
