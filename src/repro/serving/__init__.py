from .engine import Engine, SamplingConfig, serving_policy
from .scheduler import ContinuousScheduler, Request

__all__ = ["ContinuousScheduler", "Engine", "Request", "SamplingConfig", "serving_policy"]
