from repro.obs import Observability

from .engine import Engine, SamplingConfig, serving_policy
from .faults import FAULT_KINDS, FaultSpec, ServingFaultInjector
from .health import (
    STATUSES,
    HealthMonitor,
    RequestOutcome,
    ServeResult,
    StepReport,
)
from .scheduler import ContinuousScheduler, Request

__all__ = [
    "FAULT_KINDS",
    "STATUSES",
    "ContinuousScheduler",
    "Engine",
    "FaultSpec",
    "HealthMonitor",
    "Observability",
    "Request",
    "RequestOutcome",
    "SamplingConfig",
    "ServeResult",
    "ServingFaultInjector",
    "StepReport",
    "serving_policy",
]
