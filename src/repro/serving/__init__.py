from .engine import Engine, SamplingConfig
from .scheduler import ContinuousScheduler, Request

__all__ = ["ContinuousScheduler", "Engine", "Request", "SamplingConfig"]
