"""Serving health primitives: the per-request outcome state machine, the
decode-step NaN/Inf watchdog, and the health counter/audit-cadence
bookkeeping the scheduler threads its fault-tolerance decisions through.

Every request leaves the scheduler through exactly one of the terminal
states in :data:`STATUSES` (DESIGN.md §Serving fault tolerance):

    finished          ran to max_new / eos / capacity
    rejected          could never be served (prompt > capacity, prompt
                      outgrows the whole block pool, repeated
                      self-preemption without progress)
    cancelled         caller withdrew it (``ContinuousScheduler.cancel``)
    deadline_exceeded its virtual-token-clock deadline passed while it
                      was queued / prefilling / decoding
    quarantined       the decode watchdog saw non-finite logits in its
                      slot and isolated it from the batch

The scheduler records a :class:`RequestOutcome` for every request (also
attached as ``Request.outcome``), so callers distinguish the states
structurally instead of parsing warnings.
"""
from __future__ import annotations

import dataclasses

import numpy as np

STATUSES = (
    "finished",
    "rejected",
    "cancelled",
    "deadline_exceeded",
    "quarantined",
)


@dataclasses.dataclass(frozen=True)
class RequestOutcome:
    """Terminal record for one request: how it left the scheduler."""

    rid: int
    status: str                  # one of STATUSES
    reason: str = ""             # human-readable detail
    tokens: int = 0              # generated tokens at retirement
    vtime: float = 0.0           # scheduler virtual-token clock at retirement
    slot: int | None = None      # decode slot held at retirement (None when
                                 # queued / mid-prefill) — quarantines and
                                 # preempt-retires are diagnosable from the
                                 # outcome record alone

    def __post_init__(self):
        if self.status not in STATUSES:
            raise ValueError(
                f"unknown outcome status {self.status!r}; one of {STATUSES}"
            )


class StepReport:
    """Return value of ``ContinuousScheduler.step``: truthy iff the step
    made progress (back-compat with the old bool), plus the outcomes of
    every request retired during the step."""

    __slots__ = ("progressed", "retired")

    def __init__(self, progressed: bool, retired: list[RequestOutcome]):
        self.progressed = bool(progressed)
        self.retired = retired

    def __bool__(self) -> bool:
        return self.progressed

    def __repr__(self) -> str:
        return f"StepReport(progressed={self.progressed}, retired={self.retired})"


class ServeResult(dict):
    """``run()``'s return value: a plain ``rid → generated tokens`` dict
    (back-compat — equality/iteration behave exactly like before) that
    additionally carries the structured per-request outcomes."""

    def __init__(self, outputs: dict, outcomes: dict[int, RequestOutcome]):
        super().__init__(outputs)
        self.outcomes = outcomes


def nonfinite_slots(logits: np.ndarray, slots) -> list[int]:
    """The decode watchdog check: which of ``slots`` have any NaN/Inf in
    their logits row.  ``logits`` [n_slots, V] (host array)."""
    bad = ~np.isfinite(logits).all(axis=-1)
    return [s for s in slots if bad[s]]


class HealthMonitor:
    """Counters + audit cadence for one serving session.

    ``counts`` mirrors the outcome state machine (one counter per status);
    the extra counters track the fault-tolerance machinery itself:
    quarantine events, deadline expiries by phase, allocator audits run.
    """

    def __init__(self, audit_every: int | None = None):
        self.audit_every = audit_every
        self.counts: dict[str, int] = {s: 0 for s in STATUSES}
        self.audits_run = 0
        self.self_preempt_retires = 0
        # structured event log: quarantines, preemptions, prefill aborts —
        # each a dict with at least {kind, slot, rid, reason}, so chaos
        # runs are diagnosable without parsing warning text
        self.events: list[dict] = []

    def record(self, outcome: RequestOutcome) -> None:
        self.counts[outcome.status] += 1

    def record_event(self, kind: str, *, slot: int | None = None,
                     rid: int | None = None, reason: str = "",
                     **detail) -> dict:
        """Log one structured health event (quarantine / preempt /
        prefill_abort / …) with its slot id, request id, and reason."""
        ev = dict(kind=kind, slot=slot, rid=rid, reason=reason, **detail)
        self.events.append(ev)
        return ev

    def event_counts(self) -> dict[str, int]:
        """Events-by-kind histogram of the structured log — the quick
        answer to "did the offload_drop / quarantine / prefill_abort
        machinery actually fire in this run?"."""
        out: dict[str, int] = {}
        for ev in self.events:
            out[ev["kind"]] = out.get(ev["kind"], 0) + 1
        return out

    def maybe_audit(self, engine, step: int) -> bool:
        """Run the engine's allocator audit every ``audit_every`` decode
        steps (no-op when disabled or the engine is not paged; for a
        two-tier engine the audit covers the device pool AND the host
        offload tier, including cross-tier key disjointness).  Raises
        ``AllocatorAuditError`` on an invariant violation."""
        if not self.audit_every or step == 0 or step % self.audit_every:
            return False
        engine.audit()
        self.audits_run += 1
        return True

    def summary(self) -> dict:
        return dict(self.counts, audits_run=self.audits_run,
                    self_preempt_retires=self.self_preempt_retires,
                    events=len(self.events))
