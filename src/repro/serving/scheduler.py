"""Continuous-batching scheduler: admits queued requests into free engine
slots, steps the whole batch, retires finished sequences.

Host-side orchestration only — every device-side op is a jitted Engine
call.  Straggler note (DESIGN.md §4): at pod scale the per-step barrier is
the decode psum; a slow host shows up as step-time EWMA inflation, which
``repro.runtime.fault.StragglerMonitor`` watches — the same monitor object
is reused here.

Paged engines change the admission contract: a request is admitted when a
*slot* is free AND the block pool can hold its prompt (prefix-cache hits
discounted) — batch size is bounded by tokens actually resident, not by
n_slots × worst-case capacity.  When the pool runs dry mid-decode (a
running request needs a fresh tail block and none is free), the scheduler
**preempts** the youngest running request: its blocks are freed and it is
re-queued at the head with its generated tokens folded into the prompt,
so the re-admission prefill recomputes the identical continuation (greedy
decoding: bit-identical outputs with or without preemption — covered in
tests/test_paged.py).
"""
from __future__ import annotations

import dataclasses
import warnings
from collections import deque
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import engine as engine_mod


@dataclasses.dataclass
class Request:
    rid: int
    tokens: list[int]               # prompt
    max_new: int = 32
    eos: int | None = None
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    rejected: bool = False          # prompt longer than engine capacity


class ContinuousScheduler:
    def __init__(
        self,
        engine,
        params,
        pad_prompt_to: int | None = None,
        rng: jax.Array | None = None,
    ):
        self.engine = engine
        self.params = params
        self.pad = pad_prompt_to
        self.free = list(range(engine.n_slots))
        self.running: dict[int, Request] = {}   # slot → request, admission order
        self.steps = 0
        self.occupancy: list[int] = []
        self.preemptions = 0
        # sampling rng, split once per admission/decode step: every sampled
        # token — including the prefill-produced first token — draws from
        # this stream (the old _admit always took argmax(logits), so
        # temperature > 0 deployments sampled the first token greedily)
        self._rng = rng if rng is not None else jax.random.PRNGKey(0)

    def _sample(self, logits) -> int:
        self._rng, k = jax.random.split(self._rng)
        return int(engine_mod.sample_token(k, logits, self.engine.sampling)[0])

    def _release(self, cache, slot: int):
        if self.engine.paged:
            cache = self.engine.release_slot(cache, slot)
        self.free.append(slot)
        return cache

    def _admit(self, queue: deque[Request], cache, cur_tokens):
        while queue and self.free:
            req = queue[0]
            # preempted requests carry their generated tokens: the
            # re-admission prompt is prompt + out so prefill recomputes
            # the cache the preemption dropped
            toks_list = req.tokens + req.out
            if len(toks_list) > self.engine.capacity:
                # a longer prompt would write out of range (the slab
                # path's dynamic_update_slice silently clamps onto live
                # rows): reject instead of corrupting the cache
                queue.popleft()
                warnings.warn(
                    f"request {req.rid}: prompt of {len(toks_list)} tokens "
                    f"exceeds engine capacity {self.engine.capacity}; rejected"
                )
                req.done = True
                req.rejected = True
                continue
            if (
                self.engine.paged
                and self.engine.blocks_needed(toks_list) > self.engine.free_blocks
            ):
                break  # pool full: wait for running requests to retire
            slot = self.free.pop()
            queue.popleft()
            toks = np.asarray(toks_list, np.int32)
            S = self.pad or len(toks)
            S = max(S, len(toks))
            padded = np.zeros((1, S), np.int32)
            padded[0, : len(toks)] = toks
            logits, cache = self.engine.insert(
                self.params, cache, jnp.asarray(padded), len(toks), slot
            )
            first = self._sample(logits)
            req.out.append(first)
            # the prefill-produced token counts: check termination before
            # the slot ever decodes.  at_capacity: a full-capacity prompt
            # has nowhere to write the next token's KV — retire now rather
            # than let the first decode step write out of range
            at_capacity = (
                len(req.tokens) + len(req.out) - 1 >= self.engine.capacity
            )
            if (
                len(req.out) >= req.max_new
                or (req.eos is not None and first == req.eos)
                or at_capacity
            ):
                req.done = True
                cache = self._release(cache, slot)
                continue
            cur_tokens[slot] = first
            self.running[slot] = req
        return cache

    def _preempt_youngest(self, queue: deque[Request], cache) -> tuple[int, Any]:
        """Free the most recently admitted running request and push it
        back to the queue head (its generated tokens become prompt suffix
        on re-admission).  Returns (victim slot, cache)."""
        slot = next(reversed(self.running))
        req = self.running.pop(slot)
        cache = self._release(cache, slot)
        queue.appendleft(req)
        self.preemptions += 1
        return slot, cache

    def _ensure_append_capacity(self, queue: deque[Request], cache):
        """Paged: every running slot must own a writable tail block before
        the decode step (fresh block on a boundary, copy-on-write on a
        shared tail).  Preempts youngest-first while the pool is dry."""
        for slot in list(self.running):
            while slot in self.running:
                ok, cache = self.engine.advance_slot(cache, slot)
                if ok:
                    break
                victim, cache = self._preempt_youngest(queue, cache)
                # if the dry slot itself was youngest, it is preempted
                # and the loop guard exits; it re-admits from the queue
        return cache

    def run(self, requests: Sequence[Request]) -> dict[int, list[int]]:
        # deque: _admit pops FIFO from the head — list.pop(0) was O(n) per
        # admit, O(n²) across a burst of queued requests
        queue = deque(requests)
        cache = self.engine.new_cache()
        cur = np.zeros((self.engine.n_slots,), np.int32)
        cache = self._admit(queue, cache, cur)
        while self.running or queue:
            if not self.running:
                # everything got preempted/retired while the queue head
                # waited on blocks; with the pool now empty it must fit
                cache = self._admit(queue, cache, cur)
                if not self.running:
                    if queue:
                        raise RuntimeError(
                            "scheduler stalled: queued request cannot be "
                            "admitted into an empty engine"
                        )
                    break
            if self.engine.paged:
                cache = self._ensure_append_capacity(queue, cache)
                if not self.running:
                    continue
            active_np = np.zeros((self.engine.n_slots,), bool)
            for s in self.running:
                active_np[s] = True
            self._rng, step_rng = jax.random.split(self._rng)
            nxt, _, cache = self.engine.decode(
                self.params, jnp.asarray(cur), cache,
                active=jnp.asarray(active_np), rng=step_rng,
            )
            nxt = np.asarray(nxt)
            self.steps += 1
            self.occupancy.append(len(self.running))
            for slot, req in list(self.running.items()):
                tok = int(nxt[slot])
                req.out.append(tok)
                cur[slot] = tok
                at_capacity = (
                    len(req.tokens) + len(req.out) - 1 >= self.engine.capacity
                )
                if (
                    len(req.out) >= req.max_new
                    or (req.eos is not None and tok == req.eos)
                    or at_capacity
                ):
                    req.done = True
                    del self.running[slot]
                    cache = self._release(cache, slot)
            cache = self._admit(queue, cache, cur)
        return {r.rid: r.out for r in requests}

    @property
    def mean_occupancy(self) -> float:
        return float(np.mean(self.occupancy)) if self.occupancy else 0.0
