"""Continuous-batching scheduler: admits queued requests into free engine
slots, steps the whole batch, retires finished sequences.

Host-side orchestration only — every device-side op is a jitted Engine
call.  Straggler note (DESIGN.md §4): at pod scale the per-step barrier is
the decode psum; a slow host shows up as step-time EWMA inflation, which
``repro.runtime.fault.StragglerMonitor`` watches — the same monitor object
is reused here.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    tokens: list[int]               # prompt
    max_new: int = 32
    eos: int | None = None
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousScheduler:
    def __init__(
        self,
        engine,
        params,
        pad_prompt_to: int | None = None,
        rng: jax.Array | None = None,
    ):
        self.engine = engine
        self.params = params
        self.pad = pad_prompt_to
        self.free = list(range(engine.n_slots))
        self.running: dict[int, Request] = {}   # slot → request
        self.steps = 0
        self.occupancy: list[int] = []
        # sampling rng, split once per decode step: consecutive steps of a
        # temperature > 0 deployment draw from distinct keys
        self._rng = rng if rng is not None else jax.random.PRNGKey(0)

    def _admit(self, queue: deque[Request], cache, cur_tokens):
        while queue and self.free:
            slot = self.free.pop()
            req = queue.popleft()
            toks = np.asarray(req.tokens, np.int32)
            S = self.pad or len(toks)
            S = max(S, len(toks))
            padded = np.zeros((1, S), np.int32)
            padded[0, : len(toks)] = toks
            logits, cache = self.engine.insert(
                self.params, cache, jnp.asarray(padded), len(toks), slot
            )
            first = int(jnp.argmax(logits[0]))
            req.out.append(first)
            # the prefill-produced token counts: check termination before
            # the slot ever decodes
            if len(req.out) >= req.max_new or (req.eos is not None and first == req.eos):
                req.done = True
                self.free.append(slot)
                continue
            cur_tokens[slot] = first
            self.running[slot] = req
        return cache

    def run(self, requests: Sequence[Request]) -> dict[int, list[int]]:
        # deque: _admit pops FIFO from the head — list.pop(0) was O(n) per
        # admit, O(n²) across a burst of queued requests
        queue = deque(requests)
        cache = self.engine.new_cache()
        cur = np.zeros((self.engine.n_slots,), np.int32)
        cache = self._admit(queue, cache, cur)
        while self.running or queue:
            active_np = np.zeros((self.engine.n_slots,), bool)
            for s in self.running:
                active_np[s] = True
            self._rng, step_rng = jax.random.split(self._rng)
            nxt, _, cache = self.engine.decode(
                self.params, jnp.asarray(cur), cache,
                active=jnp.asarray(active_np), rng=step_rng,
            )
            nxt = np.asarray(nxt)
            self.steps += 1
            self.occupancy.append(len(self.running))
            for slot, req in list(self.running.items()):
                tok = int(nxt[slot])
                req.out.append(tok)
                cur[slot] = tok
                if len(req.out) >= req.max_new or (req.eos is not None and tok == req.eos):
                    req.done = True
                    del self.running[slot]
                    self.free.append(slot)
            cache = self._admit(queue, cache, cur)
        return {r.rid: r.out for r in requests}

    @property
    def mean_occupancy(self) -> float:
        return float(np.mean(self.occupancy)) if self.occupancy else 0.0
