"""Continuous-batching scheduler: admits queued requests into free engine
slots, steps the whole batch, retires finished sequences.

Host-side orchestration only — every device-side op is a jitted Engine
call.  Straggler note (DESIGN.md §4): at pod scale the per-step barrier is
the decode psum; a slow host shows up as step-time EWMA inflation, which
``repro.runtime.fault.StragglerMonitor`` watches — the same monitor object
is reused here.

Paged engines change the admission contract: a request is admitted when a
*slot* is free AND the block pool can hold its prompt (prefix-cache hits
discounted) — batch size is bounded by tokens actually resident, not by
n_slots × worst-case capacity.  When the pool runs dry mid-decode (a
running request needs a fresh tail block and none is free), the scheduler
**preempts** the youngest running request: its blocks are freed and it is
re-queued at the head with its generated tokens folded into the prompt,
so the re-admission prefill recomputes the identical continuation (greedy
decoding: bit-identical outputs with or without preemption — covered in
tests/test_paged.py).

Chunked prefill (``chunk_tokens=N``; DESIGN.md §Chunked prefill): instead
of running one whole-prompt prefill inside ``_admit`` — stalling every
in-flight decode for its duration — each step spends at most ``N`` prompt
tokens on ONE chunk of the in-flight admission, then runs the batched
decode step for everything resident.  Paged admission needs only the
first chunk's blocks (the quantum loop grows the allocation), and a
half-prefilled request whose next chunk finds the pool dry aborts itself
back to the queue head: its completed chunks are hash-registered, so the
re-admission resumes from the completed-chunk boundary, not token 0.
Outputs are bit-identical to monolithic admission under greedy sampling
(tests/test_serving.py).  The stepwise ``start``/``submit``/``step`` API
drives the same machinery from an arrival trace
(benchmarks/bench_serve_trace.py).
"""
from __future__ import annotations

import dataclasses
import warnings
from collections import deque
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import engine as engine_mod


@dataclasses.dataclass
class Request:
    rid: int
    tokens: list[int]               # prompt
    max_new: int = 32
    eos: int | None = None
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    rejected: bool = False          # prompt longer than engine capacity


@dataclasses.dataclass
class _ChunkState:
    """An in-flight chunked admission (at most one at a time)."""

    req: Request
    slot: int
    toks: np.ndarray                # full re-admission prompt (prompt + out)
    pos: int                        # completed-chunk boundary (next start)


class ContinuousScheduler:
    def __init__(
        self,
        engine,
        params,
        pad_prompt_to: int | None = None,
        rng: jax.Array | None = None,
        chunk_tokens: int | None = None,
    ):
        self.engine = engine
        self.params = params
        self.pad = pad_prompt_to
        # chunked prefill: per-step token quantum.  None keeps monolithic
        # admission (whole-prompt prefill inside _admit); an int admits
        # through Engine.begin_chunked/prefill_chunk, spending at most
        # `chunk_tokens` prompt tokens per step before the batched decode
        # step — one long admission no longer stalls every in-flight
        # decode for its whole prefill
        self.chunk_tokens = chunk_tokens
        self.free = list(range(engine.n_slots))
        self.running: dict[int, Request] = {}   # slot → request, admission order
        self.steps = 0
        self.occupancy: list[int] = []
        self.preemptions = 0
        self.prefill_chunks = 0                 # chunked-mode: chunks run
        self.prefill_aborts = 0                 # chunked-mode: mid-prefill preemptions
        # stepwise session state (run() drives these; trace-driven callers
        # use start()/submit()/step() directly)
        self._queue: deque[Request] = deque()
        self._cache = None
        self._cur = np.zeros((engine.n_slots,), np.int32)
        self._prefilling: _ChunkState | None = None
        # sampling rng, split once per admission/decode step: every sampled
        # token — including the prefill-produced first token — draws from
        # this stream (the old _admit always took argmax(logits), so
        # temperature > 0 deployments sampled the first token greedily)
        self._rng = rng if rng is not None else jax.random.PRNGKey(0)

    def _sample(self, logits) -> int:
        self._rng, k = jax.random.split(self._rng)
        return int(engine_mod.sample_token(k, logits, self.engine.sampling)[0])

    def _release(self, cache, slot: int):
        if self.engine.paged:
            cache = self.engine.release_slot(cache, slot)
        self.free.append(slot)
        return cache

    def _admit(self, queue: deque[Request], cache, cur_tokens):
        skipped: list[Request] = []
        while queue and self.free:
            req = queue.popleft()
            # preempted requests carry their generated tokens: the
            # re-admission prompt is prompt + out so prefill recomputes
            # the cache the preemption dropped
            toks_list = req.tokens + req.out
            if len(toks_list) > self.engine.capacity:
                # a longer prompt would write out of range (the slab
                # path's dynamic_update_slice silently clamps onto live
                # rows): reject instead of corrupting the cache
                warnings.warn(
                    f"request {req.rid}: prompt of {len(toks_list)} tokens "
                    f"exceeds engine capacity {self.engine.capacity}; rejected"
                )
                req.done = True
                req.rejected = True
                continue
            if (
                self.engine.paged
                and self.engine.blocks_needed(toks_list) > self.engine.free_blocks
            ):
                # pool full for THIS prompt: scan ahead — a later, smaller
                # request may fit the remaining blocks (the old `break`
                # head-of-line-blocked the whole queue on the big head even
                # with slots and blocks to spare).  Skipped requests go
                # back to the head in arrival order below.
                skipped.append(req)
                continue
            slot = self.free.pop()
            toks = np.asarray(toks_list, np.int32)
            S = self.pad or len(toks)
            S = max(S, len(toks))
            padded = np.zeros((1, S), np.int32)
            padded[0, : len(toks)] = toks
            logits, cache = self.engine.insert(
                self.params, cache, jnp.asarray(padded), len(toks), slot
            )
            first = self._sample(logits)
            req.out.append(first)
            # the prefill-produced token counts: check termination before
            # the slot ever decodes.  at_capacity: a full-capacity prompt
            # has nowhere to write the next token's KV — retire now rather
            # than let the first decode step write out of range
            at_capacity = (
                len(req.tokens) + len(req.out) - 1 >= self.engine.capacity
            )
            if (
                len(req.out) >= req.max_new
                or (req.eos is not None and first == req.eos)
                or at_capacity
            ):
                req.done = True
                cache = self._release(cache, slot)
                continue
            cur_tokens[slot] = first
            self.running[slot] = req
        for r in reversed(skipped):
            queue.appendleft(r)
        return cache

    def _preempt_youngest(self, queue: deque[Request], cache) -> tuple[int, Any]:
        """Free the most recently admitted running request and push it
        back to the queue head (its generated tokens become prompt suffix
        on re-admission).  Returns (victim slot, cache)."""
        slot = next(reversed(self.running))
        req = self.running.pop(slot)
        cache = self._release(cache, slot)
        queue.appendleft(req)
        self.preemptions += 1
        return slot, cache

    def _ensure_append_capacity(self, queue: deque[Request], cache):
        """Paged: every running slot must own a writable tail block before
        the decode step (fresh block on a boundary, copy-on-write on a
        shared tail).  Preempts youngest-first while the pool is dry."""
        for slot in list(self.running):
            while slot in self.running:
                ok, cache = self.engine.advance_slot(cache, slot)
                if ok:
                    break
                victim, cache = self._preempt_youngest(queue, cache)
                # if the dry slot itself was youngest, it is preempted
                # and the loop guard exits; it re-admits from the queue
        return cache

    # ------------------------------------------------------ stepwise protocol
    def start(self):
        """(Re)initialise a stepwise serving session: fresh engine cache,
        empty queue, all slots free.  ``run()`` calls this; trace-driven
        callers (benchmarks/bench_serve_trace.py) use
        ``start()`` + ``submit()`` + ``step()`` directly."""
        self.free = list(range(self.engine.n_slots))
        self.running = {}
        self._queue = deque()
        self._cache = self.engine.new_cache()
        self._cur = np.zeros((self.engine.n_slots,), np.int32)
        self._prefilling = None

    def submit(self, req: Request):
        """Enqueue a request (FIFO admission order)."""
        self._queue.append(req)

    @property
    def busy(self) -> bool:
        """Work left: anything running, queued, or mid-chunked-prefill."""
        return bool(self.running or self._queue or self._prefilling)

    def _finish_admission(self, req: Request, slot: int, logits):
        """Sample the prefill-produced first token, then either retire the
        request right away (max_new / eos / at-capacity) or mark the slot
        running — the same contract as the tail of ``_admit``."""
        first = self._sample(logits)
        req.out.append(first)
        at_capacity = len(req.tokens) + len(req.out) - 1 >= self.engine.capacity
        if (
            len(req.out) >= req.max_new
            or (req.eos is not None and first == req.eos)
            or at_capacity
        ):
            req.done = True
            self._cache = self._release(self._cache, slot)
        else:
            self._cur[slot] = first
            self.running[slot] = req

    def _start_chunked_admission(self) -> bool:
        """Pop the first admissible queued request and open its chunked
        insertion (paged: admitted on *first-chunk* blocks — the quantum
        loop grows the allocation).  Full-prompt prefix hits replay with
        zero prefill FLOPs and keep scanning.  Returns True if anything
        was admitted/replayed/rejected."""
        eng = self.engine
        q = self._queue
        progressed = False
        skipped: list[Request] = []
        while q and self.free and self._prefilling is None:
            req = q.popleft()
            toks_list = req.tokens + req.out
            if len(toks_list) > eng.capacity:
                warnings.warn(
                    f"request {req.rid}: prompt of {len(toks_list)} tokens "
                    f"exceeds engine capacity {eng.capacity}; rejected"
                )
                req.done = True
                req.rejected = True
                progressed = True
                continue
            if eng.paged:
                if (
                    eng.blocks_needed_chunk(toks_list, self.chunk_tokens)
                    > eng.free_blocks
                ):
                    skipped.append(req)
                    continue
                slot = self.free.pop()
                logits, self._cache = eng.try_prefix_replay(
                    self._cache, toks_list, slot
                )
                if logits is not None:
                    self._finish_admission(req, slot, logits)
                    progressed = True
                    continue
            else:
                slot = self.free.pop()
            toks = np.asarray(toks_list, np.int32)
            resume, self._cache = eng.begin_chunked(self._cache, slot, toks)
            self._prefilling = _ChunkState(req=req, slot=slot, toks=toks, pos=resume)
            progressed = True
        for r in reversed(skipped):
            q.appendleft(r)
        return progressed

    def _chunk_admission_step(self) -> bool:
        """Spend this step's token quantum: at most one prefill chunk of
        the in-flight admission (opening one first if none is)."""
        eng = self.engine
        if self._prefilling is None:
            progressed = self._start_chunked_admission()
            if self._prefilling is None:
                return progressed
        st = self._prefilling
        n = min(self.chunk_tokens, len(st.toks) - st.pos)
        ok, logits, self._cache = eng.prefill_chunk(
            self.params, self._cache, st.slot, st.toks, st.pos, n
        )
        if not ok:
            # pool dry mid-prefill.  The prefilling request is the youngest
            # admission, so it is its own preemption victim (running
            # decodes keep priority): completed chunks are parked in the
            # prefix cache and the request re-queues at the head — its
            # re-admission resumes from the completed-chunk boundary, not
            # token 0.
            self._cache = eng.abort_chunked(self._cache, st.slot)
            self.free.append(st.slot)
            self._queue.appendleft(st.req)
            self._prefilling = None
            self.preemptions += 1
            self.prefill_aborts += 1
            return True
        self.prefill_chunks += 1
        st.pos += n
        if logits is not None:
            self._finish_admission(st.req, st.slot, logits)
            self._prefilling = None
        return True

    def step(self) -> bool:
        """One scheduler step: admission work (one monolithic admission
        sweep, or one prefill chunk under the token quantum), then one
        batched decode step for everything resident.  Returns True if any
        work was done — False with a non-empty queue means the head can
        never be admitted (stall)."""
        progressed = False
        if self.chunk_tokens is None:
            before = (len(self.running), len(self._queue))
            self._cache = self._admit(self._queue, self._cache, self._cur)
            progressed |= (len(self.running), len(self._queue)) != before
        else:
            progressed |= self._chunk_admission_step()
        if self.running:
            if self.engine.paged:
                self._cache = self._ensure_append_capacity(self._queue, self._cache)
                if not self.running:
                    return True
            active_np = np.zeros((self.engine.n_slots,), bool)
            for s in self.running:
                active_np[s] = True
            self._rng, step_rng = jax.random.split(self._rng)
            nxt, _, self._cache = self.engine.decode(
                self.params, jnp.asarray(self._cur), self._cache,
                active=jnp.asarray(active_np), rng=step_rng,
            )
            nxt = np.asarray(nxt)
            self.steps += 1
            self.occupancy.append(len(self.running))
            for slot, req in list(self.running.items()):
                tok = int(nxt[slot])
                req.out.append(tok)
                self._cur[slot] = tok
                at_capacity = (
                    len(req.tokens) + len(req.out) - 1 >= self.engine.capacity
                )
                if (
                    len(req.out) >= req.max_new
                    or (req.eos is not None and tok == req.eos)
                    or at_capacity
                ):
                    req.done = True
                    del self.running[slot]
                    self._cache = self._release(self._cache, slot)
            progressed = True
        return progressed

    def run(self, requests: Sequence[Request]) -> dict[int, list[int]]:
        # deque: _admit pops FIFO from the head — list.pop(0) was O(n) per
        # admit, O(n²) across a burst of queued requests
        self.start()
        for r in requests:
            self.submit(r)
        while self.busy:
            if not self.step():
                raise RuntimeError(
                    "scheduler stalled: queued request cannot be "
                    "admitted into an empty engine"
                )
        return {r.rid: r.out for r in requests}

    @property
    def mean_occupancy(self) -> float:
        return float(np.mean(self.occupancy)) if self.occupancy else 0.0
