"""Continuous-batching scheduler: admits queued requests into free engine
slots, steps the whole batch, retires finished sequences.

Host-side orchestration only — every device-side op is a jitted Engine
call.  Straggler note (DESIGN.md §4): at pod scale the per-step barrier is
the decode psum; a slow host shows up as step-time EWMA inflation, which
``repro.runtime.fault.StragglerMonitor`` watches — the same monitor object
is reused here.

Paged engines change the admission contract: a request is admitted when a
*slot* is free AND the block pool can hold its prompt (prefix-cache hits
discounted) — batch size is bounded by tokens actually resident, not by
n_slots × worst-case capacity.  When the pool runs dry mid-decode (a
running request needs a fresh tail block and none is free), the scheduler
**preempts** the youngest running request: its blocks are freed and it is
re-queued at the head with its generated tokens folded into the prompt,
so the re-admission prefill recomputes the identical continuation (greedy
decoding: bit-identical outputs with or without preemption — covered in
tests/test_paged.py).

Chunked prefill (``chunk_tokens=N``; DESIGN.md §Chunked prefill): instead
of running one whole-prompt prefill inside ``_admit`` — stalling every
in-flight decode for its duration — each step spends at most ``N`` prompt
tokens on ONE chunk of the in-flight admission, then runs the batched
decode step for everything resident.  Paged admission needs only the
first chunk's blocks (the quantum loop grows the allocation), and a
half-prefilled request whose next chunk finds the pool dry aborts itself
back to the queue head: its completed chunks are hash-registered, so the
re-admission resumes from the completed-chunk boundary, not token 0.
Outputs are bit-identical to monolithic admission under greedy sampling
(tests/test_serving.py).  The stepwise ``start``/``submit``/``step`` API
drives the same machinery from an arrival trace
(benchmarks/bench_serve_trace.py).

Fault tolerance (DESIGN.md §Serving fault tolerance): every request
leaves through exactly one structured :class:`~repro.serving.health.RequestOutcome`
(``finished | rejected | cancelled | deadline_exceeded | quarantined``);
deadlines run on the scheduler's virtual-token clock (1 unit per prompt
token prefilled or token decoded); a per-step NaN/Inf watchdog
quarantines poisoned slots without touching the rest of the batch; and
under pool pressure the scheduler walks the engine's budget-degradation
ladder (downshift retrieval budget + shed middle blocks) before falling
back to preemption.  ``serving.faults.ServingFaultInjector`` drives all
of this deterministically in the chaos tests.
"""
from __future__ import annotations

import dataclasses
import warnings
from collections import deque
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.tracing import PID_REQUEST

from . import engine as engine_mod
from .health import HealthMonitor, RequestOutcome, ServeResult, StepReport, nonfinite_slots


@dataclasses.dataclass
class Request:
    rid: int
    tokens: list[int]               # prompt
    max_new: int = 32
    eos: int | None = None
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    rejected: bool = False          # prompt longer than engine capacity
    # virtual-token-clock deadline (absolute; None = no deadline): the
    # request is retired `deadline_exceeded` at the first step where the
    # scheduler clock has passed it — queued, mid-prefill, or decoding
    deadline: float | None = None
    outcome: RequestOutcome | None = None   # terminal record, set at retirement
    # livelock detection (self-preemption without progress): consecutive
    # self-preemptions and the progress marker at the last one
    self_preempts: int = 0
    preempt_progress: int = -1
    # virtual-clock submission time, recorded by ContinuousScheduler.submit
    # (trace-driven callers may pass an explicit arrival) — anchors the
    # request's queued/lifetime spans and TTFT
    arrival: float | None = None


@dataclasses.dataclass
class _ChunkState:
    """An in-flight chunked admission (at most one at a time)."""

    req: Request
    slot: int
    toks: np.ndarray                # full re-admission prompt (prompt + out)
    pos: int                        # completed-chunk boundary (next start)


class ContinuousScheduler:
    def __init__(
        self,
        engine,
        params,
        pad_prompt_to: int | None = None,
        rng: jax.Array | None = None,
        chunk_tokens: int | None = None,
        injector=None,
        audit_every: int | None = None,
        self_preempt_limit: int = 4,
        watchdog: bool = True,
    ):
        self.engine = engine
        self.params = params
        self.pad = pad_prompt_to
        # fault tolerance: deterministic chaos injector (serving.faults),
        # allocator-audit cadence, livelock retirement threshold, and the
        # per-step non-finite-logits watchdog
        self.injector = injector
        self.health = HealthMonitor(audit_every)
        self.self_preempt_limit = self_preempt_limit
        self.watchdog = watchdog
        self.vtime = 0.0                        # virtual-token clock
        # observability: the scheduler shares the engine's bundle and owns
        # the tracer's clock (spans/events land on this vtime).  Tokens
        # produced during a step are buffered and stamped once at the
        # step's *final* vtime — the clock semantics TTFT/ITL are derived
        # from (DESIGN.md §Observability).
        self.obs = engine.obs
        self.obs.tracer.set_clock(lambda: self.vtime)
        if engine.paged:
            # two-tier KV reuse rides the same virtual clock: parked-block
            # TTL aging and host-tier timestamps become deterministic
            # functions of the trace, not of wall time
            engine.set_pool_clock(lambda: self.vtime)
        self._step_tokens: list[tuple[int, int]] = []   # (rid, token)
        self.outcomes: dict[int, RequestOutcome] = {}
        self._step_retired: list[RequestOutcome] = []
        # chunked prefill: per-step token quantum.  None keeps monolithic
        # admission (whole-prompt prefill inside _admit); an int admits
        # through Engine.begin_chunked/prefill_chunk, spending at most
        # `chunk_tokens` prompt tokens per step before the batched decode
        # step — one long admission no longer stalls every in-flight
        # decode for its whole prefill
        self.chunk_tokens = chunk_tokens
        self.free = list(range(engine.n_slots))
        self.running: dict[int, Request] = {}   # slot → request, admission order
        self.steps = 0
        self.occupancy: list[int] = []
        self.preemptions = 0
        self.prefill_chunks = 0                 # chunked-mode: chunks run
        self.prefill_aborts = 0                 # chunked-mode: mid-prefill preemptions
        self.insert_retries = 0                 # transient insert-time pool failures
        # stepwise session state (run() drives these; trace-driven callers
        # use start()/submit()/step() directly)
        self._queue: deque[Request] = deque()
        self._cache = None
        self._cur = np.zeros((engine.n_slots,), np.int32)
        self._prefilling: _ChunkState | None = None
        # sampling rng, split once per admission/decode step: every sampled
        # token — including the prefill-produced first token — draws from
        # this stream (the old _admit always took argmax(logits), so
        # temperature > 0 deployments sampled the first token greedily)
        self._rng = rng if rng is not None else jax.random.PRNGKey(0)

    def _sample(self, logits) -> int:
        self._rng, k = jax.random.split(self._rng)
        return int(engine_mod.sample_token(k, logits, self.engine.sampling)[0])

    def _release(self, cache, slot: int):
        if self.engine.paged:
            cache = self.engine.release_slot(cache, slot)
        self.free.append(slot)
        return cache

    # --------------------------------------------------- request lifecycle
    def _retire(
        self, req: Request, status: str, reason: str = "",
        slot: int | None = None,
    ) -> RequestOutcome:
        """Record a request's terminal outcome (bookkeeping only — the
        caller releases slots/blocks at its own call site, since cache
        threading differs per path).  ``slot`` is the decode slot the
        request held at retirement (None when queued / prefilling), kept
        on the outcome so chaos-lane failures are diagnosable from the
        artifact alone."""
        req.done = True
        if status == "rejected":
            req.rejected = True
        oc = RequestOutcome(
            rid=req.rid, status=status, reason=reason,
            tokens=len(req.out), vtime=self.vtime, slot=slot,
        )
        req.outcome = oc
        self.outcomes[req.rid] = oc
        self.health.record(oc)
        self._step_retired.append(oc)
        if self.obs.enabled:
            tr = self.obs.tracer
            tr.instant(
                "retired", pid=PID_REQUEST, tid=req.rid, cat="lifecycle",
                status=status, reason=reason, slot=slot,
                tokens=len(req.out))
            if req.arrival is not None:
                tr.complete(
                    "request", req.arrival, self.vtime - req.arrival,
                    pid=PID_REQUEST, tid=req.rid, cat="lifecycle",
                    status=status)
            self.obs.metrics.counter(
                "requests_retired_total", "terminal request outcomes",
            ).inc(status=status)
        return oc

    def slot_of(self, rid: int) -> int | None:
        """The decode slot currently holding request ``rid`` (None when
        queued / prefilling / retired)."""
        for s, r in self.running.items():
            if r.rid == rid:
                return s
        return None

    def cancel(self, rid: int, reason: str = "cancelled by caller") -> bool:
        """Withdraw a request wherever it is — queued, mid-chunked-prefill,
        or mid-decode — releasing its blocks and recording a ``cancelled``
        outcome.  False when ``rid`` is unknown or already retired."""
        for r in self._queue:
            if r.rid == rid:
                self._queue.remove(r)
                self._retire(r, "cancelled", reason)
                return True
        st = self._prefilling
        if st is not None and st.req.rid == rid:
            self._cache = self.engine.abort_chunked(self._cache, st.slot)
            self.free.append(st.slot)
            self._prefilling = None
            self._retire(st.req, "cancelled", reason, slot=st.slot)
            return True
        slot = self.slot_of(rid)
        if slot is not None:
            req = self.running.pop(slot)
            self._cache = self._release(self._cache, slot)
            self._retire(req, "cancelled", reason, slot=slot)
            return True
        return False

    def _expire_deadlines(self) -> bool:
        """Retire every request whose virtual-token deadline has passed —
        in the queue, mid-chunked-prefill, and mid-decode."""
        any_expired = False
        for r in [
            r for r in self._queue
            if r.deadline is not None and self.vtime >= r.deadline
        ]:
            self._queue.remove(r)
            self._retire(r, "deadline_exceeded", "expired while queued")
            any_expired = True
        st = self._prefilling
        if st is not None and st.req.deadline is not None and self.vtime >= st.req.deadline:
            self._cache = self.engine.abort_chunked(self._cache, st.slot)
            self.free.append(st.slot)
            self._prefilling = None
            self._retire(
                st.req, "deadline_exceeded", "expired mid-chunked-prefill",
                slot=st.slot,
            )
            any_expired = True
        for slot, req in list(self.running.items()):
            if req.deadline is not None and self.vtime >= req.deadline:
                del self.running[slot]
                self._cache = self._release(self._cache, slot)
                self._retire(
                    req, "deadline_exceeded", "expired mid-decode", slot=slot
                )
                any_expired = True
        return any_expired

    def _note_self_preempt(self, req: Request, marker: int) -> bool:
        """Track consecutive self-preemptions without progress.  ``marker``
        is a monotone progress measure (tokens resident / completed-chunk
        boundary); a self-preemption that didn't advance it extends the
        streak.  True → the request is livelocked and should be retired."""
        if marker <= req.preempt_progress:
            req.self_preempts += 1
        else:
            req.self_preempts = 1
            req.preempt_progress = marker
        return req.self_preempts >= self.self_preempt_limit

    def _try_degrade(self, cache):
        """One rung down the budget-degradation ladder: halve the engine's
        retrieval budget and shed running slots' middle blocks (the sink
        and recent-window blocks the guard-rails read exactly are kept).
        Returns (freed any blocks?, cache) — False sends the caller to
        the preemption fallback (ladder floor reached / nothing to shed).
        """
        eng = self.engine
        if not (eng.paged and eng.degradable):
            return False, cache
        if not eng.downshift_budget():
            return False, cache
        freed = 0
        for slot in self.running:
            n, cache = eng.shed_middle_blocks(cache, slot)
            freed += n
        return freed > 0, cache

    def _reject_inadmissible(self, req: Request, toks_list) -> bool:
        """Structured rejection of requests that can never be served: a
        prompt beyond the cache capacity (a longer prompt would write out
        of range — the slab path's dynamic_update_slice silently clamps
        onto live rows), or, paged, a prompt needing more blocks than the
        whole pool owns (admitting it would only livelock the
        preempt/re-admit cycle).  The warning stays for humans; callers
        branch on the outcome record."""
        eng = self.engine
        if len(toks_list) > eng.capacity:
            msg = (
                f"request {req.rid}: prompt of {len(toks_list)} tokens "
                f"exceeds engine capacity {eng.capacity}; rejected"
            )
            warnings.warn(msg)
            self._retire(req, "rejected", msg)
            return True
        if (
            eng.paged
            and -(-len(toks_list) // eng.block_size) > eng.allocator.usable
        ):
            msg = (
                f"request {req.rid}: prompt of {len(toks_list)} tokens needs "
                f"more blocks than the whole pool holds "
                f"({eng.allocator.usable} usable × {eng.block_size}); rejected"
            )
            warnings.warn(msg)
            self._retire(req, "rejected", msg)
            return True
        return False

    def _admit(self, queue: deque[Request], cache, cur_tokens):
        skipped: list[Request] = []
        while queue and self.free:
            req = queue.popleft()
            # preempted requests carry their generated tokens: the
            # re-admission prompt is prompt + out so prefill recomputes
            # the cache the preemption dropped
            toks_list = req.tokens + req.out
            if self._reject_inadmissible(req, toks_list):
                continue
            if (
                self.engine.paged
                and self.engine.blocks_needed(toks_list) > self.engine.free_blocks
            ):
                # pool full for THIS prompt: scan ahead — a later, smaller
                # request may fit the remaining blocks (the old `break`
                # head-of-line-blocked the whole queue on the big head even
                # with slots and blocks to spare).  Skipped requests go
                # back to the head in arrival order below.
                skipped.append(req)
                continue
            slot = self.free.pop()
            toks = np.asarray(toks_list, np.int32)
            S = self.pad or len(toks)
            S = max(S, len(toks))
            padded = np.zeros((1, S), np.int32)
            padded[0, : len(toks)] = toks
            try:
                logits, cache = self.engine.insert(
                    self.params, cache, jnp.asarray(padded), len(toks), slot
                )
            except engine_mod.PoolExhausted:
                # the pool dried between the admission check and the
                # allocation (transient: a fault-injected failure burst, or
                # an admission-check race).  The insert rolled itself back;
                # re-queue and retry on a later sweep (the retry counts as
                # step progress — transient failures drain over steps).
                self.free.append(slot)
                skipped.append(req)
                self.insert_retries += 1
                continue
            if self.obs.enabled:
                self._trace_admission_start(req)
                self.obs.tracer.complete(
                    "prefill", self.vtime, len(toks), pid=PID_REQUEST,
                    tid=req.rid, cat="prefill", slot=slot, tokens=len(toks))
            self.vtime += len(toks)
            first = self._sample(logits)
            req.out.append(first)
            self._step_tokens.append((req.rid, first))
            # the prefill-produced token counts: check termination before
            # the slot ever decodes.  at_capacity: a full-capacity prompt
            # has nowhere to write the next token's KV — retire now rather
            # than let the first decode step write out of range
            at_capacity = (
                len(req.tokens) + len(req.out) - 1 >= self.engine.capacity
            )
            if (
                len(req.out) >= req.max_new
                or (req.eos is not None and first == req.eos)
                or at_capacity
            ):
                self._retire(req, "finished", slot=slot)
                cache = self._release(cache, slot)
                continue
            cur_tokens[slot] = first
            self.running[slot] = req
        for r in reversed(skipped):
            queue.appendleft(r)
        return cache

    def _preempt_youngest(
        self, queue: deque[Request], cache, requester: int | None = None
    ) -> tuple[int, Any]:
        """Free the most recently admitted running request and push it
        back to the queue head (its generated tokens become prompt suffix
        on re-admission).  Returns (victim slot, cache).

        ``requester`` is the slot whose dry append triggered this: when
        the victim IS the requester (self-preemption), the cycle makes
        no one else any room — a repeat without progress is the classic
        lone-request livelock, and after ``self_preempt_limit`` such
        cycles the request is retired ``rejected`` instead of re-queued.
        """
        slot = next(reversed(self.running))
        req = self.running.pop(slot)
        cache = self._release(cache, slot)
        self.preemptions += 1
        reason = (
            "self-preemption (own dry append)" if slot == requester
            else f"preempted for slot {requester} (pool dry)"
        )
        self.health.record_event(
            "preempt", slot=slot, rid=req.rid, reason=reason,
            requester=requester,
        )
        if self.obs.enabled:
            self.obs.tracer.instant(
                "preempt", cat="preemption", slot=slot, rid=req.rid,
                requester=requester)
            self.obs.metrics.counter(
                "preemptions_total", "running requests evicted for space",
            ).inc()
        if slot == requester and self._note_self_preempt(
            req, len(req.tokens) + len(req.out)
        ):
            self.health.self_preempt_retires += 1
            msg = (
                f"request {req.rid}: {req.self_preempts} consecutive "
                f"self-preemptions without progress (decode outgrows the "
                f"block pool); retired"
            )
            warnings.warn(msg)
            self._retire(req, "rejected", msg, slot=slot)
        else:
            queue.appendleft(req)
        return slot, cache

    def _ensure_append_capacity(self, queue: deque[Request], cache):
        """Paged: every running slot must own a writable tail block before
        the decode step (fresh block on a boundary, copy-on-write on a
        shared tail).  When the pool is dry, walk the degradation ladder
        first — downshift the retrieval budget and shed middle blocks of
        running slots — and only preempt youngest-first once the ladder
        floor is reached or shedding frees nothing."""
        for slot in list(self.running):
            while slot in self.running:
                ok, cache = self.engine.advance_slot(cache, slot)
                if ok:
                    break
                degraded, cache = self._try_degrade(cache)
                if degraded:
                    continue  # freed blocks — retry the append
                victim, cache = self._preempt_youngest(queue, cache, requester=slot)
                # if the dry slot itself was youngest, it is preempted
                # and the loop guard exits; it re-admits from the queue
        return cache

    # ------------------------------------------------------ stepwise protocol
    def start(self):
        """(Re)initialise a stepwise serving session: fresh engine cache,
        empty queue, all slots free.  ``run()`` calls this; trace-driven
        callers (benchmarks/bench_serve_trace.py) use
        ``start()`` + ``submit()`` + ``step()`` directly."""
        self.free = list(range(self.engine.n_slots))
        self.running = {}
        self._queue = deque()
        self._cache = self.engine.new_cache()
        self._cur = np.zeros((self.engine.n_slots,), np.int32)
        self._prefilling = None
        self.vtime = 0.0
        self.outcomes = {}
        self._step_retired = []
        self.health = HealthMonitor(self.health.audit_every)
        self._step_tokens = []
        # one session, one trace: vtime restarts at 0, so a carried-over
        # event buffer would be non-monotone
        self.obs.tracer.reset()

    def submit(self, req: Request, arrival: float | None = None):
        """Enqueue a request (FIFO admission order).  ``arrival`` pins the
        request's virtual-clock submission time (default: now) — the
        anchor of its queued span and TTFT."""
        req.arrival = self.vtime if arrival is None else float(arrival)
        self._queue.append(req)
        if self.obs.enabled:
            self.obs.tracer.instant(
                "submitted", ts=req.arrival, pid=PID_REQUEST, tid=req.rid,
                cat="lifecycle", prompt_tokens=len(req.tokens),
                max_new=req.max_new)

    def idle_until(self, t: float) -> None:
        """Advance the virtual clock to ``t`` (no-op when already past) —
        trace replay uses this to model idle gaps between arrivals."""
        self.vtime = max(self.vtime, float(t))

    @property
    def busy(self) -> bool:
        """Work left: anything running, queued, or mid-chunked-prefill."""
        return bool(self.running or self._queue or self._prefilling)

    def _trace_admission_start(self, req: Request) -> None:
        """Close the request's queued span at the moment it leaves the
        queue (monolithic admission, chunked open, or prefix replay)."""
        if req.arrival is not None:
            self.obs.tracer.complete(
                "queued", req.arrival, self.vtime - req.arrival,
                pid=PID_REQUEST, tid=req.rid, cat="lifecycle")

    def _finish_admission(self, req: Request, slot: int, logits):
        """Sample the prefill-produced first token, then either retire the
        request right away (max_new / eos / at-capacity) or mark the slot
        running — the same contract as the tail of ``_admit``."""
        first = self._sample(logits)
        req.out.append(first)
        self._step_tokens.append((req.rid, first))
        at_capacity = len(req.tokens) + len(req.out) - 1 >= self.engine.capacity
        if (
            len(req.out) >= req.max_new
            or (req.eos is not None and first == req.eos)
            or at_capacity
        ):
            self._retire(req, "finished", slot=slot)
            self._cache = self._release(self._cache, slot)
        else:
            self._cur[slot] = first
            self.running[slot] = req

    def _start_chunked_admission(self) -> bool:
        """Pop the first admissible queued request and open its chunked
        insertion (paged: admitted on *first-chunk* blocks — the quantum
        loop grows the allocation).  Full-prompt prefix hits replay with
        zero prefill FLOPs and keep scanning.  Returns True if anything
        was admitted/replayed/rejected."""
        eng = self.engine
        q = self._queue
        progressed = False
        skipped: list[Request] = []
        while q and self.free and self._prefilling is None:
            req = q.popleft()
            toks_list = req.tokens + req.out
            if self._reject_inadmissible(req, toks_list):
                progressed = True
                continue
            if eng.paged:
                if (
                    eng.blocks_needed_chunk(toks_list, self.chunk_tokens)
                    > eng.free_blocks
                ):
                    skipped.append(req)
                    continue
                slot = self.free.pop()
                logits, self._cache = eng.try_prefix_replay(
                    self._cache, toks_list, slot
                )
                if logits is not None:
                    if self.obs.enabled:
                        self._trace_admission_start(req)
                        self.obs.tracer.instant(
                            "prefix_replay", pid=PID_REQUEST, tid=req.rid,
                            cat="prefill", slot=slot, tokens=len(toks_list))
                    self._finish_admission(req, slot, logits)
                    progressed = True
                    continue
            else:
                slot = self.free.pop()
            if self.obs.enabled:
                self._trace_admission_start(req)
            toks = np.asarray(toks_list, np.int32)
            resume, self._cache = eng.begin_chunked(self._cache, slot, toks)
            self._prefilling = _ChunkState(req=req, slot=slot, toks=toks, pos=resume)
            progressed = True
        for r in reversed(skipped):
            q.appendleft(r)
        return progressed

    def _chunk_admission_step(self) -> bool:
        """Spend this step's token quantum: at most one prefill chunk of
        the in-flight admission (opening one first if none is)."""
        eng = self.engine
        if self._prefilling is None:
            progressed = self._start_chunked_admission()
            if self._prefilling is None:
                return progressed
        st = self._prefilling
        n = min(self.chunk_tokens, len(st.toks) - st.pos)
        ok, logits, self._cache = eng.prefill_chunk(
            self.params, self._cache, st.slot, st.toks, st.pos, n
        )
        if not ok:
            # pool dry mid-prefill.  The prefilling request is the youngest
            # admission, so it is its own preemption victim (running
            # decodes keep priority): completed chunks are parked in the
            # prefix cache and the request re-queues at the head — its
            # re-admission resumes from the completed-chunk boundary, not
            # token 0.  An abort whose completed-chunk boundary didn't
            # advance since the last one is the chunked flavour of the
            # self-preemption livelock (the pool can't hold this prompt
            # alongside the running set, and its own fresh chunks evict
            # its parked progress): retire after `self_preempt_limit`.
            self._cache = eng.abort_chunked(self._cache, st.slot)
            self.free.append(st.slot)
            self._prefilling = None
            self.preemptions += 1
            self.prefill_aborts += 1
            self.health.record_event(
                "prefill_abort", slot=st.slot, rid=st.req.rid,
                reason="pool dry mid-chunked-prefill", pos=st.pos,
            )
            if self.obs.enabled:
                self.obs.tracer.instant(
                    "prefill_abort", cat="preemption", slot=st.slot,
                    rid=st.req.rid, pos=st.pos)
                self.obs.metrics.counter(
                    "prefill_aborts_total",
                    "chunked admissions aborted by pool pressure").inc()
            if self._note_self_preempt(st.req, st.pos):
                self.health.self_preempt_retires += 1
                msg = (
                    f"request {st.req.rid}: {st.req.self_preempts} chunked-"
                    f"prefill aborts without progress (pool cannot hold the "
                    f"prompt); retired"
                )
                warnings.warn(msg)
                self._retire(st.req, "rejected", msg, slot=st.slot)
            else:
                self._queue.appendleft(st.req)
            return True
        self.prefill_chunks += 1
        if self.obs.enabled:
            self.obs.tracer.complete(
                f"prefill_chunk[{st.pos // self.chunk_tokens}]",
                self.vtime, n, pid=PID_REQUEST, tid=st.req.rid,
                cat="prefill", slot=st.slot, start=st.pos, tokens=n)
        self.vtime += n
        st.pos += n
        if logits is not None:
            self._finish_admission(st.req, st.slot, logits)
            self._prefilling = None
        return True

    def step(self) -> StepReport:
        """One scheduler step: fault hooks + deadline sweep, admission
        work (one monolithic admission sweep, or one prefill chunk under
        the token quantum), then one batched decode step for everything
        resident — with a non-finite-logits watchdog that quarantines
        poisoned slots.  Returns a truthy :class:`StepReport` if any work
        was done — falsy with a non-empty queue means the head can never
        be admitted (stall)."""
        self._step_retired = []
        progressed = False
        if self.injector is not None:
            self.injector.on_step_begin(self)
        progressed |= self._expire_deadlines()
        progressed |= bool(self._step_retired)  # injected cancels count
        # pressure cleared? step back up the degradation ladder
        if self.engine.paged and self.engine.maybe_restore_budget():
            progressed = True
        if self.engine.paged and self._cache is not None:
            # TTL sweep on the virtual clock *before* admission, so blocks
            # freed by aging are available to this step's admission work
            swept, self._cache = self.engine.sweep_parked(self._cache)
            if swept and self.obs.enabled:
                self.obs.tracer.instant("ttl_sweep", cat="pool", expired=swept)
                self.obs.metrics.counter(
                    "pool_ttl_evictions_total",
                    "parked prefix blocks expired by TTL").inc(swept)
        if self.chunk_tokens is None:
            before = (len(self.running), len(self._queue), self.insert_retries)
            self._cache = self._admit(self._queue, self._cache, self._cur)
            progressed |= (
                (len(self.running), len(self._queue), self.insert_retries)
                != before
            )
        else:
            progressed |= self._chunk_admission_step()
        if self.engine.paged:
            # host-tier recalls performed by this step's admission work
            # charge the virtual clock (far cheaper than the block_size
            # prefill tokens each recalled block saved)
            units = self.engine.take_recall_units()
            if units:
                self.vtime += units
                if self.obs.enabled:
                    self.obs.tracer.instant(
                        "recall_charge", cat="offload", units=units)
        if self.running:
            if self.engine.paged:
                self._cache = self._ensure_append_capacity(self._queue, self._cache)
                if not self.running:
                    return StepReport(True, self._step_retired)
            active_np = np.zeros((self.engine.n_slots,), bool)
            for s in self.running:
                active_np[s] = True
            self._rng, step_rng = jax.random.split(self._rng)
            nxt, logits, self._cache = self.engine.decode(
                self.params, jnp.asarray(self._cur), self._cache,
                active=jnp.asarray(active_np), rng=step_rng,
            )
            nxt = np.asarray(nxt)
            self.steps += 1
            self.occupancy.append(len(self.running))
            self.vtime += len(self.running)
            if self.watchdog or self.injector is not None:
                lg = np.asarray(logits)
                if self.injector is not None:
                    lg = self.injector.poison_logits(self, lg)
                if self.watchdog:
                    for slot in nonfinite_slots(lg, list(self.running)):
                        # quarantine ONLY the poisoned slot: its sampled
                        # token is garbage (drawn from non-finite logits),
                        # so it is discarded with the slot — the rest of
                        # the batch decodes on untouched
                        req = self.running.pop(slot)
                        self._cache = self._release(self._cache, slot)
                        reason = (
                            f"non-finite logits at decode step {self.steps}"
                        )
                        self.health.record_event(
                            "quarantine", slot=slot, rid=req.rid,
                            reason=reason,
                        )
                        if self.obs.enabled:
                            self.obs.tracer.instant(
                                "quarantine", cat="health", slot=slot,
                                rid=req.rid, reason=reason)
                        self._retire(req, "quarantined", reason, slot=slot)
            for slot, req in list(self.running.items()):
                tok = int(nxt[slot])
                req.out.append(tok)
                self._step_tokens.append((req.rid, tok))
                self._cur[slot] = tok
                at_capacity = (
                    len(req.tokens) + len(req.out) - 1 >= self.engine.capacity
                )
                if (
                    len(req.out) >= req.max_new
                    or (req.eos is not None and tok == req.eos)
                    or at_capacity
                ):
                    self._retire(req, "finished", slot=slot)
                    del self.running[slot]
                    self._cache = self._release(self._cache, slot)
            progressed = True
            if self.obs.introspector is not None and self.running:
                self.obs.introspector.probe(
                    self.engine, self._cache, list(self.running), self.steps
                )
        if self.obs.enabled:
            self._flush_step_obs()
        self.health.maybe_audit(self.engine, self.steps)
        return StepReport(progressed, self._step_retired)

    def _flush_step_obs(self) -> None:
        """End-of-step observability flush: stamp the step's buffered
        tokens at the *final* vtime (an admission-produced first token and
        a same-step decode token share one stamp — the clock semantics
        TTFT/ITL percentiles are derived from), then sample the counter
        tracks and gauges."""
        tr = self.obs.tracer
        for rid, tok in self._step_tokens:
            tr.instant("token", pid=PID_REQUEST, tid=rid, cat="decode",
                       token=tok)
        self._step_tokens = []
        tr.counter("occupancy", {"running": len(self.running),
                                 "queued": len(self._queue)})
        if self.engine.paged:
            a = self.engine.allocator
            track = {"in_use": a.n_in_use,
                     "free": len(a._free),
                     "cached": a.n_parked}
            if self.engine.offload is not None:
                track["host"] = len(self.engine.offload)
            tr.counter("pool", track)
        self.engine.sample_pool_gauges()
        self.obs.metrics.set_gauges(dict(
            sched_steps=self.steps,
            sched_vtime=self.vtime,
            sched_running=len(self.running),
            sched_queue_depth=len(self._queue),
            sched_preemptions=self.preemptions,
            sched_prefill_chunks=self.prefill_chunks,
            sched_prefill_aborts=self.prefill_aborts,
            sched_insert_retries=self.insert_retries,
        ))

    def run(self, requests: Sequence[Request]) -> ServeResult:
        """Serve ``requests`` to completion.  Returns a :class:`ServeResult`
        — a plain ``rid → generated tokens`` dict (back-compat) carrying
        the structured per-request outcomes in ``.outcomes``."""
        # deque: _admit pops FIFO from the head — list.pop(0) was O(n) per
        # admit, O(n²) across a burst of queued requests
        self.start()
        for r in requests:
            self.submit(r)
        while self.busy:
            if not self.step():
                raise RuntimeError(
                    "scheduler stalled: queued request cannot be "
                    "admitted into an empty engine"
                )
        return ServeResult(
            {r.rid: r.out for r in requests}, dict(self.outcomes)
        )

    @property
    def mean_occupancy(self) -> float:
        return float(np.mean(self.occupancy)) if self.occupancy else 0.0
