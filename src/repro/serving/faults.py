"""Deterministic serving chaos harness: seeded fault injection against a
live ``ContinuousScheduler``.

The training loop already has exception-at-step injection
(``runtime.fault.FaultInjector``); serving faults are different in kind —
they corrupt *state* (logits, cache metadata, allocator responses) or the
*request stream* (cancels) rather than raising, and the contract under
test is containment: the scheduler must survive every fault class, the
allocator must audit clean at drain, and requests not targeted by a fault
must produce bit-identical outputs to a fault-free run (asserted in
tests/test_fault.py's serving chaos matrix).

Fault classes (:data:`FAULT_KINDS`):

``alloc_fail``
    The next ``count`` block allocations return None (a transient
    pool-exhaustion burst), exercising the degradation/preemption ladder.
``poison_logits``
    The target request's logits row turns NaN at the given decode step —
    the watchdog must quarantine only that slot.
``corrupt_metadata``
    A block (paged) / slot row (slab) of the target request's FIER
    side-car is scrambled on device — retrieval quality degrades for that
    request only; everything stays finite and the batch keeps decoding.
``cancel``
    The request is cancelled mid-flight (queued, mid-chunked-prefill, or
    decoding) through the ``cancel()`` API.
``offload_drop``
    ``count`` LRU entries of the engine's host-DRAM offload tier are lost
    (models host memory reclaim / a failed D2H transfer).  Recalls that
    would have hit now miss and fall back to recomputing the prefix —
    outputs must stay bit-identical; a no-op on engines without an
    offload tier.

Injection points are either given explicitly as :class:`FaultSpec`s or
drawn from a seeded rng (:meth:`ServingFaultInjector.random`), so every
chaos run is exactly reproducible from (trace seed, injector seed).
"""
from __future__ import annotations

import dataclasses

import numpy as np

FAULT_KINDS = (
    "alloc_fail", "poison_logits", "corrupt_metadata", "cancel",
    "offload_drop",
)


@dataclasses.dataclass
class FaultSpec:
    """One fault to inject.

    ``step`` is the scheduler decode-step counter (``sched.steps``) at
    which the fault arms.  Slot-targeted faults (poison / corrupt) fire at
    the first armed step where the target request is actually resident in
    a decode slot; ``cancel`` / ``alloc_fail`` fire exactly once when
    armed.  ``rid`` is the target request where applicable; ``count`` is
    the number of consecutive allocation failures for ``alloc_fail``.
    """

    kind: str
    step: int
    rid: int | None = None
    count: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; one of {FAULT_KINDS}")


class ServingFaultInjector:
    """Deterministic fault schedule, wired into the scheduler step loop.

    The scheduler calls :meth:`on_step_begin` before each step's admission
    work and :meth:`poison_logits` on the decode logits (host copy) before
    the NaN watchdog runs; no other integration points exist, so a
    scheduler without an injector runs byte-identical code.
    """

    def __init__(self, specs: list[FaultSpec] | tuple = ()):
        self.specs = list(specs)
        self._fired: set[int] = set()        # indices into self.specs
        self.fired_log: list[tuple[int, str, int | None]] = []  # (step, kind, rid)

    @classmethod
    def random(
        cls,
        seed: int,
        *,
        rids,
        kinds=FAULT_KINDS,
        n_faults: int = 3,
        step_lo: int = 1,
        step_hi: int = 12,
    ) -> "ServingFaultInjector":
        """A seeded fault schedule: ``n_faults`` draws of (kind, step,
        target rid) — identical schedule for identical arguments."""
        rng = np.random.default_rng(seed)
        rids = list(rids)
        specs = []
        for _ in range(n_faults):
            kind = kinds[int(rng.integers(0, len(kinds)))]
            step = int(rng.integers(step_lo, step_hi + 1))
            rid = rids[int(rng.integers(0, len(rids)))] if rids else None
            specs.append(FaultSpec(kind=kind, step=step, rid=rid,
                                   count=int(rng.integers(1, 4))))
        return cls(specs)

    # ------------------------------------------------------------------ hooks
    def _mark(self, i: int, spec: FaultSpec, sched) -> None:
        self._fired.add(i)
        self.fired_log.append((sched.steps, spec.kind, spec.rid))
        # fired faults are trace events: a chaos run's injections land on
        # the same virtual-clock timeline as the preemptions/quarantines
        # they provoke (obs disabled → the null tracer swallows this)
        obs = getattr(sched, "obs", None)
        if obs is not None and obs.enabled:
            obs.tracer.instant(
                "fault", cat="fault", kind=spec.kind, rid=spec.rid,
                step=sched.steps, count=spec.count)
            obs.metrics.counter(
                "faults_injected_total", "chaos-harness faults fired",
            ).inc(kind=spec.kind)

    def on_step_begin(self, sched) -> None:
        """Fire step-armed faults: cancels, allocation-failure bursts, and
        device metadata corruption (the latter waits for its target to be
        resident in a slot)."""
        eng = sched.engine
        for i, spec in enumerate(self.specs):
            if i in self._fired or sched.steps < spec.step:
                continue
            if spec.kind == "cancel":
                # not submitted yet → cancel() refuses; retry next step
                if sched.cancel(spec.rid, reason="fault-injected cancel"):
                    self._mark(i, spec, sched)
            elif spec.kind == "alloc_fail":
                if eng.paged:
                    eng.allocator.fail_next(spec.count)
                self._mark(i, spec, sched)
            elif spec.kind == "offload_drop":
                off = getattr(eng, "offload", None)
                if off is not None:
                    n = off.drop_lru(spec.count)
                    sched.health.record_event(
                        "offload_drop", reason="fault-injected host loss",
                        dropped=n,
                    )
                self._mark(i, spec, sched)  # no-op without a host tier
            elif spec.kind == "corrupt_metadata":
                slot = sched.slot_of(spec.rid)
                if slot is None:
                    continue  # not resident yet; retry next step
                ok, sched._cache = eng.corrupt_slot_metadata(sched._cache, slot)
                if ok:  # no privately-held block yet: retry next step
                    self._mark(i, spec, sched)

    def poison_logits(self, sched, logits: np.ndarray) -> np.ndarray:
        """Overwrite armed targets' logits rows with NaN (models a
        numerically-poisoned decode step for that slot)."""
        for i, spec in enumerate(self.specs):
            if (
                i in self._fired
                or spec.kind != "poison_logits"
                or sched.steps < spec.step
            ):
                continue
            slot = sched.slot_of(spec.rid)
            if slot is None:
                continue  # not resident yet; retry next step
            logits = np.array(logits)  # never scribble on a shared buffer
            logits[slot] = np.nan
            self._mark(i, spec, sched)
        return logits

    @property
    def all_fired(self) -> bool:
        return len(self._fired) == len(self.specs)
