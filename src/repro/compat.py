"""Version shims for JAX APIs that moved between releases.

Keep every cross-version branch here so the rest of the codebase imports
one stable name.  Currently:

  * ``shard_map`` — ``jax.shard_map`` (new) vs
    ``jax.experimental.shard_map.shard_map`` (≤ 0.4.x), including the
    ``check_vma`` (new) / ``check_rep`` (old) keyword rename.
  * ``abstract_mesh`` — ``AbstractMesh`` takes a single ``shape_tuple`` of
    ``(name, size)`` pairs on the 0.4.x series pinned here; other releases
    take positional ``(axis_sizes, axis_names)`` (the fallback branch).
"""
from __future__ import annotations

import jax
from jax.sharding import AbstractMesh


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across JAX versions (kw-only, like the new API)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


def abstract_mesh(axis_sizes: tuple[int, ...], axis_names: tuple[str, ...]) -> AbstractMesh:
    """``AbstractMesh`` across the positional-args → shape_tuple API break."""
    try:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
    except TypeError:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
