"""Distributed FIER: sequence-sharded KV cache + log-sum-exp merge.

The paper runs on one GPU.  At pod scale the KV cache of a 500k-token
context does not fit one chip, so we shard the cache *along the sequence*
and exploit the structure of FIER itself:

  1. every shard scans only its packed 1-bit slice (embarrassingly parallel),
  2. takes a *local* top-k over its slice,
  3. computes exact partial attention over its local winners,
  4. partial outputs merge with the flash-decoding log-sum-exp trick —
     one ``psum`` of (num·e^{m−M}, den·e^{m−M}) per layer: O(Hq·D) bytes,
     independent of context length.

Two selection modes:
  * ``local``  (default): budget split evenly across shards — zero extra
    collectives.  An approximation of global top-k; quality validated in
    tests/benchmarks (important tokens are *sparsely distributed* — the
    paper's own OB1 — so an even split is a good prior).
  * ``exact``: shards all-gather their local candidate scores, derive the
    global k-th-score threshold τ, and keep local candidates ≥ τ.
    Matches single-device FIER modulo ties; costs one small all-gather
    (n_shards · budget f32 per (B, Hkv)).

These functions are written to run *inside* ``shard_map`` bodies (the
serving layer binds them); they only use ``jax.lax`` collectives over the
named ``axis`` they are given.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import retrieval
from .quantize import QuantizedKeys
from .retrieval import NEG_INF


def _partial_attention(
    q: jax.Array,
    Ksel: jax.Array,
    Vsel: jax.Array,
    idx_global: jax.Array,
    length: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Unnormalised attention over a shard's selected tokens.

    Returns (m [B,Hkv,rep], num [B,Hkv,rep,D], den [B,Hkv,rep]) in f32.
    Selected slots with idx >= length are masked.
    """
    B, Hq, D = q.shape
    Hkv = Ksel.shape[2]
    rep = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    # bf16 operands, f32 accumulation — never materialise f32 slab copies
    qb = q.astype(Ksel.dtype).reshape(B, Hkv, rep, D)
    s = jnp.einsum(
        "bhrd,bkhd->bhrk", qb, Ksel, preferred_element_type=jnp.float32
    ) * scale
    invalid = idx_global[:, :, None, :] >= length[:, None, None, None]
    s = jnp.where(invalid, NEG_INF, s)
    m = jnp.max(s, axis=-1)  # [B,Hkv,rep]
    # guard: a shard whose every candidate is invalid contributes nothing
    e = jnp.exp(s - m[..., None])
    e = jnp.where(invalid, 0.0, e)
    num = jnp.einsum(
        "bhrk,bkhd->bhrd", e.astype(Vsel.dtype), Vsel,
        preferred_element_type=jnp.float32,
    )
    den = e.sum(axis=-1)
    return m, num, den


def lse_combine(
    m: jax.Array, num: jax.Array, den: jax.Array, axis: str | tuple[str, ...]
) -> jax.Array:
    """Merge per-shard (m, num, den) over mesh axis/axes → normalised output."""
    M = jax.lax.pmax(m, axis)
    w = jnp.where(jnp.isfinite(m), jnp.exp(m - M), 0.0)
    num = jax.lax.psum(num * w[..., None], axis)
    den = jax.lax.psum(den * w, axis)
    den = jnp.maximum(den, 1e-30)
    return num / den[..., None]


def fier_decode_sharded(
    q: jax.Array,
    K_loc: jax.Array,
    V_loc: jax.Array,
    qk_loc: QuantizedKeys,
    budget: int,
    length: jax.Array,
    *,
    axis: str | tuple[str, ...],
    shard_start: jax.Array,
    n_shards: int,
    group_reduce: str = "max",
    mode: str = "local",
) -> jax.Array:
    """One FIER decode step on a sequence shard (runs inside shard_map).

    q:        [B, Hq, D]       replicated across seq shards
    K_loc:    [B, S_loc, Hkv, D]
    qk_loc:   packed side-car over the local slice
    length:   [B] global valid length
    shard_start: scalar int32 — global position of this shard's first token
    Returns the *merged, normalised* attention output [B, Hq, D].
    """
    B, Hq, D = q.shape
    Hkv = K_loc.shape[2]
    S_loc = K_loc.shape[1]
    local_budget = max(budget // n_shards, 1)

    scores = retrieval.approx_scores(q, qk_loc)  # [B,Hq,S_loc]
    kv_scores = retrieval.reduce_over_query_group(scores, Hkv, group_reduce)
    local_len = jnp.clip(length - shard_start, 0, S_loc)  # [B]

    drop = None
    if mode == "local":
        k_sel = min(local_budget, S_loc)
        idx = retrieval.select_topk(kv_scores, k_sel, local_len)
    elif mode == "exact":
        # each shard nominates up to 2× its fair share; the global budget-th
        # candidate score τ (from one small all-gather) is the keep threshold
        k_cand = min(max(local_budget * 2, 1) if n_shards > 1 else budget, S_loc)
        pos = jnp.arange(S_loc, dtype=jnp.int32)
        masked = jnp.where(
            pos[None, None, :] < local_len[:, None, None], kv_scores, NEG_INF
        )
        cand_s, idx = jax.lax.top_k(masked, k_cand)
        all_s = jax.lax.all_gather(cand_s, axis, axis=-1, tiled=True)
        kth = jax.lax.top_k(all_s, min(budget, all_s.shape[-1]))[0][..., -1:]
        drop = (cand_s < kth) | (cand_s <= NEG_INF)
    else:
        raise ValueError(f"unknown distributed mode {mode!r}")

    Ksel, Vsel = retrieval.gather_kv(K_loc, V_loc, idx)
    idx_global = idx + shard_start
    if drop is not None:
        # dropped nominees are pushed past ``length`` → masked in attention
        idx_global = jnp.where(drop, jnp.int32(2**30), idx_global)
    m, num, den = _partial_attention(q, Ksel, Vsel, idx_global, length)
    out = lse_combine(m, num, den, axis)
    return out.reshape(B, Hq, D).astype(q.dtype)


def full_decode_sharded(
    q: jax.Array,
    K_loc: jax.Array,
    V_loc: jax.Array,
    length: jax.Array,
    *,
    axis: str | tuple[str, ...],
    shard_start: jax.Array,
) -> jax.Array:
    """Dense decode attention over a sequence-sharded cache (flash-decoding
    style LSE merge) — the Full-KV baseline at pod scale."""
    B, Hq, D = q.shape
    S_loc, Hkv = K_loc.shape[1], K_loc.shape[2]
    idx = jnp.broadcast_to(
        jnp.arange(S_loc, dtype=jnp.int32)[None, None, :], (B, Hkv, S_loc)
    )
    m, num, den = _partial_attention(q, K_loc, V_loc, idx + shard_start, length)
    out = lse_combine(m, num, den, axis)
    return out.reshape(B, Hq, D).astype(q.dtype)
