"""FIER core: 1-bit key quantization, token-level KV retrieval, baselines.

Public surface:
    quantize      — 1-bit group RTN quantize / pack / dequantize
    retrieval     — approx scores, top-k select, sparse attention (Alg. 1)
    quest         — Quest page-level baseline
    eviction      — H2O / StreamingLLM / SnapKV / TOVA baselines
    policy        — PolicyConfig + registry used by models & serving
    distributed   — sequence-sharded FIER with log-sum-exp merge
"""
from . import distributed, eviction, quantize, quest, retrieval
from .policy import POLICIES, PolicyConfig, build_metadata, decode_attention, update_metadata
from .quantize import QuantizedKeys, dequantize, load_ratio, quantize as quantize_keys

__all__ = [
    "POLICIES",
    "PolicyConfig",
    "QuantizedKeys",
    "build_metadata",
    "decode_attention",
    "dequantize",
    "distributed",
    "eviction",
    "load_ratio",
    "quantize",
    "quantize_keys",
    "quest",
    "retrieval",
    "update_metadata",
]
