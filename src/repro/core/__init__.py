"""FIER core: 1-bit key quantization, token-level KV retrieval, baselines.

Public surface:
    quantize      — 1-bit group RTN quantize / pack / dequantize
    retrieval     — approx scores, top-k select, sparse attention (Alg. 1)
    quest         — Quest page-level baseline
    eviction      — H2O / StreamingLLM / SnapKV / TOVA baselines
    policy        — CacheView + DecodePlan + the AttentionBackend registry
                    (the decode-attention API used by models & serving)
    distributed   — sequence-sharded FIER with log-sum-exp merge
"""
from . import distributed, eviction, quantize, quest, retrieval
from .policy import (
    LAYOUTS,
    PIPELINES,
    AttentionBackend,
    CacheView,
    DecodePlan,
    PolicyConfig,
    UnsupportedPlanError,
    build_metadata,
    decode_attention,
    get_backend,
    register_backend,
    registered_backends,
    update_metadata,
)
from .quantize import QuantizedKeys, dequantize, load_ratio, quantize as quantize_keys


def __getattr__(name):
    # POLICIES mirrors the live registry (register_backend rebinds
    # policy.POLICIES); resolving it lazily here keeps repro.core.POLICIES
    # from freezing at import time while third-party backends register
    if name == "POLICIES":
        from . import policy

        return policy.POLICIES
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")


__all__ = [
    "LAYOUTS",
    "PIPELINES",
    "POLICIES",
    "AttentionBackend",
    "CacheView",
    "DecodePlan",
    "PolicyConfig",
    "QuantizedKeys",
    "UnsupportedPlanError",
    "build_metadata",
    "decode_attention",
    "dequantize",
    "distributed",
    "eviction",
    "get_backend",
    "load_ratio",
    "quantize",
    "quantize_keys",
    "quest",
    "register_backend",
    "registered_backends",
    "retrieval",
    "update_metadata",
]
