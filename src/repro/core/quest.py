"""Quest (Tang et al., 2024) page-level KV retrieval — the paper's main baseline.

Pages of ``L`` consecutive tokens store per-channel min/max vectors; a page's
importance for query ``q`` is the box upper bound
    s_P = Σ_d max(q_d · kmax_d, q_d · kmin_d)                       (Quest)
The FIER paper's Eq. 3 *prints* a max over d; the original Quest (and its
released code, which FIER benchmarks against) uses the channel sum — we
implement the sum and keep the printed variant behind ``reduce="max"`` for
the ablation.  Load ratio: 2/L (paper Eq. 4).

``score_mode="quant"`` reproduces the Tab. 3 "Quest-p16-w/quant" ablation:
pages are scored by the *mean 1-bit approximate score* of their tokens.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import retrieval
from .quantize import QuantizedKeys


@jax.tree_util.register_pytree_node_class
class PageMeta:
    """kmax/kmin: bf16[B, S//L, Hkv, D]; page: python int (L, static aux)."""

    def __init__(self, kmax, kmin, page: int):
        self.kmax = kmax
        self.kmin = kmin
        self.page = page

    def tree_flatten(self):
        return (self.kmax, self.kmin), self.page

    @classmethod
    def tree_unflatten(cls, page, children):
        return cls(*children, page)

    def __repr__(self):
        return f"PageMeta(kmax={getattr(self.kmax, 'shape', None)}, page={self.page})"


def build_page_meta(K: jax.Array, page: int) -> PageMeta:
    B, S, H, D = K.shape
    if S % page != 0:
        raise ValueError(f"seq {S} not divisible by page {page}")
    Kp = K.reshape(B, S // page, page, H, D)
    return PageMeta(
        Kp.max(axis=2).astype(jnp.bfloat16), Kp.min(axis=2).astype(jnp.bfloat16), page
    )


def page_scores(
    q: jax.Array, meta: PageMeta, reduce: str = "sum"
) -> jax.Array:
    """Upper-bound page scores.  q [B,Hq,D] → [B,Hq,P]."""
    B, Hq, D = q.shape
    Hkv = meta.kmax.shape[2]
    rep = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, rep, D)
    amax = qf[:, None] * meta.kmax.astype(jnp.float32)[:, :, :, None, :]
    amin = qf[:, None] * meta.kmin.astype(jnp.float32)[:, :, :, None, :]
    per_chan = jnp.maximum(amax, amin)  # [B,P,Hkv,rep,D]
    if reduce == "sum":
        s = per_chan.sum(axis=-1)
    elif reduce == "max":
        s = per_chan.max(axis=-1)
    else:
        raise ValueError(reduce)
    return s.transpose(0, 2, 3, 1).reshape(B, Hq, -1)


def quant_page_scores(q: jax.Array, qk: QuantizedKeys, page: int) -> jax.Array:
    """Tab. 3 ablation: mean 1-bit score per page.  → [B,Hq,P]."""
    s = retrieval.approx_scores(q, qk)  # [B,Hq,S]
    B, Hq, S = s.shape
    return s.reshape(B, Hq, S // page, page).mean(axis=-1)


def quest_token_indices(
    kv_page_scores: jax.Array,
    budget: int,
    page: int,
    length: jax.Array | None = None,
) -> jax.Array:
    """Select top pages, expand to token indices.

    kv_page_scores: [B, Hkv, P] (already reduced over the query group)
    budget: token budget; n_pages = budget // page pages are selected.
    → idx int32 [B, Hkv, n_pages*page]
    """
    B, Hkv, P = kv_page_scores.shape
    n_pages = max(budget // page, 1)
    s = kv_page_scores
    if length is not None:
        # a page is selectable iff it has at least one valid token
        first_tok = jnp.arange(P, dtype=jnp.int32) * page
        valid = first_tok[None, None, :] < length[:, None, None]
        s = jnp.where(valid, s, retrieval.NEG_INF)
    _, pidx = jax.lax.top_k(s, n_pages)  # [B,Hkv,n_pages]
    offs = jnp.arange(page, dtype=jnp.int32)
    idx = pidx[..., None] * page + offs  # [B,Hkv,n_pages,page]
    return idx.reshape(B, Hkv, n_pages * page).astype(jnp.int32)


def quest_attention_decode(
    q: jax.Array,
    K: jax.Array,
    V: jax.Array,
    meta: PageMeta,
    budget: int,
    length: jax.Array | None = None,
    *,
    group_reduce: str = "max",
    reduce: str = "sum",
) -> jax.Array:
    """End-to-end Quest decode step (page select → exact attention)."""
    Hkv = K.shape[2]
    ps = page_scores(q, meta, reduce=reduce)
    kv_ps = retrieval.reduce_over_query_group(ps, Hkv, group_reduce)
    idx = quest_token_indices(kv_ps, budget, meta.page, length)
    Ksel, Vsel = retrieval.gather_kv(K, V, idx)
    return retrieval.sparse_attention(q, Ksel, Vsel, idx, length)
