"""Cache-policy registry: pluggable decode-attention policies.

``full`` / ``fier`` / ``quest`` are the serving fast paths (stateless
selection + static metadata, jit-friendly); eviction baselines live in
``eviction.py`` and are wired directly by the quality benchmarks.

The serving engine and the model zoo only see this interface:
    meta  = build_metadata(K, cfg)            # after prefill
    meta  = update_metadata(meta, K, pos)     # after each appended token
    out   = decode_attention(q, K, V, meta, cfg, length, layer)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import quantize, quest, retrieval

# full/fier/quest: serving fast paths.  slm: StreamingLLM as a *policy*
# (sink ∪ recent window — the strongest eviction baseline that needs no
# per-step state), used by the generation-level quality benchmarks.
POLICIES = ("full", "fier", "quest", "slm")


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    kind: str = "full"
    budget: int = 1024
    group: int = 32          # FIER group size g
    page: int = 16           # Quest page size L
    group_reduce: str = "max"  # GQA query-group score reduction
    sink: int = 0            # forced sink tokens (0 = paper-faithful)
    recent: int = 0          # forced recent window (0 = paper-faithful)
    skip_layers: int = 2     # full attention on first N layers (paper/Quest setup)
    use_kernels: bool = False  # Pallas fast path for the score scan
    fused: bool = False      # fused select-and-attend decode (fier only):
                             # threshold top-k + in-kernel gather, no
                             # materialised K'/V' copies (serving default
                             # via serving.engine.serving_policy)
    one_pass: bool = True    # with fused: single-kernel retrieval (score
                             # scan + group-reduce + mask + threshold
                             # top-k in one pass — per-token scores never
                             # touch HBM).  False = two-pass kernel
                             # pipeline, kept for ablation.
    paged: bool = False      # paged KV cache: device-side block pool +
                             # host-side BlockAllocator (prefix sharing,
                             # copy-on-write) instead of per-slot capacity
                             # slabs — see kvcache.paged / DESIGN.md
                             # §Paged KV cache
    block_size: int = 32     # tokens per cache block (paged mode); must be
                             # a multiple of 8 and of `group`
    pool_blocks: int = 0     # physical blocks in the pool (paged mode);
                             # 0 → worst-case default n_slots·capacity/bs+1

    def __post_init__(self):
        if self.kind not in POLICIES:
            raise ValueError(f"unknown policy {self.kind!r}; choose from {POLICIES}")
        if self.paged:
            from repro.kvcache.paged import check_block_size

            check_block_size(self.block_size, self.group if self.kind == "fier" else 0)


def build_metadata(K: jax.Array, cfg: PolicyConfig) -> Any:
    """Selection metadata over a (capacity-sized) key slab [B,S,Hkv,D]."""
    if cfg.kind == "fier":
        return quantize.quantize(K, cfg.group)
    if cfg.kind == "quest":
        return quest.build_page_meta(K, cfg.page)
    return None


def update_metadata(meta: Any, K: jax.Array, pos: jax.Array, cfg: PolicyConfig) -> Any:
    """Refresh the metadata block containing position ``pos`` (scalar or [B]).

    The cache slab ``K`` already holds the appended token.  Groups/pages are
    aligned blocks, so only one block per sequence is touched; we recompute
    it from the slab with a dynamic slice (batch-uniform pos: the serving
    engine aligns per-request positions; per-request pos uses vmap).
    """
    if meta is None:
        return None
    B, S, H, D = K.shape
    if cfg.kind == "fier":
        g = cfg.group
        start = (pos // g) * g
        blk = jax.lax.dynamic_slice_in_dim(K, start, g, axis=1)  # [B,g,H,D]
        scale, zero = quantize.group_stats(blk, g)  # [B,1,H,D]
        bits = quantize.sign_bits(blk, zero, g)
        codes = quantize.pack_bits(bits)  # [B,g//8,H,D]
        return quantize.QuantizedKeys(
            jax.lax.dynamic_update_slice_in_dim(meta.codes, codes, start // 8, axis=1),
            jax.lax.dynamic_update_slice_in_dim(meta.scale, scale, start // g, axis=1),
            jax.lax.dynamic_update_slice_in_dim(meta.zero, zero, start // g, axis=1),
            g,
        )
    if cfg.kind == "quest":
        L = cfg.page
        start = (pos // L) * L
        blk = jax.lax.dynamic_slice_in_dim(K, start, L, axis=1)
        kmax = blk.max(axis=1, keepdims=True).astype(jnp.bfloat16)
        kmin = blk.min(axis=1, keepdims=True).astype(jnp.bfloat16)
        return quest.PageMeta(
            jax.lax.dynamic_update_slice_in_dim(meta.kmax, kmax, start // L, axis=1),
            jax.lax.dynamic_update_slice_in_dim(meta.kmin, kmin, start // L, axis=1),
            L,
        )
    return meta


def decode_attention(
    q: jax.Array,
    K: jax.Array,
    V: jax.Array,
    meta: Any,
    cfg: PolicyConfig,
    length: jax.Array,
    layer: int | jax.Array = 0,
) -> jax.Array:
    """Policy-dispatched decode attention.  Static dispatch on cfg.kind;
    ``layer < skip_layers`` and ``length <= budget`` fall back to full."""
    if cfg.kind == "slm":
        # eviction baseline: fixed sink + recent window, no metadata
        B, Hq, _ = q.shape
        Hkv = K.shape[2]
        sink = max(cfg.sink, 4)
        zeros = jnp.zeros((B, Hkv, K.shape[1]), jnp.float32)
        idx = retrieval.select_topk(
            zeros, cfg.budget, length, sink=sink, recent=cfg.budget - sink
        )
        Ksel, Vsel = retrieval.gather_kv(K, V, idx)
        return retrieval.sparse_attention(q, Ksel, Vsel, idx, length)

    if cfg.kind == "full" or meta is None:
        return retrieval.full_attention_decode(q, K, V, length)

    if cfg.kind == "fier":
        sparse = retrieval.fier_attention_decode(
            q, K, V, meta, cfg.budget, length,
            group_reduce=cfg.group_reduce, sink=cfg.sink, recent=cfg.recent,
            use_kernels=cfg.use_kernels, fused=cfg.fused,
            one_pass=cfg.one_pass,
        )
    else:
        sparse = quest.quest_attention_decode(
            q, K, V, meta, cfg.budget, length, group_reduce=cfg.group_reduce
        )

    if isinstance(layer, int):
        if layer < cfg.skip_layers:
            return retrieval.full_attention_decode(q, K, V, length)
        return sparse
    # traced layer index (scan-over-layers): select at runtime
    full = retrieval.full_attention_decode(q, K, V, length)
    return jnp.where(layer < cfg.skip_layers, full, sparse)


def decode_attention_paged(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    meta: Any,
    block_table: jax.Array,
    cfg: PolicyConfig,
    length: jax.Array,
    layer: int = 0,
) -> jax.Array:
    """Policy-dispatched decode attention over a paged block pool.

    q [B, Hq, D]; k_pool/v_pool [N, bs, Hkv, D]; block_table [B, n_btab].
    The fier fused fast path walks the block table *in-kernel* (paged
    one-pass retrieval → paged select-and-attend, nothing pool-sized
    materialised); the full / unfused paths gather the logical slab view
    through the table and reuse the slab reference pipeline — they are
    the oracle, not the serving path.
    """
    if cfg.kind not in ("full", "fier"):
        raise ValueError(f"paged decode: unsupported policy {cfg.kind!r}")
    full_path = (
        cfg.kind == "full" or meta is None or layer < cfg.skip_layers
    )
    if cfg.kind == "fier" and cfg.fused and not full_path:
        from repro.kernels import ops as kops

        return kops.paged_fused_fier_attention_decode(
            q, k_pool, v_pool, meta, block_table, cfg.budget, length,
            group_reduce=cfg.group_reduce, sink=cfg.sink, recent=cfg.recent,
        )
    from repro.kvcache.paged import gather_paged_kv

    K, V, logical = gather_paged_kv(k_pool, v_pool, meta, block_table)
    if full_path:
        return retrieval.full_attention_decode(q, K, V, length)
    return retrieval.fier_attention_decode(
        q, K, V, logical, cfg.budget, length,
        group_reduce=cfg.group_reduce, sink=cfg.sink, recent=cfg.recent,
        use_kernels=cfg.use_kernels, fused=False,
    )
