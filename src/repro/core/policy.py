"""Decode-attention backend registry: ``CacheView`` + ``DecodePlan``.

Three first-class objects replace the boolean-flag dispatch the first
three PRs accreted (``use_kernels`` × ``fused`` × ``one_pass`` ×
``paged`` and a family of parallel entrypoints):

``CacheView``
    A pytree bundling everything a decode step reads: the K/V slabs (or
    the paged block pool), the policy's side-car metadata, the block
    table, and the per-sequence ``length``.  Slab vs paged is a
    ``layout`` field, not a separate signature.

``DecodePlan``
    The resolved execution plan — ``policy × layout × pipeline`` with
    ``pipeline ∈ {reference, two_pass, one_pass}`` — validated at build
    time against the backend's capability matrix.  An unsupported
    combination (e.g. ``quest`` on a paged cache) raises
    :class:`UnsupportedPlanError` listing the supported matrix instead
    of silently falling back.

``AttentionBackend``
    Registry entries (``full`` / ``fier`` / ``quest`` / ``slm``), each
    declaring ``build_metadata`` / ``update_metadata`` / ``decode`` and
    its supported ``(layout, pipeline)`` set.  Third-party backends
    register with :func:`register_backend` (DESIGN.md §Backend registry
    & DecodePlan).

The serving engine and the model zoo only see this interface::

    plan  = DecodePlan.build(cfg, capacity=capacity)
    meta  = build_metadata(K, cfg)            # after prefill
    meta  = update_metadata(meta, K, pos, cfg)  # after each appended token
    out   = decode_attention(q, view, plan, layer=layer)

Pipelines (the FIER backend; ``full``/``quest``/``slm`` are
reference-only):

* ``reference`` — the pure-jnp oracle pipeline (score → top-k → gather →
  attend); ``PolicyConfig.use_kernels`` swaps the scoring step for the
  Pallas score kernel (ablation).
* ``two_pass``  — score-scan kernel → threshold-select kernel → fused
  select-and-attend (f32 score tensor materialised between kernels).
* ``one_pass``  — single-kernel retrieval (scores never touch HBM) →
  fused select-and-attend; the serving default.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import quantize, quest, retrieval

PIPELINES = ("reference", "two_pass", "one_pass")
LAYOUTS = ("slab", "paged")


# --------------------------------------------------------------- PolicyConfig

@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    kind: str = "full"
    budget: int = 1024
    group: int = 32          # FIER group size g
    page: int = 16           # Quest page size L
    group_reduce: str = "max"  # GQA query-group score reduction
    sink: int = 0            # forced sink tokens (0 = paper-faithful)
    recent: int = 0          # forced recent window (0 = paper-faithful)
    skip_layers: int = 2     # full attention on first N layers (paper/Quest setup)
    use_kernels: bool = False  # reference pipeline only: Pallas score scan
                             # instead of the jnp score (ablation)
    pipeline: str = "reference"  # reference | two_pass | one_pass — which
                             # decode pipeline the plan resolves to
                             # (serving default via serving_policy() is
                             # one_pass; validated against the backend's
                             # capability matrix by DecodePlan.build)
    layout: str = "slab"     # slab | paged — per-slot capacity slabs vs
                             # block-pool + block tables (kvcache.paged,
                             # DESIGN.md §Paged KV cache)
    block_size: int = 32     # tokens per cache block (paged layout); must
                             # be a multiple of 8 and of `group` —
                             # validated by DecodePlan.build
    pool_blocks: int = 0     # physical blocks in the pool (paged layout);
                             # 0 → worst-case default n_slots·capacity/bs+1

    # Deprecated boolean dispatch flags (pre-registry API).  They are
    # init-only: accepted, translated onto pipeline/layout with a
    # DeprecationWarning, and never stored.
    fused: dataclasses.InitVar[bool | None] = None
    one_pass: dataclasses.InitVar[bool | None] = None
    paged: dataclasses.InitVar[bool | None] = None

    def __post_init__(self, fused, one_pass, paged):
        if fused is not None or one_pass is not None or paged is not None:
            _warn_deprecated(
                "PolicyConfig's `fused` / `one_pass` / `paged` booleans",
                "pipeline='reference'|'two_pass'|'one_pass' and "
                "layout='slab'|'paged'",
            )
            if paged is not None:
                object.__setattr__(self, "layout", "paged" if paged else "slab")
            if fused is not None:
                if fused:
                    # the pre-registry paged dispatch ignored the
                    # `one_pass` flag (the paged fast path was always the
                    # one-pass kernels), so fused+paged maps to one_pass
                    # even when the flag is False — keeping that combo
                    # serving instead of tripping the (paged, two_pass)
                    # matrix hole
                    on_paged = self.layout == "paged"
                    pipe = (
                        "two_pass" if (one_pass is False and not on_paged)
                        else "one_pass"
                    )
                else:
                    pipe = "reference"
                object.__setattr__(self, "pipeline", pipe)
        if self.kind not in _REGISTRY:
            raise ValueError(
                f"unknown policy {self.kind!r}; registered: {tuple(_REGISTRY)}"
            )
        if self.pipeline not in PIPELINES:
            raise ValueError(
                f"unknown pipeline {self.pipeline!r}; choose from {PIPELINES}"
            )
        if self.layout not in LAYOUTS:
            raise ValueError(f"unknown layout {self.layout!r}; choose from {LAYOUTS}")
        # NOTE: the legacy flags are accepted but never stored — reading
        # ``cfg.fused`` / ``cfg.one_pass`` / ``cfg.paged`` yields the
        # InitVar default (None), not the truth.  Read ``cfg.pipeline``
        # / ``cfg.layout`` instead.  (They cannot be exposed as
        # properties: ``dataclasses.replace`` re-feeds InitVar values via
        # ``getattr``, so properties would resurrect stale flags and
        # override explicit ``replace(cfg, layout=...)`` changes.)


# ------------------------------------------------------------------ CacheView

@jax.tree_util.register_pytree_node_class
class CacheView:
    """Everything one decode-attention call reads, as a single pytree.

    ``layout='slab'``: ``k``/``v`` are per-slot capacity slabs
    [B, S, Hkv, D] and ``block_table`` is None.  ``layout='paged'``:
    ``k``/``v`` are the shared block pool [N, bs, Hkv, D] and
    ``block_table`` [B, n_btab] maps logical blocks to pool rows.
    ``meta`` is the policy side-car (``QuantizedKeys`` for fier,
    ``PageMeta`` for quest, None for full), in the matching layout.
    ``length`` [B] int32 masks unwritten positions (None = all valid).

    ``layout`` is static pytree aux data, so a jitted function traced on
    a slab view re-traces (rather than mis-dispatches) on a paged one.
    """

    __slots__ = ("k", "v", "meta", "block_table", "length", "layout")

    def __init__(self, k, v, meta=None, block_table=None, length=None,
                 *, layout: str = "slab"):
        if layout not in LAYOUTS:
            raise ValueError(f"unknown layout {layout!r}; choose from {LAYOUTS}")
        if layout == "paged" and block_table is None:
            raise ValueError("paged CacheView requires a block_table")
        self.k = k
        self.v = v
        self.meta = meta
        self.block_table = block_table
        self.length = length
        self.layout = layout

    @classmethod
    def slab(cls, k, v, meta=None, length=None) -> "CacheView":
        return cls(k, v, meta, None, length, layout="slab")

    @classmethod
    def paged(cls, k, v, meta, block_table, length=None) -> "CacheView":
        return cls(k, v, meta, block_table, length, layout="paged")

    def logical(self):
        """(K, V, meta) as logical per-request slabs — gathers the pool
        through the block table for the paged layout (the oracle /
        reference-pipeline path; the fused kernels walk the table
        in-kernel instead).  Absent leaves (e.g. a metadata-only
        retrieval view with no K/V) pass through as None."""
        if self.layout == "slab":
            return self.k, self.v, self.meta
        from repro.kvcache.paged import gather_block_rows

        def g(a):
            return None if a is None else gather_block_rows(a, self.block_table)

        meta = (
            None if self.meta is None
            else jax.tree.map(g, self.meta)  # side-car pytree, any policy
        )
        return g(self.k), g(self.v), meta

    def tree_flatten(self):
        return (self.k, self.v, self.meta, self.block_table, self.length), self.layout

    @classmethod
    def tree_unflatten(cls, layout, children):
        k, v, meta, block_table, length = children
        view = object.__new__(cls)
        view.k, view.v, view.meta = k, v, meta
        view.block_table, view.length, view.layout = block_table, length, layout
        return view

    def __repr__(self):
        sh = lambda a: getattr(a, "shape", None)
        return (
            f"CacheView(layout={self.layout!r}, k={sh(self.k)}, "
            f"meta={type(self.meta).__name__ if self.meta is not None else None}, "
            f"block_table={sh(self.block_table)})"
        )


# ----------------------------------------------------------- backend registry

class UnsupportedPlanError(ValueError):
    """(policy, layout, pipeline) combination outside the backend's
    declared capability matrix."""


@dataclasses.dataclass(frozen=True)
class AttentionBackend:
    """One registered decode-attention policy.

    ``supports`` is the declared (layout, pipeline) capability matrix —
    ``DecodePlan.build`` refuses anything outside it.  The three callables
    take the same arguments for every backend, so a third-party policy
    registers without touching the dispatch:

        register_backend(AttentionBackend(
            name="mypolicy",
            supports=frozenset({("slab", "reference")}),
            build_metadata=...,      # (K, cfg) -> meta
            update_metadata=...,     # (meta, K, pos, cfg) -> meta
            decode=...,              # (q, view, plan) -> out [B, Hq, D]
        ))
    """

    name: str
    supports: frozenset
    build_metadata: Callable[[jax.Array, PolicyConfig], Any]
    update_metadata: Callable[[Any, jax.Array, jax.Array, PolicyConfig], Any]
    decode: Callable[[jax.Array, CacheView, "DecodePlan"], jax.Array]
    # selection modes the backend supports when the plan carries a mesh
    # sharding spec (kvcache/sharded.py); empty = single-device only.
    # "exact" promises bit-identity to the single-device oracle on the
    # TP×DP paged layout, "local" admits per-shard approximate selection
    # (the sequence-sharded slab path)
    supports_sharding: frozenset = frozenset()
    # a backend whose selection needs side-car metadata falls back to
    # dense attention when the view carries none (e.g. the skip-layer
    # front caches); metadata-less backends (slm, or third parties whose
    # build_metadata returns None) set False so their decode always runs
    needs_metadata: bool = True
    # whether `layer < skip_layers` falls back to dense attention; False
    # for backends that are their own full-attention substitute (full,
    # slm)
    skip_layers_fallback: bool = True

    def supports_str(self) -> str:
        return ", ".join(f"{lo}×{pi}" for lo, pi in sorted(self.supports))

    def sharding_str(self) -> str:
        """The ``supports_sharding`` entry, rendered like the capability
        matrix ('-' when the backend is single-device only)."""
        return ", ".join(sorted(self.supports_sharding)) or "-"


_REGISTRY: dict[str, AttentionBackend] = {}


def register_backend(backend: AttentionBackend, *, overwrite: bool = False) -> None:
    global POLICIES
    if backend.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {backend.name!r} already registered")
    bad = {c for c in backend.supports if c[0] not in LAYOUTS or c[1] not in PIPELINES}
    if bad:
        raise ValueError(f"backend {backend.name!r}: invalid capabilities {bad}")
    bad_modes = set(backend.supports_sharding) - {"local", "exact"}
    if bad_modes:
        raise ValueError(
            f"backend {backend.name!r}: invalid sharding modes {sorted(bad_modes)}"
        )
    _REGISTRY[backend.name] = backend
    POLICIES = tuple(_REGISTRY)


def get_backend(name: str) -> AttentionBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r}; registered: {tuple(_REGISTRY)}"
        ) from None


def registered_backends() -> tuple[str, ...]:
    return tuple(_REGISTRY)


# ----------------------------------------------------------------- DecodePlan

@dataclasses.dataclass(frozen=True)
class DecodePlan:
    """A validated ``policy × layout × pipeline`` execution plan.

    Build via :meth:`build` — the constructor performs no validation, so
    a hand-rolled instance can bypass the capability matrix (don't).
    Plans are static/hashable: bundles build them once and close over
    them; kernels never see the plan, only the view.
    """

    policy: PolicyConfig
    layout: str = "slab"
    pipeline: str = "reference"
    # mesh sharding spec (kvcache.sharded.ShardSpec) — None = single
    # device.  Carried on the plan so decode_attention(q, view, plan)
    # composes TP×DP with every backend without new entrypoints
    shard: Any = None

    @property
    def backend(self) -> AttentionBackend:
        return get_backend(self.policy.kind)

    @classmethod
    def build(
        cls,
        policy: PolicyConfig,
        *,
        layout: str | None = None,
        pipeline: str | None = None,
        capacity: int | None = None,
        shard: Any = None,
    ) -> "DecodePlan":
        """Resolve and validate a plan.

        Validation hoisted here (out of ``PolicyConfig.__post_init__``
        and the kernels' deep shape asserts): the capability matrix, the
        paged ``block_size`` divisibility rules, and — when ``capacity``
        is known — ``budget``/``sink``/``recent`` bounds that previously
        failed only deep inside a kernel at the first decode step.
        """
        layout = layout if layout is not None else policy.layout
        pipeline = pipeline if pipeline is not None else policy.pipeline
        backend = get_backend(policy.kind)
        if (layout, pipeline) not in backend.supports:
            raise UnsupportedPlanError(
                f"policy {policy.kind!r} does not support layout={layout!r} "
                f"with pipeline={pipeline!r}; supported: {backend.supports_str()}"
            )
        if policy.budget <= 0:
            raise ValueError(f"budget must be positive, got {policy.budget}")
        if policy.sink < 0 or policy.recent < 0:
            raise ValueError(
                f"sink/recent must be >= 0, got ({policy.sink}, {policy.recent})"
            )
        if layout == "paged":
            from repro.kvcache.paged import check_block_size

            check_block_size(
                policy.block_size, policy.group if policy.kind == "fier" else 0
            )
        if shard is not None:
            # duck-typed (mesh/tp_axes/dp_axes/mode) so policy.py never
            # imports kvcache.sharded — paged.py imports this module
            axes = tuple(shard.tp_axes) + tuple(shard.dp_axes)
            if layout != "paged":
                raise UnsupportedPlanError(
                    f"policy {policy.kind!r}: mesh-sharded decode over axes "
                    f"{axes!r} requires layout='paged', got layout={layout!r}"
                )
            if shard.mode not in backend.supports_sharding:
                raise UnsupportedPlanError(
                    f"policy {policy.kind!r} does not support sharded decode "
                    f"in mode={shard.mode!r} over mesh axes {axes!r}; backend "
                    f"sharding modes: {backend.sharding_str()}; supported "
                    f"layouts: {backend.supports_str()}"
                )
        plan = cls(policy, layout, pipeline, shard)
        if capacity is not None:
            plan.validate_capacity(capacity)
        return plan

    def validate_capacity(self, capacity: int) -> "DecodePlan":
        """Check the plan against a concrete cache capacity (called by
        ``init_cache`` / the engine, where capacity is first known)."""
        pol = self.policy
        if pol.kind != "full" and pol.budget > capacity:
            raise ValueError(
                f"policy budget {pol.budget} exceeds cache capacity "
                f"{capacity}: the selection kernels require budget <= S "
                f"(clamp the budget or grow the cache)"
            )
        # no sink/recent bound: the guard-rails are score *overrides*
        # and decode-time masking clamps them to the valid prefix, so
        # any non-negative value is safe at any capacity
        if self.layout == "paged" and capacity % pol.block_size:
            raise ValueError(
                f"capacity {capacity} not divisible by block_size "
                f"{pol.block_size}"
            )
        return self

    def with_pipeline(self, pipeline: str) -> "DecodePlan":
        """Re-resolve (and re-validate) this plan with another pipeline."""
        return DecodePlan.build(
            self.policy, layout=self.layout, pipeline=pipeline, shard=self.shard
        )


# --------------------------------------------------------- metadata dispatch

def build_metadata(K: jax.Array, cfg: PolicyConfig) -> Any:
    """Selection metadata over a (capacity-sized) key slab [B,S,Hkv,D]."""
    return get_backend(cfg.kind).build_metadata(K, cfg)


def update_metadata(meta: Any, K: jax.Array, pos: jax.Array, cfg: PolicyConfig) -> Any:
    """Refresh the metadata block containing position ``pos`` (scalar or [B]).

    The cache slab ``K`` already holds the appended token.  Groups/pages are
    aligned blocks, so only one block per sequence is touched; we recompute
    it from the slab with a dynamic slice (batch-uniform pos: the serving
    engine aligns per-request positions; per-request pos uses vmap).
    """
    if meta is None:
        return None
    return get_backend(cfg.kind).update_metadata(meta, K, pos, cfg)


# ------------------------------------------------------------------ dispatch

def _dense_decode(q: jax.Array, view: CacheView) -> jax.Array:
    """Full attention over the logical cache (skip-layer / full fallback)."""
    K, V, _ = view.logical()
    return retrieval.full_attention_decode(q, K, V, view.length)


def decode_attention(q: jax.Array, *args, **kwargs) -> jax.Array:
    """The single decode-attention entrypoint: ``decode_attention(q, view,
    plan, layer=...)``.

    ``layer < plan.policy.skip_layers`` falls back to dense attention
    (the paper's skip-layers); a traced ``layer`` selects at runtime.
    ``slm`` ignores ``skip_layers`` (it is itself the full-attention
    eviction baseline).

    The pre-registry signature ``decode_attention(q, K, V, meta, cfg,
    length, layer)`` still forwards (with a DeprecationWarning).
    """
    if (args and isinstance(args[0], CacheView)) or "view" in kwargs:
        view = args[0] if args else kwargs.pop("view")
        plan = args[1] if len(args) > 1 else kwargs.pop("plan")
        layer = args[2] if len(args) > 2 else kwargs.pop("layer", 0)
        if kwargs or len(args) > 3:
            raise TypeError(f"unexpected arguments: {args[3:]} {kwargs}")
        return _decode_attention(q, view, plan, layer)
    # ---- deprecated flat-argument form
    _warn_deprecated(
        "decode_attention(q, K, V, meta, cfg, length, layer)",
        "decode_attention(q, CacheView.slab(K, V, meta, length), "
        "DecodePlan.build(cfg), layer=layer)",
    )
    names = ("K", "V", "meta", "cfg", "length", "layer")
    flat = dict(zip(names, args))
    flat.update(kwargs)
    cfg = flat["cfg"]
    view = CacheView.slab(flat["K"], flat["V"], flat.get("meta"), flat.get("length"))
    return _decode_attention(q, view, DecodePlan.build(cfg), flat.get("layer", 0))


def _decode_attention(
    q: jax.Array, view: CacheView, plan: DecodePlan, layer: int | jax.Array
) -> jax.Array:
    if plan.layout != view.layout:
        raise UnsupportedPlanError(
            f"plan layout {plan.layout!r} does not match view layout "
            f"{view.layout!r}: the plan's build-time validation covered a "
            f"different cache layout than the one being decoded"
        )
    cfg = plan.policy
    backend = plan.backend
    if backend.needs_metadata and view.meta is None:
        return _dense_decode(q, view)
    sparse = backend.decode(q, view, plan)
    if not backend.skip_layers_fallback:
        return sparse
    if isinstance(layer, int):
        if layer < cfg.skip_layers:
            return _dense_decode(q, view)
        return sparse
    # traced layer index (scan-over-layers): select at runtime
    full = _dense_decode(q, view)
    return jnp.where(layer < cfg.skip_layers, full, sparse)


# ---------------------------------------------------------- builtin backends

def _fier_build_metadata(K, cfg):
    return quantize.quantize(K, cfg.group)


def _fier_update_metadata(meta, K, pos, cfg):
    B, S, H, D = K.shape
    g = cfg.group
    start = (pos // g) * g
    blk = jax.lax.dynamic_slice_in_dim(K, start, g, axis=1)  # [B,g,H,D]
    scale, zero = quantize.group_stats(blk, g)  # [B,1,H,D]
    bits = quantize.sign_bits(blk, zero, g)
    codes = quantize.pack_bits(bits)  # [B,g//8,H,D]
    return quantize.QuantizedKeys(
        jax.lax.dynamic_update_slice_in_dim(meta.codes, codes, start // 8, axis=1),
        jax.lax.dynamic_update_slice_in_dim(meta.scale, scale, start // g, axis=1),
        jax.lax.dynamic_update_slice_in_dim(meta.zero, zero, start // g, axis=1),
        g,
    )


def _fier_decode(q, view, plan):
    cfg = plan.policy
    sel = dict(group_reduce=cfg.group_reduce, sink=cfg.sink, recent=cfg.recent)
    if plan.pipeline in ("one_pass", "two_pass"):
        from repro.kernels import ops as kops

        if plan.pipeline == "one_pass":
            return kops.fier_decode_one_pass(q, view, cfg.budget, **sel)
        return kops.fier_decode_two_pass(q, view, cfg.budget, **sel)
    K, V, meta = view.logical()
    return retrieval.fier_decode_reference(
        q, K, V, meta, cfg.budget, view.length,
        use_kernels=cfg.use_kernels, **sel,
    )


def _quest_build_metadata(K, cfg):
    return quest.build_page_meta(K, cfg.page)


def _quest_update_metadata(meta, K, pos, cfg):
    L = cfg.page
    start = (pos // L) * L
    blk = jax.lax.dynamic_slice_in_dim(K, start, L, axis=1)
    kmax = blk.max(axis=1, keepdims=True).astype(jnp.bfloat16)
    kmin = blk.min(axis=1, keepdims=True).astype(jnp.bfloat16)
    return quest.PageMeta(
        jax.lax.dynamic_update_slice_in_dim(meta.kmax, kmax, start // L, axis=1),
        jax.lax.dynamic_update_slice_in_dim(meta.kmin, kmin, start // L, axis=1),
        L,
    )


def _quest_decode(q, view, plan):
    cfg = plan.policy
    K, V, meta = view.logical()
    return quest.quest_attention_decode(
        q, K, V, meta, cfg.budget, view.length, group_reduce=cfg.group_reduce
    )


def _slm_decode(q, view, plan):
    cfg = plan.policy
    K, V, _ = view.logical()
    B, Hq, _ = q.shape
    Hkv = K.shape[2]
    sink = max(cfg.sink, 4)
    zeros = jnp.zeros((B, Hkv, K.shape[1]), jnp.float32)
    idx = retrieval.select_topk(
        zeros, cfg.budget, view.length, sink=sink, recent=cfg.budget - sink
    )
    Ksel, Vsel = retrieval.gather_kv(K, V, idx)
    return retrieval.sparse_attention(q, Ksel, Vsel, idx, view.length)


def _no_metadata(K, cfg):
    return None


def _keep_metadata(meta, K, pos, cfg):
    return meta


register_backend(AttentionBackend(
    name="full",
    supports=frozenset({("slab", "reference"), ("paged", "reference")}),
    build_metadata=_no_metadata,
    update_metadata=_keep_metadata,
    decode=lambda q, view, plan: _dense_decode(q, view),
    needs_metadata=False,
    skip_layers_fallback=False,  # decode *is* dense attention
    supports_sharding=frozenset({"local", "exact"}),
))

register_backend(AttentionBackend(
    name="fier",
    supports=frozenset({
        ("slab", "reference"), ("slab", "two_pass"), ("slab", "one_pass"),
        ("paged", "reference"), ("paged", "one_pass"),
    }),
    build_metadata=_fier_build_metadata,
    update_metadata=_fier_update_metadata,
    decode=_fier_decode,
    supports_sharding=frozenset({"local", "exact"}),
))

register_backend(AttentionBackend(
    name="quest",
    supports=frozenset({("slab", "reference")}),
    build_metadata=_quest_build_metadata,
    update_metadata=_quest_update_metadata,
    decode=_quest_decode,
))

# slm: StreamingLLM as a *policy* (sink ∪ recent window — the strongest
# eviction baseline that needs no per-step state), used by the
# generation-level quality benchmarks.
register_backend(AttentionBackend(
    name="slm",
    supports=frozenset({("slab", "reference")}),
    build_metadata=_no_metadata,
    update_metadata=_keep_metadata,
    decode=_slm_decode,
    needs_metadata=False,
    skip_layers_fallback=False,  # its own full-attention substitute
))
# POLICIES mirrors the registry (register_backend refreshes it); the
# builtin registrations above make it ("full", "fier", "quest", "slm")


# ---------------------------------------------------------------- deprecation

_warned: set[str] = set()


def _warn_deprecated(old: str, new: str) -> None:
    """One DeprecationWarning per deprecated entrypoint per process."""
    if old in _warned:
        return
    _warned.add(old)
    warnings.warn(
        f"{old} is deprecated; use {new} (DESIGN.md §Backend registry & "
        f"DecodePlan)",
        DeprecationWarning,
        stacklevel=3,
    )


def decode_attention_paged(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    meta: Any,
    block_table: jax.Array,
    cfg: PolicyConfig,
    length: jax.Array,
    layer: int = 0,
) -> jax.Array:
    """Deprecated: build a paged ``CacheView`` + ``DecodePlan`` and call
    :func:`decode_attention`."""
    _warn_deprecated(
        "decode_attention_paged(q, k_pool, v_pool, meta, block_table, cfg, "
        "length)",
        "decode_attention(q, CacheView.paged(...), DecodePlan.build(cfg, "
        "layout='paged'))",
    )
    view = CacheView.paged(k_pool, v_pool, meta, block_table, length)
    plan = DecodePlan.build(cfg, layout="paged")
    return _decode_attention(q, view, plan, layer)
