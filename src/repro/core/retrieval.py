"""FIER retrieval: approximate scores from 1-bit keys → top-k → exact attention.

Paper Algorithm 1, extended to batched GQA decode (the paper's "future work"
— see DESIGN.md §2).  All functions are pure and jit-friendly; the Pallas
fast path lives in ``repro.kernels`` and is validated against these.

Shapes (decode step):
    q        [B, Hq, D]          one new query per sequence
    K, V     [B, S, Hkv, D]      cache slabs (bf16)
    qk (side-car)                ``QuantizedKeys`` over the same slab
    length   [B] int32           valid prefix length per sequence
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .quantize import QuantizedKeys

NEG_INF = -1e30


APPROX_SCORE_BLOCK = 2048  # seq tokens per scan step (≈ a VMEM block)


def approx_scores(q: jax.Array, qk: QuantizedKeys) -> jax.Array:
    """s̃ = q·K̃ᵀ from packed 1-bit codes.  Returns f32 [B, Hq, S].

    Efficient form (what the Pallas kernel implements): for token i in
    seq-group G(i),
        s̃_i = (q ⊙ s_G)·codes_i + q·z_G
    i.e. one group-rescaled query per group plus a per-group constant.

    Computed *blockwise* over the sequence (lax.scan): the f32 unpack of
    the codes lives one block at a time, mirroring the kernel's
    HBM→VMEM streaming — the unblocked version materialised
    4·S·Hkv·D bytes per layer (gigabytes at 32k; §Perf iteration 5).
    """
    B, Hq, D = q.shape
    S = qk.seq_len
    g = qk.group
    blk = min(APPROX_SCORE_BLOCK, S)
    while S % blk:
        blk //= 2
    if blk == S:
        return _approx_scores_block(q, qk.codes, qk.scale, qk.zero, g)
    nb = S // blk
    codes = jnp.moveaxis(
        qk.codes.reshape(B, nb, blk // 8, *qk.codes.shape[2:]), 1, 0
    )
    scale = jnp.moveaxis(qk.scale.reshape(B, nb, blk // g, *qk.scale.shape[2:]), 1, 0)
    zero = jnp.moveaxis(qk.zero.reshape(B, nb, blk // g, *qk.zero.shape[2:]), 1, 0)

    def body(_, xs):
        c, s_, z_ = xs
        return None, _approx_scores_block(q, c, s_, z_, g)

    _, sb = jax.lax.scan(body, None, (codes, scale, zero))  # [nb, B, Hq, blk]
    return jnp.moveaxis(sb, 0, 2).reshape(B, Hq, S)


def _approx_scores_block(q, codes, scale, zero, g) -> jax.Array:
    """bf16-valued operands, f32 arithmetic — the exact MXU contract of the
    Pallas kernel (bf16 inputs, every product exact in f32, f32 accumulate).

    The operands are *rounded to bf16 values* but the arithmetic runs in
    f32: a bf16×bf16 product fits f32 exactly, so the only rounding left
    is the f32 accumulation — which makes this block function bit-stable
    whether it runs eagerly, jitted, or as a ``lax.scan`` body (the old
    version multiplied q⊙s *in bf16*, and XLA kept the fused intermediate
    in f32 under scan but rounded it eagerly, so results depended on
    APPROX_SCORE_BLOCK; caught by
    tests/test_retrieval.py::test_approx_scores_blockwise_independent_of_block)."""
    from .quantize import unpack_bits

    B, Hq, D = q.shape
    S = codes.shape[1] * 8
    Hkv = codes.shape[2]
    rep = Hq // Hkv
    bits = unpack_bits(codes).astype(jnp.float32)
    pm1 = (bits * 2.0 - 1.0).reshape(B, S // g, g, Hkv, D)  # exact ±1
    qf = q.astype(jnp.bfloat16).astype(jnp.float32).reshape(B, Hkv, rep, D)
    qs = qf[:, None] * scale.astype(jnp.float32)[:, :, :, None, :]  # exact
    const = jnp.einsum(
        "bhrd,bghd->bghr", qf, zero.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    s = jnp.einsum(
        "bghrd,bgthd->bghrt", qs, pm1, preferred_element_type=jnp.float32,
    ) + const[..., None]
    return s.transpose(0, 2, 3, 1, 4).reshape(B, Hq, S)


def exact_scores(q: jax.Array, K: jax.Array) -> jax.Array:
    """Ground-truth scores q·Kᵀ (no softmax scaling — ranking only)."""
    B, Hq, D = q.shape
    Hkv = K.shape[2]
    rep = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, rep, D)
    s = jnp.einsum("bhrd,bshd->bhrs", qf, K.astype(jnp.float32))
    return s.reshape(B, Hq, -1)


def reduce_over_query_group(scores: jax.Array, n_kv: int, mode: str = "max") -> jax.Array:
    """GQA extension: [B, Hq, S] → [B, Hkv, S] so top-k is per KV head."""
    B, Hq, S = scores.shape
    s = scores.reshape(B, n_kv, Hq // n_kv, S)
    if mode == "max":
        return s.max(axis=2)
    if mode == "sum":
        return s.sum(axis=2)
    raise ValueError(f"unknown group reduction {mode!r}")


def masked_scores(
    scores: jax.Array,
    length: jax.Array | None = None,
    *,
    sink: int = 0,
    recent: int = 0,
) -> jax.Array:
    """Apply the selection guard-rails to raw scores [B, Hkv, S].

    ``length`` masks out unwritten cache slots (→ NEG_INF).  ``sink`` /
    ``recent`` force the first/last tokens into the selection by score
    override (+inf), the standard serving guard-rails; paper-faithful mode
    is sink=recent=0.  Shared by the jnp ``select_topk`` oracle and the
    Pallas threshold-select fast path so both rank the same scores.
    """
    B, Hkv, S = scores.shape
    pos = jnp.arange(S, dtype=jnp.int32)
    s = scores
    if length is not None:
        valid = pos[None, None, :] < length[:, None, None]
        s = jnp.where(valid, s, NEG_INF)
    if sink > 0:
        s = jnp.where(pos[None, None, :] < sink, jnp.inf, s)
    if recent > 0 and length is not None:
        is_recent = pos[None, None, :] >= (length - recent)[:, None, None]
        is_recent &= pos[None, None, :] < length[:, None, None]
        s = jnp.where(is_recent, jnp.inf, s)
    return s


def select_topk(
    scores: jax.Array,
    budget: int,
    length: jax.Array | None = None,
    *,
    sink: int = 0,
    recent: int = 0,
) -> jax.Array:
    """Top-``budget`` token indices per (batch, kv-head).

    scores: [B, Hkv, S] → indices int32 [B, Hkv, budget]

    This is the jnp oracle (global ``lax.top_k`` sort); the serving fast
    path is ``kernels.ops.topk_select`` (threshold search, no sort), which
    must return the same index *set* for any scores.
    """
    s = masked_scores(scores, length, sink=sink, recent=recent)
    _, idx = jax.lax.top_k(s, budget)
    return idx.astype(jnp.int32)


def gather_kv(K: jax.Array, V: jax.Array, idx: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Gather selected rows: K,V [B,S,Hkv,D], idx [B,Hkv,k] → [B,k,Hkv,D]."""
    Kh = jnp.swapaxes(K, 1, 2)  # [B,Hkv,S,D]
    Vh = jnp.swapaxes(V, 1, 2)
    Ksel = jnp.take_along_axis(Kh, idx[..., None], axis=2)
    Vsel = jnp.take_along_axis(Vh, idx[..., None], axis=2)
    return jnp.swapaxes(Ksel, 1, 2), jnp.swapaxes(Vsel, 1, 2)


def sparse_attention(
    q: jax.Array,
    Ksel: jax.Array,
    Vsel: jax.Array,
    idx: jax.Array,
    length: jax.Array | None = None,
) -> jax.Array:
    """Exact softmax attention over the selected tokens (decode, 1 query).

    q [B,Hq,D], Ksel/Vsel [B,k,Hkv,D], idx [B,Hkv,k] → out [B,Hq,D].
    Invalid slots (idx >= length, possible when budget > length) are masked.
    bf16 operands / f32 accumulation: `.astype(f32)` on the slabs would
    materialise f32 cache copies (§Perf iteration B — 2.3→0.9 GB/layer).
    """
    B, Hq, D = q.shape
    Hkv = Ksel.shape[2]
    rep = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    qb = q.astype(Ksel.dtype).reshape(B, Hkv, rep, D)
    s = jnp.einsum(
        "bhrd,bkhd->bhrk", qb, Ksel, preferred_element_type=jnp.float32
    ) * scale
    if length is not None:
        invalid = idx[:, :, None, :] >= length[:, None, None, None]
        s = jnp.where(invalid, NEG_INF, s)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhrk,bkhd->bhrd", p.astype(Vsel.dtype), Vsel,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, Hq, D).astype(q.dtype)


def full_attention_decode(
    q: jax.Array, K: jax.Array, V: jax.Array, length: jax.Array | None = None
) -> jax.Array:
    """Dense decode attention over the whole cache (the Full-KV baseline).
    bf16 operands / f32 accumulation — see sparse_attention."""
    B, Hq, D = q.shape
    S, Hkv = K.shape[1], K.shape[2]
    rep = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    qb = q.astype(K.dtype).reshape(B, Hkv, rep, D)
    s = jnp.einsum(
        "bhrd,bshd->bhrs", qb, K, preferred_element_type=jnp.float32
    ) * scale
    if length is not None:
        pos = jnp.arange(S, dtype=jnp.int32)
        valid = pos[None, None, None, :] < length[:, None, None, None]
        s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhrs,bshd->bhrd", p.astype(V.dtype), V,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, Hq, D).astype(q.dtype)


def fier_decode_reference(
    q: jax.Array,
    K: jax.Array,
    V: jax.Array,
    qk: QuantizedKeys,
    budget: int,
    length: jax.Array | None = None,
    *,
    group_reduce: str = "max",
    sink: int = 0,
    recent: int = 0,
    use_kernels: bool = False,
) -> jax.Array:
    """End-to-end FIER decode step (Alg. 1 steps 2–4) for batched GQA —
    the *reference* pipeline: score → ``select_topk`` → ``gather_kv`` →
    ``sparse_attention``, every intermediate materialised.  This is the
    validation oracle the kernel pipelines (``two_pass`` / ``one_pass``,
    see ``core.policy.DecodePlan``) are tested against, and the backend's
    ``pipeline='reference'`` implementation.  ``use_kernels=True`` swaps
    the scoring step for the Pallas score kernel (ablation; selection and
    attention stay jnp).
    """
    Hkv = K.shape[2]
    if use_kernels:
        from repro.kernels import ops as kops

        scores = kops.fier_score(q, qk)
    else:
        scores = approx_scores(q, qk)
    kv_scores = reduce_over_query_group(scores, Hkv, group_reduce)
    idx = select_topk(kv_scores, budget, length, sink=sink, recent=recent)
    Ksel, Vsel = gather_kv(K, V, idx)
    return sparse_attention(q, Ksel, Vsel, idx, length)


def fier_attention_decode(
    q: jax.Array,
    K: jax.Array,
    V: jax.Array,
    qk: QuantizedKeys,
    budget: int,
    length: jax.Array | None = None,
    *,
    group_reduce: str = "max",
    sink: int = 0,
    recent: int = 0,
    use_kernels: bool = False,
    fused: bool = False,
    one_pass: bool = True,
) -> jax.Array:
    """Deprecated boolean-flag entrypoint: forwards to the plan-selected
    pipeline (``fused`` → the kernel pipelines, else the reference one).
    Use ``core.policy.decode_attention(q, view, plan)`` instead."""
    from .policy import CacheView, _warn_deprecated

    _warn_deprecated(
        "retrieval.fier_attention_decode(..., use_kernels/fused/one_pass)",
        "policy.decode_attention(q, view, plan) with "
        "pipeline='reference'|'two_pass'|'one_pass'",
    )
    if fused:
        from repro.kernels import ops as kops

        view = CacheView.slab(K, V, qk, length)
        fn = kops.fier_decode_one_pass if one_pass else kops.fier_decode_two_pass
        return fn(
            q, view, budget, group_reduce=group_reduce, sink=sink, recent=recent
        )
    return fier_decode_reference(
        q, K, V, qk, budget, length,
        group_reduce=group_reduce, sink=sink, recent=recent,
        use_kernels=use_kernels,
    )
