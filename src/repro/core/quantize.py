"""1-bit group-wise RTN quantization of the key cache (FIER, §3.2/Alg. 1).

Layout conventions
------------------
Keys are stored seq-major: ``K[b, s, h_kv, d]``.  Quantization groups are
``g`` *consecutive tokens along the sequence* within each channel (paper
Alg. 1 line 4: "partition K into groups of size g along each channel").
Each (group, channel) cell stores a bf16 ``(scale, zero)`` pair; each token
stores one sign bit per channel.

Packing: 8 consecutive tokens of one channel share a byte (seq-major bit
order, bit ``t`` = token ``8*i + t``).  This keeps the decode-time score scan
sequential in HBM and lets a Pallas block unpack with broadcast shifts.

The load ratio of the packed representation is ``(1 + 32/g) / 16`` of the
bf16 key bytes (paper Eq. 8) — verified exactly in
``benchmarks/bench_load_ratio.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
class QuantizedKeys:
    """Packed 1-bit key-cache side-car (pytree; ``group`` is static aux data
    so instances survive vmap/scan/jit and can be stacked across layers).

    codes:  uint8[B, S//8, H, D]   sign bits, 8 seq positions per byte
    scale:  bf16 [B, S//g, H, D]   per (seq-group, channel) scale  (s)
    zero:   bf16 [B, S//g, H, D]   per (seq-group, channel) zero   (z)
    group:  python int, tokens per group (g)
    """

    def __init__(self, codes, scale, zero, group: int):
        self.codes = codes
        self.scale = scale
        self.zero = zero
        self.group = group

    def tree_flatten(self):
        return (self.codes, self.scale, self.zero), self.group

    @classmethod
    def tree_unflatten(cls, group, children):
        return cls(*children, group)

    def __repr__(self):
        return (f"QuantizedKeys(codes={getattr(self.codes, 'shape', None)}, "
                f"group={self.group})")

    @property
    def seq_len(self) -> int:
        return self.codes.shape[-3] * 8


def _check_seq(S: int, group: int) -> None:
    if S % group != 0:
        raise ValueError(f"seq len {S} not divisible by group size {group}")
    if S % 8 != 0:
        raise ValueError(f"seq len {S} not divisible by 8 (bit packing)")
    if group % 8 != 0:
        raise ValueError(f"group size {group} must be a multiple of 8")


def group_stats(K: jax.Array, group: int) -> tuple[jax.Array, jax.Array]:
    """Per (seq-group, channel) midpoint/half-range: 1-bit RTN scale & zero.

    K: [B, S, H, D] → scale, zero: [B, S//g, H, D]

    With levels {-1, +1}, RTN maps a group to {z - s, z + s}; choosing
    z = (max+min)/2 and s = (max-min)/2 makes the two levels the group
    min / max, the optimum for the min-max (round-to-nearest) quantizer.
    """
    B, S, H, D = K.shape
    Kg = K.reshape(B, S // group, group, H, D)
    kmax = Kg.max(axis=2)
    kmin = Kg.min(axis=2)
    zero = (kmax + kmin) * 0.5
    scale = (kmax - kmin) * 0.5
    return scale.astype(jnp.bfloat16), zero.astype(jnp.bfloat16)


def sign_bits(K: jax.Array, zero: jax.Array, group: int) -> jax.Array:
    """±1 codes as {0,1} bits: bit = (K >= z).  [B, S, H, D] uint8 (unpacked)."""
    B, S, H, D = K.shape
    z = jnp.repeat(zero.astype(K.dtype), group, axis=1)
    return (K >= z).astype(jnp.uint8)


def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack seq-major bits [B, S, H, D] → uint8[B, S//8, H, D]."""
    B, S, H, D = bits.shape
    b8 = bits.reshape(B, S // 8, 8, H, D)
    shifts = jnp.arange(8, dtype=jnp.uint8).reshape(1, 1, 8, 1, 1)
    return jnp.sum(b8 << shifts, axis=2).astype(jnp.uint8)


def unpack_bits(codes: jax.Array) -> jax.Array:
    """uint8[B, S//8, H, D] → {0,1} uint8[B, S, H, D]."""
    B, S8, H, D = codes.shape
    shifts = jnp.arange(8, dtype=jnp.uint8).reshape(1, 1, 8, 1, 1)
    bits = (codes[:, :, None] >> shifts) & jnp.uint8(1)
    return bits.reshape(B, S8 * 8, H, D)


def quantize(K: jax.Array, group: int = 32) -> QuantizedKeys:
    """Full 1-bit group RTN quantization of a key cache slab."""
    _check_seq(K.shape[1], group)
    scale, zero = group_stats(K, group)
    bits = sign_bits(K, zero, group)
    return QuantizedKeys(pack_bits(bits), scale, zero, group)


def dequantize(q: QuantizedKeys) -> jax.Array:
    """K̃ = code·s + z ∈ {z−s, z+s}.  Returns bf16 [B, S, H, D]."""
    bits = unpack_bits(q.codes)
    pm1 = bits.astype(jnp.bfloat16) * 2.0 - 1.0
    s = jnp.repeat(q.scale, q.group, axis=1)
    z = jnp.repeat(q.zero, q.group, axis=1)
    return pm1 * s + z


def packed_nbytes(S: int, H: int, D: int, group: int) -> int:
    """Bytes touched by the score scan per batch element (codes + s/z)."""
    return S // 8 * H * D + 2 * (S // group) * H * D * 2


def load_ratio(group: int) -> float:
    """Paper Eq. 8: key-cache load ratio of the selection pass."""
    return (1.0 + 32.0 / group) / 16.0
