"""KV eviction baselines: StreamingLLM, H2O, TOVA, SnapKV.

These *permanently drop* tokens (the failure mode FIER fixes — dropped
tokens cannot be recalled).  They are implemented as an alive-mask over the
cache slab plus per-policy state, updated once per decode step.  Used by the
quality benchmarks (bench_passkey / bench_pg19 / bench_longbench_proxy); the
serving fast path only ships full/fier/quest.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .retrieval import NEG_INF


class EvictionState(NamedTuple):
    """alive: bool[B,Hkv,S]; acc: f32[B,Hkv,S] cumulative scores (H2O only)."""

    alive: jax.Array
    acc: jax.Array


def masked_attention_decode(
    q: jax.Array, K: jax.Array, V: jax.Array, alive: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Dense decode attention restricted to alive tokens.

    Returns (out [B,Hq,D], probs [B,Hkv,S] mean over the query group) — the
    probs feed H2O/TOVA state updates.
    """
    B, Hq, D = q.shape
    S, Hkv = K.shape[1], K.shape[2]
    rep = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    qf = q.astype(jnp.float32).reshape(B, Hkv, rep, D)
    s = jnp.einsum("bhrd,bshd->bhrs", qf, K.astype(jnp.float32)) * scale
    s = jnp.where(alive[:, :, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhrs,bshd->bhrd", p, V.astype(jnp.float32))
    return out.reshape(B, Hq, D).astype(q.dtype), p.mean(axis=2)


def init_state(B: int, Hkv: int, S: int, length: jax.Array) -> EvictionState:
    """All prefill tokens alive; acc zeroed."""
    pos = jnp.arange(S, dtype=jnp.int32)
    alive = jnp.broadcast_to(pos[None, :] < length[:, None], (B, S))
    alive = jnp.broadcast_to(alive[:, None, :], (B, Hkv, S))
    return EvictionState(alive, jnp.zeros((B, Hkv, S), jnp.float32))


# ---------------------------------------------------------------- StreamingLLM
def streaming_llm_mask(
    S: int, length: jax.Array, budget: int, sink: int = 4
) -> jax.Array:
    """sink ∪ recent window of (budget - sink).  → bool[B, S] (head-agnostic)."""
    pos = jnp.arange(S, dtype=jnp.int32)
    recent = budget - sink
    is_sink = pos[None, :] < jnp.minimum(sink, length[:, None])
    is_recent = (pos[None, :] >= length[:, None] - recent) & (
        pos[None, :] < length[:, None]
    )
    return is_sink | is_recent


def streaming_llm_state(
    B: int, Hkv: int, S: int, length: jax.Array, budget: int, sink: int = 4
) -> EvictionState:
    m = streaming_llm_mask(S, length, budget, sink)
    alive = jnp.broadcast_to(m[:, None, :], (B, Hkv, S))
    return EvictionState(alive, jnp.zeros((B, Hkv, S), jnp.float32))


# ------------------------------------------------------------------------ H2O
def h2o_step(
    state: EvictionState,
    probs: jax.Array,
    length: jax.Array,
    budget: int,
    recent: int = 32,
) -> EvictionState:
    """Accumulate scores; evict the lowest-acc alive non-recent token if over
    budget.  One token arrives per decode step → at most one eviction."""
    acc = state.acc + probs
    pos = jnp.arange(acc.shape[-1], dtype=jnp.int32)
    protected = pos[None, None, :] >= (length[:, None, None] - recent)
    evictable = state.alive & ~protected
    score = jnp.where(evictable, acc, jnp.inf)
    victim = jnp.argmin(score, axis=-1)  # [B,Hkv]
    over = state.alive.sum(axis=-1) > budget  # [B,Hkv]
    kill = jax.nn.one_hot(victim, acc.shape[-1], dtype=bool) & over[..., None]
    return EvictionState(state.alive & ~kill, acc)


# ----------------------------------------------------------------------- TOVA
def tova_step(
    state: EvictionState, probs: jax.Array, length: jax.Array, budget: int
) -> EvictionState:
    """Evict the alive token with the lowest *current* attention weight."""
    score = jnp.where(state.alive, probs, jnp.inf)
    victim = jnp.argmin(score, axis=-1)
    over = state.alive.sum(axis=-1) > budget
    kill = jax.nn.one_hot(victim, probs.shape[-1], dtype=bool) & over[..., None]
    return EvictionState(state.alive & ~kill, state.acc)


# --------------------------------------------------------------------- SnapKV
def snapkv_state(
    q_window: jax.Array,
    K: jax.Array,
    length: jax.Array,
    budget: int,
    *,
    window: int = 32,
    pool: int = 7,
) -> EvictionState:
    """One-shot prefill selection from the last ``window`` queries' attention,
    max-pooled over ``pool`` neighbouring positions (clustering), plus the
    observation window itself.  Selected set is fixed afterwards.

    q_window: [B, Hq, window, D] (last prefill queries)
    """
    B, Hq, W, D = q_window.shape
    S, Hkv = K.shape[1], K.shape[2]
    rep = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    qf = q_window.astype(jnp.float32).reshape(B, Hkv, rep, W, D)
    s = jnp.einsum("bhrwd,bshd->bhrws", qf, K.astype(jnp.float32)) * scale
    pos = jnp.arange(S, dtype=jnp.int32)
    valid = pos[None, None, None, None, :] < length[:, None, None, None, None]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).sum(axis=(2, 3))  # vote: [B,Hkv,S]
    # cluster votes with a max-pool along the sequence
    pooled = jax.lax.reduce_window(
        p, -jnp.inf, jax.lax.max, (1, 1, pool), (1, 1, 1), "SAME"
    )
    in_window = (pos[None, None, :] >= length[:, None, None] - window) & (
        pos[None, None, :] < length[:, None, None]
    )
    pooled = jnp.where(in_window, jnp.inf, jnp.where(valid[:, :, 0, 0], pooled, -jnp.inf))
    k = max(budget, window)
    _, idx = jax.lax.top_k(pooled, k)
    alive = jnp.zeros((B, Hkv, S), bool)
    alive = jax.vmap(jax.vmap(lambda a, i: a.at[i].set(True)))(alive, idx)
    alive &= valid[:, :, 0, 0]
    return EvictionState(alive, jnp.zeros((B, Hkv, S), jnp.float32))


def append_alive(state: EvictionState, length: jax.Array) -> EvictionState:
    """Mark the token just written at position ``length`` alive (all heads)."""
    S = state.alive.shape[-1]
    onehot = jax.nn.one_hot(length, S, dtype=bool)[:, None, :]
    return EvictionState(state.alive | onehot, state.acc)
