from .fault import FaultInjector, StragglerMonitor, run_with_recovery
from .elastic import reshard_tree

__all__ = ["FaultInjector", "StragglerMonitor", "reshard_tree", "run_with_recovery"]
