"""Fault tolerance: failure injection, recovery driver, straggler monitor.

At pod scale, failures are host/chip losses; here they are simulated as
exceptions at configurable steps.  The recovery contract the driver
enforces (and tests verify bit-exactly):

  * state (params, optimizer, step) restores from the latest checkpoint;
  * the data pipeline is (seed, step)-deterministic, so replayed steps see
    identical batches;
  * ⇒ resumed training is bit-identical to an uninterrupted run.

On real pods the same driver wraps ``jax.distributed`` re-initialisation
and, when the replacement pool is smaller (lost hosts), the elastic path:
restore with the new mesh's shardings (checkpoint.manager.restore) and
continue — see runtime/elastic.py.
"""
from __future__ import annotations

import time
from typing import Any, Callable


class FaultInjector:
    """Raises RuntimeError at the given (1-based) global steps — once each."""

    def __init__(self, fail_at: set[int] | list[int] = ()):
        self.fail_at = set(fail_at)
        self.fired: set[int] = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected fault at step {step}")


class StragglerMonitor:
    """EWMA step-time monitor; flags steps slower than ``threshold×`` EWMA.

    On real pods a flagged step triggers the drain→checkpoint→re-mesh path
    (the collective barrier makes one slow host everyone's problem); here
    it records events for tests/metrics.
    """

    def __init__(self, alpha: float = 0.1, threshold: float = 3.0):
        self.alpha = alpha
        self.threshold = threshold
        self.ewma: float | None = None
        self.events: list[tuple[int, float, float]] = []
        self._t0: float | None = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> float:
        dt = time.perf_counter() - self._t0
        if self.ewma is None:
            self.ewma = dt
        elif dt > self.threshold * self.ewma:
            self.events.append((step, dt, self.ewma))
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return dt


def run_with_recovery(
    step_fn: Callable[[Any, int], Any],
    init_state: Any,
    n_steps: int,
    ckpt,
    *,
    ckpt_every: int = 10,
    max_restarts: int = 5,
    state_like: Any = None,
    on_restore: Callable[[Any], Any] | None = None,
) -> tuple[Any, dict]:
    """Run ``state = step_fn(state, step)`` for steps [resume..n_steps) with
    checkpoint/restart.  Returns (final_state, stats)."""
    restarts = 0
    stats = {"restarts": 0, "resumed_from": []}
    state = init_state
    step = 0
    latest = ckpt.latest_step()
    if latest is not None:
        state = ckpt.restore(latest, state_like if state_like is not None else state)
        if on_restore:
            state = on_restore(state)
        step = latest
        stats["resumed_from"].append(latest)
    while step < n_steps:
        try:
            state = step_fn(state, step)
            step += 1
            if step % ckpt_every == 0 or step == n_steps:
                ckpt.wait()
                ckpt.save_async(step, state)
        except RuntimeError as e:
            restarts += 1
            stats["restarts"] = restarts
            if restarts > max_restarts:
                raise RuntimeError(f"too many restarts ({restarts})") from e
            ckpt.wait()
            latest = ckpt.latest_step()
            if latest is None:
                state, step = init_state, 0
            else:
                state = ckpt.restore(
                    latest, state_like if state_like is not None else state
                )
                if on_restore:
                    state = on_restore(state)
                step = latest
            stats["resumed_from"].append(step)
    ckpt.wait()
    return state, stats
