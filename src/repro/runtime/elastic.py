"""Elastic re-meshing: move a state pytree onto a different mesh.

After losing hosts, the surviving pool forms a smaller mesh; params and
optimizer state saved under mesh A's shardings must re-shard to mesh B.
With jax.Array this is a device_put per leaf — the checkpoint path
(restore with new shardings) covers the cold path; ``reshard_tree`` covers
the warm path (state still resident).  The train launcher composes this
with ``run_with_recovery``: shrink mesh → reshard → continue.

Scale note (1000+ nodes): the cold path is preferred — re-reading from
the distributed checkpoint avoids all-to-all resharding traffic through
the surviving hosts and handles arbitrary topology changes.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def reshard_tree(tree: Any, shardings: Any) -> Any:
    """device_put every leaf onto its (possibly new-mesh) sharding."""
    if jax.tree_util.tree_structure(shardings) == jax.tree_util.tree_structure(tree):
        return jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return jax.tree.map(lambda a: jax.device_put(a, shardings), tree)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
