"""Passkey-retrieval task generator (Peng et al., 2023 setup, Tab. 2).

A K-digit passkey is hidden at a random depth inside filler text; the
prompt ends with a query marker and the model must emit the digits.  The
token space is carved from the model's own vocab:

    [0, 10)          digit tokens
    MARK_OPEN/CLOSE  passkey delimiters
    QUERY            "what is the passkey?" marker
    [16, vocab)      filler (drawn from the bigram stream for naturalness)

This is the benchmark where eviction (H2O/SLM/TOVA) structurally fails —
once the passkey tokens are evicted they cannot be recalled — while
retrieval (Quest/FIER) succeeds, reproducing the paper's Tab. 2 contrast.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

MARK_OPEN, MARK_CLOSE, QUERY = 10, 11, 12
N_DIGITS = 3
RESERVED = 16


def make_passkey_batch(
    cfg: ModelConfig,
    B: int,
    S: int,
    *,
    seed: int = 0,
    step: int = 0,
    depth: float | None = None,
) -> tuple[dict, jax.Array]:
    """Returns (train-style batch over full sequences, answers [B, N_DIGITS]).

    Layout per row: [filler ... MARK_OPEN d0..d4 MARK_CLOSE ... filler
    QUERY d0..d4].  The loss mask covers only the answer positions, so the
    same batch trains and evaluates passkey retrieval.
    """
    from .pipeline import lm_tokens

    rng = np.random.default_rng(seed * 100003 + step)
    filler = np.asarray(
        lm_tokens(seed ^ 0xF1, step, B, S, cfg.vocab - RESERVED)
    )[:, :S] + RESERVED
    toks = filler.copy()
    answers = rng.integers(0, 10, (B, N_DIGITS))
    tail = N_DIGITS + 1  # QUERY + digits
    for b in range(B):
        if depth is None:
            pos = int(rng.integers(1, S - tail - N_DIGITS - 3))
        else:
            pos = max(1, min(int(depth * S), S - tail - N_DIGITS - 3))
        toks[b, pos] = MARK_OPEN
        toks[b, pos + 1 : pos + 1 + N_DIGITS] = answers[b]
        toks[b, pos + 1 + N_DIGITS] = MARK_CLOSE
        toks[b, S - tail] = QUERY
        toks[b, S - N_DIGITS :] = answers[b]
    toks = jnp.asarray(toks, jnp.int32)
    targets = jnp.concatenate([toks[:, 1:], toks[:, :1] * 0], axis=1)
    mask = np.zeros((B, S), np.float32)
    mask[:, S - tail : S - 1] = 1.0  # positions predicting the digits
    return (
        {"tokens": toks, "targets": targets, "loss_mask": jnp.asarray(mask)},
        jnp.asarray(answers, jnp.int32),
    )


def passkey_answer_tokens(batch: dict) -> jax.Array:
    """Prompt prefix for generation eval: everything up to and incl. QUERY."""
    toks = batch["tokens"]
    return toks[:, : toks.shape[1] - N_DIGITS]
