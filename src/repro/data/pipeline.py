"""Deterministic synthetic data pipeline.

Every batch is a pure function of ``(seed, step)`` — the property the
fault-tolerance story rests on: after restart from a step-``k`` checkpoint
the pipeline regenerates step k+1 identically, so resume is bit-exact
(verified in tests/test_fault.py).  At pod scale each process slices its
host-local shard by ``process_index`` from the same deterministic stream
(no data service, no shared state to lose in a failure).

The LM stream is a fixed random bigram Markov chain (per seed): tiny
models can actually learn it, so train-loss curves and the PG19-proxy
perplexity benchmark are meaningful rather than noise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


def _bigram_table(seed: int, vocab: int, branch: int = 8) -> jax.Array:
    """Each token has ``branch`` plausible successors (zipf-ish weights)."""
    rng = jax.random.PRNGKey(seed)
    succ = jax.random.randint(rng, (vocab, branch), 0, vocab)
    return succ


def lm_tokens(seed: int, step: int, B: int, S: int, vocab: int) -> jax.Array:
    """[B, S+1] token stream from the seed's bigram chain."""
    succ = _bigram_table(seed, vocab)
    branch = succ.shape[1]
    rng = jax.random.fold_in(jax.random.PRNGKey(seed ^ 0x5EED), step)
    k0, k1 = jax.random.split(rng)
    start = jax.random.randint(k0, (B,), 0, vocab)
    choices = jax.random.randint(k1, (B, S), 0, branch)

    def gen(tok, ch):
        nxt = succ[tok, ch]
        return nxt, nxt

    _, toks = jax.lax.scan(gen, start, choices.T)
    return jnp.concatenate([start[None], toks], axis=0).T  # [B, S+1]


def make_train_batch(
    cfg: ModelConfig,
    shape: ShapeConfig,
    step: int,
    *,
    seed: int = 0,
    process_index: int = 0,
    process_count: int = 1,
    batch_override: int | None = None,
    seq_override: int | None = None,
) -> dict:
    """Family-aware train batch: {tokens, targets, loss_mask, stubs...}."""
    B = batch_override or shape.global_batch // process_count
    S = seq_override or shape.seq_len
    # fold process index into the stream position, not the seed — every
    # process draws a disjoint slice of the same logical global batch
    eff_step = step * process_count + process_index

    if cfg.family == "vlm":
        nv = cfg.n_vision_tokens
        St = S - nv
        stream = lm_tokens(seed, eff_step, B, St, cfg.vocab)
        rng = jax.random.fold_in(jax.random.PRNGKey(seed ^ 0xB1), eff_step)
        vis = jax.random.normal(rng, (B, nv, cfg.d_model), jnp.bfloat16)
        tokens = stream[:, :-1]
        # targets aligned to the full (vision+text) sequence: position
        # nv-1+i predicts text token stream[i] (St+1 slots: the last vision
        # position predicts the first text token); vision positions masked
        targets = jnp.zeros((B, S), jnp.int32)
        targets = targets.at[:, nv - 1 : nv + St].set(stream)
        mask = jnp.zeros((B, S), jnp.float32)
        mask = mask.at[:, nv - 1 : nv + St].set(1.0)
        return {
            "tokens": tokens, "targets": targets, "loss_mask": mask,
            "vision_embeds": vis,
        }

    if cfg.family == "encdec":
        stream = lm_tokens(seed, eff_step, B, S, cfg.vocab)
        rng = jax.random.fold_in(jax.random.PRNGKey(seed ^ 0xA7D10), eff_step)
        frames = jax.random.normal(rng, (B, cfg.enc_ctx, cfg.d_model), jnp.bfloat16)
        return {
            "frames": frames,
            "tokens": stream[:, :-1],
            "targets": stream[:, 1:],
            "loss_mask": jnp.ones((B, S), jnp.float32),
        }

    stream = lm_tokens(seed, eff_step, B, S, cfg.vocab)
    return {
        "tokens": stream[:, :-1],
        "targets": stream[:, 1:],
        "loss_mask": jnp.ones((B, S), jnp.float32),
    }


def make_prefill_batch(
    cfg: ModelConfig, B: int, S: int, *, seed: int = 0, length: int | None = None
) -> dict:
    """Prefill batch (serving path) with uniform lengths."""
    stream = lm_tokens(seed, 0, B, S, cfg.vocab)[:, :S]
    lengths = jnp.full((B,), length or S, jnp.int32)
    batch = {"tokens": stream, "lengths": lengths}
    if cfg.family == "vlm":
        rng = jax.random.PRNGKey(seed ^ 0xB2)
        batch["vision_embeds"] = jax.random.normal(
            rng, (B, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16
        )
        batch["lengths"] = lengths + cfg.n_vision_tokens
    if cfg.family == "encdec":
        rng = jax.random.PRNGKey(seed ^ 0xA7D11)
        batch["frames"] = jax.random.normal(
            rng, (B, cfg.enc_ctx, cfg.d_model), jnp.bfloat16
        )
    return batch
