"""Byte-level tokenizer for demos and chat-style examples.

Vocabulary = 256 raw bytes + a handful of specials.  Enough to drive the
serving engine with real text without external assets; models trained on
the synthetic streams use their own id spaces.
"""
from __future__ import annotations

PAD, BOS, EOS = 256, 257, 258
VOCAB_SIZE = 259


def encode(text: str, *, bos: bool = True, eos: bool = False) -> list[int]:
    ids = list(text.encode("utf-8"))
    if bos:
        ids = [BOS] + ids
    if eos:
        ids = ids + [EOS]
    return ids


def decode(ids: list[int]) -> str:
    return bytes(i for i in ids if i < 256).decode("utf-8", errors="replace")
