from .pipeline import make_train_batch, make_prefill_batch
from .passkey import make_passkey_batch, passkey_answer_tokens

__all__ = [
    "make_passkey_batch",
    "make_prefill_batch",
    "make_train_batch",
    "passkey_answer_tokens",
]
