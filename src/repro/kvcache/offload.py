"""Host-DRAM KV offload tier: the second level of the two-tier prefix
cache (DESIGN.md §KV reuse tiers).

The device pool's free-but-cached blocks are the first tier: zero-copy
prefix hits until LRU/TTL pressure evicts them.  Without this module an
eviction destroys the block's contents and a later request re-prefills
the prefix from scratch.  With an offload tier attached, the engine
snapshots each evicted block — one ``jax.device_get`` of its ``[L, bs,
…]`` rows across every pool leaf (K/V and the FIER code side-car) —
into host DRAM *before* the pool row is overwritten, keyed by the same
chained block hash the trie uses.  A later admission whose prefix walk
runs off the device trie extends the match through the host tier:
freshly allocated device blocks are filled by **double-buffered async
recall** (``jax.device_put`` of block ``i+1`` dispatched while block
``i`` commits through a jitted single-block scatter), then re-registered
in the trie under their original parent linkage — bit-identical to never
having been evicted, for a per-block cost far below re-prefilling
``block_size`` tokens.

Ownership invariant: a key lives in **exactly one tier**.  ``save`` is
called only for keys just removed from the trie; recall ``pop``s the
host entry before the device re-registration.  ``BlockAllocator.audit``
cross-checks the two key sets every time the engine audits.

Everything here is host-side bookkeeping plus explicit H2D/D2H copies —
no jitted code, no new kernels.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any, Callable, Iterable, Iterator

import jax
import numpy as np

__all__ = ["HostBlock", "HostOffloadTier", "double_buffered_puts"]


def payload_nbytes(payload: Any) -> int:
    """Total host bytes of a block payload pytree."""
    return sum(int(np.asarray(leaf).nbytes) for leaf in jax.tree.leaves(payload))


def to_host(payload: Any) -> Any:
    """Materialise a device pytree as numpy on the host (one transfer per
    leaf; bf16 leaves round-trip exactly through ml_dtypes)."""
    return jax.tree.map(np.asarray, jax.device_get(payload))


@dataclasses.dataclass
class HostBlock:
    """One offloaded block: its prefix-cache identity plus the host copy
    of every pool leaf's ``[L, rows, …]`` slice for that block."""

    key: int
    parent_key: int | None
    payload: Any                    # pytree of np.ndarray, pool-leaf layout
    nbytes: int
    saved_at: float                 # tier clock (scheduler vtime when wired)
    reason: str = "lru"             # "lru" | "ttl" | "shed"


class HostOffloadTier:
    """Bounded LRU store of evicted KV blocks in host DRAM.

    ``capacity_blocks`` bounds residency (0 disables saves entirely —
    the engine treats a 0-capacity tier as absent).  The tier is passive:
    the engine decides what to save (allocator eviction log, shed middle
    blocks) and what to recall (admission-time prefix walk); the tier
    only owns the host copies and their LRU/accounting.
    """

    def __init__(self, capacity_blocks: int,
                 clock: Callable[[], float] | None = None):
        self.capacity_blocks = int(capacity_blocks)
        self._clock: Callable[[], float] = clock if clock is not None else (
            lambda: 0.0
        )
        self._store: OrderedDict[int, HostBlock] = OrderedDict()
        self.nbytes = 0
        self.saves = 0
        self.recalls = 0
        self.lru_evictions = 0      # host-capacity pressure
        self.dropped = 0            # chaos-injected losses
        self.recall_wall_s = 0.0    # cumulative wall time inside recalls

    # ------------------------------------------------------------- clock
    def set_clock(self, clock: Callable[[], float]) -> None:
        self._clock = clock

    def now(self) -> float:
        return float(self._clock())

    # ----------------------------------------------------------- queries
    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key: int) -> bool:
        return key in self._store

    def keys(self) -> set[int]:
        return set(self._store)

    def match_extension(self, keys: list[int], start: int) -> list[int]:
        """How far the host tier extends a device prefix match: the keys
        ``keys[start:start+n]`` resident here, stopping at the first
        miss.  No state change."""
        out: list[int] = []
        for key in keys[start:]:
            if key not in self._store:
                break
            out.append(key)
        return out

    # --------------------------------------------------------- save/recall
    def save(self, key: int, parent_key: int | None, payload: Any,
             reason: str = "lru") -> bool:
        """Admit one evicted block (host copy already materialised).
        False when the tier is disabled or the key is already resident
        (first writer wins, same as the trie)."""
        if self.capacity_blocks <= 0 or key in self._store:
            return False
        hb = HostBlock(
            key=key, parent_key=parent_key, payload=payload,
            nbytes=payload_nbytes(payload), saved_at=self.now(),
            reason=reason,
        )
        self._store[key] = hb
        self.nbytes += hb.nbytes
        self.saves += 1
        while len(self._store) > self.capacity_blocks:
            _, old = self._store.popitem(last=False)
            self.nbytes -= old.nbytes
            self.lru_evictions += 1
        return True

    def pop(self, key: int) -> HostBlock | None:
        """Recall: remove and return the host entry (ownership moves back
        to the device tier — the caller re-registers it in the trie)."""
        hb = self._store.pop(key, None)
        if hb is not None:
            self.nbytes -= hb.nbytes
            self.recalls += 1
        return hb

    def drop_lru(self, n: int = 1) -> int:
        """Chaos hook: lose ``n`` LRU entries (models host-tier memory
        reclaim / a dropped transfer).  Recalls that would have hit now
        miss and fall back to recompute — outputs must not change."""
        dropped = 0
        while self._store and dropped < n:
            _, hb = self._store.popitem(last=False)
            self.nbytes -= hb.nbytes
            dropped += 1
        self.dropped += dropped
        return dropped

    # ------------------------------------------------------------- stats
    def stats(self) -> dict[str, float]:
        """Canonical ``offload_*`` accounting (registry-gauge names)."""
        return dict(
            offload_capacity_blocks=self.capacity_blocks,
            offload_blocks=len(self._store),
            offload_bytes=self.nbytes,
            offload_saves=self.saves,
            offload_recalls=self.recalls,
            offload_lru_evictions=self.lru_evictions,
            offload_dropped=self.dropped,
            offload_recall_wall_s=self.recall_wall_s,
        )

    def audit(self) -> list[str]:
        """Internal invariants; returns violation strings (empty = clean).
        The engine folds these into ``BlockAllocator.audit`` alongside
        the cross-tier key-disjointness check."""
        errs: list[str] = []
        if len(self._store) > max(self.capacity_blocks, 0):
            errs.append(
                f"host tier over capacity: {len(self._store)} > "
                f"{self.capacity_blocks}"
            )
        nbytes = sum(hb.nbytes for hb in self._store.values())
        if nbytes != self.nbytes:
            errs.append(f"byte accounting drift: {self.nbytes} != {nbytes}")
        for key, hb in self._store.items():
            if hb.key != key:
                errs.append(f"store key mismatch at {key}")
        return errs


def double_buffered_puts(
    entries: Iterable[tuple[int, Any]],
) -> Iterator[tuple[int, Any]]:
    """Two-deep host→device pipeline: yields ``(bid, device_payload)``
    with the *next* entry's ``jax.device_put`` already dispatched before
    the current one is handed to the (blocking) commit scatter.  jax's
    async dispatch overlaps the H2D copy of block ``i+1`` with the commit
    of block ``i`` — the recall analogue of the one-pass kernel hiding
    scoring behind the gather; on backends where device_put is
    synchronous the pipeline degrades to sequential copies with identical
    results."""
    it = iter(entries)
    staged: tuple[int, Any] | None = None
    for bid, payload in it:
        nxt = (bid, jax.tree.map(jax.device_put, payload))
        if staged is not None:
            yield staged
        staged = nxt
    if staged is not None:
        yield staged


def timed(fn, tier: HostOffloadTier):
    """Run ``fn()`` accumulating its wall time into the tier's recall
    clock (kept out of the virtual clock: wall time is info-only)."""
    t0 = time.monotonic()
    try:
        return fn()
    finally:
        tier.recall_wall_s += time.monotonic() - t0
