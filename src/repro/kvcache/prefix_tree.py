"""Block-granular radix trie for KV prefix reuse.

The chained-hash prefix cache (``block_hash_chain``) already gives every
(prefix, block) pair a unique key: ``key_j`` covers *all* tokens up to
the end of block ``j``, so a flat ``key → block`` map answers point
lookups.  What the flat map cannot answer is *structural* questions —
which parked blocks are safe to evict without stranding cached
descendants, and how long a prefix chain has been cold.  The trie keeps
the same keys as node identities (point lookup stays O(1), a
longest-prefix walk over a prompt's key chain is O(L)) and adds the
parent/child structure on top:

* **Leaf-first LRU eviction.**  Evicting a parked interior node breaks
  the longest-prefix walk for every cached descendant (the walk stops at
  the first missing key), so those blocks keep pool space while being
  unreachable through prefix matching.  ``pop_eviction`` therefore
  prefers parked *leaves* (LRU among them) and falls back to the oldest
  parked node only when every parked node still has cached children
  (e.g. a parked parent under an in-use child).
* **TTL aging on a pluggable clock.**  Parked nodes carry their park
  timestamp; ``expired(ttl)`` returns everything parked longer than
  ``ttl`` clock units, deepest-first so chains unwind leaf-to-root.  The
  serving scheduler wires :meth:`set_clock` to its virtual token clock,
  so stale prefixes age out deterministically (same trace → same
  evictions) instead of squatting until free-list pressure.
* **Ref-count awareness by construction.**  Only *parked* (ref == 0)
  nodes appear in the eviction/TTL structures — the allocator parks a
  block exactly when its ref count drops to zero and revives it on the
  next reference, so an in-use block can never be evicted.

The trie never touches device memory: it is host-side bookkeeping owned
by :class:`~repro.kvcache.paged.BlockAllocator`, and the eviction log it
feeds (``BlockAllocator.take_evicted``) is what the engine's host-DRAM
offload tier (:mod:`repro.kvcache.offload`) consumes.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable

__all__ = ["PrefixTree", "TrieNode"]


@dataclasses.dataclass
class TrieNode:
    """One cached block: a node of the radix trie.

    ``key`` is the chained content hash identifying the whole prefix up
    to this block (the ``block_hash_chain`` key), ``bid`` the physical
    pool block holding its K/V rows.  ``parent`` is None for children of
    the root (legacy two-arg ``register`` calls land there and behave
    exactly like the flat chained-hash map).  ``parked_at`` is the clock
    reading when the block's ref count dropped to zero — None while the
    block is referenced.
    """

    key: int
    bid: int
    parent: "TrieNode | None" = None
    children: dict[int, "TrieNode"] = dataclasses.field(default_factory=dict)
    parked_at: float | None = None
    last_use: float = 0.0

    @property
    def parent_key(self) -> int | None:
        return None if self.parent is None else self.parent.key

    def is_leaf(self) -> bool:
        return not self.children


class PrefixTree:
    """Radix trie over chained block-hash keys.

    The allocator drives five lifecycle transitions:

        insert(key, bid, parent_key)   block registered while in use
        park(bid)                      ref count hit zero (evictable)
        revive(bid)                    parked block re-referenced
        pop_eviction()                 LRU pressure: reclaim one parked
        remove(bid)                    unregister (evicted / offloaded)

    ``match_longest(keys)`` is the admission-time longest-shared-prefix
    walk: node bids for the longest registered prefix of ``keys``.
    """

    def __init__(self, clock: Callable[[], float] | None = None):
        self._clock: Callable[[], float] = clock if clock is not None else (
            lambda: 0.0
        )
        self._by_key: dict[int, TrieNode] = {}
        self._by_bid: dict[int, TrieNode] = {}
        # parked nodes in park order (OrderedDict as LRU: re-park lands
        # at the end).  Values are nodes; keys are bids.
        self._parked: OrderedDict[int, TrieNode] = OrderedDict()
        self._roots: dict[int, TrieNode] = {}   # parentless top-level nodes
        self.leaf_evictions = 0       # pop_eviction served by a parked leaf
        self.interior_evictions = 0   # fallback: oldest parked non-leaf
        self.ttl_evictions = 0        # removals via expired()
        self.reparented = 0           # children re-hung on a removed node's
                                      # parent (their prefix walk now stops
                                      # one block earlier)

    # ------------------------------------------------------------- clock
    def set_clock(self, clock: Callable[[], float]) -> None:
        """Point the trie at an external monotone clock (the scheduler's
        virtual token clock) — TTL expiry and age percentiles read it."""
        self._clock = clock

    def now(self) -> float:
        return float(self._clock())

    # ----------------------------------------------------------- queries
    def __len__(self) -> int:
        return len(self._by_key)

    def __contains__(self, key: int) -> bool:
        return key in self._by_key

    @property
    def n_parked(self) -> int:
        return len(self._parked)

    def get(self, key: int) -> int | None:
        """Point lookup: the block registered under ``key`` (no state
        change — the allocator's ``lookup`` handles revival)."""
        node = self._by_key.get(key)
        return None if node is None else node.bid

    def node_of(self, bid: int) -> TrieNode | None:
        return self._by_bid.get(bid)

    def key_of(self, bid: int) -> int | None:
        node = self._by_bid.get(bid)
        return None if node is None else node.key

    def match_longest(self, keys: list[int]) -> list[int]:
        """Longest registered prefix of the key chain: bids of nodes
        ``keys[0..j)`` where ``j`` is the first miss.  O(len(keys))."""
        bids: list[int] = []
        for key in keys:
            node = self._by_key.get(key)
            if node is None:
                break
            bids.append(node.bid)
        return bids

    # --------------------------------------------------------- lifecycle
    def insert(self, key: int, bid: int, parent_key: int | None = None) -> bool:
        """Register ``bid`` under ``key``.  First writer wins: False when
        the key is already registered (the existing node keeps its block).
        ``parent_key`` links the node under its prefix parent; an unknown
        or omitted parent attaches at the root — exactly the flat
        chained-hash behaviour, so legacy ``register(bid, key)`` callers
        see no change."""
        if key in self._by_key:
            return False
        if bid in self._by_bid:
            raise ValueError(
                f"block {bid} already registered under key "
                f"{self._by_bid[bid].key}"
            )
        parent = self._by_key.get(parent_key) if parent_key is not None else None
        node = TrieNode(key=key, bid=bid, parent=parent, last_use=self.now())
        if parent is not None:
            parent.children[key] = node
        else:
            self._roots[key] = node
        self._by_key[key] = node
        self._by_bid[bid] = node
        return True

    def touch(self, bid: int) -> None:
        node = self._by_bid.get(bid)
        if node is not None:
            node.last_use = self.now()

    def park(self, bid: int) -> None:
        """Block's ref count dropped to zero: it becomes an eviction/TTL
        candidate while staying fully matchable."""
        node = self._by_bid[bid]
        assert node.parked_at is None, f"block {bid} parked twice"
        node.parked_at = self.now()
        self._parked[bid] = node

    def revive(self, bid: int) -> None:
        """Parked block re-referenced: leaves the eviction candidates."""
        node = self._by_bid[bid]
        assert node.parked_at is not None, f"block {bid} not parked"
        node.parked_at = None
        node.last_use = self.now()
        del self._parked[bid]

    def remove(self, bid: int) -> tuple[int, int | None]:
        """Unregister a (parked or in-use) block entirely.  Children are
        re-hung on the removed node's parent so the tree stays connected;
        their longest-prefix walk now stops at the removed key (counted
        in ``reparented``).  Returns (key, parent_key) — the offload tier
        needs both to re-insert the chain on recall."""
        node = self._by_bid.pop(bid)
        del self._by_key[node.key]
        if node.parked_at is not None:
            del self._parked[bid]
        parent = node.parent
        if parent is not None:
            del parent.children[node.key]
        else:
            del self._roots[node.key]
        for child in node.children.values():
            child.parent = parent
            if parent is not None:
                parent.children[child.key] = child
            else:
                self._roots[child.key] = child
            self.reparented += 1
        return node.key, node.parent_key

    # ---------------------------------------------------------- eviction
    def pop_eviction(self) -> tuple[int, int, int | None] | None:
        """Reclaim one parked block for a fresh allocation: the LRU
        parked *leaf* when one exists (evicting it strands nothing), else
        the oldest parked node outright (every parked node shields cached
        children — old flat-map behaviour).  Returns
        (bid, key, parent_key) or None when nothing is parked."""
        victim = None
        for node in self._parked.values():
            if node.is_leaf():
                victim = node
                break
        if victim is None:
            if not self._parked:
                return None
            victim = next(iter(self._parked.values()))
            self.interior_evictions += 1
        else:
            self.leaf_evictions += 1
        bid = victim.bid
        key, parent_key = self.remove(bid)
        return bid, key, parent_key

    def expired(self, ttl: float) -> list[int]:
        """Bids parked longer than ``ttl`` clock units, deepest-first so
        chains unwind leaf-to-root (a parent expelled before its cached
        child would strand it).  Callers remove() each returned bid."""
        now = self.now()
        out = [
            node for node in self._parked.values()
            if now - node.parked_at >= ttl
        ]
        out.sort(key=lambda n: -self._depth(n))
        return [n.bid for n in out]

    @staticmethod
    def _depth(node: TrieNode) -> int:
        d = 0
        while node.parent is not None:
            node = node.parent
            d += 1
        return d

    # ------------------------------------------------------------- stats
    def parked_ages(self) -> list[float]:
        """Age (clock units) of every parked block — the pool_stats
        percentile source."""
        now = self.now()
        return [now - n.parked_at for n in self._parked.values()]

    def stats(self) -> dict[str, float]:
        return dict(
            trie_nodes=len(self._by_key),
            trie_parked=len(self._parked),
            trie_leaf_evictions=self.leaf_evictions,
            trie_interior_evictions=self.interior_evictions,
            trie_ttl_evictions=self.ttl_evictions,
            trie_reparented=self.reparented,
        )

    # ------------------------------------------------------------- audit
    def audit(self) -> list[str]:
        """Internal invariant sweep; returns violation strings (empty =
        clean).  The allocator folds these into its own audit."""
        errs: list[str] = []
        if set(self._by_key) != {n.key for n in self._by_bid.values()}:
            errs.append("key/bid index mismatch")
        for key, node in self._by_key.items():
            if node.key != key or self._by_bid.get(node.bid) is not node:
                errs.append(f"index asymmetry at key {key}")
            if node.parent is None:
                if self._roots.get(key) is not node:
                    errs.append(f"parentless node {key} missing from roots")
            elif node.parent.children.get(key) is not node:
                errs.append(f"parent/child asymmetry at key {key}")
        for bid, node in self._parked.items():
            if node.parked_at is None or self._by_bid.get(bid) is not node:
                errs.append(f"parked index inconsistent at block {bid}")
        for node in self._by_key.values():
            if node.parked_at is None and node.bid in self._parked:
                errs.append(f"unparked node {node.key} in parked set")
        return errs
