from .cache import append_kv, append_token_metadata, init_layer_cache
from .offload import HostBlock, HostOffloadTier, double_buffered_puts
from .paged import (
    AllocatorAuditError,
    BlockAllocator,
    EvictedBlock,
    block_hash_chain,
    gather_paged_kv,
    init_paged_pool,
    paged_append_kv,
    paged_append_token_metadata,
)
from .prefix_tree import PrefixTree, TrieNode
from .sharded import (
    ShardSpec,
    ShardedBlockAllocator,
    shard_cache,
    sharded_paged_decode_step,
)

__all__ = [
    "AllocatorAuditError",
    "BlockAllocator",
    "EvictedBlock",
    "HostBlock",
    "HostOffloadTier",
    "PrefixTree",
    "ShardSpec",
    "ShardedBlockAllocator",
    "TrieNode",
    "append_kv",
    "append_token_metadata",
    "block_hash_chain",
    "double_buffered_puts",
    "gather_paged_kv",
    "init_layer_cache",
    "init_paged_pool",
    "paged_append_kv",
    "paged_append_token_metadata",
    "shard_cache",
    "sharded_paged_decode_step",
]
