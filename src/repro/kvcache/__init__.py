from .cache import append_kv, append_token_metadata, init_layer_cache
from .paged import (
    AllocatorAuditError,
    BlockAllocator,
    block_hash_chain,
    gather_paged_kv,
    init_paged_pool,
    paged_append_kv,
    paged_append_token_metadata,
)

__all__ = [
    "AllocatorAuditError",
    "BlockAllocator",
    "append_kv",
    "append_token_metadata",
    "block_hash_chain",
    "gather_paged_kv",
    "init_layer_cache",
    "init_paged_pool",
    "paged_append_kv",
    "paged_append_token_metadata",
]
