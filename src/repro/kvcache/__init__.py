from .cache import append_kv, append_token_metadata, init_layer_cache

__all__ = ["append_kv", "append_token_metadata", "init_layer_cache"]
