"""KV-cache containers and append primitives.

Caches are plain pytrees (dicts of stacked arrays) owned by each model
family's ``init_cache``; this module provides the shared primitives:
fixed-capacity slabs, per-sequence append (continuous batching — every
sequence has its own write position), and incremental policy-metadata
refresh (only the group/page containing the written slot is recomputed).

Capacity slabs are bf16; positions beyond ``length`` hold garbage that is
masked by every consumer (policy select / flash attention bias_mask).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.policy import PolicyConfig


def _check_capacity(capacity: int, group: int, *, what: str = "capacity") -> None:
    """FIER side-car layout constraints: 8 tokens/byte, ``group`` tokens
    per (scale, zero) cell — a non-divisible ``capacity`` (or paged
    ``block_size``) silently truncates the ``// 8`` / ``// group``
    side-car shapes and misallocates the codes."""
    if capacity <= 0:
        raise ValueError(f"{what} must be positive, got {capacity}")
    if capacity % 8:
        raise ValueError(f"{what} {capacity} not divisible by 8 (bit packing)")
    if group and capacity % group:
        raise ValueError(f"{what} {capacity} not divisible by group {group}")


def init_layer_cache(
    n_layers: int,
    B: int,
    capacity: int,
    n_kv: int,
    d_head: int,
    cfg: PolicyConfig | None,
    dtype=jnp.bfloat16,
) -> dict[str, Any]:
    """Stacked [L, B, S, Hkv, D] K/V slabs (+ policy metadata side-car).

    ``capacity`` must be divisible by 8 (bit packing) and by the policy's
    group/page size — ``capacity // 8`` would otherwise silently truncate
    and misallocate the code side-car (rows beyond the truncated count
    would be scored from the wrong bytes)."""
    if cfg is not None and cfg.kind == "fier":
        _check_capacity(capacity, cfg.group, what="capacity")
    elif cfg is not None and cfg.kind == "quest":
        if capacity % cfg.page:
            raise ValueError(
                f"capacity {capacity} not divisible by quest page {cfg.page}"
            )
    kv = dict(
        k=jnp.zeros((n_layers, B, capacity, n_kv, d_head), dtype),
        v=jnp.zeros((n_layers, B, capacity, n_kv, d_head), dtype),
    )
    if cfg is not None and cfg.kind == "fier":
        from repro.core.quantize import QuantizedKeys

        g = cfg.group
        kv["meta"] = QuantizedKeys(
            jnp.zeros((n_layers, B, capacity // 8, n_kv, d_head), jnp.uint8),
            jnp.zeros((n_layers, B, capacity // g, n_kv, d_head), jnp.bfloat16),
            jnp.zeros((n_layers, B, capacity // g, n_kv, d_head), jnp.bfloat16),
            g,
        )
    elif cfg is not None and cfg.kind == "quest":
        from repro.core.quest import PageMeta

        L = cfg.page
        kv["meta"] = PageMeta(
            jnp.zeros((n_layers, B, capacity // L, n_kv, d_head), jnp.bfloat16),
            jnp.zeros((n_layers, B, capacity // L, n_kv, d_head), jnp.bfloat16),
            L,
        )
    return kv


def append_kv(
    k_cache: jax.Array,
    v_cache: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    length: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Write one (or more) new tokens at each sequence's own position.

    k_cache [B,S,H,D], k_new [B,T,H,D], length [B] → updated slabs.
    """
    upd = jax.vmap(
        lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, axis=0)
    )
    return upd(k_cache, k_new.astype(k_cache.dtype), length), upd(
        v_cache, v_new.astype(v_cache.dtype), length
    )


def append_token_metadata(
    meta: Any,
    k_slab: jax.Array,
    length: jax.Array,
    cfg: PolicyConfig,
    commit_mask: jax.Array | None = None,
) -> Any:
    """Per-sequence incremental metadata refresh after a 1-token append.

    Each sequence may sit in a different group/page, so the single-sequence
    refresh is vmapped over the batch.  Only the block containing the
    written slot is recomputed from the slab; when ``commit_mask`` [B] is
    given, non-committing rows rewrite their OLD block (the select happens
    on the block, never the whole side-car — no slab-wide copies).
    """
    if meta is None or cfg.kind == "full":
        return meta
    if cfg.kind == "fier":
        from repro.core.quantize import QuantizedKeys

        g = cfg.group

        def one(codes, scale, zero, k, pos, ok):
            # unbatched: codes [S/8,H,D], scale/zero [S/g,H,D], k [S,H,D]
            start = (pos // g) * g
            blk = jax.lax.dynamic_slice_in_dim(k, start, g, axis=0)  # [g,H,D]
            kmax, kmin = blk.max(0), blk.min(0)
            z, s = (kmax + kmin) * 0.5, (kmax - kmin) * 0.5
            bits = (blk >= z[None].astype(blk.dtype)).astype(jnp.uint8)
            shifts = jnp.arange(8, dtype=jnp.uint8).reshape(1, 8, 1, 1)
            packed = jnp.sum(
                bits.reshape(g // 8, 8, *bits.shape[1:]) << shifts, axis=1
            ).astype(jnp.uint8)
            new_c = packed
            new_s = s[None].astype(scale.dtype)
            new_z = z[None].astype(zero.dtype)
            if ok is not None:
                old_c = jax.lax.dynamic_slice_in_dim(codes, start // 8, g // 8, 0)
                old_s = jax.lax.dynamic_slice_in_dim(scale, start // g, 1, 0)
                old_z = jax.lax.dynamic_slice_in_dim(zero, start // g, 1, 0)
                new_c = jnp.where(ok, new_c, old_c)
                new_s = jnp.where(ok, new_s, old_s)
                new_z = jnp.where(ok, new_z, old_z)
            return (
                jax.lax.dynamic_update_slice_in_dim(codes, new_c, start // 8, 0),
                jax.lax.dynamic_update_slice_in_dim(scale, new_s, start // g, 0),
                jax.lax.dynamic_update_slice_in_dim(zero, new_z, start // g, 0),
            )

        cm = commit_mask if commit_mask is not None else None
        if cm is None:
            codes, scale, zero = jax.vmap(
                lambda c, s_, z_, k, p: one(c, s_, z_, k, p, None)
            )(meta.codes, meta.scale, meta.zero, k_slab, length)
        else:
            codes, scale, zero = jax.vmap(one)(
                meta.codes, meta.scale, meta.zero, k_slab, length, cm
            )
        return QuantizedKeys(codes, scale, zero, g)

    if cfg.kind == "quest":
        from repro.core.quest import PageMeta

        L = cfg.page

        def one(kmax_c, kmin_c, k, pos, ok):
            start = (pos // L) * L
            blk = jax.lax.dynamic_slice_in_dim(k, start, L, axis=0)
            new_mx = blk.max(0, keepdims=True).astype(kmax_c.dtype)
            new_mn = blk.min(0, keepdims=True).astype(kmin_c.dtype)
            if ok is not None:
                old_mx = jax.lax.dynamic_slice_in_dim(kmax_c, start // L, 1, 0)
                old_mn = jax.lax.dynamic_slice_in_dim(kmin_c, start // L, 1, 0)
                new_mx = jnp.where(ok, new_mx, old_mx)
                new_mn = jnp.where(ok, new_mn, old_mn)
            return (
                jax.lax.dynamic_update_slice_in_dim(kmax_c, new_mx, start // L, 0),
                jax.lax.dynamic_update_slice_in_dim(kmin_c, new_mn, start // L, 0),
            )

        if commit_mask is None:
            kmax, kmin = jax.vmap(lambda a, b, k, p: one(a, b, k, p, None))(
                meta.kmax, meta.kmin, k_slab, length
            )
        else:
            kmax, kmin = jax.vmap(one)(
                meta.kmax, meta.kmin, k_slab, length, commit_mask
            )
        return PageMeta(kmax, kmin, L)
    raise ValueError(cfg.kind)


def valid_mask(capacity: int, length: jax.Array) -> jax.Array:
    """bool[B, capacity] — True for written slots."""
    pos = jnp.arange(capacity, dtype=jnp.int32)
    return pos[None, :] < length[:, None]
