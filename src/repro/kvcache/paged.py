"""Paged KV cache: device-side block pool + host-side block allocator.

Instead of one dense ``[L, B, capacity, Hkv, D]`` slab per engine slot
(HBM provisioned for the worst-case context on every slot), the paged
cache keeps a single pool of fixed-size blocks

    k, v     [L, n_blocks, block_size, Hkv, D]      (bf16)
    meta     codes [L, n_blocks, block_size//8,  Hkv, D]  uint8
             scale [L, n_blocks, block_size//g,  Hkv, D]  bf16
             zero  [L, n_blocks, block_size//g,  Hkv, D]  bf16

and a per-request **block table** ``[B, capacity // block_size]`` int32
mapping logical block ``j`` of request ``b`` to a physical pool block.
Logical token ``t`` lives at ``(block_table[b, t // bs], t % bs)``.  The
FIER 1-bit code side-car pages at the same granularity as the K/V rows it
summarizes (``block_size`` is a multiple of 8 and of the quantization
group ``g``, so a block holds whole bytes and whole (scale, zero) cells).

Block id 0 is the reserved **null block**: it is never allocated, every
block-table row starts as all-zeros, and out-of-range / inactive-slot
writes are routed to it — so a freed slot's scratch decode writes can
never corrupt a reallocated block.  Consumers mask by ``length``, so
null-block garbage is never read into a result.

Host side, :class:`BlockAllocator` owns the free list and the ref counts,
with **hash-based prefix sharing**: each prefill-time block is registered
under a chained hash of its token ids (``key_j = hash((key_{j-1},
tokens_of_block_j))``), so a later prompt with the same prefix re-uses
the physical blocks (ref-count incremented, no re-write).  Shared rows
are immutable — decode only ever *appends* at ``length`` — so sharing a
partially-filled tail block is safe until a writer appends into it, at
which point the engine performs **copy-on-write** (``ref > 1`` → copy
the block, remap the writer's table entry).  Blocks whose ref count
drops to zero but that carry a registered hash are parked in an LRU
"free-but-cached" pool: their contents stay valid for future prefix hits
until the allocator has to evict them for a fresh allocation.

Device primitives here mirror ``kvcache.cache`` exactly (same math per
token, different addressing), so a paged decode is bit-identical to the
slab decode on the same logical cache contents — asserted across the GQA
matrix in tests/test_paged.py.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.policy import PolicyConfig

from .prefix_tree import PrefixTree

NULL_BLOCK = 0  # reserved trash block: never allocated, masked everywhere


def check_block_size(block_size: int, group: int = 0) -> None:
    """A block must hold whole code bytes (8 tokens) and whole (scale,
    zero) group cells, or the ``// 8`` / ``// group`` side-car shapes
    silently truncate."""
    from .cache import _check_capacity

    _check_capacity(block_size, group, what="block_size")


def init_paged_pool(
    n_layers: int,
    n_blocks: int,
    block_size: int,
    n_kv: int,
    d_head: int,
    cfg: PolicyConfig | None,
    dtype=jnp.bfloat16,
) -> dict[str, Any]:
    """Block-pool K/V slabs [L, N, bs, Hkv, D] (+ paged FIER side-car)."""
    check_block_size(
        block_size, cfg.group if cfg is not None and cfg.kind == "fier" else 0
    )
    if n_blocks < 2:
        raise ValueError(
            f"pool needs >= 2 blocks (block 0 is the reserved null block), "
            f"got {n_blocks}"
        )
    kv = dict(
        k=jnp.zeros((n_layers, n_blocks, block_size, n_kv, d_head), dtype),
        v=jnp.zeros((n_layers, n_blocks, block_size, n_kv, d_head), dtype),
    )
    if cfg is not None and cfg.kind == "fier":
        from repro.core.quantize import QuantizedKeys

        g = cfg.group
        kv["meta"] = QuantizedKeys(
            jnp.zeros((n_layers, n_blocks, block_size // 8, n_kv, d_head), jnp.uint8),
            jnp.zeros((n_layers, n_blocks, block_size // g, n_kv, d_head), jnp.bfloat16),
            jnp.zeros((n_layers, n_blocks, block_size // g, n_kv, d_head), jnp.bfloat16),
            g,
        )
    elif cfg is not None and cfg.kind != "full":
        raise ValueError(f"paged cache does not support policy {cfg.kind!r}")
    return kv


# ---------------------------------------------------------------- addressing

def _write_target(
    block_table: jax.Array, length: jax.Array, block_size: int
) -> tuple[jax.Array, jax.Array]:
    """(physical block, offset) of each sequence's append slot ``length``.

    Out-of-range positions (length beyond the table) are routed to the
    null block, so scratch writes from frozen/inactive slots land in
    trash instead of clamping onto live data (the slab path's
    dynamic_update_slice clamp had exactly that failure mode).
    """
    n_btab = block_table.shape[1]
    bidx = jnp.clip(length // block_size, 0, n_btab - 1)
    phys = jnp.take_along_axis(block_table, bidx[:, None], axis=1)[:, 0]
    in_range = length < n_btab * block_size
    return jnp.where(in_range, phys, NULL_BLOCK), length % block_size


def gather_block_rows(pool: jax.Array, block_table: jax.Array) -> jax.Array:
    """Materialise the logical per-request view of a pool leaf.

    pool [N, pb, ...] × block_table [B, n_btab] → [B, n_btab * pb, ...]
    (pb = rows per block for this leaf: bs for K/V, bs//8 for codes,
    bs//g for scale/zero).  This is the jnp oracle / fallback path — the
    paged kernels walk the table in-kernel instead of materialising this.
    """
    B, n_btab = block_table.shape
    pb = pool.shape[1]
    g = jnp.take(pool, block_table.reshape(-1), axis=0)  # [B*n_btab, pb, ...]
    return g.reshape(B, n_btab * pb, *pool.shape[2:])


def gather_paged_kv(
    k_pool: jax.Array, v_pool: jax.Array, meta: Any, block_table: jax.Array
) -> tuple[jax.Array, jax.Array, Any]:
    """Logical [B, S, Hkv, D] slab views of the pool (+ side-car)."""
    K = gather_block_rows(k_pool, block_table)
    V = gather_block_rows(v_pool, block_table)
    if meta is None:
        return K, V, None
    from repro.core.quantize import QuantizedKeys

    m = QuantizedKeys(
        gather_block_rows(meta.codes, block_table),
        gather_block_rows(meta.scale, block_table),
        gather_block_rows(meta.zero, block_table),
        meta.group,
    )
    return K, V, m


# -------------------------------------------------------------- append paths

def paged_append_kv(
    k_pool: jax.Array,
    v_pool: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    block_table: jax.Array,
    length: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Write one new token per sequence through the block table.

    k_pool/v_pool [N, bs, Hkv, D]; k_new/v_new [B, 1, Hkv, D] (or
    [B, Hkv, D]); length [B] → updated pools.  The engine guarantees each
    *running* request's table has a writable tail block at ``length``
    (allocated / copy-on-write'd before the decode step); retired slots
    have zeroed rows, so their scratch writes hit the null block.
    """
    if k_new.ndim == 4:
        k_new, v_new = k_new[:, 0], v_new[:, 0]
    bs = k_pool.shape[1]
    phys, off = _write_target(block_table, length, bs)
    k_pool = k_pool.at[phys, off].set(k_new.astype(k_pool.dtype))
    v_pool = v_pool.at[phys, off].set(v_new.astype(v_pool.dtype))
    return k_pool, v_pool


def paged_append_token_metadata(
    meta: Any,
    k_pool: jax.Array,
    block_table: jax.Array,
    length: jax.Array,
    cfg: PolicyConfig,
) -> Any:
    """Incremental FIER side-car refresh after a paged 1-token append.

    Identical math to ``cache.append_token_metadata`` (group min/max →
    (scale, zero) → packed sign bits, recomputed for the one group
    containing the written slot) — only the addressing changes: the group
    lives inside the sequence's tail block, so one [bs, Hkv, D] block is
    gathered per sequence and one group's side-car rows are scattered
    back at the block's pool row.
    """
    if meta is None or cfg.kind == "full":
        return meta
    if cfg.kind != "fier":
        raise ValueError(f"paged metadata refresh: unsupported policy {cfg.kind!r}")
    from repro.core.quantize import QuantizedKeys

    g = cfg.group
    bs = k_pool.shape[1]
    B = length.shape[0]
    phys, off = _write_target(block_table, length, bs)
    blk = jnp.take(k_pool, phys, axis=0)                     # [B, bs, H, D]
    start = (off // g) * g                                   # [B]
    grp = jax.vmap(
        lambda b, s: jax.lax.dynamic_slice_in_dim(b, s, g, axis=0)
    )(blk, start)                                            # [B, g, H, D]
    kmax, kmin = grp.max(1), grp.min(1)                      # [B, H, D]
    z, s = (kmax + kmin) * 0.5, (kmax - kmin) * 0.5
    bits = (grp >= z[:, None].astype(grp.dtype)).astype(jnp.uint8)
    shifts = jnp.arange(8, dtype=jnp.uint8).reshape(1, 1, 8, 1, 1)
    packed = jnp.sum(
        bits.reshape(B, g // 8, 8, *bits.shape[2:]) << shifts, axis=2
    ).astype(jnp.uint8)                                      # [B, g//8, H, D]

    rows8 = (start // 8)[:, None] + jnp.arange(g // 8, dtype=start.dtype)[None]
    codes = meta.codes.at[phys[:, None], rows8].set(packed)
    scale = meta.scale.at[phys, start // g].set(s.astype(meta.scale.dtype))
    zero = meta.zero.at[phys, start // g].set(z.astype(meta.zero.dtype))
    return QuantizedKeys(codes, scale, zero, g)


# -------------------------------------------------------------- host allocator

def block_hash_chain(tokens, block_size: int) -> list[int]:
    """Chained content hashes, one per (possibly partial) prompt block.

    ``key_j`` covers *all* tokens up to the end of block ``j``, so equal
    keys ⇒ equal prefixes ⇒ equal K/V contents (causal attention,
    absolute positions).  The final key identifies the whole prompt and
    doubles as the full-prompt logits-cache key.
    """
    keys, prev = [], 0x9E3779B9
    for i in range(0, len(tokens), block_size):
        prev = hash((prev, tuple(int(t) for t in tokens[i : i + block_size])))
        keys.append(prev)
    return keys


@dataclasses.dataclass
class SeqBlocks:
    """Host-side view of one request's block table row."""

    blocks: list[int] = dataclasses.field(default_factory=list)
    length: int = 0  # next write position (== tokens resident)


class AllocatorAuditError(AssertionError):
    """A :meth:`BlockAllocator.audit` invariant violation (leak, ref-count
    drift, free-list/table overlap, or hash-index inconsistency)."""


@dataclasses.dataclass(frozen=True)
class EvictedBlock:
    """One block demoted out of the device prefix cache (LRU pressure or
    TTL expiry) while its contents were still valid — the record the
    engine's host-offload hook consumes (:mod:`repro.kvcache.offload`).
    ``parent_key`` preserves the trie linkage so a recall re-inserts the
    node under its original prefix parent."""

    bid: int
    key: int
    parent_key: int | None
    reason: str  # "lru" | "ttl"


class BlockAllocator:
    """Free-list block allocator with ref counts and a radix-trie prefix
    cache (:class:`~repro.kvcache.prefix_tree.PrefixTree`).

    States of a block id (> 0):
      * in use:        ref >= 1 (possibly shared; possibly hash-registered)
      * free-cached:   ref == 0 but hash-registered (a *parked* trie
                       node); contents still valid for prefix hits,
                       evicted leaf-first LRU when the free list runs
                       dry, or by TTL (``park_ttl`` clock units on the
                       trie's pluggable clock — the serving scheduler
                       wires its virtual token clock in)
      * free:          ref == 0, no hash; next to be handed out

    Block 0 (the null block) is never handed out.

    Evictions of still-valid cached blocks are observable: with
    ``record_evictions`` set (the engine enables it when a host offload
    tier is attached), every LRU/TTL demotion lands in an internal log
    drained via :meth:`take_evicted` — the engine snapshots those blocks
    to host DRAM *before* their pool rows are overwritten.
    """

    def __init__(self, n_blocks: int, block_size: int,
                 park_ttl: float | None = None):
        if n_blocks < 2:
            raise ValueError(f"pool needs >= 2 blocks, got {n_blocks}")
        check_block_size(block_size)
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.ref = [0] * n_blocks
        self._free: deque[int] = deque(range(1, n_blocks))
        self.tree = PrefixTree()
        self.park_ttl = park_ttl
        self._in_use = 0
        self._fail_next = 0  # fault injection: fail the next N alloc() calls
        self.peak_in_use = 0
        self.cow_copies = 0
        self.prefix_block_hits = 0
        self.injected_alloc_failures = 0
        self.ttl_evictions = 0
        # eviction log for the offload hook (bounded by its consumer: the
        # engine drains it inside the same operation that evicted)
        self.record_evictions = False
        self._evicted: list[EvictedBlock] = []

    # ------------------------------------------------------------- accounting
    def set_clock(self, clock) -> None:
        """Wire the trie's park/TTL clock to an external monotone clock
        (the scheduler's virtual token clock)."""
        self.tree.set_clock(clock)

    def key_of(self, bid: int) -> int | None:
        """The prefix-cache key ``bid`` is registered under (None when
        unregistered) — the trie-era spelling of the old ``_hash_of``."""
        return self.tree.key_of(bid)

    def key_resident(self, key: int) -> bool:
        """Whether ``key`` is registered in the device-tier prefix cache
        (in use or parked).  The engine's eviction drain asks this before
        offloading: under a sharded pool the same content key can be
        registered on several shards, and a key still resident anywhere
        on device must not be handed to the host tier (cross-tier
        single-ownership)."""
        return key in self.tree

    @property
    def usable(self) -> int:
        return self.n_blocks - 1

    @property
    def n_in_use(self) -> int:
        return self._in_use

    @property
    def n_parked(self) -> int:
        """Free-but-cached blocks (parked trie nodes)."""
        return self.tree.n_parked

    @property
    def n_free(self) -> int:
        """Blocks available to a fresh allocation (evictable cached ones
        included — alloc() reclaims them leaf-first LRU)."""
        return len(self._free) + self.tree.n_parked

    def utilization(self) -> float:
        """Blocks resident (referenced) / blocks allocated (pool size)."""
        return self.n_in_use / self.usable

    @staticmethod
    def _percentile(sorted_vals: list[float], q: float) -> float:
        """Nearest-rank percentile over a pre-sorted list (0 when empty) —
        keeps paged.py numpy-free."""
        if not sorted_vals:
            return 0.0
        idx = min(len(sorted_vals) - 1, int(q * (len(sorted_vals) - 1) + 0.5))
        return float(sorted_vals[idx])

    def stats(self) -> dict[str, float]:
        """The canonical pool-accounting snapshot, one ``pool_*`` name per
        quantity.  This is the *single* naming scheme: the metrics
        registry gauges use these names verbatim, and
        ``Engine.pool_stats()`` is a thin shim aliasing its legacy keys
        onto them (the allocator/engine dicts previously reported the
        same quantities under divergent names — e.g. ``usable`` vs
        ``blocks_allocated``)."""
        ages = sorted(self.tree.parked_ages())
        return dict(
            pool_blocks_total=self.n_blocks,
            pool_blocks_usable=self.usable,
            pool_blocks_in_use=self.n_in_use,
            pool_blocks_free=len(self._free),
            pool_blocks_cached=self.tree.n_parked,
            pool_utilization=self.utilization(),
            pool_peak_in_use=self.peak_in_use,
            pool_prefix_block_hits=self.prefix_block_hits,
            pool_cow_copies=self.cow_copies,
            pool_injected_alloc_failures=self.injected_alloc_failures,
            # parked-block age percentiles on the trie clock: how long
            # free-but-cached prefixes have been cold (satellite: stale
            # prefixes must age out deterministically, and their age is
            # the evidence)
            pool_parked_age_p50=self._percentile(ages, 0.50),
            pool_parked_age_p90=self._percentile(ages, 0.90),
            pool_parked_age_max=ages[-1] if ages else 0.0,
            pool_ttl_evictions=self.ttl_evictions,
            pool_leaf_evictions=self.tree.leaf_evictions,
            pool_interior_evictions=self.tree.interior_evictions,
        )

    # -------------------------------------------------------------- alloc/free
    def fail_next(self, n: int = 1) -> None:
        """Chaos hook: make the next ``n`` :meth:`alloc` calls report an
        empty pool (a transient exhaustion burst).  Callers already handle
        None, so the failure exercises the real degradation/preemption
        paths with no allocator state change."""
        self._fail_next += int(n)

    def alloc(self) -> int | None:
        """Hand out a free block (ref=1), evicting the LRU free-cached
        trie leaf if the plain free list is empty (oldest parked node as
        a fallback when every parked node shields cached children).
        None when dry."""
        if self._fail_next > 0:
            self._fail_next -= 1
            self.injected_alloc_failures += 1
            return None
        if self._free:
            bid = self._free.popleft()
        else:
            ev = self.tree.pop_eviction()
            if ev is None:
                return None
            bid, key, parent_key = ev
            if self.record_evictions:
                self._evicted.append(EvictedBlock(bid, key, parent_key, "lru"))
        self.ref[bid] = 1
        self._in_use += 1
        self.peak_in_use = max(self.peak_in_use, self._in_use)
        return bid

    def free(self, bid: int) -> None:
        """Drop one reference; at zero the block parks in the prefix cache
        (if registered) or returns to the free list."""
        assert bid != NULL_BLOCK and self.ref[bid] > 0, (bid, self.ref[bid])
        self.ref[bid] -= 1
        if self.ref[bid] == 0:
            self._in_use -= 1
            if self.tree.key_of(bid) is not None:
                # parks at the LRU end — a block cannot already be parked
                # while its ref count was > 0
                self.tree.park(bid)
            else:
                self._free.append(bid)

    # ------------------------------------------------------------ prefix cache
    def register(self, bid: int, key: int, parent_key: int | None = None) -> None:
        """Publish an in-use block's content hash for future prefix hits.
        First writer wins: an already-registered key keeps its block.
        ``parent_key`` (the previous key of the ``block_hash_chain``)
        links the trie node under its prefix parent — omitted, the node
        attaches at the root and behaves exactly like the old flat
        chained-hash map."""
        assert self.ref[bid] > 0, bid
        if key in self.tree:
            return
        if self.tree.key_of(bid) is not None:
            return  # block already published under its own (older) key
        self.tree.insert(key, bid, parent_key)

    def lookup(self, key: int) -> int | None:
        """Prefix hit: take a reference on the block registered under
        ``key`` (reviving it from the free-cached pool if parked)."""
        bid = self.tree.get(key)
        if bid is None:
            return None
        if self.ref[bid] == 0:
            self.tree.revive(bid)
            self._in_use += 1
        else:
            self.tree.touch(bid)
        self.ref[bid] += 1
        self.peak_in_use = max(self.peak_in_use, self._in_use)
        self.prefix_block_hits += 1
        return bid

    def peek(self, keys: list[int]) -> tuple[int, int]:
        """(hit prefix length, hits currently parked free-cached) for an
        admission-time block budget — no state change."""
        flags = self.peek_prefix(keys)
        return len(flags), sum(flags)

    def peek_prefix(self, keys: list[int]) -> list[bool]:
        """Per-block 'hit is parked free-cached' flags for the longest
        registered prefix of ``keys`` — chunked-admission accounting needs
        the per-block breakdown (tail hits past the resume cap are
        dropped, and only *their* revivals must be uncharged).  No state
        change."""
        flags: list[bool] = []
        for key in keys:
            bid = self.tree.get(key)
            if bid is None:
                break
            flags.append(self.ref[bid] == 0)
        return flags

    # ---------------------------------------------------- eviction / offload
    def expire_parked(self) -> int:
        """TTL sweep: demote every parked block older than ``park_ttl``
        (trie clock units) back to the plain free list, logging each for
        the offload hook.  Returns the number demoted; no-op without a
        TTL.  The scheduler runs this once per step, so on its virtual
        token clock stale prefixes age out deterministically."""
        if self.park_ttl is None:
            return 0
        n = 0
        for bid in self.tree.expired(self.park_ttl):
            key, parent_key = self.tree.remove(bid)
            self.tree.ttl_evictions += 1
            self.ttl_evictions += 1
            if self.record_evictions:
                self._evicted.append(EvictedBlock(bid, key, parent_key, "ttl"))
            self._free.append(bid)
            n += 1
        return n

    def take_evicted(self) -> list[EvictedBlock]:
        """Drain the pending eviction log (records appear only while
        ``record_evictions`` is set).  The engine calls this immediately
        after any operation that can evict — before the evicted blocks'
        pool rows are overwritten — and snapshots them to the host tier."""
        out, self._evicted = self._evicted, []
        return out

    def drop_key(self, key: int) -> int | None:
        """Unregister a *parked* prefix-cache entry and return its block
        to the plain free list (None when the key is absent or in use) —
        the chaos harness's host-tier drop needs the device analogue."""
        bid = self.tree.get(key)
        if bid is None or self.ref[bid] != 0:
            return None
        self.tree.remove(bid)
        self._free.append(bid)
        return bid

    def blocks_needed(self, n_tokens: int, keys: list[int] | None = None) -> int:
        """Fresh blocks a prompt admission would consume (prefix-cache
        revivals also come out of the free pool, so they count)."""
        nb = -(-n_tokens // self.block_size)
        if keys is None:
            return nb
        n_hit, revivals = self.peek(keys[:nb])
        return nb - n_hit + revivals

    # ------------------------------------------------------------------- audit
    def audit(
        self,
        owners: dict[int, int] | None = None,
        host_keys: "set[int] | None" = None,
    ) -> None:
        """Invariant checker; raises :class:`AllocatorAuditError` on the
        first violation, returns None when clean.

        Checks: (a) every block id is in exactly one state — in use
        (ref > 0), free, or free-cached (parked trie node) — i.e. the
        free structures are disjoint from each other and from referenced
        blocks, with no duplicates and no leaked ids; (b) ``_in_use``
        matches the ref counts; (c) the trie's internal indices agree
        (key↔bid symmetry, parent/child symmetry, parked bookkeeping) and
        every parked block has ref == 0; (d) with ``owners`` (bid →
        expected ref count from the engine's live sequences), ref-count
        conservation holds *exactly* — a double free or a leaked
        reference cannot hide; (e) with ``host_keys`` (the offload
        tier's resident keys), no key is owned by both tiers — a
        double-owned block would let a recall clobber a live device
        registration.
        """
        def fail(msg: str) -> None:
            raise AllocatorAuditError(f"allocator audit: {msg}")

        if self.ref[NULL_BLOCK] != 0:
            fail(f"null block has ref {self.ref[NULL_BLOCK]}")
        free = list(self._free)
        cached = list(self.tree._parked)
        if NULL_BLOCK in free or NULL_BLOCK in cached:
            fail("null block on a free list")
        if len(set(free)) != len(free):
            fail("duplicate ids on the free list (double free)")
        if set(free) & set(cached):
            fail(f"free list and free-cached overlap: {set(free) & set(cached)}")
        in_use = {b for b in range(1, self.n_blocks) if self.ref[b] > 0}
        for b in free + cached:
            if b in in_use:
                fail(f"block {b} is both referenced (ref={self.ref[b]}) and free")
        if len(in_use) + len(free) + len(cached) != self.n_blocks - 1:
            unaccounted = (
                set(range(1, self.n_blocks)) - in_use - set(free) - set(cached)
            )
            fail(f"leaked blocks (in no state): {sorted(unaccounted)}")
        if self._in_use != len(in_use):
            fail(f"_in_use counter {self._in_use} != referenced blocks {len(in_use)}")
        for err in self.tree.audit():
            fail(f"prefix trie: {err}")
        for bid in cached:
            if self.ref[bid] != 0:
                fail(f"free-cached block {bid} has ref {self.ref[bid]}")
        for bid in self.tree._by_bid:
            if bid in free:
                fail(f"registered block {bid} sits on the plain free list")
        if owners is not None:
            for b in range(1, self.n_blocks):
                expect = owners.get(b, 0)
                if self.ref[b] != expect:
                    fail(
                        f"ref-count drift on block {b}: allocator says "
                        f"{self.ref[b]}, owners hold {expect}"
                    )
        if host_keys is not None:
            both = host_keys & set(self.tree._by_key)
            if both:
                fail(
                    f"keys owned by both tiers (device trie AND host "
                    f"offload): {sorted(both)[:8]}"
                )
