"""Mesh-partitioned paged KV pool: TP×DP sharded decode + allocator.

This module turns the single-device paged subsystem (``kvcache/paged.py``)
into a multi-device one along two mesh axis groups:

* **TP (KV-head parallel)** — every pool leaf is sharded on its KV-head
  axis.  Query heads shard in matching contiguous chunks, so with GQA the
  local query head ``h`` attends to local KV head ``h // rep`` exactly as
  on one device: each shard runs the *unchanged* single-device backend
  decode (``decode_attention``) over its local heads and the concatenated
  result is bit-identical to the single-device oracle.  No LSE merge is
  needed — heads partition the output exactly.  Requires
  ``n_kv_heads % n_tp == 0``.
* **DP (batch parallel)** — the pool's block axis splits into contiguous
  per-shard ranges of ``n_local`` blocks, and the slot axis (block table
  + lengths) splits in matching ranges, so a slot's blocks always live on
  its *home shard*.  Block tables store **global** block ids; inside the
  ``shard_map`` body they are localized with a range test
  (``start <= bid < start + n_local``) that maps every foreign or null id
  to the shard's local null block.  The host side mirrors this with
  :class:`ShardedBlockAllocator`: one inner
  :class:`~repro.kvcache.paged.BlockAllocator` per DP shard, global ids
  ``gid = shard * n_local + local``, each shard's local block 0 reserved
  as its null block (global ids ``shard * n_local`` are never handed
  out), with admission accounting over the per-shard minima.

Selection under this layout is **exact by construction**: TP shards score
their own KV heads over the full sequence, DP shards score their own
batch rows over their full (home-shard) sequence — nobody ever sees a
partial sequence, so FIER's top-k needs no cross-shard threshold
exchange.  The ``local``/``exact`` distinction in :class:`ShardSpec`
matters for the *sequence*-sharded slab path
(``core/distributed.py``) and is kept on the spec so
``DecodePlan.build`` validates it against each backend's
``supports_sharding`` capability uniformly.

Prefix-cache sharing is shard-local: a prompt admitted to a slot on DP
shard 1 cannot revive blocks parked on shard 0 (documented tradeoff —
cross-shard block migration is a follow-up).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import policy as core_policy

from .paged import BlockAllocator, EvictedBlock, paged_append_kv, \
    paged_append_token_metadata

__all__ = [
    "ShardSpec",
    "ShardedBlockAllocator",
    "shard_cache",
    "sharded_paged_decode_step",
]

SHARD_MODES = ("local", "exact")


@dataclass(frozen=True)
class ShardSpec:
    """How the paged pool and decode step split over a mesh.

    ``tp_axes`` shard KV heads (tensor parallel), ``dp_axes`` shard the
    batch/slot axis (data parallel); ``mode`` is the FIER selection mode
    validated against the backend's ``supports_sharding`` capability
    (``exact`` reproduces single-device top-k bit-identically on this
    layout — see the module docstring).
    """

    mesh: object
    tp_axes: tuple[str, ...] = ()
    dp_axes: tuple[str, ...] = ()
    mode: str = "exact"

    def __post_init__(self):
        object.__setattr__(self, "tp_axes", tuple(self.tp_axes))
        object.__setattr__(self, "dp_axes", tuple(self.dp_axes))
        if self.mode not in SHARD_MODES:
            raise ValueError(
                f"shard mode must be one of {SHARD_MODES}, got {self.mode!r}"
            )
        if not self.tp_axes and not self.dp_axes:
            raise ValueError("ShardSpec needs at least one tp or dp mesh axis")
        names = tuple(self.mesh.axis_names)
        for ax in self.tp_axes + self.dp_axes:
            if ax not in names:
                raise ValueError(
                    f"mesh axis {ax!r} not in mesh axes {names!r}"
                )
        overlap = set(self.tp_axes) & set(self.dp_axes)
        if overlap:
            raise ValueError(f"axes in both tp and dp groups: {sorted(overlap)}")

    @property
    def n_tp(self) -> int:
        return math.prod(self.mesh.shape[a] for a in self.tp_axes)

    @property
    def n_dp(self) -> int:
        return math.prod(self.mesh.shape[a] for a in self.dp_axes)


def _dp_index(spec: ShardSpec):
    """This shard's linear DP index (row-major over ``dp_axes``), as a
    traced scalar.  Only valid inside a ``shard_map`` body."""
    idx = jnp.int32(0)
    mul = 1
    for ax in reversed(spec.dp_axes):
        idx = idx + jax.lax.axis_index(ax) * mul
        mul *= spec.mesh.shape[ax]
    return idx


def localize_block_table(block_table, spec: ShardSpec, n_local: int):
    """Map a global-id block table to this DP shard's local ids.

    A slot's blocks all come from its home shard's contiguous range
    ``[start, start + n_local)``, so the translation ``bid - start`` is
    exact for every block this shard will actually read; ids outside the
    range — the global null block, shed-middle holes, and every other
    shard's rows — collapse to the local null block 0 (each inner
    allocator reserves local row 0, so global ids ``shard * n_local``
    are never handed out and local 0 is always a zeroed row).
    """
    if spec.n_dp == 1:
        return block_table
    start = _dp_index(spec) * n_local
    local = block_table - start
    ok = (block_table >= start) & (block_table < start + n_local)
    return jnp.where(ok, local, 0)


def _pool_leaf_spec(dp, tp):
    """PartitionSpec for a pool-shaped leaf by rank: per-layer pools are
    ``[N, pb, H, D]``, layer-stacked pools ``[L, N, pb, H, D]``."""
    def spec_for(leaf):
        if leaf.ndim == 5:
            return P(None, dp, None, tp, None)
        return P(dp, None, tp, None)
    return spec_for


def sharded_paged_decode_step(
    q,
    k_new,
    v_new,
    k_pool,
    v_pool,
    meta,
    block_table,
    length,
    pol,
    plan,
    spec: ShardSpec,
    *,
    update_meta: bool = True,
):
    """One decode step on the mesh-sharded paged pool.

    Appends the new token's K/V (and side-car metadata) into the sharded
    pool and runs the plan's backend over the local shard — KV heads
    local under TP, batch rows local under DP — returning
    ``(out, k_pool, v_pool, meta)`` exactly like the single-device paged
    branch of ``decode_self_attention``.  The backend itself is
    unchanged: inside the body the plan is re-built shard-free so
    ``decode_attention`` takes its ordinary single-device path on the
    local views.
    """
    plan_inner = dataclasses.replace(plan, shard=None)
    dp = spec.dp_axes if spec.dp_axes else None
    tp = spec.tp_axes if spec.tp_axes else None
    n_local = k_pool.shape[0] // spec.n_dp

    q_spec = P(dp, tp, None)
    new_spec = P(dp, None, tp, None) if k_new.ndim == 4 else P(dp, tp, None)
    pool_spec = P(dp, None, tp, None)
    meta_spec = jax.tree.map(lambda _: pool_spec, meta)
    bt_spec = P(dp, None)
    len_spec = P(dp)

    def body(q_l, kn_l, vn_l, k_l, v_l, meta_l, bt_l, len_l):
        bt_loc = localize_block_table(bt_l, spec, n_local)
        k2, v2 = paged_append_kv(k_l, v_l, kn_l, vn_l, bt_loc, len_l)
        meta2 = meta_l
        if meta_l is not None and update_meta:
            meta2 = paged_append_token_metadata(meta2, k2, bt_loc, len_l, pol)
        view = core_policy.CacheView.paged(k2, v2, meta2, bt_loc, len_l + 1)
        out = core_policy.decode_attention(
            q_l, view, plan_inner, layer=pol.skip_layers
        )
        return out, k2, v2, meta2

    f = shard_map(
        body,
        mesh=spec.mesh,
        in_specs=(q_spec, new_spec, new_spec, pool_spec, pool_spec,
                  meta_spec, bt_spec, len_spec),
        out_specs=(q_spec, pool_spec, pool_spec, meta_spec),
        check_vma=False,
    )
    out, k2, v2, meta2 = f(q, k_new, v_new, k_pool, v_pool, meta,
                           block_table, length)
    if tp is not None:
        # gather the head axis before the caller's output projection: a
        # matmul contracting over a TP-sharded axis would partial-sum
        # per shard and psum-combine, whose reduction order differs from
        # the single-device dot — the O(B·Hq·D) all-gather keeps decode
        # bit-identical to the oracle
        out = jax.lax.with_sharding_constraint(
            out, NamedSharding(spec.mesh, P(dp, None, None))
        )
    return out, k2, v2, meta2


def shard_cache(cache: dict, spec: ShardSpec) -> dict:
    """Place a freshly-initialised paged cache onto the mesh: pool leaves
    sharded DP-on-blocks × TP-on-KV-heads, block table and lengths
    DP-on-slots, everything else replicated."""
    mesh = spec.mesh
    dp = spec.dp_axes if spec.dp_axes else None
    tp = spec.tp_axes if spec.tp_axes else None
    leaf_spec = _pool_leaf_spec(dp, tp)

    def put(leaf, pspec):
        return jax.device_put(leaf, NamedSharding(mesh, pspec))

    out = dict(cache)
    for name, val in cache.items():
        if name == "block_table":
            out[name] = put(val, P(dp, None))
        elif name == "length":
            out[name] = put(val, P(dp))
        else:
            out[name] = jax.tree.map(lambda x: put(x, leaf_spec(x)), val)
    return out


# --------------------------------------------------------------------------
# host-side allocator
# --------------------------------------------------------------------------


class _GlobalRefView:
    """Read-only ``allocator.ref[gid]`` over the per-shard ref lists."""

    def __init__(self, alloc: "ShardedBlockAllocator"):
        self._a = alloc

    def __getitem__(self, gid: int) -> int:
        shard, lid = self._a._split(gid)
        return self._a.shards[shard].ref[lid]


class ShardedBlockAllocator:
    """One :class:`BlockAllocator` per DP shard behind the global-id
    surface the engine/scheduler already speak.

    Global id ``gid = shard * n_local + local_id``; each inner allocator
    reserves its local block 0 as the shard's null block, so the global
    ids ``shard * n_local`` are never allocated and the device-side range
    test in :func:`localize_block_table` can collapse foreign ids onto a
    guaranteed-zero row.  Admission accounting is conservative: a
    request's blocks all come from one home shard, so :attr:`usable` and
    :attr:`n_free` report per-shard capacity (``n_local - 1`` and the
    minimum free count) rather than pool-wide sums — a request the
    scheduler admits is guaranteed to fit whichever shard its slot lands
    on.  Prefix lookups are shard-local; callers that don't know the home
    shard yet (pre-admission sizing) get the conservative no-hit answer.
    """

    def __init__(self, n_blocks: int, block_size: int, n_shards: int,
                 park_ttl: float | None = None):
        if n_shards < 1:
            raise ValueError(f"need >= 1 shard, got {n_shards}")
        if n_blocks % n_shards:
            raise ValueError(
                f"pool blocks {n_blocks} not divisible by {n_shards} DP shards"
            )
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.n_shards = n_shards
        self.n_local = n_blocks // n_shards
        self.park_ttl = park_ttl
        self.shards = [
            BlockAllocator(self.n_local, block_size, park_ttl=park_ttl)
            for _ in range(n_shards)
        ]
        self.ref = _GlobalRefView(self)
        # wrapper-level: the engine bumps cow_copies directly, and the
        # chaos injector arms fail_next before knowing which shard will
        # allocate next
        self.cow_copies = 0
        self._fail_next = 0
        self.injected_alloc_failures = 0

    # ------------------------------------------------------------- id mapping
    def _split(self, gid: int) -> tuple[int, int]:
        return divmod(gid, self.n_local)

    def _glob(self, shard: int, lid: int) -> int:
        return shard * self.n_local + lid

    def home(self, gid: int) -> int:
        return gid // self.n_local

    # ------------------------------------------------------------- accounting
    def set_clock(self, clock) -> None:
        for inner in self.shards:
            inner.set_clock(clock)

    def key_of(self, gid: int) -> int | None:
        shard, lid = self._split(gid)
        return self.shards[shard].key_of(lid)

    def key_resident(self, key: int) -> bool:
        return any(inner.key_resident(key) for inner in self.shards)

    @property
    def usable(self) -> int:
        # per-shard: one request's blocks all come from its home shard
        return self.n_local - 1

    @property
    def n_in_use(self) -> int:
        return sum(inner.n_in_use for inner in self.shards)

    @property
    def n_parked(self) -> int:
        return sum(inner.n_parked for inner in self.shards)

    @property
    def n_free(self) -> int:
        # per-device minimum: what any admitted request is guaranteed to
        # find on its home shard (ISSUE: admission over per-device minima)
        return min(inner.n_free for inner in self.shards)

    @property
    def _free(self) -> list[int]:
        out: list[int] = []
        for s, inner in enumerate(self.shards):
            out.extend(self._glob(s, lid) for lid in inner._free)
        return out

    @property
    def peak_in_use(self) -> int:
        return sum(inner.peak_in_use for inner in self.shards)

    @property
    def prefix_block_hits(self) -> int:
        return sum(inner.prefix_block_hits for inner in self.shards)

    @property
    def ttl_evictions(self) -> int:
        return sum(inner.ttl_evictions for inner in self.shards)

    @property
    def record_evictions(self) -> bool:
        return self.shards[0].record_evictions

    @record_evictions.setter
    def record_evictions(self, value: bool) -> None:
        for inner in self.shards:
            inner.record_evictions = value

    def utilization(self) -> float:
        return self.n_in_use / (self.n_blocks - self.n_shards)

    def stats(self) -> dict[str, float]:
        per = [inner.stats() for inner in self.shards]
        out = {k: sum(p[k] for p in per) for k in per[0]}
        ages = sorted(
            age for inner in self.shards for age in inner.tree.parked_ages()
        )
        out.update(
            pool_shards=self.n_shards,
            pool_blocks_total=self.n_blocks,
            pool_blocks_usable=self.n_blocks - self.n_shards,
            pool_utilization=self.utilization(),
            pool_cow_copies=self.cow_copies
            + sum(p["pool_cow_copies"] for p in per),
            pool_injected_alloc_failures=self.injected_alloc_failures
            + sum(p["pool_injected_alloc_failures"] for p in per),
            pool_parked_age_p50=BlockAllocator._percentile(ages, 0.50),
            pool_parked_age_p90=BlockAllocator._percentile(ages, 0.90),
            pool_parked_age_max=ages[-1] if ages else 0.0,
        )
        return out

    def shard_stats(self) -> list[dict[str, float]]:
        """Per-shard ``pool_*`` snapshots (for ``shard``-labeled gauges)."""
        return [inner.stats() for inner in self.shards]

    # -------------------------------------------------------------- alloc/free
    def fail_next(self, n: int = 1) -> None:
        self._fail_next += int(n)

    def alloc(self, shard: int = 0) -> int | None:
        if self._fail_next > 0:
            self._fail_next -= 1
            self.injected_alloc_failures += 1
            return None
        lid = self.shards[shard].alloc()
        return None if lid is None else self._glob(shard, lid)

    def free(self, gid: int) -> None:
        shard, lid = self._split(gid)
        self.shards[shard].free(lid)

    # ------------------------------------------------------------ prefix cache
    def register(self, gid: int, key: int, parent_key: int | None = None) -> None:
        shard, lid = self._split(gid)
        self.shards[shard].register(lid, key, parent_key)

    def lookup(self, key: int, shard: int) -> int | None:
        lid = self.shards[shard].lookup(key)
        return None if lid is None else self._glob(shard, lid)

    def peek(self, keys: list[int], shard: int | None = None) -> tuple[int, int]:
        if shard is None:
            return 0, 0
        return self.shards[shard].peek(keys)

    def peek_prefix(self, keys: list[int], shard: int | None = None) -> list[bool]:
        if shard is None:
            return []
        return self.shards[shard].peek_prefix(keys)

    def blocks_needed(self, n_tokens: int, keys: list[int] | None = None,
                      shard: int | None = None) -> int:
        if keys is None or shard is None:
            return -(-n_tokens // self.block_size)
        return self.shards[shard].blocks_needed(n_tokens, keys)

    # ---------------------------------------------------- eviction / offload
    def expire_parked(self) -> int:
        return sum(inner.expire_parked() for inner in self.shards)

    def take_evicted(self) -> list[EvictedBlock]:
        out: list[EvictedBlock] = []
        for s, inner in enumerate(self.shards):
            out.extend(
                EvictedBlock(self._glob(s, ev.bid), ev.key, ev.parent_key,
                             ev.reason)
                for ev in inner.take_evicted()
            )
        return out

    def drop_key(self, key: int) -> int | None:
        hit = None
        for s, inner in enumerate(self.shards):
            lid = inner.drop_key(key)
            if lid is not None and hit is None:
                hit = self._glob(s, lid)
        return hit

    # ------------------------------------------------------------------- audit
    def audit(
        self,
        owners: dict[int, int] | None = None,
        host_keys: "set[int] | None" = None,
    ) -> None:
        per_owner: list[dict[int, int] | None]
        if owners is None:
            per_owner = [None] * self.n_shards
        else:
            per_owner = [{} for _ in self.shards]
            for gid, refs in owners.items():
                shard, lid = self._split(gid)
                per_owner[shard][lid] = refs
        for inner, own in zip(self.shards, per_owner):
            # host_keys goes to every shard unchanged: the engine's
            # eviction drain only offloads keys resident in *no* shard
            # (key_resident), so cross-tier disjointness holds per shard
            inner.audit(own, host_keys)
