"""Pallas TPU kernels for the paper's compute hot-spots (FIER §4.4 uses a
Triton group-quantization kernel + CUDA top-k; the TPU adaptation is in
DESIGN.md §2/§6):

    fier_score      — packed 1-bit approximate-score scan (decode hot spot)
    sparse_attention — exact decode attention over the selected tokens
    pack_quantize   — prefill-time group quantize + bit-pack

``ops``: jit'd wrappers (interpret=True off-TPU).  ``ref``: jnp oracles.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
