"""Pallas TPU kernels for the paper's compute hot-spots (FIER §4.4 uses a
Triton group-quantization kernel + CUDA top-k; the TPU adaptation is in
DESIGN.md §2 and §Fused decode):

    fier_score       — packed 1-bit approximate-score scan (decode hot spot)
    fused_retrieval  — one-pass retrieval: score scan + GQA group-reduce +
                       masking + exact radix threshold top-k in a single
                       kernel; the per-token score tensors never touch
                       HBM (the serving retrieval default).  Includes the
                       page-table-aware variant (paged_fused_retrieve_hm):
                       the DMA stream walks block_table[b] over the paged
                       code pool instead of a contiguous slab
    topk_select      — threshold top-k on the f32 scores (no global sort)
    sparse_attention — exact decode attention over the selected tokens:
                       unfused (pre-gathered K'/V'), fused (in-kernel row
                       gather from the cache slabs — no materialised
                       copies; the serving fast path), and paged fused
                       (in-kernel logical→(block, offset) translation +
                       row gather from the block pool)
    pack_quantize    — prefill-time group quantize + bit-pack

``ops``: jit'd wrappers (interpret=True off-TPU) — layout dispatch goes
through ``repro.core.policy.CacheView`` (``ops.retrieve`` /
``ops.attend_selected`` / the ``fier_decode_*`` pipelines); the old
``fused_* / paged_fused_*`` names remain as deprecation shims.
``ref``: jnp oracles, including the plan-level ``ref.decode_attention``.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
