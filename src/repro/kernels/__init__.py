"""Pallas TPU kernels for the paper's compute hot-spots (FIER §4.4 uses a
Triton group-quantization kernel + CUDA top-k; the TPU adaptation is in
DESIGN.md §2 and §Fused decode):

    fier_score       — packed 1-bit approximate-score scan (decode hot spot)
    fused_retrieval  — one-pass retrieval: score scan + GQA group-reduce +
                       masking + exact radix threshold top-k in a single
                       kernel; the per-token score tensors never touch
                       HBM (the serving retrieval default)
    topk_select      — threshold top-k on the f32 scores (no global sort)
    sparse_attention — exact decode attention over the selected tokens:
                       unfused (pre-gathered K'/V') and fused
                       (in-kernel row gather from the cache slabs —
                       no materialised copies; the serving fast path)
    pack_quantize    — prefill-time group quantize + bit-pack

``ops``: jit'd wrappers (interpret=True off-TPU).  ``ref``: jnp oracles.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
