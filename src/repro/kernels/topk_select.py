"""Pallas TPU kernel: blockwise top-k *threshold* select on approximate scores.

The unfused decode path ran ``jax.lax.top_k`` over the full f32 score row —
a global sort (O(S log S), and on TPU a multi-pass XLA sort that round-trips
HBM).  Selection only needs the *k-th largest value* though: once τ (the
budget-th score) is known, the top-k index set is exactly

    { i : s_i > τ }  ∪  first (budget − m) indices with s_i == τ,

where m = |{ i : s_i > τ }| — the same set ``lax.top_k`` returns (it breaks
ties toward lower indices, and so does taking τ-ties in ascending index
order).  This file finds τ with a radix binary search over the *bit
patterns* of the scores — 32 blockwise counting passes over VMEM-resident
keys, no sort, exact result — and compacts the indices with O(S)
cumsum + scatter (``compact_indices``), not a sort.

Monotone key trick: reinterpret f32 as uint32 and flip (sign ? all : top)
bits; then float order == unsigned integer order.  −0.0 is canonicalised to
+0.0 first so float equality and key equality agree on ties.

Grid: (BH,).  VMEM per step ≈ 2·S·4 bytes (scores f32 + keys u32) — 256 KiB
at S=32k, 4 MiB at S=512k; beyond that shard the sequence (the distributed
path selects per shard anyway).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128  # lane-padded scalar outputs, matching sparse_attention's carries


def _canon(s: jax.Array) -> jax.Array:
    """Collapse -0.0 → +0.0 so key order and float ties agree."""
    return jnp.where(s == 0.0, 0.0, s)


def _sortable_keys(s: jax.Array) -> jax.Array:
    """f32 → uint32 such that float order == unsigned order."""
    u = jax.lax.bitcast_convert_type(_canon(s), jnp.uint32)
    return jnp.where(u >> 31 == 0, u | jnp.uint32(0x80000000), ~u)


def _unsortable(key: jax.Array) -> jax.Array:
    u = jnp.where(key >> 31 == 1, key ^ jnp.uint32(0x80000000), ~key)
    return jax.lax.bitcast_convert_type(u, jnp.float32)


def _kernel(s_ref, tau_ref, m_ref, keys_ref, *, budget: int, blk_s: int):
    """One (batch·kv-head) row: radix binary search for the budget-th key.

    s_ref [1, S] f32; tau_ref [1, LANE] f32; m_ref [1, LANE] int32;
    keys_ref [1, S] uint32 scratch.
    """
    S = s_ref.shape[1]
    nb = S // blk_s
    keys_ref[...] = _sortable_keys(s_ref[...])

    def count_ge(cand):
        """|{ key >= cand }| — blockwise scan over the VMEM-resident keys."""
        def blk(i, acc):
            k = keys_ref[:, pl.ds(i * blk_s, blk_s)]
            return acc + jnp.sum((k >= cand).astype(jnp.int32))

        return jax.lax.fori_loop(0, nb, blk, jnp.int32(0))

    def bit_step(i, t):
        cand = t | (jnp.uint32(1) << jnp.uint32(31 - i))
        return jnp.where(count_ge(cand) >= budget, cand, t)

    t = jax.lax.fori_loop(0, 32, bit_step, jnp.uint32(0))
    # t is the largest key with count(>= t) >= budget ⇒ exactly the
    # budget-th largest key;  m = strictly-greater count = count(>= t+1).
    m = count_ge(t + jnp.uint32(1))
    tau_ref[...] = jnp.full(tau_ref.shape, _unsortable(t), jnp.float32)
    m_ref[...] = jnp.full(m_ref.shape, m, jnp.int32)


@functools.partial(jax.jit, static_argnames=("budget", "blk_s", "interpret"))
def topk_threshold_hm(
    scores: jax.Array,
    budget: int,
    *,
    blk_s: int = 2048,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Head-major threshold search.

    scores f32 [BH, S] → (tau f32 [BH], m int32 [BH]) where tau is the
    ``budget``-th largest score per row and m the strictly-greater count.
    """
    BH, S = scores.shape
    assert 0 < budget <= S, (budget, S)
    blk_s = min(blk_s, S)
    while S % blk_s:
        blk_s //= 2
    tau, m = pl.pallas_call(
        functools.partial(_kernel, budget=budget, blk_s=blk_s),
        grid=(BH,),
        in_specs=[pl.BlockSpec((1, S), lambda b: (b, 0))],
        out_specs=[
            pl.BlockSpec((1, LANE), lambda b: (b, 0)),
            pl.BlockSpec((1, LANE), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, LANE), jnp.float32),
            jax.ShapeDtypeStruct((BH, LANE), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, S), jnp.uint32)],
        interpret=interpret,
    )(scores.astype(jnp.float32))
    return tau[:, 0], m[:, 0]


def compact_indices(
    scores: jax.Array, tau: jax.Array, m: jax.Array, budget: int
) -> jax.Array:
    """O(S) sort-free compaction: scores [BH, S], tau/m [BH] → idx [BH, budget].

    Destination of each selected element is its rank: strictly-greater
    elements land at their running count − 1 (ascending index order), the
    first (budget − m) τ-ties fill the tail.  One cumsum + one bounded
    scatter — never a sort.  The returned index *set* equals
    ``lax.top_k``'s (both break ties toward lower indices); the order is
    ascending-by-position within each class, which downstream attention is
    invariant to.
    """
    BH, S = scores.shape
    s = _canon(scores.astype(jnp.float32))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (BH, S))
    gt = s > tau[:, None]
    tie = s == tau[:, None]
    cgt = jnp.cumsum(gt, axis=-1).astype(jnp.int32)
    ctie = jnp.cumsum(tie, axis=-1).astype(jnp.int32)
    take_tie = tie & (ctie <= (budget - m)[:, None])
    dest = jnp.where(
        gt, cgt - 1, jnp.where(take_tie, m[:, None] + ctie - 1, budget)
    )
    rows = jnp.arange(BH, dtype=jnp.int32)[:, None]
    out = jnp.zeros((BH, budget + 1), jnp.int32)  # col `budget` = discard pad
    out = out.at[rows, dest].set(pos, mode="drop")
    return out[:, :budget]
