"""Pallas TPU kernel: exact decode attention over the FIER-selected tokens.

After top-k selection gathers K'/V' (budget rows, full precision), decode
attention is a single-query softmax over ``budget`` keys per kv head —
small enough that one VMEM block holds a whole (kv-head, budget) tile:
budget=4096, D=128 bf16 → 1 MiB K + 1 MiB V.  Larger budgets tile over
the budget dim with an online-softmax carry.

Grid: (B·Hkv, budget/blk_k).  Invalid slots (selection padding when
budget > length) arrive as an int8 mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, mask_ref, out_ref, m_ref, d_ref, *, scale):
    """Online-softmax step over one budget block.

    q [rep, D]; k/v [blk_k, D]; mask int8 [1, blk_k]; out [rep, D] f32;
    m/d [rep, 128] f32 carries (lane-padded scalars).
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        d_ref[...] = jnp.zeros_like(d_ref)
        out_ref[...] = jnp.zeros_like(out_ref)

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                            # [rep, blk_k]
    valid = mask_ref[...] > 0                            # [1, blk_k]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[..., 0]                               # [rep]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(valid, p, 0.0)
    v = v_ref[...].astype(jnp.float32)
    out_ref[...] = out_ref[...] * alpha[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32
    )
    d_ref[..., 0] = d_ref[..., 0] * alpha + p.sum(axis=-1)
    m_ref[..., 0] = m_new


@functools.partial(jax.jit, static_argnames=("blk_k", "interpret"))
def sparse_attention_hm(
    q: jax.Array,
    k_sel: jax.Array,
    v_sel: jax.Array,
    mask: jax.Array,
    *,
    blk_k: int = 1024,
    interpret: bool = True,
) -> jax.Array:
    """Head-major sparse decode attention.

    q [BH, rep, D]; k_sel/v_sel [BH, budget, D]; mask int8 [BH, 1, budget]
    → out f32 [BH, rep, D].
    """
    BH, rep, D = q.shape
    budget = k_sel.shape[1]
    blk_k = min(blk_k, budget)
    assert budget % blk_k == 0
    grid = (BH, budget // blk_k)
    scale = 1.0 / (D**0.5)
    out, m, d = pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, rep, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((None, blk_k, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((None, blk_k, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((None, 1, blk_k), lambda b, j: (b, 0, j)),
        ],
        out_specs=[
            pl.BlockSpec((None, rep, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((None, rep, 128), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((None, rep, 128), lambda b, j: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, rep, D), jnp.float32),
            jax.ShapeDtypeStruct((BH, rep, 128), jnp.float32),
            jax.ShapeDtypeStruct((BH, rep, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_sel, v_sel, mask)
    den = jnp.maximum(d[..., 0], 1e-30)
    return out / den[..., None]
