"""Pallas TPU kernels: exact decode attention over the FIER-selected tokens.

Two variants:

``sparse_attention_hm`` (unfused) — consumes pre-gathered K'/V' (budget
rows).  The XLA gather that feeds it *materialises* 2·budget·D bytes per
kv head per layer per step in HBM, written once and read once — the
dominant retrieval cost at serving scale (FreeKV observes the same on
GPU).  Kept as the fallback and as the shape the jnp oracle mirrors.

``fused_sparse_attention_hm`` (fused select-and-attend) — consumes top-k
*indices* (int32) plus the full seq-major cache slabs, and pulls each
selected row HBM→VMEM with per-row async DMA inside the kernel.  No K'/V'
copy ever exists in HBM: the only cache traffic is budget rows *read*
directly from the slabs.  The gather loop double-issues the K and V row
copies so both are in flight per step.

Both use the same online-softmax over budget blocks: one VMEM tile holds
a (kv-head, blk_k) stripe — budget=4096, D=128 bf16 → 1 MiB K + 1 MiB V —
and larger budgets carry (m, d) across blocks.

Grids: (B·Hkv, budget/blk_k) unfused; (B, Hkv, budget/blk_k) fused (the
fused kernel indexes the seq-major [B, S, Hkv, D] slabs directly, so the
batch and head coordinates stay separate).  Invalid slots (selection
padding when budget > length) arrive as an int8 mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _softmax_accumulate(q, k, v, valid, out_ref, m_ref, d_ref, *, scale):
    """One online-softmax block update, shared by every attend kernel.

    q [rep, D] f32; k/v [blk_k, D]; valid bool [1, blk_k]; out [rep, D]
    f32; m/d [rep, 128] f32 carries (lane-padded scalars).  Keeping this
    expression shared is what makes the slab, fused, and paged attend
    paths bit-identical at equal ``blk_k``.
    """
    s = jax.lax.dot_general(
        q, k.astype(jnp.float32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                            # [rep, blk_k]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[..., 0]                               # [rep]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(valid, p, 0.0)
    out_ref[...] = out_ref[...] * alpha[:, None] + jax.lax.dot(
        p, v.astype(jnp.float32), preferred_element_type=jnp.float32
    )
    d_ref[..., 0] = d_ref[..., 0] * alpha + p.sum(axis=-1)
    m_ref[..., 0] = m_new


def _kernel(q_ref, k_ref, v_ref, mask_ref, out_ref, m_ref, d_ref, *, scale):
    """Online-softmax step over one budget block.

    q [rep, D]; k/v [blk_k, D]; mask int8 [1, blk_k]; out [rep, D] f32;
    m/d [rep, 128] f32 carries (lane-padded scalars).
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        d_ref[...] = jnp.zeros_like(d_ref)
        out_ref[...] = jnp.zeros_like(out_ref)

    _softmax_accumulate(
        q_ref[...].astype(jnp.float32), k_ref[...], v_ref[...],
        mask_ref[...] > 0, out_ref, m_ref, d_ref, scale=scale,
    )


@functools.partial(jax.jit, static_argnames=("blk_k", "interpret"))
def sparse_attention_hm(
    q: jax.Array,
    k_sel: jax.Array,
    v_sel: jax.Array,
    mask: jax.Array,
    *,
    blk_k: int = 1024,
    interpret: bool = True,
) -> jax.Array:
    """Head-major sparse decode attention.

    q [BH, rep, D]; k_sel/v_sel [BH, budget, D]; mask int8 [BH, 1, budget]
    → out f32 [BH, rep, D].
    """
    BH, rep, D = q.shape
    budget = k_sel.shape[1]
    blk_k = min(blk_k, budget)
    assert budget % blk_k == 0
    grid = (BH, budget // blk_k)
    scale = 1.0 / (D**0.5)
    out, m, d = pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, rep, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((None, blk_k, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((None, blk_k, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((None, 1, blk_k), lambda b, j: (b, 0, j)),
        ],
        out_specs=[
            pl.BlockSpec((None, rep, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((None, rep, 128), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((None, rep, 128), lambda b, j: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, rep, D), jnp.float32),
            jax.ShapeDtypeStruct((BH, rep, 128), jnp.float32),
            jax.ShapeDtypeStruct((BH, rep, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_sel, v_sel, mask)
    den = jnp.maximum(d[..., 0], 1e-30)
    return out / den[..., None]


# ------------------------------------------------------ fused select+attend

def _fused_kernel(
    idx_ref, q_ref, mask_ref, k_hbm, v_hbm, out_ref, m_ref, d_ref,
    k_vmem, v_vmem, sems, *, scale,
):
    """One (batch, kv-head, budget-block) step of fused select-and-attend.

    idx_ref [blk_k] int32 (SMEM); q [rep, D]; mask int8 [1, blk_k];
    k_hbm/v_hbm: *whole* seq-major cache slabs [B, S, Hkv, D] (ANY space —
    never staged through VMEM wholesale); k_vmem/v_vmem [blk_k, D] scratch;
    sems: [2, 2] DMA semaphores — (slot = row parity) × (K, V) — so the
    gather loop keeps the next row's copies in flight while waiting on
    the current row's (double-buffered, not serial round-trips).
    """
    b = pl.program_id(0)
    h = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        d_ref[...] = jnp.zeros_like(d_ref)
        out_ref[...] = jnp.zeros_like(out_ref)

    blk_k = k_vmem.shape[0]

    def row_copies(i):
        """The (K, V) row-i DMA descriptors; slot = i mod 2 double-buffers
        the semaphores so row i+1's copies are in flight while row i's
        are being waited on."""
        row = idx_ref[i]
        slot = jax.lax.rem(i, 2)
        kcp = pltpu.make_async_copy(
            k_hbm.at[b, pl.ds(row, 1), h, :], k_vmem.at[pl.ds(i, 1), :],
            sems.at[slot, 0],
        )
        vcp = pltpu.make_async_copy(
            v_hbm.at[b, pl.ds(row, 1), h, :], v_vmem.at[pl.ds(i, 1), :],
            sems.at[slot, 1],
        )
        return kcp, vcp

    def start_row(i):
        kcp, vcp = row_copies(i)
        kcp.start()
        vcp.start()

    start_row(0)

    def gather(i, _):
        @pl.when(i + 1 < blk_k)
        def _prefetch():
            start_row(i + 1)

        kcp, vcp = row_copies(i)
        kcp.wait()
        vcp.wait()
        return 0

    jax.lax.fori_loop(0, blk_k, gather, 0)

    _softmax_accumulate(
        q_ref[...].astype(jnp.float32), k_vmem[...], v_vmem[...],
        mask_ref[...] > 0, out_ref, m_ref, d_ref, scale=scale,
    )


@functools.partial(jax.jit, static_argnames=("blk_k", "interpret"))
def fused_sparse_attention_hm(
    q: jax.Array,
    K: jax.Array,
    V: jax.Array,
    idx: jax.Array,
    mask: jax.Array,
    *,
    blk_k: int = 1024,
    interpret: bool = True,
) -> jax.Array:
    """Fused select-and-attend decode attention.

    q [B, Hkv, rep, D]; K/V seq-major slabs [B, S, Hkv, D]; idx int32
    [B, Hkv, budget]; mask int8 [B, Hkv, 1, budget] → out f32
    [B, Hkv, rep, D].

    The slabs are bound with ``memory_space=ANY`` — the kernel DMAs only
    the ``budget`` selected rows, so per step per kv head the cache
    traffic is budget·D·2 bytes *read* for K (same for V) and zero bytes
    written, vs. the unfused path's additional budget·D·2 written + read
    back for each materialised K'/V' copy.
    """
    B, Hkv, rep, D = q.shape
    budget = idx.shape[2]
    blk_k = min(blk_k, budget)
    assert budget % blk_k == 0
    grid = (B, Hkv, budget // blk_k)
    scale = 1.0 / (D**0.5)
    out, m, d = pl.pallas_call(
        functools.partial(_fused_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (None, None, blk_k), lambda b, h, j: (b, h, j),
                memory_space=pltpu.SMEM,
            ),
            pl.BlockSpec((None, None, rep, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((None, None, 1, blk_k), lambda b, h, j: (b, h, 0, j)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec((None, None, rep, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((None, None, rep, 128), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((None, None, rep, 128), lambda b, h, j: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, rep, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, rep, 128), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, rep, 128), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((blk_k, D), K.dtype),
            pltpu.VMEM((blk_k, D), V.dtype),
            pltpu.SemaphoreType.DMA((2, 2)),
        ],
        interpret=interpret,
    )(idx, q, mask, K, V)
    den = jnp.maximum(d[..., 0], 1e-30)
    return out / den[..., None]


# ------------------------------------------------- paged select+attend

def _paged_fused_kernel(
    bt_ref, idx_ref, q_ref, mask_ref, k_hbm, v_hbm, out_ref, m_ref, d_ref,
    k_vmem, v_vmem, sems, *, scale, block_size,
):
    """One (batch, kv-head, budget-block) step of *paged* select-and-attend.

    Identical to ``_fused_kernel`` except for row addressing: the cache
    operands are the block-pool slabs [N, bs, Hkv, D] (ANY space) and the
    selected *logical* token index ``t`` is translated in-kernel to
    ``(block_table[t // bs], t % bs)`` via the SMEM-resident table row
    ``bt_ref [n_btab]``.  The online-softmax epilogue is shared, so paged
    and slab outputs are bit-identical at equal ``blk_k``.
    """
    h = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        d_ref[...] = jnp.zeros_like(d_ref)
        out_ref[...] = jnp.zeros_like(out_ref)

    blk_k = k_vmem.shape[0]

    def row_copies(i):
        row = idx_ref[i]
        phys = bt_ref[row // block_size]
        off = jax.lax.rem(row, block_size)
        slot = jax.lax.rem(i, 2)
        kcp = pltpu.make_async_copy(
            k_hbm.at[phys, pl.ds(off, 1), h, :], k_vmem.at[pl.ds(i, 1), :],
            sems.at[slot, 0],
        )
        vcp = pltpu.make_async_copy(
            v_hbm.at[phys, pl.ds(off, 1), h, :], v_vmem.at[pl.ds(i, 1), :],
            sems.at[slot, 1],
        )
        return kcp, vcp

    def start_row(i):
        kcp, vcp = row_copies(i)
        kcp.start()
        vcp.start()

    start_row(0)

    def gather(i, _):
        @pl.when(i + 1 < blk_k)
        def _prefetch():
            start_row(i + 1)

        kcp, vcp = row_copies(i)
        kcp.wait()
        vcp.wait()
        return 0

    jax.lax.fori_loop(0, blk_k, gather, 0)

    _softmax_accumulate(
        q_ref[...].astype(jnp.float32), k_vmem[...], v_vmem[...],
        mask_ref[...] > 0, out_ref, m_ref, d_ref, scale=scale,
    )


@functools.partial(jax.jit, static_argnames=("block_size", "blk_k", "interpret"))
def paged_fused_sparse_attention_hm(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_table: jax.Array,
    idx: jax.Array,
    mask: jax.Array,
    *,
    block_size: int,
    blk_k: int = 1024,
    interpret: bool = True,
) -> jax.Array:
    """Paged fused select-and-attend decode attention.

    q [B, Hkv, rep, D]; k_pool/v_pool block-pool slabs [N, bs, Hkv, D];
    block_table int32 [B, n_btab]; idx int32 [B, Hkv, budget] (*logical*
    token positions); mask int8 [B, Hkv, 1, budget] → out f32
    [B, Hkv, rep, D].  As in the contiguous fused kernel, only the
    ``budget`` selected rows move HBM→VMEM — no K'/V' copy, and no
    materialised logical-slab view of the pool either.
    """
    B, Hkv, rep, D = q.shape
    budget = idx.shape[2]
    blk_k = min(blk_k, budget)
    assert budget % blk_k == 0
    assert k_pool.shape[1] == block_size, (k_pool.shape, block_size)
    grid = (B, Hkv, budget // blk_k)
    scale = 1.0 / (D**0.5)
    n_btab = block_table.shape[1]
    out, m, d = pl.pallas_call(
        functools.partial(_paged_fused_kernel, scale=scale, block_size=block_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (None, n_btab), lambda b, h, j: (b, 0),
                memory_space=pltpu.SMEM,
            ),
            pl.BlockSpec(
                (None, None, blk_k), lambda b, h, j: (b, h, j),
                memory_space=pltpu.SMEM,
            ),
            pl.BlockSpec((None, None, rep, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((None, None, 1, blk_k), lambda b, h, j: (b, h, 0, j)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec((None, None, rep, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((None, None, rep, 128), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((None, None, rep, 128), lambda b, h, j: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, rep, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, rep, 128), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, rep, 128), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((blk_k, D), k_pool.dtype),
            pltpu.VMEM((blk_k, D), v_pool.dtype),
            pltpu.SemaphoreType.DMA((2, 2)),
        ],
        interpret=interpret,
    )(block_table, idx, q, mask, k_pool, v_pool)
    den = jnp.maximum(d[..., 0], 1e-30)
    return out / den[..., None]
