"""Pure-jnp oracles for every Pallas kernel (single source of truth: the
reference implementations in ``repro.core``).

Each function mirrors the layout of its ``ops.py`` counterpart exactly, so
tests can sweep shapes/dtypes and ``assert_allclose`` kernel-vs-oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quantize as qz
from repro.core import retrieval
from repro.core.policy import CacheView, DecodePlan


def fier_score(q: jax.Array, qk: qz.QuantizedKeys) -> jax.Array:
    """[B,Hq,D] × QuantizedKeys([B,S/8,Hkv,D]) → f32 [B,Hq,S]."""
    return retrieval.approx_scores(q, qk)


def sparse_attention(
    q: jax.Array,
    k_sel: jax.Array,
    v_sel: jax.Array,
    idx: jax.Array,
    length: jax.Array | None,
) -> jax.Array:
    """[B,Hq,D] × selected [B,k,Hkv,D] → [B,Hq,D]."""
    return retrieval.sparse_attention(q, k_sel, v_sel, idx, length)


def pack_quantize(k: jax.Array, group: int) -> qz.QuantizedKeys:
    """[B,S,Hkv,D] → QuantizedKeys (codes/scale/zero, seq-major layout)."""
    return qz.quantize(k, group)


def topk_select(
    kv_scores: jax.Array,
    budget: int,
    length: jax.Array | None = None,
    *,
    sink: int = 0,
    recent: int = 0,
) -> jax.Array:
    """[B,Hkv,S] → int32 [B,Hkv,budget] — the lax.top_k global-sort oracle
    for the threshold-select kernel (index *sets* must match exactly)."""
    return retrieval.select_topk(
        kv_scores, budget, length, sink=sink, recent=recent
    )


def fused_retrieve(
    q: jax.Array,
    qk: qz.QuantizedKeys,
    budget: int,
    length: jax.Array | None = None,
    *,
    group_reduce: str = "max",
    sink: int = 0,
    recent: int = 0,
) -> jax.Array:
    """Oracle for the one-pass retrieval kernel: the fully-materialised
    jnp pipeline ``approx_scores → reduce_over_query_group → select_topk``
    (every intermediate score tensor written out, global lax.top_k sort).
    The kernel must return the same index *set* up to score-scan rounding;
    the *exact*-set contract is against select-over-``ops.fier_score``
    (bit-identical scores — see fier_score.score_block)."""
    Hkv = qk.codes.shape[2]
    s = retrieval.approx_scores(q, qk)
    kv = retrieval.reduce_over_query_group(s, Hkv, group_reduce)
    return retrieval.select_topk(kv, budget, length, sink=sink, recent=recent)


def fused_sparse_attention(
    q: jax.Array,
    K: jax.Array,
    V: jax.Array,
    idx: jax.Array,
    length: jax.Array | None,
) -> jax.Array:
    """Oracle for the fused kernel: materialised gather + sparse attention
    (the unfused pipeline the fused path must agree with to tolerance)."""
    k_sel, v_sel = retrieval.gather_kv(K, V, idx)
    return retrieval.sparse_attention(q, k_sel, v_sel, idx, length)


# --------------------------------------------------- CacheView/plan oracles

def retrieve(
    q: jax.Array,
    view: CacheView,
    budget: int,
    *,
    group_reduce: str = "max",
    sink: int = 0,
    recent: int = 0,
) -> jax.Array:
    """Oracle for ``ops.retrieve``: materialise the logical side-car
    (paged layouts gather through the block table), then run the fully
    materialised jnp pipeline ``approx_scores → reduce_over_query_group →
    select_topk`` (global lax.top_k sort).  Same index *set* as the
    kernel for any input — the kernels' scores round identically."""
    _, _, meta = view.logical()
    Hkv = meta.codes.shape[2]
    s = retrieval.approx_scores(q, meta)
    kv = retrieval.reduce_over_query_group(s, Hkv, group_reduce)
    return retrieval.select_topk(
        kv, budget, view.length, sink=sink, recent=recent
    )


def decode_attention(q: jax.Array, view: CacheView, plan: DecodePlan) -> jax.Array:
    """The pure-jnp oracle for ``policy.decode_attention`` at *any*
    registered (policy, layout, pipeline): materialise the logical cache
    view and run the policy's reference pipeline with every intermediate
    written out.  The compatibility-matrix test (tests/test_backends.py)
    holds each plan's output to this: bit-identical for reference
    pipelines, exact index set + attend-kernel tolerance for the fused
    ones.

    Note the reference pipelines *are* these jnp building blocks, so for
    those matrix rows this oracle pins dispatch plumbing and the paged
    logical-gather, not the math — the math itself is anchored
    independently (``exact_scores`` / ``full_attention_decode``
    comparisons in tests/test_retrieval.py and the degenerate
    budget >= length cases)."""
    from repro.core import quest as quest_mod

    cfg = plan.policy
    K, V, meta = view.logical()
    length = view.length
    if cfg.kind == "full" or meta is None and cfg.kind != "slm":
        return retrieval.full_attention_decode(q, K, V, length)
    if cfg.kind == "fier":
        return retrieval.fier_decode_reference(
            q, K, V, meta, cfg.budget, length,
            group_reduce=cfg.group_reduce, sink=cfg.sink, recent=cfg.recent,
        )
    if cfg.kind == "quest":
        return quest_mod.quest_attention_decode(
            q, K, V, meta, cfg.budget, length, group_reduce=cfg.group_reduce
        )
    if cfg.kind == "slm":
        B, Hq, _ = q.shape
        Hkv = K.shape[2]
        sink = max(cfg.sink, 4)
        zeros = jnp.zeros((B, Hkv, K.shape[1]), jnp.float32)
        idx = retrieval.select_topk(
            zeros, cfg.budget, length, sink=sink, recent=cfg.budget - sink
        )
        Ksel, Vsel = retrieval.gather_kv(K, V, idx)
        return retrieval.sparse_attention(q, Ksel, Vsel, idx, length)
    raise ValueError(f"no oracle for policy {cfg.kind!r}")


# ------------------------------------------------------------- paged oracles

def paged_fused_retrieve(
    q: jax.Array,
    meta: qz.QuantizedKeys,
    block_table: jax.Array,
    budget: int,
    length: jax.Array | None = None,
    *,
    group_reduce: str = "max",
    sink: int = 0,
    recent: int = 0,
) -> jax.Array:
    """Oracle for the paged one-pass kernel: materialise the logical
    (table-gathered) side-car, then run the fully-materialised slab
    pipeline.  Same index-set contract as ``fused_retrieve``."""
    from repro.kvcache.paged import gather_block_rows

    logical = qz.QuantizedKeys(
        gather_block_rows(meta.codes, block_table),
        gather_block_rows(meta.scale, block_table),
        gather_block_rows(meta.zero, block_table),
        meta.group,
    )
    return fused_retrieve(
        q, logical, budget, length,
        group_reduce=group_reduce, sink=sink, recent=recent,
    )


def paged_fused_fier_attention_decode(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    meta: qz.QuantizedKeys,
    block_table: jax.Array,
    budget: int,
    length: jax.Array | None = None,
    *,
    group_reduce: str = "max",
    sink: int = 0,
    recent: int = 0,
) -> jax.Array:
    """Oracle for the paged fused decode: gather the logical K/V slab and
    side-car through the block table, then run the unfused jnp pipeline."""
    from repro.kvcache.paged import gather_paged_kv

    K, V, logical = gather_paged_kv(k_pool, v_pool, meta, block_table)
    Hkv = K.shape[2]
    s = retrieval.approx_scores(q, logical)
    kv = retrieval.reduce_over_query_group(s, Hkv, group_reduce)
    idx = retrieval.select_topk(kv, budget, length, sink=sink, recent=recent)
    k_sel, v_sel = retrieval.gather_kv(K, V, idx)
    return retrieval.sparse_attention(q, k_sel, v_sel, idx, length)
