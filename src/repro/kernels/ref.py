"""Pure-jnp oracles for every Pallas kernel (single source of truth: the
reference implementations in ``repro.core``).

Each function mirrors the layout of its ``ops.py`` counterpart exactly, so
tests can sweep shapes/dtypes and ``assert_allclose`` kernel-vs-oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quantize as qz
from repro.core import retrieval


def fier_score(q: jax.Array, qk: qz.QuantizedKeys) -> jax.Array:
    """[B,Hq,D] × QuantizedKeys([B,S/8,Hkv,D]) → f32 [B,Hq,S]."""
    return retrieval.approx_scores(q, qk)


def sparse_attention(
    q: jax.Array,
    k_sel: jax.Array,
    v_sel: jax.Array,
    idx: jax.Array,
    length: jax.Array | None,
) -> jax.Array:
    """[B,Hq,D] × selected [B,k,Hkv,D] → [B,Hq,D]."""
    return retrieval.sparse_attention(q, k_sel, v_sel, idx, length)


def pack_quantize(k: jax.Array, group: int) -> qz.QuantizedKeys:
    """[B,S,Hkv,D] → QuantizedKeys (codes/scale/zero, seq-major layout)."""
    return qz.quantize(k, group)


def topk_select(
    kv_scores: jax.Array,
    budget: int,
    length: jax.Array | None = None,
    *,
    sink: int = 0,
    recent: int = 0,
) -> jax.Array:
    """[B,Hkv,S] → int32 [B,Hkv,budget] — the lax.top_k global-sort oracle
    for the threshold-select kernel (index *sets* must match exactly)."""
    return retrieval.select_topk(
        kv_scores, budget, length, sink=sink, recent=recent
    )


def fused_retrieve(
    q: jax.Array,
    qk: qz.QuantizedKeys,
    budget: int,
    length: jax.Array | None = None,
    *,
    group_reduce: str = "max",
    sink: int = 0,
    recent: int = 0,
) -> jax.Array:
    """Oracle for the one-pass retrieval kernel: the fully-materialised
    jnp pipeline ``approx_scores → reduce_over_query_group → select_topk``
    (every intermediate score tensor written out, global lax.top_k sort).
    The kernel must return the same index *set* up to score-scan rounding;
    the *exact*-set contract is against select-over-``ops.fier_score``
    (bit-identical scores — see fier_score.score_block)."""
    Hkv = qk.codes.shape[2]
    s = retrieval.approx_scores(q, qk)
    kv = retrieval.reduce_over_query_group(s, Hkv, group_reduce)
    return retrieval.select_topk(kv, budget, length, sink=sink, recent=recent)


def fused_sparse_attention(
    q: jax.Array,
    K: jax.Array,
    V: jax.Array,
    idx: jax.Array,
    length: jax.Array | None,
) -> jax.Array:
    """Oracle for the fused kernel: materialised gather + sparse attention
    (the unfused pipeline the fused path must agree with to tolerance)."""
    k_sel, v_sel = retrieval.gather_kv(K, V, idx)
    return retrieval.sparse_attention(q, k_sel, v_sel, idx, length)


# ------------------------------------------------------------- paged oracles

def paged_fused_retrieve(
    q: jax.Array,
    meta: qz.QuantizedKeys,
    block_table: jax.Array,
    budget: int,
    length: jax.Array | None = None,
    *,
    group_reduce: str = "max",
    sink: int = 0,
    recent: int = 0,
) -> jax.Array:
    """Oracle for the paged one-pass kernel: materialise the logical
    (table-gathered) side-car, then run the fully-materialised slab
    pipeline.  Same index-set contract as ``fused_retrieve``."""
    from repro.kvcache.paged import gather_block_rows

    logical = qz.QuantizedKeys(
        gather_block_rows(meta.codes, block_table),
        gather_block_rows(meta.scale, block_table),
        gather_block_rows(meta.zero, block_table),
        meta.group,
    )
    return fused_retrieve(
        q, logical, budget, length,
        group_reduce=group_reduce, sink=sink, recent=recent,
    )


def paged_fused_fier_attention_decode(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    meta: qz.QuantizedKeys,
    block_table: jax.Array,
    budget: int,
    length: jax.Array | None = None,
    *,
    group_reduce: str = "max",
    sink: int = 0,
    recent: int = 0,
) -> jax.Array:
    """Oracle for the paged fused decode: gather the logical K/V slab and
    side-car through the block table, then run the unfused jnp pipeline."""
    from repro.kvcache.paged import gather_paged_kv

    K, V, logical = gather_paged_kv(k_pool, v_pool, meta, block_table)
    Hkv = K.shape[2]
    s = retrieval.approx_scores(q, logical)
    kv = retrieval.reduce_over_query_group(s, Hkv, group_reduce)
    idx = retrieval.select_topk(kv, budget, length, sink=sink, recent=recent)
    k_sel, v_sel = retrieval.gather_kv(K, V, idx)
    return retrieval.sparse_attention(q, k_sel, v_sel, idx, length)
