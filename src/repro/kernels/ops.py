"""jit'd wrappers adapting cache layouts to the head-major Pallas kernels.

On CPU (this container) kernels run in ``interpret=True`` mode — the
kernel body executes as Python/jnp, validating the exact code that
compiles for TPU.  On a TPU backend ``interpret`` flips off automatically.

Layout note: the cache is seq-major [B, S, H, D] (sequence sharding);
kernels want head-major [B·H, S, D] so the scan streams contiguously.
The transposes below are the *baseline*; the §Perf layout iteration
measures a head-major cache variant that removes them (EXPERIMENTS.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import QuantizedKeys
from repro.core.retrieval import NEG_INF

from . import fier_score as _fs
from . import fused_retrieval as _fr
from . import pack_quantize as _pq
from . import sparse_attention as _sa
from . import topk_select as _tk


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fier_score(q: jax.Array, qk: QuantizedKeys, *, blk_s: int = 512) -> jax.Array:
    """Packed 1-bit score scan.  q [B,Hq,D], qk seq-major → f32 [B,Hq,S]."""
    B, Hq, D = q.shape
    Hkv = qk.codes.shape[2]
    rep = Hq // Hkv
    S = qk.codes.shape[1] * 8
    qhm = q.reshape(B, Hkv, rep, D).reshape(B * Hkv, rep, D)
    to_hm = lambda a: jnp.moveaxis(a, 2, 1).reshape(B * Hkv, a.shape[1], D)
    out = _fs.fier_score_hm(
        qhm, to_hm(qk.codes), to_hm(qk.scale), to_hm(qk.zero),
        group=qk.group, blk_s=min(blk_s, S), interpret=_interpret(),
    )
    return out.reshape(B, Hkv, rep, S).reshape(B, Hq, S)


def sparse_attention(
    q: jax.Array,
    k_sel: jax.Array,
    v_sel: jax.Array,
    idx: jax.Array,
    length: jax.Array | None,
    *,
    blk_k: int = 1024,
) -> jax.Array:
    """Decode attention over selected tokens.

    q [B,Hq,D]; k_sel/v_sel [B,k,Hkv,D]; idx [B,Hkv,k]; length [B]
    → [B,Hq,D] (q.dtype).
    """
    B, Hq, D = q.shape
    k = k_sel.shape[1]
    Hkv = k_sel.shape[2]
    rep = Hq // Hkv
    qhm = q.reshape(B, Hkv, rep, D).reshape(B * Hkv, rep, D)
    khm = jnp.moveaxis(k_sel, 2, 1).reshape(B * Hkv, k, D)
    vhm = jnp.moveaxis(v_sel, 2, 1).reshape(B * Hkv, k, D)
    if length is not None:
        valid = idx < length[:, None, None]
    else:
        valid = jnp.ones_like(idx, dtype=bool)
    mask = valid.reshape(B * Hkv, 1, k).astype(jnp.int8)
    out = _sa.sparse_attention_hm(
        qhm, khm, vhm, mask, blk_k=min(blk_k, k), interpret=_interpret()
    )
    return out.reshape(B, Hkv, rep, D).reshape(B, Hq, D).astype(q.dtype)


def pack_quantize(k: jax.Array, group: int, *, blk_s: int = 512) -> QuantizedKeys:
    """Quantize+pack a seq-major key slab [B,S,Hkv,D] → QuantizedKeys."""
    B, S, H, D = k.shape
    khm = jnp.moveaxis(k, 2, 1).reshape(B * H, S, D)
    codes, scale, zero = _pq.pack_quantize_hm(
        khm, group=group, blk_s=min(blk_s, S), interpret=_interpret()
    )
    back = lambda a: jnp.moveaxis(a.reshape(B, H, a.shape[1], D), 1, 2)
    return QuantizedKeys(back(codes), back(scale), back(zero), group)


def topk_select(
    kv_scores: jax.Array,
    budget: int,
    length: jax.Array | None = None,
    *,
    sink: int = 0,
    recent: int = 0,
    blk_s: int = 2048,
) -> jax.Array:
    """Threshold top-k selection — no global sort.

    kv_scores f32 [B, Hkv, S] → indices int32 [B, Hkv, budget]; same index
    set as ``retrieval.select_topk`` (the lax.top_k oracle) for any input.
    The [B·Hkv, S] reshape is a view (no copy): the kv-score layout is
    already head-major.
    """
    from repro.core import retrieval

    B, Hkv, S = kv_scores.shape
    s = retrieval.masked_scores(kv_scores, length, sink=sink, recent=recent)
    s = s.reshape(B * Hkv, S)
    tau, m = _tk.topk_threshold_hm(
        s, budget, blk_s=min(blk_s, S), interpret=_interpret()
    )
    idx = _tk.compact_indices(s, tau, m, budget)
    return idx.reshape(B, Hkv, budget)


def fused_sparse_attention(
    q: jax.Array,
    K: jax.Array,
    V: jax.Array,
    idx: jax.Array,
    length: jax.Array | None,
    *,
    blk_k: int = 1024,
) -> jax.Array:
    """Fused decode attention: gathers selected rows inside the kernel.

    q [B,Hq,D]; K/V seq-major slabs [B,S,Hkv,D]; idx [B,Hkv,budget];
    length [B] → [B,Hq,D] (q.dtype).  Unlike ``sparse_attention`` there is
    no K'/V' operand: the slabs are passed whole (ANY memory space) and
    only the selected rows move HBM→VMEM.  The q/idx/mask reshapes below
    touch O(Hq·D + budget) bytes — nothing cache-sized is copied.
    """
    B, Hq, D = q.shape
    Hkv = K.shape[2]
    rep = Hq // Hkv
    budget = idx.shape[2]
    q4 = q.reshape(B, Hkv, rep, D)
    if length is not None:
        valid = idx < length[:, None, None]
    else:
        valid = jnp.ones_like(idx, dtype=bool)
    mask = valid[:, :, None, :].astype(jnp.int8)
    blk = min(blk_k, budget)
    while budget % blk:
        blk //= 2
    out = _sa.fused_sparse_attention_hm(
        q4, K, V, idx, mask, blk_k=blk, interpret=_interpret()
    )
    return out.reshape(B, Hq, D).astype(q.dtype)


def fused_retrieve(
    q: jax.Array,
    qk: QuantizedKeys,
    budget: int,
    length: jax.Array | None = None,
    *,
    group_reduce: str = "max",
    sink: int = 0,
    recent: int = 0,
    blk_s: int = 512,
    return_stats: bool = False,
):
    """One-pass retrieval: packed codes → top-``budget`` indices, with the
    per-token scores never materialised in HBM.

    q [B,Hq,D], qk seq-major → idx int32 [B,Hkv,budget] (same index set
    as ``select_topk`` over the masked, group-reduced ``fier_score``
    scores).  One Pallas kernel streams the codes, scores each block in
    VREGs, group-reduces and masks in-register, radix-searches τ and
    compacts — neither the [B,Hq,S] nor the [B,Hkv,S] score tensor ever
    exists as an array.  ``return_stats=True`` additionally returns
    (tau f32 [B,Hkv], m int32 [B,Hkv]) — the budget-th score and the
    strictly-greater count per row.
    """
    B, Hq, D = q.shape
    Hkv = qk.codes.shape[2]
    rep = Hq // Hkv
    S = qk.seq_len
    qhm = q.reshape(B, Hkv, rep, D).reshape(B * Hkv, rep, D)
    to_hm = lambda a: jnp.moveaxis(a, 2, 1).reshape(B * Hkv, a.shape[1], D)
    if length is None:
        lens = jnp.full((B * Hkv,), S, jnp.int32)
        recent = 0  # masked_scores applies `recent` only with a length
    else:
        lens = jnp.broadcast_to(
            length.astype(jnp.int32)[:, None], (B, Hkv)
        ).reshape(B * Hkv)
    idx, tau, m = _fr.fused_retrieve_hm(
        qhm, to_hm(qk.codes), to_hm(qk.scale), to_hm(qk.zero), lens, budget,
        group=qk.group, blk_s=blk_s, group_reduce=group_reduce,
        sink=sink, recent=recent, interpret=_interpret(),
    )
    idx = idx.reshape(B, Hkv, budget)
    if return_stats:
        return idx, tau.reshape(B, Hkv), m.reshape(B, Hkv)
    return idx


def fier_attention_decode(
    q: jax.Array,
    K: jax.Array,
    V: jax.Array,
    qk: QuantizedKeys,
    budget: int,
    length: jax.Array | None = None,
    *,
    group_reduce: str = "max",
) -> jax.Array:
    """Kernel-path end-to-end FIER decode (Alg. 1 steps 2–4), unfused:
    kernel scoring but XLA top-k + materialised gather."""
    from repro.core import retrieval

    Hkv = K.shape[2]
    scores = fier_score(q, qk)
    kv_scores = retrieval.reduce_over_query_group(scores, Hkv, group_reduce)
    idx = retrieval.select_topk(kv_scores, budget, length)
    k_sel, v_sel = retrieval.gather_kv(K, V, idx)
    return sparse_attention(q, k_sel, v_sel, idx, length)


def fused_fier_attention_decode(
    q: jax.Array,
    K: jax.Array,
    V: jax.Array,
    qk: QuantizedKeys,
    budget: int,
    length: jax.Array | None = None,
    *,
    group_reduce: str = "max",
    sink: int = 0,
    recent: int = 0,
    blk_k: int = 1024,
    one_pass: bool = True,
) -> jax.Array:
    """Fully fused FIER decode step — the serving decode fast path.

    ``one_pass=True`` (default): single-kernel retrieval
    (``fused_retrieve``: scores never in HBM) → fused select-and-attend.
    ``one_pass=False``: the two-pass pipeline (score-scan kernel →
    threshold top-k kernel, f32 score tensors materialised between them),
    kept for ablation and the byte-accounting benchmarks.  Both return
    bit-identical attention outputs: they select the same index set from
    the same (bit-identical) scores and feed the same attend kernel.
    """
    if one_pass:
        idx = fused_retrieve(
            q, qk, budget, length,
            group_reduce=group_reduce, sink=sink, recent=recent,
        )
    else:
        from repro.core import retrieval

        Hkv = K.shape[2]
        scores = fier_score(q, qk)
        kv_scores = retrieval.reduce_over_query_group(scores, Hkv, group_reduce)
        idx = topk_select(kv_scores, budget, length, sink=sink, recent=recent)
    return fused_sparse_attention(q, K, V, idx, length, blk_k=blk_k)


# ------------------------------------------------------------- paged variants

def paged_fused_retrieve(
    q: jax.Array,
    meta: QuantizedKeys,
    block_table: jax.Array,
    budget: int,
    length: jax.Array | None = None,
    *,
    group_reduce: str = "max",
    sink: int = 0,
    recent: int = 0,
    return_stats: bool = False,
):
    """One-pass retrieval over a paged code pool.

    q [B, Hq, D]; meta: paged side-car pools (codes [N, bs/8, Hkv, D],
    scale/zero [N, bs/g, Hkv, D]); block_table [B, n_btab] → idx int32
    [B, Hkv, budget] of *logical* token positions.  Same index set (and
    identical array, since both compact ascending-by-position) as
    ``fused_retrieve`` over the table-gathered logical cache — and unlike
    the slab wrapper there are no head-major transposes here: the kernel
    indexes the pool's head axis directly, so nothing pool-sized is
    copied per step.
    """
    B, Hq, D = q.shape
    Hkv = meta.codes.shape[2]
    rep = Hq // Hkv
    block_size = meta.codes.shape[1] * 8
    n_btab = block_table.shape[1]
    S = n_btab * block_size
    q4 = q.reshape(B, Hkv, rep, D)
    if length is None:
        lens = jnp.full((B,), S, jnp.int32)
        recent = 0  # masked_scores applies `recent` only with a length
    else:
        lens = length.astype(jnp.int32)
    idx, tau, m = _fr.paged_fused_retrieve_hm(
        q4, meta.codes, meta.scale, meta.zero, block_table, lens, budget,
        group=meta.group, block_size=block_size, group_reduce=group_reduce,
        sink=sink, recent=recent, interpret=_interpret(),
    )
    if return_stats:
        return idx, tau, m
    return idx


def paged_fused_sparse_attention(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_table: jax.Array,
    idx: jax.Array,
    length: jax.Array | None,
    *,
    blk_k: int = 1024,
) -> jax.Array:
    """Paged fused decode attention: in-kernel (block, offset) translation
    + per-row DMA gather from the block pool.

    q [B, Hq, D]; k_pool/v_pool [N, bs, Hkv, D]; idx [B, Hkv, budget]
    logical positions; length [B] → [B, Hq, D] (q.dtype).
    """
    B, Hq, D = q.shape
    Hkv = k_pool.shape[2]
    rep = Hq // Hkv
    budget = idx.shape[2]
    block_size = k_pool.shape[1]
    q4 = q.reshape(B, Hkv, rep, D)
    if length is not None:
        valid = idx < length[:, None, None]
    else:
        valid = jnp.ones_like(idx, dtype=bool)
    mask = valid[:, :, None, :].astype(jnp.int8)
    blk = min(blk_k, budget)
    while budget % blk:
        blk //= 2
    out = _sa.paged_fused_sparse_attention_hm(
        q4, k_pool, v_pool, block_table, idx, mask,
        block_size=block_size, blk_k=blk, interpret=_interpret(),
    )
    return out.reshape(B, Hq, D).astype(q.dtype)


def paged_fused_fier_attention_decode(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    meta: QuantizedKeys,
    block_table: jax.Array,
    budget: int,
    length: jax.Array | None = None,
    *,
    group_reduce: str = "max",
    sink: int = 0,
    recent: int = 0,
    blk_k: int = 1024,
) -> jax.Array:
    """Fully fused paged FIER decode step — the paged serving fast path.

    One-pass retrieval (per-token scores never in HBM) chained into the
    paged select-and-attend kernel; both walk ``block_table`` in-kernel,
    so no logical-slab view of the pool is ever materialised.  Bit-
    identical to ``fused_fier_attention_decode`` on the same logical
    cache contents (asserted across the GQA matrix in tests/test_paged.py).
    """
    idx = paged_fused_retrieve(
        q, meta, block_table, budget, length,
        group_reduce=group_reduce, sink=sink, recent=recent,
    )
    return paged_fused_sparse_attention(
        q, k_pool, v_pool, block_table, idx, length, blk_k=blk_k
    )
