"""jit'd wrappers adapting cache layouts to the head-major Pallas kernels.

On CPU (this container) kernels run in ``interpret=True`` mode — the
kernel body executes as Python/jnp, validating the exact code that
compiles for TPU.  On a TPU backend ``interpret`` flips off automatically.

Layout note: the cache is seq-major [B, S, H, D] (sequence sharding);
kernels want head-major [B·H, S, D] so the scan streams contiguously.
The transposes below are the *baseline*; the §Perf layout iteration
measures a head-major cache variant that removes them (EXPERIMENTS.md).

Cache-layout dispatch happens on :class:`repro.core.policy.CacheView`:
``retrieve`` / ``attend_selected`` read the slab-vs-paged choice off
``view.layout`` instead of forking into ``fused_*`` / ``paged_fused_*``
entrypoint pairs (those names remain as deprecation shims below).
``fier_decode_one_pass`` / ``fier_decode_two_pass`` are the kernel
pipelines the ``fier`` backend registers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.policy import CacheView, _warn_deprecated
from repro.core.quantize import QuantizedKeys

from . import fier_score as _fs
from . import fused_retrieval as _fr
from . import pack_quantize as _pq
from . import sparse_attention as _sa
from . import topk_select as _tk


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fier_score(q: jax.Array, qk: QuantizedKeys, *, blk_s: int = 512) -> jax.Array:
    """Packed 1-bit score scan.  q [B,Hq,D], qk seq-major → f32 [B,Hq,S]."""
    B, Hq, D = q.shape
    Hkv = qk.codes.shape[2]
    rep = Hq // Hkv
    S = qk.codes.shape[1] * 8
    qhm = q.reshape(B, Hkv, rep, D).reshape(B * Hkv, rep, D)
    to_hm = lambda a: jnp.moveaxis(a, 2, 1).reshape(B * Hkv, a.shape[1], D)
    out = _fs.fier_score_hm(
        qhm, to_hm(qk.codes), to_hm(qk.scale), to_hm(qk.zero),
        group=qk.group, blk_s=min(blk_s, S), interpret=_interpret(),
    )
    return out.reshape(B, Hkv, rep, S).reshape(B, Hq, S)


def sparse_attention(
    q: jax.Array,
    k_sel: jax.Array,
    v_sel: jax.Array,
    idx: jax.Array,
    length: jax.Array | None,
    *,
    blk_k: int = 1024,
) -> jax.Array:
    """Decode attention over selected tokens.

    q [B,Hq,D]; k_sel/v_sel [B,k,Hkv,D]; idx [B,Hkv,k]; length [B]
    → [B,Hq,D] (q.dtype).
    """
    B, Hq, D = q.shape
    k = k_sel.shape[1]
    Hkv = k_sel.shape[2]
    rep = Hq // Hkv
    qhm = q.reshape(B, Hkv, rep, D).reshape(B * Hkv, rep, D)
    khm = jnp.moveaxis(k_sel, 2, 1).reshape(B * Hkv, k, D)
    vhm = jnp.moveaxis(v_sel, 2, 1).reshape(B * Hkv, k, D)
    if length is not None:
        valid = idx < length[:, None, None]
    else:
        valid = jnp.ones_like(idx, dtype=bool)
    mask = valid.reshape(B * Hkv, 1, k).astype(jnp.int8)
    out = _sa.sparse_attention_hm(
        qhm, khm, vhm, mask, blk_k=min(blk_k, k), interpret=_interpret()
    )
    return out.reshape(B, Hkv, rep, D).reshape(B, Hq, D).astype(q.dtype)


def pack_quantize(k: jax.Array, group: int, *, blk_s: int = 512) -> QuantizedKeys:
    """Quantize+pack a seq-major key slab [B,S,Hkv,D] → QuantizedKeys."""
    B, S, H, D = k.shape
    khm = jnp.moveaxis(k, 2, 1).reshape(B * H, S, D)
    codes, scale, zero = _pq.pack_quantize_hm(
        khm, group=group, blk_s=min(blk_s, S), interpret=_interpret()
    )
    back = lambda a: jnp.moveaxis(a.reshape(B, H, a.shape[1], D), 1, 2)
    return QuantizedKeys(back(codes), back(scale), back(zero), group)


def topk_select(
    kv_scores: jax.Array,
    budget: int,
    length: jax.Array | None = None,
    *,
    sink: int = 0,
    recent: int = 0,
    blk_s: int = 2048,
) -> jax.Array:
    """Threshold top-k selection — no global sort.

    kv_scores f32 [B, Hkv, S] → indices int32 [B, Hkv, budget]; same index
    set as ``retrieval.select_topk`` (the lax.top_k oracle) for any input.
    The [B·Hkv, S] reshape is a view (no copy): the kv-score layout is
    already head-major.
    """
    from repro.core import retrieval

    B, Hkv, S = kv_scores.shape
    s = retrieval.masked_scores(kv_scores, length, sink=sink, recent=recent)
    s = s.reshape(B * Hkv, S)
    tau, m = _tk.topk_threshold_hm(
        s, budget, blk_s=min(blk_s, S), interpret=_interpret()
    )
    idx = _tk.compact_indices(s, tau, m, budget)
    return idx.reshape(B, Hkv, budget)


# --------------------------------------------------- CacheView-based dispatch

def retrieve(
    q: jax.Array,
    view: CacheView,
    budget: int,
    *,
    group_reduce: str = "max",
    sink: int = 0,
    recent: int = 0,
    blk_s: int = 512,
    return_stats: bool = False,
):
    """One-pass retrieval over a ``CacheView``: packed codes →
    top-``budget`` *logical* token indices, with the per-token scores
    never materialised in HBM.

    q [B, Hq, D]; ``view.meta`` is the ``QuantizedKeys`` side-car (slab
    layout: seq-major [B, S/8, Hkv, D]; paged layout: pool
    [N, bs/8, Hkv, D] walked through ``view.block_table`` in-kernel) →
    idx int32 [B, Hkv, budget], the same index set as ``select_topk``
    over the masked, group-reduced ``fier_score`` scores.  One Pallas
    kernel streams the codes, scores each block in VREGs, group-reduces
    and masks in-register, radix-searches τ and compacts — neither the
    [B,Hq,S] nor the [B,Hkv,S] score tensor ever exists as an array.
    ``return_stats=True`` additionally returns (tau f32 [B,Hkv],
    m int32 [B,Hkv]) — the budget-th score and the strictly-greater
    count per row.
    """
    qk = view.meta
    length = view.length
    B, Hq, D = q.shape
    Hkv = qk.codes.shape[2]
    rep = Hq // Hkv
    if view.layout == "paged":
        block_size = qk.codes.shape[1] * 8
        n_btab = view.block_table.shape[1]
        S = n_btab * block_size
        q4 = q.reshape(B, Hkv, rep, D)
        if length is None:
            lens = jnp.full((B,), S, jnp.int32)
            recent = 0  # masked_scores applies `recent` only with a length
        else:
            lens = length.astype(jnp.int32)
        idx, tau, m = _fr.paged_fused_retrieve_hm(
            q4, qk.codes, qk.scale, qk.zero, view.block_table, lens, budget,
            group=qk.group, block_size=block_size, group_reduce=group_reduce,
            sink=sink, recent=recent, interpret=_interpret(),
        )
        if return_stats:
            return idx, tau, m
        return idx
    S = qk.seq_len
    qhm = q.reshape(B, Hkv, rep, D).reshape(B * Hkv, rep, D)
    to_hm = lambda a: jnp.moveaxis(a, 2, 1).reshape(B * Hkv, a.shape[1], D)
    if length is None:
        lens = jnp.full((B * Hkv,), S, jnp.int32)
        recent = 0  # masked_scores applies `recent` only with a length
    else:
        lens = jnp.broadcast_to(
            length.astype(jnp.int32)[:, None], (B, Hkv)
        ).reshape(B * Hkv)
    idx, tau, m = _fr.fused_retrieve_hm(
        qhm, to_hm(qk.codes), to_hm(qk.scale), to_hm(qk.zero), lens, budget,
        group=qk.group, blk_s=blk_s, group_reduce=group_reduce,
        sink=sink, recent=recent, interpret=_interpret(),
    )
    idx = idx.reshape(B, Hkv, budget)
    if return_stats:
        return idx, tau.reshape(B, Hkv), m.reshape(B, Hkv)
    return idx


def attend_selected(
    q: jax.Array,
    view: CacheView,
    idx: jax.Array,
    *,
    blk_k: int = 1024,
) -> jax.Array:
    """Fused select-and-attend over a ``CacheView``: the selected rows are
    gathered *inside* the kernel (per-row DMA; paged layout additionally
    translates logical→(block, offset) through ``view.block_table`` in
    SMEM), so no K'/V' copies — and nothing cache-sized — is ever
    materialised.

    q [B, Hq, D]; idx [B, Hkv, budget] logical positions → [B, Hq, D]
    (q.dtype).
    """
    B, Hq, D = q.shape
    Hkv = view.k.shape[2]
    rep = Hq // Hkv
    budget = idx.shape[2]
    length = view.length
    if length is not None:
        valid = idx < length[:, None, None]
    else:
        valid = jnp.ones_like(idx, dtype=bool)
    mask = valid[:, :, None, :].astype(jnp.int8)
    blk = min(blk_k, budget)
    while budget % blk:
        blk //= 2
    q4 = q.reshape(B, Hkv, rep, D)
    if view.layout == "paged":
        block_size = view.k.shape[1]
        out = _sa.paged_fused_sparse_attention_hm(
            q4, view.k, view.v, view.block_table, idx, mask,
            block_size=block_size, blk_k=blk, interpret=_interpret(),
        )
    else:
        out = _sa.fused_sparse_attention_hm(
            q4, view.k, view.v, idx, mask, blk_k=blk, interpret=_interpret()
        )
    return out.reshape(B, Hq, D).astype(q.dtype)


# --------------------------------------------------------- backend pipelines

def fier_decode_one_pass(
    q: jax.Array,
    view: CacheView,
    budget: int,
    *,
    group_reduce: str = "max",
    sink: int = 0,
    recent: int = 0,
    blk_k: int = 1024,
) -> jax.Array:
    """The ``one_pass`` FIER pipeline — the serving decode fast path for
    both layouts: single-kernel retrieval (per-token scores never in
    HBM) chained into the fused select-and-attend kernel.  Bit-identical
    to ``fier_decode_two_pass`` (same scores → same index set in the
    same compaction order → same attend kernel), and across layouts on
    the same logical cache contents."""
    idx = retrieve(
        q, view, budget, group_reduce=group_reduce, sink=sink, recent=recent
    )
    return attend_selected(q, view, idx, blk_k=blk_k)


def fier_decode_two_pass(
    q: jax.Array,
    view: CacheView,
    budget: int,
    *,
    group_reduce: str = "max",
    sink: int = 0,
    recent: int = 0,
    blk_k: int = 1024,
) -> jax.Array:
    """The ``two_pass`` FIER pipeline (slab layout only): score-scan
    kernel → threshold top-k kernel (f32 score tensors materialised
    between them) → fused select-and-attend.  Kept for ablation and the
    byte-accounting benchmarks."""
    from repro.core import retrieval

    if view.layout != "slab":
        raise ValueError("two_pass pipeline supports the slab layout only")
    Hkv = view.k.shape[2]
    scores = fier_score(q, view.meta)
    kv_scores = retrieval.reduce_over_query_group(scores, Hkv, group_reduce)
    idx = topk_select(
        kv_scores, budget, view.length, sink=sink, recent=recent
    )
    return attend_selected(q, view, idx, blk_k=blk_k)


# ---------------------------------------------------------- deprecated shims
# Pre-registry entrypoints: thin forwards onto the CacheView-based API,
# kept for external callers.  Each warns (DeprecationWarning) once per
# process on first call.

def fused_sparse_attention(
    q: jax.Array,
    K: jax.Array,
    V: jax.Array,
    idx: jax.Array,
    length: jax.Array | None,
    *,
    blk_k: int = 1024,
) -> jax.Array:
    """Deprecated: ``attend_selected(q, CacheView.slab(K, V), idx)``."""
    _warn_deprecated(
        "kernels.ops.fused_sparse_attention",
        "kernels.ops.attend_selected(q, CacheView.slab(K, V, length=length), idx)",
    )
    return attend_selected(
        q, CacheView.slab(K, V, length=length), idx, blk_k=blk_k
    )


def fused_retrieve(
    q: jax.Array,
    qk: QuantizedKeys,
    budget: int,
    length: jax.Array | None = None,
    *,
    group_reduce: str = "max",
    sink: int = 0,
    recent: int = 0,
    blk_s: int = 512,
    return_stats: bool = False,
):
    """Deprecated: ``retrieve(q, view, budget, ...)`` on a slab view."""
    _warn_deprecated(
        "kernels.ops.fused_retrieve",
        "kernels.ops.retrieve(q, CacheView.slab(..., meta=qk, length=length), budget)",
    )
    view = CacheView.slab(None, None, qk, length)
    return retrieve(
        q, view, budget, group_reduce=group_reduce, sink=sink, recent=recent,
        blk_s=blk_s, return_stats=return_stats,
    )


def fier_attention_decode(
    q: jax.Array,
    K: jax.Array,
    V: jax.Array,
    qk: QuantizedKeys,
    budget: int,
    length: jax.Array | None = None,
    *,
    group_reduce: str = "max",
) -> jax.Array:
    """Deprecated kernel-path unfused decode (kernel scoring + XLA top-k +
    materialised gather + kernel attend) — compose the building blocks or
    use a ``DecodePlan`` pipeline instead."""
    from repro.core import retrieval

    _warn_deprecated(
        "kernels.ops.fier_attention_decode",
        "policy.decode_attention(q, view, plan) or the fier_score / "
        "topk_select / sparse_attention building blocks",
    )
    Hkv = K.shape[2]
    scores = fier_score(q, qk)
    kv_scores = retrieval.reduce_over_query_group(scores, Hkv, group_reduce)
    idx = retrieval.select_topk(kv_scores, budget, length)
    k_sel, v_sel = retrieval.gather_kv(K, V, idx)
    return sparse_attention(q, k_sel, v_sel, idx, length)


def fused_fier_attention_decode(
    q: jax.Array,
    K: jax.Array,
    V: jax.Array,
    qk: QuantizedKeys,
    budget: int,
    length: jax.Array | None = None,
    *,
    group_reduce: str = "max",
    sink: int = 0,
    recent: int = 0,
    blk_k: int = 1024,
    one_pass: bool = True,
) -> jax.Array:
    """Deprecated: ``fier_decode_one_pass`` / ``fier_decode_two_pass`` on
    a slab ``CacheView`` (or ``policy.decode_attention`` with a plan)."""
    _warn_deprecated(
        "kernels.ops.fused_fier_attention_decode",
        "kernels.ops.fier_decode_one_pass / fier_decode_two_pass, or "
        "policy.decode_attention(q, view, plan)",
    )
    view = CacheView.slab(K, V, qk, length)
    fn = fier_decode_one_pass if one_pass else fier_decode_two_pass
    return fn(
        q, view, budget, group_reduce=group_reduce, sink=sink, recent=recent,
        blk_k=blk_k,
    )


# ------------------------------------------------- deprecated paged variants

def paged_fused_retrieve(
    q: jax.Array,
    meta: QuantizedKeys,
    block_table: jax.Array,
    budget: int,
    length: jax.Array | None = None,
    *,
    group_reduce: str = "max",
    sink: int = 0,
    recent: int = 0,
    return_stats: bool = False,
):
    """Deprecated: ``retrieve(q, view, budget, ...)`` on a paged view."""
    _warn_deprecated(
        "kernels.ops.paged_fused_retrieve",
        "kernels.ops.retrieve(q, CacheView.paged(..., meta, block_table, length), budget)",
    )
    view = CacheView.paged(None, None, meta, block_table, length)
    return retrieve(
        q, view, budget, group_reduce=group_reduce, sink=sink, recent=recent,
        return_stats=return_stats,
    )


def paged_fused_sparse_attention(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_table: jax.Array,
    idx: jax.Array,
    length: jax.Array | None,
    *,
    blk_k: int = 1024,
) -> jax.Array:
    """Deprecated: ``attend_selected`` on a paged view."""
    _warn_deprecated(
        "kernels.ops.paged_fused_sparse_attention",
        "kernels.ops.attend_selected(q, CacheView.paged(k, v, None, block_table, length), idx)",
    )
    view = CacheView.paged(k_pool, v_pool, None, block_table, length)
    return attend_selected(q, view, idx, blk_k=blk_k)


def paged_fused_fier_attention_decode(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    meta: QuantizedKeys,
    block_table: jax.Array,
    budget: int,
    length: jax.Array | None = None,
    *,
    group_reduce: str = "max",
    sink: int = 0,
    recent: int = 0,
    blk_k: int = 1024,
) -> jax.Array:
    """Deprecated: ``fier_decode_one_pass`` on a paged ``CacheView`` (or
    ``policy.decode_attention`` with a paged plan)."""
    _warn_deprecated(
        "kernels.ops.paged_fused_fier_attention_decode",
        "kernels.ops.fier_decode_one_pass(q, CacheView.paged(...), budget) "
        "or policy.decode_attention(q, view, plan)",
    )
    view = CacheView.paged(k_pool, v_pool, meta, block_table, length)
    return fier_decode_one_pass(
        q, view, budget, group_reduce=group_reduce, sink=sink, recent=recent,
        blk_k=blk_k,
    )
