"""Pallas TPU kernel: one-pass fused retrieval — the decode selection stage
with per-token scores that never touch HBM.

The two-pass pipeline (PR 1) still materialises the f32 approximate-score
tensors in HBM between its kernels: ``fier_score`` writes ``[B, Hq, S]``
(4·Hq·S bytes), XLA reads it back for the GQA group-reduce and writes
``[B, Hkv, S]``, and ``topk_select`` reads that again.  At S = 128k this
round trip (≥ 2·4·Hq·S bytes per layer per step) rivals the packed-code
read itself — the same recall-side traffic FreeKV (arXiv 2505.13109)
identifies as the dominant retrieval cost at scale.

This kernel fuses the whole retrieval stage into one ``pallas_call``:

  * the packed 1-bit codes (and the bf16 group scale/zero side-car) are
    bound with ``memory_space=ANY`` and streamed HBM→VMEM block-by-block
    with double-buffered async DMA (the next block's three copies are in
    flight while the current block is scored);
  * each block is scored in VREGs with the *exact* expression of the
    score-scan kernel (``fier_score.score_block`` — bit-identical f32
    scores), group-reduced over the query group (``max``/``sum``) and
    masked (``length``/``sink``/``recent``) in-register;
  * the masked block scores are reinterpreted as monotone uint32 keys
    (``topk_select``'s trick: float order == unsigned order) and drive an
    exact radix-histogram search for τ, the budget-th largest key —
    ``NPASS`` = 4 sweeps over the code blocks, each accumulating a
    256-bucket histogram of the next 8 key bits among the keys matching
    the prefix found so far;
  * a final sweep re-scores the blocks and compacts the selected indices
    { key > τ } ∪ first (budget − m) ties in ascending position order —
    the same index *set* ``lax.top_k`` returns on the same scores.

Per-token state in HBM: none.  The score tensors simply never exist as
arrays — each block's scores live in VREGs for the duration of one fold
step.  The only outputs are the index set ``[BH, budget]`` and the
(lane-padded) τ/m scalars.

Cost: NPASS + 1 = 5 streaming sweeps over the packed codes.  The codes
are 1/16 of the bf16 key bytes (Eq. 8), so five sweeps ≈ 0.31× the key
bytes — still far below the 2·4·Hq·S score-tensor round trip the fusion
removes (at Hq = 32, D = 128: score round trip ≈ 256·S bytes vs
5·codes = 80·S bytes per batch row, and the gap widens with Hq).

VMEM per step: 2 double-buffer slots of (codes + scale + zero) block ≈
2·(blk_s·D/8 + 2·(blk_s/g)·D·2) bytes — 48 KiB at blk_s = 512, D = 128,
g = 32 — plus the [1, budget] index block.  Grid: (B·Hkv,).

Interpret-mode notes (CPU CI runs the exact kernel code): the index
compaction uses a bounded ``.at[].set(mode="drop")`` scatter on a
VREG-resident [budget] vector (never a sort), and the histogram is a
blockwise one-hot reduction — both stay on-chip on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.retrieval import NEG_INF

from .fier_score import score_block
from .topk_select import LANE, _sortable_keys, _unsortable

NPASS = 4    # radix-histogram passes: 8 bits of the uint32 keys per pass
RADIX = 256  # buckets per pass


def _threshold_select(sweep, budget: int, idx_ref, tau_ref, m_ref):
    """Radix-histogram τ search + tie-aware index compaction over a
    ``sweep(fold, init)`` abstraction that folds over (keys, pos) blocks.

    Shared verbatim by the contiguous (slab) and the page-table-aware
    retrieval kernels: both produce per-block monotone-uint32 keys of the
    masked kv scores; only the *addressing* of the code stream differs.
    Writes the selected index set, τ, and the strictly-greater count to
    the (lane-padded) output refs.
    """
    # ---- phase 1: radix-histogram search for τ (the budget-th largest key)
    def radix_pass(p, carry):
        t, remaining, greater = carry
        pw = p.astype(jnp.uint32)
        shift = jnp.uint32(24) - jnp.uint32(8) * pw
        # participation: keys matching the 8p prefix bits found so far
        # (p = 0: everyone; the clamp keeps the dead branch's shift < 32)
        himask = jnp.where(
            p == 0,
            jnp.uint32(0),
            jnp.uint32(0xFFFFFFFF)
            << jnp.minimum(jnp.uint32(32) - jnp.uint32(8) * pw, jnp.uint32(31)),
        )

        def fold(keys, pos, hist):
            blk = keys.shape[1]
            part = (keys & himask) == t                     # [1, blk]
            digit = ((keys >> shift) & jnp.uint32(0xFF)).astype(jnp.int32)
            onehot = (
                digit[0][:, None]
                == jax.lax.broadcasted_iota(jnp.int32, (blk, RADIX), 1)
            ) & part[0][:, None]
            return hist + onehot.astype(jnp.int32).sum(axis=0)[None, :]

        hist = sweep(fold, jnp.zeros((1, RADIX), jnp.int32))
        ge = jnp.cumsum(hist[:, ::-1], axis=1)[:, ::-1]     # count(digit ≥ j)
        iota = jax.lax.broadcasted_iota(jnp.int32, (1, RADIX), 1)
        # τ's digit: the highest bucket where the ≥-count reaches `remaining`
        jstar = jnp.max(jnp.where(ge >= remaining, iota, -1))
        above = jnp.sum(jnp.where(iota > jstar, hist, 0))
        t = t | (jstar.astype(jnp.uint32) << shift)
        return t, remaining - above, greater + above

    tau_key, _, m = jax.lax.fori_loop(
        0, NPASS, radix_pass,
        (jnp.uint32(0), jnp.int32(budget), jnp.int32(0)),
    )
    # m = |{ key > τ }| exactly: every strictly-greater key is counted at
    # the first radix pass where its digit exceeds τ's (it matches the
    # prefix up to that pass), and never again after it stops matching.

    # ---- phase 2: re-score and compact { key > τ } ∪ first (budget−m) ties
    def compact_fold(keys, pos, carry):
        ngt, ntie, out = carry
        gt = (keys > tau_key)[0]                            # [blk]
        tie = (keys == tau_key)[0]
        cgt = jnp.cumsum(gt.astype(jnp.int32))
        ctie = jnp.cumsum(tie.astype(jnp.int32))
        take_tie = tie & (ntie + ctie <= budget - m)
        dest = jnp.where(
            gt, ngt + cgt - 1,
            jnp.where(take_tie, m + ntie + ctie - 1, budget),
        )
        # bounded scatter by rank: >τ fill [0, m) in ascending position,
        # taken ties fill [m, budget); dest == budget is dropped
        out = out.at[dest].set(pos[0], mode="drop")
        return ngt + cgt[-1], ntie + ctie[-1], out

    _, _, out = sweep(
        compact_fold,
        (jnp.int32(0), jnp.int32(0), jnp.zeros((budget,), jnp.int32)),
    )
    idx_ref[...] = out.reshape(idx_ref.shape)
    tau_ref[...] = jnp.full(tau_ref.shape, _unsortable(tau_key), jnp.float32)
    m_ref[...] = jnp.full(m_ref.shape, m, jnp.int32)


def _masked_block_keys(s, i, blk_s, length, sink, recent, group_reduce):
    """Group-reduce + mask one scored block and lift to monotone keys.

    s [rep, blk_s] f32 (VREG-resident scores) → (keys uint32 [1, blk_s],
    pos int32 [1, blk_s]).  Shared by the slab and paged kernels so the
    masking arithmetic is identical bit for bit.
    """
    if group_reduce == "max":
        kv = s.max(axis=0, keepdims=True)                   # [1, blk_s]
    else:
        kv = s.sum(axis=0, keepdims=True)
    pos = i * blk_s + jax.lax.broadcasted_iota(jnp.int32, (1, blk_s), 1)
    kv = jnp.where(pos < length, kv, NEG_INF)
    if sink > 0:
        kv = jnp.where(pos < sink, jnp.inf, kv)
    if recent > 0:
        is_recent = (pos >= length - recent) & (pos < length)
        kv = jnp.where(is_recent, jnp.inf, kv)
    return _sortable_keys(kv), pos


def _kernel(
    len_ref, q_ref, codes_hbm, scale_hbm, zero_hbm,
    idx_ref, tau_ref, m_ref,
    codes_v, scale_v, zero_v, sems, *,
    budget: int, group: int, blk_s: int, group_reduce: str,
    sink: int, recent: int, S: int,
):
    """One (batch·kv-head) row of one-pass retrieval.

    len_ref [1] int32 (SMEM); q_ref [rep, D]; codes/scale/zero: whole
    head-major slabs [BH, S/8|S/g, D] in ANY space (DMA'd blockwise);
    idx_ref [1, budget] int32; tau_ref [1, LANE] f32; m_ref [1, LANE]
    int32; codes_v/scale_v/zero_v: [2, ...] double-buffer scratch;
    sems [2, 3] DMA semaphores (slot × operand).
    """
    b = pl.program_id(0)
    nb = S // blk_s
    n8 = blk_s // 8
    ng = blk_s // group
    length = len_ref[0]
    qbf = q_ref[...].astype(jnp.bfloat16)

    def block_copies(i, slot):
        """The three HBM→VMEM copy descriptors for code block i."""
        return (
            pltpu.make_async_copy(
                codes_hbm.at[b, pl.ds(i * n8, n8), :],
                codes_v.at[slot], sems.at[slot, 0],
            ),
            pltpu.make_async_copy(
                scale_hbm.at[b, pl.ds(i * ng, ng), :],
                scale_v.at[slot], sems.at[slot, 1],
            ),
            pltpu.make_async_copy(
                zero_hbm.at[b, pl.ds(i * ng, ng), :],
                zero_v.at[slot], sems.at[slot, 2],
            ),
        )

    def start_block(i):
        for cp in block_copies(i, jax.lax.rem(i, 2)):
            cp.start()

    def wait_block(i):
        for cp in block_copies(i, jax.lax.rem(i, 2)):
            cp.wait()

    def block_keys(i):
        """Monotone-uint32 keys of block i's masked kv scores: [1, blk_s].

        Scores exist only here, in VREGs, for the duration of one fold.
        """
        slot = jax.lax.rem(i, 2)
        s = score_block(
            qbf, codes_v[slot], scale_v[slot], zero_v[slot], group=group
        )                                                   # [rep, blk_s]
        return _masked_block_keys(s, i, blk_s, length, sink, recent, group_reduce)

    def sweep(fold, init):
        """fold(keys, pos, carry) over all code blocks, next block's DMA
        in flight while the current block is scored."""
        start_block(0)

        def body(i, carry):
            @pl.when(i + 1 < nb)
            def _prefetch():
                start_block(i + 1)

            wait_block(i)
            keys, pos = block_keys(i)
            return fold(keys, pos, carry)

        return jax.lax.fori_loop(0, nb, body, init)

    _threshold_select(sweep, budget, idx_ref, tau_ref, m_ref)


@functools.partial(
    jax.jit,
    static_argnames=(
        "budget", "group", "blk_s", "group_reduce", "sink", "recent",
        "interpret",
    ),
)
def fused_retrieve_hm(
    q: jax.Array,
    codes: jax.Array,
    scale: jax.Array,
    zero: jax.Array,
    lengths: jax.Array,
    budget: int,
    *,
    group: int,
    blk_s: int = 512,
    group_reduce: str = "max",
    sink: int = 0,
    recent: int = 0,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Head-major one-pass retrieval.

    q [BH, rep, D]; codes [BH, S/8, D] uint8; scale/zero [BH, S/g, D];
    lengths [BH] int32 → (idx int32 [BH, budget], tau f32 [BH],
    m int32 [BH]).  The index *set* equals ``lax.top_k`` over the masked,
    group-reduced ``fier_score`` scores; tau is the budget-th largest
    masked score and m the strictly-greater count.
    """
    BH, rep, D = q.shape
    S = codes.shape[1] * 8
    assert 0 < budget <= S, (budget, S)
    if group_reduce not in ("max", "sum"):
        raise ValueError(f"unknown group reduction {group_reduce!r}")
    blk = min(blk_s, S)
    while S % blk:
        blk //= 2
    assert blk % 8 == 0 and blk % group == 0, (blk, group)
    idx, tau, m = pl.pallas_call(
        functools.partial(
            _kernel, budget=budget, group=group, blk_s=blk,
            group_reduce=group_reduce, sink=sink, recent=recent, S=S,
        ),
        grid=(BH,),
        in_specs=[
            pl.BlockSpec((None, 1), lambda b: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((None, rep, D), lambda b: (b, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec((1, budget), lambda b: (b, 0)),
            pl.BlockSpec((1, LANE), lambda b: (b, 0)),
            pl.BlockSpec((1, LANE), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, budget), jnp.int32),
            jax.ShapeDtypeStruct((BH, LANE), jnp.float32),
            jax.ShapeDtypeStruct((BH, LANE), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, blk // 8, D), jnp.uint8),
            pltpu.VMEM((2, blk // group, D), scale.dtype),
            pltpu.VMEM((2, blk // group, D), zero.dtype),
            pltpu.SemaphoreType.DMA((2, 3)),
        ],
        interpret=interpret,
    )(lengths[:, None], q, codes, scale, zero)
    return idx, tau[:, 0], m[:, 0]


# ------------------------------------------------------- page-table variant

def _paged_kernel(
    bt_ref, len_ref, q_ref, codes_hbm, scale_hbm, zero_hbm,
    idx_ref, tau_ref, m_ref,
    codes_v, scale_v, zero_v, sems, *,
    budget: int, group: int, block_size: int, group_reduce: str,
    sink: int, recent: int, n_btab: int,
):
    """One (batch, kv-head) row of one-pass retrieval over a *paged* pool.

    bt_ref [n_btab] int32 (SMEM) — this request's block table row;
    len_ref [1] int32 (SMEM); q_ref [rep, D]; codes/scale/zero: whole
    paged side-car pools [N, bs/8|bs/g, Hkv, D] in ANY space; outputs and
    scratch as in the contiguous kernel.  The per-row DMA stream walks
    ``block_table[b]`` instead of a contiguous slab: logical code block
    ``i`` is fetched from pool row ``bt[i]`` (unallocated entries point
    at the null block, whose garbage scores are masked by ``length``).
    The scoring block size *is* the cache block size, so the selected
    indices are logical token positions ``i·bs + offset`` — τ search and
    compaction are shared verbatim with the slab kernel.
    """
    h = pl.program_id(1)
    bs = block_size
    n8 = bs // 8
    ng = bs // group
    length = len_ref[0]
    qbf = q_ref[...].astype(jnp.bfloat16)

    def block_copies(i, slot):
        phys = bt_ref[i]
        return (
            pltpu.make_async_copy(
                codes_hbm.at[phys, :, h, :], codes_v.at[slot], sems.at[slot, 0]
            ),
            pltpu.make_async_copy(
                scale_hbm.at[phys, :, h, :], scale_v.at[slot], sems.at[slot, 1]
            ),
            pltpu.make_async_copy(
                zero_hbm.at[phys, :, h, :], zero_v.at[slot], sems.at[slot, 2]
            ),
        )

    def start_block(i):
        for cp in block_copies(i, jax.lax.rem(i, 2)):
            cp.start()

    def wait_block(i):
        for cp in block_copies(i, jax.lax.rem(i, 2)):
            cp.wait()

    def block_keys(i):
        slot = jax.lax.rem(i, 2)
        s = score_block(
            qbf, codes_v[slot], scale_v[slot], zero_v[slot], group=group
        )                                                   # [rep, bs]
        return _masked_block_keys(s, i, bs, length, sink, recent, group_reduce)

    def sweep(fold, init):
        start_block(0)

        def body(i, carry):
            @pl.when(i + 1 < n_btab)
            def _prefetch():
                start_block(i + 1)

            wait_block(i)
            keys, pos = block_keys(i)
            return fold(keys, pos, carry)

        return jax.lax.fori_loop(0, n_btab, body, init)

    _threshold_select(sweep, budget, idx_ref, tau_ref, m_ref)


@functools.partial(
    jax.jit,
    static_argnames=(
        "budget", "group", "block_size", "group_reduce", "sink", "recent",
        "interpret",
    ),
)
def paged_fused_retrieve_hm(
    q: jax.Array,
    codes: jax.Array,
    scale: jax.Array,
    zero: jax.Array,
    block_table: jax.Array,
    lengths: jax.Array,
    budget: int,
    *,
    group: int,
    block_size: int,
    group_reduce: str = "max",
    sink: int = 0,
    recent: int = 0,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Page-table-aware one-pass retrieval.

    q [B, Hkv, rep, D]; codes [N, bs/8, Hkv, D] uint8; scale/zero
    [N, bs/g, Hkv, D]; block_table [B, n_btab] int32; lengths [B] int32 →
    (idx int32 [B, Hkv, budget], tau f32 [B, Hkv], m int32 [B, Hkv]).

    Returns the exact index set / τ / m of ``fused_retrieve_hm`` on the
    logical (table-gathered) cache contents: scores are computed by the
    same ``score_block`` at per-token granularity, so values — hence keys,
    τ, and the compacted index order — are bit-identical to the slab
    kernel's.  Per-token score state in HBM: none, as in the slab kernel.
    """
    B, Hkv, rep, D = q.shape
    n_btab = block_table.shape[1]
    S = n_btab * block_size
    assert 0 < budget <= S, (budget, S)
    assert codes.shape[1] * 8 == block_size, (codes.shape, block_size)
    if group_reduce not in ("max", "sum"):
        raise ValueError(f"unknown group reduction {group_reduce!r}")
    idx, tau, m = pl.pallas_call(
        functools.partial(
            _paged_kernel, budget=budget, group=group, block_size=block_size,
            group_reduce=group_reduce, sink=sink, recent=recent, n_btab=n_btab,
        ),
        grid=(B, Hkv),
        in_specs=[
            pl.BlockSpec(
                (None, n_btab), lambda b, h: (b, 0), memory_space=pltpu.SMEM
            ),
            pl.BlockSpec((None, 1), lambda b, h: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((None, None, rep, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec((None, None, budget), lambda b, h: (b, h, 0)),
            pl.BlockSpec((None, None, LANE), lambda b, h: (b, h, 0)),
            pl.BlockSpec((None, None, LANE), lambda b, h: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, budget), jnp.int32),
            jax.ShapeDtypeStruct((B, Hkv, LANE), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, LANE), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, block_size // 8, D), jnp.uint8),
            pltpu.VMEM((2, block_size // group, D), scale.dtype),
            pltpu.VMEM((2, block_size // group, D), zero.dtype),
            pltpu.SemaphoreType.DMA((2, 3)),
        ],
        interpret=interpret,
    )(block_table, lengths[:, None], q, codes, scale, zero)
    return idx, tau[:, :, 0], m[:, :, 0]
