"""Pallas TPU kernel: FIER 1-bit approximate score scan (the decode hot spot).

The paper's Triton kernel reads 1-bit quantized keys and computes
approximate attention scores.  TPU adaptation (DESIGN.md §2): the win is
HBM *bytes*, not popcount arithmetic — packed codes stream HBM→VMEM at
1/16 the bf16 key bytes, unpack to ±1 inside VREGs, and the MXU computes
the two small matmuls

    s̃[t, r] = Σ_d (codes±1[t,d] · s[t,d]) · q[r,d]  +  Σ_d z[t,d] · q[r,d]

with the group-broadcast of (s, z) done in-register (scale/zero add
2·16/g bits per weight bit — Eq. 8's load ratio, measured exactly in
bench_load_ratio).

Layout: the kernel works on head-major views [B, Hkv, ...] so the seq
scan is the innermost contiguous stream; ``ops.fier_score`` adapts from
the seq-major cache layout.

Grid: (B·Hkv, S/blk_s).  VMEM per step ≈ blk_s·D/8 (codes) +
2·(blk_s/g)·D·2 (s,z) + rep·D·4 (q) + blk_s·rep·4 (out) bytes —
blk_s=512, D=128, g=32: 8 KiB + 16 KiB + ~4 KiB + 16·rep KiB ≪ VMEM;
block shapes are (8,128)-aligned for the VPU/MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def score_block(
    qbf: jax.Array,
    codes: jax.Array,
    scale: jax.Array,
    zero: jax.Array,
    *,
    group: int,
) -> jax.Array:
    """Score one seq block of packed codes against a kv head's query group.

    qbf [rep, D] bf16; codes [blk_s/8, D] uint8; scale/zero [blk_s/g, D]
    → f32 [rep, blk_s].

    bf16 operands, f32 MXU accumulation (±1 and the stored (s, z) are
    exact in bf16).  Shared by the score-scan kernel and the one-pass
    fused-retrieval kernel so their per-token scores are *bit-identical*
    — the one-pass index set is validated exactly against
    select-over-``fier_score``, which only holds if both paths evaluate
    the same expression at the same shapes.
    """
    n8, D = codes.shape
    blk_s = n8 * 8
    # unpack: bit t of byte i is token 8i+t
    shifts = jax.lax.broadcasted_iota(jnp.uint8, (n8, 8, D), 1)
    bits = (codes[:, None, :] >> shifts) & jnp.uint8(1)
    pm1 = bits.reshape(blk_s, D).astype(jnp.bfloat16) * 2.0 - 1.0

    ng = scale.shape[0]
    scale_b = jnp.broadcast_to(
        scale.astype(jnp.bfloat16)[:, None, :], (ng, group, D)
    ).reshape(blk_s, D)
    zero_b = jnp.broadcast_to(
        zero.astype(jnp.bfloat16)[:, None, :], (ng, group, D)
    ).reshape(blk_s, D)

    a = pm1 * scale_b + zero_b           # = dequantized keys, in-register
    return jax.lax.dot_general(
        qbf, a, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


def _kernel(q_ref, codes_ref, scale_ref, zero_ref, out_ref, *, group: int):
    """One (batch·kv-head, seq-block) step.

    q_ref:     [rep, D]       f32/bf16 — queries of this kv head's group
    codes_ref: [blk_s/8, D]   uint8 packed sign bits (seq-major bit order)
    scale_ref: [blk_s/g, D]   bf16 group scales
    zero_ref:  [blk_s/g, D]   bf16 group zeros
    out_ref:   [rep, blk_s]   f32 scores
    """
    out_ref[...] = score_block(
        q_ref[...].astype(jnp.bfloat16), codes_ref[...], scale_ref[...],
        zero_ref[...], group=group,
    )


@functools.partial(jax.jit, static_argnames=("group", "blk_s", "interpret"))
def fier_score_hm(
    q: jax.Array,
    codes: jax.Array,
    scale: jax.Array,
    zero: jax.Array,
    *,
    group: int,
    blk_s: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Head-major score scan.

    q [BH, rep, D], codes [BH, S/8, D] uint8, scale/zero [BH, S/g, D]
    → scores f32 [BH, rep, S].
    """
    BH, rep, D = q.shape
    S = codes.shape[1] * 8
    blk_s = min(blk_s, S)
    assert S % blk_s == 0 and blk_s % group == 0 and blk_s % 8 == 0
    grid = (BH, S // blk_s)
    return pl.pallas_call(
        functools.partial(_kernel, group=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, rep, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, blk_s // 8, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, blk_s // group, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, blk_s // group, D), lambda b, i: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((None, rep, blk_s), lambda b, i: (b, 0, i)),
        out_shape=jax.ShapeDtypeStruct((BH, rep, S), jnp.float32),
        interpret=interpret,
    )(q, codes, scale, zero)
