"""Pallas TPU kernel: prefill-time 1-bit group quantize + bit-pack.

One pass over the key slab: per (seq-group, channel) min/max → (scale,
zero), sign-compare, pack 8 seq-consecutive bits per byte.  Runs once per
prefill (and per appended block at decode via the incremental update), so
it is bandwidth-bound on reading K — the kernel streams [blk_s, D] tiles.

Grid: (B·Hkv, S/blk_s); blk_s a multiple of the group size g (group stats
never straddle blocks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(k_ref, codes_ref, scale_ref, zero_ref, *, group: int):
    """k [blk_s, D] → codes [blk_s/8, D] u8, scale/zero [blk_s/g, D] bf16."""
    k = k_ref[...].astype(jnp.float32)
    blk_s, D = k.shape
    ng = blk_s // group
    kg = k.reshape(ng, group, D)
    kmax = kg.max(axis=1)
    kmin = kg.min(axis=1)
    zero = (kmax + kmin) * 0.5
    scale = (kmax - kmin) * 0.5
    # compare against the *stored* (bf16-rounded) zero so codes match what
    # the score scan will dequantize with (and the jnp oracle)
    zb = zero.astype(jnp.bfloat16).astype(jnp.float32)
    zfull = jnp.broadcast_to(zb[:, None, :], (ng, group, D)).reshape(blk_s, D)
    bits = (k >= zfull).astype(jnp.uint8)
    shifts = jax.lax.broadcasted_iota(jnp.uint8, (blk_s // 8, 8, D), 1)
    packed = jnp.sum(bits.reshape(blk_s // 8, 8, D) << shifts, axis=1)
    codes_ref[...] = packed.astype(jnp.uint8)
    scale_ref[...] = scale.astype(jnp.bfloat16)
    zero_ref[...] = zero.astype(jnp.bfloat16)


@functools.partial(jax.jit, static_argnames=("group", "blk_s", "interpret"))
def pack_quantize_hm(
    k: jax.Array, *, group: int, blk_s: int = 512, interpret: bool = True
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Head-major quantize+pack: k [BH, S, D] → (codes [BH,S/8,D] u8,
    scale [BH,S/g,D] bf16, zero [BH,S/g,D] bf16)."""
    BH, S, D = k.shape
    blk_s = min(blk_s, S)
    assert S % blk_s == 0 and blk_s % group == 0 and blk_s % 8 == 0
    grid = (BH, S // blk_s)
    return pl.pallas_call(
        functools.partial(_kernel, group=group),
        grid=grid,
        in_specs=[pl.BlockSpec((None, blk_s, D), lambda b, i: (b, i, 0))],
        out_specs=[
            pl.BlockSpec((None, blk_s // 8, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, blk_s // group, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, blk_s // group, D), lambda b, i: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S // 8, D), jnp.uint8),
            jax.ShapeDtypeStruct((BH, S // group, D), jnp.bfloat16),
            jax.ShapeDtypeStruct((BH, S // group, D), jnp.bfloat16),
        ],
        interpret=interpret,
    )(k)
