"""Production meshes.  Functions, not module constants — importing this
module never touches jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* first init).

Topology: TPU v5e, 16×16 = 256 chips per pod; 2 pods = 512 chips over DCN.
Axis meanings:
    pod    — data parallel across pods (gradient all-reduce over DCN)
    data   — data parallel / FSDP within a pod
    model  — tensor/expert parallel + decode-time KV sequence sharding
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Whatever devices exist (tests / single host): (data, model)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def fsdp_axes(mesh, param_bytes: float) -> tuple[str, ...]:
    """FSDP policy: everything shards over 'data'; >50 GB param trees also
    shard over 'pod' (ZeRO-3 across pods, paid in inter-pod all-gathers —
    quantified in EXPERIMENTS.md §Roofline)."""
    if param_bytes > 50e9 and "pod" in mesh.axis_names:
        return ("pod", "data")
    return ("data",)
