"""train_step / serve_step builders — the functions the launcher jits and
the dry-run lowers."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.model_zoo import ModelBundle
from repro.optim import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    compress_decompress,
    cosine_schedule,
    ef_state_init,
    wsd_schedule,
)


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"          # cosine | wsd (minicpm)
    grad_clip: float = 1.0
    weight_decay: float = 0.1
    compress_grads: bool = False      # 1-bit error-feedback (beyond-paper)
    microbatches: int = 1             # gradient accumulation (memory / step)
    accum_dtype: str = "float32"      # grad accumulator (bf16 for 100B+ cells)


def make_schedule(hp: TrainHParams) -> Callable:
    if hp.schedule == "wsd":
        return partial(
            wsd_schedule, peak_lr=hp.peak_lr, warmup=hp.warmup, total=hp.total_steps
        )
    return partial(
        cosine_schedule, peak_lr=hp.peak_lr, warmup=hp.warmup, total=hp.total_steps
    )


def make_train_step(bundle: ModelBundle, hp: TrainHParams) -> Callable:
    """(state, batch) → (state, metrics);
    state = {params, opt, ef?} — a single pytree so checkpointing and
    recovery handle one object."""
    sched = make_schedule(hp)

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        if hp.microbatches > 1:
            # gradient accumulation: scan over microbatches — activations
            # and attention/MoE transients shrink by ×microbatches
            n = hp.microbatches
            adt = jnp.bfloat16 if hp.accum_dtype == "bfloat16" else jnp.float32
            mb = jax.tree.map(
                lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch
            )

            def mb_body(acc, b):
                (l, m), g = jax.value_and_grad(bundle.train_loss, has_aux=True)(
                    params, b
                )
                acc = jax.tree.map(lambda a, x: a + x.astype(adt), acc, g)
                return acc, (l, m)

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
            grads, (losses, ms) = jax.lax.scan(mb_body, zeros, mb)
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = losses.mean()
            metrics = jax.tree.map(lambda x: x.mean(axis=0), ms)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                bundle.train_loss, has_aux=True
            )(params, batch)
        grads, gnorm = clip_by_global_norm(grads, hp.grad_clip)
        if hp.compress_grads:
            grads, ef = compress_decompress(grads, state["ef"])
        lr = sched(opt.step)
        params, opt = adamw_update(
            grads, opt, params, lr, weight_decay=hp.weight_decay
        )
        new_state = dict(state, params=params, opt=opt)
        if hp.compress_grads:
            new_state["ef"] = ef
        metrics = dict(metrics, grad_norm=gnorm, lr=lr, total=loss)
        return new_state, metrics

    return train_step


def init_train_state(bundle: ModelBundle, rng, hp: TrainHParams) -> dict:
    params = bundle.init(rng)
    state = {"params": params, "opt": adamw_init(params)}
    if hp.compress_grads:
        state["ef"] = ef_state_init(params)
    return state


def make_serve_step(bundle: ModelBundle) -> Callable:
    """(params, token [B], cache) → (logits, cache) — the decode hot loop."""
    return bundle.decode_step


def make_prefill_step(bundle: ModelBundle, capacity: int) -> Callable:
    return partial(bundle.prefill, capacity=capacity)
