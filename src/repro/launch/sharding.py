"""Sharding plan: param/optimizer/cache/batch PartitionSpecs by tree path.

Megatron-style TP on the flattened head·d_head / d_ff / padded-vocab dims
over 'model'; FSDP (ZeRO-3) over 'data' (+'pod' for ≥50 GB trees); MoE
experts over 'model' (EP); decode KV caches sharded over batch×sequence
(the distributed-FIER axes).  Rules match on path substrings and apply to
the *trailing* dims, so layer-stacked ([L, ...]) and superblock-stacked
([n_apps, E, ...]) params resolve automatically.

Divisibility: vocab is padded to 256 (configs.padded_vocab); all model
dims in the assigned archs divide the 16-way model axis on their
*flattened* projections (verified in tests/test_sharding.py) — per-head
reshapes for non-divisible head counts (minicpm 36H, whisper 12H) are
left to GSPMD, which inserts resharding there (visible in the roofline
collective term; see DESIGN.md §4).
"""
from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# rule: (path regex, trailing-dims spec builder given (fsdp, model))
_RULES: list[tuple[str, Any]] = [
    (r"moe/w1$|moe/w3$", lambda f, m: (m, f, None)),   # [E, d, ff] → EP
    (r"moe/w2$", lambda f, m: (m, None, f)),           # [E, ff, d]
    (r"moe/router$", lambda f, m: (None, None)),
    (r"embed$", lambda f, m: (m, f)),                  # [Vp, d]
    (r"lm_head$", lambda f, m: (f, m)),                # [d, Vp]
    (r"pos_dec$", lambda f, m: (None, f)),
    (r"wq$|wk$|wv$", lambda f, m: (f, m)),             # [d, H·Dh]
    (r"wo$", lambda f, m: (m, f)),                     # [H·Dh, d]
    (r"w1$|w3$", lambda f, m: (f, m)),                 # [d, ff]
    (r"w2$", lambda f, m: (m, f)),                     # [ff, d]
    (r"bq$|bk$|bv$", lambda f, m: (m,)),
    (r"in_proj$", lambda f, m: (f, m)),                # [d, 2di+2N+H]
    (r"out_proj$", lambda f, m: (m, f)),               # [di, d]
    (r"conv_w$|conv_b$", lambda f, m: None),           # small, replicate
    (r"norm_w$|A_log$|D$|dt_bias$", lambda f, m: None),
]


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def param_pspec(path_str: str, ndim: int, fsdp, model: str = "model") -> P:
    f = fsdp if fsdp else None
    for pat, builder in _RULES:
        if re.search(pat, path_str):
            tail = builder(f, model)
            if tail is None:
                return P()
            pad = ndim - len(tail)
            if pad < 0:  # param smaller than rule (e.g. un-stacked bias)
                tail = tail[-ndim:]
                pad = 0
            return P(*([None] * pad + list(tail)))
    return P()  # norms, scalars → replicated


def param_shardings(
    params_shape: Any,
    mesh: Mesh,
    fsdp: tuple[str, ...] | None,
    strategy: str = "tp",
) -> Any:
    """Pytree of NamedShardings matching a params shape-tree.

    strategy="tp": Megatron TP over 'model' + FSDP over ``fsdp`` (default).
    strategy="fsdp_pure": no tensor parallelism — every ≥2D param shards
    its first divisible dim over ALL of ``fsdp`` (ZeRO-3); batch then
    spans the whole mesh.  §Perf iteration 9: for ≤8B dense archs this
    trades per-layer TP/SP collectives for one weight all-gather."""
    f = tuple(fsdp) if fsdp else None

    if strategy == "fsdp_pure":
        n = 1
        axis_sizes = dict(zip(mesh.axis_names, mesh.shape.values() if hasattr(mesh.shape, "values") else mesh.axis_sizes))
        for a in f or ():
            n *= axis_sizes[a]

        def one_fsdp(path, leaf):
            if f and len(leaf.shape) >= 2:
                for dim, d in enumerate(leaf.shape):
                    if d % n == 0 and d >= n:
                        spec = [None] * len(leaf.shape)
                        spec[dim] = f
                        return NamedSharding(mesh, P(*spec))
            return NamedSharding(mesh, P())

        return jax.tree_util.tree_map_with_path(one_fsdp, params_shape)

    def one(path, leaf):
        spec = param_pspec(_path_str(path), len(leaf.shape), f)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_shardings(opt_shape: Any, params_sh: Any, mesh: Mesh) -> Any:
    """AdamW moments shard exactly like their params; step is replicated."""
    params_flat = jax.tree_util.tree_leaves(params_sh)

    def build(tree):
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        return jax.tree_util.tree_unflatten(treedef, params_flat[: len(leaves)])

    from repro.optim.adamw import AdamWState

    return AdamWState(
        step=NamedSharding(mesh, P()),
        mu=build(opt_shape.mu),
        nu=build(opt_shape.nu),
    )


# ------------------------------------------------------------ cache / batch

def cache_batch_axes(init_cache, capacity: int | None = None) -> Any:
    """Discover every cache leaf's batch-axis index by shape-diffing
    ``init_cache`` at two batch sizes (same trick as serving.Engine).

    The probe capacity must satisfy the bundle plan's capacity validation
    (budget <= capacity, block divisibility).  Pass the cell's real
    capacity when known; with ``capacity=None`` the probe grows a dummy
    capacity until validation accepts it (shape-only ``eval_shape``, so
    over-sizing costs nothing).  The odd multipliers cover paged block
    sizes with an odd factor (24, 40, 48, …), which no power of two
    divides."""
    if capacity is not None:
        caps = [capacity]
    else:
        caps = [b * m for b in (64, 1024, 8192, 1 << 20) for m in (1, 3, 5, 7)]
    err = None
    for cap in caps:
        try:
            c2 = jax.eval_shape(lambda: init_cache(2, cap, 0))
            c3 = jax.eval_shape(lambda: init_cache(3, cap, 0))
            break
        except ValueError as e:
            err = e
    else:
        raise err

    def axis(a, b):
        diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
        if len(diffs) != 1:
            raise ValueError(f"ambiguous batch axis: {a.shape} vs {b.shape}")
        return diffs[0]

    return jax.tree.map(axis, c2, c3)


def cache_shardings(
    cache_shape: Any,
    mesh: Mesh,
    batch: tuple[str, ...],
    seq: tuple[str, ...],
    batch_axis_tree: Any,
) -> Any:
    """Decode-cache shardings: batch dim over ``batch`` axes; for KV slabs
    and their metadata side-cars, the sequence dim (= batch dim + 1) over
    ``seq`` axes (distributed FIER).  Mamba/conv states and cross-attn
    caches shard on batch only."""
    b = tuple(batch) if batch else None
    s = tuple(seq) if seq else None

    def one(path, leaf, baxis):
        ps = _path_str(path)
        nd = len(leaf.shape)
        spec = [None] * nd
        spec[baxis] = b
        is_slab = (
            re.search(r"(^|/)(k|v|codes|scale|zero|kmax|kmin)$", ps) or "meta" in ps
        )
        if is_slab and "cross" not in ps and nd > baxis + 1:
            spec[baxis + 1] = s
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_shape, batch_axis_tree)


def batch_shardings(batch_shape: Any, mesh: Mesh, batch: tuple[str, ...]) -> Any:
    b = tuple(batch) if batch else None

    def one(leaf):
        spec = [None] * len(leaf.shape)
        if spec:
            spec[0] = b
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_shape)


def tree_bytes(shape_tree: Any) -> int:
    return sum(
        int(l.size) * l.dtype.itemsize for l in jax.tree_util.tree_leaves(shape_tree)
    )
