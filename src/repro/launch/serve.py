"""Serving driver: ``python -m repro.launch.serve --arch olmo-1b --reduced``

Spins up the Engine + continuous-batching scheduler on synthetic requests
and reports throughput/occupancy.  Policy selectable: full | fier | quest.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core.policy import PolicyConfig
from repro.data.pipeline import lm_tokens
from repro.launch.mesh import batch_axes, make_local_mesh
from repro.models import DistConfig, build_model
from repro.serving import ContinuousScheduler, Engine, Request, SamplingConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--policy", default="fier", choices=["full", "fier", "quest"])
    ap.add_argument("--budget", type=int, default=64)
    ap.add_argument("--group", type=int, default=8)
    ap.add_argument("--n-requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="block-pool KV cache (prefix sharing + preemption)")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--pool-blocks", type=int, default=0,
                    help="pool size in blocks; 0 = worst-case default")
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_local_mesh(model_axis=args.model_axis)
    layout = "paged" if args.paged else "slab"
    pol = None
    if args.policy != "full" and not cfg.attention_free:
        # paged fier serves through the one-pass kernel pipeline (the
        # only paged fier pipeline in the capability matrix besides the
        # reference oracle); slab mode keeps the reference pipeline so
        # the driver exercises both ends of the matrix
        pol = PolicyConfig(
            kind=args.policy, budget=args.budget, group=args.group,
            skip_layers=1 if args.reduced else 2,
            pipeline="one_pass" if args.paged else "reference",
            layout=layout,
            block_size=args.block_size, pool_blocks=args.pool_blocks,
        )
    elif args.paged:
        pol = PolicyConfig(
            kind="full", layout="paged", block_size=args.block_size,
            pool_blocks=args.pool_blocks,
        )
    dcfg = DistConfig(mesh=mesh, batch_axes=batch_axes(mesh))
    if args.paged:
        dcfg = DistConfig(mesh=None)  # paged + seq-sharding: follow-up PR
    bundle = build_model(cfg, pol, dcfg, max_positions=args.capacity)
    params = bundle.init(jax.random.PRNGKey(args.seed))

    eng = Engine(bundle, n_slots=args.slots, capacity=args.capacity,
                 sampling=SamplingConfig(temperature=0.0))
    sched = ContinuousScheduler(eng, params, pad_prompt_to=args.prompt_len)
    toks = np.asarray(lm_tokens(args.seed, 0, args.n_requests, args.prompt_len, cfg.vocab))
    reqs = [Request(rid=i, tokens=toks[i, : args.prompt_len].tolist(),
                    max_new=args.max_new) for i in range(args.n_requests)]
    t0 = time.time()
    out = sched.run(reqs)
    wall = time.time() - t0
    total_tokens = sum(len(v) for v in out.values())
    report = {
        "arch": cfg.name, "policy": args.policy, "requests": len(reqs),
        "tokens": total_tokens, "wall_s": round(wall, 2),
        "tok_per_s": round(total_tokens / wall, 1),
        "decode_steps": sched.steps,
        "mean_occupancy": round(sched.mean_occupancy, 2),
    }
    if args.paged:
        report.update(sched.engine.pool_stats(), preemptions=sched.preemptions)
    print(json.dumps(report))


if __name__ == "__main__":
    main()
