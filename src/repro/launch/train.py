"""Training driver: ``python -m repro.launch.train --arch olmo-1b ...``

End-to-end: config → mesh → sharded train_step jit → deterministic data →
checkpoint/restart (fault-injectable) → metrics log.  Reduced configs run
on this container's CPU; full configs + production mesh go through
dryrun.py (and on real pods, this same driver with --mesh production).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import SHAPES, get_config, reduced_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import make_train_batch
from repro.launch import sharding as shard
from repro.launch.mesh import batch_axes, fsdp_axes, make_local_mesh
from repro.launch.steps import TrainHParams, init_train_state, make_train_step
from repro.models import DistConfig, build_model
from repro.runtime import FaultInjector, StragglerMonitor, run_with_recovery


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default=None, choices=[None, "cosine", "wsd"])
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject faults at these steps (fault-tolerance demo)")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_local_mesh(model_axis=args.model_axis)
    b_axes = batch_axes(mesh)
    f_axes = fsdp_axes(mesh, cfg.param_count() * 4)
    schedule = args.schedule or ("wsd" if "minicpm" in args.arch else "cosine")
    hp = TrainHParams(
        peak_lr=args.lr, warmup=max(args.steps // 10, 1), total_steps=args.steps,
        schedule=schedule, compress_grads=args.compress_grads,
    )
    dcfg = DistConfig(
        mesh=mesh, batch_axes=b_axes,
        ep_axis="model" if cfg.family == "moe" and mesh.shape["model"] > 1 else None,
        fsdp_axes=(),
    )
    max_pos = args.seq if cfg.family == "encdec" else None
    bundle = build_model(cfg, None, dcfg, max_positions=max_pos)
    train_step = make_train_step(bundle, hp)

    state = init_train_state(bundle, jax.random.PRNGKey(args.seed), hp)
    params_sh = shard.param_shardings(jax.eval_shape(lambda: state["params"]), mesh, f_axes)
    state_sh = {
        "params": params_sh,
        "opt": shard.opt_shardings(jax.eval_shape(lambda: state["opt"]), params_sh, mesh),
    }
    if "ef" in state:
        state_sh["ef"] = params_sh
    state = jax.tree.map(jax.device_put, state, state_sh)

    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    step_jit = jax.jit(train_step, donate_argnums=(0,))

    ckpt = CheckpointManager(args.ckpt_dir, keep_n=2)
    injector = FaultInjector(args.fail_at)
    monitor = StragglerMonitor()
    t_start = time.time()

    def one_step(st, step):
        injector.maybe_fail(step)
        batch = make_train_batch(cfg, shape, step, seed=args.seed)
        monitor.start()
        st, metrics = step_jit(st, batch)
        dt = monitor.stop(step)
        if step % args.log_every == 0 or step == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            print(json.dumps({"step": step, "dt_s": round(dt, 3), **m}))
        return st

    state, stats = run_with_recovery(
        one_step, state, args.steps, ckpt, ckpt_every=args.ckpt_every,
        state_like=state,
    )
    print(json.dumps({
        "done": True, "steps": args.steps, "wall_s": round(time.time() - t_start, 1),
        "restarts": stats["restarts"], "resumed_from": stats["resumed_from"],
        "straggler_events": len(monitor.events),
    }))


if __name__ == "__main__":
    main()
