import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-importing import: jax locks the device count on
# first init, and the production meshes below need 512 host placeholders.

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import subprocess    # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

"""Multi-pod dry-run: prove every (arch × shape × mesh) cell lowers,
partitions, and compiles coherently — sharding mismatches, unsupported
collectives, and absurd per-device memory all surface here, without
hardware.

For each cell:
    lowered  = jax.jit(step_fn).lower(*sharded ShapeDtypeStructs)
    compiled = lowered.compile()
    print(compiled.memory_analysis())   # proves it fits
    print(compiled.cost_analysis())     # FLOPs/bytes → §Roofline
plus a pass over the partitioned HLO summing collective wire bytes
(ring-model per-chip estimates, classified by op kind) → §Roofline's
collective term.

Usage:
    python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] --out results.jsonl
(--all fans each cell into a subprocess: isolation against OOM/compile
state, fresh device count, one JSON record per line.)
"""

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"=\s*\(?((?:[a-z0-9]+\[[0-9,]*\](?:\{[0-9,]*\})?,?\s*)+)\)?\s*(?:all|collective)")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def _shape_bytes(tok: str) -> int:
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", tok)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def _group_size(line: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)  # iota form [n,g]
    if m:
        return int(m.group(2))
    return total_devices


def collective_wire_bytes(hlo_text: str, total_devices: int) -> dict:
    """Per-chip wire-byte estimates by collective kind (ring model):
    AR 2·X·(n−1)/n, AG X_out·(n−1)/n, RS X_out·(n−1), A2A X·(n−1)/n,
    permute X.  Shapes in the partitioned module are already per-device."""
    out = {k: 0.0 for k in
           ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
            "collective-permute")}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        sm = re.search(r"=\s*\(?([^)=]*?)\s*" + re.escape(kind), line)
        toks = re.findall(r"[a-z0-9]+\[[0-9,]*\]", sm.group(1)) if sm else []
        x = sum(_shape_bytes(t) for t in toks)
        n = _group_size(line, total_devices)
        if n <= 1:
            continue
        if kind == "all-reduce":
            w = 2 * x * (n - 1) / n
        elif kind == "all-gather":
            w = x * (n - 1) / n
        elif kind == "reduce-scatter":
            w = x * (n - 1)
        elif kind == "all-to-all":
            w = x * (n - 1) / n
        else:
            w = x
        out[kind] += w
        counts[kind] += 1
    out["counts"] = counts
    out["total"] = sum(v for k, v in out.items() if isinstance(v, float))
    return out


def run_cell(arch: str, shape: str, *, multi_pod: bool, policy: str = "fier",
             budget: int = 4096, dist_mode: str = "local", verbose: bool = True,
             cost_depth: int | None = None, cost_depth_enc: int | None = None,
             flops_only: bool = False, strategy: str = "tp") -> dict:
    import jax
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_cell
    from repro.models import tuning

    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {
        "arch": arch, "shape": shape, "policy": policy,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "devices": mesh.devices.size, "multi_pod": multi_pod,
        "dist_mode": dist_mode, "budget": budget,
        "cost_depth": cost_depth, "cost_depth_enc": cost_depth_enc,
        "strategy": strategy,
    }
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, policy_kind=policy, budget=budget,
                      dist_mode=dist_mode, cost_depth=cost_depth,
                      cost_depth_enc=cost_depth_enc, strategy=strategy)
    rec["kind"] = cell.kind

    if flops_only:
        # scan-aware jaxpr FLOP count (global) — no compile
        import sys as _sys
        _sys.path.insert(0, "benchmarks")
        from flopcount import count_fn_flops

        with jax.set_mesh(mesh):
            rec["jaxpr_flops_global"] = float(count_fn_flops(cell.fn, *cell.args))
        rec["jaxpr_flops_per_device"] = rec["jaxpr_flops_global"] / mesh.devices.size
        _finish_model_flops(rec, arch, shape, cell, mesh)
        if verbose:
            print(f"[flops] {arch} × {shape}: global={rec['jaxpr_flops_global']:.3e} "
                  f"per-device={rec['jaxpr_flops_per_device']:.3e}")
        return rec

    # NOTE on donation: deployed steps donate the cache/state so outputs
    # alias inputs; we lower WITHOUT donation here because XLA:CPU's
    # buffer accounting degrades under donation (f32 shadow copies of
    # bf16 slabs — see EXPERIMENTS.md §Dry-run caveats).  Deployment
    # memory ≈ args + temp (out aliased).
    with jax.set_mesh(mesh), tuning.tuned(**cell.tuning):
        lowered = jax.jit(cell.fn).lower(*cell.args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
        rec[field] = int(getattr(mem, field, -1))
    rec["per_device_bytes"] = (
        rec["argument_size_in_bytes"] + rec["temp_size_in_bytes"]
    )
    cost = compiled.cost_analysis()
    rec["flops"] = float(cost.get("flops", -1.0))
    rec["bytes_accessed"] = float(cost.get("bytes accessed", -1.0))
    text = compiled.as_text()
    rec["collectives"] = collective_wire_bytes(text, mesh.devices.size)
    _finish_model_flops(rec, arch, shape, cell, mesh)
    if verbose:
        print(f"[dryrun] {arch} × {shape} ({cell.kind}) on {rec['mesh']}:")
        print(f"  memory_analysis: args={rec['argument_size_in_bytes']/1e9:.2f}GB "
              f"temp={rec['temp_size_in_bytes']/1e9:.2f}GB "
              f"out={rec['output_size_in_bytes']/1e9:.2f}GB (per device)")
        print(f"  cost_analysis: flops={rec['flops']:.3e} "
              f"bytes={rec['bytes_accessed']:.3e} (per device)")
        print(f"  collectives (wire bytes/chip): " +
              ", ".join(f"{k}={v:.2e}" for k, v in rec["collectives"].items()
                        if isinstance(v, float) and v > 0))
    return rec


def _finish_model_flops(rec, arch, shape, cell, mesh):
    """6·N_active·tokens (train; the 6 covers fwd+bwd) or 2·N·tokens
    (prefill/decode fwd-only)."""
    from repro.configs import SHAPES, get_config

    cfg = get_config(arch)
    sh = SHAPES[shape]
    n_active = cfg.active_param_count()
    tokens = sh.global_batch * (sh.seq_len if cell.kind != "decode" else 1)
    mult = 6 if cell.kind == "train" else 2
    rec["model_flops_global"] = float(mult * n_active * tokens)
    rec["model_flops_per_device"] = rec["model_flops_global"] / mesh.devices.size


def all_cells(multi_pod_too: bool = True):
    from repro.configs import ARCHS, shape_cells

    for arch in ARCHS:
        for shape in shape_cells(arch):
            yield arch, shape, False
            if multi_pod_too:
                yield arch, shape, True


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--policy", default="fier",
                    choices=["fier", "quest", "full"])
    ap.add_argument("--budget", type=int, default=4096)
    ap.add_argument("--dist-mode", default="local", choices=["local", "exact"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--json", action="store_true", help="print record as JSON line")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--cost-depth", type=int, default=None,
                    help="roofline extrapolation: rebuild at this depth, unrolled")
    ap.add_argument("--cost-depth-enc", type=int, default=None)
    ap.add_argument("--flops-only", action="store_true",
                    help="jaxpr FLOP count only (no compile)")
    ap.add_argument("--strategy", default="tp", choices=["tp", "fsdp_pure"])
    args = ap.parse_args()

    if args.all:
        failures = []
        sink = open(args.out, "a") if args.out else None
        for arch, shape, mp in all_cells(multi_pod_too=not args.single_pod_only):
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
                   "--shape", shape, "--policy", args.policy, "--json",
                   "--budget", str(args.budget), "--dist-mode", args.dist_mode]
            if mp:
                cmd.append("--multi-pod")
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout,
                               env={**os.environ, "PYTHONPATH": "src"})
            dt = time.time() - t0
            tag = f"{arch} × {shape} × {'2pod' if mp else '1pod'}"
            if r.returncode == 0:
                line = r.stdout.strip().splitlines()[-1]
                print(f"PASS {tag} ({dt:.0f}s)")
                if sink:
                    sink.write(line + "\n")
                    sink.flush()
            else:
                print(f"FAIL {tag}:\n{r.stderr[-2000:]}")
                failures.append(tag)
        if sink:
            sink.close()
        print(f"\n{'ALL PASS' if not failures else f'{len(failures)} FAILURES'}")
        for f in failures:
            print(" -", f)
        return 1 if failures else 0

    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   policy=args.policy, budget=args.budget,
                   dist_mode=args.dist_mode, verbose=not args.json,
                   cost_depth=args.cost_depth, cost_depth_enc=args.cost_depth_enc,
                   flops_only=args.flops_only, strategy=args.strategy)
    if args.json:
        print(json.dumps(rec))
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
