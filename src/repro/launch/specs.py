"""Dry-run cell construction: (arch × shape × mesh) → step fn + sharded
ShapeDtypeStruct arguments.  No arrays are ever allocated — everything is
``jax.eval_shape`` + ``ShapeDtypeStruct(..., sharding=...)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.policy import PolicyConfig
from repro.data.pipeline import make_train_batch
from repro.models import DistConfig, build_model
from repro.optim.adamw import adamw_init

from . import sharding as shard
from .mesh import batch_axes as mesh_batch_axes
from .mesh import fsdp_axes as mesh_fsdp_axes
from .steps import TrainHParams, init_train_state, make_serve_step, make_train_step


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str                 # train | prefill | decode
    fn: Callable              # the step to lower
    args: tuple               # sharded ShapeDtypeStructs
    cfg: ModelConfig
    mesh: Any
    notes: str = ""
    tuning: dict = dataclasses.field(default_factory=dict)


def _struct(tree_shape: Any, tree_shard: Any) -> Any:
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree_shape,
        tree_shard,
    )


def decode_policy(cfg: ModelConfig, budget: int = 4096, use_kernels: bool = False) -> PolicyConfig | None:
    if cfg.attention_free:
        return None  # FIER inapplicable (DESIGN.md §5)
    return PolicyConfig(
        kind="fier", budget=budget, group=32, skip_layers=2, use_kernels=use_kernels
    )


def seq_axes_for(shape: ShapeConfig, mesh) -> tuple[str, ...]:
    """KV sequence sharding at decode: 'model' normally; for batch=1
    long-context everything shards the sequence."""
    if shape.global_batch == 1:
        return tuple(mesh.axis_names)  # ('pod',)? + ('data','model')
    return ("model",)


def build_cell(
    arch: str,
    shape_name: str,
    mesh,
    *,
    policy_kind: str = "fier",
    budget: int = 4096,
    hp: TrainHParams | None = None,
    remat: bool = True,
    dist_mode: str = "local",
    cost_depth: int | None = None,
    cost_depth_enc: int | None = None,
    strategy: str = "tp",
) -> Cell:
    """``cost_depth``: roofline depth-extrapolation mode — rebuild the arch
    at 1–2 (super)layers with the layer scan UNROLLED (XLA cost_analysis
    counts loop bodies once; see benchmarks/flopcount.py), microbatches=1,
    skip_layers=0.  Two depths give exact per-layer bytes/collectives."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    cell_tuning: dict = {}
    if cost_depth is not None:
        depth = (
            cost_depth * cfg.attn_every if cfg.family == "hybrid" else cost_depth
        )
        repl = {"n_layers": depth}
        if cfg.family == "encdec":
            repl["n_enc_layers"] = cost_depth_enc or 1
        cfg = dataclasses.replace(cfg, **repl)
        hp = hp or TrainHParams(microbatches=1)
        cell_tuning = {"scan_layers": False}
    b_axes = mesh_batch_axes(mesh)
    # param bytes estimate for the FSDP policy
    itemsize = 2 if cfg.param_dtype == "bfloat16" else 4
    pbytes = cfg.param_count() * itemsize
    f_axes = mesh_fsdp_axes(mesh, pbytes)
    notes = []
    if strategy == "fsdp_pure":
        # ZeRO-3 over the whole mesh; batch spans as many axes as divide
        # the global batch (within-pod at 512 chips — grads AR over 'pod')
        f_axes = tuple(mesh.axis_names)
        b_axes = ()
        n = 1
        for a in ("data", "model", "pod"):
            if a in mesh.axis_names and shape.global_batch % (n * mesh.shape[a]) == 0:
                b_axes += (a,)
                n *= mesh.shape[a]
        notes.append("strategy=fsdp_pure")

    if shape.kind == "train":
        if hp is None:
            # 100B+ cells: gradient accumulation + bf16 accumulator to fit
            # v5e HBM; hybrid (Zamba2) microbatches for its SSD intra-chunk
            # transients (see EXPERIMENTS.md §Dry-run memory table).
            # fsdp_pure: tokens/chip are already minimal (batch spans the
            # mesh) and each microbatch would re-gather every weight — mb=1.
            big = pbytes > 50e9
            mb = 8 if big else (4 if cfg.family == "hybrid" else 1)
            if strategy == "fsdp_pure":
                mb = 1
            hp = TrainHParams(
                schedule="wsd" if "minicpm" in arch else "cosine",
                microbatches=mb,
                accum_dtype="bfloat16" if big else "float32",
            )
        dcfg = DistConfig(
            mesh=mesh, batch_axes=b_axes, ep_axis="model" if cfg.family == "moe" else None,
            fsdp_axes=f_axes if cfg.family == "moe" else (),
        )
        max_pos = shape.seq_len if cfg.family == "encdec" else None
        bundle = build_model(cfg, None, dcfg, remat=remat, max_positions=max_pos)
        step_fn = make_train_step(bundle, hp)
        params_shape = jax.eval_shape(bundle.init, jax.random.key(0))
        params_sh = shard.param_shardings(params_shape, mesh, f_axes, strategy)
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        opt_sh = shard.opt_shardings(opt_shape, params_sh, mesh)
        state_struct = {
            "params": _struct(params_shape, params_sh),
            "opt": _struct(opt_shape, opt_sh),
        }
        batch_shape = jax.eval_shape(
            lambda: make_train_batch(cfg, shape, 0, batch_override=shape.global_batch)
        )
        batch_sh = shard.batch_shardings(batch_shape, mesh, b_axes)
        batch_struct = _struct(batch_shape, batch_sh)
        return Cell(arch, shape_name, "train", step_fn, (state_struct, batch_struct),
                    cfg, mesh, "; ".join(notes), tuning=cell_tuning)

    pol = decode_policy(cfg, budget) if policy_kind == "fier" else (
        None if policy_kind == "full" or cfg.attention_free
        else PolicyConfig(kind=policy_kind, budget=budget, skip_layers=2)
    )
    if cost_depth is not None and pol is not None:
        pol = dataclasses.replace(pol, skip_layers=0)
    # a batch of 1 (long_500k) cannot shard its batch dim — everything
    # shards the sequence instead
    cell_b_axes = b_axes if shape.global_batch > 1 else ()
    s_axes = seq_axes_for(shape, mesh) if shape.kind == "decode" else ("model",)
    dcfg = DistConfig(
        mesh=mesh, seq_axes=s_axes if shape.kind == "decode" else (),
        mode=dist_mode, batch_axes=cell_b_axes,
        ep_axis="model" if cfg.family == "moe" else None,
        fsdp_axes=f_axes if cfg.family == "moe" else (),
    )
    max_pos = shape.seq_len if cfg.family == "encdec" else None
    bundle = build_model(cfg, pol, dcfg, remat=remat, max_positions=max_pos)
    params_shape = jax.eval_shape(bundle.init, jax.random.key(0))
    # serving: no optimizer — params shard TP over model + FSDP over data
    params_sh = shard.param_shardings(params_shape, mesh, f_axes)
    params_struct = _struct(params_shape, params_sh)

    if shape.kind == "prefill":
        B, S = shape.global_batch, shape.seq_len
        from repro.data.pipeline import make_prefill_batch

        if cfg.family == "ssm":
            # uniform-length fast path: static conv-tail slice (§Perf it. 11)
            step_fn = lambda params, batch: bundle.prefill(
                params, batch, capacity=S, uniform_full=True)
        else:
            step_fn = lambda params, batch: bundle.prefill(params, batch, capacity=S)
        batch_shape = jax.eval_shape(lambda: make_prefill_batch(cfg, B, _text_len(cfg, S)))
        batch_sh = shard.batch_shardings(batch_shape, mesh, cell_b_axes)
        return Cell(arch, shape_name, "prefill", step_fn,
                    (params_struct, _struct(batch_shape, batch_sh)), cfg, mesh,
                    tuning=cell_tuning)

    # decode: cache at capacity seq_len, one new token
    B, S = shape.global_batch, shape.seq_len
    step_fn = make_serve_step(bundle)
    cache_shape = jax.eval_shape(lambda: bundle.init_cache(B, S, S - 1))
    baxes_tree = shard.cache_batch_axes(bundle.init_cache, S)
    cache_sh = shard.cache_shardings(cache_shape, mesh, cell_b_axes, s_axes, baxes_tree)
    token_struct = jax.ShapeDtypeStruct(
        (B,), jnp.int32,
        sharding=NamedSharding(mesh, P(tuple(cell_b_axes) if cell_b_axes else None)),
    )
    return Cell(arch, shape_name, "decode", step_fn,
                (params_struct, token_struct, _struct(cache_shape, cache_sh)),
                cfg, mesh, tuning=cell_tuning)


def _text_len(cfg: ModelConfig, S: int) -> int:
    return S - cfg.n_vision_tokens if cfg.family == "vlm" else S
