"""Checkpoint manager: atomic, async-capable, elastic-reshard-capable.

Layout per step::

    <dir>/step_000123.tmp/ → (atomic rename) → <dir>/step_000123/
        manifest.json          tree structure, shapes, dtypes, step, mesh
        shard_p0.npz           this process's addressable array shards

Design for 1000+ nodes (documented; exercised single-host here):
  * every process writes only its addressable shards → no coordinator I/O
    bottleneck; the atomic-rename publish is done by process 0 after a
    barrier;
  * manifests record *global* logical shapes, so restore onto a different
    mesh (elastic resize after failures) re-shards on load —
    ``restore(..., sharding=...)`` device_puts into whatever sharding the
    new mesh wants (tests/test_checkpoint.py proves a mesh(4)→mesh(2)
    round-trip);
  * ``save_async`` copies to host then writes on a background thread —
    the train loop never blocks on disk;
  * keep_n garbage collection.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep_n: int = 3, process_index: int = 0):
        self.dir = directory
        self.keep_n = keep_n
        self.process_index = process_index
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, *, blocking: bool = True) -> str:
        names, leaves, _ = _flatten_with_names(tree)
        host_leaves = [np.asarray(x) for x in leaves]  # device→host copy
        if blocking:
            return self._write(step, names, host_leaves)
        self.wait()  # at most one in-flight async save
        self._thread = threading.Thread(
            target=self._write, args=(step, names, host_leaves), daemon=True
        )
        self._thread.start()
        return self._path(step)

    def save_async(self, step: int, tree: Any) -> str:
        return self.save(step, tree, blocking=False)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}")

    def _write(self, step: int, names: list[str], leaves: list[np.ndarray]) -> str:
        final = self._path(step)
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(
            os.path.join(tmp, f"shard_p{self.process_index}.npz"),
            **{f"a{i}": x for i, x in enumerate(leaves)},
        )
        manifest = {
            "step": step,
            "names": names,
            "shapes": [list(x.shape) for x in leaves],
            "dtypes": [str(x.dtype) for x in leaves],
            "process_count": 1,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_n] if self.keep_n else []:
            shutil.rmtree(self._path(s), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, *, sharding: Any = None) -> Any:
        """Restore into the structure of ``like``.  ``sharding``: optional
        pytree (or single sharding) to device_put into — the elastic path:
        a checkpoint saved on mesh A loads onto mesh B by passing B's
        shardings here."""
        path = self._path(step)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, f"shard_p{self.process_index}.npz"))
        leaves = [data[f"a{i}"] for i in range(len(manifest["names"]))]
        names, like_leaves, treedef = _flatten_with_names(like)
        if names != manifest["names"]:
            raise ValueError(
                f"checkpoint tree mismatch: {set(names) ^ set(manifest['names'])}"
            )
        arrs = []
        for x, ref in zip(leaves, like_leaves):
            a = jax.numpy.asarray(x, dtype=ref.dtype)
            arrs.append(a)
        tree = jax.tree_util.tree_unflatten(treedef, arrs)
        if sharding is not None:
            if not isinstance(sharding, (list, dict)) and not hasattr(
                sharding, "keys"
            ):
                try:
                    flat_sh = jax.tree_util.tree_leaves(sharding)
                    if len(flat_sh) == len(arrs):
                        tree = jax.tree.map(
                            lambda a, s: jax.device_put(a, s), tree, sharding
                        )
                    else:
                        tree = jax.tree.map(lambda a: jax.device_put(a, sharding), tree)
                except Exception:
                    tree = jax.tree.map(lambda a: jax.device_put(a, sharding), tree)
            else:
                tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, sharding)
        return tree
