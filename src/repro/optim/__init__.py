from .adamw import adamw_init, adamw_update, clip_by_global_norm
from .grad_compress import compress_decompress, compressed_psum, ef_state_init
from .schedules import cosine_schedule, wsd_schedule

__all__ = [
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "compress_decompress",
    "compressed_psum",
    "cosine_schedule",
    "ef_state_init",
    "wsd_schedule",
]
