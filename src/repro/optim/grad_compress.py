"""1-bit gradient compression with error feedback (beyond-paper extension).

The paper's insight — 1-bit codes preserve what matters when the objective
is relaxed — has a training-side mirror: signSGD-style gradient all-reduce
with error feedback (Seide et al. 2014; 1-bit Adam).  The DP gradient
all-reduce dominates the collective roofline term for the large dense
cells; sign+scale compression cuts those bytes ~16× (bf16 → 1 bit + one
fp32 scale per tensor).

Two entry points:
  * ``compress_decompress`` — pjit-path simulation: grads pass through the
    quantizer (with persistent error-feedback state) before the optimizer;
    numerically identical to what the compressed collective would deliver,
    byte savings accounted analytically in EXPERIMENTS.md §Roofline.
  * ``compressed_psum`` — the real thing for shard_map training loops:
    packs sign bits to uint8, psums the packed planes and per-shard
    scales, unpacks.  Validated on a multi-device CPU mesh in tests.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def ef_state_init(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_decompress(grads: Any, ef: Any) -> tuple[Any, Any]:
    """sign(g+e)·mean|g+e| per tensor, with error feedback residual."""

    def one(g, e):
        x = g.astype(jnp.float32) + e
        scale = jnp.mean(jnp.abs(x))
        q = jnp.sign(x) * scale
        return q, x - q

    out = jax.tree.map(one, grads, ef)
    comp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return comp, new_ef


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """All-reduce mean of a 1-bit (sign+scale) representation of ``x``.

    Runs inside shard_map.  Wire format per shard: ceil(n/8) uint8 sign
    planes + one f32 scale — 1/16 the bf16 bytes.  The psum of unpacked
    ±scale equals summing each shard's dequantised tensor (associative),
    so the result is the exact mean of the per-shard quantised values.
    """
    n = x.size
    xf = x.astype(jnp.float32).reshape(-1)
    scale = jnp.mean(jnp.abs(xf))
    bits = (xf >= 0).astype(jnp.float32)  # {0,1}
    pm1 = bits * 2.0 - 1.0
    contrib = pm1 * scale
    total = jax.lax.psum(contrib, axis_name)
    denom = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return (total / denom).reshape(x.shape).astype(x.dtype)


def compressed_wire_bytes(n_params: int, n_shards: int) -> int:
    """Bytes on the wire per shard for the compressed all-reduce."""
    return n_params // 8 + 4
