"""LR schedules: cosine and WSD (warmup-stable-decay — MiniCPM's schedule,
wired to --arch minicpm-2b by the train launcher)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, peak_lr, warmup, total, final_frac=0.1):
    s = jnp.asarray(step, jnp.float32)
    warm = peak_lr * s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = final_frac * peak_lr + (1 - final_frac) * peak_lr * 0.5 * (
        1 + jnp.cos(jnp.pi * prog)
    )
    return jnp.where(s < warmup, warm, cos)


def wsd_schedule(step, *, peak_lr, warmup, total, decay_frac=0.1, final_frac=0.01):
    """Warmup → stable plateau → sharp exponential-ish decay tail
    (arXiv:2404.06395 §4).  decay_frac: fraction of ``total`` in the tail."""
    s = jnp.asarray(step, jnp.float32)
    decay_steps = decay_frac * total
    decay_start = total - decay_steps
    warm = peak_lr * s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - decay_start) / jnp.maximum(decay_steps, 1), 0.0, 1.0)
    decay = peak_lr * (final_frac ** prog)
    out = jnp.where(s < warmup, warm, peak_lr)
    return jnp.where(s > decay_start, decay, out)
