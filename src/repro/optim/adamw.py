"""AdamW with decoupled weight decay and fp32 master moments.

Pure pytree implementation (no optax dependency in this container).
Moments are kept fp32 regardless of param dtype (bf16 params on the
100B+ configs keep an fp32 update path through the moments).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    gn = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    lr: jax.Array | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> tuple[Any, AdamWState]:
    step = state.step + 1
    t = step.astype(jnp.float32)

    def upd(g, m, n, p):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        n2 = b2 * n + (1 - b2) * gf * gf
        mhat = m2 / (1 - b1**t)
        nhat = n2 / (1 - b2**t)
        delta = mhat / (jnp.sqrt(nhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, n2

    flat = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda x: x[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda x: x[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda x: x[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_mu, new_nu)
