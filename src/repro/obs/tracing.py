"""Structured span/event tracing on the scheduler's virtual token clock.

The :class:`Tracer` records a flat, append-only list of events.  Each
event carries **two** timestamps: ``ts`` — the scheduler's virtual token
clock (``ContinuousScheduler.vtime``: 1 unit per prefill token, 1 per
active slot per decode step), which is deterministic across seeded runs —
and ``wall_ts`` (``time.monotonic()``), which is informational.  All
derived serving numbers (:func:`derive_serving_metrics`) use ``ts`` only,
so two identical seeded runs produce identical traces modulo ``wall_ts``
(gated in tests/test_obs.py).

Event vocabulary (Chrome trace-event ``ph`` phases):

* ``X`` complete spans — request lifecycle: ``queued``, ``prefill``,
  ``prefill_chunk[i]``, ``prefix_replay``, ``request`` (whole lifetime);
* ``i`` instants — ``submitted``, ``token``, ``retired``, ``preempt``,
  ``prefill_abort``, ``budget_downshift`` / ``budget_restore``,
  ``blocks_shed``, ``quarantine``, ``fault``;
* ``C`` counters — ``pool`` (block-pool occupancy), ``occupancy``
  (running slots), introspection series.

Track layout: requests live on ``pid=1`` with ``tid = rid`` (one lane per
request in Perfetto); scheduler-global events on ``pid=0, tid=0``;
counter tracks on ``pid=0``.  Export: :meth:`Tracer.to_chrome_trace`
(the ``{"traceEvents": [...]}`` JSON Perfetto loads — virtual ts maps to
µs) and :meth:`Tracer.to_jsonl` (one event per line for grep/pandas).
"""
from __future__ import annotations

import dataclasses
import json
import math
import time
from typing import Any, Callable

# Perfetto process/track ids
PID_SCHED = 0
PID_REQUEST = 1

_CHROME_PHASES = ("X", "B", "E", "i", "C", "M")


@dataclasses.dataclass(frozen=True)
class Event:
    name: str
    ph: str                  # chrome trace-event phase
    ts: float                # virtual token clock
    wall_ts: float           # time.monotonic(), informational
    cat: str = "serving"
    pid: int = PID_SCHED
    tid: int = 0
    dur: float | None = None       # X spans only (virtual units)
    args: tuple[tuple[str, Any], ...] = ()

    def arg(self, key: str, default: Any = None) -> Any:
        for k, v in self.args:
            if k == key:
                return v
        return default


class _NullTracer:
    """Disabled tracer: every emit is a no-op (shared instance)."""

    enabled = False
    events: tuple = ()

    def set_clock(self, clock: Callable[[], float]) -> None: ...
    def reset(self) -> None: ...
    def now(self) -> float: return 0.0
    def instant(self, name, **kw) -> None: ...
    def complete(self, name, ts, dur, **kw) -> None: ...
    def counter(self, name, values, **kw) -> None: ...


NULL_TRACER = _NullTracer()


class Tracer:
    """Append-only trace buffer bound to a virtual clock.

    ``set_clock`` is called by the scheduler (``lambda: sched.vtime``);
    until then ``now()`` reads the last explicit timestamp (0.0 at
    start), so engine-level events emitted outside a scheduler still
    land on a monotone axis.
    """

    enabled = True

    def __init__(self, clock: Callable[[], float] | None = None):
        self.events: list[Event] = []
        self._clock = clock
        self._last_ts = 0.0

    def set_clock(self, clock: Callable[[], float]) -> None:
        self._clock = clock

    def reset(self) -> None:
        """Drop all buffered events (a new serving session restarts the
        virtual clock at 0, so a carried-over buffer would be
        non-monotone)."""
        self.events.clear()
        self._last_ts = 0.0

    def now(self) -> float:
        if self._clock is not None:
            self._last_ts = float(self._clock())
        return self._last_ts

    def _emit(self, name: str, ph: str, ts: float | None, *, cat: str,
              pid: int, tid: int, dur: float | None = None,
              **args: Any) -> None:
        self.events.append(Event(
            name=name, ph=ph,
            ts=self.now() if ts is None else float(ts),
            wall_ts=time.monotonic(), cat=cat, pid=pid, tid=tid, dur=dur,
            args=tuple(sorted(args.items())),
        ))

    # ------------------------------------------------------------- emitters
    def instant(self, name: str, *, ts: float | None = None,
                cat: str = "serving", pid: int = PID_SCHED, tid: int = 0,
                **args: Any) -> None:
        self._emit(name, "i", ts, cat=cat, pid=pid, tid=tid, **args)

    def complete(self, name: str, ts: float, dur: float, *,
                 cat: str = "serving", pid: int = PID_SCHED, tid: int = 0,
                 **args: Any) -> None:
        self._emit(name, "X", ts, cat=cat, pid=pid, tid=tid,
                   dur=float(dur), **args)

    def counter(self, name: str, values: dict[str, float], *,
                ts: float | None = None, cat: str = "serving",
                pid: int = PID_SCHED, tid: int = 0) -> None:
        self._emit(name, "C", ts, cat=cat, pid=pid, tid=tid,
                   **{k: float(v) for k, v in values.items()})

    # -------------------------------------------------------------- exports
    def to_chrome_trace(self) -> dict:
        """Chrome trace-event JSON (Perfetto-loadable).  Virtual token
        units map 1:1 onto trace µs; ``wall_ts`` rides along in args."""
        events: list[dict] = [
            {"name": "process_name", "ph": "M", "pid": PID_SCHED, "tid": 0,
             "args": {"name": "scheduler"}},
            {"name": "process_name", "ph": "M", "pid": PID_REQUEST, "tid": 0,
             "args": {"name": "requests"}},
        ]
        named_tids: set[tuple[int, int]] = set()
        for e in self.events:
            if e.pid == PID_REQUEST and (e.pid, e.tid) not in named_tids:
                named_tids.add((e.pid, e.tid))
                events.append({
                    "name": "thread_name", "ph": "M", "pid": e.pid,
                    "tid": e.tid, "args": {"name": f"rid={e.tid}"}})
            row: dict[str, Any] = {
                "name": e.name, "ph": e.ph, "cat": e.cat,
                "ts": e.ts, "pid": e.pid, "tid": e.tid,
                "args": dict(e.args, wall_ts=e.wall_ts),
            }
            if e.ph == "X":
                row["dur"] = e.dur
            if e.ph == "i":
                row["s"] = "t"   # thread-scoped instant
            events.append(row)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> dict:
        doc = self.to_chrome_trace()
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        return doc

    def to_jsonl(self) -> str:
        lines = []
        for e in self.events:
            row = dataclasses.asdict(e)
            row["args"] = dict(e.args)
            lines.append(json.dumps(row, sort_keys=True))
        return "\n".join(lines) + ("\n" if lines else "")

    # ------------------------------------------------------------- analysis
    def request_events(self, rid: int) -> list[Event]:
        return [e for e in self.events
                if e.pid == PID_REQUEST and e.tid == rid]

    def canonical(self) -> list[tuple]:
        """Deterministic projection (drops ``wall_ts``) — two identical
        seeded runs must compare equal on this."""
        return [(e.name, e.ph, e.ts, e.cat, e.pid, e.tid, e.dur, e.args)
                for e in self.events]


def validate_chrome_trace(doc: Any) -> list[str]:
    """Stdlib-only structural check that ``doc`` is a Perfetto-loadable
    Chrome trace-event document.  Returns a list of problems (empty =
    valid).  Used by ``tools/obs_report.py --validate`` and the exporter
    round-trip tests."""
    errs: list[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["top level must be an object with a 'traceEvents' array"]
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        return ["'traceEvents' must be an array"]
    for i, e in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            errs.append(f"{where}: not an object")
            continue
        for field in ("name", "ph", "pid", "tid"):
            if field not in e:
                errs.append(f"{where}: missing {field!r}")
        ph = e.get("ph")
        if ph not in _CHROME_PHASES:
            errs.append(f"{where}: unknown phase {ph!r}")
            continue
        if ph != "M":
            ts = e.get("ts")
            if not isinstance(ts, (int, float)):
                errs.append(f"{where}: ts must be a number, got {ts!r}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"{where}: X span needs numeric dur >= 0")
        if ph == "C":
            args = e.get("args", {})
            if not isinstance(args, dict) or not args:
                errs.append(f"{where}: C event needs non-empty args")
            elif not all(isinstance(v, (int, float))
                         for k, v in args.items() if k != "wall_ts"):
                errs.append(f"{where}: C args must be numeric")
        if "args" in e and not isinstance(e["args"], dict):
            errs.append(f"{where}: args must be an object")
    return errs


def load_trace_events(doc: dict) -> list[Event]:
    """Parse a Chrome trace document back into :class:`Event` rows
    (metadata events dropped) — the Perfetto-JSON half of the exporter
    round-trip test."""
    out: list[Event] = []
    for row in doc["traceEvents"]:
        if row.get("ph") == "M":
            continue
        args = dict(row.get("args", {}))
        wall = args.pop("wall_ts", 0.0)
        out.append(Event(
            name=row["name"], ph=row["ph"], ts=float(row["ts"]),
            wall_ts=float(wall), cat=row.get("cat", "serving"),
            pid=int(row["pid"]), tid=int(row["tid"]),
            dur=(float(row["dur"]) if "dur" in row else None),
            args=tuple(sorted(args.items())),
        ))
    return out


def _percentile(sorted_vals: list[float], q: float) -> float:
    # linear interpolation between closest ranks on a pre-sorted list —
    # bit-identical to np.percentile's default method including its lerp
    # branch (t >= 0.5 computes from the upper rank), so span-derived
    # numbers match historical BENCH_serve_trace baselines exactly
    if not sorted_vals:
        return 0.0
    rank = q * (len(sorted_vals) - 1)
    lo = math.floor(rank)
    hi = math.ceil(rank)
    a, b = float(sorted_vals[lo]), float(sorted_vals[hi])
    t = rank - lo
    if t >= 0.5:
        return b - (b - a) * (1.0 - t)
    return a + (b - a) * t


def derive_serving_metrics(events: list[Event] | Tracer) -> dict:
    """Compute TTFT / ITL / throughput from a request-span trace — the
    single source of truth shared by ``bench_serve_trace`` and the
    metrics snapshot, so the benchmark and the engine can never disagree.

    Per rid: TTFT = first ``token`` ts − ``submitted`` ts; ITL = gaps
    between consecutive ``token`` ts.  Throughput = total tokens /
    makespan (first ``submitted`` → last ``token``), in tokens per 1000
    virtual units.  All on the virtual clock.
    """
    if isinstance(events, Tracer):
        events = events.events
    submitted: dict[int, float] = {}
    tokens: dict[int, list[float]] = {}
    for e in events:
        if e.pid != PID_REQUEST:
            continue
        if e.name == "submitted":
            submitted.setdefault(e.tid, e.ts)
        elif e.name == "token":
            tokens.setdefault(e.tid, []).append(e.ts)
    ttfts = sorted(tokens[rid][0] - t0 for rid, t0 in submitted.items()
                   if tokens.get(rid))
    itls = sorted(b - a
                  for stamps in tokens.values()
                  for a, b in zip(stamps, stamps[1:]))
    total_tokens = sum(len(v) for v in tokens.values())
    t_start = min(submitted.values(), default=0.0)
    t_end = max((v[-1] for v in tokens.values() if v), default=t_start)
    makespan = max(t_end - t_start, 1e-9)
    return {
        "ttft_p50": _percentile(ttfts, 0.50),
        "ttft_p99": _percentile(ttfts, 0.99),
        "itl_p50": _percentile(itls, 0.50),
        "itl_p99": _percentile(itls, 0.99),
        "total_tokens": total_tokens,
        "makespan": makespan,
        "tokens_per_kunit": 1000.0 * total_tokens / makespan,
        "n_requests": len(submitted),
        "n_finished_first_token": len(ttfts),
    }
