"""Zero-dependency in-process metrics registry.

One :class:`MetricsRegistry` per serving session (the engine and the
scheduler share it through :class:`repro.obs.Observability`).  Three
instrument kinds — :class:`Counter` (monotone), :class:`Gauge` (level),
:class:`Histogram` (bucketed distribution + exact sum/count) — each
holding *labeled series*: ``counter.inc(1, status="finished")`` keeps one
float per distinct label set, so the registry is the single namespace for
every quantity the serving stack reports (DESIGN.md §Observability).

Design constraints, in order:

* **Host-side only.**  Instruments never appear inside jitted code; a
  metric update is a Python dict write.  The disabled registry
  (``MetricsRegistry(enabled=False)``) hands out one shared no-op
  instrument, so the cold path costs an attribute load — no measurable
  per-step cost and zero jit recompiles (gated in tests/test_obs.py).
* **Snapshot/diff semantics.**  :meth:`MetricsRegistry.snapshot` freezes
  every series into a :class:`Snapshot`; ``snap_b.diff(snap_a)`` returns
  the counter/histogram deltas (gauges keep their newer level), so a
  benchmark can meter exactly one replay on a shared registry.
* **Self-describing exposition.**  Each instrument carries ``unit`` /
  ``better`` / ``gate`` metadata (the benchmarks/persist.py contract), so
  a snapshot serialises to JSON that
  ``tools/check_bench_regression.py`` can gate directly, and to
  Prometheus text exposition — both round-trip (``Snapshot.from_json``,
  :func:`parse_prometheus_text`).
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Iterable

OBS_SCHEMA_VERSION = 1

# matches benchmarks/persist.py: gated series must declare a direction
BETTER = ("lower", "higher", "info")

# generic latency-ish buckets in virtual token units (powers of 2 cover
# the trace benchmark's 1..10^4 range); histograms accept overrides
DEFAULT_BUCKETS = tuple(float(2**i) for i in range(0, 15))


def _label_key(labels: dict[str, str]) -> tuple[tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _format_labels(key: tuple[tuple[str, str], ...]) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


class _Instrument:
    """Shared series bookkeeping for one named metric."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "", *, unit: str = "",
                 better: str = "info", gate: bool = False):
        if better not in BETTER:
            raise ValueError(f"better must be one of {BETTER}, got {better!r}")
        if gate and better == "info":
            raise ValueError(f"metric {name!r}: gated metrics need a direction")
        self.name = name
        self.help = help
        self.unit = unit
        self.better = better
        self.gate = gate
        self._series: dict[tuple[tuple[str, str], ...], float] = {}

    def _meta(self) -> dict:
        return dict(unit=self.unit, better=self.better, gate=self.gate,
                    help=self.help)


class Counter(_Instrument):
    """Monotone accumulator.  ``inc(amount, **labels)``."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative inc {amount}")
        key = _label_key(labels)
        self._series[key] = self._series.get(key, 0.0) + float(amount)

    def value(self, **labels: str) -> float:
        return self._series.get(_label_key(labels), 0.0)


class Gauge(_Instrument):
    """Point-in-time level.  ``set(value, **labels)`` / ``add(delta)``."""

    kind = "gauge"

    def set(self, value: float, **labels: str) -> None:
        self._series[_label_key(labels)] = float(value)

    def add(self, delta: float, **labels: str) -> None:
        key = _label_key(labels)
        self._series[key] = self._series.get(key, 0.0) + float(delta)

    def value(self, **labels: str) -> float:
        return self._series.get(_label_key(labels), 0.0)


class Histogram(_Instrument):
    """Cumulative-bucket histogram with exact ``sum``/``count``.

    Buckets are upper bounds (``le``); an implicit ``+inf`` bucket always
    exists.  Series value is ``(bucket_counts, sum, count)``.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "", *, unit: str = "",
                 better: str = "info", gate: bool = False,
                 buckets: Iterable[float] = DEFAULT_BUCKETS):
        super().__init__(name, help, unit=unit, better=better, gate=gate)
        bs = tuple(sorted(float(b) for b in buckets))
        if not bs:
            raise ValueError(f"histogram {name!r}: need at least one bucket")
        self.buckets = bs

    def observe(self, value: float, **labels: str) -> None:
        key = _label_key(labels)
        state = self._series.get(key)
        if state is None:
            state = self._series[key] = [
                [0] * (len(self.buckets) + 1), 0.0, 0]
        counts, _, _ = state
        v = float(value)
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                counts[i] += 1
                break
        else:
            counts[-1] += 1
        state[1] += v
        state[2] += 1

    def count(self, **labels: str) -> int:
        state = self._series.get(_label_key(labels))
        return 0 if state is None else state[2]

    def sum(self, **labels: str) -> float:
        state = self._series.get(_label_key(labels))
        return 0.0 if state is None else state[1]

    def mean(self, **labels: str) -> float:
        state = self._series.get(_label_key(labels))
        if state is None or state[2] == 0:
            return 0.0
        return state[1] / state[2]

    def percentile(self, q: float, **labels: str) -> float:
        """Bucket-resolution quantile estimate (``0 <= q <= 1``): the
        upper bound of the first bucket whose cumulative count reaches
        ``q · count``.  Observations past the last bound clamp to it, so
        the estimate never exceeds the configured bucket range — use
        ``sum()/count()`` when exact tails matter."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        state = self._series.get(_label_key(labels))
        if state is None or state[2] == 0:
            return 0.0
        counts, _, total = state
        rank = q * total
        cum = 0
        for i, ub in enumerate(self.buckets):
            cum += counts[i]
            if cum >= rank:
                return ub
        return self.buckets[-1]


class _NullInstrument:
    """The disabled registry's single shared instrument: every mutator is
    a no-op, every reader returns zero."""

    def inc(self, amount: float = 1.0, **labels: str) -> None: ...
    def set(self, value: float, **labels: str) -> None: ...
    def add(self, delta: float, **labels: str) -> None: ...
    def observe(self, value: float, **labels: str) -> None: ...
    def value(self, **labels: str) -> float: return 0.0
    def count(self, **labels: str) -> int: return 0
    def sum(self, **labels: str) -> float: return 0.0
    def mean(self, **labels: str) -> float: return 0.0
    def percentile(self, q: float, **labels: str) -> float: return 0.0


_NULL = _NullInstrument()


@dataclasses.dataclass(frozen=True)
class Series:
    """One flattened (metric, labels) series inside a :class:`Snapshot`."""

    name: str
    kind: str                       # counter | gauge | histogram
    labels: tuple[tuple[str, str], ...]
    value: float                    # counter/gauge value; histogram sum
    unit: str = ""
    better: str = "info"
    gate: bool = False
    # histogram extras (None for scalar kinds)
    buckets: tuple[float, ...] | None = None
    bucket_counts: tuple[int, ...] | None = None
    count: int | None = None

    @property
    def full_name(self) -> str:
        return self.name + _format_labels(self.labels)


class Snapshot:
    """A frozen view of every series in a registry at one instant."""

    def __init__(self, series: list[Series]):
        self.series = list(series)
        self._by_key = {(s.name, s.labels): s for s in self.series}

    def get(self, name: str, **labels: str) -> Series | None:
        return self._by_key.get((name, _label_key(labels)))

    def value(self, name: str, **labels: str) -> float:
        s = self.get(name, **labels)
        return 0.0 if s is None else s.value

    def as_dict(self) -> dict[str, float]:
        """Flat ``name{labels} -> value`` mapping (histogram → sum)."""
        return {s.full_name: s.value for s in self.series}

    def diff(self, older: "Snapshot") -> "Snapshot":
        """Delta snapshot: counters/histograms subtract the older series
        (absent in older → unchanged); gauges keep their newer level."""
        out: list[Series] = []
        for s in self.series:
            if s.kind == "gauge":
                out.append(s)
                continue
            o = older._by_key.get((s.name, s.labels))
            if o is None:
                out.append(s)
            elif s.kind == "counter":
                out.append(dataclasses.replace(s, value=s.value - o.value))
            else:
                bc = tuple(a - b for a, b in
                           zip(s.bucket_counts, o.bucket_counts))
                out.append(dataclasses.replace(
                    s, value=s.value - o.value, bucket_counts=bc,
                    count=s.count - o.count))
        return Snapshot(out)

    # ------------------------------------------------------------- exposition
    def to_json(self) -> dict:
        """The registry-snapshot document format — understood by
        ``tools/check_bench_regression.py`` and ``tools/obs_report.py``."""
        rows = []
        for s in self.series:
            row = {
                "name": s.name,
                "kind": s.kind,
                "labels": {k: v for k, v in s.labels},
                "value": s.value,
                "unit": s.unit,
                "better": s.better,
                "gate": s.gate,
            }
            if s.kind == "histogram":
                row["buckets"] = list(s.buckets)
                row["bucket_counts"] = list(s.bucket_counts)
                row["count"] = s.count
            rows.append(row)
        return {
            "obs_schema": OBS_SCHEMA_VERSION,
            "kind": "metrics_snapshot",
            "series": rows,
        }

    @classmethod
    def from_json(cls, doc: dict) -> "Snapshot":
        if doc.get("obs_schema") != OBS_SCHEMA_VERSION:
            raise ValueError(
                f"obs_schema {doc.get('obs_schema')} != {OBS_SCHEMA_VERSION}"
            )
        out = []
        for row in doc["series"]:
            out.append(Series(
                name=row["name"], kind=row["kind"],
                labels=_label_key(row.get("labels", {})),
                value=float(row["value"]), unit=row.get("unit", ""),
                better=row.get("better", "info"),
                gate=bool(row.get("gate", False)),
                buckets=(tuple(row["buckets"])
                         if "buckets" in row else None),
                bucket_counts=(tuple(row["bucket_counts"])
                               if "bucket_counts" in row else None),
                count=row.get("count"),
            ))
        return cls(out)

    def to_prometheus_text(self) -> str:
        """Prometheus text exposition format (round-trips through
        :func:`parse_prometheus_text` for every kind)."""
        lines: list[str] = []
        seen: set[str] = set()
        for s in self.series:
            if s.name not in seen:
                seen.add(s.name)
                lines.append(f"# TYPE {s.name} {s.kind}")
            lab = _format_labels(s.labels)
            if s.kind != "histogram":
                lines.append(f"{s.name}{lab} {s.value!r}")
                continue
            cum = 0
            for ub, c in zip(s.buckets, s.bucket_counts):
                cum += c
                key = _label_key(dict(s.labels, le=_le_str(ub)))
                lines.append(f"{s.name}_bucket{_format_labels(key)} {cum}")
            cum += s.bucket_counts[-1]
            key = _label_key(dict(s.labels, le="+Inf"))
            lines.append(f"{s.name}_bucket{_format_labels(key)} {cum}")
            lines.append(f"{s.name}_sum{lab} {s.value!r}")
            lines.append(f"{s.name}_count{lab} {s.count}")
        return "\n".join(lines) + "\n"


def _le_str(ub: float) -> str:
    return repr(ub) if not math.isinf(ub) else "+Inf"


def parse_prometheus_text(text: str) -> dict[str, float]:
    """Parse exposition text back to a flat ``name{labels} -> value``
    mapping (stdlib-only; the exporter round-trip test's other half).
    Histogram ``_bucket``/``_sum``/``_count`` samples appear under their
    exposed names."""
    out: dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        sample, value = line.rsplit(" ", 1)
        if "{" in sample:
            name, rest = sample.split("{", 1)
            labels = {}
            for part in rest.rstrip("}").split(","):
                if not part:
                    continue
                k, v = part.split("=", 1)
                labels[k] = v.strip('"')
            key = name + _format_labels(_label_key(labels))
        else:
            key = sample
        out[key] = float(value)
    return out


class MetricsRegistry:
    """The serving stack's metric namespace.

    ``counter`` / ``gauge`` / ``histogram`` create-or-return instruments
    by name (re-registration with the same kind returns the existing one,
    so instrumented sites can look up lazily).  ``enabled=False`` hands
    out the shared no-op instrument and snapshots empty.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._instruments: dict[str, _Instrument] = {}

    def _get(self, cls, name, help, **kw):
        if not self.enabled:
            return _NULL
        inst = self._instruments.get(name)
        if inst is not None:
            if not isinstance(inst, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {inst.kind}"
                )
            return inst
        inst = self._instruments[name] = cls(name, help, **kw)
        return inst

    def counter(self, name: str, help: str = "", *, unit: str = "",
                better: str = "info", gate: bool = False) -> Counter:
        return self._get(Counter, name, help, unit=unit, better=better,
                         gate=gate)

    def gauge(self, name: str, help: str = "", *, unit: str = "",
              better: str = "info", gate: bool = False) -> Gauge:
        return self._get(Gauge, name, help, unit=unit, better=better,
                         gate=gate)

    def histogram(self, name: str, help: str = "", *, unit: str = "",
                  better: str = "info", gate: bool = False,
                  buckets: Iterable[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, unit=unit, better=better,
                         gate=gate, buckets=buckets)

    def set_gauges(self, values: dict[str, float], *, prefix: str = "",
                   unit: str = "", **labels: str) -> None:
        """Bulk gauge update — the allocator/pool sampling helper."""
        for k, v in values.items():
            self.gauge(prefix + k, unit=unit).set(float(v), **labels)

    def snapshot(self) -> Snapshot:
        series: list[Series] = []
        for inst in self._instruments.values():
            for key, val in sorted(inst._series.items()):
                if inst.kind == "histogram":
                    counts, total, n = val
                    series.append(Series(
                        name=inst.name, kind=inst.kind, labels=key,
                        value=total, unit=inst.unit, better=inst.better,
                        gate=inst.gate, buckets=inst.buckets,
                        bucket_counts=tuple(counts), count=n,
                    ))
                else:
                    series.append(Series(
                        name=inst.name, kind=inst.kind, labels=key,
                        value=val, unit=inst.unit, better=inst.better,
                        gate=inst.gate,
                    ))
        return Snapshot(series)

    def write_snapshot_json(self, path: str) -> dict:
        doc = self.snapshot().to_json()
        with open(path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        return doc
