"""Observability: metrics registry + span tracing + retrieval introspection.

One :class:`Observability` bundle travels with a serving session: the
engine and the scheduler share its :class:`~repro.obs.metrics.MetricsRegistry`
(counters / gauges / histograms with labeled series, snapshot/diff,
Prometheus-text + JSON exposition) and its
:class:`~repro.obs.tracing.Tracer` (request-lifecycle spans and scheduler
events on the virtual token clock, exported as Chrome trace-event /
Perfetto JSON or JSONL).  ``introspect=True`` additionally attaches a
:class:`~repro.obs.introspect.RetrievalIntrospector` that samples the
FIER retrieval stage per decode step (budget utilization, τ thresholds,
oracle overlap, recaptured attention mass) into the same registry.

The default is **disabled**: ``Observability.disabled()`` (what an
engine constructs when none is passed) hands out no-op instruments and
the null tracer, so un-instrumented serving runs the same host work and
the same jitted functions as before the subsystem existed — gated by
the overhead/compile-count tests in tests/test_obs.py.

See DESIGN.md §Observability and ``tools/obs_report.py``.
"""
from __future__ import annotations

from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Series,
    Snapshot,
    parse_prometheus_text,
)
from .tracing import (
    NULL_TRACER,
    Event,
    Tracer,
    derive_serving_metrics,
    load_trace_events,
    validate_chrome_trace,
)

__all__ = [
    "Counter",
    "Event",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_TRACER",
    "Observability",
    "ProbeRecord",
    "RetrievalIntrospector",
    "Series",
    "Snapshot",
    "Tracer",
    "derive_serving_metrics",
    "load_trace_events",
    "parse_prometheus_text",
    "validate_chrome_trace",
]

# repro.obs.introspect needs numpy; metrics/tracing are stdlib-only, and
# stdlib-only tools (tools/obs_report.py, tools/check_bench_regression.py)
# import through this package — so the introspector loads lazily
_INTROSPECT_NAMES = {"ProbeRecord", "RetrievalIntrospector"}


def __getattr__(name: str):
    if name in _INTROSPECT_NAMES:
        from . import introspect

        return getattr(introspect, name)
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")


class Observability:
    """The per-session observability bundle: ``metrics`` + ``tracer``
    (+ optional ``introspector``).

    ``enabled`` turns both the registry and the tracer on; pass
    ``introspect=True`` (implies nothing about ``enabled`` — it needs it)
    to attach the retrieval-quality debug probe.  ``metrics`` shares an
    existing registry between sessions (benchmarks meter several replays
    into one snapshot); the default is a fresh one.
    """

    def __init__(self, enabled: bool = True, *, introspect: bool = False,
                 probe_layer: int = 0, probe_every: int = 1,
                 metrics: MetricsRegistry | None = None):
        self.enabled = enabled
        self.metrics = (metrics if metrics is not None
                        else MetricsRegistry(enabled=enabled))
        self.tracer: Tracer = Tracer() if enabled else NULL_TRACER
        self.introspector = None
        if enabled and introspect:
            from .introspect import RetrievalIntrospector

            self.introspector = RetrievalIntrospector(
                self.metrics, self.tracer,
                probe_layer=probe_layer, every=probe_every,
            )

    @classmethod
    def disabled(cls) -> "Observability":
        return cls(enabled=False)

    def __repr__(self) -> str:
        return (f"Observability(enabled={self.enabled}, "
                f"introspect={self.introspector is not None})")
