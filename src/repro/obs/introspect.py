"""Retrieval-quality introspection: how good is FIER's approximate top-k?

Opt-in debug mode (``Observability(introspect=True)``): each scheduler
decode step (subsampled by ``every``) re-runs the retrieval stage for the
probed layer *outside* the jitted decode — eagerly, via the jnp reference
pipeline — and compares the 1-bit approximate selection against the exact
dot-product oracle on the same cache contents.  Per running slot it
records:

* **budget utilization** — ``min(length, budget) / budget``: how much of
  the configured (possibly degraded) retrieval budget addresses real
  tokens.  Below 1.0 the top-k is vacuous (everything fits).
* **τ threshold** — the ``budget``-th largest approximate score (the
  admission threshold the one-pass kernel radix-searches for), mean over
  KV heads, on length-masked scores (guard-rail ±inf overrides excluded
  so τ stays finite).
* **oracle overlap** — ``|topk(approx) ∩ topk(exact)| / k_eff`` under the
  *same* sink/recent guard-rails: the paper's selection-quality metric.
* **recaptured attention mass** — sum of the exact softmax attention
  weights (1/√D-scaled, length-masked) that the approximate selection
  retains — FIER's "recall" framing: quality loss is the mass you drop.

Everything lands in the shared metrics registry (histograms + gauges)
and as per-step ``C`` counter rows on the tracer, so ``obs_report``
renders it next to the serving numbers.  Cost caveat (DESIGN.md
§Observability): one probe is O(S·Hkv·D) eager work per running slot —
strictly a debugging mode, never on in benchmarks' timed sections.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from .metrics import MetricsRegistry
from .tracing import NULL_TRACER


@dataclasses.dataclass(frozen=True)
class ProbeRecord:
    """One (step, slot) introspection sample."""

    step: int
    slot: int
    length: int
    budget: int
    budget_utilization: float
    tau: float
    oracle_overlap: float
    recaptured_mass: float


# buckets for ratio-valued series in [0, 1]
_RATIO_BUCKETS = tuple(i / 10 for i in range(1, 11))


class RetrievalIntrospector:
    """Probes the FIER retrieval stage of a live engine cache.

    ``probe_layer`` indexes the *rest* (retrieval-policy) layer stack;
    ``every`` subsamples decode steps.  Slab and paged layouts are both
    supported — paged probes materialise the logical view through the
    block table (the jnp oracle path)."""

    def __init__(self, registry: MetricsRegistry | None = None,
                 tracer=NULL_TRACER, *, probe_layer: int = 0,
                 every: int = 1):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer
        self.probe_layer = probe_layer
        self.every = max(1, every)
        self.records: list[ProbeRecord] = []
        r = self.registry
        self._h_overlap = r.histogram(
            "fier_oracle_overlap",
            "fraction of exact top-k recovered by the 1-bit selection",
            unit="ratio", better="higher", buckets=_RATIO_BUCKETS)
        self._h_mass = r.histogram(
            "fier_recaptured_mass",
            "exact attention mass retained by the approximate selection",
            unit="ratio", better="higher", buckets=_RATIO_BUCKETS)
        self._h_util = r.histogram(
            "fier_budget_utilization",
            "min(length, budget) / budget per probed slot-step",
            unit="ratio", buckets=_RATIO_BUCKETS)
        self._g_tau = r.gauge(
            "fier_tau", "latest top-k admission threshold (approx score)")
        self._c_probes = r.counter(
            "fier_probes_total", "introspection probes taken")

    # ------------------------------------------------------------------ cache
    def _layer_view(self, engine, cache) -> tuple[Any, Any] | None:
        """(K [B,S,Hkv,D], QuantizedKeys) logical view of the probed rest
        layer, or None when the cache has no FIER side-car."""
        from repro.core.quantize import QuantizedKeys

        rest = cache["rest"]
        if not isinstance(rest, dict) or "meta" not in rest:
            return None
        m = rest["meta"]
        lyr = self.probe_layer
        if not (0 <= lyr < rest["k"].shape[0]):
            # probe layer outside the rest (retrieval-policy) stack — e.g.
            # a reduced config whose layers are all skip layers
            return None
        K = rest["k"][lyr]
        codes, scale, zero = m.codes[lyr], m.scale[lyr], m.zero[lyr]
        if engine.paged:
            from repro.kvcache.paged import gather_block_rows

            tbl = cache["block_table"]
            K = gather_block_rows(K, tbl)
            codes = gather_block_rows(codes, tbl)
            scale = gather_block_rows(scale, tbl)
            zero = gather_block_rows(zero, tbl)
        # (slab leaves already carry the batch axis: [B, S | S//8 | S//g, H, D])
        return K, QuantizedKeys(codes, scale, zero, m.group)

    # ------------------------------------------------------------------ probe
    def probe(self, engine, cache, running_slots, step: int) -> list[ProbeRecord]:
        """Sample every running slot at this decode step (subject to
        ``every``).  Returns the new records (also appended to
        ``self.records`` / the registry / the tracer)."""
        if step % self.every:
            return []
        pol = engine.bundle.policy
        if pol is None or pol.kind != "fier":
            return []
        view = self._layer_view(engine, cache)
        if view is None:
            return []
        import jax.numpy as jnp

        from repro.core import retrieval as R
        from repro.core.quantize import QuantizedKeys

        K, qk = view
        lengths = np.asarray(cache["length"])
        budget = int(engine.current_budget)
        out: list[ProbeRecord] = []
        for slot in running_slots:
            L = int(lengths[slot])
            if L < 2 or budget < 1:
                continue
            Kb = K[slot:slot + 1]                       # [1, S, Hkv, D]
            qkb = QuantizedKeys(
                qk.codes[slot:slot + 1], qk.scale[slot:slot + 1],
                qk.zero[slot:slot + 1], qk.group)
            # probe query: the newest resident key (Hq = Hkv, rep = 1) —
            # a zero-setup stand-in with the true q's scale and layout
            q = Kb[:, L - 1].astype(jnp.float32)        # [1, Hkv, D]
            length = jnp.asarray([L], jnp.int32)
            Hkv = Kb.shape[2]
            approx = R.reduce_over_query_group(
                R.approx_scores(q, qkb), Hkv, pol.group_reduce)
            exact = R.reduce_over_query_group(
                R.exact_scores(q, Kb), Hkv, pol.group_reduce)
            k_eff = min(budget, L)
            # τ on length-masked-only scores (no ±inf guard-rail overrides)
            am = np.asarray(R.masked_scores(approx, length))   # [1, Hkv, S]
            tau = float(np.mean(np.sort(am[0], axis=-1)[:, -k_eff]))
            idx_a = np.asarray(R.select_topk(
                approx, k_eff, length, sink=pol.sink, recent=pol.recent))
            idx_e = np.asarray(R.select_topk(
                exact, k_eff, length, sink=pol.sink, recent=pol.recent))
            overlaps, masses = [], []
            em = np.asarray(R.masked_scores(exact, length))[0]  # [Hkv, S]
            scale = 1.0 / np.sqrt(float(Kb.shape[-1]))
            for h in range(Hkv):
                sel_a, sel_e = set(idx_a[0, h]), set(idx_e[0, h])
                overlaps.append(len(sel_a & sel_e) / k_eff)
                # exact softmax over the valid prefix; mass at approx picks
                s = em[h, :L] * scale
                p = np.exp(s - s.max())
                p /= p.sum()
                masses.append(float(sum(
                    p[i] for i in sel_a if 0 <= i < L)))
            rec = ProbeRecord(
                step=step, slot=int(slot), length=L, budget=budget,
                budget_utilization=k_eff / budget, tau=tau,
                oracle_overlap=float(np.mean(overlaps)),
                recaptured_mass=float(np.mean(masses)),
            )
            out.append(rec)
            self.records.append(rec)
            labels = {"slot": str(slot)}
            if getattr(engine, "_n_dp", 1) > 1:
                # mesh-sharded pool: stamp the slot's home DP shard so
                # retrieval quality can be sliced per shard
                labels["shard"] = str(engine.slot_shard(slot))
            self._h_overlap.observe(rec.oracle_overlap, **labels)
            self._h_mass.observe(rec.recaptured_mass, **labels)
            self._h_util.observe(rec.budget_utilization, **labels)
            self._g_tau.set(rec.tau, **labels)
            self._c_probes.inc()
            self.tracer.counter(
                f"introspect/slot{slot}",
                {"oracle_overlap": rec.oracle_overlap,
                 "recaptured_mass": rec.recaptured_mass,
                 "budget_utilization": rec.budget_utilization,
                 "tau": rec.tau},
                cat="introspect",
            )
        return out
