"""FIER reproduction: fine-grained 1-bit KV-cache retrieval for
long-context LLM decode, on JAX/Pallas.

The public surface below is snapshot-guarded in CI
(``tools/check_api_snapshot.py`` against ``api_snapshot.txt``): changing
``__all__`` — or the decode-backend registry in ``repro.core.policy`` —
without regenerating the snapshot fails the lint/API lane.

Submodules are imported lazily so ``import repro`` stays cheap.
"""
from __future__ import annotations

import importlib

__all__ = [
    # subpackages
    "configs",
    "core",
    "data",
    "kernels",
    "kvcache",
    "launch",
    "models",
    "obs",
    "serving",
    # decode-attention API (re-exported from repro.core.policy)
    "AttentionBackend",
    "CacheView",
    "DecodePlan",
    "PolicyConfig",
    "UnsupportedPlanError",
    "decode_attention",
    "get_backend",
    "register_backend",
]

_POLICY_NAMES = {
    "AttentionBackend",
    "CacheView",
    "DecodePlan",
    "PolicyConfig",
    "UnsupportedPlanError",
    "decode_attention",
    "get_backend",
    "register_backend",
}


def __getattr__(name: str):
    if name in _POLICY_NAMES:
        from repro.core import policy

        return getattr(policy, name)
    if name in __all__:
        return importlib.import_module(f"repro.{name}")
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
