"""mamba2-370m [ssm]: SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified] 48L d_model=1024 (attn-free) d_ff=0
vocab=50280, ssm_state=128.  d_inner = 2·1024 = 2048, head_dim 64 →
32 SSD heads.  FIER is INAPPLICABLE (no KV cache — DESIGN.md §5); the
arch runs without it and its decode state is O(1) per step natively.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_head=0,
    d_ff=0,
    vocab=50280,
    norm="rms",
    act="silu",
    use_rope=False,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    conv_kernel=4,
    ssm_chunk=128,
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
)
