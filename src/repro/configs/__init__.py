"""Config registry: ``get_config(arch_id)`` + reduced configs for smoke tests."""
from __future__ import annotations

import dataclasses

from . import (
    command_r_plus_104b,
    granite_moe_1b_a400m,
    llava_next_mistral_7b,
    mamba2_370m,
    minicpm_2b,
    olmo_1b,
    qwen3_moe_235b_a22b,
    starcoder2_3b,
    whisper_small,
    zamba2_7b,
)
from .base import SHAPES, MeshConfig, ModelConfig, PolicyDefaults, ShapeConfig, padded_vocab

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        whisper_small,
        llava_next_mistral_7b,
        olmo_1b,
        command_r_plus_104b,
        starcoder2_3b,
        minicpm_2b,
        mamba2_370m,
        granite_moe_1b_a400m,
        qwen3_moe_235b_a22b,
        zamba2_7b,
    )
}


def get_config(arch: str) -> ModelConfig:
    key = arch.replace("_", "-")
    if key not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[key]


def reduced_config(arch: str) -> ModelConfig:
    """Same family/topology, tiny dims — CPU smoke tests (full configs are
    exercised only via the ShapeDtypeStruct dry-run)."""
    c = get_config(arch)
    kv = 2 if c.n_kv_heads and c.n_kv_heads < c.n_heads else 4
    red = dict(
        n_layers=2,
        d_model=64,
        n_heads=4 if c.n_heads else 0,
        n_kv_heads=(kv if c.n_kv_heads else 0),
        d_head=16 if c.d_head else 0,
        d_ff=128 if c.d_ff else 0,
        vocab=512,
        rope_theta=min(c.rope_theta, 1e4),
    )
    if c.family == "moe":
        red.update(n_experts=4, topk_experts=2, d_ff=64)
    if c.family in ("ssm", "hybrid"):
        red.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16, n_layers=4)
    if c.family == "hybrid":
        red.update(attn_every=2, n_heads=4, n_kv_heads=4, d_head=32, d_ff=128)
    if c.family == "encdec":
        red.update(n_enc_layers=2, enc_ctx=16, max_target_positions=128)
    if c.family == "vlm":
        red.update(n_vision_tokens=8)
    return dataclasses.replace(c, **red)


# long_500k applicability (DESIGN.md §5): skipped only for whisper-small
# (family-bounded decoder positions); FIER-enabled attention archs run it
# because FIER decode is linear-scan + O(budget) attention.
def shape_cells(arch: str) -> list[str]:
    cells = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    if arch.replace("_", "-") == "whisper-small":
        cells.remove("long_500k")
    return cells


__all__ = [
    "ARCHS",
    "SHAPES",
    "MeshConfig",
    "ModelConfig",
    "PolicyDefaults",
    "ShapeConfig",
    "get_config",
    "padded_vocab",
    "reduced_config",
    "shape_cells",
]
