"""qwen3-moe-235b-a22b [moe]: 128 experts, top-8, 94 layers.

[hf:Qwen/Qwen3-30B-A3B; hf] 94L d_model=4096 64H (GQA kv=4) d_ff=1536
(per expert) vocab=151936, MoE 128e top-8.  d_head=128 (q/k projections
are d_model → n_heads·128, wider than d_model — Qwen3 style).  Deviation
noted: Qwen3 applies QK-norm; we omit it (orthogonal to FIER; recorded per
DESIGN.md §2).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_head=128,
    d_ff=1536,
    vocab=151936,
    norm="rms",
    act="silu",
    rope_theta=1e6,
    n_experts=128,
    topk_experts=8,
    param_dtype="bfloat16",  # 235B: bf16 params + fp32 master in optimizer
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)
