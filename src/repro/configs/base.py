"""Config system: ModelConfig (architecture), ShapeConfig (workload),
MeshConfig (distribution), RunConfig (composition + CLI overrides)."""
from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str            # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    norm: str = "rms"      # rms | layernorm | nonparametric
    act: str = "silu"      # silu (SwiGLU) | gelu
    rope_theta: float = 1e4
    use_rope: bool = True
    qkv_bias: bool = False
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    topk_experts: int = 0
    capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    conv_kernel: int = 4
    ssm_chunk: int = 128
    # hybrid (Zamba2): one shared attention block applied every ``attn_every``
    attn_every: int = 0
    # enc-dec (Whisper)
    n_enc_layers: int = 0
    enc_ctx: int = 0        # encoder frames (audio stub length)
    max_target_positions: int = 0  # bounded decoder (whisper: 448 by family)
    # VLM stub
    n_vision_tokens: int = 0
    # precision
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # notes from the source config
    source: str = ""

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND math."""
        d, ff, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        emb = V * d * (1 if self.tie_embeddings else 2)
        qkv = d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head
        attn = qkv + self.n_heads * self.d_head * d
        mlp_mult = 3 if self.act == "silu" else 2
        if self.family == "moe":
            mlp = self.n_experts * mlp_mult * d * ff + d * self.n_experts
        else:
            mlp = mlp_mult * d * ff
        if self.family == "ssm":
            blk = self._ssm_block_params()
            return emb + L * blk
        if self.family == "hybrid":
            blk = self._ssm_block_params()
            shared = attn * 4 + mlp_mult * d * ff  # concat(2d) shared block
            return emb + L * blk + shared
        if self.family == "encdec":
            enc = self.n_enc_layers * (attn + mlp)
            dec = L * (2 * attn + mlp)  # self + cross
            return emb // 2 + enc + dec + self.enc_ctx * d  # tied emb + pos
        return emb + L * (attn + mlp)

    def _ssm_block_params(self) -> int:
        d, di, N = self.d_model, self.d_inner, self.ssm_state
        H = self.n_ssm_heads
        in_proj = d * (2 * di + 2 * N + H)
        conv = (di + 2 * N) * self.conv_kernel
        out = di * d
        return in_proj + conv + out + 2 * H + di  # A_log, D, norm

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only) for 6·N_active·D."""
        if self.family != "moe":
            return self.param_count()
        d, ff, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        emb = V * d * (1 if self.tie_embeddings else 2)
        qkv = d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head
        attn = qkv + self.n_heads * self.d_head * d
        mlp_mult = 3 if self.act == "silu" else 2
        mlp = self.topk_experts * mlp_mult * d * ff + d * self.n_experts
        return emb + L * (attn + mlp)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # train | prefill | decode
    # decode shapes: cache holds ``seq_len`` tokens, one new token is decoded


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    shape: tuple[int, ...] = (16, 16)
    axes: tuple[str, ...] = ("data", "model")
    # which mesh axes shard what
    batch_axes: tuple[str, ...] = ("data",)       # + 'pod' prepended if present
    tensor_axis: str = "model"
    fsdp_axes: tuple[str, ...] = ()               # param/optimizer sharding (ZeRO)
    seq_axes_decode: tuple[str, ...] = ("model",)  # KV-cache sequence sharding


@dataclasses.dataclass(frozen=True)
class PolicyDefaults:
    kind: str = "fier"
    budget: int = 4096
    group: int = 32
    page: int = 16
    skip_layers: int = 2


def pad_to(x: int, multiple: int) -> int:
    return -(-x // multiple) * multiple


def padded_vocab(cfg: ModelConfig, multiple: int = 256) -> int:
    return pad_to(cfg.vocab, multiple)
