"""llava-next-mistral-7b [vlm]: Mistral-7B backbone, anyres tiling stubbed.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] 32L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=32000.  The vision tower + anyres tiling is a
STUB per assignment: ``input_specs()`` provides precomputed patch
embeddings [B, n_vision_tokens, d_model] which the backbone consumes as a
prefix of the sequence.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab=32000,
    norm="rms",
    act="silu",
    rope_theta=1e6,
    n_vision_tokens=576,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)
