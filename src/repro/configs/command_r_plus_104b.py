"""command-r-plus-104b [dense]: GQA, no biases.

[hf:CohereForAI/c4ai-command-r-v01; unverified] 64L d_model=12288 96H
(GQA kv=8) d_ff=33792 vocab=256000.  LayerNorm (bias-free), SwiGLU,
RoPE theta 75e6, tied embeddings.  Deviation noted: the HF model uses
parallel attn+FFN blocks; we use sequential blocks (same FLOPs/params to
first order) — recorded here per DESIGN.md §2.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_head=128,
    d_ff=33792,
    vocab=256000,
    norm="layernorm",
    act="silu",
    rope_theta=75e6,
    tie_embeddings=True,
    param_dtype="bfloat16",  # 104B: bf16 params + fp32 master in optimizer
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
)
