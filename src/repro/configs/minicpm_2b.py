"""minicpm-2b [dense]: llama-like; trains with the WSD schedule.

[arXiv:2404.06395; hf] 40L d_model=2304 36H (GQA kv=36, i.e. MHA)
d_ff=5760 vocab=122753.  36 heads is NOT divisible by the 16-way model
axis — this arch exercises the flattened-hidden-dim sharding path
(DESIGN.md §4).  The WSD (warmup-stable-decay) schedule is wired in
``repro.optim.schedules`` and selected by ``train.py --arch minicpm-2b``.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_head=64,
    d_ff=5760,
    vocab=122753,
    norm="rms",
    act="silu",
    tie_embeddings=True,
    source="arXiv:2404.06395; hf",
)
