"""starcoder2-3b [dense]: GQA kv=2, RoPE.

[arXiv:2402.19173; hf] 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152.  LayerNorm with biases, GeLU MLP, qkv biases, tied embeddings.
kv=2 makes the GQA query-group score reduction (DESIGN.md §2) maximally
load-bearing for FIER here.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_head=128,
    d_ff=12288,
    vocab=49152,
    norm="layernorm",
    act="gelu",
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
    source="arXiv:2402.19173; hf",
)
