"""whisper-small [audio]: enc-dec, conv frontend stubbed (precomputed frames).

[arXiv:2212.04356; unverified] 12L d_model=768 12H (GQA kv=12) d_ff=3072
vocab=51865.  Decoder positions bounded at 448 by family design; encoder
audio context 1500 frames.  Norm: LayerNorm; act: GeLU; learned positions
(no RoPE).  long_500k is skipped for this arch (DESIGN.md §5).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,
    n_enc_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_head=64,
    d_ff=3072,
    vocab=51865,
    norm="layernorm",
    act="gelu",
    use_rope=False,
    qkv_bias=True,
    tie_embeddings=True,
    enc_ctx=1500,
    max_target_positions=448,
    source="arXiv:2212.04356; unverified",
)
