"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242; unverified] 81L d_model=3584 32H (GQA kv=32)
d_ff=14336 vocab=32000, ssm_state=64.  One *weight-shared* attention+MLP
block is applied every ``attn_every``=6 Mamba2 layers, consuming
concat(hidden, original embedding) (width 2·d_model) per the Zamba2
design.  The shared block's KV cache is the only attention cache in the
model → FIER applies exactly there (DESIGN.md §5).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_head=112,
    d_ff=14336,
    vocab=32000,
    norm="rms",
    act="silu",
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    conv_kernel=4,
    # chunk 64 (not 128): the SSD intra-chunk decay tensor is
    # [B, nc, c, c, H] — with H=112 heads, c=128 costs 3.8 GB/layer/device
    # at train_4k; c=64 quarters it (EXPERIMENTS.md §Dry-run memory notes)
    ssm_chunk=64,
    attn_every=6,
    tie_embeddings=True,
    source="arXiv:2411.15242; unverified",
)
