"""olmo-1b [dense]: non-parametric LayerNorm (no learnable scale/bias).

[arXiv:2402.00838; hf] 16L d_model=2048 16H (GQA kv=16, i.e. MHA)
d_ff=8192 vocab=50304.  SwiGLU; RoPE; weight-tied embeddings.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=8192,
    vocab=50304,
    norm="nonparametric",
    act="silu",
    tie_embeddings=True,
    source="arXiv:2402.00838; hf",
)
